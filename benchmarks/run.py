"""Benchmark entry point: one function per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run            # everything
    PYTHONPATH=src python -m benchmarks.run --only segm_real

Prints ``name,us_per_call,derived``-style CSV per table and saves JSON
artifacts under benchmarks/artifacts/.
"""
from __future__ import annotations

import argparse


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="single_tpu|segm_synth|segm_real|stage_balance|"
                         "lm_balance|roofline|kernels|serving|"
                         "serving_stream")
    args = ap.parse_args()

    from . import (kernel_bench, lm_pipeline_balance, pipeline_serving,
                   roofline, segm_real, segm_synth, serving_bench,
                   single_tpu_curve, stage_balance)

    jobs = {
        "single_tpu": lambda: (single_tpu_curve.run(),
                               single_tpu_curve.run_real()),
        "segm_synth": segm_synth.run,
        "segm_real": segm_real.run,
        "stage_balance": stage_balance.run,
        "lm_balance": lm_pipeline_balance.run,
        "roofline": roofline.run,
        "kernels": kernel_bench.run,
        "serving": pipeline_serving.run,
        "serving_stream": serving_bench.run,
    }
    if args.only:
        jobs[args.only]()
        return
    for name, fn in jobs.items():
        print(f"\n{'='*70}\n== {name}\n{'='*70}")
        fn()


if __name__ == "__main__":
    main()
