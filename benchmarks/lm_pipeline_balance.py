"""Beyond-paper: the paper's segmentation on the assigned LM architectures.

For each arch x stage-count: max-stage params (the pipeline pacing metric)
under SEGM_COMP-style equal-count splitting vs SEGM_BALANCED, and the
implied pipeline utilization gain.  This is the LM-scale analogue of paper
Fig. 10 — embedding/LM-head stages play the role of the compiler's
overloaded segments."""
from __future__ import annotations

from repro import configs
from repro.api import DeploymentSpec, plan
from repro.models.lm_graph import lm_layer_graph

from .common import emit


def run() -> None:
    rows = []
    for arch in configs.arch_ids():
        cfg = configs.get(arch).config()
        g = lm_layer_graph(cfg)
        for n in (4, 8, 16):
            if n >= g.depth:
                continue
            comp = plan(DeploymentSpec(stages=n, strategy="comp"), graph=g)
            bal = plan(DeploymentSpec(stages=n,
                                      strategy="balanced_norefine"), graph=g)
            mx_c = max(comp.stage_params)
            mx_b = max(bal.stage_params)
            rows.append({
                "arch": arch, "stages": n,
                "comp_max_mparams": round(mx_c / 1e6, 1),
                "balanced_max_mparams": round(mx_b / 1e6, 1),
                "max_stage_reduction": round(mx_c / mx_b, 3),
                "pipeline_util_comp": round(
                    g.total_params / (n * mx_c), 3),
                "pipeline_util_balanced": round(
                    g.total_params / (n * mx_b), 3),
            })
    emit("lm_pipeline_balance", rows,
         ["arch", "stages", "comp_max_mparams", "balanced_max_mparams",
          "max_stage_reduction", "pipeline_util_comp",
          "pipeline_util_balanced"])
    gains = [r["max_stage_reduction"] for r in rows]
    print(f"derived: balanced reduces the pacing stage by up to "
          f"{max(gains):.2f}x (mean {sum(gains)/len(gains):.2f}x) across "
          f"{len(rows)} (arch x stages) cells")


if __name__ == "__main__":
    run()
