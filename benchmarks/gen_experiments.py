"""Generate the §Dry-run/§Roofline tables of EXPERIMENTS.md from the sweep
artifacts (baseline sweep in artifacts/dryrun, optimized in
artifacts/dryrun_opt)."""
from __future__ import annotations

import glob
import json
import os
import sys

ART = os.path.join(os.path.dirname(os.path.abspath(__file__)), "artifacts")


def load(d):
    out = {}
    for p in sorted(glob.glob(os.path.join(ART, d, "*.json"))):
        r = json.load(open(p))
        out[(r["arch"], r["shape"], r["mesh"])] = r
    return out


def fmt_ms(s):
    return f"{s*1e3:,.1f}"


def roofline_table(recs, mesh="16x16"):
    lines = ["| arch | shape | C (ms) | M (ms) | X (ms) | dominant | useful | GiB/dev | fits |",
             "|---|---|---:|---:|---:|---|---:|---:|---|"]
    for (arch, shape, m), r in sorted(recs.items()):
        if m != mesh:
            continue
        if r.get("status") == "skipped":
            lines.append(f"| {arch} | {shape} | — | — | — | *skipped:"
                         f" sub-quadratic-only shape* | — | — | — |")
            continue
        t = r["roofline"]
        uf = r.get("useful_flops_ratio")
        lines.append(
            f"| {arch} | {shape} | {fmt_ms(t['compute_s'])} | "
            f"{fmt_ms(t['memory_s'])} | {fmt_ms(t['collective_s'])} | "
            f"{t['dominant'].replace('_s','')} | "
            f"{uf:.3f} | {r['device_bytes']/2**30:.2f} | "
            f"{'yes' if r['fits_hbm'] else 'NO'} |")
    return "\n".join(lines)


def totals(recs, mesh="16x16"):
    tot = {}
    for (arch, shape, m), r in recs.items():
        if m != mesh or r.get("status") != "ok":
            continue
        t = r["roofline"]
        tot[(arch, shape)] = t["compute_s"] + t["memory_s"] + t["collective_s"]
    return tot


def main():
    base = load("dryrun")
    opt = load("dryrun_opt")
    print("## Optimized roofline table (single pod, 16x16)\n")
    print(roofline_table(opt, "16x16"))
    print("\n## Optimized roofline table (multi-pod, 2x16x16)\n")
    print(roofline_table(opt, "2x16x16"))
    # improvement summary
    tb, to = totals(base), totals(opt)
    rows = []
    for k in sorted(to):
        if k in tb and to[k] > 0:
            rows.append((tb[k] / to[k], k, tb[k], to[k]))
    rows.sort(reverse=True)
    print("\n## First-green vs optimized (sum of terms, single pod)\n")
    print("| arch | shape | first-green (ms) | optimized (ms) | speedup |")
    print("|---|---|---:|---:|---:|")
    for sp, (a, s), b, o in rows:
        print(f"| {a} | {s} | {fmt_ms(b)} | {fmt_ms(o)} | {sp:.2f}x |")
    import statistics
    sps = [r[0] for r in rows]
    print(f"\ngeomean speedup: "
          f"{statistics.geometric_mean(sps):.2f}x over {len(sps)} cells; "
          f"max {max(sps):.1f}x")


if __name__ == "__main__":
    main()
