"""Kernel micro-benchmarks: interpret-mode correctness timing is
meaningless, so this reports the *oracle* (jnp) wall time on CPU as
``us_per_call`` plus the kernels' analytic VMEM working sets — the numbers
a TPU deployment would tile against."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ref

from .common import emit


def _time(fn, *args, iters=3):
    fn(*args)                             # compile
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6


def run() -> None:
    rng = np.random.default_rng(0)
    rows = []

    # int8 matmul (Edge TPU analogue): 512^3
    m = k = n = 512
    x = jnp.asarray(rng.integers(-128, 128, (m, k), dtype=np.int8))
    w = jnp.asarray(rng.integers(-128, 128, (k, n), dtype=np.int8))
    f = jax.jit(ref.matmul_qi8_ref)
    us = _time(f, x, w)
    vmem = (128 * 128 * 2 + 128 * 128 * 4) / 1024
    rows.append({"name": "matmul_qi8_512", "us_per_call": round(us, 1),
                 "derived": f"tile_vmem_kib={vmem:.0f}"})

    # flash attention 1x8h 1k x 1k x 128
    q = jnp.asarray(rng.normal(size=(1, 8, 1024, 128)), jnp.float32)
    kv = jnp.asarray(rng.normal(size=(1, 8, 1024, 128)), jnp.float32)
    f = jax.jit(lambda a, b, c: ref.flash_attention_ref(a, b, c, True))
    us = _time(f, q, kv, kv)
    rows.append({"name": "flash_attn_1k", "us_per_call": round(us, 1),
                 "derived": "tile=(128,128)x128d, vmem<1MiB"})

    # rglru scan 2x1024x1024
    a = jnp.asarray(rng.uniform(0.5, 1, (2, 1024, 1024)), jnp.float32)
    g = jnp.asarray(rng.normal(size=(2, 1024, 1024)) * 0.1, jnp.float32)
    h0 = jnp.zeros((2, 1024), jnp.float32)
    f = jax.jit(ref.rglru_scan_ref)
    us = _time(f, a, g, h0)
    rows.append({"name": "rglru_scan_1k", "us_per_call": round(us, 1),
                 "derived": "chunk=256, carry_vmem=B*R*4"})

    # rwkv6 scan 1x8hx512x64
    r = jnp.asarray(rng.normal(size=(1, 8, 512, 64)), jnp.float32)
    kk = jnp.asarray(rng.normal(size=(1, 8, 512, 64)) * 0.2, jnp.float32)
    v = jnp.asarray(rng.normal(size=(1, 8, 512, 64)), jnp.float32)
    w2 = jnp.asarray(rng.uniform(0.8, 1, (1, 8, 512, 64)), jnp.float32)
    u = jnp.asarray(rng.normal(size=(8, 64)) * 0.1, jnp.float32)
    s0 = jnp.zeros((1, 8, 64, 64), jnp.float32)
    f = jax.jit(ref.rwkv6_scan_ref)
    us = _time(f, r, kk, v, w2, u, s0)
    rows.append({"name": "rwkv6_scan_512", "us_per_call": round(us, 1),
                 "derived": "state_vmem=64*64*4=16KiB/head"})

    emit("kernel_bench", rows, ["name", "us_per_call", "derived"])


if __name__ == "__main__":
    run()
