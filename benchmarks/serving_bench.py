"""Streaming vs batch-barrier serving benchmark (ISSUE 3 acceptance).

The streaming executor admits every request into the stage queues as it
arrives (``PipelineExecutor.submit``); the batch-barrier baseline is the
historical serving shape — admit a batch, wait for the ``run_batch``
barrier, admit the next — which drains and refills the pipeline at every
batch boundary (a bubble of ~one pipeline fill per batch).

Per Table-1 model: take the ``balanced`` plan's modeled stage times at
``--stages`` stages, scale them so the slowest stage is a few ms, and play
them as simulated-latency stages.  At **equal max queue depth** (window W
in flight for streaming == batch size W for the barrier):

* **sustained throughput** — closed loop, N items, best of R rounds;
* **latency percentiles** — open loop at several offered loads (fraction
  of the pipeline's pacing capacity ``1/max_stage``), p50/p95/p99 per
  mode; at high load the barrier server's fill bubbles show up directly
  as queueing delay.

A dynamic micro-batching section rides along: a stage with a fixed
per-call dispatch overhead plus a per-row cost, streamed at window W with
``microbatch=k`` vs without — the amortization the executor's
shape-bucketed aggregator buys on real concurrent traffic.

Acceptance (recorded in ``BENCH_serving.json`` at the repo root):
streaming sustains >= 1.3x the barrier throughput at equal queue depth on
every >=4-stage model pipeline benched, and ``run_batch`` outputs remain
bit-identical (asserted in tests/test_streaming_executor.py).

    PYTHONPATH=src python -m benchmarks.serving_bench
    PYTHONPATH=src python -m benchmarks.serving_bench --smoke
"""
from __future__ import annotations

import argparse
import queue as queue_mod
import threading
import time
from collections import deque
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.api import DeploymentSpec, plan
from repro.core import PipelineExecutor, simulated_stage
from repro.models.cnn import REAL_CNNS
from repro.serving import latency_percentiles

from .common import emit, write_bench


DEFAULT_MODELS = ("ResNet50", "InceptionV3", "MobileNet", "Xception")
STAGES = 6
WINDOW = 6          # equal max queue depth: W in flight == batch size W
TARGET_MAX_S = 3e-3  # scale the slowest modeled stage to ~3 ms
LOADS = (0.6, 0.9)


def model_stage_latencies(name: str, stages: int) -> List[float]:
    """Modeled per-stage seconds of the balanced plan, rescaled so the
    pacing stage is TARGET_MAX_S (keeps a full bench run in seconds)."""
    g = REAL_CNNS[name]().to_layer_graph()
    pl = plan(DeploymentSpec(stages=stages, strategy="balanced_norefine"),
              graph=g)
    times = [t for t in pl.stage_times_s if t is not None]
    scale = TARGET_MAX_S / max(times)
    return [t * scale for t in times]


# ---------------------------------------------------------------------------
# closed loop (sustained throughput at fixed queue depth)
# ---------------------------------------------------------------------------
def closed_loop_streaming(ex: PipelineExecutor, n_items: int,
                          window: int) -> Tuple[float, List[float]]:
    """Keep exactly `window` items in flight; returns (req/s, latencies)."""
    futs: deque = deque()
    lats: List[float] = []
    submitted = 0
    t0 = time.perf_counter()
    while submitted < min(window, n_items):
        futs.append((ex.submit(submitted), time.perf_counter()))
        submitted += 1
    while futs:
        fut, ts = futs.popleft()
        fut.result(timeout=60)
        lats.append(time.perf_counter() - ts)
        if submitted < n_items:
            futs.append((ex.submit(submitted), time.perf_counter()))
            submitted += 1
    dt = time.perf_counter() - t0
    return n_items / dt, lats


def closed_loop_barrier(ex: PipelineExecutor, n_items: int,
                        window: int) -> Tuple[float, List[float]]:
    """Admit a batch of `window`, wait for the barrier, repeat: the
    pipeline drains and refills between batches."""
    lats: List[float] = []
    t0 = time.perf_counter()
    for off in range(0, n_items, window):
        batch = list(range(off, min(off + window, n_items)))
        tb = time.perf_counter()
        ex.run_batch(batch)
        done = time.perf_counter()
        lats.extend([done - tb] * len(batch))
    dt = time.perf_counter() - t0
    return n_items / dt, lats


# ---------------------------------------------------------------------------
# open loop (latency under an offered load)
# ---------------------------------------------------------------------------
def open_loop_streaming(fns, window: int, interval_s: float,
                        n_arrivals: int) -> List[float]:
    lats: List[float] = []
    lock = threading.Lock()
    done = threading.Event()

    def record(ts: float):
        def cb(fut):
            lat = time.perf_counter() - ts
            with lock:
                lats.append(lat)
                if len(lats) == n_arrivals:
                    done.set()
        return cb

    with PipelineExecutor(fns, queue_size=window) as ex:
        ex.run_batch([0])                  # warm the workers
        nxt = time.perf_counter()
        for i in range(n_arrivals):
            delay = nxt - time.perf_counter()
            if delay > 0:
                time.sleep(delay)
            ts = time.perf_counter()
            ex.submit(i).add_done_callback(record(ts))
            nxt += interval_s
        done.wait(timeout=120)
    return lats


def open_loop_barrier(fns, window: int, interval_s: float,
                      n_arrivals: int) -> List[float]:
    """Batch-synchronous server under the same arrivals: whatever arrived
    while the previous batch ran forms the next batch (<= window)."""
    arrivals: "queue_mod.Queue[Tuple[float, int]]" = queue_mod.Queue()

    def producer():
        nxt = time.perf_counter()
        for i in range(n_arrivals):
            delay = nxt - time.perf_counter()
            if delay > 0:
                time.sleep(delay)
            arrivals.put((time.perf_counter(), i))
            nxt += interval_s

    lats: List[float] = []
    with PipelineExecutor(fns, queue_size=window) as ex:
        ex.run_batch([0])                  # warm the workers
        th = threading.Thread(target=producer, daemon=True)
        th.start()
        served = 0
        while served < n_arrivals:
            batch = [arrivals.get(timeout=60)]
            while len(batch) < window:
                try:
                    batch.append(arrivals.get_nowait())
                except queue_mod.Empty:
                    break
            ex.run_batch([i for _, i in batch])
            now = time.perf_counter()
            lats.extend(now - ts for ts, _ in batch)
            served += len(batch)
        th.join(timeout=5)
    return lats


# ---------------------------------------------------------------------------
# per-model streaming vs barrier
# ---------------------------------------------------------------------------
def bench_model(name: str, stages: int, window: int, n_items: int,
                rounds: int, loads: Sequence[float],
                n_arrivals: int) -> Dict:
    latencies = model_stage_latencies(name, stages)
    fns = [simulated_stage(t) for t in latencies]
    max_t = max(latencies)

    thr_stream = thr_barrier = 0.0
    lat_stream: List[float] = []
    lat_barrier: List[float] = []
    with PipelineExecutor(fns, queue_size=window) as ex:
        ex.run_batch(list(range(window)))  # warm the workers
        for _ in range(rounds):
            t, l = closed_loop_streaming(ex, n_items, window)
            if t > thr_stream:
                thr_stream, lat_stream = t, l
            t, l = closed_loop_barrier(ex, n_items, window)
            if t > thr_barrier:
                thr_barrier, lat_barrier = t, l

    by_load = {}
    for load in loads:
        interval = max_t / load
        ls = open_loop_streaming(fns, window, interval, n_arrivals)
        lb = open_loop_barrier(fns, window, interval, n_arrivals)
        by_load[str(load)] = {
            "streaming": latency_percentiles(ls),
            "barrier": latency_percentiles(lb),
        }

    return {
        "model": name, "stages": stages, "window": window,
        "stage_ms": [round(t * 1e3, 4) for t in latencies],
        "sum_over_max": round(sum(latencies) / max_t, 3),
        "streaming_rps": round(thr_stream, 1),
        "barrier_rps": round(thr_barrier, 1),
        "speedup": round(thr_stream / thr_barrier, 3),
        "closed_loop_latency": {
            "streaming": latency_percentiles(lat_stream),
            "barrier": latency_percentiles(lat_barrier),
        },
        "open_loop_latency_by_load": by_load,
    }


# ---------------------------------------------------------------------------
# dynamic micro-batching amortization
# ---------------------------------------------------------------------------
def bench_microbatch(k: int = 8, overhead_ms: float = 1.0,
                     per_row_ms: float = 0.125,
                     n_items: int = 160) -> Dict:
    """A stage shaped like a jitted accelerator call: fixed dispatch +
    weight-load overhead per call, linear per-row compute.  Streaming at
    window k with microbatch=k stacks concurrent same-shape requests, so
    the overhead amortizes across the bucket."""
    overhead = overhead_ms / 1e3
    per_row = per_row_ms / 1e3

    def stage(x):
        time.sleep(overhead + per_row * x.shape[0])
        return x

    payloads = [np.zeros((1, 1)) for _ in range(n_items)]

    def run(**kw) -> Tuple[float, Dict]:
        with PipelineExecutor([stage], queue_size=k, **kw) as ex:
            ex.run_batch(payloads[:2])
            futs: deque = deque()
            submitted = 0
            t0 = time.perf_counter()
            while submitted < min(k, n_items):
                futs.append(ex.submit(payloads[submitted]))
                submitted += 1
            while futs:
                futs.popleft().result(timeout=60)
                if submitted < n_items:
                    futs.append(ex.submit(payloads[submitted]))
                    submitted += 1
            dt = time.perf_counter() - t0
            mb = ex.microbatch_snapshot()
        return n_items / dt, mb

    rps_single, _ = run()
    rps_mb, mb = run(microbatch=k, microbatch_wait_s=0.002)
    calls = max(1, mb["calls"][0])
    return {
        "bucket_k": k, "overhead_ms": overhead_ms,
        "per_row_ms": per_row_ms,
        "single_rps": round(rps_single, 1),
        "microbatched_rps": round(rps_mb, 1),
        "speedup": round(rps_mb / rps_single, 2),
        "mean_items_per_stacked_call": round(mb["items"][0] / calls, 2),
    }


# ---------------------------------------------------------------------------
def run(models: Optional[List[str]] = None, stages: int = STAGES,
        window: int = WINDOW, n_items: int = 120, rounds: int = 3,
        loads: Sequence[float] = LOADS, n_arrivals: int = 80,
        write: bool = True) -> Dict:
    names = models or list(DEFAULT_MODELS)
    unknown = [n for n in names if n not in REAL_CNNS]
    if unknown:
        raise SystemExit(f"unknown model(s) {unknown}; "
                         f"pick from {sorted(REAL_CNNS)}")
    results = []
    for name in names:
        r = bench_model(name, stages, window, n_items, rounds, loads,
                        n_arrivals)
        results.append(r)
        lat9 = r["open_loop_latency_by_load"].get(str(loads[-1]), {})
        p95s = lat9.get("streaming", {}).get("p95_s", 0.0) * 1e3
        p95b = lat9.get("barrier", {}).get("p95_s", 0.0) * 1e3
        print(f"{name:16s} x{stages}  stream {r['streaming_rps']:7.1f} rps "
              f"vs barrier {r['barrier_rps']:7.1f} rps "
              f"({r['speedup']:.2f}x)  p95@{loads[-1]}load "
              f"{p95s:.1f} vs {p95b:.1f} ms")

    mb = bench_microbatch(n_items=max(40, n_items))
    print(f"microbatch k={mb['bucket_k']}: {mb['microbatched_rps']:.1f} vs "
          f"{mb['single_rps']:.1f} rps ({mb['speedup']}x, "
          f"{mb['mean_items_per_stacked_call']} items/call)")

    rows = [{"name": f"serving_{r['model']}",
             "us_per_call": round(1e6 / r["streaming_rps"], 1),
             "derived": (f"speedup={r['speedup']}x,"
                         f"barrier_rps={r['barrier_rps']},"
                         f"sum_over_max={r['sum_over_max']}")}
            for r in results]
    rows.append({"name": "serving_microbatch",
                 "us_per_call": round(1e6 / mb["microbatched_rps"], 1),
                 "derived": f"speedup={mb['speedup']}x,"
                            f"items_per_call="
                            f"{mb['mean_items_per_stacked_call']}"})
    emit("serving_bench", rows, ["name", "us_per_call", "derived"])

    min_speedup = min(r["speedup"] for r in results)
    summary = {
        "note": "streaming (continuous admission, per-request futures) vs "
                "batch-barrier serving at equal max queue depth on "
                "simulated-latency pipelines built from balanced Table-1 "
                "plans; see EXPERIMENTS.md §Streaming serving",
        "config": {"stages": stages, "window": window, "n_items": n_items,
                   "rounds": rounds, "loads": list(loads),
                   "target_max_stage_ms": TARGET_MAX_S * 1e3},
        "models": results,
        "microbatch": mb,
        "acceptance": {
            "min_streaming_vs_barrier_speedup": min_speedup,
            "floor_met": bool(min_speedup >= 1.3),
            "pipeline_stages": stages,
            "equal_queue_depth": window,
        },
    }
    if write:
        write_bench("serving", summary)
    print(f"min streaming/barrier speedup: {min_speedup:.2f}x "
          f"(floor 1.3x: {'met' if min_speedup >= 1.3 else 'MISSED'})")
    return summary


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--models", nargs="*", default=None,
                    help="subset of Table-1 names")
    ap.add_argument("--stages", type=int, default=STAGES)
    ap.add_argument("--smoke", action="store_true",
                    help="fast CI mode: 2 models, few items, no "
                         "BENCH_serving.json write, no acceptance assert")
    args = ap.parse_args()
    if args.smoke:
        summary = run(models=args.models or ["MobileNet", "ResNet50"],
                      stages=args.stages, n_items=36, rounds=1,
                      loads=(0.8,), n_arrivals=24, write=False)
        # smoke still sanity-checks that streaming beats the barrier at all
        assert summary["acceptance"]["min_streaming_vs_barrier_speedup"] \
            > 1.0, summary["acceptance"]
        return
    summary = run(models=args.models, stages=args.stages)
    assert summary["acceptance"]["floor_met"], summary["acceptance"]


if __name__ == "__main__":
    main()
