"""Multi-tenant fleet benchmark (ISSUE 9 acceptance).

One shared homogeneous pool, three models with skewed SLOs and demand:
``vision`` (a heavier synthetic CNN with a high throughput SLO) next to
``detect`` / ``embed`` (lighter models with tight p95 targets).  The
same traffic is played through two arms built from the *same*
:class:`~repro.fleet.scenario.FleetScenario` machinery:

* **fleet** — the solved pool split (:func:`~repro.fleet.plan_fleet`
  minimax DP over the joint cuts+replicas oracle) with the
  :class:`~repro.fleet.autoscale.FleetAutoscaler` ticking once per
  traffic window;
* **static** — the naive baseline: an equal split pinned via
  ``fixed_counts``, no autoscaler.

Phase 1 is the skew the solver was told about (vision-heavy); phase 2 is
a mid-run traffic shift (detect surges, vision recedes) the *solver
never saw* — only the autoscaler can chase it, by moving a device from
vision to detect through ``Deployment.reconfigure`` hot-swaps.

Acceptance (recorded in ``BENCH_fleet.json`` at the repo root):

* worst-model SLO attainment under the fleet arm strictly better than
  the static equal split (packing + autoscaling must pay);
* the phase-2 shift triggers >= 1 *committed* device reallocation;
* 0 lost and 0 misordered requests per member across every hot-swap
  (the drain contract, audited at merge exit via the router's
  completion tap).

    PYTHONPATH=src python -m benchmarks.fleet_bench            # full, writes JSON
    PYTHONPATH=src python -m benchmarks.fleet_bench --smoke    # CI: small, no write
"""
from __future__ import annotations

import argparse
from typing import Any, Dict

from repro.api import DeploymentSpec
from repro.fleet import FleetMemberSpec, FleetSpec
from repro.fleet.scenario import FleetScenario, TrafficPhase, summarize_member

from .common import emit, write_bench

POOL_DEVICES = 9

# per-member service-time truth (whole-model sleep budget, seconds)
SERVICE_SUM_S = {"vision": 10e-3, "detect": 5e-3, "embed": 5e-3}

# traffic (requests per window): phase 1 is the solver's skew, phase 2
# shifts demand onto detect — the move the autoscaler must make
RATES_BASE = {"vision": 12, "detect": 3, "embed": 3}
RATES_SHIFT = {"vision": 4, "detect": 8, "embed": 3}


def fleet_spec() -> FleetSpec:
    """The 3-model skewed mix.  SLO scales are chosen against the
    analytic cost model (which prices the pool split) so the solved
    split is genuinely skewed: vision's throughput SLO needs most of
    the pool, detect/embed fit on one device each with donor headroom
    left for the autoscaler."""
    members = (
        FleetMemberSpec(
            name="vision",
            spec=DeploymentSpec(model="synthetic-cnn:16",
                                slo_p95_ms=38.0,
                                slo_throughput_rps=12000.0,
                                deadline_ms=500.0,
                                max_wait_s=2e-3),
            share=3.0),
        FleetMemberSpec(
            name="detect",
            spec=DeploymentSpec(model="synthetic-cnn:12",
                                slo_p95_ms=25.0,
                                slo_throughput_rps=2000.0,
                                deadline_ms=500.0,
                                max_wait_s=2e-3),
            share=1.0),
        FleetMemberSpec(
            name="embed",
            spec=DeploymentSpec(model="synthetic-cnn:12",
                                slo_p95_ms=25.0,
                                slo_throughput_rps=2000.0,
                                deadline_ms=500.0,
                                max_wait_s=2e-3),
            share=1.0),
    )
    return FleetSpec(members=members, device_budget=POOL_DEVICES)


def equal_counts(spec: FleetSpec) -> Dict[str, int]:
    n = len(spec.members)
    base, rem = divmod(POOL_DEVICES, n)
    return {m.name: base + (1 if i < rem else 0)
            for i, m in enumerate(spec.members)}


def run_arm(arm: str, windows_base: int, windows_shift: int
            ) -> Dict[str, Any]:
    """One full scenario pass; ``arm`` is 'fleet' (solved split +
    autoscaler) or 'static' (equal fixed split, no autoscaler)."""
    spec = fleet_spec()
    sc = FleetScenario(spec, SERVICE_SUM_S)
    if arm == "fleet":
        fleet = sc.deploy()
    else:
        fleet = sc.deploy(fixed_counts=equal_counts(spec),
                          autoscale=False)
    counts_before = fleet.device_counts()
    with fleet:
        metrics = sc.drive(
            fleet,
            [TrafficPhase(windows=windows_base, rates=RATES_BASE),
             TrafficPhase(windows=windows_shift, rates=RATES_SHIFT)])
        counts_after = fleet.device_counts()
        committed = (fleet.autoscaler.committed_moves
                     if fleet.autoscaler is not None else 0)
        events = [e for e in (fleet.autoscaler.events
                              if fleet.autoscaler is not None else [])
                  if e["event"] in ("move", "commit", "rollback")]
    att = sc.attainment(metrics)
    return {
        "arm": arm,
        "device_counts_before": counts_before,
        "device_counts_after": counts_after,
        "committed_moves": committed,
        "autoscaler_events": events,
        "members": {n: summarize_member(m) for n, m in metrics.items()},
        "attainment": {n: round(a, 4) for n, a in att.items()},
        "worst_attainment": round(FleetScenario.worst(att), 4),
        "audit": sc.audit(),
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--windows", type=int, default=10,
                    help="windows per traffic phase (full mode)")
    ap.add_argument("--smoke", action="store_true",
                    help="small run, functional asserts only, no JSON")
    args = ap.parse_args()
    wb, ws = (3, 5) if args.smoke else (args.windows, args.windows + 2)

    arms = {}
    for arm in ("fleet", "static"):
        print(f"\n=== {arm} arm ({wb}+{ws} windows) ===")
        r = run_arm(arm, wb, ws)
        arms[arm] = r
        print(f"  split {r['device_counts_before']} -> "
              f"{r['device_counts_after']}  "
              f"committed_moves={r['committed_moves']}")
        print(f"  attainment {r['attainment']}  "
              f"worst={r['worst_attainment']}")

    rows = []
    for arm, r in arms.items():
        for name, m in r["members"].items():
            rows.append({"arm": arm, "member": name,
                         "devices": r["device_counts_after"][name],
                         "attainment": r["attainment"][name],
                         "p95_ms": m["p95_ms"],
                         "submitted": m["submitted"],
                         "shed": m["shed"],
                         "deadline_exceeded": m["deadline_exceeded"]})
    emit("fleet_attainment", rows,
         ["arm", "member", "devices", "attainment", "p95_ms",
          "submitted", "shed", "deadline_exceeded"])

    # drain contract holds in every arm, across every hot-swap
    for arm, r in arms.items():
        for name, a in r["audit"].items():
            assert a["lost"] == 0, (arm, name, a)
            assert a["misordered"] == 0, (arm, name, a)
    # the solver's split is genuinely skewed (not the equal baseline)
    fc = arms["fleet"]["device_counts_before"]
    assert fc != arms["static"]["device_counts_before"], fc
    assert fc["vision"] > fc["detect"], fc

    summary = {
        "pool_devices": POOL_DEVICES,
        "service_sum_ms": {n: s * 1e3 for n, s in SERVICE_SUM_S.items()},
        "rates_base": RATES_BASE,
        "rates_shift": RATES_SHIFT,
        "windows": {"base": wb, "shift": ws},
        "arms": arms,
        "worst_attainment": {a: r["worst_attainment"]
                             for a, r in arms.items()},
    }

    if args.smoke:
        print("\nsmoke OK (no JSON written)")
        return

    # full-mode acceptance: packing + autoscaling must actually pay
    assert (arms["fleet"]["worst_attainment"]
            > arms["static"]["worst_attainment"]), summary["worst_attainment"]
    assert arms["fleet"]["committed_moves"] >= 1, \
        arms["fleet"]["autoscaler_events"]
    write_bench("fleet", summary)


if __name__ == "__main__":
    main()
