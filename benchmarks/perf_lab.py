"""Perf-iteration harness (§Perf): lower one cell with variant knobs and
print the three roofline terms — the measure step of the
hypothesis -> change -> measure -> validate loop.

    PYTHONPATH=src python -m benchmarks.perf_lab --arch qwen3-1.7b \
        --shape decode_32k --variant cache_seq
"""
import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))

import argparse      # noqa: E402
import json          # noqa: E402

from repro.launch import dryrun as dr    # noqa: E402


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--variant", default="baseline",
                    help="comma-separated knobs, applied via env (see "
                         "repro.launch.variants)")
    ap.add_argument("--tag", default=None)
    args = ap.parse_args()

    os.environ["REPRO_VARIANT"] = args.variant
    rec = dr.dryrun_cell(args.arch, args.shape, args.multi_pod)
    rec["variant"] = args.variant
    out = os.path.join("benchmarks/artifacts/perf",
                       f"{args.tag or args.arch}_{args.shape}_"
                       f"{args.variant.replace(',', '+')}.json")
    os.makedirs(os.path.dirname(out), exist_ok=True)
    with open(out, "w") as f:
        json.dump(rec, f, indent=1)
    t = rec["roofline"]
    print(f"RESULT {args.arch} {args.shape} [{args.variant}]: "
          f"C={t['compute_s']*1e3:.1f}ms M={t['memory_s']*1e3:.1f}ms "
          f"X={t['collective_s']*1e3:.1f}ms useful="
          f"{rec.get('useful_flops_ratio')}")


if __name__ == "__main__":
    main()
