"""End-to-end serving benchmark: real JAX stage execution through the
host-threaded pipeline for an LM smoke model, comp vs balanced plans
(throughput + stage balance), mirroring the paper's deployment.

Also hosts the executor steady-state microbenchmark: the persistent
PipelineExecutor (long-lived workers, reusable queues, zero threads per
batch) vs a seed-style executor that spawns one thread per stage per batch —
the paper's Fig. 5 shape, many small camera batches.  Stage fns come from a
PlacementPlan; replicated-stage throughput is measured in
benchmarks/placement_bench.py."""
from __future__ import annotations

import math
import queue as queue_mod
import threading
import time

import jax

from repro import configs
from repro.api import DeploymentSpec, deploy
from repro.configs.common import concrete_batch
from repro.core.pipeline import (PipelineExecutor, simulated_stage,
                                 stage_balance_metrics)
from repro.launch.serve import make_stage_fns
from repro.launch.pipeline_spmd import stage_block_counts
from repro.models import api, lm_graph

from .common import emit

_SENTINEL = object()


class _SeedExecutor:
    """Seed-equivalent executor: one fresh thread per stage per batch, fresh
    queues per batch (the pre-refactor PipelineExecutor, kept here as the
    before/after baseline)."""

    def __init__(self, stage_fns, queue_size: int = 64):
        self.stage_fns = list(stage_fns)
        self.queue_size = queue_size

    def run_batch(self, inputs):
        n = len(self.stage_fns)
        qs = [queue_mod.Queue(self.queue_size) for _ in range(n + 1)]

        def worker(i):
            fn = self.stage_fns[i]
            while True:
                item = qs[i].get()
                if item is _SENTINEL:
                    qs[i + 1].put(_SENTINEL)
                    return
                qs[i + 1].put(fn(item))

        threads = [threading.Thread(target=worker, args=(i,), daemon=True)
                   for i in range(n)]
        for t in threads:
            t.start()
        for x in inputs:
            qs[0].put(x)
        qs[0].put(_SENTINEL)
        outputs = []
        while True:
            item = qs[n].get()
            if item is _SENTINEL:
                break
            outputs.append(item)
        for t in threads:
            t.join(timeout=30)
        return outputs


def run_executor_bench(n_batches: int = 60, batch: int = 15,
                       stages: int = 4, latency_s: float = 0.0,
                       emit_rows: bool = True) -> dict:
    """Steady-state throughput on many small simulated batches: persistent
    executor vs seed-style spawn-per-batch executor.  Returns the summary
    (req/s both ways, speedup, threads created per steady-state batch)."""
    fns = [simulated_stage(latency_s) for _ in range(stages)]
    inputs = list(range(batch))

    seed_ex = _SeedExecutor(fns)
    with PipelineExecutor(fns) as ex:
        seed_ex.run_batch(inputs)                   # warm both
        ex.run_batch(inputs)
        threads_before = threading.active_count()
        # interleave rounds so load drift hits both executors equally;
        # take the best round each (steady-state capability)
        dt_seed = math.inf
        dt_pers = math.inf
        for _ in range(5):
            t0 = time.perf_counter()
            for _ in range(n_batches):
                seed_ex.run_batch(inputs)
            dt_seed = min(dt_seed, time.perf_counter() - t0)
            t0 = time.perf_counter()
            for _ in range(n_batches):
                ex.run_batch(inputs)
            dt_pers = min(dt_pers, time.perf_counter() - t0)
        threads_created = threading.active_count() - threads_before

    n_req = n_batches * batch
    summary = {
        "batches": n_batches, "batch": batch, "stages": stages,
        "seed_req_per_s": round(n_req / dt_seed, 1),
        "persistent_req_per_s": round(n_req / dt_pers, 1),
        "speedup": round(dt_seed / dt_pers, 2),
        "threads_created_steady_state": threads_created,
    }
    if emit_rows:
        rows = [
            {"name": "executor_seed_spawn_per_batch",
             "us_per_call": round(dt_seed / n_req * 1e6, 1),
             "derived": f"req_per_s={summary['seed_req_per_s']}"},
            {"name": "executor_persistent",
             "us_per_call": round(dt_pers / n_req * 1e6, 1),
             "derived": f"req_per_s={summary['persistent_req_per_s']},"
                        f"speedup={summary['speedup']}x,"
                        f"new_threads={threads_created}"},
        ]
        emit("executor_throughput", rows, ["name", "us_per_call", "derived"])
    return summary


def run(arch: str = "qwen3-1.7b", stages: int = 4, requests: int = 15,
        seq: int = 64) -> None:
    cfg = configs.get(arch).smoke_config()
    params = api.init(cfg, jax.random.PRNGKey(0))
    g = lm_graph.lm_layer_graph(cfg, seq_len=seq)
    reqs = [concrete_batch(cfg, seq, 1, key=jax.random.PRNGKey(i),
                           kind="prefill")["tokens"]
            for i in range(requests)]

    rows = []
    for strat in ("comp", "balanced_norefine"):
        spec = DeploymentSpec(stages=stages, strategy=strat,
                              max_batch=requests)
        dep = deploy(spec, graph=g, stage_fn_builder=lambda p: make_stage_fns(
            cfg, params, stage_block_counts(p, cfg.n_layers)))
        pl = dep.plan
        counts = stage_block_counts(pl, cfg.n_layers)
        with dep.serve() as srv:
            srv.serve_batch(reqs[:1])          # warm the jits
            srv.snapshot()                     # reset the delta window
            t0 = time.perf_counter()
            srv.serve_batch(reqs)
            dt = time.perf_counter() - t0
            m = stage_balance_metrics(srv.snapshot()["stage_busy_s"])
        rows.append({"name": f"serve_{strat}",
                     "us_per_call": round(dt / requests * 1e6, 1),
                     "derived": f"balance={m['balance']:.3f},"
                                f"counts={'|'.join(map(str, counts))}"})
    emit("pipeline_serving", rows, ["name", "us_per_call", "derived"])


if __name__ == "__main__":
    run_executor_bench()
    run()
