"""End-to-end serving benchmark: real JAX stage execution through the
host-threaded pipeline for an LM smoke model, comp vs balanced plans
(throughput + stage balance), mirroring the paper's deployment."""
from __future__ import annotations

import time

import jax

from repro import configs
from repro.configs.common import concrete_batch
from repro.core import plan
from repro.core.pipeline import stage_balance_metrics
from repro.launch.serve import make_stage_fns
from repro.launch.pipeline_spmd import stage_block_counts
from repro.models import api, lm_graph
from repro.serving import PipelinedModelServer

from .common import emit


def run(arch: str = "qwen3-1.7b", stages: int = 4, requests: int = 15,
        seq: int = 64) -> None:
    cfg = configs.get(arch).smoke_config()
    params = api.init(cfg, jax.random.PRNGKey(0))
    g = lm_graph.lm_layer_graph(cfg, seq_len=seq)
    reqs = [concrete_batch(cfg, seq, 1, key=jax.random.PRNGKey(i),
                           kind="prefill")["tokens"]
            for i in range(requests)]

    rows = []
    for strat in ("comp", "balanced_norefine"):
        pl = plan(g, stages, strat)
        counts = stage_block_counts(pl, cfg.n_layers)
        fns = make_stage_fns(cfg, params, counts)
        srv = PipelinedModelServer(pl, fns, max_batch=requests)
        srv.serve_batch(reqs[:1])          # warm the jits
        srv.stats["stage_busy_s"] = [0.0] * stages
        t0 = time.perf_counter()
        srv.serve_batch(reqs)
        dt = time.perf_counter() - t0
        m = stage_balance_metrics(srv.stats["stage_busy_s"])
        rows.append({"name": f"serve_{strat}",
                     "us_per_call": round(dt / requests * 1e6, 1),
                     "derived": f"balance={m['balance']:.3f},"
                                f"counts={'|'.join(map(str, counts))}"})
    emit("pipeline_serving", rows, ["name", "us_per_call", "derived"])


if __name__ == "__main__":
    run()
