"""Decode serving-tier benchmark (ISSUE 10 acceptance).

Two comparisons, one artifact (``BENCH_decode.json``):

1. **Placement (modeled)** — steady-state decode tokens/s of the
   KV-cache-aware ``decode_placement`` plan vs the weight-balanced
   (Algorithm 1) cuts, both priced under the *same* decode step cost
   (:func:`repro.decode.placement.step_cost_fn`), across 2-3 concurrency
   levels per LM.  The strategy carries a hard never-worse guarantee, so
   decode-aware >= weight-balanced on every row; the interesting column
   is the gap where KV pressure bends the economy away from weights.

2. **Runtime (measured)** — the continuous-batching
   :class:`~repro.decode.scheduler.DecodeScheduler` (prefill-join at
   token boundaries over the running batch) vs the sequential baseline
   (one request decoded to completion at batch 1 before the next is
   admitted) on the real jitted :class:`PipelineDecodeEngine`, same
   prompts, same weights (float32 so greedy argmax ties cannot flake).
   Records tokens/s, the speedup, and p95 inter-token latency, and
   audits every stream: zero lost tokens, zero misordered indices, and
   continuous-batch tokens bit-equal to the sequential reference.

Acceptance floors (asserted in full mode): decode-aware >=
weight-balanced tokens/s on every modeled row, continuous batching >=
1.3x sequential at concurrency >= 4, zero lost/misordered tokens.

    PYTHONPATH=src python -m benchmarks.decode_bench
    PYTHONPATH=src python -m benchmarks.decode_bench --smoke
"""
from __future__ import annotations

import argparse
import dataclasses
import math
import time
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.api import DeploymentSpec, plan, resolve_model_graph
from repro.core.edge_tpu_model import EdgeTPUModel, EdgeTPUSpec
from repro.core.segmentation import balanced_split, segment_ranges
from repro.decode.costing import DecodeCostSource, DecodeOperatingPoint
from repro.decode.engine import PipelineDecodeEngine
from repro.decode.placement import decode_config_for, step_cost_fn
from repro.decode.scheduler import DecodeScheduler

from .common import emit, write_bench

MODELED_ARCHS = ("qwen3-1.7b", "qwen2.5-14b")
MODELED_CONCURRENCY = (2, 4, 8)
MODELED_CONTEXT = 512
MODELED_STAGES = 4

KV_PRESSURE = (("qwen3-1.7b", 8, 2048), ("qwen2.5-14b", 8, 2048))

RUNTIME_ARCH = "qwen3-1.7b"
RUNTIME_CONCURRENCY = (2, 4)
RUNTIME_CONTEXT = 64
RUNTIME_STAGES = 2


# ---------------------------------------------------------------------------
# 1. modeled placement: decode-aware vs weight-balanced cuts
# ---------------------------------------------------------------------------
def modeled_row(arch: str, stages: int, concurrency: int,
                max_context: int) -> Dict:
    g = resolve_model_graph(f"lm:{arch}")
    pl = plan(DeploymentSpec(model=f"lm:{arch}", strategy="decode_placement",
                             stages=stages, workload="decode",
                             max_context=max_context,
                             decode_concurrency=concurrency), graph=g)
    rep = pl.report

    # weight-balanced baseline: Algorithm 1 cuts priced under the *same*
    # decode step cost the strategy's DP minimized
    cfg = decode_config_for(f"lm:{arch}")
    point = DecodeOperatingPoint(concurrency=concurrency,
                                 max_context=max_context)
    base = EdgeTPUSpec()
    model = EdgeTPUModel(g, base,
                         cost_source=DecodeCostSource(cfg, point))
    cost = step_cost_fn(model.engine, base, point)
    bal = balanced_split(g.params_per_depth(), stages)
    bal_pace = max(cost(lo, hi)
                   for lo, hi in segment_ranges(g.depth, bal))
    bal_tps = concurrency / bal_pace if bal_pace not in (0.0, math.inf) \
        else 0.0

    return {
        "arch": arch, "stages": stages, "concurrency": concurrency,
        "max_context": max_context,
        "decode_aware_tok_s": round(rep.decode_tokens_per_s, 1),
        "weight_balanced_tok_s": round(bal_tps, 1),
        "balanced_feasible": bal_pace != math.inf,
        "gain": (round(rep.decode_tokens_per_s / bal_tps, 3)
                 if bal_tps > 0 else float("inf")),
        "kv_headroom_pct": round(rep.kv_headroom_pct, 1),
        "p95_proxy_step_ms": (round(1e3 * concurrency
                                    / rep.decode_tokens_per_s, 3)
                              if rep.decode_tokens_per_s > 0 else None),
    }


def bench_modeled(archs: Sequence[str], stages: int,
                  concurrencies: Sequence[int],
                  max_context: int) -> List[Dict]:
    rows = []
    for arch in archs:
        for c in concurrencies:
            r = modeled_row(arch, stages, c, max_context)
            rows.append(r)
            print(f"{arch:16s} c={c:<2d} ctx={max_context}: decode-aware "
                  f"{r['decode_aware_tok_s']:9.1f} tok/s vs balanced "
                  f"{r['weight_balanced_tok_s']:9.1f} "
                  f"({r['gain']}x, KV headroom "
                  f"{r['kv_headroom_pct']:.0f}%)")
    return rows


# ---------------------------------------------------------------------------
# 1b. KV pressure: the operating point changes the *required* stage count
# ---------------------------------------------------------------------------
def weight_auto_stages(g, base: EdgeTPUSpec) -> int:
    """The stage count a weight-only planner picks: the smallest count
    whose balanced cuts hold every stage's weights on-chip (the paper's
    §5.2.2 no-spill rule) — blind to decode KV."""
    eng = EdgeTPUModel(g, base).engine
    for s in range(1, g.depth + 1):
        cuts = balanced_split(g.params_per_depth(), s)
        if all(eng.segment_split(lo, hi)[1] == 0
               for lo, hi in segment_ranges(g.depth, cuts)):
            return s
    return g.depth


def kv_pressure_row(arch: str, concurrency: int, max_context: int) -> Dict:
    """Weight-balanced at its own (weight-derived) stage count vs
    decode-aware auto-staging, both priced under the decode step cost.
    At a hot operating point the weight count's stages blow the KV cap
    (0 tok/s — an OOM in practice) while the decode planner scales out."""
    g = resolve_model_graph(f"lm:{arch}")
    base = EdgeTPUSpec()
    cfg = decode_config_for(f"lm:{arch}")
    point = DecodeOperatingPoint(concurrency=concurrency,
                                 max_context=max_context)
    model = EdgeTPUModel(g, base,
                         cost_source=DecodeCostSource(cfg, point))
    cost = step_cost_fn(model.engine, base, point)

    s_w = weight_auto_stages(g, base)
    bal = balanced_split(g.params_per_depth(), s_w)
    bal_pace = max(cost(lo, hi) for lo, hi in segment_ranges(g.depth, bal))
    bal_tps = concurrency / bal_pace if bal_pace not in (0.0, math.inf) \
        else 0.0

    pl = plan(DeploymentSpec(model=f"lm:{arch}",
                             strategy="decode_placement", workload="decode",
                             max_context=max_context,
                             decode_concurrency=concurrency), graph=g)
    return {
        "arch": arch, "concurrency": concurrency,
        "max_context": max_context,
        "weight_auto_stages": s_w,
        "weight_balanced_tok_s": round(bal_tps, 1),
        "balanced_feasible": bal_pace != math.inf,
        "decode_auto_stages": pl.n_stages,
        "decode_aware_tok_s": round(pl.report.decode_tokens_per_s, 1),
        "kv_headroom_pct": round(pl.report.kv_headroom_pct, 1),
    }


def bench_kv_pressure(rows_in: Sequence[Tuple[str, int, int]]) -> List[Dict]:
    rows = []
    for arch, c, ctx in rows_in:
        r = kv_pressure_row(arch, c, ctx)
        rows.append(r)
        bal = (f"{r['weight_balanced_tok_s']:.1f} tok/s"
               if r["balanced_feasible"] else "KV-infeasible (OOM)")
        print(f"{arch:16s} c={c:<2d} ctx={ctx}: weight planner picks "
              f"{r['weight_auto_stages']} stage(s) -> {bal}; decode-aware "
              f"scales to {r['decode_auto_stages']} -> "
              f"{r['decode_aware_tok_s']:.1f} tok/s "
              f"({r['kv_headroom_pct']:.0f}% headroom)")
    return rows


# ---------------------------------------------------------------------------
# 2. runtime: continuous batching vs sequential decode
# ---------------------------------------------------------------------------
def audit_streams(reqs, expected_tokens: int) -> Dict[str, int]:
    """Drain every request's stream; count lost and misordered tokens."""
    lost = misordered = 0
    for req in reqs:
        got: List[Tuple[int, int]] = []
        while True:
            try:
                got.append(req.stream.get_nowait())
            except Exception:
                break
        lost += max(0, expected_tokens - len(got))
        misordered += sum(1 for pos, (idx, _) in enumerate(got)
                          if idx != pos)
        # the stream must agree with the accumulated token list
        misordered += sum(1 for (_, tok), acc in zip(got, req.tokens)
                          if tok != acc)
    return {"lost": lost, "misordered": misordered}


def sequential_decode(engine: PipelineDecodeEngine,
                      prompts: np.ndarray,
                      max_new_tokens: int) -> Tuple[float, List[List[int]]]:
    """The baseline: each request decoded to completion at batch 1 before
    the next is admitted.  Returns (seconds, token lists)."""
    outs: List[List[int]] = []
    t0 = time.perf_counter()
    for prompt in prompts:
        tok = engine.prefill(0, prompt)
        toks = [tok]
        ctx = prompt.size + 1
        while len(toks) < max_new_tokens:
            tok = engine.step([0], [ctx], [tok])[0]
            ctx += 1
            toks.append(tok)
        outs.append(toks)
    return time.perf_counter() - t0, outs


def runtime_row(cfg, params, concurrency: int, n_requests: int,
                prompt_len: int, max_new_tokens: int, max_context: int,
                stage_blocks: Optional[List[int]]) -> Dict:
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab, (n_requests, prompt_len),
                           dtype=np.int32)

    # continuous batching over the running batch
    engine = PipelineDecodeEngine(cfg, params, n_slots=concurrency,
                                  max_context=max_context,
                                  stage_blocks=stage_blocks)
    sched = DecodeScheduler(engine, max_context=max_context,
                            queue_size=max(64, 2 * n_requests))
    with engine, sched:
        sched.submit(prompts[0], max_new_tokens=2).result(timeout=600)
        sched.snapshot()                      # reset the delta window
        t0 = time.perf_counter()
        reqs = [sched.submit(p, max_new_tokens=max_new_tokens)
                for p in prompts]
        cont_tokens = [r.result(timeout=600) for r in reqs]
        cont_s = time.perf_counter() - t0
        snap = sched.snapshot()
    audit = audit_streams(reqs, max_new_tokens)

    # sequential baseline: batch-1 engine, same weights, same prompts
    seq_engine = PipelineDecodeEngine(cfg, params, n_slots=1,
                                      max_context=max_context,
                                      stage_blocks=stage_blocks)
    with seq_engine:
        sequential_decode(seq_engine, prompts[:1], 2)      # warm the jit
        seq_s, seq_tokens = sequential_decode(seq_engine, prompts,
                                              max_new_tokens)

    mismatch = sum(1 for a, b in zip(cont_tokens, seq_tokens) if a != b)
    total = n_requests * max_new_tokens
    return {
        "concurrency": concurrency, "n_requests": n_requests,
        "prompt_len": prompt_len, "max_new_tokens": max_new_tokens,
        "continuous_tok_s": round(total / cont_s, 1),
        "sequential_tok_s": round(total / seq_s, 1),
        "speedup": round(seq_s / cont_s, 3),
        "batched_steps": snap["steps"],
        "inter_token_p50_ms": round(snap["inter_token_p50_s"] * 1e3, 3),
        "inter_token_p95_ms": round(snap["inter_token_p95_s"] * 1e3, 3),
        "lost_tokens": audit["lost"],
        "misordered_tokens": audit["misordered"],
        "mismatched_vs_sequential": mismatch,
    }


def bench_runtime(arch: str, concurrencies: Sequence[int],
                  requests_per_slot: int, prompt_len: int,
                  max_new_tokens: int, max_context: int,
                  stages: int) -> List[Dict]:
    import jax
    import jax.numpy as jnp
    from repro.models import lm

    # float32 smoke weights: greedy argmax is tie-free, so the continuous
    # batch must reproduce the sequential reference token for token
    cfg = dataclasses.replace(decode_config_for(f"lm:{arch}"),
                              dtype=jnp.float32)
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    per = cfg.n_layers // stages
    stage_blocks = [per] * (stages - 1) + [cfg.n_layers - per * (stages - 1)]

    rows = []
    for c in concurrencies:
        r = runtime_row(cfg, params, c, requests_per_slot * c, prompt_len,
                        max_new_tokens, max_context, stage_blocks)
        rows.append(r)
        print(f"{arch:16s} c={c:<2d}: continuous "
              f"{r['continuous_tok_s']:7.1f} tok/s vs sequential "
              f"{r['sequential_tok_s']:7.1f} ({r['speedup']:.2f}x), "
              f"p95 inter-token {r['inter_token_p95_ms']:.2f} ms, "
              f"lost={r['lost_tokens']} misordered={r['misordered_tokens']} "
              f"mismatch={r['mismatched_vs_sequential']}")
    return rows


# ---------------------------------------------------------------------------
def run(modeled_archs: Sequence[str] = MODELED_ARCHS,
        modeled_concurrency: Sequence[int] = MODELED_CONCURRENCY,
        modeled_context: int = MODELED_CONTEXT,
        modeled_stages: int = MODELED_STAGES,
        runtime_arch: str = RUNTIME_ARCH,
        runtime_concurrency: Sequence[int] = RUNTIME_CONCURRENCY,
        requests_per_slot: int = 3, prompt_len: int = 8,
        max_new_tokens: int = 16, runtime_context: int = RUNTIME_CONTEXT,
        runtime_stages: int = RUNTIME_STAGES,
        kv_pressure_points: Sequence[Tuple[str, int, int]] = KV_PRESSURE,
        write: bool = True) -> Dict:
    modeled = bench_modeled(modeled_archs, modeled_stages,
                            modeled_concurrency, modeled_context)
    pressure = bench_kv_pressure(kv_pressure_points)
    runtime = bench_runtime(runtime_arch, runtime_concurrency,
                            requests_per_slot, prompt_len, max_new_tokens,
                            runtime_context, runtime_stages)

    emit("decode_bench",
         [{"name": f"decode_plan_{r['arch']}_c{r['concurrency']}",
           "us_per_call": (round(1e6 / r["decode_aware_tok_s"], 2)
                           if r["decode_aware_tok_s"] else ""),
           "derived": f"gain={r['gain']}x,"
                      f"headroom={r['kv_headroom_pct']}%"}
          for r in modeled]
         + [{"name": f"decode_runtime_c{r['concurrency']}",
             "us_per_call": round(1e6 / r["continuous_tok_s"], 2),
             "derived": f"speedup={r['speedup']}x,"
                        f"p95_ms={r['inter_token_p95_ms']}"}
            for r in runtime],
         ["name", "us_per_call", "derived"])

    aware_ge_balanced = all(
        r["decode_aware_tok_s"] >= r["weight_balanced_tok_s"]
        for r in modeled + pressure)
    pressure_win = any(not r["balanced_feasible"]
                       and r["decode_aware_tok_s"] > 0 for r in pressure)
    hi = [r for r in runtime if r["concurrency"] >= 4]
    hi_speedup = min((r["speedup"] for r in hi), default=0.0)
    lost = sum(r["lost_tokens"] for r in runtime)
    misordered = sum(r["misordered_tokens"] for r in runtime)
    mismatched = sum(r["mismatched_vs_sequential"] for r in runtime)
    summary = {
        "note": "decode serving tier: KV-aware placement vs weight-"
                "balanced cuts (both priced under the decode step cost) "
                "and continuous batching vs sequential decode on the "
                "jitted pipeline engine; see EXPERIMENTS.md "
                "§Decode serving",
        "modeled_placement": modeled,
        "kv_pressure": pressure,
        "runtime_continuous_batching": runtime,
        "acceptance": {
            "decode_aware_ge_weight_balanced": aware_ge_balanced,
            "kv_pressure_win": pressure_win,
            "min_continuous_speedup_at_c4plus": hi_speedup,
            "speedup_floor_met": bool(hi_speedup >= 1.3),
            "lost_tokens": lost,
            "misordered_tokens": misordered,
            "mismatched_vs_sequential": mismatched,
            "token_audit_clean": bool(lost == 0 and misordered == 0
                                      and mismatched == 0),
        },
    }
    if write:
        write_bench("decode", summary)
    print(f"decode-aware >= weight-balanced on all "
          f"{len(modeled) + len(pressure)} modeled rows: "
          f"{aware_ge_balanced} (KV-pressure win: {pressure_win}); "
          f"min continuous/sequential speedup at c>=4: {hi_speedup:.2f}x "
          f"(floor 1.3x: {'met' if hi_speedup >= 1.3 else 'MISSED'}); "
          f"token audit: lost={lost} misordered={misordered} "
          f"mismatch={mismatched}")
    return summary


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="fast CI mode: one arch, small batch, no "
                         "BENCH_decode.json write")
    args = ap.parse_args()
    if args.smoke:
        summary = run(modeled_archs=("qwen3-1.7b",),
                      modeled_concurrency=(2, 4), modeled_context=256,
                      runtime_concurrency=(4,), requests_per_slot=1,
                      prompt_len=4, max_new_tokens=4, runtime_context=32,
                      kv_pressure_points=(("qwen3-1.7b", 8, 2048),),
                      write=False)
        acc = summary["acceptance"]
        assert acc["decode_aware_ge_weight_balanced"], acc
        assert acc["token_audit_clean"], acc
        assert acc["min_continuous_speedup_at_c4plus"] > 1.0, acc
        return
    summary = run()
    acc = summary["acceptance"]
    assert acc["decode_aware_ge_weight_balanced"], acc
    assert acc["kv_pressure_win"], acc
    assert acc["speedup_floor_met"], acc
    assert acc["token_audit_clean"], acc


if __name__ == "__main__":
    main()
