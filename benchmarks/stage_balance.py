"""Paper Fig. 10: slowest-stage time and its deviation from the stage mean,
comp vs balanced (+ beyond-paper cost-balanced), per real model."""
from __future__ import annotations

from repro.api import DeploymentSpec, plan
from repro.core import EdgeTPUModel
from repro.core.placement import min_stages_no_spill
from repro.models.cnn import REAL_CNNS

from .common import emit

MODELS = ("Xception", "ResNet50", "ResNet101", "ResNet152", "InceptionV3",
          "InceptionV4", "InceptionResNetV2", "DenseNet121", "DenseNet169",
          "DenseNet201", "EfficientNetLiteB3", "EfficientNetLiteB4")


def run() -> None:
    rows = []
    for name in MODELS:
        g = REAL_CNNS[name]().to_layer_graph()
        m = EdgeTPUModel(g)
        n = min_stages_no_spill(g, m)
        rec = {"model": name, "n": n}
        for strat in ("comp", "balanced", "balanced_cost"):
            pl = plan(DeploymentSpec(stages=n, strategy=strat),
                      graph=g, tpu_model=m)
            ts = m.stage_times(pl.cuts)
            mx, mean = max(ts), sum(ts) / len(ts)
            rec[f"{strat}_max_ms"] = round(mx * 1e3, 2)
            rec[f"{strat}_dev_ms"] = round((mx - mean) * 1e3, 2)
            rec[f"{strat}_balance"] = round(mean / mx, 3)
        rows.append(rec)
    emit("fig10_stage_balance", rows,
         ["model", "n"] + [f"{s}_{k}" for s in
                           ("comp", "balanced", "balanced_cost")
                           for k in ("max_ms", "dev_ms", "balance")])
    better = sum(1 for r in rows
                 if r["balanced_max_ms"] <= r["comp_max_ms"] * 1.001)
    print(f"derived: balanced slowest-stage <= comp on {better}/{len(rows)} "
          f"models (paper Fig. 10: all)")


if __name__ == "__main__":
    run()
