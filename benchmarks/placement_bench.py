"""Replicated vs. best non-replicated placement on the skewed Table-1 models.

The paper's Table 5 shows residual imbalance whenever a single dominant
layer pins the minimax DP: past some stage count ``s_pin`` adding more cuts
cannot lower the max stage time, because one stage is a single depth level
no cut can shrink.  This bench finds ``s_pin`` per model (smallest s whose
exact-DP plan is pinned at the dominant single-depth segment time), then
compares at a device budget of ``s_pin + 1``:

* **non-replicated** — the exact O(d²·s) minimax DP with ``s_pin + 1``
  stages, one device each (the best any cut placement can do);
* **replicated** — ``plan_placement`` joint DP over cuts *and* replica
  counts: the pinned stage may take 2 devices (round-robin fan-out), its
  pacing time dropping to ``t_weight_load + (t - t_weight_load)/2``.

Acceptance (ISSUE 2): the replicated plan's modeled max stage time is
*strictly lower* on at least 3 models.  A replicated-executor throughput
microbenchmark (simulated latencies, bottleneck stage replicated) rides
along.  Summary lands in ``BENCH_placement.json`` at the repo root.

    PYTHONPATH=src python -m benchmarks.placement_bench
    PYTHONPATH=src python -m benchmarks.placement_bench --models ResNet50
"""
from __future__ import annotations

import argparse
import time
from typing import Dict, List, Optional

from repro.api import DeploymentSpec, plan
from repro.core import EdgeTPUModel, PipelineExecutor, simulated_stage
from repro.core.segmentation import minimax_time_split
from repro.models.cnn import REAL_CNNS

from .common import emit, write_bench


# Exact joint DP is O(d^2 * budget^2): the default set keeps depth and
# pinned stage counts where a model benches in seconds (ResNet101/152 and
# the DenseNets take minutes; pass --models to include them).
DEFAULT_MODELS = ("Xception", "ResNet50", "ResNet50V2", "InceptionV3",
                  "MobileNet", "MobileNetV2", "NASNetMobile",
                  "EfficientNetLiteB0")
MAX_PIN_STAGES = 24


def find_pinned_stages(model: EdgeTPUModel, depth: int) -> Optional[int]:
    """Smallest s whose exact minimax plan is pinned: its max stage time
    has stopped improving against the dominant single-depth segment."""
    t_dom = max(model.segment_time(i, i) for i in range(depth))
    for s in range(2, min(depth, MAX_PIN_STAGES + 1)):
        cuts = minimax_time_split(depth, s, model.segment_time, exact=True)
        if max(model.stage_times(cuts)) <= t_dom * (1 + 1e-9):
            return s
    return None


def bench_model(name: str) -> Dict:
    g = REAL_CNNS[name]().to_layer_graph()
    m = EdgeTPUModel(g)
    d = g.depth
    t0 = time.perf_counter()
    s_pin = find_pinned_stages(m, d)
    if s_pin is None:
        return {"model": name, "depth": d, "pinned": False}
    budget = s_pin + 1
    cuts_nr = minimax_time_split(d, budget, m.segment_time, exact=True)
    t_nonrep = max(m.stage_times(cuts_nr))
    pl = plan(DeploymentSpec(strategy="placement", device_budget=budget,
                             replicate=True), graph=g,
              attach_report=False)      # timed: plan search only, as before
    t_rep = pl.max_stage_time_s
    dt = time.perf_counter() - t0
    return {
        "model": name, "depth": d, "pinned": True, "s_pin": s_pin,
        "budget": budget,
        "nonrep_max_stage_ms": round(t_nonrep * 1e3, 4),
        "rep_max_stage_ms": round(t_rep * 1e3, 4),
        "gain_pct": round((1 - t_rep / t_nonrep) * 100, 2),
        "replicas": pl.replica_counts,
        "strict_win": bool(t_rep < t_nonrep * (1 - 1e-12)),
        "bench_s": round(dt, 1),
    }


def run_replicated_executor_bench(batch: int = 64, rounds: int = 5,
                                  bottleneck_ms: float = 2.0) -> Dict:
    """Measured (not modeled) throughput: a pipeline whose middle stage is
    3x slower, run unreplicated vs. with that stage replicated 3-way."""
    lat = bottleneck_ms / 1e3
    fns = [simulated_stage(lat / 3), simulated_stage(lat),
           simulated_stage(lat / 3)]
    inputs = list(range(batch))
    with PipelineExecutor(fns) as base:
        base.run_batch(inputs)
        dt_base = min(_timed(base, inputs) for _ in range(rounds))
    with PipelineExecutor(fns, replicas=[1, 3, 1]) as rep:
        outs, _ = rep.run_batch(inputs)
        assert outs == inputs, "replicated pipeline broke ordering"
        dt_rep = min(_timed(rep, inputs) for _ in range(rounds))
    return {
        "batch": batch, "bottleneck_ms": bottleneck_ms,
        "unreplicated_req_per_s": round(batch / dt_base, 1),
        "replicated_req_per_s": round(batch / dt_rep, 1),
        "speedup": round(dt_base / dt_rep, 2),
    }


def _timed(ex: PipelineExecutor, inputs: List) -> float:
    t0 = time.perf_counter()
    ex.run_batch(inputs)
    return time.perf_counter() - t0


def run(models: Optional[List[str]] = None, rounds: int = 5,
        write: bool = True) -> Dict:
    names = models or list(DEFAULT_MODELS)
    unknown = [n for n in names if n not in REAL_CNNS]
    if unknown:
        raise SystemExit(f"unknown model(s) {unknown}; "
                         f"pick from {sorted(REAL_CNNS)}")
    results = []
    for name in names:
        r = bench_model(name)
        results.append(r)
        if not r.get("pinned"):
            print(f"{name:22s} d={r['depth']:3d}  no pinned stage count "
                  f"within {MAX_PIN_STAGES} — skipped")
            continue
        print(f"{name:22s} d={r['depth']:3d} s_pin={r['s_pin']:2d}  "
              f"nonrep {r['nonrep_max_stage_ms']:.4f} ms -> "
              f"rep {r['rep_max_stage_ms']:.4f} ms "
              f"({r['gain_pct']:+.2f}%)  win={r['strict_win']}")

    rows = [{"name": f"placement_{r['model']}",
             "us_per_call": r.get("rep_max_stage_ms", ""),
             "derived": (f"nonrep_ms={r.get('nonrep_max_stage_ms')},"
                         f"gain={r.get('gain_pct')}%,"
                         f"win={r.get('strict_win')}")}
            for r in results if r.get("pinned")]
    emit("placement_bench", rows, ["name", "us_per_call", "derived"])

    exec_summary = run_replicated_executor_bench(rounds=rounds)
    wins = sum(1 for r in results if r.get("strict_win"))
    summary = {
        "note": "replicated vs best non-replicated plan at device budget "
                "s_pin+1 on skewed Table-1 models (analytical Edge TPU "
                "model; see EXPERIMENTS.md §Heterogeneous topologies) + "
                "measured replicated-executor throughput",
        "models": results,
        "replicated_executor": exec_summary,
        "acceptance": {
            "models_with_strict_win": wins,
            "win_floor_met": bool(wins >= 3),
            "executor_speedup": exec_summary["speedup"],
        },
    }
    if write:
        write_bench("placement", summary)
    print(f"\n{wins} models with a strict replication win; "
          f"replicated executor {exec_summary['speedup']}x on the "
          f"bottleneck pipeline")
    return summary


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--models", nargs="*", default=None,
                    help="subset of Table-1 names (default: skewed fast set)")
    ap.add_argument("--smoke", action="store_true",
                    help="fast CI mode: fastest models only, fewer "
                         "executor rounds, no BENCH_placement.json write, "
                         "relaxed acceptance")
    args = ap.parse_args()
    if args.smoke:
        summary = run(models=args.models or ["MobileNet", "MobileNetV2"],
                      rounds=2, write=False)
        # smoke gates on the deterministic modeled metric only; the
        # wall-clock executor speedup is printed but not asserted (shared
        # CI runners are too noisy — ordering correctness is asserted
        # inside run_replicated_executor_bench regardless)
        assert summary["acceptance"]["models_with_strict_win"] >= 1, \
            summary["acceptance"]
        return
    summary = run(args.models)
    assert summary["acceptance"]["win_floor_met"], summary["acceptance"]
    assert summary["acceptance"]["executor_speedup"] >= 1.5, \
        summary["acceptance"]


if __name__ == "__main__":
    main()
