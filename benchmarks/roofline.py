"""Roofline table from the dry-run artifacts (§Roofline deliverable).

Reads benchmarks/artifacts/dryrun/*.json (written by
``python -m repro.launch.dryrun --all --mesh both``) and prints the
per-(arch x shape x mesh) three-term roofline with the dominant bottleneck
and useful-FLOPs ratio."""
from __future__ import annotations

import glob
import json
import os

from .common import ARTIFACTS, emit

DRYRUN_OPT = os.path.join(ARTIFACTS, "dryrun_opt")   # optimized defaults
DRYRUN_BASE = os.path.join(ARTIFACTS, "dryrun")      # first-green baseline


def load_records():
    d = DRYRUN_OPT if glob.glob(os.path.join(DRYRUN_OPT, "*.json")) \
        else DRYRUN_BASE
    recs = []
    for p in sorted(glob.glob(os.path.join(d, "*.json"))):
        with open(p) as f:
            recs.append(json.load(f))
    return recs


def run() -> None:
    recs = load_records()
    if not recs:
        print("no dry-run artifacts found; run "
              "`PYTHONPATH=src python -m repro.launch.dryrun --all "
              "--mesh both` first")
        return
    rows = []
    for r in recs:
        if r.get("status") == "skipped":
            rows.append({"arch": r["arch"], "shape": r["shape"],
                         "mesh": r["mesh"], "status": "skipped"})
            continue
        if r.get("status") != "ok":
            rows.append({"arch": r["arch"], "shape": r["shape"],
                         "mesh": r["mesh"], "status": "ERROR"})
            continue
        t = r["roofline"]
        rows.append({
            "arch": r["arch"], "shape": r["shape"], "mesh": r["mesh"],
            "status": "ok",
            "compute_ms": round(t["compute_s"] * 1e3, 2),
            "memory_ms": round(t["memory_s"] * 1e3, 2),
            "collective_ms": round(t["collective_s"] * 1e3, 2),
            "dominant": t["dominant"].replace("_s", ""),
            "useful_flops": (round(r["useful_flops_ratio"], 3)
                             if r.get("useful_flops_ratio") else ""),
            "GiB_per_dev": round(r["device_bytes"] / 2 ** 30, 2),
            "fits": r["fits_hbm"],
        })
    emit("roofline_table", rows,
         ["arch", "shape", "mesh", "status", "compute_ms", "memory_ms",
          "collective_ms", "dominant", "useful_flops", "GiB_per_dev",
          "fits"])
    ok = [r for r in rows if r["status"] == "ok"]
    from collections import Counter
    doms = Counter(r["dominant"] for r in ok)
    print(f"derived: {len(ok)} compiled cells; dominant terms: {dict(doms)}; "
          f"all fit HBM: {all(r['fits'] for r in ok)}")


if __name__ == "__main__":
    run()
