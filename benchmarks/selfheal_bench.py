"""Self-healing serving benchmark (ISSUE 8 acceptance).

A live ``PipelinedModelServer`` runs a Table-1 model's balanced plan on a
*synthetic device the analytic model badly mispredicts*: dense MACs are
fast, but low-arithmetic-intensity MACs (MobileNet's depthwise convs) pay
an 80x penalty the closed-form model knows nothing about — the class of
off-chip/intensity cliffs BENCH_profile.json measures offline.  The
self-healing controller must discover this *online*, from nothing but
``snapshot()`` deltas, and re-cut the pipeline through guarded (canary +
rollback) reconfigures:

* **phase 1 — miscalibration**: serving starts on the analytic plan.
  The controller's rolling live trace exposes the true per-stage shape;
  drift triggers replans (front-door registry, live trace cost source)
  until the committed cuts stop improving.  Recovery = true bottleneck
  stage time of the analytic plan / the converged plan's.
* **phase 2 — injected drift**: a sustained ``slowdown`` ChaosEvent
  (the PR-6 chaos hooks) multiplies the service time of the widest
  committed stage's depth range by ``SLOWDOWN_X`` mid-serving.  The
  first canary attempt is sabotaged (the guarded builder returns
  exploding stage fns once) to exercise the rollback + backoff path;
  the retry commits and the loop converges again.  Recovery = true
  bottleneck right after the slowdown / after reconvergence.

"True" stage times are a static per-depth table (sleep-based stage fns),
so both recovery ratios are exact properties of the committed cuts — not
wall-clock measurements.  Functional acceptance in every mode (``--smoke``
included): zero lost requests, zero misordered outputs across every
hot-swap, >= 1 exercised rollback, >= 1 commit.  Full mode additionally
asserts phase-2 recovery >= ``RECOVERY_BOUND`` and runs the overload
scenario (deadline shedding + jittered retry hints under a burst), then
writes ``BENCH_selfheal.json`` at the repo root.

    PYTHONPATH=src python -m benchmarks.selfheal_bench
    PYTHONPATH=src python -m benchmarks.selfheal_bench --smoke
"""
from __future__ import annotations

import argparse
import threading
import time
from typing import Dict, List, Optional

from repro.api import DeploymentSpec, deploy, plan
from repro.models.cnn import REAL_CNNS
from repro.profiling.live import LOW_INTENSITY_MACS_PER_BYTE
from repro.runtime import ChaosEvent, ChaosMonkey, DriftPolicy
from repro.serving import DeadlineExceeded, Overloaded

from .common import emit, write_bench


MODEL = "MobileNet"          # Table-1; depthwise convs = low-MAC cliffs
STAGES = 4
TRUE_SUM_S = 8e-3            # whole-model true service time (sleep scale)
SLOWDOWN_X = 5.0             # phase-2 sustained slowdown factor
RECOVERY_BOUND = 2.0         # phase-2 bottleneck recovery (full mode)

# the synthetic truth: dense MACs at 4 TMAC/s, low-intensity MACs 80x
# slower, weights over 30 GB/s.  The analytic model prices every MAC the
# same, so it stacks MobileNet's cheap-looking depthwise depths into one
# catastrophically slow stage.
MAC_RATE = 4.0e12
LOW_MAC_RATE = 0.05e12
WEIGHT_RATE = 30e9


def true_depth_times(g) -> List[float]:
    """Static per-depth service times of the synthetic device, scaled so
    the whole model sums to ``TRUE_SUM_S``."""
    levels = g.levels()
    macs = g.macs_per_depth()
    wb = g.bytes_per_depth()
    low = [sum(g.nodes[n].macs for n in lvl
               if g.nodes[n].macs
               <= LOW_INTENSITY_MACS_PER_BYTE * max(1, g.nodes[n].out_bytes))
           for lvl in levels]
    raw = [m / MAC_RATE + lo / LOW_MAC_RATE + b / WEIGHT_RATE
           for m, lo, b in zip(macs, low, wb)]
    scale = TRUE_SUM_S / sum(raw)
    return [t * scale for t in raw]


def true_stage_times(pl, true_s, factor) -> List[float]:
    return [sum(true_s[d] * factor[d] for d in range(lo, hi + 1))
            for (lo, hi) in pl.stage_depth_ranges]


class Scenario:
    """One self-healing serving run over the synthetic-truth device."""

    def __init__(self, model: str = MODEL, stages: int = STAGES,
                 window_reqs: int = 8, true_sum_s: float = TRUE_SUM_S):
        self.g = REAL_CNNS[model]().to_layer_graph()
        self.true_s = [t * (true_sum_s / TRUE_SUM_S)
                       for t in true_depth_times(self.g)]
        self.factor = [1.0] * self.g.depth       # live slowdown state
        self.window_reqs = window_reqs
        self.fail_next_canary = False            # sabotage flag (rollback)
        self.exit_order: List[int] = []
        self._tap_lock = threading.Lock()
        self._next_id = 0
        self.lost = 0
        self.errors = 0

    # -- stage functions ------------------------------------------------------
    def builder(self, pl):
        """Stage fns sleeping the *current* true time of their depth range
        (``factor`` is read per call, so chaos slowdowns apply live).  The
        last stage taps exit order for non-negative payloads — canaries
        ride negative ids and stay out of the audit."""
        if self.fail_next_canary:
            self.fail_next_canary = False

            def boom(x):
                raise RuntimeError("injected canary fault")
            return [boom] * pl.n_stages

        fns = []
        n = pl.n_stages
        for si, (lo, hi) in enumerate(pl.stage_depth_ranges):
            def fn(x, lo=lo, hi=hi, last=(si == n - 1)):
                time.sleep(sum(self.true_s[d] * self.factor[d]
                               for d in range(lo, hi + 1)))
                if last and x >= 0:
                    with self._tap_lock:
                        self.exit_order.append(int(x))
                return x
            fns.append(fn)
        return fns

    # -- windows --------------------------------------------------------------
    def run_window(self, server) -> None:
        reqs = []
        for _ in range(self.window_reqs):
            reqs.append(server.submit(self._next_id))
            self._next_id += 1
        for r in reqs:
            if not r.event.wait(30):
                self.lost += 1
            elif r.error is not None:
                self.errors += 1

    def drive(self, server, ctl, max_windows: int,
              stable_after: int = 8) -> int:
        """Window loop: serve a batch, then one synchronous control tick.
        Stops early once no commit landed for ``stable_after`` windows
        (and the loop is not mid-backoff).  Returns windows driven."""
        last_commit_w = ctl.windows
        for w in range(max_windows):
            self.run_window(server)
            n_commits = ctl.commits
            ctl.tick()
            if ctl.commits > n_commits:
                last_commit_w = ctl.windows
            if (ctl.windows - last_commit_w >= stable_after
                    and ctl.state in ("steady", "degraded")):
                break
        return w + 1

    def misordered(self) -> int:
        return sum(1 for a, b in zip(self.exit_order, self.exit_order[1:])
                   if b < a)


def run_selfheal(window_reqs: int, p1_windows: int, p2_windows: int,
                 true_sum_s: float, smoke: bool) -> Dict:
    sc = Scenario(window_reqs=window_reqs, true_sum_s=true_sum_s)
    spec = DeploymentSpec(stages=STAGES, strategy="balanced",
                          max_batch=window_reqs, max_wait_s=0.002,
                          drift_threshold=0.2, canary_requests=4)
    policy = DriftPolicy(drift_threshold=0.2, hysteresis=2,
                         cooldown_windows=1, ewma_alpha=0.5, live_alpha=0.5,
                         canary_margin=1.2, max_canary_retries=4,
                         backoff_base_windows=1, backoff_max_windows=4,
                         canary_requests=4)
    dep = deploy(spec, graph=sc.g, stage_fn_builder=sc.builder)
    analytic_plan = dep.plan
    p1_pre = max(true_stage_times(analytic_plan, sc.true_s, sc.factor))

    with dep.serve() as server:
        server.start()
        # canaries are negative ids: they validate candidate executors
        # only and never touch the exit-order audit
        ctl = dep.self_heal([-1, -2, -3, -4], policy=policy)

        # phase 1: analytic miscalibration
        w1 = sc.drive(server, ctl, p1_windows)
        p1_plan = server.plan
        p1_post = max(true_stage_times(p1_plan, sc.true_s, sc.factor))
        p1_commits = ctl.commits
        print(f"phase 1: {w1} windows, {p1_commits} commits, cuts "
              f"{analytic_plan.cuts} -> {p1_plan.cuts}, true bottleneck "
              f"{p1_pre*1e3:.2f} -> {p1_post*1e3:.2f} ms "
              f"({p1_pre/p1_post:.2f}x)")

        # phase 2: sustained slowdown on the widest committed stage,
        # injected through the chaos hooks; first canary sabotaged
        widths = [hi - lo for lo, hi in p1_plan.stage_depth_ranges]
        slow_stage = max(range(len(widths)), key=lambda i: widths[i])

        def apply_slowdown(stage: int, f: float) -> None:
            lo, hi = server.plan.stage_depth_ranges[stage]
            for d in range(lo, hi + 1):
                sc.factor[d] *= f

        monkey = ChaosMonkey(lambda: server.executor,
                             [ChaosEvent(at_s=0.0, kind="slowdown",
                                         stage=slow_stage,
                                         factor=SLOWDOWN_X)],
                             slowdown_target=apply_slowdown)
        monkey.start()
        monkey.join(timeout=5)
        assert monkey.applied and monkey.applied[0][1], \
            "slowdown event did not apply"
        sc.fail_next_canary = True               # exercise the rollback
        p2_pre = max(true_stage_times(p1_plan, sc.true_s, sc.factor))

        w2 = sc.drive(server, ctl, p2_windows)
        p2_plan = server.plan
        p2_post = max(true_stage_times(p2_plan, sc.true_s, sc.factor))
        print(f"phase 2: {w2} windows, {ctl.commits - p1_commits} commits,"
              f" {ctl.rollbacks} rollbacks, cuts {p1_plan.cuts} -> "
              f"{p2_plan.cuts}, true bottleneck {p2_pre*1e3:.2f} -> "
              f"{p2_post*1e3:.2f} ms ({p2_pre/p2_post:.2f}x)")

    # functional acceptance: every mode
    mis = sc.misordered()
    assert sc.lost == 0, f"{sc.lost} lost requests"
    assert sc.errors == 0, f"{sc.errors} request errors"
    assert mis == 0, f"{mis} misordered outputs"
    assert len(sc.exit_order) == sc._next_id, \
        (len(sc.exit_order), sc._next_id)
    assert ctl.commits >= 1, "no guarded reconfigure committed"
    assert ctl.rollbacks >= 1, "rollback path never exercised"
    kinds = [e["kind"] for e in ctl.events]
    assert "rollback" in kinds and "commit" in kinds

    recovery1 = p1_pre / p1_post
    recovery2 = p2_pre / p2_post
    if not smoke:
        assert recovery2 >= RECOVERY_BOUND, \
            (recovery2, p1_plan.cuts, p2_plan.cuts)
        assert recovery1 >= 1.5, (recovery1, p1_plan.cuts)

    return {
        "model": MODEL, "stages": STAGES,
        "requests": sc._next_id,
        "windows": ctl.windows, "replans": ctl.replans,
        "commits": ctl.commits, "rollbacks": ctl.rollbacks,
        "final_state": ctl.state,
        "events": [{k: v for k, v in e.items()} for e in ctl.events],
        "phase1": {"analytic_cuts": list(analytic_plan.cuts),
                   "converged_cuts": list(p1_plan.cuts),
                   "true_bottleneck_pre_ms": round(p1_pre * 1e3, 3),
                   "true_bottleneck_post_ms": round(p1_post * 1e3, 3),
                   "recovery_x": round(recovery1, 2)},
        "phase2": {"slow_stage": slow_stage, "slowdown_x": SLOWDOWN_X,
                   "converged_cuts": list(p2_plan.cuts),
                   "true_bottleneck_pre_ms": round(p2_pre * 1e3, 3),
                   "true_bottleneck_post_ms": round(p2_post * 1e3, 3),
                   "recovery_x": round(recovery2, 2)},
        "acceptance": {"lost": sc.lost, "misordered": mis,
                       "rollbacks_exercised": ctl.rollbacks,
                       "recovery_bound": RECOVERY_BOUND,
                       "bound_met": bool(recovery2 >= RECOVERY_BOUND)},
    }


def run_overload(n_requests: int = 60) -> Dict:
    """Burst a deadline-shedding server far past its capacity: every
    request must resolve (completed, ``Overloaded`` with a positive
    jittered retry hint, or ``DeadlineExceeded``) — nothing hangs."""
    g = REAL_CNNS[MODEL]().to_layer_graph()
    # a small executor queue makes admission completion-paced: the pace
    # EWMA primes after the first drains and the queue-delay estimate
    # (in_flight x pace) starts exceeding later arrivals' budgets
    spec = DeploymentSpec(stages=2, strategy="balanced",
                          max_batch=8, max_wait_s=0.001, queue_size=4,
                          deadline_ms=30.0, shed_policy="deadline")

    def builder(pl):
        def slow(x):
            time.sleep(0.004)
            return x

        def fast(x):
            return x
        return [slow] + [fast] * (pl.n_stages - 1)

    dep = deploy(spec, graph=g, stage_fn_builder=builder)
    with dep.serve() as server:
        server.start()
        reqs = [server.submit(i) for i in range(n_requests)]
        for r in reqs:
            assert r.event.wait(30), f"request {r.rid} hung"
        snap = server.snapshot()

    completed = sum(1 for r in reqs if r.error is None)
    shed = [r for r in reqs if isinstance(r.error, Overloaded)]
    late = [r for r in reqs if isinstance(r.error, DeadlineExceeded)]
    assert completed + len(shed) + len(late) == n_requests
    assert completed >= 1, "burst starved completely"
    assert shed, "shed policy never engaged under the burst"
    assert all(r.error.retry_after_s > 0 for r in shed)
    assert snap["shed"] == len(shed)
    assert snap["deadline_exceeded"] == len(late)
    hints = [r.error.retry_after_s for r in shed]
    return {"submitted": n_requests, "completed": completed,
            "shed": len(shed), "deadline_exceeded": len(late),
            "retry_after_ms": {"min": round(min(hints) * 1e3, 2),
                               "max": round(max(hints) * 1e3, 2)}}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--window-reqs", type=int, default=8)
    ap.add_argument("--smoke", action="store_true",
                    help="fast CI mode: smaller true times + fewer "
                         "windows, functional asserts only (loop "
                         "mechanics: 0 lost / 0 misordered, >= 1 commit, "
                         ">= 1 rollback), no BENCH_selfheal.json write")
    args = ap.parse_args()
    smoke = args.smoke

    heal = run_selfheal(
        window_reqs=4 if smoke else args.window_reqs,
        p1_windows=20 if smoke else 48,
        p2_windows=20 if smoke else 48,
        true_sum_s=2e-3 if smoke else TRUE_SUM_S,
        smoke=smoke)

    summary = {
        "note": "closed-loop self-healing serving: live snapshot deltas "
                "-> rolling trace -> drift detection -> guarded (canary "
                "+ rollback) replans on a synthetic device the analytic "
                "model mispredicts, plus a sustained chaos slowdown; "
                "see EXPERIMENTS.md §Self-healing serving",
        "selfheal": heal,
    }
    if not smoke:
        summary["overload"] = run_overload()
        write_bench("selfheal", summary)

    p1, p2 = heal["phase1"], heal["phase2"]
    rows = [
        {"name": "selfheal_phase1_bottleneck",
         "us_per_call": round(1e3 * p1["true_bottleneck_post_ms"], 1),
         "derived": f"recovery={p1['recovery_x']}x,"
                    f"commits={heal['commits']}"},
        {"name": "selfheal_phase2_bottleneck",
         "us_per_call": round(1e3 * p2["true_bottleneck_post_ms"], 1),
         "derived": f"recovery={p2['recovery_x']}x,"
                    f"rollbacks={heal['rollbacks']}"},
    ]
    if not smoke:
        ov = summary["overload"]
        rows.append({"name": "selfheal_overload",
                     "us_per_call": ov["submitted"],
                     "derived": f"completed={ov['completed']},"
                                f"shed={ov['shed']},"
                                f"late={ov['deadline_exceeded']}"})
    emit("selfheal_bench", rows, ["name", "us_per_call", "derived"])
    print(f"phase1 {p1['recovery_x']}x, phase2 {p2['recovery_x']}x, "
          f"{heal['commits']} commits, {heal['rollbacks']} rollbacks, "
          f"0 lost, 0 misordered")


if __name__ == "__main__":
    main()
