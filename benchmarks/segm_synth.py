"""Paper Table 4 + Fig. 6 (SEGM_COMP on synthetic models) and
Table 6 + Fig. 7 (SEGM_PROF), plus SEGM_BALANCED for comparison."""
from __future__ import annotations

from repro.api import DeploymentSpec, plan
from repro.core import EdgeTPUModel
from repro.models.cnn import synthetic_cnn

from .common import emit

MIB = 2 ** 20
# the paper's Table 4/6 range: models that spill on one TPU but whose
# layers fit individually (first drop .. fourth drop)
F_VALUES = (460, 500, 540, 580, 620, 660, 700, 740)


def run() -> None:
    # Table 4 / Table 6 analogues: per-stage memory for 4-way splits
    mem_rows = []
    for f in F_VALUES:
        g = synthetic_cnn(f).to_layer_graph()
        m = EdgeTPUModel(g)
        row = {"size_mib": round(g.total_bytes / MIB, 2)}
        for strat in ("comp", "balanced"):
            pl = plan(DeploymentSpec(stages=4, strategy=strat),
                      graph=g, tpu_model=m)
            mems = m.stage_memories(pl.cuts)
            row[f"{strat}_dev_mib"] = "|".join(
                f"{r.device_bytes/MIB:.2f}" for r in mems)
            row[f"{strat}_host_mib"] = "|".join(
                f"{r.host_bytes/MIB:.2f}" for r in mems)
        mem_rows.append(row)
    emit("table4_table6_synthetic_segment_memory", mem_rows,
         ["size_mib", "comp_dev_mib", "comp_host_mib",
          "balanced_dev_mib", "balanced_host_mib"])

    # Fig. 6 / Fig. 7: speedups for 2/3/4 TPUs
    sp_rows = []
    for f in F_VALUES:
        g = synthetic_cnn(f).to_layer_graph()
        m = EdgeTPUModel(g)
        row = {"f": f, "size_mib": round(g.total_bytes / MIB, 2),
               "t1_ms": round(m.single_tpu_time() * 1e3, 2)}
        for n in (2, 3, 4):
            for strat in ("comp", "prof", "balanced"):
                pl = plan(DeploymentSpec(stages=n, strategy=strat),
                          graph=g, tpu_model=m)
                row[f"{strat}_x{n}"] = round(m.speedup(pl.cuts, batch=15), 2)
        sp_rows.append(row)
    emit("fig6_fig7_synthetic_speedups", sp_rows,
         ["f", "size_mib", "t1_ms"]
         + [f"{s}_x{n}" for n in (2, 3, 4)
            for s in ("comp", "prof", "balanced")])

    # paper §6.2 claim: balanced == prof on the synthetic family.  Under
    # our time model both reach the same minimax segment size; prof
    # additionally exploits the stage-IO asymmetry (the last stage sends no
    # output), worth ~5% at n=3.  Report the worst ratio.
    worst = max(r[f"prof_x{n}"] / r[f"balanced_x{n}"]
                for r in sp_rows for n in (2, 3, 4))
    exact = sum(1 for r in sp_rows
                if all(abs(r[f"balanced_x{n}"] - r[f"prof_x{n}"]) <= 0.05
                       for n in (2, 3, 4)))
    print(f"derived: balanced within {(worst-1)*100:.1f}% of prof on all "
          f"synthetic models (exact on {exact}/{len(sp_rows)}; paper: "
          f"identical partitions — the gap is stage-IO placement below "
          f"the paper's measurement resolution)")


if __name__ == "__main__":
    run()
