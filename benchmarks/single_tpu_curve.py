"""Paper Fig. 2 + Fig. 4 + Table 2: single-TPU performance vs model size,
with device/host memory usage (analytical Edge TPU model)."""
from __future__ import annotations

from repro.core import EdgeTPUModel
from repro.models.cnn import REAL_CNNS, synthetic_cnn

from .common import emit

MIB = 2 ** 20


def run() -> None:
    rows = []
    for f in range(32, 1160, 40):
        m = EdgeTPUModel(synthetic_cnn(f).to_layer_graph())
        rep = m.whole_model_memory()
        rows.append({
            "f": f,
            "size_mib": round(m.graph.total_bytes / MIB, 2),
            "device_mib": round(rep.device_bytes / MIB, 2),
            "host_mib": round(rep.host_bytes / MIB, 2),
            "time_ms": round(m.single_tpu_time() * 1e3, 2),
            "tops": round(m.single_tpu_tops(), 3),
        })
    emit("fig2_fig4_synthetic_curve", rows,
         ["f", "size_mib", "device_mib", "host_mib", "time_ms", "tops"])

    # Table 2: memory before/after each big drop
    drops = []
    prev_host = 0.0
    for r in rows:
        if r["host_mib"] > prev_host + 0.5:
            drops.append({"size_mib": r["size_mib"],
                          "device_mib": r["device_mib"],
                          "host_mib": r["host_mib"],
                          "host_frac": round(
                              r["host_mib"] / r["size_mib"], 2)})
        prev_host = r["host_mib"]
    emit("table2_spill_steps", drops,
         ["size_mib", "device_mib", "host_mib", "host_frac"])


def run_real() -> None:
    """Paper Table 3 + Fig. 2 real-model points."""
    rows = []
    for name, fn in REAL_CNNS.items():
        g = fn().to_layer_graph()
        m = EdgeTPUModel(g)
        rep = m.whole_model_memory()
        rows.append({
            "model": name,
            "size_mib": round(g.total_bytes / MIB, 2),
            "device_mib": round(rep.device_bytes / MIB, 2),
            "host_mib": round(rep.host_bytes / MIB, 2),
            "time_ms": round(m.single_tpu_time() * 1e3, 2),
            "tops": round(m.single_tpu_tops(), 3),
        })
    emit("table3_real_memory", rows,
         ["model", "size_mib", "device_mib", "host_mib", "time_ms", "tops"])


if __name__ == "__main__":
    run()
    run_real()
