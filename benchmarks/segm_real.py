"""Paper Table 5 (SEGM_COMP on real CNNs) + Table 7 (SEGM_BALANCED headline)
+ the beyond-paper cost-balanced variant.

Columns mirror the paper: TPU count (minimum that ideally avoids host
memory), host MiB under each strategy, inference time (15-input batch,
per-inference), speedups absolute and normalized, and the paper's reported
numbers side-by-side where available."""
from __future__ import annotations

from repro.api import DeploymentSpec, plan
from repro.core import EdgeTPUModel
from repro.core.placement import min_stages_no_spill
from repro.models.cnn import REAL_CNNS

from .common import emit

MIB = 2 ** 20

# (paper num TPUs, paper 1-TPU ms, paper comp ms, paper balanced ms)
PAPER_T57 = {
    "Xception": (4, 60.11, 16.60, 12.64),
    "ResNet50": (4, 29.69, 7.60, 5.28),
    "ResNet50V2": (4, 30.94, 8.15, 6.13),
    "ResNet101": (6, 44.73, 11.58, 5.59),
    "ResNet101V2": (6, 54.94, 11.33, 5.52),
    "ResNet152": (8, 68.94, 12.62, 6.30),
    "ResNet152V2": (8, 72.84, 12.87, 6.63),
    "InceptionV3": (4, 36.96, 11.24, 6.72),
    "InceptionV4": (7, 82.73, 13.94, 8.69),
    "InceptionResNetV2": (8, 86.87, 21.55, 8.28),
    "DenseNet121": (2, 14.88, 8.52, 6.05),
    "DenseNet169": (3, 30.94, 12.97, 8.96),
    "DenseNet201": (4, 50.12, 14.11, 10.13),
    "EfficientNetLiteB3": (2, 10.31, 3.96, 3.88),
    "EfficientNetLiteB4": (3, 38.17, 10.99, 10.68),
}


def run() -> None:
    rows = []
    for name, paper in PAPER_T57.items():
        g = REAL_CNNS[name]().to_layer_graph()
        m = EdgeTPUModel(g)
        n = min_stages_no_spill(g, m)
        t1 = m.single_tpu_time() * 1e3

        rec = {"model": name, "n_tpus": n, "paper_n": paper[0],
               "t1_ms": round(t1, 2), "paper_t1_ms": paper[1]}
        for strat in ("comp", "balanced", "balanced_cost"):
            pl = plan(DeploymentSpec(stages=n, strategy=strat),
                      graph=g, tpu_model=m)
            mems = m.stage_memories(pl.cuts)
            host = sum(r.host_bytes for r in mems) / MIB
            t = m.pipeline_batch_time(pl.cuts, batch=15) / 15 * 1e3
            rec[f"{strat}_host_mib"] = round(host, 2)
            rec[f"{strat}_ms"] = round(t, 2)
            rec[f"{strat}_speedup"] = round(t1 / t, 2)
            rec[f"{strat}_norm"] = round(t1 / t / n, 2)
            rec[f"{strat}_ds_mib"] = round(pl.imbalance / MIB, 2)
        rec["bal_vs_comp"] = round(rec["comp_ms"] / rec["balanced_ms"], 2)
        rec["paper_bal_vs_comp"] = round(paper[2] / paper[3], 2)
        rows.append(rec)

    emit("table5_table7_real_models", rows,
         ["model", "n_tpus", "paper_n", "t1_ms", "paper_t1_ms",
          "comp_host_mib", "comp_ms", "comp_speedup", "comp_ds_mib",
          "balanced_host_mib", "balanced_ms", "balanced_speedup",
          "balanced_norm", "balanced_cost_ms", "balanced_cost_speedup",
          "bal_vs_comp", "paper_bal_vs_comp"])

    # paper-claim validation summary
    n_bal_better = sum(1 for r in rows
                       if r["balanced_ms"] <= r["comp_ms"] * 1.001)
    n_superlinear = sum(1 for r in rows if r["balanced_norm"] > 1.0)
    n_no_host = sum(1 for r in rows if r["balanced_host_mib"] == 0.0)
    n_cost_better = sum(1 for r in rows
                        if r["balanced_cost_ms"] < r["balanced_ms"] - 1e-9)
    print(f"derived: balanced<=comp on {n_bal_better}/{len(rows)} "
          f"(paper: 15/15); superlinear on {n_superlinear}/{len(rows)} "
          f"(paper: 15/15); zero-host on {n_no_host}/{len(rows)} "
          f"(paper: 15/15); beyond-paper cost-balance improves "
          f"{n_cost_better}/{len(rows)}")


if __name__ == "__main__":
    run()
