"""Shared benchmark utilities: CSV emission + artifact directory."""
from __future__ import annotations

import json
import os
import time
from typing import Any, Dict, Iterable, List

ARTIFACTS = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                         "artifacts")


def emit(table: str, rows: List[Dict[str, Any]], keys: Iterable[str]) -> None:
    """Print `name,us_per_call,derived`-style CSV and save JSON artifact."""
    keys = list(keys)
    print(f"\n# {table}")
    print(",".join(keys))
    for r in rows:
        print(",".join(str(r.get(k, "")) for k in keys))
    os.makedirs(ARTIFACTS, exist_ok=True)
    with open(os.path.join(ARTIFACTS, f"{table}.json"), "w") as f:
        json.dump(rows, f, indent=1)


class Timer:
    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *a):
        self.dt = time.perf_counter() - self.t0
