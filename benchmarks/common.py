"""Shared benchmark utilities: CSV emission + the canonical artifact
writers.

Artifact layout (see EXPERIMENTS.md §Benchmark artifacts):

* ``BENCH_<name>.json`` at the repo root — the acceptance artifact a
  benchmark's full mode records, written only through :func:`write_bench`
  so every bench lands the same way (and a copy rides along under
  ``benchmarks/artifacts/`` for archival tooling that syncs one dir).
* ``benchmarks/artifacts/<table>.json`` — per-table row dumps from
  :func:`emit`, the CSV companion.
"""
from __future__ import annotations

import json
import os
import time
from typing import Any, Dict, Iterable, List

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
ARTIFACTS = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                         "artifacts")


def emit(table: str, rows: List[Dict[str, Any]], keys: Iterable[str]) -> None:
    """Print `name,us_per_call,derived`-style CSV and save JSON artifact."""
    keys = list(keys)
    print(f"\n# {table}")
    print(",".join(keys))
    for r in rows:
        print(",".join(str(r.get(k, "")) for k in keys))
    os.makedirs(ARTIFACTS, exist_ok=True)
    with open(os.path.join(ARTIFACTS, f"{table}.json"), "w") as f:
        json.dump(rows, f, indent=1)


def write_bench(name: str, summary: Dict[str, Any]) -> str:
    """Write a benchmark's acceptance artifact the canonical way:
    ``BENCH_<name>.json`` at the repo root plus a copy in the artifacts
    dir.  Returns the repo-root path (also printed, the grep target CI
    logs rely on)."""
    out = os.path.join(REPO_ROOT, f"BENCH_{name}.json")
    with open(out, "w") as f:
        json.dump(summary, f, indent=1)
    os.makedirs(ARTIFACTS, exist_ok=True)
    with open(os.path.join(ARTIFACTS, f"BENCH_{name}.json"), "w") as f:
        json.dump(summary, f, indent=1)
    print(f"wrote {out}")
    return out


class Timer:
    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *a):
        self.dt = time.perf_counter() - self.t0
