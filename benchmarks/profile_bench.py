"""Profile -> calibrate -> plan: the measured cost loop end to end.

The paper's segmentation is profile-based; this bench exercises the whole
ISSUE-5 pipeline on real JAX forwards (host CPU standing in for the Edge
TPU, exactly as the analytical model does elsewhere in the repo):

1. **capture** a layer-granular :class:`repro.profiling.ProfileTrace` of
   each model (warmup + repeats + trimmed mean, persisted to
   ``benchmarks/artifacts/trace_<model>.json``);
2. **modeling error** — price one fixed params-balanced plan against the
   trace with (a) the uncalibrated analytic Edge TPU model and (b) the
   :class:`~repro.profiling.CalibratedCostSource` least-squares fit of
   the same model to the trace, and compare the mean modeled-vs-measured
   stage-time error (the ``PlanReport.stage_time_error_pct`` column);
3. **plan deltas** — plan again with ``cost_source="trace:<path>"`` and
   record how the cuts move, plus each plan's *measured* bottleneck
   stage time under the trace (trace-backed planning must not be worse).

Acceptance (ISSUE 5): the calibrated source reduces the mean stage-time
modeling error vs the uncalibrated analytic model on >= 3 profiled
models.  Summary lands in ``BENCH_profile.json`` at the repo root.

    PYTHONPATH=src python -m benchmarks.profile_bench
    PYTHONPATH=src python -m benchmarks.profile_bench --smoke
"""
from __future__ import annotations

import argparse
import os
from typing import Dict, List, Optional

from repro.api import DeploymentSpec, PlanReport, plan
from repro.core import EdgeTPUModel, PlacementPlan
from repro.models.cnn import REAL_CNNS, synthetic_cnn
from repro.profiling import CalibratedCostSource, profile_model

from .common import ARTIFACTS, REPO_ROOT, emit, write_bench


# small, fast-forward members of the zoo + one synthetic §3.1 model: the
# profiler runs every depth level (warmup+repeats) eagerly on CPU, so the
# big Inception/ResNet-152 graphs would take minutes each without adding
# signal (pass --models to include them anyway)
DEFAULT_MODELS = ("MobileNet", "MobileNetV2", "EfficientNetLiteB0",
                  "synthetic:64")


def build_model(name: str):
    if name.startswith("synthetic:"):
        return synthetic_cnn(int(name.split(":", 1)[1]))
    return REAL_CNNS[name]()


def bench_model(name: str, warmup: int, repeats: int) -> Dict:
    gm = build_model(name)
    g = gm.to_layer_graph()
    trace = profile_model(gm, warmup=warmup, repeats=repeats)
    os.makedirs(ARTIFACTS, exist_ok=True)
    trace_path = os.path.join(ARTIFACTS,
                              f"trace_{name.replace(':', '_')}.json")
    trace.save(trace_path)

    s = max(2, min(4, g.depth - 1))
    # -- modeling error on one fixed stage partition -------------------------
    pl = plan(DeploymentSpec(stages=s, strategy="balanced_norefine"),
              graph=g)
    analytic_model = EdgeTPUModel(g)
    err_analytic = PlanReport.from_plan(
        pl, base_model=analytic_model, trace=trace).stage_time_error_pct
    cal_source = CalibratedCostSource(trace)
    cal_model = EdgeTPUModel(g, cost_source=cal_source)
    pl_cal = PlacementPlan.from_cuts(g, pl.cuts, strategy="balanced_norefine",
                                     tpu_model=cal_model)
    err_cal = PlanReport.from_plan(
        pl_cal, base_model=cal_model, trace=trace).stage_time_error_pct

    # -- plan deltas: analytic vs trace-backed planning ----------------------
    spec_kw = dict(stages=s, strategy="balanced_cost", refine=False)
    pl_a = plan(DeploymentSpec(**spec_kw), graph=g)
    pl_t = plan(DeploymentSpec(cost_source=f"trace:{trace_path}", **spec_kw),
                graph=g)
    measured_a = trace.stage_times(pl_a.stage_depth_ranges)
    measured_t = trace.stage_times(pl_t.stage_depth_ranges)
    max_a, max_t = max(measured_a), max(measured_t)

    return {
        "model": name, "depth": g.depth, "stages": s,
        "trace_path": os.path.relpath(trace_path, REPO_ROOT),
        "trace_total_ms": round(trace.total_time_s * 1e3, 3),
        "err_analytic_pct": round(err_analytic, 2),
        "err_calibrated_pct": round(err_cal, 2),
        "calibration_improves": bool(err_cal < err_analytic),
        "fit": {k: (float(f"{v:.4g}") if isinstance(v, float) else v)
                for k, v in cal_source.coefficients().items()},
        "cuts_analytic": pl_a.cuts,
        "cuts_trace": pl_t.cuts,
        "cuts_changed": bool(pl_a.cuts != pl_t.cuts),
        "measured_max_stage_ms_analytic_cuts": round(max_a * 1e3, 4),
        "measured_max_stage_ms_trace_cuts": round(max_t * 1e3, 4),
        "trace_plan_not_worse": bool(max_t <= max_a * (1 + 1e-9)),
    }


def run(models: Optional[List[str]] = None, warmup: int = 1,
        repeats: int = 5, write: bool = True) -> Dict:
    names = list(models or DEFAULT_MODELS)
    unknown = [n for n in names if not n.startswith("synthetic:")
               and n not in REAL_CNNS]
    if unknown:
        raise SystemExit(f"unknown model(s) {unknown}; pick from "
                         f"{sorted(REAL_CNNS)} or synthetic:<f>")
    results = []
    for name in names:
        r = bench_model(name, warmup, repeats)
        results.append(r)
        print(f"{name:22s} d={r['depth']:3d} s={r['stages']}  "
              f"err analytic {r['err_analytic_pct']:8.1f}% -> "
              f"calibrated {r['err_calibrated_pct']:6.1f}%  "
              f"cuts {r['cuts_analytic']} -> {r['cuts_trace']}  "
              f"measured max {r['measured_max_stage_ms_analytic_cuts']:.3f}"
              f" -> {r['measured_max_stage_ms_trace_cuts']:.3f} ms")

    emit("profile_bench",
         [{"name": f"profile_{r['model']}",
           "us_per_call": r["err_calibrated_pct"],
           "derived": (f"analytic={r['err_analytic_pct']}%,"
                       f"improves={r['calibration_improves']},"
                       f"cuts_changed={r['cuts_changed']}")}
          for r in results],
         ["name", "us_per_call", "derived"])

    improved = sum(1 for r in results if r["calibration_improves"])
    not_worse = sum(1 for r in results if r["trace_plan_not_worse"])
    summary = {
        "note": "profile->calibrate->plan loop on host-CPU JAX forwards "
                "(the profiled device; the uncalibrated analytic model "
                "predicts Edge TPU magnitudes, hence its large error). "
                "err_* = mean modeled-vs-trace stage-time error on a "
                "fixed params-balanced partition; plan deltas compare "
                "analytic vs trace-backed balanced_cost cuts under the "
                "measured profile. See EXPERIMENTS.md §Profiling & "
                "calibration.",
        "profiler": {"warmup": warmup, "repeats": repeats, "trim": 0.2},
        "models": results,
        "acceptance": {
            "models_profiled": len(results),
            "models_calibration_improves": improved,
            "improvement_floor_met": bool(improved >= 3),
            "trace_plans_not_worse": not_worse,
        },
    }
    if write:
        write_bench("profile", summary)
    print(f"\ncalibration improves modeling error on {improved}/"
          f"{len(results)} models; trace-backed cuts not worse on "
          f"{not_worse}/{len(results)}")
    return summary


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--models", nargs="*", default=None,
                    help="Table-1 names or synthetic:<f> "
                         "(default: fast set)")
    ap.add_argument("--repeats", type=int, default=5)
    ap.add_argument("--smoke", action="store_true",
                    help="fast CI mode: one tiny synthetic model, 1 "
                         "repeat, no BENCH_profile.json write; asserts "
                         "the capture->calibrate->plan loop only")
    args = ap.parse_args()
    if args.smoke:
        summary = run(models=args.models or ["synthetic:16"], warmup=0,
                      repeats=1, write=False)
        # smoke gates on the loop being exercised end to end (capture,
        # calibrate, trace-backed plan), not on timing quality — shared
        # CI runners are too noisy for error-magnitude asserts
        acc = summary["acceptance"]
        assert acc["models_profiled"] >= 1, acc
        return
    summary = run(args.models, repeats=args.repeats)
    assert summary["acceptance"]["improvement_floor_met"], \
        summary["acceptance"]


if __name__ == "__main__":
    main()
