"""SPMD pipeline benchmark: modeled-vs-real on a forced multi-device mesh.

Exercises the :class:`~repro.launch.pipeline_spmd.SpmdPipelineExecutor` —
the plan lowered onto a shard_map device mesh (fused per-stage callables,
ppermute hops, last-stage-only gather) — and records three things in
``BENCH_spmd.json`` at the repo root:

1. **Equivalence + throughput** — a CNN ``GraphModel`` (apply_subset layer
   ranges) and an LM smoke config (scan-block ranges) each lowered onto a
   4-stage mesh; per ``--microbatches`` count the end-to-end batch time and
   items/s, plus the max abs error against direct single-device
   application.
2. **Predicted vs achieved per-stage times** — the plan's modeled
   ``stage_times_s`` next to each stage's fused callable timed in
   isolation on its own mesh device (the paper's modeled-vs-real loop at
   execution granularity).
3. **Weight-streaming fill** — ``stream_stage_weights`` with
   ``overlap=True`` (per-stage transfers issued async, the pipeline's AOT
   compile running while they land) vs ``overlap=False`` (each stage's
   transfer completes before the next; compile strictly after).  Two
   numbers per arm, from the :class:`StreamReport`: the wall fill and
   ``blocked_s``, the host time spent *waiting* on transfers.  Overlap
   eliminates the blocked time on any backend (transfers land behind the
   compile; the final drain finds them done) — that is the asserted
   savings.  Wall fill is recorded but not asserted: on the CPU-emulated
   mesh host-to-device copies run on the same worker pool and memory bus
   as every other XLA op, so wall time is conserved whatever the issue
   order; it shrinks only where transfers have their own DMA engine
   (real accelerators).  The measurement uses warm host buffers, per-rep
   device-shard deletion, interleaved arms, and medians
   (fresh-allocation page faults otherwise swamp the signal).

Forced-mesh note: the device count is forced *before* the first jax import
via ``XLA_FLAGS=--xla_force_host_platform_device_count``; all heavy
imports therefore live inside functions.

    PYTHONPATH=src python -m benchmarks.spmd_bench            # full, writes JSON
    PYTHONPATH=src python -m benchmarks.spmd_bench --smoke    # CI: small, no write
"""
from __future__ import annotations

import argparse
import os
import statistics
import time

from .common import write_bench

N_DEVICES = 4
STAGES = 4


# ---------------------------------------------------------------------------
# section 1+2: executor equivalence, throughput, predicted-vs-achieved
# ---------------------------------------------------------------------------
def bench_cnn(mesh, microbatch_counts, *, f, L, hw, batch):
    import jax
    import jax.numpy as jnp

    from repro.api import DeploymentSpec
    from repro.api import plan as api_plan
    from repro.launch.pipeline_spmd import SpmdPipelineExecutor
    from repro.models.cnn import synthetic_cnn

    model = synthetic_cnn(f, L=L, hw=hw)
    params = model.init(jax.random.PRNGKey(0))
    pl = api_plan(DeploymentSpec(stages=STAGES,
                                 strategy="balanced_norefine"),
                  graph=model.to_layer_graph())
    x = jax.random.normal(jax.random.PRNGKey(1), (batch, hw, hw, 3))
    ref = model.apply(params, x)

    rows, max_err, pred, ach = [], 0.0, None, None
    for m in microbatch_counts:
        with SpmdPipelineExecutor.for_cnn(model, params, pl,
                                          mesh=mesh, n_microbatches=m,
                                          batch_size=batch) as ex:
            got = ex(x)                      # warmup (compile)
            t0 = time.perf_counter()
            got = ex(x)
            dt = time.perf_counter() - t0
            err = float(jnp.max(jnp.abs(got - ref)))
            max_err = max(max_err, err)
            rows.append({"n_microbatches": m, "batch_s": dt,
                         "items_per_s": batch / dt, "max_err": err,
                         "fill_s": ex.fill_s})
            if m == microbatch_counts[-1]:
                pred = ex.predicted_stage_times()
                ach = ex.achieved_stage_times()
        print(f"  cnn m={m}: {batch / dt:8.1f} items/s  err {err:.2e}")
    return {"model": model.name, "stages": STAGES, "batch": batch,
            "equivalence_max_err": max_err, "throughput": rows,
            "predicted_stage_s": pred, "achieved_stage_s": ach}


def bench_lm(mesh, microbatch_counts, *, arch, seq, batch):
    import jax
    import jax.numpy as jnp

    from repro import configs
    from repro.api import DeploymentSpec
    from repro.api import plan as api_plan
    from repro.configs.common import concrete_batch
    from repro.launch.pipeline_spmd import SpmdPipelineExecutor
    from repro.models import api as lm_api
    from repro.models import lm_graph

    cfg = configs.get(arch).smoke_config()
    params = lm_api.init(cfg, jax.random.PRNGKey(0))
    tokens = concrete_batch(cfg, seq, batch, kind="prefill")["tokens"]
    g = lm_graph.lm_layer_graph(cfg, seq_len=seq)
    pl = api_plan(DeploymentSpec(stages=STAGES,
                                 strategy="balanced_norefine"), graph=g)
    ref = lm_api.forward(cfg, params, {"tokens": tokens})

    rows, max_err, pred, ach = [], 0.0, None, None
    for m in microbatch_counts:
        with SpmdPipelineExecutor.for_lm(cfg, params, pl,
                                         mesh=mesh, n_microbatches=m,
                                         batch_size=batch,
                                         seq_len=seq) as ex:
            got = ex(tokens)                 # warmup (compile)
            t0 = time.perf_counter()
            got = ex(tokens)
            dt = time.perf_counter() - t0
            err = float(jnp.max(jnp.abs(got - ref)))
            max_err = max(max_err, err)
            rows.append({"n_microbatches": m, "batch_s": dt,
                         "items_per_s": batch / dt, "max_err": err,
                         "fill_s": ex.fill_s})
            if m == microbatch_counts[-1]:
                pred = ex.predicted_stage_times()
                ach = ex.achieved_stage_times()
        print(f"  lm  m={m}: {batch / dt:8.1f} items/s  err {err:.2e}")
    return {"arch": f"{arch}-smoke", "stages": STAGES, "seq": seq,
            "batch": batch, "equivalence_max_err": max_err,
            "throughput": rows, "predicted_stage_s": pred,
            "achieved_stage_s": ach}


# ---------------------------------------------------------------------------
# section 3: weight-streaming fill, overlapped vs serial
# ---------------------------------------------------------------------------
def _make_compile_fn(seed: int, depth: int):
    """A cache-busted stand-in for the pipeline's AOT compile: the baked
    ``seed`` constant makes every rep's HLO distinct (same structure and
    cost both arms), so jit's cache cannot turn later compiles into
    no-ops and erase the overlap partner."""
    import jax
    import jax.numpy as jnp

    def f(x):
        y = x
        for i in range(depth):
            y = jnp.tanh(y @ x + (seed + i))
        return y

    jitted = jax.jit(f)
    struct = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    return lambda: jitted.lower(struct).compile()


def bench_fill(mesh, *, payload_mb: int, reps: int, compile_depth: int):
    import numpy as np

    import jax

    from repro.launch.pipeline_spmd import stream_stage_weights

    elems = int(payload_mb * 2**20 / 4 / STAGES)
    rng = np.random.default_rng(0)
    # warm host buffer: allocated (and its pages touched) exactly once —
    # fresh giant allocations per rep cause page-fault storms that swamp
    # the transfer-vs-compile signal
    stacked = {"w": rng.standard_normal((STAGES, elems)).astype(np.float32)}

    wall = {True: [], False: []}
    blocked = {True: [], False: []}
    for rep in range(reps):
        arms = [("serial", False), ("overlap", True)]
        if rep % 2:                    # alternate order to cancel drift
            arms.reverse()
        for name, ov in arms:
            g, compiled, stream = stream_stage_weights(
                mesh, stacked, "model", overlap=ov,
                compile_fn=_make_compile_fn(rep * 2 + int(ov),
                                            compile_depth))
            assert compiled is not None
            for leaf in jax.tree.leaves(g):
                leaf.delete()          # release device memory for next rep
            wall[ov].append(stream.fill_s)
            blocked[ov].append(stream.blocked_s)
            print(f"  fill rep {rep} {name}: wall {stream.fill_s * 1e3:7.1f}"
                  f"  blocked {stream.blocked_s * 1e3:7.1f} ms")
    med = statistics.median
    return {"payload_mb": payload_mb, "stages": STAGES, "reps": reps,
            "serial_fill_s": wall[False], "overlap_fill_s": wall[True],
            "serial_blocked_s": blocked[False],
            "overlap_blocked_s": blocked[True],
            "serial_median_s": med(wall[False]),
            "overlap_median_s": med(wall[True]),
            "serial_blocked_median_s": med(blocked[False]),
            "overlap_blocked_median_s": med(blocked[True]),
            "wall_savings_s": med(wall[False]) - med(wall[True]),
            "blocked_savings_s": (med(blocked[False])
                                  - med(blocked[True]))}


# ---------------------------------------------------------------------------
def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="fast CI mode: tiny models and payload, no "
                         "BENCH_spmd.json write, no overlap timing assert "
                         "(functional equivalence still asserted)")
    ap.add_argument("--fill-mb", type=int, default=None,
                    help="total synthetic stage-weight payload for the "
                         "streaming section (default 1024 full / 8 smoke)")
    ap.add_argument("--fill-reps", type=int, default=None,
                    help="interleaved serial/overlap rep pairs "
                         "(default 5 full / 1 smoke)")
    args = ap.parse_args()

    # must precede the first jax import anywhere in the process
    os.environ["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={N_DEVICES} "
        + os.environ.get("XLA_FLAGS", ""))
    import jax

    from repro.launch.mesh import make_mesh

    assert jax.device_count() >= N_DEVICES, (
        f"need {N_DEVICES} devices, got {jax.device_count()} — jax was "
        f"imported before the XLA_FLAGS override took effect")
    mesh = make_mesh((1, N_DEVICES), ("data", "model"))

    smoke = args.smoke
    fill_mb = args.fill_mb or (8 if smoke else 1024)
    fill_reps = args.fill_reps or (1 if smoke else 5)
    microbatches = [2, 4] if smoke else [1, 2, 4, 8]

    print("# cnn executor")
    cnn = bench_cnn(mesh, microbatches,
                    f=4 if smoke else 8, L=6 if smoke else 8,
                    hw=16 if smoke else 32, batch=8 if smoke else 16)
    print("# lm executor")
    lm = bench_lm(mesh, microbatches, arch="qwen3-1.7b",
                  seq=16 if smoke else 32, batch=8 if smoke else 16)
    print("# weight-streaming fill")
    fill = bench_fill(mesh, payload_mb=fill_mb, reps=fill_reps,
                      compile_depth=20 if smoke else 60)

    summary = {
        "note": "PlacementPlan lowered onto a forced "
                f"{N_DEVICES}-device host mesh (shard_map + ppermute, "
                "fused per-stage callables, last-stage-only gather); "
                "see EXPERIMENTS.md §SPMD execution",
        "smoke": smoke,
        "n_devices": N_DEVICES,
        "cnn": cnn,
        "lm": lm,
        "weight_streaming": fill,
        "acceptance": {
            "cnn_equivalent": bool(cnn["equivalence_max_err"] < 1e-3),
            "lm_equivalent": bool(lm["equivalence_max_err"] < 2e-2),
            # overlap drives host-blocked transfer time to ~0: the
            # non-amortizing weight-load term lands behind the compile
            "overlap_unblocks_host": bool(
                fill["blocked_savings_s"] > 0
                and fill["overlap_blocked_median_s"]
                    < 0.5 * fill["serial_blocked_median_s"]),
            "blocked_savings_ms": fill["blocked_savings_s"] * 1e3,
            # wall fill on the CPU-emulated mesh is informational only
            # (shared worker pool + memory bus conserve it; see module
            # docstring) — real accelerators convert the unblocked time
            # into wall savings via their DMA engines
            "wall_savings_ms": fill["wall_savings_s"] * 1e3,
        },
    }
    assert summary["acceptance"]["cnn_equivalent"], cnn["equivalence_max_err"]
    assert summary["acceptance"]["lm_equivalent"], lm["equivalence_max_err"]
    print(f"fill wall   serial -> overlap: "
          f"{fill['serial_median_s'] * 1e3:7.0f} -> "
          f"{fill['overlap_median_s'] * 1e3:7.0f} ms")
    print(f"fill blocked serial -> overlap: "
          f"{fill['serial_blocked_median_s'] * 1e3:7.0f} -> "
          f"{fill['overlap_blocked_median_s'] * 1e3:7.0f} ms")
    if not smoke:
        assert summary["acceptance"]["overlap_unblocks_host"], fill
        write_bench("spmd", summary)


if __name__ == "__main__":
    main()
