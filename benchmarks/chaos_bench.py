"""Chaos benchmark: the fault-tolerance tier under injected failures
(ISSUE 6 acceptance).

Four scenarios over a simulated-latency pipeline built from a balanced
Table-1 plan (same construction as ``serving_bench``), replicated on the
pacing stages:

* **baseline** — no faults; the latency/throughput reference.
* **failover** — K replica kills (deterministic seed, last replica of
  every stage spared) under open-loop load: the dispatcher re-dispatches
  each dead replica's in-flight envelopes to survivors and the
  order-restoring merge slots them back by stream sequence.
* **hedging** — transient stragglers (first attempt of an unlucky item
  sleeps ~20x; the model is thermal throttling, §4 of the paper) with
  and without ``hedge_after`` speculative re-dispatch; first result wins
  via the merge's dedup-by-sequence.
* **degraded** — a live ``PipelinedModelServer`` loses a whole stage
  under load; the ``HealthMonitor`` replans via ``ElasticPlanner`` and
  hot-swaps through ``reconfigure()`` while ``stage_loss_retries``
  re-admits the requests that failed fast across the dead stage.

Functional acceptance (asserted in every mode, ``--smoke`` included):
zero lost requests, zero misordered outputs, every submitted request
completes exactly once.  Timing acceptance (full mode only — CI boxes
jitter): failover p99 stays under ``P99_INFLATION_BOUND`` x the no-fault
baseline p99, recorded in ``BENCH_chaos.json`` at the repo root.

    PYTHONPATH=src python -m benchmarks.chaos_bench
    PYTHONPATH=src python -m benchmarks.chaos_bench --smoke
"""
from __future__ import annotations

import argparse
import threading
import time
from typing import Dict, List, Optional

from repro.api import DeploymentSpec, plan
from repro.models.cnn import REAL_CNNS
from repro.runtime import (ElasticPlanner, FaultPolicy, HealthMonitor,
                           replica_kill_schedule, run_chaos_executor)
from repro.serving import PipelinedModelServer

from .common import emit, write_bench


MODEL = "ResNet50"
STAGES = 4
TARGET_MAX_S = 2e-3         # pacing stage ~2 ms
REPLICAS_PER_STAGE = 3
P99_INFLATION_BOUND = 5.0   # documented failover-vs-baseline p99 bound


def stage_latencies(model: str, stages: int) -> List[float]:
    g = REAL_CNNS[model]().to_layer_graph()
    pl = plan(DeploymentSpec(stages=stages, strategy="balanced_norefine"),
              graph=g)
    times = [t for t in pl.stage_times_s if t is not None]
    scale = TARGET_MAX_S / max(times)
    return [t * scale for t in times]


def identity_stage(latency_s: float):
    """Like ``simulated_stage`` but returns its input unchanged, so the
    chaos tap can audit exit order against submission order."""
    def fn(x):
        time.sleep(latency_s)
        return x
    return fn


class TransientStraggler:
    """A stage whose *first* attempt at an unlucky item sleeps ~20x (a
    throttled device); any re-attempt (hedge) runs at base speed.  The
    unlucky set is a deterministic function of the item, so hedged and
    unhedged runs see identical stragglers."""

    def __init__(self, base_s: float, every: int = 10, factor: float = 20.0):
        self.base_s = base_s
        self.every = every
        self.factor = factor
        self._seen: Dict[int, int] = {}
        self._lock = threading.Lock()

    def __call__(self, x):
        i = int(x)
        with self._lock:
            attempt = self._seen.get(i, 0)
            self._seen[i] = attempt + 1
        slow = (i % self.every == self.every - 1) and attempt == 0
        time.sleep(self.base_s * (self.factor if slow else 1.0))
        return x


def scenario_baseline(lats, n_requests, interval_s):
    fns = [identity_stage(t) for t in lats]
    reps = [REPLICAS_PER_STAGE] * len(lats)
    return run_chaos_executor(fns, reps, n_requests, interval_s)


def scenario_failover(lats, n_requests, interval_s, n_kills, seed):
    fns = [identity_stage(t) for t in lats]
    reps = [REPLICAS_PER_STAGE] * len(lats)
    # at most one kill per stage: two of three replicas survive, so the
    # post-kill capacity still covers the offered load — the measured
    # p99 inflation is failover cost, not sustained overload
    duration = n_requests * interval_s
    events = replica_kill_schedule(reps, n_kills, duration, seed=seed,
                                   spare_last=True, max_per_stage=1)
    return run_chaos_executor(fns, reps, n_requests, interval_s,
                              events=events)


def scenario_hedging(lats, n_requests, interval_s,
                     hedge_after: Optional[float]):
    # light feeder stages + one replicated straggler-prone pacing stage:
    # the offered load stays well under capacity so queue wait (which
    # also counts toward the hedge age) does not drown the signal
    # straggles add (factor-1)*base/every extra seconds per item spread
    # over the replicas; arrivals are slowed to keep offered load under
    # that effective capacity — hedging cuts tail latency, it cannot
    # rescue an overloaded stage (the 20x first attempt still burns a
    # replica for its full sleep)
    base = max(lats)
    fns = [identity_stage(base / 10) for _ in lats[:-1]] \
        + [TransientStraggler(base, every=20)]
    reps = [1] * (len(lats) - 1) + [REPLICAS_PER_STAGE]
    return run_chaos_executor(fns, reps, n_requests, interval_s * 2,
                              hedge_after=hedge_after)


def scenario_degraded(n_requests: int) -> Dict:
    """Kill a whole stage of a live server: HealthMonitor -> ElasticPlanner
    -> reconfigure(), stage_loss_retries re-admits the casualties."""
    g = REAL_CNNS[MODEL]().to_layer_graph()
    ep = ElasticPlanner(g, "balanced_norefine")
    pl = ep.plan_for(STAGES)

    def builder(p):
        return [identity_stage(5e-4)] * p.n_stages

    srv = PipelinedModelServer(pl, builder(pl), max_batch=8,
                               max_wait_s=0.002, stage_loss_retries=8)
    srv.executor.start()
    srv.start()
    mon = HealthMonitor(srv, ep, builder,
                        policy=FaultPolicy(poll_interval_s=0.005)).start()
    t0 = time.monotonic()
    reqs = [srv.submit(i) for i in range(n_requests // 2)]
    time.sleep(0.01)
    srv.executor.kill_stage(1)
    reqs += [srv.submit(i) for i in range(n_requests // 2, n_requests)]
    done = all(r.event.wait(60) for r in reqs)
    duration = time.monotonic() - t0
    errs = [r for r in reqs if r.error is not None]
    snap = srv.snapshot()
    mon.stop()
    srv.stop()
    return {
        "submitted": len(reqs),
        "completed": sum(1 for r in reqs if r.error is None and r.event.is_set()),
        "hung": 0 if done else sum(1 for r in reqs if not r.event.is_set()),
        "failed": len(errs),
        "retried": snap["retried"],
        "replans": mon.replans,
        "duration_s": duration,
    }


def run(n_requests: int, interval_s: float, n_kills: int, seed: int,
        hedge_after: float, write: bool, timing_asserts: bool) -> Dict:
    lats = stage_latencies(MODEL, STAGES)

    base = scenario_baseline(lats, n_requests, interval_s)
    fail = scenario_failover(lats, n_requests, interval_s, n_kills, seed)
    unhedged = scenario_hedging(lats, n_requests, interval_s, None)
    hedged = scenario_hedging(lats, n_requests, interval_s, hedge_after)
    degraded = scenario_degraded(max(20, n_requests // 5))

    # exactly-once contract: every mode, every scenario
    for name, rep in (("baseline", base), ("failover", fail),
                      ("unhedged", unhedged), ("hedged", hedged)):
        assert rep.lost == 0, (name, rep.to_dict())
        assert rep.misordered == 0, (name, rep.to_dict())
        assert rep.completed + rep.failed == rep.submitted, \
            (name, rep.to_dict())
        assert rep.failed == 0, (name, rep.to_dict())
    assert fail.kills_applied == n_kills, fail.to_dict()
    assert sum(fail.health["redispatches"]) >= 1, fail.to_dict()
    assert degraded["failed"] == 0 and degraded["hung"] == 0, degraded
    assert degraded["completed"] == degraded["submitted"], degraded
    assert len(degraded["replans"]) >= 1, degraded
    assert sum(hedged.health["hedges"]) >= 1, hedged.to_dict()
    assert sum(unhedged.health["hedges"]) == 0, unhedged.to_dict()

    p99_inflation = (fail.latency["p99_ms"] / base.latency["p99_ms"]
                     if base.latency["p99_ms"] > 0 else 0.0)
    hedge_p99_gain = (unhedged.latency["p99_ms"] / hedged.latency["p99_ms"]
                      if hedged.latency["p99_ms"] > 0 else 0.0)
    if timing_asserts:
        assert p99_inflation <= P99_INFLATION_BOUND, \
            (p99_inflation, base.latency, fail.latency)

    summary = {
        "note": "chaos harness over the fault-tolerant streaming "
                "executor: replica kills with in-flight failover, hedged "
                "dispatch vs transient stragglers, and whole-stage loss "
                "with HealthMonitor degraded-mode replanning; see "
                "EXPERIMENTS.md §Fault tolerance & chaos",
        "config": {"model": MODEL, "stages": STAGES,
                   "replicas_per_stage": REPLICAS_PER_STAGE,
                   "n_requests": n_requests, "interval_ms": interval_s * 1e3,
                   "n_kills": n_kills, "seed": seed,
                   "hedge_after_ms": hedge_after * 1e3},
        "baseline": base.to_dict(),
        "failover": fail.to_dict(),
        "hedging": {"unhedged": unhedged.to_dict(),
                    "hedged": hedged.to_dict(),
                    "p99_gain": round(hedge_p99_gain, 2)},
        "degraded": degraded,
        "acceptance": {
            "lost_requests": 0,
            "misordered_outputs": 0,
            "failover_p99_inflation": round(p99_inflation, 2),
            "p99_inflation_bound": P99_INFLATION_BOUND,
            "bound_met": bool(p99_inflation <= P99_INFLATION_BOUND),
            "degraded_replans": len(degraded["replans"]),
        },
    }
    if write:
        write_bench("chaos", summary)

    emit("chaos_bench", [
        {"name": "chaos_baseline_p99",
         "us_per_call": round(1e3 * base.latency["p99_ms"], 1),
         "derived": f"completed={base.completed}"},
        {"name": "chaos_failover_p99",
         "us_per_call": round(1e3 * fail.latency["p99_ms"], 1),
         "derived": f"kills={fail.kills_applied},"
                    f"redispatches={sum(fail.health['redispatches'])},"
                    f"inflation={round(p99_inflation, 2)}x"},
        {"name": "chaos_hedged_p99",
         "us_per_call": round(1e3 * hedged.latency["p99_ms"], 1),
         "derived": f"hedges={sum(hedged.health['hedges'])},"
                    f"gain={round(hedge_p99_gain, 2)}x"},
        {"name": "chaos_degraded",
         "us_per_call": round(1e6 * degraded["duration_s"]
                              / max(1, degraded["submitted"]), 1),
         "derived": f"retried={degraded['retried']},"
                    f"replans={len(degraded['replans'])}"},
    ], ["name", "us_per_call", "derived"])
    print(f"failover p99 inflation {p99_inflation:.2f}x "
          f"(bound {P99_INFLATION_BOUND}x), hedging p99 gain "
          f"{hedge_p99_gain:.2f}x, degraded replans "
          f"{len(degraded['replans'])}")
    return summary


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=400)
    ap.add_argument("--interval-ms", type=float, default=1.5,
                    help="open-loop arrival interval; keep above "
                         "max_stage_latency / (replicas - max kills per "
                         "stage) so the post-kill pipeline still covers "
                         "the offered load")
    ap.add_argument("--kills", type=int, default=4)
    ap.add_argument("--seed", type=int, default=7)
    ap.add_argument("--hedge-after-ms", type=float, default=8.0)
    ap.add_argument("--smoke", action="store_true",
                    help="fast CI mode: fewer requests, functional "
                         "asserts only (no timing asserts), no "
                         "BENCH_chaos.json write")
    args = ap.parse_args()
    run(n_requests=60 if args.smoke else args.requests,
        interval_s=args.interval_ms / 1e3,
        n_kills=2 if args.smoke else args.kills,
        seed=args.seed,
        hedge_after=args.hedge_after_ms / 1e3,
        write=not args.smoke,
        timing_asserts=not args.smoke)


if __name__ == "__main__":
    main()
