"""Plan-search wall-time benchmark over the paper's Table-1 model zoo.

Per model, measures end-to-end ``plan(graph, s, strategy)`` for:

* ``balanced`` on the *seed* path — ``LayerGraph(cache=False)`` +
  ``EdgeTPUModel(use_engine=False)``, i.e. per-depth arrays recomputed per
  query and every segment cost a full layer walk (the pre-engine behaviour);
* ``balanced`` on the engine path (acceptance floor: >= 10x on ResNet152);
* ``comp`` and the beyond-paper ``opt`` minimax-time DP;
* ``prof`` feasibility (C(d-1, s-1) candidate count — the paper's point is
  that it explodes for deep models).

It also runs the exact O(d^2 s) DP oracle to confirm ``opt`` achieves a max
modeled stage time <= ``balanced``'s on every model, and folds in the
persistent-executor throughput microbenchmark.  Summary lands in
``BENCH_planner.json`` at the repo root (plus the usual artifacts JSON).
All plans are :class:`~repro.core.placement.PlacementPlan` objects; the
replicated-placement comparison (joint cuts+replicas DP vs. the best
non-replicated plan) lives in ``benchmarks/placement_bench.py``.

    PYTHONPATH=src python -m benchmarks.planner_bench
    PYTHONPATH=src python -m benchmarks.planner_bench --models ResNet152 --repeats 5
"""
from __future__ import annotations

import argparse
import math
import time
from typing import Dict, List

from repro.api import DeploymentSpec, plan
from repro.core import EdgeTPUModel
from repro.core.placement import min_stages_no_spill
from repro.core.segmentation import minimax_time_split
from repro.models.cnn import REAL_CNNS

from .common import emit, write_bench
from .pipeline_serving import run_executor_bench

EXACT_ORACLE_MAX_DEPTH = 600          # O(d^2 s) — skip only absurd depths


def _plan(graph, s, strategy, model):
    """One front-door call (report construction excluded: the timed
    quantity is the plan search, same as the pre-API benchmarks)."""
    return plan(DeploymentSpec(stages=s, strategy=strategy), graph=graph,
                tpu_model=model, attach_report=False)


def _time_plan(graph, s, strategy, model, repeats: int) -> float:
    best = math.inf
    for _ in range(repeats):
        t0 = time.perf_counter()
        _plan(graph, s, strategy, model)
        best = min(best, time.perf_counter() - t0)
    return best


def bench_model(name: str, repeats: int = 3) -> Dict:
    build = REAL_CNNS[name]
    g_fast = build().to_layer_graph()
    m_fast = EdgeTPUModel(g_fast)
    s = min_stages_no_spill(g_fast, m_fast)
    s = max(2, min(s, g_fast.depth - 1))

    # seed baseline: uncached graph + naive layer-walk model
    g_seed = build().to_layer_graph()
    g_seed.set_cache_enabled(False)
    m_seed = EdgeTPUModel(g_seed, spec=m_fast.spec, use_engine=False)
    t_seed = _time_plan(g_seed, s, "balanced", m_seed, max(1, repeats - 2))
    t_engine = _time_plan(g_fast, s, "balanced", m_fast, repeats)
    t_comp = _time_plan(g_fast, s, "comp", m_fast, repeats)
    t_opt = _time_plan(g_fast, s, "opt", m_fast, repeats)

    # plans + quality
    p_bal = _plan(g_fast, s, "balanced", m_fast)
    p_opt = _plan(g_fast, s, "opt", m_fast)
    max_bal = max(m_fast.stage_times(p_bal.cuts))
    max_opt = max(m_fast.stage_times(p_opt.cuts))

    # exact-DP oracle (the dp_split analog over modeled stage time)
    d = g_fast.depth
    if d <= EXACT_ORACLE_MAX_DEPTH:
        oracle_cuts = minimax_time_split(d, s, m_fast.segment_time,
                                         exact=True)
        max_oracle = max(m_fast.stage_times(oracle_cuts))
    else:
        max_oracle = float("nan")

    prof_candidates = math.comb(d - 1, s - 1)
    return {
        "model": name, "depth": d, "stages": s,
        "seed_balanced_ms": round(t_seed * 1e3, 2),
        "engine_balanced_ms": round(t_engine * 1e3, 3),
        "speedup": round(t_seed / t_engine, 1),
        "comp_ms": round(t_comp * 1e3, 3),
        "opt_ms": round(t_opt * 1e3, 3),
        "prof_candidates": prof_candidates,
        "prof_feasible": prof_candidates <= 2_000_000,
        "max_stage_balanced_ms": round(max_bal * 1e3, 4),
        "max_stage_opt_ms": round(max_opt * 1e3, 4),
        "max_stage_oracle_ms": (round(max_oracle * 1e3, 4)
                                if max_oracle == max_oracle else None),
        "opt_le_balanced": bool(max_opt <= max_bal + 1e-15),
        "opt_gain_pct": round((1 - max_opt / max_bal) * 100, 2),
    }


def run(models: List[str] | None = None, repeats: int = 3) -> Dict:
    names = models or list(REAL_CNNS)
    unknown = [n for n in names if n not in REAL_CNNS]
    if unknown:
        raise SystemExit(f"unknown model(s) {unknown}; "
                         f"pick from {sorted(REAL_CNNS)}")
    results = []
    for name in names:
        r = bench_model(name, repeats=repeats)
        results.append(r)
        print(f"{name:22s} d={r['depth']:3d} s={r['stages']}  "
              f"balanced {r['seed_balanced_ms']:8.2f} -> "
              f"{r['engine_balanced_ms']:6.3f} ms ({r['speedup']:6.1f}x)  "
              f"opt {r['opt_ms']:7.3f} ms  "
              f"max-stage opt/bal {r['opt_gain_pct']:+.2f}%  "
              f"oracle_ok={r['opt_le_balanced']}")

    rows = [{"name": f"plan_balanced_{r['model']}",
             "us_per_call": round(r["engine_balanced_ms"] * 1e3, 1),
             "derived": f"seed_ms={r['seed_balanced_ms']},"
                        f"speedup={r['speedup']}x,"
                        f"opt_gain={r['opt_gain_pct']}%"}
            for r in results]
    emit("planner_bench", rows, ["name", "us_per_call", "derived"])

    exec_summary = run_executor_bench(emit_rows=False)
    summary = {
        "note": "plan-search wall time per strategy (analytical Edge TPU "
                "model) + persistent-executor throughput; see EXPERIMENTS.md",
        "models": results,
        "executor": exec_summary,
        "acceptance": {
            "resnet152_speedup": next((r["speedup"] for r in results
                                       if r["model"] == "ResNet152"), None),
            "all_opt_le_balanced": all(r["opt_le_balanced"]
                                       for r in results),
            "executor_speedup": exec_summary["speedup"],
            "executor_threads_created_steady_state":
                exec_summary["threads_created_steady_state"],
        },
    }
    write_bench("planner", summary)
    print(f"executor: {exec_summary['speedup']}x, "
          f"{exec_summary['threads_created_steady_state']} threads created "
          f"in steady state")
    return summary


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--models", nargs="*", default=None,
                    help="subset of Table-1 names (default: full zoo)")
    ap.add_argument("--repeats", type=int, default=3)
    args = ap.parse_args()
    summary = run(args.models, repeats=args.repeats)
    acc = summary["acceptance"]
    if acc["resnet152_speedup"] is not None:
        assert acc["resnet152_speedup"] >= 10, acc
    assert acc["all_opt_le_balanced"], acc


if __name__ == "__main__":
    main()
