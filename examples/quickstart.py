"""Quickstart: the paper's pipeline in five minutes, on CPU — through the
``repro.api`` front door.

1. Describe the deployment declaratively (a DeploymentSpec naming a real
   CNN), let the strategy registry plan it, and read the PlanReport.
2. Compare the paper's strategies by swapping one spec field.
3. Really run a pipelined forward (threads + queues, paper Fig. 5) via a
   Deployment handle and check it matches the direct forward.

    PYTHONPATH=src python examples/quickstart.py
    PYTHONPATH=src python examples/quickstart.py --smoke   # CI-sized
"""
import argparse

import jax
import jax.numpy as jnp

from repro.api import DeploymentSpec, deploy, plan
from repro.core import EdgeTPUModel
from repro.models.cnn import REAL_CNNS, synthetic_cnn
from repro.models.layers import GraphModel

MIB = 2 ** 20


def main(smoke: bool = False) -> None:
    # --- 1. one declarative spec; stages=None means the paper's §5.2.2
    # auto rule (fewest TPUs whose refined plan avoids host memory) -------
    graph = REAL_CNNS["ResNet50"]().to_layer_graph()
    model = EdgeTPUModel(graph)
    pl = plan(DeploymentSpec(model="cnn:ResNet50", strategy="balanced"),
              graph=graph, tpu_model=model)
    n = pl.n_stages
    print(f"ResNet50: {graph.summary()}")
    print(f"min TPUs to avoid host memory: {n} (paper Table 5: 4)")
    print(f"report: {pl.report.describe()}\n")

    # --- 2. strategy comparison = one spec field ------------------------
    for strat in ("comp", "balanced_norefine", "balanced"):
        p = plan(DeploymentSpec(stages=n, strategy=strat), graph=graph,
                 tpu_model=model)
        host = p.report.spill_bytes / MIB
        sp = model.speedup(p.cuts, batch=15)
        print(f"{strat:18s} host={host:5.2f} MiB  speedup vs 1 TPU: "
              f"{sp:4.2f}x   {p.describe()}")

    # --- 3. really run a pipelined model (small synthetic CNN) ----------
    print("\npipelined execution check (synthetic CNN, 3 stages):")
    m = synthetic_cnn(6 if smoke else 12, hw=16 if smoke else 32)
    g = m.to_layer_graph()
    params = m.init(jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (1,) + m.input_shape)
    direct = m.apply(params, x)

    dep = deploy(
        DeploymentSpec(stages=3, strategy="balanced_norefine"), graph=g,
        stage_fn_builder=lambda p: [
            (lambda layers: lambda b: m.apply_subset(params, b, layers))(ls)
            for ls in p.stage_layers])
    with dep.executor() as ex:
        outs, _ = ex.run_batch([{GraphModel.INPUT: x}])
    err = float(jnp.max(jnp.abs(outs[0][m.output] - direct)))
    print(f"pipeline vs direct max err: {err:.2e} (stages: "
          f"{[len(ls) for ls in dep.plan.stage_layers]} layers)")
    assert err < 1e-4
    print("OK")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run: smaller synthetic CNN")
    main(smoke=ap.parse_args().smoke)
