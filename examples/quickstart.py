"""Quickstart: the paper's pipeline in five minutes, on CPU.

1. Build a real CNN (ResNet50) as a LayerGraph.
2. Segment it with the paper's three strategies and compare.
3. Run a *real* pipelined forward (threads + queues, paper Fig. 5) and
   check it matches the direct forward.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import EdgeTPUModel, PipelineExecutor, plan
from repro.core.planner import min_stages_no_spill
from repro.models.cnn import REAL_CNNS, synthetic_cnn
from repro.models.layers import GraphModel

MIB = 2 ** 20


def main() -> None:
    # --- 1. the paper's segmentation on ResNet50 ---------------------------
    graph = REAL_CNNS["ResNet50"]().to_layer_graph()
    model = EdgeTPUModel(graph)
    n = min_stages_no_spill(graph, model)
    print(f"ResNet50: {graph.summary()}")
    print(f"min TPUs to avoid host memory: {n} (paper Table 5: 4)\n")

    for strat in ("comp", "balanced_norefine", "balanced"):
        pl = plan(graph, n, strat, tpu_model=model)
        mems = model.stage_memories(pl.cuts)
        host = sum(m.host_bytes for m in mems) / MIB
        sp = model.speedup(pl.cuts, batch=15)
        print(f"{strat:18s} host={host:5.2f} MiB  speedup vs 1 TPU: "
              f"{sp:4.2f}x   {pl.describe()}")

    # --- 2. really run a pipelined model (small synthetic CNN) -------------
    print("\npipelined execution check (synthetic CNN, 3 stages):")
    m = synthetic_cnn(12, hw=32)
    g = m.to_layer_graph()
    pl = plan(g, 3, "balanced_norefine")
    params = m.init(jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (1,) + m.input_shape)
    direct = m.apply(params, x)

    fns = [(lambda layers: lambda b: m.apply_subset(params, b, layers))(ls)
           for ls in pl.stage_layers]
    outs, _ = PipelineExecutor(fns).run_batch([{GraphModel.INPUT: x}])
    err = float(jnp.max(jnp.abs(outs[0][m.output] - direct)))
    print(f"pipeline vs direct max err: {err:.2e} (stages: "
          f"{[len(ls) for ls in pl.stage_layers]} layers)")
    assert err < 1e-4
    print("OK")


if __name__ == "__main__":
    main()
