"""End-to-end serving driver (the paper's kind: inference): segment an LM
with SEGM_BALANCED, serve a *continuous request stream* through the
pipelined executor (per-request futures, no inter-batch barrier), report
throughput + latency percentiles + stage balance, and demonstrate elastic
replanning on a live server plus straggler hedging.

    PYTHONPATH=src python examples/segment_and_serve.py
"""
import time

import jax
import jax.numpy as jnp

from repro import configs
from repro.configs.common import concrete_batch
from repro.core import PlacementPlan, plan
from repro.core.pipeline import stage_balance_metrics
from repro.launch.pipeline_spmd import stage_block_counts
from repro.launch.serve import make_stage_fns
from repro.models import api, lm_graph
from repro.runtime import ElasticPlanner, SpeculativeExecutor
from repro.serving import PipelinedModelServer


def main() -> None:
    arch, stages, n_req, seq = "qwen3-1.7b", 4, 15, 64
    cfg = configs.get(arch).smoke_config()
    params = api.init(cfg, jax.random.PRNGKey(0))
    g = lm_graph.lm_layer_graph(cfg, seq_len=seq)

    # --- plan + stream ------------------------------------------------------
    pl = plan(g, stages, "balanced_norefine")
    counts = stage_block_counts(pl, cfg.n_layers)
    print("plan:", pl.describe())

    def fns_for(p):
        return make_stage_fns(cfg, params,
                              stage_block_counts(p, cfg.n_layers))

    fns = fns_for(pl)
    server = PipelinedModelServer(pl, fns, max_batch=n_req,
                                  max_wait_s=0.005)

    reqs = [concrete_batch(cfg, seq, 1, key=jax.random.PRNGKey(i),
                           kind="prefill")["tokens"] for i in range(n_req)]
    server.serve_batch(reqs[:1])                     # warm the jits
    server.start()                                   # admission loop
    server.snapshot()                                # reset delta window
    t0 = time.perf_counter()
    pending = [server.submit(r) for r in reqs]       # continuous admission
    for req in pending:
        assert req.event.wait(120) and req.error is None
    dt = time.perf_counter() - t0
    outs = [r.result for r in pending]
    snap = server.snapshot()
    m = stage_balance_metrics(snap["stage_busy_s"])
    lat = snap["latency"]
    print(f"{len(outs)} streamed requests in {dt*1e3:.1f} ms "
          f"({snap['throughput_rps']:.1f} req/s), "
          f"p50/p95 latency {lat['p50_s']*1e3:.1f}/{lat['p95_s']*1e3:.1f} ms, "
          f"stage balance {m['balance']:.3f}")

    ref = api.forward(cfg, params, {"tokens": reqs[0]}, last_token_only=True)
    err = float(jnp.max(jnp.abs(outs[0] - ref)))
    assert err < 2e-2, err
    print(f"pipeline output matches direct forward (err {err:.2e})")

    # --- replicated bottleneck stage ----------------------------------------
    # Hand-build a placement replicating the slowest stage across 2 devices:
    # the executor round-robins its traffic over 2 workers and restores
    # stream order, so outputs match the unreplicated run bit-for-bit.
    slowest = max(range(stages), key=lambda i: pl.stages[i].time_s)
    reps = [1] * stages
    reps[slowest] = 2
    pl_rep = PlacementPlan.from_cuts(g, pl.cuts, strategy="replicated",
                                     replicas=reps)
    print(f"\nreplicated plan: {pl_rep.describe()}")
    with PipelinedModelServer(pl_rep, fns, max_batch=n_req) as srv:
        srv.serve_batch(reqs[:1])
        outs_rep = srv.serve_batch(reqs)
    same = all(bool(jnp.array_equal(a, b))
               for a, b in zip(outs, outs_rep))
    print(f"replicated outputs match unreplicated bit-for-bit: {same}")
    assert same

    # plans serialize: ship them instead of re-planning at startup
    pl_back = PlacementPlan.from_json(pl_rep.to_json())
    assert pl_back.cuts == pl_rep.cuts
    assert pl_back.replica_counts == pl_rep.replica_counts
    print("plan JSON round-trip OK")

    # --- elastic: a device leaves, hot-swap the live server ------------------
    ep = ElasticPlanner(g, "balanced_norefine")
    t0 = time.perf_counter()
    pl3 = ep.resize_server(server, fns_for, stages - 1)
    swap_ms = (time.perf_counter() - t0) * 1e3
    print(f"\nelastic: replanned {stages}->{stages-1} stages in "
          f"{ep.replan_times[stages-1]*1e3:.2f} ms, live swap {swap_ms:.1f} "
          f"ms: {pl3.describe()}")
    req = server.submit(reqs[0])                    # served by the new plan
    assert req.event.wait(120) and req.error is None
    err3 = float(jnp.max(jnp.abs(req.result - ref)))
    assert err3 < 2e-2, err3
    print(f"post-resize output still matches (err {err3:.2e})")
    server.stop()

    # --- straggler hedging ----------------------------------------------------
    calls = {"n": 0}

    def flaky_stage(x):
        calls["n"] += 1
        if calls["n"] == 1:
            time.sleep(0.3)                      # transient straggler
        return x

    ex = SpeculativeExecutor(flaky_stage, hedge_after=0.05)
    ex.map(list(range(5)))
    print(f"straggler mitigation: {ex.hedged} hedged dispatch(es) "
          f"recovered the slow item")
    ex.shutdown()


if __name__ == "__main__":
    main()
