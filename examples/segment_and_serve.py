"""End-to-end serving driver (the paper's kind: inference) on the
``repro.api`` front door: one declarative DeploymentSpec is planned through
the strategy registry and deployed; the Deployment handle owns the
streaming server (per-request futures, no inter-batch barrier), reports
throughput + latency percentiles + stage balance, hot-swaps the live
server on an elastic resize (``Deployment.reconfigure``), and the
replicated-bottleneck and straggler-hedging demos ride along.

    PYTHONPATH=src python examples/segment_and_serve.py
    PYTHONPATH=src python examples/segment_and_serve.py --smoke  # CI-sized
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro import configs
from repro.api import Deployment, DeploymentSpec, deploy
from repro.configs.common import concrete_batch
from repro.core import PlacementPlan
from repro.core.pipeline import stage_balance_metrics
from repro.launch.pipeline_spmd import stage_block_counts
from repro.launch.serve import make_stage_fns
from repro.models import api, lm_graph
from repro.runtime import SpeculativeExecutor


def main(smoke: bool = False) -> None:
    arch, stages, n_req, seq = "qwen3-1.7b", 4, (6 if smoke else 15), 64
    cfg = configs.get(arch).smoke_config()
    params = api.init(cfg, jax.random.PRNGKey(0))
    g = lm_graph.lm_layer_graph(cfg, seq_len=seq)

    # --- one declarative spec: model, strategy, serving policy ----------
    spec = DeploymentSpec(model=f"lm:{arch}:seq={seq}", stages=stages,
                          strategy="balanced_norefine",
                          max_batch=n_req, max_wait_s=0.005)

    def fns_for(p):
        return make_stage_fns(cfg, params,
                              stage_block_counts(p, cfg.n_layers))

    dep = deploy(spec, graph=g, stage_fn_builder=fns_for)
    pl = dep.plan
    print("plan:", pl.describe())
    print("report:", pl.report.describe())

    reqs = [concrete_batch(cfg, seq, 1, key=jax.random.PRNGKey(i),
                           kind="prefill")["tokens"] for i in range(n_req)]
    server = dep.serve()
    server.serve_batch(reqs[:1])                     # warm the jits
    server.start()                                   # admission loop
    server.snapshot()                                # reset delta window
    t0 = time.perf_counter()
    pending = [server.submit(r) for r in reqs]       # continuous admission
    for req in pending:
        assert req.event.wait(120) and req.error is None
    dt = time.perf_counter() - t0
    outs = [r.result for r in pending]
    snap = server.snapshot()
    m = stage_balance_metrics(snap["stage_busy_s"])
    lat = snap["latency"]
    print(f"{len(outs)} streamed requests in {dt*1e3:.1f} ms "
          f"({snap['throughput_rps']:.1f} req/s), "
          f"p50/p95 latency {lat['p50_s']*1e3:.1f}/{lat['p95_s']*1e3:.1f} ms, "
          f"stage balance {m['balance']:.3f}")

    ref = api.forward(cfg, params, {"tokens": reqs[0]}, last_token_only=True)
    err = float(jnp.max(jnp.abs(outs[0] - ref)))
    assert err < 2e-2, err
    print(f"pipeline output matches direct forward (err {err:.2e})")

    # --- replicated bottleneck stage ------------------------------------
    # Hand-build a placement replicating the slowest stage across 2
    # devices, then wrap it in a Deployment (Deployment.from_plan): the
    # executor round-robins its traffic over 2 workers and restores
    # stream order, so outputs match the unreplicated run bit-for-bit.
    slowest = max(range(stages), key=lambda i: pl.stages[i].time_s)
    reps = [1] * stages
    reps[slowest] = 2
    pl_rep = PlacementPlan.from_cuts(g, pl.cuts, strategy="replicated",
                                     replicas=reps)
    print(f"\nreplicated plan: {pl_rep.describe()}")
    dep_rep = Deployment.from_plan(pl_rep, graph=g, stage_fn_builder=fns_for)
    with dep_rep.serve() as srv:
        srv.serve_batch(reqs[:1])
        outs_rep = srv.serve_batch(reqs)
    same = all(bool(jnp.array_equal(a, b))
               for a, b in zip(outs, outs_rep))
    print(f"replicated outputs match unreplicated bit-for-bit: {same}")
    assert same

    # specs and plans both serialize: ship a deployment as two JSON
    # documents instead of re-planning at startup
    assert DeploymentSpec.from_json(spec.to_json()) == spec
    pl_back = PlacementPlan.from_json(pl_rep.to_json())
    assert pl_back.cuts == pl_rep.cuts
    assert pl_back.replica_counts == pl_rep.replica_counts
    print("spec + plan JSON round-trip OK")

    # --- elastic: a device leaves, hot-swap the live server -------------
    t0 = time.perf_counter()
    pl3 = dep.reconfigure(stages=stages - 1)
    swap_ms = (time.perf_counter() - t0) * 1e3
    print(f"\nelastic: replanned + live-swapped {stages}->{stages-1} "
          f"stages in {swap_ms:.1f} ms: {pl3.describe()}")
    req = server.submit(reqs[0])                    # served by the new plan
    assert req.event.wait(120) and req.error is None
    err3 = float(jnp.max(jnp.abs(req.result - ref)))
    assert err3 < 2e-2, err3
    print(f"post-resize output still matches (err {err3:.2e})")
    dep.close()

    # --- straggler hedging ----------------------------------------------
    calls = {"n": 0}

    def flaky_stage(x):
        calls["n"] += 1
        if calls["n"] == 1:
            time.sleep(0.3)                      # transient straggler
        return x

    ex = SpeculativeExecutor(flaky_stage, hedge_after=0.05)
    ex.map(list(range(5)))
    print(f"straggler mitigation: {ex.hedged} hedged dispatch(es) "
          f"recovered the slow item")
    ex.shutdown()


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run: fewer requests")
    main(smoke=ap.parse_args().smoke)
