"""Train a small LM end-to-end with the fault-tolerant runtime: a few
hundred steps on CPU with an injected failure, checkpoint/restart, and a
decreasing loss.  (The pod-scale path lowers the same train_step through
launch/dryrun.py.)

    PYTHONPATH=src python examples/train_lm.py
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.checkpoint import CheckpointStore
from repro.data import DataConfig, SyntheticLMDataset, prefetch
from repro.launch import steps as steps_lib
from repro.optim import AdamWConfig
from repro.runtime import FailureInjector, TrainSupervisor


def main() -> None:
    steps, batch, seq = 200, 8, 64
    cfg = dataclasses.replace(configs.get("qwen3-1.7b").smoke_config(),
                              n_layers=2, d_model=128, d_ff=256)
    print(f"training {cfg.name}: {steps} steps, batch {batch}, seq {seq}")

    data = SyntheticLMDataset(DataConfig(global_batch=batch, seq_len=seq,
                                         vocab=cfg.vocab))
    params, opt_state = steps_lib.init_train_state(cfg, jax.random.PRNGKey(0))
    raw_step = jax.jit(steps_lib.make_train_step(
        cfg, AdamWConfig(lr=3e-3, warmup_steps=20, total_steps=steps),
        loss_chunk=seq))

    def step_fn(state, step):
        p, o = state
        b = {k: jnp.asarray(v) for k, v in data.batch_at(step).items()}
        p, o, metrics = raw_step(p, o, b)
        return (p, o), {k: float(v) for k, v in metrics.items()}

    store = CheckpointStore("/tmp/repro_example_ckpt", keep=2)
    sup = TrainSupervisor(store, step_fn, ckpt_every=50,
                          injector=FailureInjector(fail_at_steps=[77]))
    (params, opt_state), report = sup.run((params, opt_state), steps)

    losses = [m["loss"] for _, m in report.history]
    head = float(np.mean(losses[:10]))
    tail = float(np.mean(losses[-10:]))
    print(f"restarts={report.restarts} checkpoints={report.checkpoints}")
    print(f"loss {head:.3f} -> {tail:.3f}")
    assert report.restarts == 1, "failure injection should trigger exactly once"
    assert tail < head, "loss must decrease"
    print("OK: fault-tolerant training converges")


if __name__ == "__main__":
    main()
