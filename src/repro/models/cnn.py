"""The paper's CNN model zoo.

* :func:`synthetic_cnn` — the parametric family of §3.1 (L=5 conv layers,
  3×3 kernels, stride 1, zero padding, 64×64×3 inputs, f ∈ [32, 1152]).
* Real-world CNNs of Table 1, built layer-by-layer so that parameter/MAC
  totals track the paper's Table 1 and the DAG depth structure (branches,
  concats, residuals) matches the real topologies — this is what the
  depth-based segmentation (paper §6.1.1) operates on.

All builders return a :class:`~repro.models.layers.GraphModel`; call
``.to_layer_graph()`` for the segmentation view and ``.init/.apply`` to run
real JAX forwards (used by the pipelined-executor correctness tests).

NASNetMobile is a *structural approximation* (same depth scale / param count
ballpark, simplified cell wiring) — flagged here and in DESIGN.md; it is not
used in the paper's Table 5/7 experiments.
"""
from __future__ import annotations

import math
from typing import Dict, List, Optional, Sequence, Tuple

from .layers import Builder, GraphModel

IMAGENET_CLASSES = 1000


# ---------------------------------------------------------------------------
# Synthetic family (paper §3.1)
# ---------------------------------------------------------------------------
def synthetic_cnn(f: int, L: int = 5, hw: int = 64, cin: int = 3,
                  kernel: int = 3) -> GraphModel:
    """#params(f) = Fw*Fh*f*(C + f*(L-1)) — exactly the paper's formula."""
    b = Builder(f"synthetic_f{f}", (hw, hw), cin)
    x = Builder.INPUT if False else b.model.INPUT
    for i in range(L):
        x = b.conv(x, f, kernel, stride=1, padding="same", use_bias=False,
                   name=f"conv{i}")
    return b.build()


def synthetic_family(f_values: Sequence[int]) -> List[GraphModel]:
    return [synthetic_cnn(f) for f in f_values]


# ---------------------------------------------------------------------------
# ResNet v1 / v2 (He et al.; Keras variants)
# ---------------------------------------------------------------------------
_RESNET_BLOCKS = {"50": (3, 4, 6, 3), "101": (3, 4, 23, 3), "152": (3, 8, 36, 3)}


def resnet(depth: str = "50", v2: bool = False,
           classes: int = IMAGENET_CLASSES) -> GraphModel:
    blocks = _RESNET_BLOCKS[depth]
    name = f"ResNet{depth}{'V2' if v2 else ''}"
    b = Builder(name, (224, 224), 3)
    x = b.model.INPUT
    x = b.conv(x, 64, 7, stride=2, padding="same", use_bias=not v2,
               name="stem_conv")
    if not v2:
        x = b.bn(x, "stem_bn")
        x = b.act(x, "relu", "stem_relu")
    x = b.pool(x, "max", 3, 2, "same", "stem_pool")

    filters = 64
    for si, n in enumerate(blocks):
        for bi in range(n):
            stride = 2 if (bi == 0 and si > 0) else 1
            pfx = f"s{si}b{bi}"
            if v2:
                # pre-activation bottleneck
                pre = b.bn(x, f"{pfx}_prebn")
                pre = b.act(pre, "relu", f"{pfx}_prerelu")
                if bi == 0:
                    sc = b.conv(pre, filters * 4, 1, stride, "same",
                                use_bias=True, name=f"{pfx}_scconv")
                else:
                    sc = x
                y = b.conv(pre, filters, 1, 1, "same", use_bias=False,
                           name=f"{pfx}_c1")
                y = b.bn(y, f"{pfx}_bn1"); y = b.act(y, "relu", f"{pfx}_r1")
                y = b.conv(y, filters, 3, stride, "same", use_bias=False,
                           name=f"{pfx}_c2")
                y = b.bn(y, f"{pfx}_bn2"); y = b.act(y, "relu", f"{pfx}_r2")
                y = b.conv(y, filters * 4, 1, 1, "same", use_bias=True,
                           name=f"{pfx}_c3")
                x = b.add([sc, y], f"{pfx}_add")
            else:
                if bi == 0:
                    sc = b.conv(x, filters * 4, 1, stride, "same",
                                use_bias=False, name=f"{pfx}_scconv")
                    sc = b.bn(sc, f"{pfx}_scbn")
                else:
                    sc = x
                y = b.conv_bn(x, filters, 1, stride, "same", "relu", f"{pfx}_a")
                y = b.conv_bn(y, filters, 3, 1, "same", "relu", f"{pfx}_b")
                y = b.conv(y, filters * 4, 1, 1, "same", use_bias=False,
                           name=f"{pfx}_c_conv")
                y = b.bn(y, f"{pfx}_c_bn")
                x = b.add([sc, y], f"{pfx}_add")
                x = b.act(x, "relu", f"{pfx}_out")
        filters *= 2
    if v2:
        x = b.bn(x, "post_bn")
        x = b.act(x, "relu", "post_relu")
    x = b.gap(x, "avg_pool")
    b.dense(x, classes, name="predictions")
    return b.build()


# ---------------------------------------------------------------------------
# DenseNet (Huang et al.)
# ---------------------------------------------------------------------------
_DENSENET_BLOCKS = {"121": (6, 12, 24, 16), "169": (6, 12, 32, 32),
                    "201": (6, 12, 48, 32)}


def densenet(depth: str = "121", growth: int = 32,
             classes: int = IMAGENET_CLASSES) -> GraphModel:
    blocks = _DENSENET_BLOCKS[depth]
    b = Builder(f"DenseNet{depth}", (224, 224), 3)
    x = b.conv(b.model.INPUT, 64, 7, 2, "same", use_bias=False, name="stem_conv")
    x = b.bn(x, "stem_bn"); x = b.act(x, "relu", "stem_relu")
    x = b.pool(x, "max", 3, 2, "same", "stem_pool")
    ch = 64
    for si, n in enumerate(blocks):
        for bi in range(n):
            pfx = f"d{si}b{bi}"
            y = b.bn(x, f"{pfx}_bn1"); y = b.act(y, "relu", f"{pfx}_r1")
            y = b.conv(y, 4 * growth, 1, 1, "same", use_bias=False,
                       name=f"{pfx}_c1")
            y = b.bn(y, f"{pfx}_bn2"); y = b.act(y, "relu", f"{pfx}_r2")
            y = b.conv(y, growth, 3, 1, "same", use_bias=False,
                       name=f"{pfx}_c2")
            x = b.concat([x, y], f"{pfx}_cat")
            ch += growth
        if si < len(blocks) - 1:
            pfx = f"t{si}"
            ch = ch // 2
            x = b.bn(x, f"{pfx}_bn"); x = b.act(x, "relu", f"{pfx}_r")
            x = b.conv(x, ch, 1, 1, "same", use_bias=False, name=f"{pfx}_c")
            x = b.pool(x, "avg", 2, 2, "same", f"{pfx}_pool")
    x = b.bn(x, "post_bn"); x = b.act(x, "relu", "post_relu")
    x = b.gap(x, "avg_pool")
    b.dense(x, classes, name="predictions")
    return b.build()


# ---------------------------------------------------------------------------
# MobileNet v1 / v2 (Howard et al.; Sandler et al.)
# ---------------------------------------------------------------------------
def mobilenet(classes: int = IMAGENET_CLASSES) -> GraphModel:
    b = Builder("MobileNet", (224, 224), 3)
    x = b.conv(b.model.INPUT, 32, 3, 2, "same", use_bias=False, name="stem")
    x = b.bn(x); x = b.act(x, "relu6")
    cfg = [(64, 1), (128, 2), (128, 1), (256, 2), (256, 1), (512, 2),
           (512, 1), (512, 1), (512, 1), (512, 1), (512, 1), (1024, 2),
           (1024, 1)]
    for i, (f, s) in enumerate(cfg):
        x = b.dwconv(x, 3, s, "same", use_bias=False, name=f"dw{i}")
        x = b.bn(x); x = b.act(x, "relu6")
        x = b.conv(x, f, 1, 1, "same", use_bias=False, name=f"pw{i}")
        x = b.bn(x); x = b.act(x, "relu6")
    x = b.gap(x, "avg_pool")
    b.dense(x, classes, name="predictions")
    return b.build()


def mobilenet_v2(classes: int = IMAGENET_CLASSES) -> GraphModel:
    b = Builder("MobileNetV2", (224, 224), 3)
    x = b.conv(b.model.INPUT, 32, 3, 2, "same", use_bias=False, name="stem")
    x = b.bn(x); x = b.act(x, "relu6")
    # (expansion t, channels, repeats, stride)
    cfg = [(1, 16, 1, 1), (6, 24, 2, 2), (6, 32, 3, 2), (6, 64, 4, 2),
           (6, 96, 3, 1), (6, 160, 3, 2), (6, 320, 1, 1)]
    cin = 32
    bi = 0
    for t, c, n, s in cfg:
        for i in range(n):
            stride = s if i == 0 else 1
            pfx = f"ir{bi}"
            inp = x
            y = x
            if t != 1:
                y = b.conv(y, cin * t, 1, 1, "same", use_bias=False,
                           name=f"{pfx}_exp")
                y = b.bn(y); y = b.act(y, "relu6")
            y = b.dwconv(y, 3, stride, "same", use_bias=False, name=f"{pfx}_dw")
            y = b.bn(y); y = b.act(y, "relu6")
            y = b.conv(y, c, 1, 1, "same", use_bias=False, name=f"{pfx}_proj")
            y = b.bn(y)
            if stride == 1 and cin == c:
                x = b.add([inp, y], f"{pfx}_add")
            else:
                x = y
            cin = c
            bi += 1
    x = b.conv(x, 1280, 1, 1, "same", use_bias=False, name="head_conv")
    x = b.bn(x); x = b.act(x, "relu6")
    x = b.gap(x, "avg_pool")
    b.dense(x, classes, name="predictions")
    return b.build()


# ---------------------------------------------------------------------------
# EfficientNet-Lite B0..B4 (fixed stem/head, no SE, ReLU6)
# ---------------------------------------------------------------------------
_EFFLITE = {  # (width_mult, depth_mult, resolution)
    "B0": (1.0, 1.0, 224), "B1": (1.0, 1.1, 240), "B2": (1.1, 1.2, 260),
    "B3": (1.2, 1.4, 280), "B4": (1.4, 1.8, 300),
}
_EFF_BLOCKS = [  # (expand t, channels, repeats, stride, kernel)
    (1, 16, 1, 1, 3), (6, 24, 2, 2, 3), (6, 40, 2, 2, 5), (6, 80, 3, 2, 3),
    (6, 112, 3, 1, 5), (6, 192, 4, 2, 5), (6, 320, 1, 1, 3),
]


def _round_filters(f: int, mult: float, divisor: int = 8) -> int:
    f = f * mult
    new = max(divisor, int(f + divisor / 2) // divisor * divisor)
    if new < 0.9 * f:
        new += divisor
    return int(new)


def efficientnet_lite(variant: str = "B0",
                      classes: int = IMAGENET_CLASSES) -> GraphModel:
    wm, dm, res = _EFFLITE[variant]
    b = Builder(f"EfficientNetLite{variant}", (res, res), 3)
    x = b.conv(b.model.INPUT, 32, 3, 2, "same", use_bias=False, name="stem")
    x = b.bn(x); x = b.act(x, "relu6")
    cin = 32
    bi = 0
    n_stages = len(_EFF_BLOCKS)
    for si, (t, c, n, s, k) in enumerate(_EFF_BLOCKS):
        c = _round_filters(c, wm)
        # Lite: repeats of first and last stage are NOT depth-scaled
        reps = n if si in (0, n_stages - 1) else int(math.ceil(n * dm))
        for i in range(reps):
            stride = s if i == 0 else 1
            pfx = f"mb{bi}"
            inp = x
            y = x
            if t != 1:
                y = b.conv(y, cin * t, 1, 1, "same", use_bias=False,
                           name=f"{pfx}_exp")
                y = b.bn(y); y = b.act(y, "relu6")
            y = b.dwconv(y, k, stride, "same", use_bias=False, name=f"{pfx}_dw")
            y = b.bn(y); y = b.act(y, "relu6")
            y = b.conv(y, c, 1, 1, "same", use_bias=False, name=f"{pfx}_proj")
            y = b.bn(y)
            if stride == 1 and cin == c:
                x = b.add([inp, y], f"{pfx}_add")
            else:
                x = y
            cin = c
            bi += 1
    x = b.conv(x, 1280, 1, 1, "same", use_bias=False, name="head_conv")
    x = b.bn(x); x = b.act(x, "relu6")
    x = b.gap(x, "avg_pool")
    b.dense(x, classes, name="predictions")
    return b.build()


# ---------------------------------------------------------------------------
# Xception (Chollet)
# ---------------------------------------------------------------------------
def _sepconv_bn(b: Builder, x: str, filters: int, prefix: str,
                act_before: bool = False, kernel: int = 3) -> str:
    if act_before:
        x = b.act(x, "relu", f"{prefix}_prerelu")
    x = b.dwconv(x, kernel, 1, "same", use_bias=False, name=f"{prefix}_dw")
    x = b.conv(x, filters, 1, 1, "same", use_bias=False, name=f"{prefix}_pw")
    x = b.bn(x, f"{prefix}_bn")
    return x


def xception(classes: int = IMAGENET_CLASSES) -> GraphModel:
    b = Builder("Xception", (299, 299), 3)
    x = b.conv_bn(b.model.INPUT, 32, 3, 2, "valid", "relu", "stem1")
    x = b.conv_bn(x, 64, 3, 1, "valid", "relu", "stem2")
    # entry flow residual modules
    for i, f in enumerate([128, 256, 728]):
        pfx = f"entry{i}"
        sc = b.conv(x, f, 1, 2, "same", use_bias=False, name=f"{pfx}_sc")
        sc = b.bn(sc, f"{pfx}_scbn")
        y = _sepconv_bn(b, x, f, f"{pfx}_s1", act_before=(i > 0))
        y = b.act(y, "relu", f"{pfx}_r")
        y = _sepconv_bn(b, y, f, f"{pfx}_s2")
        y = b.pool(y, "max", 3, 2, "same", f"{pfx}_pool")
        x = b.add([sc, y], f"{pfx}_add")
    # middle flow
    for i in range(8):
        pfx = f"mid{i}"
        y = x
        for j in range(3):
            y = _sepconv_bn(b, y, 728, f"{pfx}_s{j}", act_before=True)
        x = b.add([x, y], f"{pfx}_add")
    # exit flow
    sc = b.conv(x, 1024, 1, 2, "same", use_bias=False, name="exit_sc")
    sc = b.bn(sc, "exit_scbn")
    y = _sepconv_bn(b, x, 728, "exit_s1", act_before=True)
    y = _sepconv_bn(b, y, 1024, "exit_s2", act_before=True)
    y = b.pool(y, "max", 3, 2, "same", "exit_pool")
    x = b.add([sc, y], "exit_add")
    x = _sepconv_bn(b, x, 1536, "exit_s3")
    x = b.act(x, "relu", "exit_r3")
    x = _sepconv_bn(b, x, 2048, "exit_s4")
    x = b.act(x, "relu", "exit_r4")
    x = b.gap(x, "avg_pool")
    b.dense(x, classes, name="predictions")
    return b.build()


# ---------------------------------------------------------------------------
# Inception V3 (Szegedy et al.; Keras topology)
# ---------------------------------------------------------------------------
def inception_v3(classes: int = IMAGENET_CLASSES) -> GraphModel:
    b = Builder("InceptionV3", (299, 299), 3)
    x = b.conv_bn(b.model.INPUT, 32, 3, 2, "valid", "relu", "stem1")
    x = b.conv_bn(x, 32, 3, 1, "valid", "relu", "stem2")
    x = b.conv_bn(x, 64, 3, 1, "same", "relu", "stem3")
    x = b.pool(x, "max", 3, 2, "valid", "stem_pool1")
    x = b.conv_bn(x, 80, 1, 1, "valid", "relu", "stem4")
    x = b.conv_bn(x, 192, 3, 1, "valid", "relu", "stem5")
    x = b.pool(x, "max", 3, 2, "valid", "stem_pool2")

    def block_a(x: str, pool_f: int, pfx: str) -> str:
        b1 = b.conv_bn(x, 64, 1, 1, "same", "relu", f"{pfx}_b1")
        b2 = b.conv_bn(x, 48, 1, 1, "same", "relu", f"{pfx}_b2a")
        b2 = b.conv_bn(b2, 64, 5, 1, "same", "relu", f"{pfx}_b2b")
        b3 = b.conv_bn(x, 64, 1, 1, "same", "relu", f"{pfx}_b3a")
        b3 = b.conv_bn(b3, 96, 3, 1, "same", "relu", f"{pfx}_b3b")
        b3 = b.conv_bn(b3, 96, 3, 1, "same", "relu", f"{pfx}_b3c")
        b4 = b.pool(x, "avg", 3, 1, "same", f"{pfx}_b4p")
        b4 = b.conv_bn(b4, pool_f, 1, 1, "same", "relu", f"{pfx}_b4")
        return b.concat([b1, b2, b3, b4], f"{pfx}_cat")

    def reduction_a(x: str, pfx: str) -> str:
        b1 = b.conv_bn(x, 384, 3, 2, "valid", "relu", f"{pfx}_b1")
        b2 = b.conv_bn(x, 64, 1, 1, "same", "relu", f"{pfx}_b2a")
        b2 = b.conv_bn(b2, 96, 3, 1, "same", "relu", f"{pfx}_b2b")
        b2 = b.conv_bn(b2, 96, 3, 2, "valid", "relu", f"{pfx}_b2c")
        b3 = b.pool(x, "max", 3, 2, "valid", f"{pfx}_pool")
        return b.concat([b1, b2, b3], f"{pfx}_cat")

    def block_b(x: str, c7: int, pfx: str) -> str:
        b1 = b.conv_bn(x, 192, 1, 1, "same", "relu", f"{pfx}_b1")
        b2 = b.conv_bn(x, c7, 1, 1, "same", "relu", f"{pfx}_b2a")
        b2 = b.conv_bn(b2, c7, (1, 7), 1, "same", "relu", f"{pfx}_b2b")
        b2 = b.conv_bn(b2, 192, (7, 1), 1, "same", "relu", f"{pfx}_b2c")
        b3 = b.conv_bn(x, c7, 1, 1, "same", "relu", f"{pfx}_b3a")
        b3 = b.conv_bn(b3, c7, (7, 1), 1, "same", "relu", f"{pfx}_b3b")
        b3 = b.conv_bn(b3, c7, (1, 7), 1, "same", "relu", f"{pfx}_b3c")
        b3 = b.conv_bn(b3, c7, (7, 1), 1, "same", "relu", f"{pfx}_b3d")
        b3 = b.conv_bn(b3, 192, (1, 7), 1, "same", "relu", f"{pfx}_b3e")
        b4 = b.pool(x, "avg", 3, 1, "same", f"{pfx}_b4p")
        b4 = b.conv_bn(b4, 192, 1, 1, "same", "relu", f"{pfx}_b4")
        return b.concat([b1, b2, b3, b4], f"{pfx}_cat")

    def reduction_b(x: str, pfx: str) -> str:
        b1 = b.conv_bn(x, 192, 1, 1, "same", "relu", f"{pfx}_b1a")
        b1 = b.conv_bn(b1, 320, 3, 2, "valid", "relu", f"{pfx}_b1b")
        b2 = b.conv_bn(x, 192, 1, 1, "same", "relu", f"{pfx}_b2a")
        b2 = b.conv_bn(b2, 192, (1, 7), 1, "same", "relu", f"{pfx}_b2b")
        b2 = b.conv_bn(b2, 192, (7, 1), 1, "same", "relu", f"{pfx}_b2c")
        b2 = b.conv_bn(b2, 192, 3, 2, "valid", "relu", f"{pfx}_b2d")
        b3 = b.pool(x, "max", 3, 2, "valid", f"{pfx}_pool")
        return b.concat([b1, b2, b3], f"{pfx}_cat")

    def block_c(x: str, pfx: str) -> str:
        b1 = b.conv_bn(x, 320, 1, 1, "same", "relu", f"{pfx}_b1")
        b2 = b.conv_bn(x, 384, 1, 1, "same", "relu", f"{pfx}_b2a")
        b2a = b.conv_bn(b2, 384, (1, 3), 1, "same", "relu", f"{pfx}_b2b")
        b2b = b.conv_bn(b2, 384, (3, 1), 1, "same", "relu", f"{pfx}_b2c")
        b3 = b.conv_bn(x, 448, 1, 1, "same", "relu", f"{pfx}_b3a")
        b3 = b.conv_bn(b3, 384, 3, 1, "same", "relu", f"{pfx}_b3b")
        b3a = b.conv_bn(b3, 384, (1, 3), 1, "same", "relu", f"{pfx}_b3c")
        b3b = b.conv_bn(b3, 384, (3, 1), 1, "same", "relu", f"{pfx}_b3d")
        b4 = b.pool(x, "avg", 3, 1, "same", f"{pfx}_b4p")
        b4 = b.conv_bn(b4, 192, 1, 1, "same", "relu", f"{pfx}_b4")
        return b.concat([b1, b2a, b2b, b3a, b3b, b4], f"{pfx}_cat")

    x = block_a(x, 32, "a0")
    x = block_a(x, 64, "a1")
    x = block_a(x, 64, "a2")
    x = reduction_a(x, "ra")
    for i, c7 in enumerate([128, 160, 160, 192]):
        x = block_b(x, c7, f"b{i}")
    x = reduction_b(x, "rb")
    x = block_c(x, "c0")
    x = block_c(x, "c1")
    x = b.gap(x, "avg_pool")
    b.dense(x, classes, name="predictions")
    return b.build()


# ---------------------------------------------------------------------------
# Inception V4 and Inception-ResNet V2 (Szegedy et al. 2016)
# ---------------------------------------------------------------------------
def _inc_v4_stem(b: Builder) -> str:
    x = b.conv_bn(b.model.INPUT, 32, 3, 2, "valid", "relu", "stem1")
    x = b.conv_bn(x, 32, 3, 1, "valid", "relu", "stem2")
    x = b.conv_bn(x, 64, 3, 1, "same", "relu", "stem3")
    p1 = b.pool(x, "max", 3, 2, "valid", "stem_p1")
    p2 = b.conv_bn(x, 96, 3, 2, "valid", "relu", "stem_c1")
    x = b.concat([p1, p2], "stem_cat1")
    q1 = b.conv_bn(x, 64, 1, 1, "same", "relu", "stem_q1a")
    q1 = b.conv_bn(q1, 96, 3, 1, "valid", "relu", "stem_q1b")
    q2 = b.conv_bn(x, 64, 1, 1, "same", "relu", "stem_q2a")
    q2 = b.conv_bn(q2, 64, (1, 7), 1, "same", "relu", "stem_q2b")
    q2 = b.conv_bn(q2, 64, (7, 1), 1, "same", "relu", "stem_q2c")
    q2 = b.conv_bn(q2, 96, 3, 1, "valid", "relu", "stem_q2d")
    x = b.concat([q1, q2], "stem_cat2")
    r1 = b.conv_bn(x, 192, 3, 2, "valid", "relu", "stem_r1")
    r2 = b.pool(x, "max", 3, 2, "valid", "stem_r2")
    return b.concat([r1, r2], "stem_cat3")


def inception_v4(classes: int = IMAGENET_CLASSES) -> GraphModel:
    b = Builder("InceptionV4", (299, 299), 3)
    x = _inc_v4_stem(b)

    def block_a(x: str, pfx: str) -> str:
        b1 = b.conv_bn(x, 96, 1, 1, "same", "relu", f"{pfx}_b1")
        b2 = b.conv_bn(x, 64, 1, 1, "same", "relu", f"{pfx}_b2a")
        b2 = b.conv_bn(b2, 96, 3, 1, "same", "relu", f"{pfx}_b2b")
        b3 = b.conv_bn(x, 64, 1, 1, "same", "relu", f"{pfx}_b3a")
        b3 = b.conv_bn(b3, 96, 3, 1, "same", "relu", f"{pfx}_b3b")
        b3 = b.conv_bn(b3, 96, 3, 1, "same", "relu", f"{pfx}_b3c")
        b4 = b.pool(x, "avg", 3, 1, "same", f"{pfx}_b4p")
        b4 = b.conv_bn(b4, 96, 1, 1, "same", "relu", f"{pfx}_b4")
        return b.concat([b1, b2, b3, b4], f"{pfx}_cat")

    def reduction_a(x: str, pfx: str) -> str:
        b1 = b.conv_bn(x, 384, 3, 2, "valid", "relu", f"{pfx}_b1")
        b2 = b.conv_bn(x, 192, 1, 1, "same", "relu", f"{pfx}_b2a")
        b2 = b.conv_bn(b2, 224, 3, 1, "same", "relu", f"{pfx}_b2b")
        b2 = b.conv_bn(b2, 256, 3, 2, "valid", "relu", f"{pfx}_b2c")
        b3 = b.pool(x, "max", 3, 2, "valid", f"{pfx}_pool")
        return b.concat([b1, b2, b3], f"{pfx}_cat")

    def block_b(x: str, pfx: str) -> str:
        b1 = b.conv_bn(x, 384, 1, 1, "same", "relu", f"{pfx}_b1")
        b2 = b.conv_bn(x, 192, 1, 1, "same", "relu", f"{pfx}_b2a")
        b2 = b.conv_bn(b2, 224, (1, 7), 1, "same", "relu", f"{pfx}_b2b")
        b2 = b.conv_bn(b2, 256, (7, 1), 1, "same", "relu", f"{pfx}_b2c")
        b3 = b.conv_bn(x, 192, 1, 1, "same", "relu", f"{pfx}_b3a")
        b3 = b.conv_bn(b3, 192, (7, 1), 1, "same", "relu", f"{pfx}_b3b")
        b3 = b.conv_bn(b3, 224, (1, 7), 1, "same", "relu", f"{pfx}_b3c")
        b3 = b.conv_bn(b3, 224, (7, 1), 1, "same", "relu", f"{pfx}_b3d")
        b3 = b.conv_bn(b3, 256, (1, 7), 1, "same", "relu", f"{pfx}_b3e")
        b4 = b.pool(x, "avg", 3, 1, "same", f"{pfx}_b4p")
        b4 = b.conv_bn(b4, 128, 1, 1, "same", "relu", f"{pfx}_b4")
        return b.concat([b1, b2, b3, b4], f"{pfx}_cat")

    def reduction_b(x: str, pfx: str) -> str:
        b1 = b.conv_bn(x, 192, 1, 1, "same", "relu", f"{pfx}_b1a")
        b1 = b.conv_bn(b1, 192, 3, 2, "valid", "relu", f"{pfx}_b1b")
        b2 = b.conv_bn(x, 256, 1, 1, "same", "relu", f"{pfx}_b2a")
        b2 = b.conv_bn(b2, 256, (1, 7), 1, "same", "relu", f"{pfx}_b2b")
        b2 = b.conv_bn(b2, 320, (7, 1), 1, "same", "relu", f"{pfx}_b2c")
        b2 = b.conv_bn(b2, 320, 3, 2, "valid", "relu", f"{pfx}_b2d")
        b3 = b.pool(x, "max", 3, 2, "valid", f"{pfx}_pool")
        return b.concat([b1, b2, b3], f"{pfx}_cat")

    def block_c(x: str, pfx: str) -> str:
        b1 = b.conv_bn(x, 256, 1, 1, "same", "relu", f"{pfx}_b1")
        b2 = b.conv_bn(x, 384, 1, 1, "same", "relu", f"{pfx}_b2a")
        b2a = b.conv_bn(b2, 256, (1, 3), 1, "same", "relu", f"{pfx}_b2b")
        b2b = b.conv_bn(b2, 256, (3, 1), 1, "same", "relu", f"{pfx}_b2c")
        b3 = b.conv_bn(x, 384, 1, 1, "same", "relu", f"{pfx}_b3a")
        b3 = b.conv_bn(b3, 448, (3, 1), 1, "same", "relu", f"{pfx}_b3b")
        b3 = b.conv_bn(b3, 512, (1, 3), 1, "same", "relu", f"{pfx}_b3c")
        b3a = b.conv_bn(b3, 256, (1, 3), 1, "same", "relu", f"{pfx}_b3d")
        b3b = b.conv_bn(b3, 256, (3, 1), 1, "same", "relu", f"{pfx}_b3e")
        b4 = b.pool(x, "avg", 3, 1, "same", f"{pfx}_b4p")
        b4 = b.conv_bn(b4, 256, 1, 1, "same", "relu", f"{pfx}_b4")
        return b.concat([b1, b2a, b2b, b3a, b3b, b4], f"{pfx}_cat")

    for i in range(4):
        x = block_a(x, f"a{i}")
    x = reduction_a(x, "ra")
    for i in range(7):
        x = block_b(x, f"b{i}")
    x = reduction_b(x, "rb")
    for i in range(3):
        x = block_c(x, f"c{i}")
    x = b.gap(x, "avg_pool")
    b.dense(x, classes, name="predictions")
    return b.build()


def inception_resnet_v2(classes: int = IMAGENET_CLASSES) -> GraphModel:
    b = Builder("InceptionResNetV2", (299, 299), 3)
    # Keras stem (simpler than pure V4 stem)
    x = b.conv_bn(b.model.INPUT, 32, 3, 2, "valid", "relu", "stem1")
    x = b.conv_bn(x, 32, 3, 1, "valid", "relu", "stem2")
    x = b.conv_bn(x, 64, 3, 1, "same", "relu", "stem3")
    x = b.pool(x, "max", 3, 2, "valid", "stem_p1")
    x = b.conv_bn(x, 80, 1, 1, "valid", "relu", "stem4")
    x = b.conv_bn(x, 192, 3, 1, "valid", "relu", "stem5")
    x = b.pool(x, "max", 3, 2, "valid", "stem_p2")
    # mixed_5b (Inception-A)
    b1 = b.conv_bn(x, 96, 1, 1, "same", "relu", "m5b_b1")
    b2 = b.conv_bn(x, 48, 1, 1, "same", "relu", "m5b_b2a")
    b2 = b.conv_bn(b2, 64, 5, 1, "same", "relu", "m5b_b2b")
    b3 = b.conv_bn(x, 64, 1, 1, "same", "relu", "m5b_b3a")
    b3 = b.conv_bn(b3, 96, 3, 1, "same", "relu", "m5b_b3b")
    b3 = b.conv_bn(b3, 96, 3, 1, "same", "relu", "m5b_b3c")
    b4 = b.pool(x, "avg", 3, 1, "same", "m5b_b4p")
    b4 = b.conv_bn(b4, 64, 1, 1, "same", "relu", "m5b_b4")
    x = b.concat([b1, b2, b3, b4], "m5b_cat")  # 320ch

    def block35(x: str, pfx: str) -> str:        # Inception-ResNet-A
        b1 = b.conv_bn(x, 32, 1, 1, "same", "relu", f"{pfx}_b1")
        b2 = b.conv_bn(x, 32, 1, 1, "same", "relu", f"{pfx}_b2a")
        b2 = b.conv_bn(b2, 32, 3, 1, "same", "relu", f"{pfx}_b2b")
        b3 = b.conv_bn(x, 32, 1, 1, "same", "relu", f"{pfx}_b3a")
        b3 = b.conv_bn(b3, 48, 3, 1, "same", "relu", f"{pfx}_b3b")
        b3 = b.conv_bn(b3, 64, 3, 1, "same", "relu", f"{pfx}_b3c")
        cat = b.concat([b1, b2, b3], f"{pfx}_cat")
        up = b.conv(cat, 320, 1, 1, "same", use_bias=True, name=f"{pfx}_up")
        y = b.add([x, up], f"{pfx}_add")
        return b.act(y, "relu", f"{pfx}_relu")

    for i in range(10):
        x = block35(x, f"b35_{i}")
    # reduction-A -> 1088ch
    r1 = b.conv_bn(x, 384, 3, 2, "valid", "relu", "redA_b1")
    r2 = b.conv_bn(x, 256, 1, 1, "same", "relu", "redA_b2a")
    r2 = b.conv_bn(r2, 256, 3, 1, "same", "relu", "redA_b2b")
    r2 = b.conv_bn(r2, 384, 3, 2, "valid", "relu", "redA_b2c")
    r3 = b.pool(x, "max", 3, 2, "valid", "redA_pool")
    x = b.concat([r1, r2, r3], "redA_cat")

    def block17(x: str, pfx: str) -> str:        # Inception-ResNet-B
        b1 = b.conv_bn(x, 192, 1, 1, "same", "relu", f"{pfx}_b1")
        b2 = b.conv_bn(x, 128, 1, 1, "same", "relu", f"{pfx}_b2a")
        b2 = b.conv_bn(b2, 160, (1, 7), 1, "same", "relu", f"{pfx}_b2b")
        b2 = b.conv_bn(b2, 192, (7, 1), 1, "same", "relu", f"{pfx}_b2c")
        cat = b.concat([b1, b2], f"{pfx}_cat")
        up = b.conv(cat, 1088, 1, 1, "same", use_bias=True, name=f"{pfx}_up")
        y = b.add([x, up], f"{pfx}_add")
        return b.act(y, "relu", f"{pfx}_relu")

    for i in range(20):
        x = block17(x, f"b17_{i}")
    # reduction-B -> 2080ch
    r1 = b.conv_bn(x, 256, 1, 1, "same", "relu", "redB_b1a")
    r1 = b.conv_bn(r1, 384, 3, 2, "valid", "relu", "redB_b1b")
    r2 = b.conv_bn(x, 256, 1, 1, "same", "relu", "redB_b2a")
    r2 = b.conv_bn(r2, 288, 3, 2, "valid", "relu", "redB_b2b")
    r3 = b.conv_bn(x, 256, 1, 1, "same", "relu", "redB_b3a")
    r3 = b.conv_bn(r3, 288, 3, 1, "same", "relu", "redB_b3b")
    r3 = b.conv_bn(r3, 320, 3, 2, "valid", "relu", "redB_b3c")
    r4 = b.pool(x, "max", 3, 2, "valid", "redB_pool")
    x = b.concat([r1, r2, r3, r4], "redB_cat")

    def block8(x: str, pfx: str, relu: bool = True) -> str:  # Inception-ResNet-C
        b1 = b.conv_bn(x, 192, 1, 1, "same", "relu", f"{pfx}_b1")
        b2 = b.conv_bn(x, 192, 1, 1, "same", "relu", f"{pfx}_b2a")
        b2 = b.conv_bn(b2, 224, (1, 3), 1, "same", "relu", f"{pfx}_b2b")
        b2 = b.conv_bn(b2, 256, (3, 1), 1, "same", "relu", f"{pfx}_b2c")
        cat = b.concat([b1, b2], f"{pfx}_cat")
        up = b.conv(cat, 2080, 1, 1, "same", use_bias=True, name=f"{pfx}_up")
        y = b.add([x, up], f"{pfx}_add")
        return b.act(y, "relu", f"{pfx}_relu") if relu else y

    for i in range(9):
        x = block8(x, f"b8_{i}")
    x = block8(x, "b8_9", relu=False)
    x = b.conv_bn(x, 1536, 1, 1, "same", "relu", "conv_7b")
    x = b.gap(x, "avg_pool")
    b.dense(x, classes, name="predictions")
    return b.build()


# ---------------------------------------------------------------------------
# NASNetMobile — STRUCTURAL APPROXIMATION (flagged; see module docstring)
# ---------------------------------------------------------------------------
def nasnet_mobile_approx(classes: int = IMAGENET_CLASSES) -> GraphModel:
    b = Builder("NASNetMobile~approx", (224, 224), 3)
    x = b.conv_bn(b.model.INPUT, 32, 3, 2, "valid", "relu", "stem")

    def sep_block(x: str, f: int, k: int, s: int, pfx: str) -> str:
        y = b.act(x, "relu", f"{pfx}_r1")
        y = b.dwconv(y, k, s, "same", use_bias=False, name=f"{pfx}_dw1")
        y = b.conv(y, f, 1, 1, "same", use_bias=False, name=f"{pfx}_pw1")
        y = b.bn(y, f"{pfx}_bn1")
        y = b.act(y, "relu", f"{pfx}_r2")
        y = b.dwconv(y, k, 1, "same", use_bias=False, name=f"{pfx}_dw2")
        y = b.conv(y, f, 1, 1, "same", use_bias=False, name=f"{pfx}_pw2")
        return b.bn(y, f"{pfx}_bn2")

    def cell(x: str, f: int, reduce_: bool, pfx: str) -> str:
        s = 2 if reduce_ else 1
        a1 = sep_block(x, f, 3, s, f"{pfx}_a1")
        a2 = sep_block(x, f, 5, s, f"{pfx}_a2")
        a3 = (b.pool(x, "avg", 3, s, "same", f"{pfx}_p")
              if True else x)
        a3 = b.conv(a3, f, 1, 1, "same", use_bias=False, name=f"{pfx}_pc")
        a3 = b.bn(a3, f"{pfx}_pbn")
        y = b.add([a1, a2, a3], f"{pfx}_add")
        return y

    f = 44
    x = cell(x, f, True, "r0")
    for stage in range(3):
        # NASNetMobile concentrates parameters at the last (7x7) stage; the
        # approximation mirrors that with extra low-resolution cells.
        n_cells = 4 if stage < 2 else 21
        for i in range(n_cells):
            x = cell(x, f, False, f"s{stage}c{i}")
        if stage < 2:
            f *= 2
            x = cell(x, f, True, f"red{stage}")
    x = b.conv_bn(x, 1056, 1, 1, "same", "relu", "head")
    x = b.gap(x, "avg_pool")
    b.dense(x, classes, name="predictions")
    return b.build()


# ---------------------------------------------------------------------------
# Registry (paper Table 1)
# ---------------------------------------------------------------------------
REAL_CNNS = {
    "Xception": xception,
    "ResNet50": lambda: resnet("50", v2=False),
    "ResNet50V2": lambda: resnet("50", v2=True),
    "ResNet101": lambda: resnet("101", v2=False),
    "ResNet101V2": lambda: resnet("101", v2=True),
    "ResNet152": lambda: resnet("152", v2=False),
    "ResNet152V2": lambda: resnet("152", v2=True),
    "InceptionV3": inception_v3,
    "InceptionV4": inception_v4,
    "MobileNet": mobilenet,
    "MobileNetV2": mobilenet_v2,
    "InceptionResNetV2": inception_resnet_v2,
    "DenseNet121": lambda: densenet("121"),
    "DenseNet169": lambda: densenet("169"),
    "DenseNet201": lambda: densenet("201"),
    "NASNetMobile": nasnet_mobile_approx,
    "EfficientNetLiteB0": lambda: efficientnet_lite("B0"),
    "EfficientNetLiteB1": lambda: efficientnet_lite("B1"),
    "EfficientNetLiteB2": lambda: efficientnet_lite("B2"),
    "EfficientNetLiteB3": lambda: efficientnet_lite("B3"),
    "EfficientNetLiteB4": lambda: efficientnet_lite("B4"),
}

# Paper Table 1 reference values (params M, MACs M) for validation.
TABLE1 = {
    "Xception": (22.9, 8363), "ResNet50": (25.6, 3864),
    "ResNet50V2": (25.6, 3486), "ResNet101": (44.7, 7579),
    "ResNet101V2": (44.7, 7200), "ResNet152": (60.4, 11294),
    "ResNet152V2": (60.4, 10915), "InceptionV3": (23.9, 5725),
    "InceptionV4": (43.0, 12276), "MobileNet": (4.3, 568),
    "MobileNetV2": (3.5, 300), "InceptionResNetV2": (55.9, 13171),
    "DenseNet121": (8.1, 2835), "DenseNet169": (14.3, 3361),
    "DenseNet201": (20.2, 4292), "NASNetMobile": (5.3, 568),
    "EfficientNetLiteB0": (4.7, 385), "EfficientNetLiteB1": (5.4, 600),
    "EfficientNetLiteB2": (6.1, 859), "EfficientNetLiteB3": (8.2, 1383),
    "EfficientNetLiteB4": (13.0, 2553),
}
