"""Lower an LM architecture to a :class:`LayerGraph` for the segmentation
planner — the bridge between the assigned archs and the paper's technique.

Per-node parameter counts are **exact**: they come from ``jax.eval_shape``
over the real initializer (no allocation), so the planner balances the same
bytes the runtime will hold.  MACs use the per-family analytical estimators.

Depth structure:
* decoder-only archs: ``embed -> block_0 .. block_{L-1} -> final_norm -> head``
* whisper (enc-dec): encoder chain and decoder chain, with cross-attention
  edges ``enc_final -> dec_i`` — the longest-path depth rule (paper §6.1.1)
  then places every decoder layer after the whole encoder.
"""
from __future__ import annotations

from typing import Dict

import jax
import numpy as np

from ..core.costs import TransformerBlockCost
from ..core.graph import LayerGraph
from . import api
from .lm import LMConfig
from .rglru import n_super_and_tail


def _tree_size(tree) -> int:
    return int(sum(np.prod(l.shape) for l in jax.tree.leaves(tree)))


def _param_shapes(cfg: LMConfig):
    return jax.eval_shape(lambda k: api.init(cfg, k),
                          jax.ShapeDtypeStruct((2,), "uint32"))


def _block_cost(cfg: LMConfig) -> TransformerBlockCost:
    return TransformerBlockCost(
        d_model=cfg.d_model, n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
        d_ff=cfg.d_ff, head_dim=cfg.hd, qkv_bias=cfg.qkv_bias,
        n_experts=cfg.n_experts, top_k=cfg.top_k,
        ffn_gated=cfg.mlp_kind in ("swiglu", "geglu"))


def _rwkv_macs(cfg: LMConfig, seq: int) -> int:
    d, f = cfg.d_model, cfg.d_ff
    tm = 5 * d * d + d * (cfg.rwkv_head_dim * 2)       # proj + wkv per token
    cm = 2 * d * f + d * d
    return seq * (tm + cm)


def _rec_macs(cfg: LMConfig, seq: int) -> int:
    d = cfg.d_model
    temporal = 3 * d * d + cfg.conv_width * d          # wx, wgate, wo + conv
    mlp = 3 * d * cfg.d_ff
    return seq * (temporal + mlp)


def lm_layer_graph(cfg: LMConfig, seq_len: int = 4096,
                   act_bytes_per_elt: int = 2) -> LayerGraph:
    """Build the segmentation view of an LM arch (per single sequence)."""
    g = LayerGraph(cfg.name)
    shapes = _param_shapes(cfg)
    act = seq_len * cfg.d_model * act_bytes_per_elt
    bc = _block_cost(cfg)
    w_bytes = 2  # bf16 weights

    def wb(p):  # weight bytes for param count p
        return p * w_bytes

    if cfg.family == "encdec":
        enc_total = _tree_size(shapes["enc"])
        dec_total = _tree_size(shapes["dec"])
        embed_p = _tree_size(shapes["embed"])
        frame_act = cfg.n_frames * cfg.d_model * act_bytes_per_elt
        g.add_layer("encoder_input", params=0, macs=0, out_bytes=frame_act,
                    kind="stub")
        prev = "encoder_input"
        per_enc = enc_total // cfg.n_enc_layers
        enc_macs = bc.block_macs(cfg.n_frames, cfg.n_frames)
        for i in range(cfg.n_enc_layers):
            g.add_layer(f"enc_{i}", params=per_enc, macs=enc_macs,
                        out_bytes=frame_act, inputs=[prev],
                        weight_bytes=wb(per_enc), kind="enc_block")
            prev = f"enc_{i}"
        enc_out = prev
        g.add_layer("embed", params=embed_p, macs=seq_len * cfg.d_model,
                    out_bytes=act, weight_bytes=wb(embed_p), kind="embed")
        prev = "embed"
        per_dec = dec_total // cfg.n_layers
        dec_macs = (bc.block_macs(seq_len, seq_len)
                    + 2 * seq_len * cfg.n_frames * cfg.n_heads * cfg.hd)
        for i in range(cfg.n_layers):
            g.add_layer(f"dec_{i}", params=per_dec, macs=dec_macs,
                        out_bytes=act, inputs=[prev, enc_out],
                        weight_bytes=wb(per_dec), kind="dec_block")
            prev = f"dec_{i}"
        ln = _tree_size(shapes["dec_ln"]) + _tree_size(shapes["enc_ln"])
        # tied unembedding: weight bytes live with embed; head MACs here
        g.add_layer("head", params=ln, macs=seq_len * cfg.d_model * cfg.vocab,
                    out_bytes=0, inputs=[prev], weight_bytes=wb(ln),
                    kind="head")
        return g

    embed_p = _tree_size(shapes["embed"])
    g.add_layer("embed", params=embed_p, macs=seq_len * cfg.d_model,
                out_bytes=act, weight_bytes=wb(embed_p), kind="embed")
    prev = "embed"

    if cfg.family == "hybrid":
        n_super, tail = n_super_and_tail(cfg.n_layers, cfg.attn_every)
        per_super = _tree_size(shapes["super"]) // n_super
        one_super = jax.tree.map(lambda s: s, shapes["super"])
        rec_p = _tree_size(one_super["rec1"]) // n_super
        attn_p = per_super - 2 * rec_p
        attn_macs = bc.block_macs(seq_len, min(seq_len, cfg.local_window))
        rec_macs = _rec_macs(cfg, seq_len)
        li = 0
        for s in range(n_super):
            for kind, p, m in (("rec", rec_p, rec_macs),
                               ("rec", rec_p, rec_macs),
                               ("attn", attn_p, attn_macs)):
                g.add_layer(f"block_{li}_{kind}", params=p, macs=m,
                            out_bytes=act, inputs=[prev], weight_bytes=wb(p),
                            kind=f"{kind}_block")
                prev = f"block_{li}_{kind}"
                li += 1
        if tail:
            tail_p = _tree_size(shapes["tail"]) // tail
            for t in range(tail):
                g.add_layer(f"block_{li}_rec", params=tail_p, macs=rec_macs,
                            out_bytes=act, inputs=[prev],
                            weight_bytes=wb(tail_p), kind="rec_block")
                prev = f"block_{li}_rec"
                li += 1
    else:
        per_block = _tree_size(shapes["blocks"]) // cfg.n_layers
        if cfg.family == "ssm":
            macs = _rwkv_macs(cfg, seq_len)
        else:
            macs = bc.block_macs(seq_len, seq_len)
        for i in range(cfg.n_layers):
            g.add_layer(f"block_{i}", params=per_block, macs=macs,
                        out_bytes=act, inputs=[prev],
                        weight_bytes=wb(per_block), kind="block")
            prev = f"block_{i}"

    norm_p = _tree_size(shapes["final_norm"])
    g.add_layer("final_norm", params=norm_p, macs=0, out_bytes=act,
                inputs=[prev], weight_bytes=wb(norm_p), kind="norm")
    head_p = (_tree_size(shapes["head"]) if "head" in shapes else 0)
    g.add_layer("head", params=head_p, macs=seq_len * cfg.d_model * cfg.vocab,
                out_bytes=0, inputs=["final_norm"], weight_bytes=wb(head_p),
                kind="head")
    return g
