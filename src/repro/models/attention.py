"""Attention + norm primitives shared by the LM architecture family.

Everything is pure ``jnp`` so that ``.lower().compile()`` works on any
backend (the Pallas flash kernel in ``repro.kernels`` is the TPU hot-path
drop-in; see kernels/ops.py).  Numerics: bf16 params/activations with fp32
softmax and norm accumulation.

Covers the features the assigned archs need: GQA, RoPE (incl. M-RoPE
sections for qwen2-vl), qk_norm (qwen3), QKV bias (qwen2.5), sliding-window
local attention (recurrentgemma), non-causal encoder attention + cross
attention (whisper), chunked-query causal attention for long prefill, and
single-token KV-cache decode.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return ((xf * jax.lax.rsqrt(var + eps)) * (1.0 + scale.astype(jnp.float32))).astype(dt)


def layer_norm(x: jax.Array, scale: jax.Array, bias: jax.Array,
               eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dt)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------
def rope_freqs(head_dim: int, theta: float = 10000.0) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array,
               theta: float = 10000.0) -> jax.Array:
    """x: (..., S, H, D); positions: broadcastable to (..., S)."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                       # (D/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs   # (..., S, D/2)
    ang = ang[..., None, :]                            # (..., S, 1, D/2)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x: jax.Array, positions3: jax.Array, sections: Tuple[int, ...],
                theta: float = 10000.0) -> jax.Array:
    """M-RoPE (qwen2-vl): the head_dim/2 frequency slots are split into
    `sections` (temporal, height, width); each section rotates with its own
    position stream.  positions3: (3, ..., S)."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                       # (D/2,)
    assert sum(sections) == d // 2, (sections, d)
    # build per-slot positions by section
    parts = []
    off = 0
    for i, sec in enumerate(sections):
        pos = positions3[i]                            # (..., S)
        ang = pos[..., None].astype(jnp.float32) * freqs[off:off + sec]
        parts.append(ang)
        off += sec
    ang = jnp.concatenate(parts, axis=-1)[..., None, :]   # (..., S, 1, D/2)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Core attention math (GQA; q: (B,S,Hq,D), k/v: (B,T,Hkv,D))
# ---------------------------------------------------------------------------
def _gqa_scores(q: jax.Array, k: jax.Array) -> jax.Array:
    """-> (B, Hkv, G, S, T) scores, scaled.  fp32 by default; bf16 under
    the ``scores_bf16`` perf knob (halves the materialized score traffic of
    the jnp attention path; softmax stats then run in bf16 — acceptable for
    the roofline study, numerics documented in EXPERIMENTS.md §Perf)."""
    b, s, hq, d = q.shape
    hkv = k.shape[2]
    g = hq // hkv
    qg = q.reshape(b, s, hkv, g, d)
    acc = jnp.bfloat16 if _scores_bf16() else jnp.float32
    scores = jnp.einsum("bskgd,btkd->bkgst", qg, k,
                        preferred_element_type=acc)
    return scores / jnp.sqrt(jnp.asarray(d, acc))


def _gqa_out(probs: jax.Array, v: jax.Array, out_dtype) -> jax.Array:
    """probs: (B,Hkv,G,S,T); v: (B,T,Hkv,D) -> (B,S,Hq,D)."""
    b, hkv, g, s, t = probs.shape
    out = jnp.einsum("bkgst,btkd->bskgd", probs.astype(v.dtype), v)
    return out.reshape(b, s, hkv * g, -1).astype(out_dtype)


def _scores_bf16() -> bool:
    import os
    return "scores_bf16" in os.environ.get("REPRO_VARIANT", "")


def full_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                   causal: bool = True,
                   q_offset: int | jax.Array = 0,
                   window: Optional[int] = None) -> jax.Array:
    """Unchunked attention; fine for short sequences / smoke tests."""
    s, t = q.shape[1], k.shape[1]
    scores = _gqa_scores(q, k)
    qpos = jnp.arange(s)[:, None] + q_offset
    kpos = jnp.arange(t)[None, :]
    mask = jnp.ones((s, t), bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    neg = jnp.asarray(-3e4 if scores.dtype == jnp.bfloat16 else NEG_INF,
                      scores.dtype)
    scores = jnp.where(mask, scores, neg)
    probs = jax.nn.softmax(scores, axis=-1)
    return _gqa_out(probs, v, q.dtype)


def chunked_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                      causal: bool = True, q_chunk: int = 1024,
                      window: Optional[int] = None) -> jax.Array:
    """Query-chunked attention: peak memory O(q_chunk * T) instead of O(S*T).

    The long-prefill path (32k tokens).  Equivalent to full_attention (same
    softmax; chunking only over queries, so no online renormalization is
    needed).  Causal masking is applied per chunk.
    """
    b, s, hq, d = q.shape
    if s <= q_chunk:
        return full_attention(q, k, v, causal=causal, window=window)
    assert s % q_chunk == 0, (s, q_chunk)
    n = s // q_chunk
    qs = q.reshape(b, n, q_chunk, hq, d).transpose(1, 0, 2, 3, 4)
    offsets = jnp.arange(n) * q_chunk

    def body(carry, xs):
        qc, off = xs
        out = full_attention(qc, k, v, causal=causal, q_offset=off,
                             window=window)
        return carry, out

    _, outs = jax.lax.scan(body, None, (qs, offsets))
    return outs.transpose(1, 0, 2, 3, 4).reshape(b, s, hq, d)


def decode_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                     cache_len: jax.Array,
                     window: Optional[int] = None) -> jax.Array:
    """One-token decode: q (B,1,Hq,D) against a (B,T,Hkv,D) cache.

    `cache_len` is the number of valid cache entries (the new token's k/v
    must already be written at position cache_len-1).
    """
    t = k_cache.shape[1]
    scores = _gqa_scores(q, k_cache)                    # (B,Hkv,G,1,T)
    kpos = jnp.arange(t)[None, :]
    valid = kpos < cache_len                            # (B,T) or (1,T)
    if window is not None:
        valid &= kpos >= cache_len - window
    scores = jnp.where(valid[:, None, None, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    return _gqa_out(probs, v_cache, q.dtype)


def cross_attention(q: jax.Array, k: jax.Array, v: jax.Array) -> jax.Array:
    """Non-causal attention over a fixed memory (whisper decoder)."""
    scores = _gqa_scores(q, k)
    probs = jax.nn.softmax(scores, axis=-1)
    return _gqa_out(probs, v, q.dtype)
