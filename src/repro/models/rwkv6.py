"""RWKV6 "Finch" (arXiv:2404.05892) — attention-free token mixer.

Structure per layer (faithful to Finch at the tensor level):
* **time-mix**: token-shift with data-dependent interpolation (ddlerp via a
  low-rank adapter), projections r/k/v/gate, *data-dependent per-channel
  decay* ``w_t = exp(-exp(w0 + lora_w(x)))`` and the WKV state recurrence
      S_t = diag(w_t) S_{t-1} + k_t^T v_t
      y_t = r_t (S_{t-1} + diag(u) k_t^T v_t)
  evaluated per head (head_dim 64), fp32 state.
* **channel-mix**: token-shifted squared-ReLU MLP with a sigmoid gate.

Training/prefill scans over time inside each layer (the Pallas kernel
``repro.kernels.rwkv6_scan`` is the blocked TPU version of the same
recurrence; ``kernels/ref.py`` mirrors this module).  Decode carries
(shift_tm, shift_cm, S) per layer — O(1) per token, which is why this arch
runs the ``long_500k`` shape.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from . import attention as A
from .lm import LMConfig, _dense_init, _stack_init

Params = Dict[str, Any]

LORA_TM = 32      # token-shift ddlerp adapter rank
LORA_W = 64       # decay adapter rank
N_MIX = 5         # r, k, v, w, g


def init_rwkv_block(cfg: LMConfig, key, dtype) -> Params:
    d, f = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 12)
    h = d // cfg.rwkv_head_dim
    return {
        "ln1": {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)},
        "ln2": {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)},
        "tm": {
            "mu": 0.5 * jnp.ones((N_MIX, d), dtype),
            "mu_x": 0.5 * jnp.ones((d,), dtype),
            "maa_w1": _dense_init(ks[0], (d, N_MIX * LORA_TM), dtype, 0.01),
            "maa_w2": _dense_init(ks[1], (N_MIX, LORA_TM, d), dtype, 0.01),
            "wr": _dense_init(ks[2], (d, d), dtype),
            "wk": _dense_init(ks[3], (d, d), dtype),
            "wv": _dense_init(ks[4], (d, d), dtype),
            "wg": _dense_init(ks[5], (d, d), dtype),
            "wo": _dense_init(ks[6], (d, d), dtype),
            "w0": jnp.full((d,), -6.0, jnp.float32),     # slow decay init
            "w_lora1": _dense_init(ks[7], (d, LORA_W), dtype, 0.01),
            "w_lora2": _dense_init(ks[8], (LORA_W, d), dtype, 0.01),
            "u": _dense_init(ks[9], (h, cfg.rwkv_head_dim), jnp.float32, 0.1),
            "ln_x": {"scale": jnp.ones((d,), dtype),
                     "bias": jnp.zeros((d,), dtype)},
        },
        "cm": {
            "mu_k": 0.5 * jnp.ones((d,), dtype),
            "mu_r": 0.5 * jnp.ones((d,), dtype),
            "wk": _dense_init(ks[10], (d, f), dtype),
            "wv": _dense_init(ks[11], (f, d), dtype),
            "wr": _dense_init(jax.random.fold_in(key, 99), (d, d), dtype),
        },
    }


def init_params(cfg: LMConfig, key) -> Params:
    dtype = cfg.dtype
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "embed": _dense_init(k1, (cfg.vocab, cfg.d_model), dtype, 0.02),
        "blocks": _stack_init(k2, cfg.n_layers,
                              lambda k: init_rwkv_block(cfg, k, dtype)),
        "final_norm": {"scale": jnp.ones((cfg.d_model,), dtype),
                       "bias": jnp.zeros((cfg.d_model,), dtype)},
        "head": _dense_init(k3, (cfg.d_model, cfg.vocab), dtype),
    }


# ---------------------------------------------------------------------------
# time-mix
# ---------------------------------------------------------------------------
def _ddlerp(tm: Params, x: jax.Array, x_prev: jax.Array):
    """Finch data-dependent token-shift: returns (x_r, x_k, x_v, x_w, x_g)."""
    dx = x_prev - x
    xx = x + dx * tm["mu_x"]
    z = jnp.tanh(xx @ tm["maa_w1"])                        # (..., 5*LORA)
    z = z.reshape(z.shape[:-1] + (N_MIX, LORA_TM))
    m = jnp.einsum("...nl,nld->...nd", z, tm["maa_w2"])    # (..., 5, D)
    mixed = x[..., None, :] + dx[..., None, :] * (tm["mu"] + m)
    return [mixed[..., i, :] for i in range(N_MIX)]


def _decay(tm: Params, x_w: jax.Array) -> jax.Array:
    lora = jnp.tanh(x_w @ tm["w_lora1"]) @ tm["w_lora2"]
    return jnp.exp(-jnp.exp(tm["w0"] + lora.astype(jnp.float32)))  # (.., D) in (0,1)


def wkv_step(state: jax.Array, r, k, v, w, u) -> Tuple[jax.Array, jax.Array]:
    """One WKV step, all heads.  state: (B,H,K,V) fp32; r/k/v/w: (B,H,Kdim)."""
    kv = jnp.einsum("bhk,bhv->bhkv", k, v)                 # outer product
    y = jnp.einsum("bhk,bhkv->bhv", r, state + u[None, :, :, None] * kv)
    state = w[..., None] * state + kv
    return state, y


def wkv_chunked(r, k, v, w, u, state, chunk: int = 64):
    """Chunked-parallel WKV (the jnp mirror of kernels/rwkv6_scan.py's
    blocking): the fp32 state crosses HBM once per *chunk* instead of once
    per token; within a chunk the recurrence becomes decay-weighted
    matmuls.  r/k/v/w: (B,S,H,D) fp32, state: (B,H,K,V) fp32.

    Math per chunk (L_t = prod_{i<=t} w_i, E_t = L_t / w_t exclusive):
        y_t = (r_t*E_t) . S_in  +  sum_{s<t} [(r_t*E_t).(k_s/L_s)] v_s
              + (r_t.(u*k_t)) v_t
        S_out = L_T * S_in + sum_s (k_s * L_T/L_s) (x) v_s
    Numerics: safe for chunk<=64 with the model's decay scale (w ~ 0.99+);
    documented in EXPERIMENTS.md §Perf."""
    b, s, h, d = r.shape
    chunk = min(chunk, s)
    assert s % chunk == 0, (s, chunk)
    n = s // chunk
    rc, kc, vc, wc = (x.reshape(b, n, chunk, h, d).transpose(1, 0, 2, 3, 4)
                      for x in (r, k, v, w))
    tri = jnp.tril(jnp.ones((chunk, chunk), jnp.float32), k=-1)

    def body(S, xs):
        rt, kt, vt, wt = xs                       # (B,T,H,D)
        logw = jnp.log(jnp.maximum(wt, 1e-30))
        clog = jnp.cumsum(logw, axis=1)           # log L_t (inclusive)
        L = jnp.exp(clog)
        E = jnp.exp(clog - logw)                  # exclusive cumprod
        a = rt * E                                # (B,T,H,K)
        bs = kt * jnp.exp(-clog)                  # k_s / L_s
        Amat = jnp.einsum("bthk,bshk->bhts", a, bs) * tri
        diag = jnp.einsum("bthk,hk,bthk->bth", rt, u, kt)
        y = (jnp.einsum("bhts,bshv->bthv", Amat, vt)
             + diag[..., None] * vt
             + jnp.einsum("bthk,bhkv->bthv", a, S))
        LT = L[:, -1]                             # (B,H,K)
        c = kt * jnp.exp(clog[:, -1:] - clog)     # k_s * L_T/L_s
        S = LT[..., None] * S + jnp.einsum("bthk,bthv->bhkv", c, vt)
        return S, y

    state, ys = jax.lax.scan(body, state, (rc, kc, vc, wc))
    y = ys.transpose(1, 0, 2, 3, 4).reshape(b, s, h, d)
    return y, state


def time_mix(cfg: LMConfig, tm: Params, x: jax.Array, x_prev: jax.Array,
             state: jax.Array):
    """x: (B,S,D) (S>=1).  x_prev: (B,D) shift carry.  state: (B,H,K,V) fp32.
    Returns (out, new_x_prev, new_state)."""
    b, s, d = x.shape
    hd = cfg.rwkv_head_dim
    h = d // hd
    prev = jnp.concatenate([x_prev[:, None], x[:, :-1]], axis=1)
    x_r, x_k, x_v, x_w, x_g = _ddlerp(tm, x, prev)
    r = (x_r @ tm["wr"]).reshape(b, s, h, hd).astype(jnp.float32)
    k = (x_k @ tm["wk"]).reshape(b, s, h, hd).astype(jnp.float32)
    v = (x_v @ tm["wv"]).reshape(b, s, h, hd).astype(jnp.float32)
    g = jax.nn.silu(x_g @ tm["wg"])
    w = _decay(tm, x_w).reshape(b, s, h, hd)               # fp32

    from ..launch import variants
    # chunked-parallel WKV is the default (measured 5-10x memory-term win,
    # EXPERIMENTS.md §Perf); `rwkv_scan` knob reverts to per-token scan
    if not variants.on("rwkv_scan") and s > 1:
        chunk = 64 if s % 64 == 0 else s
        ys4, state = wkv_chunked(r, k, v, w, tm["u"], state, chunk=chunk)
        y = ys4.reshape(b, s, d).astype(x.dtype)
    else:
        def body(st, xs):
            r_t, k_t, v_t, w_t = xs
            st, y = wkv_step(st, r_t, k_t, v_t, w_t, tm["u"])
            return st, y

        xs = (r.transpose(1, 0, 2, 3), k.transpose(1, 0, 2, 3),
              v.transpose(1, 0, 2, 3), w.transpose(1, 0, 2, 3))
        state, ys = jax.lax.scan(body, state, xs)          # ys: (S,B,H,V)
        y = ys.transpose(1, 0, 2, 3).reshape(b, s, d).astype(x.dtype)
    y = A.layer_norm(y, tm["ln_x"]["scale"], tm["ln_x"]["bias"])
    out = (y * g) @ tm["wo"]
    return out, x[:, -1], state


def channel_mix(cm: Params, x: jax.Array, x_prev: jax.Array):
    prev = jnp.concatenate([x_prev[:, None], x[:, :-1]], axis=1)
    x_k = x + (prev - x) * cm["mu_k"]
    x_r = x + (prev - x) * cm["mu_r"]
    k = jnp.square(jax.nn.relu(x_k @ cm["wk"]))
    return jax.nn.sigmoid(x_r @ cm["wr"]) * (k @ cm["wv"]), x[:, -1]


# ---------------------------------------------------------------------------
# full model
# ---------------------------------------------------------------------------
def _zero_layer_state(cfg: LMConfig, b: int):
    d = cfg.d_model
    h = d // cfg.rwkv_head_dim
    return {"wkv": jnp.zeros((b, h, cfg.rwkv_head_dim, cfg.rwkv_head_dim),
                             jnp.float32),
            "shift_tm": jnp.zeros((b, d), cfg.dtype),
            "shift_cm": jnp.zeros((b, d), cfg.dtype)}


def init_cache(cfg: LMConfig, batch: int, max_len: int = 0) -> Params:
    st = _zero_layer_state(cfg, batch)
    return {"layers": jax.tree.map(
                lambda x: jnp.broadcast_to(x, (cfg.n_layers,) + x.shape),
                st),
            "len": jnp.zeros((), jnp.int32)}


def _block(cfg: LMConfig, bp: Params, x: jax.Array, st: Params):
    h = A.layer_norm(x, bp["ln1"]["scale"], bp["ln1"]["bias"])
    out, sh_tm, wkv = time_mix(cfg, bp["tm"], h, st["shift_tm"], st["wkv"])
    x = x + out
    h = A.layer_norm(x, bp["ln2"]["scale"], bp["ln2"]["bias"])
    out, sh_cm = channel_mix(bp["cm"], h, st["shift_cm"])
    x = x + out
    return x, {"wkv": wkv, "shift_tm": sh_tm, "shift_cm": sh_cm}


def forward(cfg: LMConfig, params: Params, batch: Dict[str, jax.Array],
            cache: Optional[Params] = None, last_token_only: bool = False):
    """Full-sequence forward.  Returns logits; with cache, also new cache."""
    tokens = batch["tokens"]
    b = tokens.shape[0]
    x = jnp.take(params["embed"], tokens, axis=0)
    init_st = (cache["layers"] if cache is not None
               else jax.tree.map(
                   lambda y: jnp.broadcast_to(y, (cfg.n_layers,) + y.shape),
                   _zero_layer_state(cfg, b)))

    def body(x, xs):
        bp, st = xs
        if cfg.seq_shard_acts and tokens.shape[1] > 1:
            from .lm import seq_shard_constraint
            x = seq_shard_constraint(x)
        x, st = _block(cfg, bp, x, st)
        return x, st

    blk = jax.checkpoint(body) if cfg.remat else body
    x, new_st = jax.lax.scan(blk, x, (params["blocks"], init_st))
    if last_token_only:
        x = x[:, -1:]
    x = A.layer_norm(x, params["final_norm"]["scale"],
                     params["final_norm"]["bias"])
    logits = (x @ params["head"]).astype(jnp.float32)
    if cache is not None:
        return logits, {"layers": new_st, "len": cache["len"] + tokens.shape[1]}
    return logits


def forward_decode(cfg: LMConfig, params: Params, tokens: jax.Array,
                   cache: Params):
    """tokens (B,1); O(1) per step — state-based decode."""
    return forward(cfg, params, {"tokens": tokens}, cache=cache)


def forward_hidden(cfg: LMConfig, params: Params,
                   batch: Dict[str, jax.Array]) -> jax.Array:
    """Post-block hidden states (B, S, D) — pair with :func:`unembed`."""
    tokens = batch["tokens"]
    b = tokens.shape[0]
    x = jnp.take(params["embed"], tokens, axis=0)
    init_st = jax.tree.map(
        lambda y: jnp.broadcast_to(y, (cfg.n_layers,) + y.shape),
        _zero_layer_state(cfg, b))

    def body(x, xs):
        bp, st = xs
        x, st = _block(cfg, bp, x, st)
        return x, st

    blk = jax.checkpoint(body) if cfg.remat else body
    x, _ = jax.lax.scan(blk, x, (params["blocks"], init_st))
    return x


def unembed(cfg: LMConfig, params: Params, x: jax.Array) -> jax.Array:
    x = A.layer_norm(x, params["final_norm"]["scale"],
                     params["final_norm"]["bias"])
    return (x @ params["head"]).astype(jnp.float32)
