"""LM architecture family: config, parameter init, and forward passes.

One config dataclass covers the 10 assigned architectures; ``family``
dispatches to the dense/MoE path here, or to the hybrid (rglru.py), ssm
(rwkv6.py) and enc-dec (whisper.py) modules.

Implementation notes (dry-run driven):
* homogeneous blocks are **stacked** along a leading layer axis and executed
  with ``jax.lax.scan`` — keeps HLO size O(1) in depth so an 80-layer model
  compiles quickly even on the CPU host that carries 512 fake devices;
* MoE uses GShard-style dense dispatch (one-hot capacity routing) — no ragged
  ops, shardable over the expert axis;
* attention dispatches to full/chunked/decode variants (attention.py);
* params are bf16; losses/softmax in fp32.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from . import attention as A


@jax.custom_jvp
def _opt_barrier(x):
    """``jax.lax.optimization_barrier`` with a differentiation rule.

    The barrier is semantically the identity, but jaxlib only grew its
    built-in differentiation rule after 0.4.x — under ``value_and_grad``
    older releases raise ``NotImplementedError: Differentiation rule for
    'optimization_barrier'``.  The custom JVP passes tangents through
    unchanged (the identity's exact derivative), keeping the barrier's
    convert-motion fence in the primal computation only."""
    return jax.lax.optimization_barrier(x)


@_opt_barrier.defjvp
def _opt_barrier_jvp(primals, tangents):
    (x,), (t,) = primals, tangents
    return _opt_barrier(x), t

Params = Dict[str, Any]


@dataclasses.dataclass(frozen=True)
class LMConfig:
    name: str
    family: str                   # dense | moe | vlm | hybrid | ssm | encdec
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0             # 0 -> d_model // n_heads
    qkv_bias: bool = False
    qk_norm: bool = False
    mlp_kind: str = "swiglu"      # swiglu | geglu | relu2 | gelu
    rope_theta: float = 1_000_000.0
    tie_embeddings: bool = False
    norm: str = "rms"             # rms | layer
    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    moe_group: int = 512          # routing group size (dispatch-cost bound)
    # --- hybrid (recurrentgemma / griffin) ---
    attn_every: int = 0           # every k-th layer (k=3: rec,rec,attn)
    local_window: int = 2048
    conv_width: int = 4
    # --- enc-dec (whisper) ---
    n_enc_layers: int = 0
    n_frames: int = 1500
    # --- vlm (qwen2-vl) ---
    mrope_sections: Tuple[int, ...] = ()
    n_patches: int = 0
    # --- rwkv ---
    rwkv_head_dim: int = 64
    # --- numerics / memory ---
    dtype: Any = jnp.bfloat16
    remat: bool = True
    q_chunk: int = 1024           # chunked-attention query block (long prefill)
    seq_shard_acts: bool = True   # Megatron-SP activation sharding at block
                                  # boundaries (see seq_shard_constraint)

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.hd

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.hd

    def validate(self) -> "LMConfig":
        assert self.n_heads % max(1, self.n_kv_heads) == 0, "GQA group size"
        if self.family == "moe":
            assert self.n_experts > 0 and 0 < self.top_k <= self.n_experts
        if self.family == "vlm":
            assert self.mrope_sections and sum(self.mrope_sections) == self.hd // 2
        if self.family == "hybrid":
            assert self.attn_every >= 2
        return self


# ---------------------------------------------------------------------------
# Init helpers
# ---------------------------------------------------------------------------
def _dense_init(key, shape, dtype, scale: Optional[float] = None):
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    s = scale if scale is not None else fan_in ** -0.5
    return (jax.random.normal(key, shape, jnp.float32) * s).astype(dtype)


def _stack_init(key, n: int, fn):
    """Initialize n copies of a param tree and stack along axis 0."""
    keys = jax.random.split(key, n)
    trees = [fn(k) for k in keys]
    return jax.tree.map(lambda *xs: jnp.stack(xs, 0), *trees)


def init_attn_params(cfg: LMConfig, key, dtype) -> Params:
    ks = jax.random.split(key, 8)
    d, qd, kvd = cfg.d_model, cfg.q_dim, cfg.kv_dim
    p: Params = {
        "wq": _dense_init(ks[0], (d, qd), dtype),
        "wk": _dense_init(ks[1], (d, kvd), dtype),
        "wv": _dense_init(ks[2], (d, kvd), dtype),
        "wo": _dense_init(ks[3], (qd, d), dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((qd,), dtype)
        p["bk"] = jnp.zeros((kvd,), dtype)
        p["bv"] = jnp.zeros((kvd,), dtype)
    if cfg.qk_norm:
        p["q_norm"] = jnp.zeros((cfg.hd,), dtype)
        p["k_norm"] = jnp.zeros((cfg.hd,), dtype)
    return p


def init_mlp_params(cfg: LMConfig, key, dtype) -> Params:
    ks = jax.random.split(key, 4)
    d, f = cfg.d_model, cfg.d_ff
    if cfg.family == "moe":
        e = cfg.n_experts
        return {
            "router": _dense_init(ks[0], (d, e), jnp.float32),
            "wg": _dense_init(ks[1], (e, d, f), dtype),
            "wu": _dense_init(ks[2], (e, d, f), dtype),
            "wd": _dense_init(ks[3], (e, f, d), dtype),
        }
    if cfg.mlp_kind in ("swiglu", "geglu"):
        return {"wg": _dense_init(ks[0], (d, f), dtype),
                "wu": _dense_init(ks[1], (d, f), dtype),
                "wd": _dense_init(ks[2], (f, d), dtype)}
    return {"wu": _dense_init(ks[0], (d, f), dtype),
            "wd": _dense_init(ks[1], (f, d), dtype)}


def _norm_params(cfg: LMConfig, dtype) -> Params:
    if cfg.norm == "layer":
        return {"scale": jnp.ones((cfg.d_model,), dtype),
                "bias": jnp.zeros((cfg.d_model,), dtype)}
    return {"scale": jnp.zeros((cfg.d_model,), dtype)}


def init_block_params(cfg: LMConfig, key, dtype) -> Params:
    k1, k2 = jax.random.split(key)
    return {"ln1": _norm_params(cfg, dtype),
            "attn": init_attn_params(cfg, k1, dtype),
            "ln2": _norm_params(cfg, dtype),
            "mlp": init_mlp_params(cfg, k2, dtype)}


def init_params(cfg: LMConfig, key) -> Params:
    """Init for dense / moe / vlm families (hybrid/ssm/encdec: own modules)."""
    dtype = cfg.dtype
    k_emb, k_blocks, k_head = jax.random.split(key, 3)
    params: Params = {
        "embed": _dense_init(k_emb, (cfg.vocab, cfg.d_model), dtype, scale=0.02),
        "blocks": _stack_init(k_blocks, cfg.n_layers,
                              lambda k: init_block_params(cfg, k, dtype)),
        "final_norm": _norm_params(cfg, dtype),
    }
    if not cfg.tie_embeddings:
        params["head"] = _dense_init(k_head, (cfg.d_model, cfg.vocab), dtype)
    return params


# ---------------------------------------------------------------------------
# Blocks
# ---------------------------------------------------------------------------
def _norm(cfg: LMConfig, p: Params, x: jax.Array) -> jax.Array:
    if cfg.norm == "layer":
        return A.layer_norm(x, p["scale"], p["bias"])
    return A.rms_norm(x, p["scale"])


def _qkv(cfg: LMConfig, p: Params, x: jax.Array):
    b, s, _ = x.shape
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(b, s, cfg.n_heads, cfg.hd)
    k = k.reshape(b, s, cfg.n_kv_heads, cfg.hd)
    v = v.reshape(b, s, cfg.n_kv_heads, cfg.hd)
    if cfg.qk_norm:
        q = A.rms_norm(q, p["q_norm"])
        k = A.rms_norm(k, p["k_norm"])
    return q, k, v


def _rope_qk(cfg: LMConfig, q, k, positions):
    if cfg.family == "vlm":
        return (A.apply_mrope(q, positions, cfg.mrope_sections, cfg.rope_theta),
                A.apply_mrope(k, positions, cfg.mrope_sections, cfg.rope_theta))
    return (A.apply_rope(q, positions, cfg.rope_theta),
            A.apply_rope(k, positions, cfg.rope_theta))


def attn_block(cfg: LMConfig, p: Params, x: jax.Array, positions,
               window: Optional[int] = None) -> jax.Array:
    """Full-sequence causal attention (train / prefill)."""
    q, k, v = _qkv(cfg, p, x)
    q, k = _rope_qk(cfg, q, k, positions)
    q, k, v = attn_shard_constraints(q, k, v)
    s = x.shape[1]
    if s > cfg.q_chunk:
        out = A.chunked_attention(q, k, v, causal=True, q_chunk=cfg.q_chunk,
                                  window=window)
    else:
        out = A.full_attention(q, k, v, causal=True, window=window)
    b = x.shape[0]
    return out.reshape(b, s, cfg.q_dim) @ p["wo"]


def attn_block_decode(cfg: LMConfig, p: Params, x: jax.Array,
                      k_cache: jax.Array, v_cache: jax.Array,
                      cache_len: jax.Array, positions,
                      window: Optional[int] = None):
    """Single-token decode; returns (out, new_k_cache, new_v_cache).

    Caches are (B, T, Hkv, D).  For windowed layers T may be the window size
    and slots are addressed modulo T (ring buffer).
    """
    b = x.shape[0]
    q, k, v = _qkv(cfg, p, x)                       # S == 1
    q, k = _rope_qk(cfg, q, k, positions)
    t = k_cache.shape[1]
    slot = jnp.mod(cache_len - 1, t)
    from ..launch import variants
    if cfg.family in ("dense", "moe", "vlm") and not variants.on("cache_hd"):
        # DEFAULT: sequence-sharded cache (flash-decoding; 2.9x decode win,
        # EXPERIMENTS.md §Perf).  A dynamic-update-slice across the sharded
        # T axis forces a full reshard in GSPMD; the one-hot masked write
        # is pointwise over T and stays local.
        hit = (jnp.arange(t) == slot)[None, :, None, None]
        k_cache = jnp.where(hit, k.astype(k_cache.dtype), k_cache)
        v_cache = jnp.where(hit, v.astype(v_cache.dtype), v_cache)
    else:
        k_cache = jax.lax.dynamic_update_slice(k_cache, k, (0, slot, 0, 0))
        v_cache = jax.lax.dynamic_update_slice(v_cache, v, (0, slot, 0, 0))
    if window is not None and t <= window:
        # ring buffer: all t slots are valid once cache_len >= t
        valid_len = jnp.minimum(cache_len, t)
        out = A.decode_attention(q, k_cache, v_cache, valid_len[None],
                                 window=None)
    else:
        out = A.decode_attention(q, k_cache, v_cache, cache_len[None],
                                 window=window)
    return out.reshape(b, 1, cfg.q_dim) @ p["wo"], k_cache, v_cache


def mlp_block(cfg: LMConfig, p: Params, x: jax.Array) -> jax.Array:
    if cfg.family == "moe":
        return moe_block(cfg, p, x)
    kind = cfg.mlp_kind
    if kind == "swiglu":
        h = jax.nn.silu(x @ p["wg"]) * (x @ p["wu"])
    elif kind == "geglu":
        h = jax.nn.gelu(x @ p["wg"]) * (x @ p["wu"])
    elif kind == "relu2":
        h = jnp.square(jax.nn.relu(x @ p["wu"]))
    else:  # gelu
        h = jax.nn.gelu(x @ p["wu"])
    return h @ p["wd"]


# ---------------------------------------------------------------------------
# MoE (GShard dense dispatch; EP-shardable over the expert axis)
# ---------------------------------------------------------------------------
def moe_block(cfg: LMConfig, p: Params, x: jax.Array) -> jax.Array:
    """Grouped GShard dispatch: tokens are routed within contiguous groups
    of ``moe_group`` tokens, keeping the one-hot dispatch einsum cost
    O(group * E * cap * D) — linear in sequence length (the ungrouped
    dispatch is quadratic and would dominate FLOPs at 32k prefill)."""
    bb, ss, d = x.shape
    g = min(cfg.moe_group, ss)
    assert ss % g == 0, (ss, g)
    x = x.reshape(bb * (ss // g), g, d)
    b, s, _ = x.shape
    e, k = cfg.n_experts, cfg.top_k
    cap = min(int(cfg.capacity_factor * s * k / e) + 1, s)

    logits = (x.astype(jnp.float32) @ p["router"])          # (B,S,E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, k)            # (B,S,k)
    gate_vals = gate_vals / (jnp.sum(gate_vals, -1, keepdims=True) + 1e-9)

    # one-hot dispatch with capacity: position of each token within its expert
    onehot = jax.nn.one_hot(gate_idx, e, dtype=jnp.float32)  # (B,S,k,E)
    # fold the top-k choices into a single (B,S,E) assignment weight
    combine_w = jnp.einsum("bske,bsk->bse", onehot, gate_vals)
    assign = jnp.max(onehot, axis=2)                         # (B,S,E) 0/1
    pos_in_expert = jnp.cumsum(assign, axis=1) * assign - 1  # (B,S,E)
    keep = (pos_in_expert >= 0) & (pos_in_expert < cap)
    pos_clamped = jnp.clip(pos_in_expert, 0, cap - 1).astype(jnp.int32)
    slot_oh = jax.nn.one_hot(pos_clamped, cap, dtype=jnp.float32)  # (B,S,E,C)
    dispatch = slot_oh * keep[..., None]                     # (B,S,E,C)
    combine = dispatch * combine_w[..., None]                # (B,S,E,C)

    xt = jnp.einsum("bsec,bsd->ebcd", dispatch, x.astype(jnp.float32))
    xt = xt.astype(x.dtype)                                  # (E,B,C,D)
    h = jax.nn.silu(jnp.einsum("ebcd,edf->ebcf", xt, p["wg"])) * \
        jnp.einsum("ebcd,edf->ebcf", xt, p["wu"])
    y = jnp.einsum("ebcf,efd->ebcd", h, p["wd"])             # (E,B,C,D)
    out = jnp.einsum("bsec,ebcd->bsd", combine.astype(x.dtype), y)
    return out.reshape(bb, ss, d)


def moe_aux_loss(cfg: LMConfig, logits: jax.Array, gate_idx: jax.Array) -> jax.Array:
    """Switch-style load-balancing auxiliary loss."""
    e = cfg.n_experts
    probs = jax.nn.softmax(logits, axis=-1)
    me = jnp.mean(probs.reshape(-1, e), axis=0)
    ce = jnp.mean(jax.nn.one_hot(gate_idx.reshape(-1), e, dtype=jnp.float32),
                  axis=0)
    return e * jnp.sum(me * ce)


# ---------------------------------------------------------------------------
# Whole-model forward (dense / moe / vlm)
# ---------------------------------------------------------------------------
def _mesh_info():
    """Mesh names/sizes at trace time (launchers register via
    mesh_context; get_abstract_mesh is empty under a plain `with mesh:`)."""
    from ..launch.mesh import current_mesh_info
    info = current_mesh_info()
    if info is not None:
        return info
    try:
        am = jax.sharding.get_abstract_mesh()
        names = tuple(am.axis_names) if am is not None else ()
        if not names:
            return None
        sizes = dict(zip(names, am.axis_sizes)) if hasattr(am, "axis_sizes") \
            else {n: am.shape[n] for n in names}
        return names, sizes
    except Exception:       # pragma: no cover - older jax
        return None


def attn_shard_constraints(q: jax.Array, k: jax.Array, v: jax.Array):
    """Explicit attention shardings (perf knob ``attn_shard``): q sharded
    over heads when divisible, k/v replicated over model.  Stops GSPMD from
    propagating the kv-feature sharding into the score einsums (which
    otherwise psums fp32 score tensors per layer)."""
    from ..launch import variants
    if not variants.on("attn_shard"):
        return q, k, v
    info = _mesh_info()
    if info is None or "model" not in info[0]:
        return q, k, v
    names, sizes = info
    daxes = tuple(a for a in ("pod", "data") if a in names)
    dsize = 1
    for a in daxes:
        dsize *= sizes[a]
    bspec = (daxes if len(daxes) > 1 else daxes[0]) \
        if (daxes and q.shape[0] % dsize == 0) else None
    from jax.sharding import PartitionSpec as P
    try:
        hq = q.shape[2]
        qspec = P(bspec, None, "model" if hq % sizes["model"] == 0 else None,
                  None)
        q = jax.lax.with_sharding_constraint(q, qspec)
        kvspec = P(bspec, None, None, None)
        k = jax.lax.with_sharding_constraint(k, kvspec)
        v = jax.lax.with_sharding_constraint(v, kvspec)
    except Exception:
        pass
    return q, k, v


def weight_gather_constraint(bp: Params) -> Params:
    """FSDP weight-gathering (the MaxText pattern): inside the layer scan,
    constrain every block tensor to its TP-only spec.  Without this, GSPMD
    may instead run matmuls with the *data-sharded weight dim as a split
    contraction* and all-reduce the activations — measured at 11.6 TiB of
    all-reduce per step on qwen2.5-14b train (EXPERIMENTS.md §Perf).  With
    it, each layer all-gathers its (small) weight slice once per pass.
    No-op when params are not data-sharded or no mesh is active.
    Disable with the ``no_wgather`` variant knob."""
    from ..launch import variants
    if variants.on("no_wgather"):
        return bp
    info = _mesh_info()
    if info is None or "model" not in info[0]:
        return bp
    names_axes, sizes = info
    msize = sizes["model"]
    from ..launch.sharding import _path_names, _spec_for_param

    def one(path, leaf):
        names = _path_names(path)
        sp = _spec_for_param(names, leaf.shape, msize, True)
        try:
            return jax.lax.with_sharding_constraint(leaf, sp)
        except Exception:
            return leaf

    return jax.tree_util.tree_map_with_path(one, bp)


def seq_shard_constraint(x: jax.Array) -> jax.Array:
    """Megatron-SP-style activation sharding at block boundaries: shard the
    carry (B, S, D) as (data, model, None) when a mesh is active and the
    dims divide.  The remat-saved residual stack inherits this sharding —
    for an 80L x 8192d model that is a 16x reduction of the dominant
    activation buffer (85 GiB -> 5.3 GiB/device); XLA inserts the per-layer
    all-gather/reduce-scatter pair this implies.  No-op outside a mesh."""
    from ..launch import variants
    if variants.on("no_seqshard"):
        return x
    info = _mesh_info()
    if info is None:
        return x
    names, sizes = info
    if "model" not in names or x.ndim != 3:
        return x
    daxes = tuple(a for a in ("pod", "data") if a in names)
    dsize = 1
    for a in daxes:
        dsize *= sizes[a]
    spec_b = None
    if daxes and x.shape[0] % dsize == 0:
        spec_b = daxes if len(daxes) > 1 else daxes[0]
    if x.shape[1] % sizes["model"] != 0:
        return x
    from jax.sharding import PartitionSpec as P
    try:
        return jax.lax.with_sharding_constraint(
            x, P(spec_b, "model", None))
    except Exception:
        return x


def _block_fn(cfg: LMConfig, window: Optional[int] = None):
    def fn(x, bp, positions):
        bp = weight_gather_constraint(bp)
        x = x + attn_block(cfg, bp["attn"], _norm(cfg, bp["ln1"], x),
                           positions, window=window)
        x = x + mlp_block(cfg, bp["mlp"], _norm(cfg, bp["ln2"], x))
        return x
    return fn


def embed_tokens(cfg: LMConfig, params: Params, tokens: jax.Array) -> jax.Array:
    return jnp.take(params["embed"], tokens, axis=0)


def unembed(cfg: LMConfig, params: Params, x: jax.Array) -> jax.Array:
    x = _norm(cfg, params["final_norm"], x)
    w = params["embed"].T if cfg.tie_embeddings else params["head"]
    return (x @ w).astype(jnp.float32)


def _default_positions(cfg: LMConfig, batch: Dict[str, jax.Array],
                       seq: int) -> jax.Array:
    if "positions" in batch:
        return batch["positions"]
    pos = jnp.arange(seq)[None, :]
    if cfg.family == "vlm":
        return jnp.broadcast_to(pos[None], (3,) + (batch["tokens"].shape[0], seq))
    return pos


def forward(cfg: LMConfig, params: Params, batch: Dict[str, jax.Array],
            last_token_only: bool = False) -> jax.Array:
    """Full-sequence forward -> fp32 logits (B, S, V).

    batch["tokens"]: (B, S) int32.  For vlm, batch["embeds"] (B, P, D) is
    prepended (stub vision frontend) and positions are (3, B, P+S).
    ``last_token_only``: unembed only the final position (prefill serving
    path — avoids materializing (B, S, V) logits).
    """
    x = embed_tokens(cfg, params, batch["tokens"])
    if cfg.family == "vlm" and "embeds" in batch:
        x = jnp.concatenate([batch["embeds"].astype(x.dtype), x], axis=1)
    seq = x.shape[1]
    positions = _default_positions(cfg, batch, seq)
    fn = _block_fn(cfg)
    if cfg.remat:
        fn = jax.checkpoint(fn)

    def body(x, bp):
        # barrier between the remat-saved carry and its f32 consumers:
        # without it XLA convert-motion rewrites the stacked bf16 residual
        # buffer updates in f32 (2x the activation stack).
        if cfg.seq_shard_acts:
            x = seq_shard_constraint(x)
        return fn(_opt_barrier(x), bp, positions), None

    x, _ = jax.lax.scan(body, x, params["blocks"])
    if last_token_only:
        x = x[:, -1:]
    return unembed(cfg, params, x)


def forward_hidden(cfg: LMConfig, params: Params,
                   batch: Dict[str, jax.Array]) -> jax.Array:
    """Post-block hidden states (B, S, D) — pair with :func:`unembed`
    for chunked (memory-bounded) loss computation."""
    x = embed_tokens(cfg, params, batch["tokens"])
    if cfg.family == "vlm" and "embeds" in batch:
        x = jnp.concatenate([batch["embeds"].astype(x.dtype), x], axis=1)
    seq = x.shape[1]
    positions = _default_positions(cfg, batch, seq)
    fn = _block_fn(cfg)
    if cfg.remat:
        fn = jax.checkpoint(fn)

    def body(x, bp):
        if cfg.seq_shard_acts:
            x = seq_shard_constraint(x)
        return fn(_opt_barrier(x), bp, positions), None

    x, _ = jax.lax.scan(body, x, params["blocks"])
    return x


# ---------------------------------------------------------------------------
# KV-cache decode
# ---------------------------------------------------------------------------
def init_cache(cfg: LMConfig, batch: int, max_len: int) -> Params:
    t = max_len
    shape = (cfg.n_layers, batch, t, cfg.n_kv_heads, cfg.hd)
    return {"k": jnp.zeros(shape, cfg.dtype), "v": jnp.zeros(shape, cfg.dtype),
            "len": jnp.zeros((), jnp.int32)}


def forward_decode(cfg: LMConfig, params: Params, tokens: jax.Array,
                   cache: Params) -> Tuple[jax.Array, Params]:
    """One decode step: tokens (B, 1) -> logits (B, 1, V), updated cache."""
    x = embed_tokens(cfg, params, tokens)
    new_len = cache["len"] + 1
    pos = (new_len - 1)[None, None]                     # (1,1) broadcast
    if cfg.family == "vlm":
        pos = jnp.broadcast_to(pos[None], (3, 1, 1))

    def body(x, xs):
        bp, kc, vc = xs
        # barrier: prevents CPU float-normalization from hoisting an f32
        # convert of the whole stacked cache out of the layer loop (a
        # CPU-only legalization; TPU dots consume bf16 natively)
        kc, vc = _opt_barrier((kc, vc))
        h = _norm(cfg, bp["ln1"], x)
        out, kc, vc = attn_block_decode(cfg, bp["attn"], h, kc, vc,
                                        new_len, pos)
        x = x + out
        x = x + mlp_block(cfg, bp["mlp"], _norm(cfg, bp["ln2"], x))
        return x, (kc, vc)

    x, (k_new, v_new) = jax.lax.scan(body, x,
                                     (params["blocks"], cache["k"], cache["v"]))
    logits = unembed(cfg, params, x)
    return logits, {"k": k_new, "v": v_new, "len": new_len}


# ---------------------------------------------------------------------------
# Loss
# ---------------------------------------------------------------------------
def lm_loss(logits: jax.Array, labels: jax.Array,
            mask: Optional[jax.Array] = None) -> jax.Array:
    """Mean token cross-entropy in fp32; labels (B, S) int32."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is not None:
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)
