"""Whisper-tiny backbone (arXiv:2212.04356) — encoder-decoder transformer.

Per the assignment the conv audio frontend is a **stub**: ``input_specs()``
supplies precomputed mel-frame embeddings (B, n_frames=1500, d=384); the
backbone (4 encoder layers, 4 decoder layers with cross-attention, LayerNorm,
GELU MLP, bias on projections, tied unembedding) is fully modeled.

Deviation noted in DESIGN.md: positions are sinusoidal for both encoder and
decoder (real Whisper uses learned decoder positions capped at 448) so the
stress decode shapes (32k cache) remain well-defined.

DAG note (paper §6.1.1): cross-attention edges make every decoder layer
*deeper* than the last encoder layer, so horizontal cuts naturally split
encoder stages first — the LayerGraph in lm_graph.py encodes those edges.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from . import attention as A
from .lm import LMConfig, _dense_init, _stack_init

Params = Dict[str, Any]


def _ln_params(d: int, dtype) -> Params:
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def _ln(p: Params, x: jax.Array) -> jax.Array:
    return A.layer_norm(x, p["scale"], p["bias"])


def _attn_params(cfg: LMConfig, key, dtype) -> Params:
    d, qd, kvd = cfg.d_model, cfg.q_dim, cfg.kv_dim
    ks = jax.random.split(key, 4)
    return {"wq": _dense_init(ks[0], (d, qd), dtype),
            "bq": jnp.zeros((qd,), dtype),
            "wk": _dense_init(ks[1], (d, kvd), dtype),
            "wv": _dense_init(ks[2], (d, kvd), dtype),
            "bv": jnp.zeros((kvd,), dtype),
            "wo": _dense_init(ks[3], (qd, d), dtype),
            "bo": jnp.zeros((d,), dtype)}


def _mlp_params(cfg: LMConfig, key, dtype) -> Params:
    k1, k2 = jax.random.split(key)
    return {"wu": _dense_init(k1, (cfg.d_model, cfg.d_ff), dtype),
            "bu": jnp.zeros((cfg.d_ff,), dtype),
            "wd": _dense_init(k2, (cfg.d_ff, cfg.d_model), dtype),
            "bd": jnp.zeros((cfg.d_model,), dtype)}


def init_params(cfg: LMConfig, key) -> Params:
    dtype = cfg.dtype
    k1, k2, k3 = jax.random.split(key, 3)

    def enc_layer(k):
        ka, kb = jax.random.split(k)
        return {"ln1": _ln_params(cfg.d_model, dtype),
                "attn": _attn_params(cfg, ka, dtype),
                "ln2": _ln_params(cfg.d_model, dtype),
                "mlp": _mlp_params(cfg, kb, dtype)}

    def dec_layer(k):
        ka, kb, kc = jax.random.split(k, 3)
        return {"ln1": _ln_params(cfg.d_model, dtype),
                "attn": _attn_params(cfg, ka, dtype),
                "ln_x": _ln_params(cfg.d_model, dtype),
                "xattn": _attn_params(cfg, kb, dtype),
                "ln2": _ln_params(cfg.d_model, dtype),
                "mlp": _mlp_params(cfg, kc, dtype)}

    return {
        "embed": _dense_init(k1, (cfg.vocab, cfg.d_model), dtype, 0.02),
        "enc": _stack_init(k2, cfg.n_enc_layers, enc_layer),
        "enc_ln": _ln_params(cfg.d_model, dtype),
        "dec": _stack_init(k3, cfg.n_layers, dec_layer),
        "dec_ln": _ln_params(cfg.d_model, dtype),
    }


def _sinusoid(seq: int, d: int, offset=0) -> jax.Array:
    pos = jnp.arange(seq)[:, None] + offset
    dim = jnp.arange(0, d, 2)[None, :] / d
    ang = pos / jnp.power(10000.0, dim)
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def _split_heads(cfg: LMConfig, x: jax.Array, n: int) -> jax.Array:
    b, s, _ = x.shape
    return x.reshape(b, s, n, cfg.hd)


def _self_attn(cfg: LMConfig, p: Params, x: jax.Array, causal: bool) -> jax.Array:
    q = _split_heads(cfg, x @ p["wq"] + p["bq"], cfg.n_heads)
    k = _split_heads(cfg, x @ p["wk"], cfg.n_kv_heads)
    v = _split_heads(cfg, x @ p["wv"] + p["bv"], cfg.n_kv_heads)
    out = A.full_attention(q, k, v, causal=causal)
    b, s = x.shape[:2]
    return out.reshape(b, s, cfg.q_dim) @ p["wo"] + p["bo"]


def _cross_attn(cfg: LMConfig, p: Params, x: jax.Array,
                mem_k: jax.Array, mem_v: jax.Array) -> jax.Array:
    q = _split_heads(cfg, x @ p["wq"] + p["bq"], cfg.n_heads)
    out = A.cross_attention(q, mem_k, mem_v)
    b, s = x.shape[:2]
    return out.reshape(b, s, cfg.q_dim) @ p["wo"] + p["bo"]


def _mlp(p: Params, x: jax.Array) -> jax.Array:
    return jax.nn.gelu(x @ p["wu"] + p["bu"]) @ p["wd"] + p["bd"]


def encode(cfg: LMConfig, params: Params, frames: jax.Array) -> jax.Array:
    """frames: (B, n_frames, D) stub embeddings -> encoder memory."""
    x = frames.astype(cfg.dtype) + _sinusoid(frames.shape[1],
                                             cfg.d_model).astype(cfg.dtype)

    def body(x, lp):
        x = x + _self_attn(cfg, lp["attn"], _ln(lp["ln1"], x), causal=False)
        x = x + _mlp(lp["mlp"], _ln(lp["ln2"], x))
        return x, None

    x, _ = jax.lax.scan(body, x, params["enc"])
    return _ln(params["enc_ln"], x)


def _mem_kv(cfg: LMConfig, params: Params, memory: jax.Array):
    """Precompute per-decoder-layer cross K/V from encoder memory."""
    def one(lp):
        k = _split_heads(cfg, memory @ lp["xattn"]["wk"], cfg.n_kv_heads)
        v = _split_heads(cfg, memory @ lp["xattn"]["wv"] + lp["xattn"]["bv"],
                         cfg.n_kv_heads)
        return k, v
    return jax.vmap(one)(params["dec"])     # stacked over layers


def decode_train(cfg: LMConfig, params: Params, tokens: jax.Array,
                 memory: jax.Array, last_token_only: bool = False
                 ) -> jax.Array:
    """Teacher-forced decoder pass -> fp32 logits."""
    b, s = tokens.shape
    x = jnp.take(params["embed"], tokens, axis=0)
    x = x + _sinusoid(s, cfg.d_model).astype(cfg.dtype)
    mem_k, mem_v = _mem_kv(cfg, params, memory)

    def body(x, xs):
        lp, mk, mv = xs
        x = x + _self_attn(cfg, lp["attn"], _ln(lp["ln1"], x), causal=True)
        x = x + _cross_attn(cfg, lp["xattn"], _ln(lp["ln_x"], x), mk, mv)
        x = x + _mlp(lp["mlp"], _ln(lp["ln2"], x))
        return x, None

    x, _ = jax.lax.scan(body, x, (params["dec"], mem_k, mem_v))
    if last_token_only:
        x = x[:, -1:]
    x = _ln(params["dec_ln"], x)
    return (x @ params["embed"].T).astype(jnp.float32)   # tied unembedding


def forward(cfg: LMConfig, params: Params, batch: Dict[str, jax.Array],
            last_token_only: bool = False) -> jax.Array:
    memory = encode(cfg, params, batch["frames"])
    return decode_train(cfg, params, batch["tokens"], memory,
                        last_token_only=last_token_only)


def forward_hidden(cfg: LMConfig, params: Params,
                   batch: Dict[str, jax.Array]) -> jax.Array:
    memory = encode(cfg, params, batch["frames"])
    tokens = batch["tokens"]
    x = jnp.take(params["embed"], tokens, axis=0)
    x = x + _sinusoid(tokens.shape[1], cfg.d_model).astype(cfg.dtype)
    mem_k, mem_v = _mem_kv(cfg, params, memory)

    def body(x, xs):
        lp, mk, mv = xs
        x = x + _self_attn(cfg, lp["attn"], _ln(lp["ln1"], x), causal=True)
        x = x + _cross_attn(cfg, lp["xattn"], _ln(lp["ln_x"], x), mk, mv)
        x = x + _mlp(lp["mlp"], _ln(lp["ln2"], x))
        return x, None

    x, _ = jax.lax.scan(body, x, (params["dec"], mem_k, mem_v))
    return x


def unembed(cfg: LMConfig, params: Params, x: jax.Array) -> jax.Array:
    x = _ln(params["dec_ln"], x)
    return (x @ params["embed"].T).astype(jnp.float32)


# ---------------------------------------------------------------------------
# KV-cache decode
# ---------------------------------------------------------------------------
def init_cache(cfg: LMConfig, batch: int, max_len: int,
               memory: Optional[jax.Array] = None,
               params: Optional[Params] = None) -> Params:
    L = cfg.n_layers
    shape = (L, batch, max_len, cfg.n_kv_heads, cfg.hd)
    cache: Params = {"k": jnp.zeros(shape, cfg.dtype),
                     "v": jnp.zeros(shape, cfg.dtype),
                     "len": jnp.zeros((), jnp.int32)}
    if memory is not None and params is not None:
        mk, mv = _mem_kv(cfg, params, memory)
        cache["mem_k"], cache["mem_v"] = mk, mv
    else:
        mshape = (L, batch, cfg.n_frames, cfg.n_kv_heads, cfg.hd)
        cache["mem_k"] = jnp.zeros(mshape, cfg.dtype)
        cache["mem_v"] = jnp.zeros(mshape, cfg.dtype)
    return cache


def forward_decode(cfg: LMConfig, params: Params, tokens: jax.Array,
                   cache: Params) -> Tuple[jax.Array, Params]:
    b = tokens.shape[0]
    new_len = cache["len"] + 1
    x = jnp.take(params["embed"], tokens, axis=0)
    x = x + _sinusoid(1, cfg.d_model, offset=new_len - 1).astype(cfg.dtype)

    def body(x, xs):
        lp, kc, vc, mk, mv = xs
        h = _ln(lp["ln1"], x)
        q = _split_heads(cfg, h @ lp["attn"]["wq"] + lp["attn"]["bq"],
                         cfg.n_heads)
        k = _split_heads(cfg, h @ lp["attn"]["wk"], cfg.n_kv_heads)
        v = _split_heads(cfg, h @ lp["attn"]["wv"] + lp["attn"]["bv"],
                         cfg.n_kv_heads)
        slot = new_len - 1
        kc = jax.lax.dynamic_update_slice(kc, k, (0, slot, 0, 0))
        vc = jax.lax.dynamic_update_slice(vc, v, (0, slot, 0, 0))
        out = A.decode_attention(q, kc, vc, new_len[None])
        x = x + out.reshape(b, 1, cfg.q_dim) @ lp["attn"]["wo"] + lp["attn"]["bo"]
        x = x + _cross_attn(cfg, lp["xattn"], _ln(lp["ln_x"], x), mk, mv)
        x = x + _mlp(lp["mlp"], _ln(lp["ln2"], x))
        return x, (kc, vc)

    x, (kc, vc) = jax.lax.scan(
        body, x, (params["dec"], cache["k"], cache["v"],
                  cache["mem_k"], cache["mem_v"]))
    x = _ln(params["dec_ln"], x)
    logits = (x @ params["embed"].T).astype(jnp.float32)
    return logits, dict(cache, k=kc, v=vc, len=new_len)
