"""RecurrentGemma / Griffin hybrid (arXiv:2402.19427).

38 layers in a 1:2 attention:recurrence pattern — layer i is **local sliding-
window attention** (window 2048, MQA kv=1, head_dim 256) when ``i % 3 == 2``,
otherwise a **recurrent block**: dual projections (value + GeLU gate), a
short causal depthwise conv (width 4) and the RG-LRU diagonal recurrence

    r_t = sigma(w_a . x_t + b_a)          (recurrence gate, diagonal)
    i_t = sigma(w_i . x_t + b_i)          (input gate, diagonal)
    log a_t = -c * softplus(Lambda) * r_t  (c = 8)
    h_t = a_t h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

Every layer carries its own GeGLU MLP (d_ff 12288).  Gates are diagonal
(per-channel) — the official model uses block-diagonal; the simplification
is parameter-neutral at the reported scale and noted in DESIGN.md.

For scan-friendliness layers are grouped into stacked **super-blocks** of
(rec, rec, attn) x12 plus a stacked (rec, rec) tail = 38 layers.

Decode state: conv tail (W-1 inputs) + fp32 LRU h per rec layer; a ring KV
cache of min(seq, window) per attn layer — O(window) memory, which is why
this arch runs ``long_500k``.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from . import attention as A
from .lm import (LMConfig, _dense_init, _stack_init, _norm, init_attn_params,
                 init_mlp_params, attn_block, attn_block_decode, mlp_block)

Params = Dict[str, Any]
LRU_C = 8.0


def n_super_and_tail(n_layers: int, attn_every: int) -> Tuple[int, int]:
    n_super = n_layers // attn_every
    tail = n_layers - n_super * attn_every          # trailing rec layers
    return n_super, tail


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------
def init_rec_block(cfg: LMConfig, key, dtype) -> Params:
    d = cfg.d_model
    r = d                                           # lru width == d_model
    ks = jax.random.split(key, 5)
    return {
        "ln1": {"scale": jnp.zeros((d,), dtype)},
        "ln2": {"scale": jnp.zeros((d,), dtype)},
        "rec": {
            "wx": _dense_init(ks[0], (d, r), dtype),
            "wgate": _dense_init(ks[1], (d, r), dtype),
            "conv_w": _dense_init(ks[2], (cfg.conv_width, r), dtype, 0.3),
            "conv_b": jnp.zeros((r,), dtype),
            "a_gate_w": jnp.ones((r,), jnp.float32),
            "a_gate_b": jnp.zeros((r,), jnp.float32),
            "i_gate_w": jnp.ones((r,), jnp.float32),
            "i_gate_b": jnp.zeros((r,), jnp.float32),
            "lam": jnp.full((r,), 1.0, jnp.float32),
            "wo": _dense_init(ks[3], (r, d), dtype),
        },
        "mlp": init_mlp_params(cfg, ks[4], dtype),
    }


def init_attn_layer(cfg: LMConfig, key, dtype) -> Params:
    k1, k2 = jax.random.split(key)
    return {"ln1": {"scale": jnp.zeros((cfg.d_model,), dtype)},
            "attn": init_attn_params(cfg, k1, dtype),
            "ln2": {"scale": jnp.zeros((cfg.d_model,), dtype)},
            "mlp": init_mlp_params(cfg, k2, dtype)}


def init_params(cfg: LMConfig, key) -> Params:
    dtype = cfg.dtype
    n_super, tail = n_super_and_tail(cfg.n_layers, cfg.attn_every)
    k1, k2, k3, k4 = jax.random.split(key, 4)

    def super_block(k):
        ka, kb, kc = jax.random.split(k, 3)
        return {"rec1": init_rec_block(cfg, ka, dtype),
                "rec2": init_rec_block(cfg, kb, dtype),
                "attn": init_attn_layer(cfg, kc, dtype)}

    params: Params = {
        "embed": _dense_init(k1, (cfg.vocab, cfg.d_model), dtype, 0.02),
        "super": _stack_init(k2, n_super, super_block),
        "final_norm": {"scale": jnp.zeros((cfg.d_model,), dtype)},
    }
    if tail:
        params["tail"] = _stack_init(
            k3, tail, lambda k: init_rec_block(cfg, k, dtype))
    if not cfg.tie_embeddings:
        params["head"] = _dense_init(k4, (cfg.d_model, cfg.vocab), dtype)
    return params


# ---------------------------------------------------------------------------
# RG-LRU + conv
# ---------------------------------------------------------------------------
def _causal_conv(p: Params, x: jax.Array,
                 carry: Optional[jax.Array] = None):
    """Per-channel causal conv, width W.  carry: (B, W-1, R) previous inputs.
    Returns (y, new_carry)."""
    w = p["conv_w"].shape[0]
    if carry is None:
        carry = jnp.zeros((x.shape[0], w - 1, x.shape[-1]), x.dtype)
    xp = jnp.concatenate([carry, x], axis=1)         # (B, S+W-1, R)
    y = sum(xp[:, i:i + x.shape[1]] * p["conv_w"][i] for i in range(w))
    y = y + p["conv_b"]
    return y, xp[:, -(w - 1):]


def rg_lru(p: Params, x: jax.Array, h0: jax.Array):
    """x: (B,S,R); h0: (B,R) fp32.  Returns (y, h_last)."""
    xf = x.astype(jnp.float32)
    r = jax.nn.sigmoid(xf * p["a_gate_w"] + p["a_gate_b"])
    i = jax.nn.sigmoid(xf * p["i_gate_w"] + p["i_gate_b"])
    log_a = -LRU_C * jax.nn.softplus(p["lam"]) * r          # (B,S,R)
    a = jnp.exp(log_a)
    gated = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * (i * xf)

    def step(h, xs):
        a_t, g_t = xs
        h = a_t * h + g_t
        return h, h

    h_last, ys = jax.lax.scan(
        step, h0, (a.transpose(1, 0, 2), gated.transpose(1, 0, 2)))
    return ys.transpose(1, 0, 2).astype(x.dtype), h_last


def rec_temporal(cfg: LMConfig, p: Params, x: jax.Array, state: Params):
    """Recurrent temporal mixing.  state: {"conv": (B,W-1,R), "h": (B,R)}."""
    val = x @ p["wx"]
    gate = jax.nn.gelu(x @ p["wgate"])
    val, conv_carry = _causal_conv(p, val, state["conv"])
    y, h_last = rg_lru(p, val, state["h"])
    out = (y * gate) @ p["wo"]
    return out, {"conv": conv_carry, "h": h_last}


def _zero_rec_state(cfg: LMConfig, b: int) -> Params:
    r = cfg.d_model
    return {"conv": jnp.zeros((b, cfg.conv_width - 1, r), cfg.dtype),
            "h": jnp.zeros((b, r), jnp.float32)}


def rec_layer(cfg: LMConfig, bp: Params, x: jax.Array, state: Params):
    out, state = rec_temporal(cfg, bp["rec"], _norm(cfg, bp["ln1"], x), state)
    x = x + out
    x = x + mlp_block(cfg, bp["mlp"], _norm(cfg, bp["ln2"], x))
    return x, state


# ---------------------------------------------------------------------------
# forward (train / prefill)
# ---------------------------------------------------------------------------
def forward(cfg: LMConfig, params: Params, batch: Dict[str, jax.Array],
            last_token_only: bool = False,
            _hidden_only: bool = False) -> jax.Array:
    tokens = batch["tokens"]
    b, s = tokens.shape
    x = jnp.take(params["embed"], tokens, axis=0)
    positions = jnp.arange(s)[None, :]
    zero_state = _zero_rec_state(cfg, b)

    def super_fn(x, bp):
        if cfg.seq_shard_acts:
            from .lm import seq_shard_constraint
            x = seq_shard_constraint(x)
        x, _ = rec_layer(cfg, bp["rec1"], x, zero_state)
        x, _ = rec_layer(cfg, bp["rec2"], x, zero_state)
        ab = bp["attn"]
        x = x + attn_block(cfg, ab["attn"], _norm(cfg, ab["ln1"], x),
                           positions, window=cfg.local_window)
        x = x + mlp_block(cfg, ab["mlp"], _norm(cfg, ab["ln2"], x))
        return x, None

    fn = jax.checkpoint(super_fn) if cfg.remat else super_fn
    x, _ = jax.lax.scan(lambda c, bp: fn(c, bp), x, params["super"])

    if "tail" in params:
        def tail_fn(x, bp):
            x, _ = rec_layer(cfg, bp, x, zero_state)
            return x, None
        tfn = jax.checkpoint(tail_fn) if cfg.remat else tail_fn
        x, _ = jax.lax.scan(lambda c, bp: tfn(c, bp), x, params["tail"])

    if _hidden_only:
        return x
    if last_token_only:
        x = x[:, -1:]
    return unembed(cfg, params, x)


def forward_hidden(cfg: LMConfig, params: Params,
                   batch: Dict[str, jax.Array]) -> jax.Array:
    return forward(cfg, params, batch, _hidden_only=True)


def unembed(cfg: LMConfig, params: Params, x: jax.Array) -> jax.Array:
    x = _norm(cfg, params["final_norm"], x)
    w = params["embed"].T if cfg.tie_embeddings else params["head"]
    return (x @ w).astype(jnp.float32)


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------
def init_cache(cfg: LMConfig, batch: int, max_len: int) -> Params:
    n_super, tail = n_super_and_tail(cfg.n_layers, cfg.attn_every)
    w = min(max_len, cfg.local_window)
    rec = _zero_rec_state(cfg, batch)

    def stack(tree, n):
        return jax.tree.map(lambda x: jnp.broadcast_to(x, (n,) + x.shape), tree)

    cache: Params = {
        "rec1": stack(rec, n_super),
        "rec2": stack(rec, n_super),
        "k": jnp.zeros((n_super, batch, w, cfg.n_kv_heads, cfg.hd), cfg.dtype),
        "v": jnp.zeros((n_super, batch, w, cfg.n_kv_heads, cfg.hd), cfg.dtype),
        "len": jnp.zeros((), jnp.int32),
    }
    if tail:
        cache["tail"] = stack(rec, tail)
    return cache


def forward_decode(cfg: LMConfig, params: Params, tokens: jax.Array,
                   cache: Params) -> Tuple[jax.Array, Params]:
    x = jnp.take(params["embed"], tokens, axis=0)
    new_len = cache["len"] + 1
    pos = (new_len - 1)[None, None]

    def super_fn(x, xs):
        bp, st1, st2, kc, vc = xs
        x, st1 = rec_layer(cfg, bp["rec1"], x, st1)
        x, st2 = rec_layer(cfg, bp["rec2"], x, st2)
        ab = bp["attn"]
        h = _norm(cfg, ab["ln1"], x)
        out, kc, vc = attn_block_decode(cfg, ab["attn"], h, kc, vc, new_len,
                                        pos, window=cfg.local_window)
        x = x + out
        x = x + mlp_block(cfg, ab["mlp"], _norm(cfg, ab["ln2"], x))
        return x, (st1, st2, kc, vc)

    x, (st1, st2, kc, vc) = jax.lax.scan(
        super_fn, x,
        (params["super"], cache["rec1"], cache["rec2"], cache["k"], cache["v"]))
    new_cache = dict(cache, rec1=st1, rec2=st2, k=kc, v=vc, len=new_len)

    if "tail" in params:
        def tail_fn(x, xs):
            bp, st = xs
            x, st = rec_layer(cfg, bp, x, st)
            return x, st
        x, st_tail = jax.lax.scan(tail_fn, x, (params["tail"], cache["tail"]))
        new_cache["tail"] = st_tail

    x = _norm(cfg, params["final_norm"], x)
    w = params["embed"].T if cfg.tie_embeddings else params["head"]
    return (x @ w).astype(jnp.float32), new_cache
