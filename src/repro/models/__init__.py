"""Model zoo: the paper's CNNs (synthetic + real) and the assigned LM archs."""
