"""Family-dispatch API: one surface for all 10 assigned architectures.

    init(cfg, key)                      -> params
    forward(cfg, params, batch)         -> fp32 logits     (train / prefill)
    init_cache(cfg, batch, max_len)     -> decode cache
    decode(cfg, params, tokens, cache)  -> (logits, cache) (one token)
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax

from . import lm, rglru, rwkv6, whisper
from .lm import LMConfig

Params = Dict[str, Any]

_ATTN_FAMILIES = ("dense", "moe", "vlm")


def init(cfg: LMConfig, key: jax.Array) -> Params:
    cfg.validate()
    if cfg.family in _ATTN_FAMILIES:
        return lm.init_params(cfg, key)
    if cfg.family == "ssm":
        return rwkv6.init_params(cfg, key)
    if cfg.family == "hybrid":
        return rglru.init_params(cfg, key)
    if cfg.family == "encdec":
        return whisper.init_params(cfg, key)
    raise ValueError(cfg.family)


def forward(cfg: LMConfig, params: Params, batch: Dict[str, jax.Array],
            last_token_only: bool = False):
    if cfg.family in _ATTN_FAMILIES:
        return lm.forward(cfg, params, batch, last_token_only)
    if cfg.family == "ssm":
        return rwkv6.forward(cfg, params, batch,
                             last_token_only=last_token_only)
    if cfg.family == "hybrid":
        return rglru.forward(cfg, params, batch, last_token_only)
    if cfg.family == "encdec":
        return whisper.forward(cfg, params, batch, last_token_only)
    raise ValueError(cfg.family)


def forward_hidden(cfg: LMConfig, params: Params,
                   batch: Dict[str, jax.Array]) -> jax.Array:
    """Post-block hidden states — pair with :func:`unembed` for the
    memory-bounded chunked loss."""
    if cfg.family in _ATTN_FAMILIES:
        return lm.forward_hidden(cfg, params, batch)
    if cfg.family == "ssm":
        return rwkv6.forward_hidden(cfg, params, batch)
    if cfg.family == "hybrid":
        return rglru.forward_hidden(cfg, params, batch)
    if cfg.family == "encdec":
        return whisper.forward_hidden(cfg, params, batch)
    raise ValueError(cfg.family)


def unembed(cfg: LMConfig, params: Params, x: jax.Array) -> jax.Array:
    if cfg.family in _ATTN_FAMILIES:
        return lm.unembed(cfg, params, x)
    if cfg.family == "ssm":
        return rwkv6.unembed(cfg, params, x)
    if cfg.family == "hybrid":
        return rglru.unembed(cfg, params, x)
    if cfg.family == "encdec":
        return whisper.unembed(cfg, params, x)
    raise ValueError(cfg.family)


def init_cache(cfg: LMConfig, batch: int, max_len: int) -> Params:
    if cfg.family in _ATTN_FAMILIES:
        return lm.init_cache(cfg, batch, max_len)
    if cfg.family == "ssm":
        return rwkv6.init_cache(cfg, batch, max_len)
    if cfg.family == "hybrid":
        return rglru.init_cache(cfg, batch, max_len)
    if cfg.family == "encdec":
        return whisper.init_cache(cfg, batch, max_len)
    raise ValueError(cfg.family)


def decode(cfg: LMConfig, params: Params, tokens: jax.Array, cache: Params
           ) -> Tuple[jax.Array, Params]:
    if cfg.family in _ATTN_FAMILIES:
        return lm.forward_decode(cfg, params, tokens, cache)
    if cfg.family == "ssm":
        return rwkv6.forward_decode(cfg, params, tokens, cache)
    if cfg.family == "hybrid":
        return rglru.forward_decode(cfg, params, tokens, cache)
    if cfg.family == "encdec":
        return whisper.forward_decode(cfg, params, tokens, cache)
    raise ValueError(cfg.family)


def param_count(cfg: LMConfig) -> int:
    """Exact parameter count via eval_shape (no allocation)."""
    shapes = jax.eval_shape(lambda k: init(cfg, k),
                            jax.ShapeDtypeStruct((2,), "uint32"))
    import numpy as np
    return int(sum(np.prod(l.shape) for l in jax.tree.leaves(shapes)))


def active_param_count(cfg: LMConfig) -> int:
    """Active params per token (MoE: only top_k experts count)."""
    total = param_count(cfg)
    if cfg.family != "moe":
        return total
    expert_params = 3 * cfg.d_model * cfg.d_ff
    inactive = cfg.n_layers * (cfg.n_experts - cfg.top_k) * expert_params
    return total - inactive
