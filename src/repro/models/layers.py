"""Functional graph-model framework for the CNN zoo.

Every model is a :class:`GraphModel`: a DAG of :class:`OpNode`, each with a
parameter initializer and a pure-JAX apply function.  From a GraphModel we
derive:

* a runnable forward pass (``init`` / ``apply``), NHWC layout;
* partial execution of any layer subset (``apply_subset``) — this is what the
  pipelined executor runs per stage, with cut-crossing activations passed
  through the stage boundary exactly like the paper's host queues;
* a :class:`repro.core.graph.LayerGraph` with per-layer params/MACs/activation
  bytes (``to_layer_graph``) — the input to the segmentation strategies.

BatchNorm follows inference semantics (running stats folded in); parameter
counts include the 4 per-channel BN tensors, matching Keras' "params" metric
used by the paper's Table 1.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core.graph import LayerGraph

Params = Dict[str, Any]


@dataclasses.dataclass
class OpNode:
    name: str
    inputs: List[str]
    init: Callable[[jax.Array], Params]          # key -> params
    apply: Callable[[Params, List[jax.Array]], jax.Array]
    params_count: int
    macs: int
    out_shape: Tuple[int, ...]                   # per-single-input (no batch)
    kind: str = "generic"
    act_dtype_bytes: int = 1                     # int8 CNN path by default

    @property
    def out_bytes(self) -> int:
        return int(np.prod(self.out_shape)) * self.act_dtype_bytes


class GraphModel:
    """A DAG of OpNodes with one input placeholder and one output node."""

    def __init__(self, name: str, input_shape: Tuple[int, ...]):
        self.name = name
        self.input_shape = input_shape
        self.nodes: Dict[str, OpNode] = {}
        self._order: List[str] = []
        self.output: Optional[str] = None

    INPUT = "__input__"

    def add(self, node: OpNode) -> str:
        if node.name in self.nodes or node.name == self.INPUT:
            raise ValueError(f"duplicate node {node.name}")
        for i in node.inputs:
            if i != self.INPUT and i not in self.nodes:
                raise ValueError(f"unknown input {i} of {node.name}")
        self.nodes[node.name] = node
        self._order.append(node.name)
        self.output = node.name
        return node.name

    def shape_of(self, name: str) -> Tuple[int, ...]:
        if name == self.INPUT:
            return self.input_shape
        return self.nodes[name].out_shape

    # -- parameters -----------------------------------------------------------
    def init(self, key: jax.Array) -> Params:
        params: Params = {}
        keys = jax.random.split(key, max(1, len(self._order)))
        for k, name in zip(keys, self._order):
            p = self.nodes[name].init(k)
            if p:
                params[name] = p
        return params

    @property
    def total_params(self) -> int:
        return sum(n.params_count for n in self.nodes.values())

    @property
    def total_macs(self) -> int:
        return sum(n.macs for n in self.nodes.values())

    # -- execution --------------------------------------------------------------
    def apply(self, params: Params, x: jax.Array) -> jax.Array:
        acts: Dict[str, jax.Array] = {self.INPUT: x}
        for name in self._order:
            node = self.nodes[name]
            xs = [acts[i] for i in node.inputs]
            acts[name] = node.apply(params.get(name, {}), xs)
        assert self.output is not None
        return acts[self.output]

    def apply_subset(self, params: Params, boundary: Dict[str, jax.Array],
                     layer_names: Sequence[str]) -> Dict[str, jax.Array]:
        """Execute only `layer_names` (a contiguous depth range), reading
        cut-crossing inputs from `boundary`; returns activations needed by
        later layers (plus the model output if produced)."""
        subset = set(layer_names)
        acts: Dict[str, jax.Array] = dict(boundary)
        for name in self._order:
            if name not in subset:
                continue
            node = self.nodes[name]
            xs = [acts[i] for i in node.inputs]
            acts[name] = node.apply(params.get(name, {}), xs)
        # outputs = activations consumed outside the subset, or final output
        needed: Dict[str, jax.Array] = {}
        for name in self._order:
            if name in subset:
                continue
            for i in self.nodes[name].inputs:
                if i in subset:
                    needed[i] = acts[i]
        if self.output in subset:
            needed[self.output] = acts[self.output]
        return needed

    # -- lowering to the segmentation representation ----------------------------
    def to_layer_graph(self) -> LayerGraph:
        g = LayerGraph(self.name)
        for name in self._order:
            node = self.nodes[name]
            inputs = [i for i in node.inputs if i != self.INPUT]
            g.add_layer(name, params=node.params_count, macs=node.macs,
                        out_bytes=node.out_bytes, inputs=inputs, kind=node.kind)
        return g


# ---------------------------------------------------------------------------
# Builder: tracks spatial shapes and emits OpNodes with cost annotations.
# ---------------------------------------------------------------------------
class Builder:
    """Convenience layer-emitter for CNN definitions (NHWC, single image)."""

    def __init__(self, name: str, input_hw: Tuple[int, int], channels: int = 3):
        h, w = input_hw
        self.model = GraphModel(name, (h, w, channels))
        self._n = 0

    def _uniq(self, prefix: str) -> str:
        self._n += 1
        return f"{prefix}_{self._n}"

    # ---- primitive ops -------------------------------------------------------
    def conv(self, x: str, filters: int, kernel: int | Tuple[int, int],
             stride: int = 1, padding: str = "same", use_bias: bool = True,
             name: Optional[str] = None, groups: int = 1) -> str:
        kh, kw = (kernel, kernel) if isinstance(kernel, int) else kernel
        in_shape = self.model.shape_of(x)
        h, w, cin = in_shape
        if cin % groups:
            raise ValueError("cin % groups != 0")
        if padding == "same":
            oh, ow = math.ceil(h / stride), math.ceil(w / stride)
        else:
            oh, ow = (h - kh) // stride + 1, (w - kw) // stride + 1
        wshape = (kh, kw, cin // groups, filters)
        pcount = int(np.prod(wshape)) + (filters if use_bias else 0)
        macs = (cin // groups) * filters * kh * kw * oh * ow
        nm = name or self._uniq("conv")

        def init(key: jax.Array) -> Params:
            fan_in = kh * kw * (cin // groups)
            wkey, _ = jax.random.split(key)
            p = {"w": jax.random.normal(wkey, wshape, jnp.float32)
                      * (1.0 / math.sqrt(fan_in))}
            if use_bias:
                p["b"] = jnp.zeros((filters,), jnp.float32)
            return p

        pad = padding.upper()
        strides = (stride, stride)

        def apply(p: Params, xs: List[jax.Array]) -> jax.Array:
            y = jax.lax.conv_general_dilated(
                xs[0], p["w"], window_strides=strides, padding=pad,
                dimension_numbers=("NHWC", "HWIO", "NHWC"),
                feature_group_count=groups)
            if use_bias:
                y = y + p["b"]
            return y

        self.model.add(OpNode(nm, [x], init, apply, pcount, macs,
                              (oh, ow, filters), kind="conv"))
        return nm

    def dwconv(self, x: str, kernel: int, stride: int = 1,
               padding: str = "same", use_bias: bool = True,
               name: Optional[str] = None, multiplier: int = 1) -> str:
        in_shape = self.model.shape_of(x)
        _, _, cin = in_shape
        return self.conv(x, cin * multiplier, kernel, stride, padding,
                         use_bias, name or self._uniq("dwconv"), groups=cin)

    def bn(self, x: str, name: Optional[str] = None) -> str:
        h, w, c = self.model.shape_of(x)
        nm = name or self._uniq("bn")

        def init(key: jax.Array) -> Params:
            return {"gamma": jnp.ones((c,)), "beta": jnp.zeros((c,)),
                    "mean": jnp.zeros((c,)), "var": jnp.ones((c,))}

        def apply(p: Params, xs: List[jax.Array]) -> jax.Array:
            inv = jax.lax.rsqrt(p["var"] + 1e-3)
            return (xs[0] - p["mean"]) * inv * p["gamma"] + p["beta"]

        # Keras counts all 4 BN tensors in "params" (2 trainable + 2 stats)
        self.model.add(OpNode(nm, [x], init, apply, 4 * c, 0, (h, w, c),
                              kind="bn"))
        return nm

    def act(self, x: str, fn: str = "relu", name: Optional[str] = None) -> str:
        shape = self.model.shape_of(x)
        nm = name or self._uniq(fn)
        f = {"relu": jax.nn.relu,
             "relu6": lambda v: jnp.clip(v, 0, 6),
             "swish": jax.nn.silu,
             "sigmoid": jax.nn.sigmoid}[fn]

        def apply(p: Params, xs: List[jax.Array]) -> jax.Array:
            return f(xs[0])

        self.model.add(OpNode(nm, [x], lambda k: {}, apply, 0, 0, shape,
                              kind="act"))
        return nm

    def pool(self, x: str, kind: str, size: int, stride: int,
             padding: str = "same", name: Optional[str] = None) -> str:
        h, w, c = self.model.shape_of(x)
        if padding == "same":
            oh, ow = math.ceil(h / stride), math.ceil(w / stride)
        else:
            oh, ow = (h - size) // stride + 1, (w - size) // stride + 1
        nm = name or self._uniq(f"{kind}pool")
        pad = padding.upper()

        def apply(p: Params, xs: List[jax.Array]) -> jax.Array:
            v = xs[0]
            if kind == "max":
                return jax.lax.reduce_window(
                    v, -jnp.inf, jax.lax.max, (1, size, size, 1),
                    (1, stride, stride, 1), pad)
            s = jax.lax.reduce_window(
                v, 0.0, jax.lax.add, (1, size, size, 1),
                (1, stride, stride, 1), pad)
            ones = jnp.ones_like(v)
            cnt = jax.lax.reduce_window(
                ones, 0.0, jax.lax.add, (1, size, size, 1),
                (1, stride, stride, 1), pad)
            return s / cnt

        self.model.add(OpNode(nm, [x], lambda k: {}, apply, 0, 0,
                              (oh, ow, c), kind="pool"))
        return nm

    def gap(self, x: str, name: Optional[str] = None) -> str:
        _, _, c = self.model.shape_of(x)
        nm = name or self._uniq("gap")

        def apply(p: Params, xs: List[jax.Array]) -> jax.Array:
            return jnp.mean(xs[0], axis=(1, 2))

        self.model.add(OpNode(nm, [x], lambda k: {}, apply, 0, 0, (c,),
                              kind="pool"))
        return nm

    def dense(self, x: str, units: int, use_bias: bool = True,
              name: Optional[str] = None) -> str:
        shape = self.model.shape_of(x)
        fin = int(np.prod(shape))
        nm = name or self._uniq("dense")
        pcount = fin * units + (units if use_bias else 0)

        def init(key: jax.Array) -> Params:
            p = {"w": jax.random.normal(key, (fin, units), jnp.float32)
                      * (1.0 / math.sqrt(fin))}
            if use_bias:
                p["b"] = jnp.zeros((units,))
            return p

        def apply(p: Params, xs: List[jax.Array]) -> jax.Array:
            v = xs[0].reshape((xs[0].shape[0], -1))
            y = v @ p["w"]
            return y + p["b"] if use_bias else y

        self.model.add(OpNode(nm, [x], init, apply, pcount, fin * units,
                              (units,), kind="dense"))
        return nm

    def add(self, xs: Sequence[str], name: Optional[str] = None) -> str:
        shape = self.model.shape_of(xs[0])
        nm = name or self._uniq("add")

        def apply(p: Params, vs: List[jax.Array]) -> jax.Array:
            out = vs[0]
            for v in vs[1:]:
                out = out + v
            return out

        self.model.add(OpNode(nm, list(xs), lambda k: {}, apply, 0, 0, shape,
                              kind="add"))
        return nm

    def concat(self, xs: Sequence[str], name: Optional[str] = None) -> str:
        shapes = [self.model.shape_of(x) for x in xs]
        h, w = shapes[0][0], shapes[0][1]
        c = sum(s[2] for s in shapes)
        nm = name or self._uniq("concat")

        def apply(p: Params, vs: List[jax.Array]) -> jax.Array:
            return jnp.concatenate(vs, axis=-1)

        self.model.add(OpNode(nm, list(xs), lambda k: {}, apply, 0, 0,
                              (h, w, c), kind="concat"))
        return nm

    # ---- compound blocks ------------------------------------------------------
    def conv_bn(self, x: str, filters: int, kernel, stride: int = 1,
                padding: str = "same", act: Optional[str] = "relu",
                prefix: Optional[str] = None) -> str:
        p = prefix or self._uniq("cb")
        y = self.conv(x, filters, kernel, stride, padding, use_bias=False,
                      name=f"{p}_conv")
        y = self.bn(y, name=f"{p}_bn")
        if act:
            y = self.act(y, act, name=f"{p}_{act}")
        return y

    def build(self) -> GraphModel:
        return self.model
