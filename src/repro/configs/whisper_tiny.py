"""whisper-tiny [audio] — enc-dec backbone; conv audio frontend is a STUB
(input_specs provides precomputed frame embeddings).  [arXiv:2212.04356]"""
from ..models.lm import LMConfig
from .common import shrink

ARCH_ID = "whisper-tiny"
SKIP_SHAPES = {"long_500k": "full-attention enc-dec; 512k decoder cache is "
                            "out of scope per assignment (see DESIGN.md §6)"}


def config() -> LMConfig:
    return LMConfig(
        name=ARCH_ID, family="encdec",
        n_layers=4, n_enc_layers=4, d_model=384, n_heads=6, n_kv_heads=6,
        d_ff=1536, vocab=51865, head_dim=64,
        mlp_kind="gelu", norm="layer", n_frames=1500, tie_embeddings=True,
    ).validate()


def smoke_config() -> LMConfig:
    return shrink(config())
