"""granite-moe-1b-a400m [moe] — 32 experts top-8, tiny per-expert FFN.
[hf:ibm-granite/granite-3.0-1b-a400m-base; hf]"""
from ..models.lm import LMConfig
from .common import shrink

ARCH_ID = "granite-moe-1b-a400m"
SKIP_SHAPES = {"long_500k": "full-attention arch (MoE FFN does not change "
                            "the KV cache); skipped per assignment "
                            "(see DESIGN.md §6)"}


def config() -> LMConfig:
    return LMConfig(
        name=ARCH_ID, family="moe",
        n_layers=24, d_model=1024, n_heads=16, n_kv_heads=8,
        d_ff=512, vocab=49155, head_dim=64,
        mlp_kind="swiglu", rope_theta=10_000.0,
        n_experts=32, top_k=8, tie_embeddings=True,
    ).validate()


def smoke_config() -> LMConfig:
    return shrink(config(), n_experts=8, top_k=2)
