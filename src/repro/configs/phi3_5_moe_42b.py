"""phi3.5-moe-42b-a6.6b [moe] — 16 experts top-2.
[hf:microsoft/Phi-3.5-MoE-instruct; hf]"""
from ..models.lm import LMConfig
from .common import shrink

ARCH_ID = "phi3.5-moe-42b-a6.6b"
SKIP_SHAPES = {"long_500k": "full-attention arch (MoE FFN does not change "
                            "the KV cache); skipped per assignment "
                            "(see DESIGN.md §6)"}


def config() -> LMConfig:
    return LMConfig(
        name=ARCH_ID, family="moe",
        n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8,
        d_ff=6400, vocab=32064, head_dim=128,
        mlp_kind="swiglu", rope_theta=10_000.0,
        n_experts=16, top_k=2,
    ).validate()


def smoke_config() -> LMConfig:
    return shrink(config(), n_experts=4, top_k=2)
