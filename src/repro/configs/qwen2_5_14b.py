"""qwen2.5-14b [dense] — GQA, QKV bias.  [hf:Qwen/Qwen2.5-0.5B; hf]"""
from ..models.lm import LMConfig
from .common import shrink

ARCH_ID = "qwen2.5-14b"
SKIP_SHAPES = {"long_500k": "pure full-attention arch; 512k dense KV cache "
                            "is out of scope per assignment (see DESIGN.md §6)"}


def config() -> LMConfig:
    return LMConfig(
        name=ARCH_ID, family="dense",
        n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8,
        d_ff=13824, vocab=152064, head_dim=128,
        qkv_bias=True, mlp_kind="swiglu", rope_theta=1_000_000.0,
    ).validate()


def smoke_config() -> LMConfig:
    return shrink(config(), n_kv_heads=2)
