"""Architecture registry: ``get(arch_id)`` -> config module.

Each module exposes ``config()`` (the exact assigned configuration),
``smoke_config()`` (reduced same-family variant for CPU tests) and
``SKIP_SHAPES`` (shape_name -> reason, per the long_500k rule).
"""
from __future__ import annotations

from types import ModuleType
from typing import Dict, List

from . import (granite_moe_1b, minitron_4b, phi3_5_moe_42b, phi3_mini_3_8b,
               qwen2_5_14b, qwen2_vl_72b, qwen3_1_7b, recurrentgemma_9b,
               rwkv6_1_6b, whisper_tiny)
from .common import SHAPES, ShapeSpec, concrete_batch, input_specs, shrink

_MODULES = (qwen2_5_14b, qwen3_1_7b, phi3_mini_3_8b, minitron_4b,
            qwen2_vl_72b, granite_moe_1b, phi3_5_moe_42b, whisper_tiny,
            recurrentgemma_9b, rwkv6_1_6b)

ARCHS: Dict[str, ModuleType] = {m.ARCH_ID: m for m in _MODULES}


def get(arch_id: str) -> ModuleType:
    if arch_id not in ARCHS:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(ARCHS)}")
    return ARCHS[arch_id]


def arch_ids() -> List[str]:
    return list(ARCHS.keys())


def cells(include_skipped: bool = False):
    """All (arch_id, shape_name) dry-run cells; skipped cells annotated."""
    out = []
    for aid, mod in ARCHS.items():
        for sname in SHAPES:
            skip = mod.SKIP_SHAPES.get(sname)
            if skip is None or include_skipped:
                out.append((aid, sname, skip))
    return out


__all__ = ["ARCHS", "SHAPES", "ShapeSpec", "get", "arch_ids", "cells",
           "input_specs", "concrete_batch", "shrink"]
