"""qwen2-vl-72b [vlm] — M-RoPE, dynamic resolution (vision frontend is a STUB:
input_specs provides precomputed patch embeddings).  [arXiv:2409.12191; hf]"""
from ..models.lm import LMConfig
from .common import shrink

ARCH_ID = "qwen2-vl-72b"
SKIP_SHAPES = {"long_500k": "pure full-attention arch; 512k dense KV cache "
                            "(~336 GiB) is out of scope per assignment "
                            "(see DESIGN.md §6)"}


def config() -> LMConfig:
    return LMConfig(
        name=ARCH_ID, family="vlm",
        n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8,
        d_ff=29568, vocab=152064, head_dim=128,
        qkv_bias=True, mlp_kind="swiglu", rope_theta=1_000_000.0,
        mrope_sections=(16, 24, 24),       # temporal/h/w slots (sum = hd/2)
        n_patches=1024,                    # stub vision tokens per prompt
    ).validate()


def smoke_config() -> LMConfig:
    return shrink(config(), n_kv_heads=2)
