"""minitron-4b [dense] — pruned nemotron: squared-ReLU MLP (non-gated),
huge 256k vocab.  [arXiv:2407.14679; hf]"""
from ..models.lm import LMConfig
from .common import shrink

ARCH_ID = "minitron-4b"
SKIP_SHAPES = {"long_500k": "pure full-attention arch; 512k dense KV cache "
                            "is out of scope per assignment (see DESIGN.md §6)"}


def config() -> LMConfig:
    return LMConfig(
        name=ARCH_ID, family="dense",
        n_layers=32, d_model=3072, n_heads=24, n_kv_heads=8,
        d_ff=9216, vocab=256000, head_dim=128,
        mlp_kind="relu2", rope_theta=10_000.0,
    ).validate()


def smoke_config() -> LMConfig:
    return shrink(config(), n_kv_heads=2)
