"""recurrentgemma-9b [hybrid] — RG-LRU + local attention, 1:2 pattern
(layer i%3==2 is windowed attention, window 2048, MQA).  Sub-quadratic:
runs long_500k.  [arXiv:2402.19427]"""
from ..models.lm import LMConfig
from .common import shrink

ARCH_ID = "recurrentgemma-9b"
SKIP_SHAPES = {}            # RG-LRU state + 2048-window cache: long_500k OK


def config() -> LMConfig:
    return LMConfig(
        name=ARCH_ID, family="hybrid",
        n_layers=38, d_model=4096, n_heads=16, n_kv_heads=1,
        d_ff=12288, vocab=256000, head_dim=256,
        mlp_kind="geglu", rope_theta=10_000.0,
        attn_every=3, local_window=2048, conv_width=4,
        tie_embeddings=True,
    ).validate()


def smoke_config() -> LMConfig:
    return shrink(config())
