"""Shared config machinery: the four assigned input shapes, ShapeDtypeStruct
input specs per family (vision/audio frontends are stubs providing
precomputed embeddings), and the smoke-test reduction helper."""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..models.lm import LMConfig


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str                  # train | prefill | decode


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}


def input_specs(cfg: LMConfig, shape: ShapeSpec) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every model input (no allocation).

    train/prefill: the full-sequence batch (+labels for train).
    decode: one new token; the KV cache of ``seq_len`` is supplied by the
    serve-step builder via ``jax.eval_shape`` over ``init_cache``.
    """
    b, s = shape.global_batch, shape.seq_len
    i32 = jnp.int32

    def tok(bb, ss):
        return jax.ShapeDtypeStruct((bb, ss), i32)

    if shape.kind == "decode":
        return {"tokens": tok(b, 1)}

    batch: Dict[str, Any] = {}
    if cfg.family == "vlm":
        p = cfg.n_patches
        batch["tokens"] = tok(b, s - p)
        batch["embeds"] = jax.ShapeDtypeStruct((b, p, cfg.d_model), cfg.dtype)
        batch["positions"] = jax.ShapeDtypeStruct((3, b, s), i32)
    elif cfg.family == "encdec":
        batch["frames"] = jax.ShapeDtypeStruct((b, cfg.n_frames, cfg.d_model),
                                               cfg.dtype)
        batch["tokens"] = tok(b, s)
    else:
        batch["tokens"] = tok(b, s)
    if shape.kind == "train":
        batch["labels"] = tok(b, s)
    return batch


def concrete_batch(cfg: LMConfig, seq_len: int, batch: int,
                   key: Optional[jax.Array] = None,
                   kind: str = "train") -> Dict[str, jax.Array]:
    """Materialized (small) batch for smoke tests and examples."""
    key = key if key is not None else jax.random.PRNGKey(0)
    k1, k2 = jax.random.split(key)
    out: Dict[str, jax.Array] = {}
    if cfg.family == "vlm":
        p = cfg.n_patches
        out["tokens"] = jax.random.randint(k1, (batch, seq_len - p), 0,
                                           cfg.vocab, jnp.int32)
        out["embeds"] = jax.random.normal(k2, (batch, p, cfg.d_model),
                                          jnp.float32).astype(cfg.dtype)
        pos = jnp.broadcast_to(jnp.arange(seq_len)[None, None],
                               (3, batch, seq_len))
        out["positions"] = pos.astype(jnp.int32)
    elif cfg.family == "encdec":
        out["frames"] = jax.random.normal(
            k2, (batch, cfg.n_frames, cfg.d_model), jnp.float32
        ).astype(cfg.dtype)
        out["tokens"] = jax.random.randint(k1, (batch, seq_len), 0, cfg.vocab,
                                           jnp.int32)
    else:
        out["tokens"] = jax.random.randint(k1, (batch, seq_len), 0, cfg.vocab,
                                           jnp.int32)
    if kind == "train":
        out["labels"] = jax.random.randint(
            jax.random.fold_in(key, 7),
            out["tokens"].shape if cfg.family != "vlm"
            else (batch, seq_len), 0, cfg.vocab, jnp.int32)
    return out


def shrink(cfg: LMConfig, **over) -> LMConfig:
    """Reduced same-family config for CPU smoke tests."""
    d = dict(
        name=cfg.name + "-smoke",
        n_layers=min(cfg.n_layers, 4),
        d_model=64, d_ff=128, vocab=512,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 4) if cfg.n_kv_heads < cfg.n_heads else 4,
        head_dim=16,
        q_chunk=32,
        remat=False,
        dtype=jnp.float32,
    )
    if cfg.family == "moe":
        # capacity_factor high enough that smoke runs never drop tokens
        # (decode-vs-forward equivalence tests rely on no-drop routing)
        d.update(n_experts=4, top_k=min(cfg.top_k, 2), capacity_factor=8.0)
    if cfg.family == "vlm":
        d.update(mrope_sections=(4, 2, 2), n_patches=4)
    if cfg.family == "hybrid":
        d.update(n_layers=5, local_window=16, head_dim=16, n_kv_heads=1)
    if cfg.family == "encdec":
        d.update(n_enc_layers=2, n_layers=2, n_frames=12, n_kv_heads=4)
    if cfg.family == "ssm":
        d.update(rwkv_head_dim=16)
    d.update(over)
    return dataclasses.replace(cfg, **d).validate()
