"""phi3-mini-3.8b [dense] — RoPE SwiGLU, MHA (kv == heads).  [arXiv:2404.14219]"""
from ..models.lm import LMConfig
from .common import shrink

ARCH_ID = "phi3-mini-3.8b"
SKIP_SHAPES = {"long_500k": "pure full-attention arch; 512k dense KV cache "
                            "is out of scope per assignment (see DESIGN.md §6)"}


def config() -> LMConfig:
    return LMConfig(
        name=ARCH_ID, family="dense",
        n_layers=32, d_model=3072, n_heads=32, n_kv_heads=32,
        d_ff=8192, vocab=32064, head_dim=96,
        mlp_kind="swiglu", rope_theta=10_000.0,
    ).validate()


def smoke_config() -> LMConfig:
    return shrink(config(), n_kv_heads=4)
