"""rwkv6-1.6b [ssm] "Finch" — attention-free, data-dependent decay.
Constant-size WKV state: runs long_500k.  [arXiv:2404.05892]"""
from ..models.lm import LMConfig
from .common import shrink

ARCH_ID = "rwkv6-1.6b"
SKIP_SHAPES = {}            # O(1) state decode: long_500k OK


def config() -> LMConfig:
    return LMConfig(
        name=ARCH_ID, family="ssm",
        n_layers=24, d_model=2048, n_heads=32, n_kv_heads=32,
        d_ff=7168, vocab=65536, head_dim=64, rwkv_head_dim=64,
        mlp_kind="relu2", norm="layer",
    ).validate()


def smoke_config() -> LMConfig:
    return shrink(config())
