"""qwen3-1.7b [dense] — qk_norm, GQA.  [hf:Qwen/Qwen3-8B; hf]"""
from ..models.lm import LMConfig
from .common import shrink

ARCH_ID = "qwen3-1.7b"
SKIP_SHAPES = {"long_500k": "pure full-attention arch; 512k dense KV cache "
                            "is out of scope per assignment (see DESIGN.md §6)"}


def config() -> LMConfig:
    return LMConfig(
        name=ARCH_ID, family="dense",
        n_layers=28, d_model=2048, n_heads=16, n_kv_heads=8,
        d_ff=6144, vocab=151936, head_dim=128,
        qk_norm=True, mlp_kind="swiglu", rope_theta=1_000_000.0,
        tie_embeddings=True,
    ).validate()


def smoke_config() -> LMConfig:
    return shrink(config(), n_kv_heads=2)
