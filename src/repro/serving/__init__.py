from .server import MicroBatcher, PipelinedModelServer, Request

__all__ = ["Request", "MicroBatcher", "PipelinedModelServer"]
