from .server import (DeadlineExceeded, MicroBatcher, Overloaded,
                     PipelinedModelServer, Request, latency_percentiles)
from ..core.pipeline import PipelineStopped

__all__ = ["Request", "MicroBatcher", "PipelinedModelServer",
           "PipelineStopped", "latency_percentiles",
           "DeadlineExceeded", "Overloaded"]
