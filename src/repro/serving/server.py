"""Batched-request serving loop over the segmented pipeline.

This is the paper's deployment shape (§5.1): "it is common to have several
data sources gathering data at once that allow forming a small batch for
each read period (e.g., many cameras for object detection)".

* :class:`MicroBatcher` — gathers requests into a batch of up to
  ``max_batch``, waiting at most ``max_wait_s`` (latency bound).
* :class:`PipelinedModelServer` — a PlacementPlan + per-stage functions
  (from GraphModel.apply_subset or the LM stage executor), the host
  pipeline executor, optional straggler hedging, and an elastic hook: if a
  stage executor dies, the plan is re-derived for the surviving devices
  (ElasticPlanner) and serving continues.  Replicated stages in the plan
  (``replicas > 1``) map onto the executor's round-robin fan-out: the
  stage function is shared by k workers, so it must be thread-safe (jitted
  JAX callables are).
"""
from __future__ import annotations

import dataclasses
import itertools
import queue
import threading
import time
from typing import Any, Callable, Dict, List, Optional, Sequence

from ..core.pipeline import PipelineExecutor
from ..core.planner import PlacementPlan


@dataclasses.dataclass
class Request:
    rid: int
    payload: Any
    t_submit: float = dataclasses.field(default_factory=time.perf_counter)
    result: Any = None
    t_done: Optional[float] = None
    event: threading.Event = dataclasses.field(
        default_factory=threading.Event)

    @property
    def latency(self) -> float:
        return (self.t_done or time.perf_counter()) - self.t_submit


class MicroBatcher:
    def __init__(self, max_batch: int = 15, max_wait_s: float = 0.02):
        self.max_batch = max_batch
        self.max_wait_s = max_wait_s
        self.q: "queue.Queue[Request]" = queue.Queue()

    def submit(self, payload: Any, rid: Optional[int] = None) -> Request:
        req = Request(rid=rid if rid is not None else id(payload),
                      payload=payload)
        self.q.put(req)
        return req

    def next_batch(self, block: bool = True) -> List[Request]:
        batch: List[Request] = []
        try:
            batch.append(self.q.get(block=block, timeout=self.max_wait_s))
        except queue.Empty:
            return batch
        deadline = time.perf_counter() + self.max_wait_s
        while len(batch) < self.max_batch:
            remaining = deadline - time.perf_counter()
            if remaining <= 0:
                break
            try:
                batch.append(self.q.get(timeout=remaining))
            except queue.Empty:
                break
        return batch


class PipelinedModelServer:
    """Serve batched requests through the stage pipeline of a plan.

    Owns a *persistent* :class:`PipelineExecutor`: stage worker threads and
    queues are created once and reused for every batch, so the steady-state
    serving loop creates zero threads per batch.  Use as a context manager
    (or call :meth:`stop`) for a clean shutdown."""

    def __init__(self, plan: PlacementPlan,
                 stage_fns: Sequence[Callable[[Any], Any]],
                 max_batch: int = 15, max_wait_s: float = 0.02):
        assert len(stage_fns) == plan.n_stages
        self.plan = plan
        self.executor = PipelineExecutor(
            stage_fns, name=f"serve-{plan.graph_name}",
            replicas=getattr(plan, "replica_counts", None))
        self.batcher = MicroBatcher(max_batch, max_wait_s)
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None
        self.stats: Dict[str, Any] = {"batches": 0, "requests": 0,
                                      "stage_busy_s": [0.0] * plan.n_stages}

    def __enter__(self) -> "PipelinedModelServer":
        self.executor.start()
        return self

    def __exit__(self, *exc) -> None:
        self.stop()

    # -- synchronous API ------------------------------------------------------
    def serve_batch(self, payloads: Sequence[Any]) -> List[Any]:
        outs, busy = self.executor.run_batch(payloads,
                                             collect_stage_times=True)
        self.stats["batches"] += 1
        self.stats["requests"] += len(payloads)
        for i, b in enumerate(busy or []):
            self.stats["stage_busy_s"][i] += b
        return outs

    # -- background loop ----------------------------------------------------------
    def start(self) -> None:
        def loop():
            while not self._stop.is_set():
                batch = self.batcher.next_batch()
                if not batch:
                    continue
                outs = self.serve_batch([r.payload for r in batch])
                now = time.perf_counter()
                for req, out in zip(batch, outs):
                    req.result = out
                    req.t_done = now
                    req.event.set()
        self._thread = threading.Thread(target=loop, daemon=True)
        self._thread.start()

    def submit(self, payload: Any) -> Request:
        return self.batcher.submit(payload)

    def stop(self) -> None:
        """Stop the background loop and shut down the stage workers."""
        self._stop.set()
        if self._thread:
            self._thread.join(timeout=5)
            self._thread = None
        self.executor.stop()

    close = stop
