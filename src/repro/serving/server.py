"""Streaming request serving over the segmented pipeline.

This is the paper's deployment shape (§5.1): "it is common to have several
data sources gathering data at once that allow forming a small batch for
each read period (e.g., many cameras for object detection)" — extended from
batch-synchronous to *continuous admission*: requests flow from the batcher
straight into the executor's stream (``PipelineExecutor.submit``), so the
pipeline never drains and refills at a batch boundary and every stage stays
fed under load.

* :class:`MicroBatcher` — gathers requests into a batch of up to
  ``max_batch``, waiting at most ``max_wait_s`` from *entry* (latency
  bound).  Under the streaming server this bounds admission-loop wakeups,
  not pipeline occupancy: admitted requests overlap in flight regardless
  of which gather window they arrived in.
* :class:`PipelinedModelServer` — a PlacementPlan + per-stage functions
  (from GraphModel.apply_subset or the LM stage executor) over a persistent
  streaming executor.  An admission thread moves requests from the batcher
  into the stream; each request's future completes it individually
  (``Request.event`` / ``Request.result`` / ``Request.error``) with
  per-request latency recorded.  Busy-time and request accounting are
  monotonic counters; :meth:`PipelinedModelServer.snapshot` returns deltas
  (throughput, per-stage busy seconds, latency percentiles) since the last
  snapshot.  Replicated stages in the plan (``replicas > 1``) map onto the
  executor's round-robin fan-out — the stage function is shared by k
  workers, so it must be thread-safe (jitted JAX callables are) — and
  ``microbatch`` enables the executor's shape-bucketed dynamic
  micro-batching for accelerator stages.  The elastic hook
  (:meth:`reconfigure`, driven by ``runtime.ft.ElasticPlanner``) drains
  in-flight work and hot-swaps the plan + stage functions when the device
  pool resizes.

  Fault tolerance: within a replicated stage, replica death is absorbed
  by the executor (in-flight re-dispatch — requests never notice).  When
  a stage loses its *last* replica its requests fail fast as
  :class:`~repro.core.pipeline.StageLost`; with ``stage_loss_retries > 0``
  the server re-admits them through the batcher instead of failing them,
  so they are served by whatever plan is live once the degraded-mode
  replan (``runtime.ft.HealthMonitor`` → ``ElasticPlanner.resize_server``
  → :meth:`reconfigure`) lands.  ``hedge_after`` enables the executor's
  hedged dispatch on replicated stages.  Stage-lost events fan out to
  listeners registered via :meth:`add_stage_lost_listener` (re-wired
  automatically across reconfigure swaps).

  Overload protection (ISSUE 8): per-request **deadlines** — a request
  carries an absolute deadline (``deadline_ms`` server default, or per
  ``submit``); one that is already past due at admission, or whose result
  exits the merge after its deadline, is completed with
  :class:`DeadlineExceeded` instead of waiting (or returning) unbounded —
  a request is *never* silently stuck.  **Admission control** — with
  ``shed_policy="deadline"`` the admission loop estimates queue delay as
  ``executor.in_flight x pace`` (pace = EWMA of inter-completion gaps
  while the pipeline is saturated) and *sheds* a request whose estimated
  completion would outlive its deadline, completing it immediately with
  :class:`Overloaded` carrying a ``retry_after_s`` hint — jittered
  exponential backoff over consecutive sheds (seeded: deterministic in
  tests), reset on the first successful admission.  Shed/deadline counts
  ride the same monotonic stats stream (:meth:`snapshot` deltas).
"""
from __future__ import annotations

import dataclasses
import itertools
import math
import queue
import random
import threading
import time
from collections import deque
from typing import (Any, Callable, Dict, List, Optional, Sequence, Union)

from ..core.pipeline import PipelineExecutor, PipelineStopped, StageLost
from ..core.placement import PlacementPlan

# process-wide request ids: ``id(payload)`` collided when payload objects
# were reused (or GC'd and their addresses recycled) across requests
_RID = itertools.count()


class DeadlineExceeded(RuntimeError):
    """Completion error for a request that outlived its deadline — either
    already past due at admission (it sat in the batcher too long) or its
    result exited the merge after the deadline.  Either way the request
    *completes* (event set, error recorded); it is never silently stuck."""

    def __init__(self, rid: int, overshoot_s: float, where: str):
        super().__init__(f"request {rid} exceeded its deadline by "
                         f"{overshoot_s * 1e3:.1f} ms ({where})")
        self.rid = rid
        self.overshoot_s = overshoot_s
        self.where = where


class Overloaded(RuntimeError):
    """Completion error for a request shed at admission: the estimated
    queue delay would outlive its deadline budget.  Carries
    ``retry_after_s`` — a jittered exponential-backoff hint that grows
    with consecutive sheds, so synchronized callers spread their
    retries instead of stampeding the recovering server."""

    def __init__(self, rid: int, retry_after_s: float,
                 queue_delay_est_s: float):
        super().__init__(f"request {rid} shed at admission "
                         f"(queue-delay estimate "
                         f"{queue_delay_est_s * 1e3:.1f} ms past deadline); "
                         f"retry after {retry_after_s * 1e3:.0f} ms")
        self.rid = rid
        self.retry_after_s = retry_after_s
        self.queue_delay_est_s = queue_delay_est_s


@dataclasses.dataclass
class Request:
    rid: int
    payload: Any
    t_submit: float = dataclasses.field(default_factory=time.perf_counter)
    result: Any = None
    error: Optional[BaseException] = None
    retries: int = 0          # stage-loss re-admissions of this request
    t_done: Optional[float] = None
    deadline_s: Optional[float] = None    # absolute (perf_counter) deadline
    event: threading.Event = dataclasses.field(
        default_factory=threading.Event)
    # completion observer, invoked after the event is set (the fleet
    # router chains member-server completions back to its own requests
    # this way); must not block — it runs on the executor's collector
    on_done: Optional[Callable[["Request"], None]] = None

    @property
    def latency(self) -> float:
        return (self.t_done or time.perf_counter()) - self.t_submit


class MicroBatcher:
    def __init__(self, max_batch: int = 15, max_wait_s: float = 0.02):
        self.max_batch = max_batch
        self.max_wait_s = max_wait_s
        self.q: "queue.Queue[Request]" = queue.Queue()

    def submit(self, payload: Any, rid: Optional[int] = None,
               deadline_s: Optional[float] = None) -> Request:
        req = Request(rid=rid if rid is not None else next(_RID),
                      payload=payload)
        if deadline_s is not None:
            req.deadline_s = req.t_submit + deadline_s
        self.q.put(req)
        return req

    def next_batch(self, block: bool = True) -> List[Request]:
        # the deadline starts at entry: the wait for the *first* request
        # counts against it, so the worst case is max_wait_s, not 2x
        deadline = time.perf_counter() + self.max_wait_s
        batch: List[Request] = []
        try:
            batch.append(self.q.get(block=block, timeout=self.max_wait_s))
        except queue.Empty:
            return batch
        while len(batch) < self.max_batch:
            remaining = deadline - time.perf_counter()
            if remaining <= 0:
                break
            try:
                batch.append(self.q.get(timeout=remaining))
            except queue.Empty:
                break
        return batch


def latency_percentiles(latencies_s: Sequence[float]) -> Dict[str, float]:
    """p50/p95/p99 (+ mean/max) of a latency sample, in seconds.
    Empty samples yield an all-zero record."""
    if not latencies_s:
        return {"n": 0, "p50_s": 0.0, "p95_s": 0.0, "p99_s": 0.0,
                "mean_s": 0.0, "max_s": 0.0}
    xs = sorted(latencies_s)
    n = len(xs)

    def pct(p: float) -> float:
        # nearest-rank: smallest x with at least p*n samples <= x
        return xs[min(n - 1, max(0, math.ceil(p * n) - 1))]

    return {"n": n, "p50_s": pct(0.50), "p95_s": pct(0.95),
            "p99_s": pct(0.99), "mean_s": sum(xs) / n, "max_s": xs[-1]}


class PipelinedModelServer:
    """Serve a continuous request stream through the stage pipeline of a
    plan.

    Owns a *persistent streaming* :class:`PipelineExecutor`: stage worker
    threads and queues are created once; requests are admitted into the
    stream as they arrive (no inter-batch barrier) and completed
    individually by the executor's collector.  Use as a context manager
    (or call :meth:`stop`) for a clean shutdown — in-flight requests are
    then completed with :class:`PipelineStopped` rather than left hanging.
    """

    def __init__(self, plan: PlacementPlan,
                 stage_fns: Sequence[Callable[[Any], Any]],
                 max_batch: int = 15, max_wait_s: float = 0.02,
                 queue_size: int = 64,
                 microbatch: Optional[Union[int, Sequence[int]]] = None,
                 microbatch_wait_s: float = 0.0,
                 hedge_after: Optional[float] = None,
                 stage_loss_retries: int = 0,
                 latency_window: int = 4096,
                 deadline_s: Optional[float] = None,
                 shed_policy: str = "none",
                 backoff_base_s: float = 0.05,
                 backoff_max_s: float = 2.0,
                 backoff_seed: int = 0):
        assert len(stage_fns) == plan.n_stages
        if stage_loss_retries < 0:
            raise ValueError("stage_loss_retries must be >= 0")
        if deadline_s is not None and deadline_s <= 0:
            raise ValueError("deadline_s must be > 0 (or None)")
        if shed_policy not in ("none", "deadline"):
            raise ValueError(f"unknown shed_policy {shed_policy!r} "
                             f"(expected 'none' or 'deadline')")
        if backoff_base_s <= 0 or backoff_max_s < backoff_base_s:
            raise ValueError("need 0 < backoff_base_s <= backoff_max_s")
        self.plan = plan
        self.stage_fns = list(stage_fns)
        self.queue_size = queue_size
        self.microbatch = microbatch
        self.microbatch_wait_s = microbatch_wait_s
        self.hedge_after = hedge_after
        self.stage_loss_retries = stage_loss_retries
        self.deadline_s = deadline_s
        self.shed_policy = shed_policy
        self.backoff_base_s = backoff_base_s
        self.backoff_max_s = backoff_max_s
        # service pace: EWMA of inter-completion gaps observed while the
        # pipeline still holds work (saturated => gap == service pace);
        # queue-delay estimate for admission control = in_flight * pace
        self._pace_ewma: Optional[float] = None
        self._pace_alpha = 0.2
        self._last_done_t: Optional[float] = None
        self._consec_sheds = 0
        self._backoff_rng = random.Random(backoff_seed)
        self._stage_lost_listeners: List[Callable[[int], None]] = []
        self.executor = self._make_executor(plan, self.stage_fns)
        self.batcher = MicroBatcher(max_batch, max_wait_s)
        self._stop_evt = threading.Event()
        self._admission = threading.Lock()   # held to pause admission
        self._thread: Optional[threading.Thread] = None
        self._stopped = False
        # monotonic counters; read intervals via snapshot() deltas
        self.stats: Dict[str, Any] = {"batches": 0, "requests": 0,
                                      "admitted": 0,
                                      "completed": 0, "failed": 0,
                                      "retried": 0, "shed": 0,
                                      "deadline_exceeded": 0}
        self._stats_lock = threading.Lock()
        self._t_start = time.perf_counter()
        # executor item counters reset on reconfigure(); the lifetime
        # total rebases over the retired epochs so snapshot()'s ``totals``
        # block stays monotonic across hot-swaps
        self._items_epoch_base = 0
        self._recent_lat: deque = deque(maxlen=latency_window)
        self._window_lat: List[float] = []
        self._snap_state = {"t": time.perf_counter(),
                            "busy": self.executor.busy_snapshot(),
                            "items": self.executor.items_snapshot(),
                            "requests": 0, "completed": 0, "failed": 0,
                            "retried": 0, "shed": 0,
                            "deadline_exceeded": 0}

    def _make_executor(self, plan: PlacementPlan,
                       stage_fns: Sequence[Callable[[Any], Any]]
                       ) -> PipelineExecutor:
        ex = PipelineExecutor.for_plan(
            plan, stage_fns, queue_size=self.queue_size,
            microbatch=self.microbatch,
            microbatch_wait_s=self.microbatch_wait_s,
            hedge_after=self.hedge_after,
            name_prefix="serve")
        # every executor epoch (initial + each reconfigure swap) reports
        # stage losses to the same listeners (HealthMonitor et al.)
        ex.on_stage_lost = self._notify_stage_lost
        return ex

    def add_stage_lost_listener(self, cb: Callable[[int], None]) -> None:
        """Register an observer for last-replica-of-a-stage losses.
        Called from executor threads — observers must not block (enqueue
        and return; ``runtime.ft.HealthMonitor`` does exactly that)."""
        self._stage_lost_listeners.append(cb)

    def _notify_stage_lost(self, stage: int) -> None:
        for cb in list(self._stage_lost_listeners):
            try:
                cb(stage)
            except Exception:
                pass

    def __enter__(self) -> "PipelinedModelServer":
        self.executor.start()
        return self

    def __exit__(self, *exc) -> None:
        self.stop()

    # -- synchronous API ------------------------------------------------------
    def serve_batch(self, payloads: Sequence[Any]) -> List[Any]:
        """Admit a whole batch and wait for it (the paper's §5.1 camera
        read): outputs in submission order, first error re-raised after the
        batch drains.  Counts toward the same monotonic stats stream.
        Admission happens under the admission lock so a concurrent
        :meth:`reconfigure` cannot stop the executor under our feet; the
        wait happens outside it so the admission loop keeps flowing."""
        with self._admission:
            futures = [self.executor.submit(p) for p in payloads]
        with self._stats_lock:
            self.stats["admitted"] += len(futures)
        outputs: List[Any] = []
        errors: List[BaseException] = []
        done = 0
        for fut in futures:
            try:
                outputs.append(fut.result())
                done += 1
            except BaseException as e:
                errors.append(e)
        with self._stats_lock:
            self.stats["batches"] += 1
            self.stats["requests"] += len(payloads)
            self.stats["completed"] += done
            self.stats["failed"] += len(errors)
        if errors:
            raise errors[0]
        return outputs

    # -- streaming API -------------------------------------------------------
    def start(self) -> None:
        """Start the admission loop: requests flow from the batcher into
        the executor's stream as they arrive."""
        if self._thread is not None:
            return
        self._stop_evt.clear()

        def loop():
            while not self._stop_evt.is_set():
                batch = self.batcher.next_batch()
                if not batch:
                    continue
                with self._admission:
                    for req in batch:
                        self._admit(req)

        self._thread = threading.Thread(
            target=loop, daemon=True,
            name=f"serve-{self.plan.graph_name}-admit")
        self._thread.start()

    def submit(self, payload: Any,
               deadline_s: Optional[float] = None) -> Request:
        """Enqueue a request.  ``deadline_s`` is a relative budget from
        submit time (falls back to the server default); a request past its
        deadline completes with :class:`DeadlineExceeded`, never hangs."""
        budget = deadline_s if deadline_s is not None else self.deadline_s
        return self.batcher.submit(payload, deadline_s=budget)

    def _retry_after_s(self) -> float:
        """Jittered exponential backoff hint over consecutive sheds.
        Seeded rng => deterministic sequences in tests."""
        base = min(self.backoff_max_s,
                   self.backoff_base_s * (2.0 ** self._consec_sheds))
        return base * (1.0 + 0.25 * self._backoff_rng.random())

    def _admit(self, req: Request) -> None:
        now = time.perf_counter()
        if req.deadline_s is not None:
            if now >= req.deadline_s:
                # dead on arrival (sat in the batcher past its budget)
                self._finish(req, None, DeadlineExceeded(
                    req.rid, now - req.deadline_s, "admission"))
                return
            if (self.shed_policy == "deadline"
                    and self._pace_ewma is not None):
                est = self.executor.in_flight * self._pace_ewma
                if now + est > req.deadline_s:
                    retry_after = self._retry_after_s()
                    self._consec_sheds += 1
                    self._finish(req, None, Overloaded(
                        req.rid, retry_after, est))
                    return
        try:
            fut = self.executor.submit(req.payload)
        except RuntimeError as e:       # executor stopping under our feet
            self._finish(req, None, PipelineStopped(str(e)))
            return
        with self._stats_lock:
            self.stats["admitted"] += 1
        self._consec_sheds = 0          # admitted: reset backoff ladder
        fut.add_done_callback(
            lambda f, r=req: self._on_done(r, f))

    def _on_done(self, req: Request, fut) -> None:
        try:
            result = fut.result()
        except BaseException as e:
            # a request that crossed a dead stage is not lost: re-admit it
            # through the batcher so it is served by whatever plan is live
            # after the degraded-mode replan (reconfigure holds admission
            # while it swaps, so queued retries land on the new executor)
            if (isinstance(e, StageLost)
                    and req.retries < self.stage_loss_retries
                    and not self._stopped):
                req.retries += 1
                with self._stats_lock:
                    self.stats["retried"] += 1
                self.batcher.q.put(req)
                return
            self._finish(req, None, e)
            return
        if (req.deadline_s is not None
                and time.perf_counter() > req.deadline_s):
            # result arrived, but past due: complete with the deadline
            # error so the caller's wait is bounded and honest
            self._finish(req, None, DeadlineExceeded(
                req.rid, time.perf_counter() - req.deadline_s, "merge"))
            return
        self._finish(req, result, None)

    def _finish(self, req: Request, result: Any,
                error: Optional[BaseException]) -> None:
        req.result = result
        req.error = error
        req.t_done = time.perf_counter()
        lat = req.t_done - req.t_submit
        with self._stats_lock:
            self.stats["requests"] += 1
            if error is None:
                self.stats["completed"] += 1
                # pace signal: while the pipeline still holds work the gap
                # between completions is the service pace (saturated); an
                # idle-gap sample would poison the queue-delay estimate
                if (self._last_done_t is not None
                        and self.executor.in_flight > 0):
                    gap = req.t_done - self._last_done_t
                    if gap > 0:
                        self._pace_ewma = (
                            gap if self._pace_ewma is None else
                            self._pace_alpha * gap
                            + (1 - self._pace_alpha) * self._pace_ewma)
                self._last_done_t = req.t_done
            else:
                self.stats["failed"] += 1
                if isinstance(error, Overloaded):
                    self.stats["shed"] += 1
                elif isinstance(error, DeadlineExceeded):
                    self.stats["deadline_exceeded"] += 1
            if not isinstance(error, (Overloaded, DeadlineExceeded)):
                # shed/expired latencies are not service latencies
                self._recent_lat.append(lat)
                self._window_lat.append(lat)
        req.event.set()
        if req.on_done is not None:
            try:
                req.on_done(req)
            except Exception:
                pass            # an observer must never break completion

    # -- accounting ----------------------------------------------------------
    def snapshot(self) -> Dict[str, Any]:
        """Deltas since the previous snapshot: requests finished,
        throughput, per-stage busy seconds, and latency percentiles over
        the interval's completed requests.  Counters stay monotonic — this
        is the only reset-free way to watch a continuous stream.

        Taken under the admission lock so a concurrent :meth:`reconfigure`
        cannot swap the executor between reading its busy counters and
        rebasing ``_snap_state`` (which would yield negative deltas)."""
        with self._admission:
            return self._snapshot_locked()

    def _snapshot_locked(self) -> Dict[str, Any]:
        now = time.perf_counter()
        busy = self.executor.busy_snapshot()
        items = self.executor.items_snapshot()
        with self._stats_lock:
            window = self._window_lat
            self._window_lat = []
            requests = self.stats["requests"]
            admitted = self.stats["admitted"]
            completed = self.stats["completed"]
            failed = self.stats["failed"]
            retried = self.stats["retried"]
            shed = self.stats["shed"]
            deadline_exceeded = self.stats["deadline_exceeded"]
        prev = self._snap_state
        dt = now - prev["t"]
        done = requests - prev["requests"]
        busy_d = [b - a for a, b in zip(prev["busy"], busy)]
        items_d = [b - a for a, b in
                   zip(prev.get("items", items), items)]
        # every field below is neutral (0 / 0.0 / empty-sample record) on
        # an empty delta window — a zero-completion interval must never
        # crash or emit NaN (latency_percentiles handles the empty sample)
        snap = {
            "dt_s": dt,
            "requests": done,
            "completed": completed - prev.get("completed", 0),
            "failed": failed - prev["failed"],
            "retried": retried - prev.get("retried", 0),
            "shed": shed - prev.get("shed", 0),
            "deadline_exceeded": (deadline_exceeded
                                  - prev.get("deadline_exceeded", 0)),
            "throughput_rps": (done / dt) if dt > 0 else 0.0,
            "stage_busy_s": busy_d,
            "stage_items": items_d,
            # per-item observed stage time — the live-telemetry signal the
            # self-healing loop (runtime.selfheal) refits the cost model
            # from; 0.0 (not NaN) for stages that applied nothing
            "stage_time_per_req_s": [
                (b / n) if n > 0 else 0.0
                for b, n in zip(busy_d, items_d)],
            "queue_depth": self.batcher.q.qsize(),
            "in_flight": self.executor.in_flight,
            "latency": latency_percentiles(window),
            # lifetime view alongside the delta view: cumulative counters
            # since construction (server-level counters survive
            # reconfigure() by construction; the executor item total is
            # rebased across epochs).  The fleet autoscaler folds these
            # into SLO headroom; ops dashboards read them directly.
            "totals": {
                "admitted": admitted,
                "requests": requests,
                "completed": completed,
                "failed": failed,
                "retried": retried,
                "shed": shed,
                "deadline_exceeded": deadline_exceeded,
                "stage_items": self._items_epoch_base + sum(items),
                "uptime_s": now - self._t_start,
            },
        }
        self._snap_state = {"t": now, "busy": busy, "items": items,
                            "requests": requests, "completed": completed,
                            "failed": failed, "retried": retried,
                            "shed": shed,
                            "deadline_exceeded": deadline_exceeded}
        return snap

    # -- elastic hook --------------------------------------------------------
    def reconfigure(self, plan: PlacementPlan,
                    stage_fns: Sequence[Callable[[Any], Any]],
                    drain_timeout: float = 30.0) -> None:
        """Hot-swap the plan + stage functions (elastic resize): pause
        admission, let in-flight requests drain, then replace the executor.
        Requests still queued in the batcher are served by the new plan."""
        assert len(stage_fns) == plan.n_stages
        with self._admission:
            deadline = time.monotonic() + drain_timeout
            while (self.executor.in_flight
                   and time.monotonic() < deadline):
                time.sleep(0.001)
            # fold the retiring epoch's item counters into the lifetime
            # total before its counters are lost with the executor
            self._items_epoch_base += sum(self.executor.items_snapshot())
            self.executor.stop(
                timeout=max(0.1, deadline - time.monotonic()))
            self.plan = plan
            self.stage_fns = list(stage_fns)
            self.executor = self._make_executor(plan, self.stage_fns)
            self.executor.start()
            # rebase busy/items deltas onto the new executor's counters
            self._snap_state["busy"] = self.executor.busy_snapshot()
            self._snap_state["items"] = self.executor.items_snapshot()
            # the new plan invalidates the old service-pace signal
            self._pace_ewma = None
            self._last_done_t = None

    @property
    def stopped(self) -> bool:
        """True once :meth:`stop` ran — a lifecycle owner (e.g. the
        ``repro.api.Deployment`` handle) must treat this server as dead."""
        return self._stopped

    def stop(self) -> None:
        """Stop the admission loop and shut down the stage workers.
        In-flight requests complete with :class:`PipelineStopped`;
        never-admitted requests still waiting in the batcher do too."""
        self._stopped = True
        self._stop_evt.set()
        if self._thread:
            self._thread.join(timeout=5)
            self._thread = None
        self.executor.stop()
        while True:
            try:
                req = self.batcher.q.get_nowait()
            except queue.Empty:
                break
            self._finish(req, None,
                         PipelineStopped("server stopped before admission"))

    close = stop
