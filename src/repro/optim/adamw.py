"""Self-contained AdamW + schedule + clipping (no external deps).

Moments are fp32 regardless of param dtype (bf16-safe).  The update is a
pure pytree function so it jits/shards transparently with the train step;
optimizer state sharding follows parameter sharding (same tree structure).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

Params = Any


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


def cosine_warmup_schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(cfg.warmup_steps, 1)
    prog = (step - cfg.warmup_steps) / jnp.maximum(
        cfg.total_steps - cfg.warmup_steps, 1)
    prog = jnp.clip(prog, 0.0, 1.0)
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (
        1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * jnp.where(step < cfg.warmup_steps, warm, cos)


def clip_by_global_norm(grads: Params, max_norm: float
                        ) -> Tuple[Params, jax.Array]:
    leaves = jax.tree.leaves(grads)
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                         for l in leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gnorm, 1e-12))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale
                                   ).astype(g.dtype), grads), gnorm


def adamw_init(params: Params) -> Dict[str, Any]:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {"mu": jax.tree.map(zeros, params),
            "nu": jax.tree.map(zeros, params),
            "step": jnp.zeros((), jnp.int32)}


def adamw_update(cfg: AdamWConfig, params: Params, grads: Params,
                 state: Dict[str, Any]) -> Tuple[Params, Dict[str, Any],
                                                 Dict[str, jax.Array]]:
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    step = state["step"] + 1
    lr = cosine_warmup_schedule(cfg, step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, mu, nu):
        gf = g.astype(jnp.float32)
        mu = cfg.b1 * mu + (1 - cfg.b1) * gf
        nu = cfg.b2 * nu + (1 - cfg.b2) * gf * gf
        mhat = mu / b1c
        nhat = nu / b2c
        delta = mhat / (jnp.sqrt(nhat) + cfg.eps)
        pf = p.astype(jnp.float32)
        pf = pf - lr * (delta + cfg.weight_decay * pf)
        return pf.astype(p.dtype), mu, nu

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_mu = jax.tree.leaves(state["mu"])
    flat_nu = jax.tree.leaves(state["nu"])
    outs = [upd(p, g, m, n) for p, g, m, n
            in zip(flat_p, flat_g, flat_mu, flat_nu)]
    new_p = jax.tree.unflatten(treedef, [o[0] for o in outs])
    new_mu = jax.tree.unflatten(treedef, [o[1] for o in outs])
    new_nu = jax.tree.unflatten(treedef, [o[2] for o in outs])
    metrics = {"lr": lr, "grad_norm": gnorm}
    return new_p, {"mu": new_mu, "nu": new_nu, "step": step}, metrics
