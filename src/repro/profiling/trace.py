"""ProfileTrace: a persisted, versioned record of per-depth measured times.

The paper's segmentation is *profile-based*: per-layer inference times are
measured on the real device and drive the balanced cuts (§5).  This module
is the artifact side of that loop — a layer-granular profile captured by
:mod:`repro.profiling.profiler`, serialized to JSON, and consumed by the
:class:`~repro.profiling.sources.TraceCostSource` /
:class:`~repro.profiling.sources.CalibratedCostSource` planner inputs.

Schema stability rules (the document ships between machines and releases):

* ``format`` is ``repro.profile_trace/v1``; loaders accept any document
  whose major version matches (``repro.profile_trace/v1*``) and reject
  other formats loudly.
* Unknown fields — at the trace level and the per-sample level — are
  **ignored**, not errors: a newer profiler may annotate more columns and
  an older planner must still read the times (regression-tested in
  tests/test_profiling.py).
* ``from_json(to_json(trace))`` round-trips exactly (floats included).
"""
from __future__ import annotations

import dataclasses
import json
from typing import Dict, List, Optional, Sequence, Tuple

TRACE_FORMAT = "repro.profile_trace/v1"


def _known_fields(cls, doc: Dict) -> Dict:
    """Filter a document to the dataclass' declared fields (unknown-field
    tolerance: newer writers may add columns)."""
    names = {f.name for f in dataclasses.fields(cls)}
    return {k: v for k, v in doc.items() if k in names}


@dataclasses.dataclass(frozen=True)
class DepthSample:
    """One depth level's measurement: the trimmed-mean wall time of running
    every layer at that depth once, plus the static costs the calibration
    fit regresses against."""

    depth: int
    time_s: float
    layers: Tuple[str, ...] = ()
    params: int = 0
    macs: int = 0
    weight_bytes: int = 0
    act_bytes: int = 0          # activation bytes produced by the level
    low_intensity_macs: int = 0  # MACs in layers below the roofline knee
                                 # (MACs/act-byte < threshold: depthwise
                                 # convs, pooling — memory-bound regime)
    raw_times_s: Tuple[float, ...] = ()     # every repeat, for audit

    def to_dict(self) -> Dict:
        d = dataclasses.asdict(self)
        d["layers"] = list(self.layers)
        d["raw_times_s"] = list(self.raw_times_s)
        return d

    @classmethod
    def from_dict(cls, doc: Dict) -> "DepthSample":
        doc = _known_fields(cls, doc)
        doc["layers"] = tuple(doc.get("layers", ()))
        doc["raw_times_s"] = tuple(doc.get("raw_times_s", ()))
        return cls(**doc)


@dataclasses.dataclass(frozen=True)
class ProfileTrace:
    """A layer-granular profile of one model on one device.

    ``samples`` need not cover every depth of the graph — a partial trace
    is legal, and the cost sources fall back to the analytic model for
    unprofiled depths.
    """

    graph_name: str
    samples: Tuple[DepthSample, ...]
    device: str = "host-cpu"
    warmup: int = 0
    repeats: int = 1
    trim: float = 0.0
    batch: int = 1
    captured_unix_s: float = 0.0

    def __post_init__(self):
        object.__setattr__(self, "samples", tuple(self.samples))

    # -- queries -------------------------------------------------------------
    def depth_time_map(self) -> Dict[int, float]:
        return {s.depth: s.time_s for s in self.samples}

    @property
    def depths(self) -> Tuple[int, ...]:
        return tuple(s.depth for s in self.samples)

    @property
    def total_time_s(self) -> float:
        return sum(s.time_s for s in self.samples)

    def coverage(self, n_depths: int) -> float:
        """Fraction of ``n_depths`` depth levels the trace covers."""
        if n_depths <= 0:
            return 0.0
        covered = sum(1 for s in self.samples if 0 <= s.depth < n_depths)
        return covered / n_depths

    def stage_times(self, ranges: Sequence[Tuple[int, int]]
                    ) -> Optional[List[float]]:
        """Measured compute time per stage (sum of the stage's depth
        samples), or None when any stage touches an unprofiled depth —
        a partial trace cannot price a plan's stages honestly."""
        tmap = self.depth_time_map()
        out: List[float] = []
        for lo, hi in ranges:
            try:
                out.append(sum(tmap[d] for d in range(lo, hi + 1)))
            except KeyError:
                return None
        return out

    def describe(self) -> str:
        return (f"trace[{self.graph_name} @ {self.device}]: "
                f"{len(self.samples)} depths, "
                f"{self.total_time_s * 1e3:.2f} ms total, "
                f"{self.repeats} repeats (trim {self.trim})")

    # -- (de)serialization ---------------------------------------------------
    def to_dict(self) -> Dict:
        return {
            "format": TRACE_FORMAT,
            "graph_name": self.graph_name,
            "device": self.device,
            "warmup": self.warmup,
            "repeats": self.repeats,
            "trim": self.trim,
            "batch": self.batch,
            "captured_unix_s": self.captured_unix_s,
            "samples": [s.to_dict() for s in self.samples],
        }

    @classmethod
    def from_dict(cls, doc: Dict) -> "ProfileTrace":
        fmt = doc.get("format")
        if not isinstance(fmt, str) or not fmt.startswith(TRACE_FORMAT):
            raise ValueError(f"not a profile trace document: {fmt!r} "
                             f"(expected {TRACE_FORMAT})")
        body = _known_fields(cls, doc)
        body.pop("samples", None)
        samples = tuple(DepthSample.from_dict(s)
                        for s in doc.get("samples", ()))
        return cls(samples=samples, **{k: v for k, v in body.items()
                                       if k != "samples"})

    def to_json(self, indent: Optional[int] = 1) -> str:
        return json.dumps(self.to_dict(), indent=indent)

    @classmethod
    def from_json(cls, text: str) -> "ProfileTrace":
        return cls.from_dict(json.loads(text))

    def save(self, path: str) -> str:
        with open(path, "w") as f:
            f.write(self.to_json())
        return path

    @classmethod
    def load(cls, path: str) -> "ProfileTrace":
        with open(path) as f:
            return cls.from_json(f.read())
