"""Layer-granular profiler: run the JAX model depth-by-depth and time it.

This plays the role the real Edge TPU + ``perf`` harness plays in the paper
(§5): measure per-layer inference time on the executing device, so the
planner can balance *measured* stage times instead of the analytic model's
prediction.  The unit of measurement is one **depth level** — the same
granularity the horizontal-cut segmentation operates on (§6.1.1), so the
trace maps 1:1 onto the planner's per-depth cost arrays.

Method: one forward pass records the boundary activations entering every
depth level; each level is then re-executed in isolation through
``GraphModel.apply_subset`` (the exact code path the pipelined executor
runs per stage) under ``time.perf_counter``, with ``warmup`` discarded
runs, ``repeats`` timed runs, and a trimmed mean over the repeats
(``jax.block_until_ready`` fences every run — async dispatch would
otherwise attribute one level's work to the next).
"""
from __future__ import annotations

import math
import time
from typing import Optional, Sequence

import jax

from .trace import DepthSample, ProfileTrace


def trimmed_mean(values: Sequence[float], trim: float = 0.2) -> float:
    """Mean of ``values`` with ``floor(trim * n)`` dropped from each end —
    robust to the scheduler hiccups that plague short wall-clock timings."""
    if not values:
        raise ValueError("trimmed_mean of no values")
    vals = sorted(values)
    k = int(math.floor(trim * len(vals)))
    kept = vals[k:len(vals) - k] or [vals[len(vals) // 2]]
    return sum(kept) / len(kept)


# roofline knee separating the compute-bound from the memory-bound layer
# regime: a 3x3 depthwise conv produces ~9 MACs per activation byte, a
# pointwise conv ~its channel count — devices execute the two regimes at
# very different MAC rates, so the calibration fits them separately
LOW_INTENSITY_MACS_PER_BYTE = 32.0


def profile_model(model, *, warmup: int = 1, repeats: int = 5,
                  trim: float = 0.2, batch: int = 1, seed: int = 0,
                  device: Optional[str] = None,
                  stamp_time: bool = True) -> ProfileTrace:
    """Capture a :class:`ProfileTrace` of a ``GraphModel``.

    ``model`` is any :class:`repro.models.layers.GraphModel` (the CNN zoo
    and the synthetic family both build one).  Parameters are initialized
    fresh from ``seed`` — the profile measures op time, which is
    weight-value independent.
    """
    if repeats < 1:
        raise ValueError(f"repeats must be >= 1, got {repeats}")
    graph = model.to_layer_graph()
    levels = graph.levels()
    params_pd = graph.params_per_depth()
    macs_pd = graph.macs_per_depth()
    bytes_pd = graph.bytes_per_depth()
    act_pd = [sum(graph.nodes[n].out_bytes for n in lvl) for lvl in levels]
    low_pd = [sum(graph.nodes[n].macs for n in lvl
                  if graph.nodes[n].macs <= LOW_INTENSITY_MACS_PER_BYTE
                  * max(1, graph.nodes[n].out_bytes))
              for lvl in levels]

    params = model.init(jax.random.PRNGKey(seed))
    x = jax.random.normal(jax.random.PRNGKey(seed + 1),
                          (batch,) + tuple(model.input_shape))

    # one recording pass: the boundary activations entering each depth
    # level, pruned to the inputs that level actually consumes (keeping a
    # full snapshot per level would pin every earlier activation for the
    # whole run on deep models)
    acts = {model.INPUT: x}
    boundaries = []
    for lvl in levels:
        need = {i for n in lvl for i in model.nodes[n].inputs}
        boundaries.append({k: acts[k] for k in need if k in acts})
        outs = model.apply_subset(params, acts, lvl)
        acts.update(outs)
    jax.block_until_ready(boundaries)
    acts = None

    samples = []
    for d, lvl in enumerate(levels):
        boundary = boundaries[d]

        def run_level():
            out = model.apply_subset(params, boundary, lvl)
            jax.block_until_ready(out)
            return out

        for _ in range(warmup):
            run_level()
        times = []
        for _ in range(repeats):
            t0 = time.perf_counter()
            run_level()
            times.append(time.perf_counter() - t0)
        samples.append(DepthSample(
            depth=d, time_s=trimmed_mean(times, trim),
            layers=tuple(lvl), params=params_pd[d], macs=macs_pd[d],
            weight_bytes=bytes_pd[d], act_bytes=act_pd[d],
            low_intensity_macs=low_pd[d], raw_times_s=tuple(times)))

    dev = device or jax.devices()[0].platform
    return ProfileTrace(
        graph_name=graph.name, samples=tuple(samples), device=dev,
        warmup=warmup, repeats=repeats, trim=trim, batch=batch,
        captured_unix_s=time.time() if stamp_time else 0.0)
