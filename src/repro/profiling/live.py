"""LiveTraceBuilder: fold serving telemetry into a rolling ProfileTrace.

PR 5's profile -> calibrate -> plan workflow is offline and file-based
(``trace:<path>``): a :mod:`repro.profiling.profiler` run on an idle device
produces the artifact the planner consumes.  A *serving* pipeline measures
the same quantity for free — the executor's monotonic busy/items counters
give an observed per-item time for every stage of the live plan — but at
stage granularity, not the per-depth granularity the cost sources need.

This module closes that gap.  A :class:`LiveTraceBuilder` precomputes the
graph's static per-depth costs (MACs, weight bytes, activation bytes,
low-intensity MACs — exactly the columns the offline profiler records)
and, on every telemetry window, **apportions** each stage's observed
per-item time across the depth levels the stage spans, proportionally to
the analytic model's per-depth time share.  The analytic model's *shape*
within a stage is the best available prior (relative layer weights); its
*scale* is exactly what the observation corrects.  Per-depth estimates are
EWMA-smoothed across windows, and :meth:`trace` emits a standard
:class:`~repro.profiling.trace.ProfileTrace` over the covered depths —
partial coverage is legal, unprofiled depths fall back to analytic, and
:meth:`cost_source` wraps the current trace in a
:class:`~repro.profiling.sources.CalibratedCostSource` (structural
extrapolation to depths no live stage has visited yet) ready to hand to
``plan(..., cost_source=...)``.

This is the telemetry half of the self-healing loop
(:mod:`repro.runtime.selfheal`): observe -> refit -> replan -> canary.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

from ..core.edge_tpu_model import EdgeTPUSpec
from ..core.graph import LayerGraph
from .sources import (CalibratedCostSource, CostSource, TraceCostSource,
                      _analytic_depth_time)
from .trace import DepthSample, ProfileTrace

# same roofline knee the offline profiler uses (profiler.py): layers with
# fewer MACs per produced activation byte than this are memory-bound
LOW_INTENSITY_MACS_PER_BYTE = 32.0


class LiveTraceBuilder:
    """Accumulate observed per-stage times into per-depth estimates.

    ``alpha`` is the EWMA smoothing factor per depth (the first
    observation seats the estimate directly, so a cold builder converges
    in one window).  ``observe`` is cheap — O(depth) per window — and
    thread-compatible with the self-healing controller's single-writer
    discipline (one controller thread calls it; ``trace()`` copies).
    """

    def __init__(self, graph: LayerGraph,
                 reference_spec: Optional[EdgeTPUSpec] = None,
                 alpha: float = 0.25, device: str = "live"):
        if not 0.0 < alpha <= 1.0:
            raise ValueError("alpha must be in (0, 1]")
        self.graph = graph
        self.reference_spec = reference_spec or EdgeTPUSpec()
        self.alpha = alpha
        self.device = device
        # static per-depth cost columns, exactly as the offline profiler
        # records them (profiler.profile_model)
        levels = graph.levels()
        self._layers = [tuple(lvl) for lvl in levels]
        self._params = list(graph.params_per_depth())
        self._macs = list(graph.macs_per_depth())
        self._weight_bytes = list(graph.bytes_per_depth())
        self._act_bytes = [sum(graph.nodes[n].out_bytes for n in lvl)
                           for lvl in levels]
        self._low_macs = [sum(graph.nodes[n].macs for n in lvl
                              if graph.nodes[n].macs
                              <= LOW_INTENSITY_MACS_PER_BYTE
                              * max(1, graph.nodes[n].out_bytes))
                          for lvl in levels]
        # analytic per-depth time: the apportioning prior (shape within a
        # stage); scale comes from the observation
        self._prior = [_analytic_depth_time(self._macs[d],
                                            self._weight_bytes[d],
                                            self.reference_spec)
                       for d in range(graph.depth)]
        self._est: Dict[int, float] = {}    # depth -> EWMA'd time_s
        self.windows = 0                    # observe() calls that landed

    # -- ingestion -----------------------------------------------------------
    def observe(self, stage_ranges: Sequence[Tuple[int, int]],
                stage_time_per_item_s: Sequence[float],
                stage_items: Optional[Sequence[int]] = None) -> int:
        """Fold one telemetry window in.  ``stage_ranges`` are the live
        plan's inclusive ``(lo, hi)`` depth ranges;
        ``stage_time_per_item_s`` the window's observed per-item stage
        times (``snapshot()['stage_time_per_req_s']``).  Stages with no
        signal (0.0 per-item time, or 0 items when ``stage_items`` is
        given) are skipped — an empty window teaches nothing.  Returns the
        number of depth levels updated."""
        assert len(stage_ranges) == len(stage_time_per_item_s)
        updated = 0
        for i, ((lo, hi), t_item) in enumerate(
                zip(stage_ranges, stage_time_per_item_s)):
            if t_item <= 0.0:
                continue
            if stage_items is not None and stage_items[i] <= 0:
                continue
            prior = [max(self._prior[d], 1e-12)
                     for d in range(lo, hi + 1)]
            total = sum(prior)
            for d, p in zip(range(lo, hi + 1), prior):
                obs = t_item * (p / total)
                old = self._est.get(d)
                self._est[d] = (obs if old is None
                                else self.alpha * obs
                                + (1 - self.alpha) * old)
                updated += 1
        if updated:
            self.windows += 1
        return updated

    # -- queries -------------------------------------------------------------
    def coverage(self) -> float:
        """Fraction of the graph's depth levels with a live estimate."""
        return len(self._est) / max(1, self.graph.depth)

    def depth_time(self, depth: int) -> Optional[float]:
        return self._est.get(depth)

    def trace(self) -> ProfileTrace:
        """The current estimates as a standard (partial) ProfileTrace —
        consumable by every trace-backed cost source, saveable for
        offline audit."""
        samples = tuple(
            DepthSample(depth=d, time_s=self._est[d],
                        layers=self._layers[d],
                        params=self._params[d], macs=self._macs[d],
                        weight_bytes=self._weight_bytes[d],
                        act_bytes=self._act_bytes[d],
                        low_intensity_macs=self._low_macs[d])
            for d in sorted(self._est))
        return ProfileTrace(graph_name=self.graph.name, samples=samples,
                            device=self.device, repeats=self.windows)

    def cost_source(self, kind: str = "calibrated") -> CostSource:
        """The current trace wrapped as a planner-ready cost source.
        ``calibrated`` (default) refits the analytic coefficients — it
        extrapolates structurally to depths no live stage has covered;
        ``trace`` prices covered depths raw with analytic fallback."""
        tr = self.trace()
        if kind == "calibrated":
            return CalibratedCostSource(
                tr, reference_spec=self.reference_spec)
        if kind == "trace":
            return TraceCostSource(tr, reference_spec=self.reference_spec)
        raise ValueError(f"unknown live cost-source kind {kind!r}")
