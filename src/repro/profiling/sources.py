"""CostSource: the pluggable per-depth cost API behind the planner.

Every planning strategy prices candidate segments through a
:class:`~repro.core.cost_engine.SegmentCostEngine`.  The engine used to
reach into ``LayerGraph``'s cost formulas directly — one hard-wired
analytic MACs/params model.  A :class:`CostSource` is the seam that
replaces it: a source materializes, once per (graph, device spec), the
per-depth arrays the engine's O(1) prefix-sum machinery consumes
(:class:`DepthCosts`), and answers per-depth point queries
(:meth:`CostSource.layer_time_s`, :meth:`CostSource.layer_params`,
activation / host-transfer bytes) for direct consumers.

Three implementations:

* :class:`AnalyticCostSource` — today's closed-form model.  It returns
  ``time_s=None``, telling the engine to keep its exact legacy arithmetic
  (segment MAC/byte sums divided by spec rates, in the same float order),
  so plans are **bit-identical** to the pre-CostSource planner — asserted
  over all 21 Table-1 models in tests/test_profiling.py.
* :class:`TraceCostSource` — measured per-depth times from a persisted
  :class:`~repro.profiling.trace.ProfileTrace` (the paper's profile-based
  planning); unprofiled depths fall back to the analytic prediction.
* :class:`CalibratedCostSource` — the analytic model with its per-device
  coefficients re-fit against a trace by least squares
  (:mod:`repro.profiling.calibrate`): keeps the analytic form (so it
  extrapolates structurally) but matches the measured magnitudes.

Device scaling: a trace measures ONE device.  When the engine prices a
different :class:`~repro.core.topology.DeviceSpec` (heterogeneous
topologies), measured times scale by the ratio of the reference spec's MAC
rate to the target's — ``compute_scale=2`` halves measured times, exactly
as it doubles the analytic rate.  A reference device (scale 1.0) applies
no float op at all, keeping homogeneous plans bit-stable.

Spec syntax (``DeploymentSpec.cost_source``): ``"analytic"`` (default),
``"trace:<path>"``, ``"calibrated:<path>"`` — resolved by
:func:`resolve_cost_source`.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Sequence, Tuple

from ..core.edge_tpu_model import EdgeTPUSpec
from ..core.graph import LayerGraph
from .calibrate import CalibrationFit, fit_trace
from .trace import ProfileTrace


@dataclasses.dataclass(frozen=True)
class DepthCosts:
    """Per-depth arrays a :class:`SegmentCostEngine` materializes once.

    ``time_s is None`` means "no measured times: use the closed-form
    analytic expression over the integer arrays" — the bit-identical
    legacy path.  When ``time_s`` is given, ``weight_load_s`` must be too
    (the non-amortizing replication term).

    ``state_bytes`` is the decode regime's third axis (ISSUE 10): per-depth
    *per-sequence* steady-state bytes a depth level pins on-device while a
    sequence is in flight — KV cache for attention blocks (a function of
    context length), O(1) recurrent state for rwkv6/rglru blocks, zero for
    stateless levels.  ``None`` (every prefill/batch source) keeps the
    engine's state queries inert."""

    params: Sequence[int]
    macs: Sequence[int]
    weight_bytes: Sequence[int]
    cut_bytes: Sequence[int]
    time_s: Optional[Sequence[float]] = None
    weight_load_s: Optional[Sequence[float]] = None
    state_bytes: Optional[Sequence[int]] = None


def _analytic_depth_time(macs: int, weight_bytes: int,
                         spec: EdgeTPUSpec) -> float:
    """The analytic model's compute+weight-load time of one depth level."""
    return (macs / spec.macs_per_s
            + weight_bytes / (spec.weight_load_gbps * 1e9))


class CostSource:
    """Base class / protocol.  Subclasses override :meth:`materialize`;
    the per-depth point queries are derived from it."""

    name: str = "abstract"

    def materialize(self, graph: LayerGraph, spec: EdgeTPUSpec
                    ) -> DepthCosts:
        raise NotImplementedError

    def _cached_costs(self, graph: LayerGraph, spec) -> DepthCosts:
        """One-entry materialization memo (identity-keyed) so the per-depth
        point queries below are O(1) per call instead of re-running the
        O(depth) materialize per depth."""
        hit = getattr(self, "_dc_cache", None)
        if hit is not None and hit[0] is graph and hit[1] is spec:
            return hit[2]
        dc = self.materialize(graph, spec)
        self._dc_cache = (graph, spec, dc)
        return dc

    # -- per-depth point queries (protocol surface) --------------------------
    def layer_time_s(self, depth: int, graph: LayerGraph,
                     spec: EdgeTPUSpec) -> float:
        """Modeled/measured compute time of one depth level on the device
        ``spec`` describes (transfer terms excluded — those depend on the
        segment, not the layer)."""
        dc = self._cached_costs(graph, spec)
        if dc.time_s is not None:
            return dc.time_s[depth]
        return _analytic_depth_time(dc.macs[depth], dc.weight_bytes[depth],
                                    spec)

    def layer_params(self, depth: int, graph: LayerGraph) -> int:
        return graph.params_per_depth()[depth]

    def layer_weight_bytes(self, depth: int, graph: LayerGraph) -> int:
        return graph.bytes_per_depth()[depth]

    def activation_bytes(self, depth: int, graph: LayerGraph) -> int:
        """Host-transfer bytes crossing a cut placed after ``depth``."""
        return graph.out_bytes_per_depth()[depth]

    def describe(self) -> str:
        return self.name


class AnalyticCostSource(CostSource):
    """The closed-form model — wraps today's formulas exactly.

    ``materialize`` hands the engine the graph's own per-depth integer
    arrays (the very same cached list objects) and no measured times, so
    the engine's arithmetic — and therefore every plan — is bit-identical
    to the pre-CostSource code."""

    name = "analytic"

    def materialize(self, graph: LayerGraph, spec: EdgeTPUSpec
                    ) -> DepthCosts:
        return DepthCosts(
            params=graph.params_per_depth(),
            macs=graph.macs_per_depth(),
            weight_bytes=graph.bytes_per_depth(),
            cut_bytes=graph.out_bytes_per_depth(),
            time_s=None, weight_load_s=None)


class _TraceBackedSource(CostSource):
    """Shared machinery: per-depth measured/predicted times with analytic
    fallback for unprofiled depths + device scaling."""

    def __init__(self, trace: ProfileTrace,
                 reference_spec: Optional[EdgeTPUSpec] = None):
        self.trace = trace
        self.reference_spec = reference_spec or EdgeTPUSpec()

    def _predicted_time(self, depth: int) -> Optional[float]:
        """Time for a profiled depth on the reference device, or None when
        the trace does not cover it."""
        raise NotImplementedError

    def _scale_for(self, spec: EdgeTPUSpec) -> float:
        ref = self.reference_spec
        if spec.macs_per_s == ref.macs_per_s:
            return 1.0
        return ref.macs_per_s / spec.macs_per_s

    def materialize(self, graph: LayerGraph, spec: EdgeTPUSpec
                    ) -> DepthCosts:
        macs_pd = graph.macs_per_depth()
        bytes_pd = graph.bytes_per_depth()
        scale = self._scale_for(spec)
        wl_rate = spec.weight_load_gbps * 1e9
        times = []
        wloads = []
        for d in range(graph.depth):
            t = self._predicted_time(d)
            if t is None:            # unprofiled depth: analytic fallback
                t = _analytic_depth_time(macs_pd[d], bytes_pd[d], spec)
            elif scale != 1.0:
                t = t * scale
            # the weight-load fraction (non-amortizing under replication)
            # is the analytic fill-rate term, clamped to the measured
            # total — a replica cannot spend longer loading weights than
            # the whole level measured
            wloads.append(min(t, bytes_pd[d] / wl_rate))
            times.append(t)
        return DepthCosts(
            params=graph.params_per_depth(), macs=macs_pd,
            weight_bytes=bytes_pd, cut_bytes=graph.out_bytes_per_depth(),
            time_s=times, weight_load_s=wloads)


class TraceCostSource(_TraceBackedSource):
    """Plan from raw measured per-depth times (the paper's SEGM_PROF /
    SEGM_BALANCED profiling loop, with a persisted artifact standing in
    for the live device)."""

    name = "trace"

    def __init__(self, trace: ProfileTrace,
                 reference_spec: Optional[EdgeTPUSpec] = None):
        super().__init__(trace, reference_spec)
        self._times = trace.depth_time_map()

    def _predicted_time(self, depth: int) -> Optional[float]:
        return self._times.get(depth)

    def describe(self) -> str:
        return f"trace({self.trace.graph_name} @ {self.trace.device})"


class CalibratedCostSource(_TraceBackedSource):
    """The analytic model with coefficients least-squares-fit to a trace.

    Falls back to the *uncalibrated* analytic prediction when the trace is
    too small to fit (< 2 samples) and for unprofiled depths.  The fit is
    deterministic: the same trace always yields the same coefficients
    (and therefore the same plans)."""

    name = "calibrated"

    def __init__(self, trace: ProfileTrace,
                 reference_spec: Optional[EdgeTPUSpec] = None):
        super().__init__(trace, reference_spec)
        from .calibrate import cliff_bytes_per_depth
        ref = self.reference_spec
        capacity = ref.onchip_bytes - ref.fixed_reserve
        try:
            self.fit: Optional[CalibrationFit] = fit_trace(
                trace, capacity_bytes=capacity)
        except ValueError:
            self.fit = None
        self._sample_by_depth = {s.depth: s for s in trace.samples}
        # the cliff regressor, positioned exactly as fit_trace saw it —
        # prediction must apply every coefficient the fit solved for
        ordered = sorted(trace.samples, key=lambda s: s.depth)
        cliffs = cliff_bytes_per_depth(
            tuple(s.weight_bytes for s in ordered), capacity)
        self._cliff_by_depth = {s.depth: c
                                for s, c in zip(ordered, cliffs)}

    def _predicted_time(self, depth: int) -> Optional[float]:
        if self.fit is None:
            return None
        s = self._sample_by_depth.get(depth)
        if s is None:
            return None
        return self.fit.predict(s.macs, s.weight_bytes, s.act_bytes,
                                cliff_bytes=self._cliff_by_depth[depth],
                                low_intensity_macs=s.low_intensity_macs)

    def coefficients(self) -> Dict:
        return {} if self.fit is None else self.fit.to_dict()

    def describe(self) -> str:
        tag = "unfit" if self.fit is None else (
            f"mac_s={self.fit.mac_s:.3e}, "
            f"load={self.fit.load_s_per_byte:.3e} s/B, "
            f"fix={self.fit.fixed_s:.3e} s")
        return f"calibrated({self.trace.graph_name}: {tag})"


# ---------------------------------------------------------------------------
# spec-string resolution
# ---------------------------------------------------------------------------
COST_SOURCE_KINDS = ("analytic", "trace", "calibrated")


def parse_cost_source(ref: str) -> Tuple[str, Optional[str]]:
    """``"analytic"`` / ``"trace:<path>"`` / ``"calibrated:<path>"`` ->
    (kind, path).  Raises ValueError on malformed refs (shared by
    DeploymentSpec validation, so bad specs fail at construction)."""
    kind, _, path = ref.partition(":")
    if kind == "analytic":
        if path:
            raise ValueError(f"'analytic' cost source takes no argument, "
                             f"got {ref!r}")
        return kind, None
    if kind in ("trace", "calibrated"):
        if not path:
            raise ValueError(f"cost source {ref!r} needs a trace path: "
                             f"'{kind}:<path>'")
        return kind, path
    raise ValueError(f"unknown cost source {ref!r}; expected 'analytic', "
                     f"'trace:<path>' or 'calibrated:<path>'")


def resolve_cost_source(ref: str,
                        reference_spec: Optional[EdgeTPUSpec] = None
                        ) -> CostSource:
    """Turn a ``DeploymentSpec.cost_source`` string into a live source
    (loading the trace artifact for the trace-backed kinds)."""
    kind, path = parse_cost_source(ref)
    if kind == "analytic":
        return AnalyticCostSource()
    trace = ProfileTrace.load(path)
    cls = TraceCostSource if kind == "trace" else CalibratedCostSource
    return cls(trace, reference_spec=reference_spec)
