"""Least-squares calibration of the analytic cost model against a trace.

The analytic Edge TPU model predicts a depth level's compute time as a
linear form in the level's static costs::

    t(d) = macs(d) * c_mac + low_intensity_macs(d) * c_low
           + weight_bytes(d) * c_load + act_bytes(d) * c_act
           + cliff_bytes(d) * c_cliff + c_fix

* ``c_mac`` — seconds per MAC (the inverse sustained MAC rate);
* ``c_low`` — *extra* seconds per MAC in layers below the roofline knee
  (depthwise convs, pooling: few MACs per activation byte, executed at a
  far lower rate — a single MAC rate is exactly what Seshadri et al.
  show mispredicting on the Edge TPU, and XLA-CPU behaves the same way);
* ``c_load`` — seconds per weight byte (systolic-array fill / streaming);
* ``c_act`` — seconds per activation byte produced (memory traffic of
  the layer's output);
* ``c_cliff`` — *extra* seconds per weight byte past the on-chip-memory
  cliff (Seshadri et al., PAPERS.md: layer times jump by large factors
  once cumulative weights exceed on-chip capacity and spill to host —
  ``cliff_bytes(d)`` is the portion of depth ``d``'s weights beyond that
  capacity under the whole-model greedy placement);
* ``c_fix`` — fixed per-level dispatch overhead.

:func:`fit_trace` solves for the coefficients by least squares over the
trace's samples, with negative coefficients clamped to zero and the system
re-solved without them (physical rates cannot be negative; the iteration
is deterministic, so the same trace always yields the same fit — asserted
in tests/test_profiling.py).  The rows are weighted by ``1 / time`` so the
solver minimizes *relative* residuals: per-layer times span orders of
magnitude within one model, and an unweighted fit buys accuracy on the
few big layers by over-predicting the many small ones — exactly the
mean-relative-stage-error metric the calibration exists to reduce.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import numpy as np

MIB = 1024 * 1024


@dataclasses.dataclass(frozen=True)
class CalibrationFit:
    """Fitted per-device coefficients of the analytic time model."""

    mac_s: float            # seconds per MAC (compute-bound regime)
    low_mac_s: float        # EXTRA seconds per MAC below the roofline
                            # knee (depthwise/pooling: memory-bound)
    load_s_per_byte: float  # seconds per on-chip weight byte
    act_s_per_byte: float   # seconds per activation byte produced
    cliff_s_per_byte: float  # extra seconds per byte past the memory cliff
    fixed_s: float          # per-depth-level fixed overhead
    n_samples: int
    residual_rms_s: float

    @property
    def macs_per_s(self) -> float:
        return 1.0 / self.mac_s if self.mac_s > 0 else float("inf")

    @property
    def weight_load_gbps(self) -> float:
        return (1.0 / (self.load_s_per_byte * 1e9)
                if self.load_s_per_byte > 0 else float("inf"))

    def predict(self, macs: int, weight_bytes: int, act_bytes: int = 0,
                cliff_bytes: int = 0, low_intensity_macs: int = 0) -> float:
        return (macs * self.mac_s
                + low_intensity_macs * self.low_mac_s
                + weight_bytes * self.load_s_per_byte
                + act_bytes * self.act_s_per_byte
                + cliff_bytes * self.cliff_s_per_byte + self.fixed_s)

    def to_dict(self) -> Dict:
        return dataclasses.asdict(self)


def cliff_bytes_per_depth(weight_bytes: Tuple[int, ...],
                          capacity_bytes: int) -> Tuple[int, ...]:
    """Portion of each depth's weight bytes past ``capacity_bytes`` when
    depths are placed greedily in order (the on-chip-memory cliff of the
    whole-model placement).

    Caveat: callers pass the weights of the depths they *have* — for a
    partial trace the accumulation skips the unprofiled depths' weights,
    placing the cliff later than the full model would.  Full-coverage
    traces (what the profiler captures) are exact."""
    out = []
    cum = 0
    for b in weight_bytes:
        below = max(0, min(b, capacity_bytes - cum))
        out.append(b - below)
        cum += b
    return tuple(out)


def fit_trace(trace, capacity_bytes: Optional[int] = None
              ) -> CalibrationFit:
    """Fit the four coefficients to a :class:`ProfileTrace`.

    ``capacity_bytes`` is the on-chip weight capacity used to locate the
    cliff (default: the reference Edge TPU's 8 MiB minus the fixed
    reserve).  Raises ValueError on traces with fewer than 2 samples —
    a single point cannot constrain a rate.
    """
    samples = sorted(trace.samples, key=lambda s: s.depth)
    if len(samples) < 2:
        raise ValueError(f"calibration needs >= 2 trace samples, "
                         f"got {len(samples)}")
    if capacity_bytes is None:
        capacity_bytes = 8 * MIB - int(0.1 * MIB)
    bytes_pd = tuple(s.weight_bytes for s in samples)
    cliff = cliff_bytes_per_depth(bytes_pd, capacity_bytes)
    X = np.array([[s.macs, s.low_intensity_macs, s.weight_bytes,
                   s.act_bytes, c, 1.0]
                  for s, c in zip(samples, cliff)], dtype=np.float64)
    y = np.array([s.time_s for s in samples], dtype=np.float64)
    # relative-error weighting: scale each row by 1/time so small levels
    # count as much as big ones (guarded against zero-time samples)
    w = 1.0 / np.maximum(y, 1e-12)
    Xw = X * w[:, None]
    yw = y * w

    # non-negative least squares via deterministic clamp-and-refit: solve,
    # drop the most-negative column, repeat (at most 4 rounds)
    active = list(range(X.shape[1]))
    coef = np.zeros(X.shape[1])
    while active:
        sol, *_ = np.linalg.lstsq(Xw[:, active], yw, rcond=None)
        neg = [(v, c) for v, c in zip(sol, active) if v < 0.0]
        if not neg:
            coef[:] = 0.0
            for v, c in zip(sol, active):
                coef[c] = v
            break
        worst = min(neg)[1]           # most negative coefficient
        active.remove(worst)
    resid = y - X @ coef
    rms = float(np.sqrt(np.mean(resid * resid)))
    return CalibrationFit(
        mac_s=float(coef[0]), low_mac_s=float(coef[1]),
        load_s_per_byte=float(coef[2]), act_s_per_byte=float(coef[3]),
        cliff_s_per_byte=float(coef[4]), fixed_s=float(coef[5]),
        n_samples=len(samples), residual_rms_s=rms)
