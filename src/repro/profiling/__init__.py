"""Profiling, trace, and calibration: the pluggable cost layer.

The paper's segmentation is profile-based — measured per-layer times on
the real device drive the balanced cuts.  This package provides that loop
for the reproduction:

* :func:`profile_model` — run a ``GraphModel`` depth-by-depth under
  ``time.perf_counter`` (warmup / repeats / trimmed mean) and capture a
  versioned, JSON-persisted :class:`ProfileTrace`;
* :class:`CostSource` and its three implementations
  (:class:`AnalyticCostSource`, :class:`TraceCostSource`,
  :class:`CalibratedCostSource`) — the seam the
  :class:`~repro.core.cost_engine.SegmentCostEngine` prices segments
  through, selected per-deployment via ``DeploymentSpec.cost_source``
  (``"analytic"`` / ``"trace:<path>"`` / ``"calibrated:<path>"``);
* :func:`fit_trace` — least-squares calibration of the analytic model's
  per-device coefficients against a trace;
* :class:`LiveTraceBuilder` — the online variant: fold serving telemetry
  (observed per-stage per-item times) into a rolling partial trace and a
  continuously-refit calibrated source, the feedback half of the
  self-healing loop (:mod:`repro.runtime.selfheal`).

See EXPERIMENTS.md §Profiling & calibration for the capture -> calibrate
-> plan workflow.
"""
from .calibrate import CalibrationFit, cliff_bytes_per_depth, fit_trace
from .live import LiveTraceBuilder
from .sources import (AnalyticCostSource, CalibratedCostSource, CostSource,
                      DepthCosts, TraceCostSource, parse_cost_source,
                      resolve_cost_source)
from .trace import TRACE_FORMAT, DepthSample, ProfileTrace


def __getattr__(name):
    # the profiler runs real JAX forwards; import it lazily so spec
    # validation / trace-backed planning stay jax-free
    if name in ("profile_model", "trimmed_mean"):
        from . import profiler
        return getattr(profiler, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")

__all__ = [
    "ProfileTrace", "DepthSample", "TRACE_FORMAT",
    "profile_model", "trimmed_mean",
    "CostSource", "DepthCosts", "AnalyticCostSource", "TraceCostSource",
    "CalibratedCostSource", "parse_cost_source", "resolve_cost_source",
    "CalibrationFit", "fit_trace", "cliff_bytes_per_depth",
    "LiveTraceBuilder",
]
