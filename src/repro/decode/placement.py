"""KV-cache-aware decode placement: maximize steady-state tokens/s.

The paper's strategies balance static weights (or prefill time) against
on-chip memory.  At a decode operating point the binding constraint moves:
every attention layer a stage holds pins ``concurrency x context x KV-row``
bytes of cache on-device, and whatever the cache displaces from the weight
budget must be re-streamed over PCIe each step.  ``decode_placement``
prices both effects on the existing minimax DP skeleton:

* a segment whose KV (at the operating point) exceeds the on-chip budget
  is **infeasible** (cost = inf) — the per-stage KV cap;
* a feasible segment's cost is one decode *step* of the whole running
  batch (``DecodeCostSource`` time) plus PCIe streaming of the weights
  the KV displaced from on-chip capacity;
* the DP minimizes the max stage cost — steady-state tokens/s is
  ``concurrency / max_stage_step_time``, so minimax *is* the tokens/s
  maximizer — and the result is compared against the weight-balanced
  (Algorithm 1) cuts priced under the same decode cost, keeping the
  ``opt``-style hard never-worse guarantee.

The plan carries a ``decode_info`` dict (per-stage KV bytes, caps,
headroom, modeled tokens/s) that ``repro.api.plan`` folds into the
:class:`~repro.api.report.PlanReport`.
"""
from __future__ import annotations

import math
from typing import Dict, List, Optional, Tuple

from ..core.edge_tpu_model import EdgeTPUModel, EdgeTPUSpec
from ..core.placement import PlacementPlan
from ..core.segmentation import (balanced_split, minimax_time_split,
                                 segment_ranges)
from ..models.lm import LMConfig
from .costing import DecodeCostSource, DecodeOperatingPoint

# defaults when the spec leaves the operating point open
DEFAULT_CONCURRENCY = 4
DEFAULT_MAX_CONTEXT = 256

# families the *runtime* decode engine executes (scan-block KV decode);
# planning covers every family — recurrent ones as O(1)-state blocks
DECODE_FAMILIES = ("dense", "moe", "vlm")


def decode_config_for(model: Optional[str]) -> LMConfig:
    """Resolve a spec's ``lm:`` ref to its smoke LMConfig, with an
    actionable error for anything else."""
    if model is None or not model.startswith("lm:"):
        raise ValueError(
            f"decode placement needs an 'lm:<arch>' model ref (the decode "
            f"cost regime is derived from the LM config: KV heads, head "
            f"dim, window, family); got {model!r}. Pick an arch from "
            f"repro.configs.arch_ids(), e.g. model='lm:qwen3-1.7b'")
    from .. import configs
    arch = model[len("lm:"):].partition(":")[0]
    return configs.get(arch).smoke_config()


def operating_point(spec) -> DecodeOperatingPoint:
    """The (concurrency, max_context) point a spec asks to be planned at
    (falling back to the module defaults)."""
    return DecodeOperatingPoint(
        concurrency=spec.decode_concurrency or DEFAULT_CONCURRENCY,
        max_context=spec.max_context or DEFAULT_MAX_CONTEXT)


def kv_budget_bytes(base: EdgeTPUSpec) -> int:
    """On-chip bytes a stage may spend on decode state."""
    return base.onchip_bytes - base.fixed_reserve


def max_feasible_concurrency(engine, cuts: List[int],
                             base: EdgeTPUSpec) -> int:
    """Largest concurrency the plan's stages can hold at the engine's
    operating context (KV cap only; 0 means even one sequence spills)."""
    budget = kv_budget_bytes(base)
    out = math.inf
    for lo, hi in segment_ranges(engine.depth, cuts):
        per_seq = engine.segment_state_bytes(lo, hi)
        if per_seq > 0:
            out = min(out, budget // per_seq)
    return int(out) if out is not math.inf else 2 ** 30


def step_cost_fn(engine, base: EdgeTPUSpec, point: DecodeOperatingPoint):
    """The decode stage-cost model: one step of the whole running batch
    over a segment, inf past the KV cap.  Shared by the strategy's DP and
    the benchmark's weight-balanced baseline (both price under the *same*
    cost, so the comparison is apples to apples)."""
    budget = kv_budget_bytes(base)
    n = point.concurrency
    pcie = base.pcie_gbps * 1e9

    def stage_cost(lo: int, hi: int) -> float:
        kv = n * engine.segment_state_bytes(lo, hi)
        if kv > budget:
            return math.inf          # per-stage KV cap
        t = engine.segment_time(lo, hi)
        # KV displaces weights from on-chip capacity: whatever the greedy
        # placement kept on-device past the shrunken budget is
        # re-streamed every step
        dev, host = engine.segment_split(lo, hi)
        allowed = max(0, engine.segment_capacity(lo, hi) - kv)
        extra = max(0, dev - allowed)
        if extra > 0:
            t += extra / pcie
            if host == 0:
                t += base.spill_event_overhead_s
        return t

    return stage_cost


def _register() -> None:
    """Register the strategy (deferred: repro.api.strategies imports the
    spec module, so a module-level import here would cycle through
    repro.api.__init__)."""
    from ..api.strategies import PlanStrategy, register_strategy

    @register_strategy("decode_placement")
    class DecodePlacementStrategy(PlanStrategy):
        objective = "max_decode_tokens_per_s"

        def plan(self, ctx) -> PlacementPlan:
            spec = ctx.spec
            cfg = decode_config_for(spec.model)
            point = operating_point(spec)
            base = ctx.device_base_spec() or EdgeTPUSpec()
            src = DecodeCostSource(cfg, point)
            model = EdgeTPUModel(ctx.graph, base, cost_source=src)
            eng = model.engine
            depth = ctx.graph.depth
            budget = kv_budget_bytes(base)
            n = point.concurrency
            stage_cost = step_cost_fn(eng, base, point)

            s = spec.stages
            if s is None:
                topo = spec.resolved_topology()
                s = topo.n_devices if topo is not None else None
            if s is None:
                # auto: smallest stage count whose best split fits the
                # KV cap (decode's analog of the §5.2.2 no-spill rule)
                for cand in range(1, depth + 1):
                    cuts = minimax_time_split(depth, cand, stage_cost,
                                              exact=True)
                    if max(stage_cost(lo, hi) for lo, hi
                           in segment_ranges(depth, cuts)) < math.inf:
                        s = cand
                        break
                else:
                    s = depth
            else:
                cuts = minimax_time_split(depth, s, stage_cost,
                                          exact=True)

            costs = [stage_cost(lo, hi)
                     for lo, hi in segment_ranges(depth, cuts)]
            if max(costs) == math.inf:
                raise ValueError(
                    f"no feasible decode placement for {cfg.name} at "
                    f"concurrency={n}, max_context={point.max_context} "
                    f"with {s} stages (some stage's KV exceeds the "
                    f"{budget} byte on-chip budget); add stages, lower "
                    f"decode_concurrency, or lower max_context")

            # hard guarantee: never worse than the weight-balanced cuts
            # priced under the same decode cost (the bench baseline)
            bal = balanced_split(ctx.graph.params_per_depth(), s)
            bal_costs = [stage_cost(lo, hi)
                         for lo, hi in segment_ranges(depth, bal)]
            if max(bal_costs) < max(costs):
                cuts, costs = bal, bal_costs

            pl = PlacementPlan.from_cuts(
                ctx.graph, cuts, strategy="decode_placement",
                tpu_model=model)
            pl.decode_info = decode_info(eng, cuts, point, base, costs)
            return pl


def decode_info(engine, cuts: List[int], point: DecodeOperatingPoint,
                base: EdgeTPUSpec,
                stage_costs: Optional[List[float]] = None) -> Dict:
    """The decode columns of a plan's report: per-stage KV at the
    operating point, the cap, headroom, and modeled steady-state
    tokens/s."""
    budget = kv_budget_bytes(base)
    ranges = segment_ranges(engine.depth, cuts)
    kv = [point.concurrency * engine.segment_state_bytes(lo, hi)
          for lo, hi in ranges]
    if stage_costs is None:
        stage_costs = [engine.segment_time(lo, hi) for lo, hi in ranges]
    pace = max(stage_costs)
    tps = (point.concurrency / pace
           if pace > 0 and pace != math.inf else 0.0)
    headroom = min((budget - b) / budget * 100.0 for b in kv)
    return {
        "decode_tokens_per_s": tps,
        "decode_concurrency": point.concurrency,
        "decode_max_context": point.max_context,
        "stage_kv_bytes": tuple(kv),
        "stage_kv_cap_bytes": tuple([budget] * len(kv)),
        "kv_headroom_pct": headroom,
    }
