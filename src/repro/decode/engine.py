"""Pipelined decode-batch execution for the scan-block LM families.

:class:`PipelineDecodeEngine` runs the continuous decode batch through the
paper's host-threaded :class:`~repro.core.pipeline.PipelineExecutor`, one
stage per plan segment.  Each stage owns its blocks' K/V caches, laid out
``(n_blocks_stage, n_slots, max_context, n_kv_heads, head_dim)`` — slot
``i`` is sequence ``i`` of the running batch, so admission/eviction is
just the scheduler re-using a slot index; no cache shuffling.

Two payload ops travel the stream:

* ``prefill`` — one prompt (B=1, full-sequence causal attention) writes
  its post-RoPE K/V rows into slot ``i`` of every block cache and returns
  the first greedy token from the last position;
* ``step`` — one decode step of *all* slots at once with a per-slot
  context vector: positions ``ctx-1``, a one-hot masked cache write at
  each slot's own ring position (``ctx=0`` slots match nothing and stay
  untouched), and per-slot attention masks via ``decode_attention``'s
  broadcastable ``cache_len``.  Inactive slots compute garbage that is
  never read — fixed shapes keep one jit trace for the whole serve.

FIFO-per-stage ordering is what makes the scheduler's prefill-join sound:
a prefill submitted before the next step reaches each stage's cache
before that step reads it.

The reference semantics are ``repro.models.lm.forward_decode`` fed one
token at a time (tests pin exact greedy-token equality at B=1).
"""
from __future__ import annotations

import threading
from typing import Any, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from ..core.pipeline import PipelineExecutor
from ..models import attention as A
from ..models import lm
from .costing import _itemsize
from .placement import DECODE_FAMILIES
from .scheduler import DecodeScheduler


class PipelineDecodeEngine:
    """The running decode batch over a staged dense/MoE/VLM LM."""

    def __init__(self, cfg: lm.LMConfig, params: Dict[str, Any], *,
                 n_slots: int, max_context: int,
                 stage_blocks: Optional[Sequence[int]] = None,
                 queue_size: int = 8):
        if cfg.family not in DECODE_FAMILIES:
            raise ValueError(
                f"PipelineDecodeEngine supports the scan-block attention "
                f"families {DECODE_FAMILIES}; {cfg.name} is "
                f"family={cfg.family!r}")
        if n_slots < 1:
            raise ValueError(f"n_slots must be >= 1, got {n_slots}")
        if max_context < 2:
            raise ValueError(f"max_context must be >= 2, got {max_context}")
        self.cfg = cfg
        self.params = params
        self.n_slots = int(n_slots)
        self.max_context = int(max_context)
        if stage_blocks is None:
            stage_blocks = [cfg.n_layers]
        if sum(stage_blocks) != cfg.n_layers:
            raise ValueError(f"stage_blocks {list(stage_blocks)} must sum "
                             f"to n_layers={cfg.n_layers}")
        self.stage_blocks = [int(b) for b in stage_blocks]
        self._lock = threading.Lock()   # serialize prefill/step submitters
        fns = []
        lo = 0
        for si, nb in enumerate(self.stage_blocks):
            fns.append(self._build_stage(si, lo, lo + nb))
            lo += nb
        self.pipe = PipelineExecutor(fns, queue_size=queue_size,
                                     name=f"decode-{cfg.name}")

    # bytes one generated token adds across every layer's K+V cache —
    # the scheduler's per-slot KV-occupancy unit
    @property
    def kv_bytes_per_token(self) -> int:
        c = self.cfg
        return c.n_layers * 2 * c.n_kv_heads * c.hd * _itemsize(c.dtype)

    # -- stage construction ---------------------------------------------------
    def _build_stage(self, si: int, lo: int, hi: int):
        cfg = self.cfg
        first = si == 0
        last = si == len(self.stage_blocks) - 1
        bp = jax.tree.map(lambda x: x[lo:hi], self.params["blocks"])
        extras: Dict[str, Any] = {}
        if first or (last and cfg.tie_embeddings):
            extras["embed"] = self.params["embed"]
        if last:
            extras["final_norm"] = self.params["final_norm"]
            if not cfg.tie_embeddings:
                extras["head"] = self.params["head"]
        t = self.max_context
        shape = (hi - lo, self.n_slots, t, cfg.n_kv_heads, cfg.hd)
        cache = [jnp.zeros(shape, cfg.dtype), jnp.zeros(shape, cfg.dtype)]

        def positions(pos):
            if cfg.family == "vlm":
                return jnp.broadcast_to(pos[None], (3,) + pos.shape)
            return pos

        def block(blk, h, q, k, v, attend):
            out = attend(q, k, v)
            b, s = h.shape[:2]
            h = h + out.reshape(b, s, cfg.q_dim) @ blk["attn"]["wo"]
            return h + lm.mlp_block(cfg, blk["mlp"],
                                    lm._norm(cfg, blk["ln2"], h))

        def prefill_impl(bp, extras, kc, vc, x, slot):
            h = (lm.embed_tokens(cfg, extras, x) if first else x)
            n = h.shape[1]
            pos = positions(jnp.arange(n)[None, :])

            def body(h, xs):
                blk, kci, vci = xs
                q, k, v = lm._qkv(cfg, blk["attn"],
                                  lm._norm(cfg, blk["ln1"], h))
                q, k = lm._rope_qk(cfg, q, k, pos)
                h = block(blk, h, q, k, v,
                          lambda q, k, v: A.full_attention(q, k, v,
                                                           causal=True))
                # the slot's prompt rows, post-RoPE (what decode reads)
                kci = jax.lax.dynamic_update_slice(
                    kci, k.astype(kci.dtype), (slot, 0, 0, 0))
                vci = jax.lax.dynamic_update_slice(
                    vci, v.astype(vci.dtype), (slot, 0, 0, 0))
                return h, (kci, vci)

            h, (kc, vc) = jax.lax.scan(body, h, (bp, kc, vc))
            if last:
                logits = lm.unembed(cfg, extras, h[:, -1:])
                return jnp.argmax(logits[:, -1, :], axis=-1), kc, vc
            return h, kc, vc

        def step_impl(bp, extras, kc, vc, x, ctx):
            h = (lm.embed_tokens(cfg, extras, x) if first else x)
            pos = positions(jnp.clip(ctx - 1, 0)[:, None])
            slotpos = ctx - 1                     # ctx=0 slots match nothing
            hit = (jnp.arange(t)[None, :]
                   == slotpos[:, None])[:, :, None, None]

            def body(h, xs):
                blk, kci, vci = xs
                q, k, v = lm._qkv(cfg, blk["attn"],
                                  lm._norm(cfg, blk["ln1"], h))
                q, k = lm._rope_qk(cfg, q, k, pos)
                kci = jnp.where(hit, k.astype(kci.dtype), kci)
                vci = jnp.where(hit, v.astype(vci.dtype), vci)
                h = block(blk, h, q, kci, vci,
                          lambda q, kc_, vc_: A.decode_attention(
                              q, kc_, vc_, ctx[:, None]))
                return h, (kci, vci)

            h, (kc, vc) = jax.lax.scan(body, h, (bp, kc, vc))
            if last:
                logits = lm.unembed(cfg, extras, h)
                return jnp.argmax(logits[:, -1, :], axis=-1), kc, vc
            return h, kc, vc

        jit_prefill = jax.jit(prefill_impl)
        jit_step = jax.jit(step_impl)

        def stage(payload):
            op = payload[0]
            if op == "prefill":
                _, slot, x = payload
                out, cache[0], cache[1] = jit_prefill(
                    bp, extras, cache[0], cache[1], x,
                    jnp.asarray(slot, jnp.int32))
                if last:
                    return ("token", np.asarray(out))
                return ("prefill", slot, out)
            if op == "step":
                _, x, ctx = payload
                out, cache[0], cache[1] = jit_step(
                    bp, extras, cache[0], cache[1], x,
                    jnp.asarray(ctx, jnp.int32))
                if last:
                    return ("tokens", np.asarray(out))
                return ("step", out, ctx)
            raise ValueError(f"unknown decode payload op {op!r}")

        return stage

    # -- lifecycle ------------------------------------------------------------
    def start(self) -> "PipelineDecodeEngine":
        self.pipe.start()
        return self

    def stop(self) -> None:
        self.pipe.stop()

    def __enter__(self) -> "PipelineDecodeEngine":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    # -- scheduler protocol ---------------------------------------------------
    def prefill(self, slot: int, prompt: np.ndarray) -> int:
        """Write the prompt's KV into ``slot``; return the first greedy
        token."""
        prompt = np.asarray(prompt, np.int32).reshape(1, -1)
        if not (0 <= slot < self.n_slots):
            raise ValueError(f"slot {slot} out of range 0..{self.n_slots-1}")
        if prompt.shape[1] >= self.max_context:
            raise ValueError(f"prompt of {prompt.shape[1]} tokens leaves no "
                             f"room in max_context={self.max_context}")
        with self._lock:
            fut = self.pipe.submit(("prefill", int(slot), prompt))
        op, tok = fut.result()
        return int(tok[0])

    def step(self, slots: Sequence[int], ctx_lens: Sequence[int],
             last_tokens: Sequence[int]) -> List[int]:
        """One decode step of the listed slots (the rest idle in-batch);
        returns their next greedy tokens in the same order."""
        tokens = np.zeros((self.n_slots, 1), np.int32)
        ctx = np.zeros((self.n_slots,), np.int32)
        for s, c, tk in zip(slots, ctx_lens, last_tokens):
            if not (2 <= c <= self.max_context):
                raise ValueError(f"slot {s}: context {c} outside "
                                 f"2..{self.max_context}")
            tokens[s, 0] = tk
            ctx[s] = c
        with self._lock:
            fut = self.pipe.submit(("step", tokens, ctx))
        op, out = fut.result()
        return [int(out[s]) for s in slots]


class DecodeServer:
    """Engine + scheduler lifecycle bundle — what ``Deployment.serve``
    returns for ``workload="decode"``.  ``submit`` streams tokens via the
    returned :class:`~repro.decode.scheduler.DecodeRequest`."""

    def __init__(self, engine: PipelineDecodeEngine,
                 scheduler: DecodeScheduler):
        self.engine = engine
        self.scheduler = scheduler

    def start(self) -> "DecodeServer":
        self.engine.start()
        self.scheduler.start()
        return self

    def stop(self, drain: bool = True) -> None:
        self.scheduler.stop(drain=drain)
        self.engine.stop()

    def __enter__(self) -> "DecodeServer":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    def submit(self, prompt, max_new_tokens: Optional[int] = None):
        return self.scheduler.submit(prompt, max_new_tokens)

    def snapshot(self) -> Dict[str, Any]:
        return self.scheduler.snapshot()


def build_decode_server(spec, plan=None, params=None,
                        seed: int = 0, **scheduler_kw) -> DecodeServer:
    """Wire a :class:`DecodeServer` from a deployment spec (+ optionally
    its plan, whose stage cuts become pipeline stages).  ``params=None``
    draws fresh smoke weights."""
    from .placement import decode_config_for, operating_point
    cfg = decode_config_for(spec.model)
    if cfg.family not in DECODE_FAMILIES:
        raise ValueError(
            f"decode serving runs the scan-block attention families "
            f"{DECODE_FAMILIES}; {cfg.name} is family={cfg.family!r} "
            f"(recurrent/enc-dec families plan with 'decode_placement' "
            f"but have no continuous-batching engine yet)")
    point = operating_point(spec)
    if params is None:
        params = lm.init_params(cfg, jax.random.PRNGKey(seed))
    stage_blocks = None
    if plan is not None:
        from ..launch.pipeline_spmd import stage_block_counts
        stage_blocks = stage_block_counts(plan, cfg.n_layers)
    engine = PipelineDecodeEngine(cfg, params,
                                  n_slots=point.concurrency,
                                  max_context=point.max_context,
                                  stage_blocks=stage_blocks)
    sched = DecodeScheduler(engine, max_context=point.max_context,
                            **scheduler_kw)
    return DecodeServer(engine, sched)
