"""Continuous batching for autoregressive decode.

Sequential serving decodes one request to completion before admitting the
next — every step runs at batch 1 and the pipeline idles between
requests.  :class:`DecodeScheduler` keeps one *running decode batch* over
a fixed set of slots and:

* **admits at token boundaries** — between engine steps, pending prompts
  are prefilled into free slots and join the very next step (no drain, no
  batch barrier);
* **evicts finished sequences** (token budget or EOS) immediately, so a
  freed slot is refilled at the next boundary;
* **tracks per-slot KV occupancy** (context length x the engine's
  per-token KV bytes) — :meth:`snapshot` exposes it;
* **sheds at the KV cap**: slots *are* the planned KV budget
  (``decode_concurrency`` at ``max_context``); when every slot is busy
  requests queue, and when the queue is full they complete immediately
  with :class:`~repro.serving.server.Overloaded` carrying the PR-8
  jittered-exponential ``retry_after_s`` hint (seeded, reset on the
  first successful enqueue);
* **drains on stop()**: in-flight sequences run to completion,
  never-admitted ones complete with
  :class:`~repro.core.pipeline.PipelineStopped`.

Token order per request is by construction: one scheduler thread owns the
engine, appends tokens sequentially, and stamps each with its index —
the audit the decode bench asserts (zero lost, zero misordered).

The engine is duck-typed (see :class:`repro.decode.engine
.PipelineDecodeEngine` for the real one; tests use scripted fakes):
``n_slots``; ``prefill(slot, prompt) -> first_token``;
``step(slots, ctx_lens, last_tokens) -> next_tokens``; optionally
``release(slot)``, ``kv_bytes_per_token``, ``start()``/``stop()``.
"""
from __future__ import annotations

import dataclasses
import itertools
import queue
import random
import threading
import time
from collections import deque
from typing import Any, Dict, List, Optional, Sequence

import numpy as np

from ..core.pipeline import PipelineStopped
from ..serving.server import Overloaded

_RID = itertools.count()


@dataclasses.dataclass
class DecodeRequest:
    """One streaming decode request.

    ``stream`` yields ``(index, token)`` pairs as they are generated
    (index is the token's position in the response, 0-based, strictly
    increasing); ``tokens`` accumulates them; ``event`` fires at
    completion with ``error`` set on shed/stop."""

    rid: int
    prompt: np.ndarray
    max_new_tokens: int
    tokens: List[int] = dataclasses.field(default_factory=list)
    stream: "queue.Queue" = dataclasses.field(default_factory=queue.Queue)
    error: Optional[BaseException] = None
    event: threading.Event = dataclasses.field(
        default_factory=threading.Event)
    t_submit: float = dataclasses.field(default_factory=time.perf_counter)
    t_first: Optional[float] = None
    t_done: Optional[float] = None

    @property
    def done(self) -> bool:
        return self.event.is_set()

    def result(self, timeout: Optional[float] = None) -> List[int]:
        """Block until completion; raises the completion error if any."""
        if not self.event.wait(timeout):
            raise TimeoutError(f"decode request {self.rid} timed out")
        if self.error is not None:
            raise self.error
        return list(self.tokens)


@dataclasses.dataclass
class _Slot:
    req: DecodeRequest
    context_len: int          # valid cache positions (prompt + generated)
    last_token: int


class DecodeScheduler:
    """Continuous-batching admission/eviction loop over a decode engine."""

    def __init__(self, engine, *, max_context: int,
                 default_max_new_tokens: int = 32,
                 eos_token: Optional[int] = None,
                 queue_size: int = 64,
                 backoff_base_s: float = 0.05,
                 backoff_max_s: float = 2.0,
                 backoff_seed: int = 0):
        if max_context < 2:
            raise ValueError(f"max_context must be >= 2, got {max_context}")
        if queue_size < 1:
            raise ValueError(f"queue_size must be >= 1, got {queue_size}")
        if backoff_base_s <= 0 or backoff_max_s < backoff_base_s:
            raise ValueError("need 0 < backoff_base_s <= backoff_max_s")
        self.engine = engine
        self.n_slots = int(engine.n_slots)
        if self.n_slots < 1:
            raise ValueError(f"engine has no slots ({self.n_slots})")
        self.max_context = max_context
        self.default_max_new_tokens = default_max_new_tokens
        self.eos_token = eos_token
        self.queue_size = queue_size
        self.backoff_base_s = backoff_base_s
        self.backoff_max_s = backoff_max_s
        self._backoff_rng = random.Random(backoff_seed)
        self._consec_sheds = 0

        self._cond = threading.Condition()
        self._pending: deque = deque()
        self._slots: List[Optional[_Slot]] = [None] * self.n_slots
        self._thread: Optional[threading.Thread] = None
        self._stopping = False
        self._drain = True
        self._seq_s_ewma: Optional[float] = None   # per-sequence service
        # monotonic counters + gap samples; snapshot() takes deltas
        self._stats = {"admitted": 0, "shed": 0, "completed": 0,
                       "tokens": 0, "steps": 0}
        self._last_stats = dict(self._stats)
        self._gaps: List[float] = []
        self._last_t = time.perf_counter()

    # -- submission ----------------------------------------------------------
    def submit(self, prompt: Sequence[int],
               max_new_tokens: Optional[int] = None) -> DecodeRequest:
        """Enqueue a prompt.  Returns immediately; the request streams
        tokens as the running batch reaches it.  At the KV cap (all slots
        busy + full queue) the request completes *now* with
        :class:`Overloaded` + a retry hint instead of waiting unbounded."""
        prompt = np.asarray(prompt, dtype=np.int32).reshape(-1)
        budget = (max_new_tokens if max_new_tokens is not None
                  else self.default_max_new_tokens)
        req = DecodeRequest(rid=next(_RID), prompt=prompt,
                            max_new_tokens=max(1, int(budget)))
        if prompt.size < 1 or prompt.size >= self.max_context:
            self._finish(req, ValueError(
                f"prompt of {prompt.size} tokens does not fit "
                f"max_context={self.max_context} (need >= 1 and room for "
                f"at least one generated token)"))
            return req
        with self._cond:
            if self._stopping:
                self._finish(req, PipelineStopped(
                    RuntimeError("decode scheduler is stopping")))
                return req
            if len(self._pending) >= self.queue_size:
                retry = self._retry_after_s()
                self._consec_sheds += 1
                self._stats["shed"] += 1
                est = (len(self._pending)
                       * (self._seq_s_ewma or retry)) / self.n_slots
                self._finish(req, Overloaded(req.rid, retry, est))
                return req
            self._consec_sheds = 0     # accepted: reset the backoff ladder
            self._pending.append(req)
            self._cond.notify()
        return req

    def _retry_after_s(self) -> float:
        """PR-8 semantics: jittered exponential backoff over consecutive
        sheds (seeded => deterministic in tests)."""
        base = min(self.backoff_max_s,
                   self.backoff_base_s * (2.0 ** self._consec_sheds))
        return base * (1.0 + 0.25 * self._backoff_rng.random())

    def _finish(self, req: DecodeRequest,
                error: Optional[BaseException] = None) -> None:
        req.error = error
        req.t_done = time.perf_counter()
        req.event.set()

    # -- lifecycle -----------------------------------------------------------
    def start(self) -> "DecodeScheduler":
        with self._cond:
            if self._thread is not None:
                return self            # idempotent: already running
            self._stopping = False
            self._thread = threading.Thread(target=self._loop,
                                            name="decode-sched",
                                            daemon=True)
            self._thread.start()
        return self

    def stop(self, drain: bool = True) -> None:
        """Stop the loop.  ``drain=True`` (default) completes every
        *admitted* (in-flight) sequence first; pending never-admitted
        requests complete with :class:`PipelineStopped` either way."""
        with self._cond:
            self._stopping = True
            self._drain = drain
            self._cond.notify_all()
            thread = self._thread
        if thread is not None:
            thread.join(timeout=300)
            self._thread = None
        # no loop ever ran: fail whatever is still queued/slotted
        with self._cond:
            leftovers = list(self._pending)
            self._pending.clear()
            slots = [s for s in self._slots if s is not None]
            self._slots = [None] * self.n_slots
        for req in leftovers:
            self._finish(req, PipelineStopped(
                RuntimeError("decode scheduler stopped before admission")))
        for sl in slots:
            self._finish(sl.req, PipelineStopped(
                RuntimeError("decode scheduler stopped mid-sequence")))

    def __enter__(self) -> "DecodeScheduler":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    # -- the loop ------------------------------------------------------------
    def _free_slots(self) -> List[int]:
        return [i for i, s in enumerate(self._slots) if s is None]

    def _emit(self, slot: _Slot, token: int) -> bool:
        """Append one token to the slot's request (index = position).
        Returns True when the sequence just finished."""
        req = slot.req
        now = time.perf_counter()
        if req.t_first is None:
            req.t_first = now
        else:
            self._gaps.append(now - req.t_done_gap)   # type: ignore
        req.t_done_gap = now                           # type: ignore
        req.tokens.append(int(token))
        req.stream.put((len(req.tokens) - 1, int(token)))
        self._stats["tokens"] += 1
        slot.last_token = int(token)
        if len(req.tokens) >= req.max_new_tokens:
            return True
        if self.eos_token is not None and int(token) == self.eos_token:
            return True
        return slot.context_len + 1 >= self.max_context

    def _evict(self, idx: int) -> None:
        sl = self._slots[idx]
        self._slots[idx] = None
        release = getattr(self.engine, "release", None)
        if release is not None:
            release(idx)
        self._stats["completed"] += 1
        dt = time.perf_counter() - sl.req.t_submit
        ew = self._seq_s_ewma
        self._seq_s_ewma = dt if ew is None else 0.7 * ew + 0.3 * dt
        self._finish(sl.req)

    def _loop(self) -> None:
        while True:
            with self._cond:
                while (not self._stopping and not self._pending
                       and all(s is None for s in self._slots)):
                    self._cond.wait(timeout=0.5)
                if self._stopping:
                    drain = self._drain
                    # pending requests are never admitted past stop()
                    rejected = list(self._pending)
                    self._pending.clear()
                    active = [s for s in self._slots if s is not None]
                    if not drain:
                        self._slots = [None] * self.n_slots
                else:
                    drain, rejected, active = True, [], None
                admits = []
                if not self._stopping:
                    for idx in self._free_slots():
                        if not self._pending:
                            break
                        admits.append((idx, self._pending.popleft()))
            for req in rejected:
                self._finish(req, PipelineStopped(
                    RuntimeError("decode scheduler stopped before this "
                                 "request was admitted")))
            if self._stopping:
                if not drain:
                    for sl in active:
                        self._finish(sl.req, PipelineStopped(
                            RuntimeError("decode scheduler stopped "
                                         "mid-sequence")))
                    return
                if not any(s is not None for s in self._slots):
                    return                     # drained: all in-flight done

            # prefill-join at the token boundary: each admitted prompt is
            # prefilled and contributes its first token before the next
            # batched step
            for idx, req in admits:
                self._stats["admitted"] += 1
                first = self.engine.prefill(idx, req.prompt)
                sl = _Slot(req=req, context_len=req.prompt.size + 1,
                           last_token=int(first))
                self._slots[idx] = sl
                if self._emit(sl, first):
                    self._evict(idx)

            # one decode step of the whole running batch
            live = [(i, s) for i, s in enumerate(self._slots)
                    if s is not None]
            if not live:
                continue
            idxs = [i for i, _ in live]
            ctxs = [s.context_len for _, s in live]
            toks = [s.last_token for _, s in live]
            nxt = self.engine.step(idxs, ctxs, toks)
            self._stats["steps"] += 1
            for (i, sl), tok in zip(live, nxt):
                sl.context_len += 1
                if self._emit(sl, tok):
                    self._evict(i)

    # -- telemetry -----------------------------------------------------------
    def snapshot(self) -> Dict[str, Any]:
        """Delta counters since the last snapshot + live slot/KV state."""
        now = time.perf_counter()
        with self._cond:
            cur = dict(self._stats)
            delta = {k: cur[k] - self._last_stats[k] for k in cur}
            self._last_stats = cur
            gaps = sorted(self._gaps)
            self._gaps = []
            kv_per_tok = int(getattr(self.engine, "kv_bytes_per_token", 0))
            slots = [{"slot": i, "rid": s.req.rid,
                      "context_len": s.context_len,
                      "kv_bytes": s.context_len * kv_per_tok}
                     for i, s in enumerate(self._slots) if s is not None]
            queue_depth = len(self._pending)
        window = max(now - self._last_t, 1e-9)
        self._last_t = now

        def pct(p: float) -> float:
            if not gaps:
                return 0.0
            return gaps[min(len(gaps) - 1, int(p * len(gaps)))]

        delta.update(
            tokens_per_s=delta["tokens"] / window,
            window_s=window,
            inter_token_p50_s=pct(0.50),
            inter_token_p95_s=pct(0.95),
            slots=slots,
            slots_busy=len(slots),
            n_slots=self.n_slots,
            kv_bytes_total=sum(s["kv_bytes"] for s in slots),
            queue_depth=queue_depth)
        return delta
