"""Per-token decode costing: the second economy on the planner's seam.

Prefill/batch costing (the paper's regime) prices a depth level by its
MACs over the whole sequence and its static weight bytes.  Steady-state
decode prices the same level very differently:

* **compute** — one token per sequence per step: the level's weight matrix
  is touched once per token (``~params`` MACs) plus the attention
  read of the live context (``2 * context * n_heads * head_dim``);
* **state** — the bytes a level pins on-device *per in-flight sequence*:
  full KV cache ``2 * context * n_kv_heads * head_dim * itemsize`` for
  dense/MoE/VLM attention, window-clamped KV for hybrid local-attention
  layers, self+cross KV for enc-dec decoder layers, and **O(1) recurrent
  state** for rwkv6 (wkv matrix + channel shifts) and rglru (conv tail +
  hidden) blocks — these do not grow with context at all, which is
  exactly why a recurrent stage can hold far more concurrent sequences;
* MoE compute only touches the ``top_k`` active experts per token, so the
  inactive expert weights count toward memory but not decode MACs.

:class:`DecodeCostSource` materializes this regime through the existing
:class:`~repro.core.cost_engine.SegmentCostEngine` measured-mode seam
(per-depth ``time_s`` at the operating point's concurrency) plus the new
``state_bytes`` axis the engine prefix-sums for O(1)
``segment_state_bytes`` queries.
"""
from __future__ import annotations

import dataclasses
from typing import List, Sequence, Tuple

import numpy as np

from ..core.edge_tpu_model import EdgeTPUSpec
from ..core.graph import LayerGraph
from ..models.lm import LMConfig
from ..profiling.sources import CostSource, DepthCosts

ACT_BYTES = 2          # bf16 activations between decode stages


def _itemsize(dtype) -> int:
    try:
        return int(np.dtype(dtype).itemsize)
    except TypeError:
        return 2       # bf16-class dtypes on exotic stacks


@dataclasses.dataclass(frozen=True)
class DecodeOperatingPoint:
    """The (concurrency, context) point a decode plan is sized for.

    ``concurrency`` — sequences decoding together in steady state (the
    running batch); ``max_context`` — the per-sequence KV budget each
    attention layer must hold (prompt + generated tokens)."""

    concurrency: int = 4
    max_context: int = 256

    def __post_init__(self):
        if self.concurrency < 1:
            raise ValueError(f"concurrency must be >= 1, "
                             f"got {self.concurrency}")
        if self.max_context < 1:
            raise ValueError(f"max_context must be >= 1, "
                             f"got {self.max_context}")


def _node_token_costs(cfg: LMConfig, node, point: DecodeOperatingPoint
                      ) -> Tuple[int, int]:
    """(per-token MACs, per-sequence state bytes) of one graph node in the
    decode regime."""
    kind = node.kind
    d = cfg.d_model
    ctx = point.max_context
    kv_item = _itemsize(cfg.dtype)
    kv_row = 2 * cfg.n_kv_heads * cfg.hd * kv_item     # K+V bytes per pos
    attn_read = 2 * cfg.n_heads * cfg.hd               # QK^T + PV per pos

    if kind in ("stub", "enc_block"):
        # encoder work happens once at prefill; in steady-state decode an
        # encoder level does no per-token compute and pins no state
        return 0, 0
    if kind in ("embed", "norm"):
        return d, 0
    if kind == "head":
        return d * cfg.vocab, 0
    if kind == "rec_block":
        # rglru temporal block: O(1) state (conv tail in cfg.dtype +
        # fp32 hidden), linear per-token compute
        state = ((cfg.conv_width - 1) * d * _itemsize(cfg.dtype)
                 + d * 4)
        return node.params, state
    if kind == "attn_block":
        # hybrid local attention: the ring buffer clamps KV to the window
        w = min(ctx, cfg.local_window or ctx)
        return node.params + w * attn_read, w * kv_row
    if kind == "dec_block":
        # enc-dec decoder: causal self-KV over the context plus the fixed
        # cross-attention KV over the encoded frames
        span = ctx + cfg.n_frames
        return node.params + span * attn_read, span * kv_row
    if kind == "block":
        if cfg.family == "ssm":
            # rwkv6: wkv state matrix (fp32) + token/channel shifts; no
            # context term at all — the recurrent families' O(1) promise
            heads = d // cfg.rwkv_head_dim
            state = (heads * cfg.rwkv_head_dim * cfg.rwkv_head_dim * 4
                     + 2 * d * _itemsize(cfg.dtype))
            return node.params, state
        macs = node.params
        if cfg.family == "moe":
            # only top_k experts run per token; wg/wu/wd per expert
            inactive = ((cfg.n_experts - cfg.top_k)
                        * 3 * d * cfg.d_ff)
            macs = max(d, node.params - inactive)
        return macs + ctx * attn_read, ctx * kv_row
    raise ValueError(f"decode costing: unknown node kind {kind!r} "
                     f"({node.name})")


def decode_depth_costs(cfg: LMConfig, graph: LayerGraph,
                       point: DecodeOperatingPoint
                       ) -> Tuple[List[int], List[int]]:
    """Per-depth (per-token MACs, per-sequence state bytes) aligned with
    ``graph.levels()`` (levels with several nodes — the enc-dec DAG —
    sum their members)."""
    nodes = graph.nodes
    macs, state = [], []
    for lvl in graph.levels():
        m = s = 0
        for name in lvl:
            nm, ns = _node_token_costs(cfg, nodes[name], point)
            m += nm
            s += ns
        macs.append(m)
        state.append(s)
    return macs, state


class DecodeCostSource(CostSource):
    """Price a graph at a decode operating point.

    Rides the engine's measured-mode seam: ``time_s[d]`` is the weight
    fill plus ``concurrency`` tokens of decode compute for depth ``d``,
    so ``segment_time`` models one decode *step* of the whole running
    batch (the quantity whose max over stages paces tokens/s).
    ``state_bytes`` feeds ``segment_state_bytes`` — per sequence, so the
    placement cap multiplies by concurrency explicitly."""

    def __init__(self, cfg: LMConfig, point: DecodeOperatingPoint):
        self.cfg = cfg
        self.point = point
        self.name = (f"decode(c={point.concurrency},"
                     f"ctx={point.max_context})")

    def materialize(self, graph: LayerGraph, spec: EdgeTPUSpec
                    ) -> DepthCosts:
        spec = spec or EdgeTPUSpec()
        token_macs, state = decode_depth_costs(self.cfg, graph, self.point)
        n = self.point.concurrency
        weight_bytes = graph.bytes_per_depth()
        wl_rate = spec.weight_load_gbps * 1e9
        mac_rate = spec.macs_per_s
        wloads = [b / wl_rate for b in weight_bytes]
        times = [w + n * m / mac_rate
                 for w, m in zip(wloads, token_macs)]
        # one token's hidden state per in-flight sequence crosses a cut
        depth = len(token_macs)
        step_act = n * self.cfg.d_model * ACT_BYTES
        cut = [step_act] * depth
        if depth:
            cut[-1] = 0
        return DepthCosts(
            params=graph.params_per_depth(),
            macs=[n * m for m in token_macs],
            weight_bytes=weight_bytes, cut_bytes=cut,
            time_s=times, weight_load_s=wloads,
            state_bytes=state)

    def describe(self) -> str:
        return f"{self.name} on {self.cfg.name}"
