"""Decode serving tier: KV-cache-aware placement + continuous batching.

Autoregressive decode inverts the paper's memory economy (ROADMAP item 3):
stage feasibility is dominated by the *growing KV cache* — a function of
``(context_len, n_kv_heads, head_dim, window)`` times the number of
concurrent sequences — not by static weight bytes.  This package layers a
second cost regime on the same planner:

* :mod:`repro.decode.costing` — :class:`DecodeCostSource`: per-token
  decode compute + per-sequence state bytes per depth (KV for attention
  blocks, O(1) recurrent state for rwkv6/rglru), materialized through the
  existing :class:`~repro.core.cost_engine.SegmentCostEngine` seam.
* :mod:`repro.decode.placement` — ``@register_strategy
  ("decode_placement")``: maximize steady-state tokens/s subject to a
  per-stage KV-memory cap at a target ``(concurrency, max_context)``
  operating point, on the minimax DP skeleton.
* :mod:`repro.decode.scheduler` — :class:`DecodeScheduler`: continuous
  batching — prefill requests join the running decode batch at token
  boundaries, finished sequences are evicted, per-slot KV occupancy is
  tracked, and overload sheds with the PR-8 ``Overloaded`` semantics.
* :mod:`repro.decode.engine` — :class:`PipelineDecodeEngine`: the decode
  batch executed through the streaming :class:`~repro.core.pipeline
  .PipelineExecutor`, one stage per plan segment, per-stage KV caches.

Front door: ``DeploymentSpec(model="lm:...", workload="decode",
max_context=..., decode_concurrency=...)`` -> ``plan(spec)`` ->
``Deployment.serve()`` streaming tokens.  See EXPERIMENTS.md §Decode
serving.
"""
from .costing import (DecodeCostSource, DecodeOperatingPoint,
                      decode_depth_costs)
from .engine import (DecodeServer, PipelineDecodeEngine,
                     build_decode_server)
from .placement import (DECODE_FAMILIES, decode_config_for,
                        max_feasible_concurrency)
from .scheduler import DecodeRequest, DecodeScheduler

__all__ = [
    "DecodeCostSource", "DecodeOperatingPoint", "decode_depth_costs",
    "DecodeRequest", "DecodeScheduler", "DecodeServer",
    "PipelineDecodeEngine", "build_decode_server",
    "DECODE_FAMILIES", "decode_config_for", "max_feasible_concurrency",
]
