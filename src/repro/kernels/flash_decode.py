"""Flash-decoding kernel: single-token cached attention, GQA-aware.

The §Roofline analysis puts every decode cell 5-20x above the cache-read
floor because the jnp path materializes per-layer fp32 score vectors in
HBM.  This kernel streams the KV cache through VMEM once, carrying the
online-softmax stats in scratch — the in-chip analogue of the
sequence-sharded cache the SPMD layer already uses across chips
(EXPERIMENTS.md §Perf pair 3).

Grid: (B, Hq, T/bk), KV innermost (sequential; scratch persists).  The
valid cache length arrives as a scalar in SMEM; blocks fully past it are
skipped.  q: (B, Hq, D); k/v caches: (B, Hkv, T, D); GQA folded into the
cache index maps (q-head h reads kv-head h // group).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
            *, nk: int, bk: int, scale: float):
    ki = pl.program_id(2)
    valid_len = len_ref[0]

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(ki * bk < valid_len)
    def _step():
        q = q_ref[0, 0].astype(jnp.float32)            # (1, D) row
        k = k_ref[0, 0].astype(jnp.float32)            # (bk, D)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        kpos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (1, bk), 1)
        s = jnp.where(kpos < valid_len, s, NEG_INF)     # (1, bk)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ki == nk - 1)
    def _flush():
        o_ref[0, 0] = (acc_ref[...] /
                       jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def flash_decode(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                 cache_len: jax.Array, bk: int = 128,
                 interpret: bool = False) -> jax.Array:
    """q: (B, Hq, D); k/v caches: (B, Hkv, T, D); cache_len: () int32
    (entries [0, cache_len) are valid) -> (B, Hq, D)."""
    b, hq, d = q.shape
    _, hkv, t, _ = k_cache.shape
    assert hq % hkv == 0
    group = hq // hkv
    assert t % bk == 0, (t, bk)
    nk = t // bk
    scale = d ** -0.5
    q4 = q[:, :, None, :]                                # (B, Hq, 1, D)
    len_arr = jnp.asarray(cache_len, jnp.int32).reshape(1)
    kernel = functools.partial(_kernel, nk=nk, bk=bk, scale=scale)
    out = pl.pallas_call(
        kernel,
        grid=(b, hq, nk),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1, 1, d), lambda bb, h, ki: (bb, h, 0, 0)),
            pl.BlockSpec((1, 1, bk, d),
                         lambda bb, h, ki: (bb, h // group, ki, 0)),
            pl.BlockSpec((1, 1, bk, d),
                         lambda bb, h, ki: (bb, h // group, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, 1, d), lambda bb, h, ki: (bb, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hq, 1, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((1, 1), jnp.float32),     # running max
            pltpu.VMEM((1, 1), jnp.float32),     # running denominator
            pltpu.VMEM((1, d), jnp.float32),     # output accumulator
        ],
        interpret=interpret,
    )(len_arr, q4, k_cache, v_cache)
    return out[:, :, 0, :]
