"""Pure-jnp oracles for every Pallas kernel (the allclose references)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def matmul_qi8_ref(x: jax.Array, w: jax.Array) -> jax.Array:
    """int8 x int8 -> int32 exact."""
    return jax.lax.dot_general(
        x.astype(jnp.int32), w.astype(jnp.int32),
        (((1,), (0,)), ((), ())), preferred_element_type=jnp.int32)


def flash_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                        causal: bool = True) -> jax.Array:
    """q: (B,Hq,S,D); k/v: (B,Hkv,T,D) -> (B,Hq,S,D), fp32 softmax."""
    b, hq, s, d = q.shape
    hkv, t = k.shape[1], k.shape[2]
    g = hq // hkv
    qg = q.reshape(b, hkv, g, s, d).astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    scores = jnp.einsum("bkgsd,bktd->bkgst", qg, kf) / (d ** 0.5)
    if causal:
        mask = jnp.tril(jnp.ones((s, t), bool), k=t - s)
        scores = jnp.where(mask, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgst,bktd->bkgsd", probs, vf)
    return out.reshape(b, hq, s, d).astype(q.dtype)


def flash_decode_ref(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                     cache_len) -> jax.Array:
    """Single-token cached attention.  q: (B,Hq,D); caches (B,Hkv,T,D)."""
    b, hq, d = q.shape
    hkv, t = k_cache.shape[1], k_cache.shape[2]
    g = hq // hkv
    qg = q.reshape(b, hkv, g, d).astype(jnp.float32)
    scores = jnp.einsum("bkgd,bktd->bkgt", qg,
                        k_cache.astype(jnp.float32)) / (d ** 0.5)
    valid = jnp.arange(t)[None, None, None, :] < cache_len
    scores = jnp.where(valid, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgt,bktd->bkgd", probs,
                     v_cache.astype(jnp.float32))
    return out.reshape(b, hq, d).astype(q.dtype)


def rglru_scan_ref(a: jax.Array, g: jax.Array, h0: jax.Array):
    """h_t = a_t h_{t-1} + g_t.  Returns (y (B,S,R), h_last fp32)."""
    def step(h, xs):
        a_t, g_t = xs
        h = a_t.astype(jnp.float32) * h + g_t.astype(jnp.float32)
        return h, h

    h_last, ys = jax.lax.scan(
        step, h0.astype(jnp.float32),
        (a.transpose(1, 0, 2), g.transpose(1, 0, 2)))
    return ys.transpose(1, 0, 2).astype(a.dtype), h_last


def rwkv6_scan_ref(r, k, v, w, u, s0):
    """Per-head WKV recurrence.  Returns (y (B,H,S,D), s_last fp32)."""
    rf, kf, vf, wf = (x.astype(jnp.float32) for x in (r, k, v, w))
    uf = u.astype(jnp.float32)

    def step(state, xs):
        r_t, k_t, v_t, w_t = xs                       # (B,H,D) each
        kv = k_t[..., :, None] * v_t[..., None, :]    # (B,H,K,V)
        y = jnp.einsum("bhk,bhkv->bhv", r_t,
                       state + uf[None, :, :, None] * kv)
        state = w_t[..., None] * state + kv
        return state, y

    xs = tuple(x.transpose(2, 0, 1, 3) for x in (rf, kf, vf, wf))
    s_last, ys = jax.lax.scan(step, s0.astype(jnp.float32), xs)
    return ys.transpose(1, 2, 0, 3).astype(r.dtype), s_last
