"""Pallas TPU kernels for the compute hot-spots.

Each kernel module holds the ``pl.pallas_call`` + BlockSpec implementation;
``ops.py`` exposes jit'd wrappers with CPU fallbacks; ``ref.py`` holds the
pure-jnp oracles used by the allclose test sweeps (interpret=True on CPU).
"""
from .ops import (flash_attention, flash_decode, matmul_qi8, quantize_int8,
                  quantized_dense, rglru_scan, rwkv6_scan)

__all__ = ["flash_attention", "flash_decode", "matmul_qi8", "quantize_int8",
           "quantized_dense", "rglru_scan", "rwkv6_scan"]
