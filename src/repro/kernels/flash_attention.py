"""Blocked online-softmax (flash) attention forward, GQA-aware.

TPU-native adaptation of the attention hot-spot for the LM prefill path:
instead of materializing the (S, T) score matrix in HBM, each (batch,
q-head, q-block) streams KV blocks through VMEM carrying running max /
denominator / accumulator in VMEM scratch — the TPU grid's innermost
dimension executes sequentially per core, so scratch persists across the
KV loop.

Grid: (B, Hq, S/bq, T/bk).  GQA is folded into the k/v BlockSpec index maps
(q-head h reads kv-head h // group) — no materialized head broadcast.
Causal masking skips fully-masked KV blocks via the index map (they still
occupy grid steps but exit early through @pl.when).

Block defaults bq=bk=128: q tile (128, D) + k/v tiles (128, D) + fp32
accumulators -> < 1 MiB VMEM for D=128, leaving room for double buffering.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
            *, nk: int, bq: int, bk: int, scale: float, causal: bool,
            q_offset: int):
    ki = pl.program_id(3)
    qi = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    run = True
    if causal:
        # kv block strictly after the q block's last row -> fully masked
        run = (ki * bk) <= (q_offset + qi * bq + bq - 1)

    @pl.when(run)
    def _step():
        q = q_ref[0, 0].astype(jnp.float32)           # (bq, D)
        k = k_ref[0, 0].astype(jnp.float32)           # (bk, D)
        v = v_ref[0, 0].astype(jnp.float32)           # (bk, D)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if causal:
            qpos = (q_offset + qi * bq
                    + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0))
            kpos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            s = jnp.where(kpos <= qpos, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ki == nk - 1)
    def _flush():
        o_ref[0, 0] = (acc_ref[...] /
                       jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                    causal: bool = True, bq: int = 128, bk: int = 128,
                    interpret: bool = False) -> jax.Array:
    """q: (B, Hq, S, D); k/v: (B, Hkv, T, D) -> (B, Hq, S, D).

    When S != T (chunked prefill / cache-extended queries) the queries are
    right-aligned: query i sits at absolute position T - S + i."""
    b, hq, s, d = q.shape
    _, hkv, t, _ = k.shape
    assert hq % hkv == 0
    group = hq // hkv
    assert s % bq == 0 and t % bk == 0, (s, t, bq, bk)
    grid = (b, hq, s // bq, t // bk)
    scale = d ** -0.5
    kernel = functools.partial(_kernel, nk=t // bk, bq=bq, bk=bk,
                               scale=scale, causal=causal, q_offset=t - s)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, d), lambda bb, h, qi, ki: (bb, h, qi, 0)),
            pl.BlockSpec((1, 1, bk, d),
                         lambda bb, h, qi, ki: (bb, h // group, ki, 0)),
            pl.BlockSpec((1, 1, bk, d),
                         lambda bb, h, qi, ki: (bb, h // group, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, d),
                               lambda bb, h, qi, ki: (bb, h, qi, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),    # running max
            pltpu.VMEM((bq, 1), jnp.float32),    # running denominator
            pltpu.VMEM((bq, d), jnp.float32),    # output accumulator
        ],
        interpret=interpret,
    )(q, k, v)
