"""int8 x int8 -> int32 tiled matmul — the Edge TPU systolic-array analogue.

The Edge TPU performs all inference as int8 MACs on a 64x64 systolic array
(paper §2.1).  On a TPU v5e the equivalent compute unit is the 128x128 MXU;
this kernel expresses the quantized matmul with MXU-aligned tiles:

* grid (M/bm, N/bn, K/bk); K is the innermost (sequential) dimension,
* x tile (bm, bk) int8 and w tile (bk, bn) int8 live in VMEM,
* accumulation in an int32 VMEM scratch across the K loop
  (zeroed at k==0, flushed to the output at k==nk-1),
* per-tensor scales are folded in by the ops.py wrapper (dequantize).

Block defaults (128, 128, 128): one MXU-shaped tile per step; VMEM working
set = bm*bk + bk*bn (int8) + bm*bn*4 (int32 acc) ~= 96 KiB, far below the
~16 MiB/core VMEM budget so the pipeline can double-buffer HBM streams.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK = (128, 128, 128)


def _kernel(x_ref, w_ref, o_ref, acc_ref, *, nk: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...].astype(jnp.int32)
    w = w_ref[...].astype(jnp.int32)
    acc_ref[...] += jax.lax.dot_general(
        x, w, (((1,), (0,)), ((), ())), preferred_element_type=jnp.int32)

    @pl.when(pl.program_id(2) == nk - 1)
    def _flush():
        o_ref[...] = acc_ref[...]


def matmul_qi8(x: jax.Array, w: jax.Array,
               block=DEFAULT_BLOCK, interpret: bool = False) -> jax.Array:
    """x: (M, K) int8; w: (K, N) int8 -> (M, N) int32."""
    assert x.dtype == jnp.int8 and w.dtype == jnp.int8
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, (x.shape, w.shape)
    bm, bn, bk = block
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, \
        f"shape {(m, k, n)} not divisible by block {block}"
    nk = k // bk
    grid = (m // bm, n // bn, nk)
    return pl.pallas_call(
        functools.partial(_kernel, nk=nk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.int32),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32)],
        interpret=interpret,
    )(x, w)
