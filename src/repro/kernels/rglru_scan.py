"""Blocked RG-LRU linear recurrence (recurrentgemma temporal core).

    h_t = a_t * h_{t-1} + g_t        (diagonal, per channel)

The sequence axis is cut into chunks; the grid's sequential innermost
dimension walks the chunks in order while the carry ``h`` persists in fp32
VMEM scratch.  Within a chunk the recurrence runs as an unrolled VPU loop
over time steps — each step is a fused multiply-add over the (B, R) lane
tile, which is exactly how the TPU's vector unit wants this memory-bound
recurrence (contrast the GPU formulation: a warp-parallel Blelloch scan;
on TPU the sequential-grid + VMEM-carry shape avoids cross-core shuffles
entirely — see DESIGN.md hardware-adaptation notes).

Inputs a, g: (B, S, R) (decay and gated input, precomputed pointwise);
h0: (B, R) fp32.  Outputs: hidden sequence (B, S, R) + final carry.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(a_ref, g_ref, h0_ref, y_ref, hout_ref, h_ref,
            *, chunk: int, n_chunks: int):
    @pl.when(pl.program_id(0) == 0)
    def _init():
        h_ref[...] = h0_ref[...].astype(jnp.float32)

    def step(t, h):
        h = a_ref[:, t, :].astype(jnp.float32) * h + \
            g_ref[:, t, :].astype(jnp.float32)
        y_ref[:, t, :] = h.astype(y_ref.dtype)
        return h

    h = jax.lax.fori_loop(0, chunk, step, h_ref[...])
    h_ref[...] = h

    @pl.when(pl.program_id(0) == n_chunks - 1)
    def _flush():
        hout_ref[...] = h


def rglru_scan(a: jax.Array, g: jax.Array, h0: jax.Array,
               chunk: int = 256, interpret: bool = False):
    """Returns (y, h_last).  a/g: (B, S, R); h0: (B, R)."""
    b, s, r = a.shape
    assert g.shape == (b, s, r) and h0.shape == (b, r)
    chunk = min(chunk, s)
    assert s % chunk == 0, (s, chunk)
    n_chunks = s // chunk
    kernel = functools.partial(_kernel, chunk=chunk, n_chunks=n_chunks)
    y, h_last = pl.pallas_call(
        kernel,
        grid=(n_chunks,),
        in_specs=[
            pl.BlockSpec((b, chunk, r), lambda c: (0, c, 0)),
            pl.BlockSpec((b, chunk, r), lambda c: (0, c, 0)),
            pl.BlockSpec((b, r), lambda c: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((b, chunk, r), lambda c: (0, c, 0)),
            pl.BlockSpec((b, r), lambda c: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, s, r), a.dtype),
            jax.ShapeDtypeStruct((b, r), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((b, r), jnp.float32)],
        interpret=interpret,
    )(a, g, h0)
    return y, h_last
