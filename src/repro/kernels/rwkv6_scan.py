"""Blocked RWKV6 WKV recurrence (Finch time-mix core).

Per head with state S in R^(K x V):

    y_t = r_t (S_{t-1} + diag(u) k_t^T v_t)
    S_t = diag(w_t) S_{t-1} + k_t^T v_t

The (K, V) state tile lives in fp32 VMEM scratch and persists across the
sequential chunk grid; within a chunk the recurrence is an unrolled loop of
rank-1 updates + (1, K) x (K, V) matvecs — MXU/VPU-friendly, no cross-core
communication (the GPU reference implementation's shared-memory tiling maps
to the VMEM-resident state here; see DESIGN.md).

Inputs r/k/v/w: (B, H, S, D) with D = head_dim (K == V == D); u: (H, D).
Outputs y: (B, H, S, D) + final state (B, H, D, D).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(r_ref, k_ref, v_ref, w_ref, u_ref, s0_ref, y_ref, sout_ref,
            st_ref, *, chunk: int, n_chunks: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        st_ref[...] = s0_ref[0, 0].astype(jnp.float32)

    u = u_ref[0].astype(jnp.float32)                     # (D,)

    def step(t, state):
        r = r_ref[0, 0, t].astype(jnp.float32)           # (D,)
        k = k_ref[0, 0, t].astype(jnp.float32)
        v = v_ref[0, 0, t].astype(jnp.float32)
        w = w_ref[0, 0, t].astype(jnp.float32)
        kv = k[:, None] * v[None, :]                     # (K, V) rank-1
        y = jnp.einsum("k,kv->v", r, state + u[:, None] * kv)
        y_ref[0, 0, t] = y.astype(y_ref.dtype)
        return w[:, None] * state + kv

    state = jax.lax.fori_loop(0, chunk, step, st_ref[...])
    st_ref[...] = state

    @pl.when(pl.program_id(2) == n_chunks - 1)
    def _flush():
        sout_ref[0, 0] = state


def rwkv6_scan(r: jax.Array, k: jax.Array, v: jax.Array, w: jax.Array,
               u: jax.Array, s0: jax.Array, chunk: int = 128,
               interpret: bool = False):
    """Returns (y, s_last).  r/k/v/w: (B,H,S,D); u: (H,D); s0: (B,H,D,D)."""
    b, h, s, d = r.shape
    assert u.shape == (h, d) and s0.shape == (b, h, d, d)
    chunk = min(chunk, s)
    assert s % chunk == 0, (s, chunk)
    n_chunks = s // chunk
    kernel = functools.partial(_kernel, chunk=chunk, n_chunks=n_chunks)
    y, s_last = pl.pallas_call(
        kernel,
        grid=(b, h, n_chunks),
        in_specs=[
            pl.BlockSpec((1, 1, chunk, d), lambda bb, hh, c: (bb, hh, c, 0)),
            pl.BlockSpec((1, 1, chunk, d), lambda bb, hh, c: (bb, hh, c, 0)),
            pl.BlockSpec((1, 1, chunk, d), lambda bb, hh, c: (bb, hh, c, 0)),
            pl.BlockSpec((1, 1, chunk, d), lambda bb, hh, c: (bb, hh, c, 0)),
            pl.BlockSpec((1, d), lambda bb, hh, c: (hh, 0)),
            pl.BlockSpec((1, 1, d, d), lambda bb, hh, c: (bb, hh, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, chunk, d), lambda bb, hh, c: (bb, hh, c, 0)),
            pl.BlockSpec((1, 1, d, d), lambda bb, hh, c: (bb, hh, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, h, s, d), r.dtype),
            jax.ShapeDtypeStruct((b, h, d, d), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((d, d), jnp.float32)],
        interpret=interpret,
    )(r, k, v, w, u, s0)
    return y, s_last
