"""Public jit'd wrappers for the Pallas kernels.

Policy: on TPU backends the Pallas kernel runs compiled; everywhere else
(`interpret=True` or a non-TPU backend) the wrapper either interprets the
kernel (tests) or falls back to the jnp oracle (production CPU path), so the
library is runnable on any backend.  Quantization helpers for the int8
(Edge-TPU-faithful) inference mode live here too.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from . import ref
from .flash_attention import flash_attention as _flash_pallas
from .flash_decode import flash_decode as _flash_decode_pallas
from .matmul_qi8 import matmul_qi8 as _matmul_pallas
from .rglru_scan import rglru_scan as _rglru_pallas
from .rwkv6_scan import rwkv6_scan as _rwkv6_pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


# ---------------------------------------------------------------------------
# Quantization (per-tensor symmetric int8 — the Edge TPU scheme, paper §2.1)
# ---------------------------------------------------------------------------
def quantize_int8(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Returns (q int8, scale fp32) with q * scale ~= x."""
    amax = jnp.maximum(jnp.max(jnp.abs(x)), 1e-8)
    scale = (amax / 127.0).astype(jnp.float32)
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


@functools.partial(jax.jit, static_argnames=("block", "use_pallas"))
def matmul_qi8(x_q: jax.Array, w_q: jax.Array, x_scale: jax.Array,
               w_scale: jax.Array, block=(128, 128, 128),
               use_pallas: Optional[bool] = None) -> jax.Array:
    """Quantized matmul -> fp32 (dequantized).  x_q (M,K), w_q (K,N) int8."""
    use = _on_tpu() if use_pallas is None else use_pallas
    acc = (_matmul_pallas(x_q, w_q, block=block)
           if use else ref.matmul_qi8_ref(x_q, w_q))
    return acc.astype(jnp.float32) * x_scale * w_scale


def quantized_dense(x: jax.Array, w: jax.Array) -> jax.Array:
    """fp32 in/out dense through the int8 path (quantize -> mm -> dequant)."""
    xq, sx = quantize_int8(x)
    wq, sw = quantize_int8(w)
    return ref.matmul_qi8_ref(xq, wq).astype(jnp.float32) * sx * sw


# ---------------------------------------------------------------------------
# Flash attention
# ---------------------------------------------------------------------------
@functools.partial(jax.jit, static_argnames=("causal", "bq", "bk",
                                             "use_pallas"))
def flash_attention(q, k, v, causal: bool = True, bq: int = 128,
                    bk: int = 128, use_pallas: Optional[bool] = None):
    use = _on_tpu() if use_pallas is None else use_pallas
    if use:
        return _flash_pallas(q, k, v, causal=causal, bq=bq, bk=bk)
    return ref.flash_attention_ref(q, k, v, causal=causal)


@functools.partial(jax.jit, static_argnames=("bk", "use_pallas"))
def flash_decode(q, k_cache, v_cache, cache_len, bk: int = 128,
                 use_pallas: Optional[bool] = None):
    """Single-token cached attention (decode hot path)."""
    use = _on_tpu() if use_pallas is None else use_pallas
    if use:
        return _flash_decode_pallas(q, k_cache, v_cache, cache_len, bk=bk)
    return ref.flash_decode_ref(q, k_cache, v_cache, cache_len)


# ---------------------------------------------------------------------------
# Recurrences
# ---------------------------------------------------------------------------
@functools.partial(jax.jit, static_argnames=("chunk", "use_pallas"))
def rglru_scan(a, g, h0, chunk: int = 256,
               use_pallas: Optional[bool] = None):
    use = _on_tpu() if use_pallas is None else use_pallas
    if use:
        return _rglru_pallas(a, g, h0, chunk=chunk)
    return ref.rglru_scan_ref(a, g, h0)


@functools.partial(jax.jit, static_argnames=("chunk", "use_pallas"))
def rwkv6_scan(r, k, v, w, u, s0, chunk: int = 128,
               use_pallas: Optional[bool] = None):
    use = _on_tpu() if use_pallas is None else use_pallas
    if use:
        return _rwkv6_pallas(r, k, v, w, u, s0, chunk=chunk)
    return ref.rwkv6_scan_ref(r, k, v, w, u, s0)
