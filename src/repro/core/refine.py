"""Segmentation refinement (paper §6.1.3, Fig. 9).

The balanced split of Algorithm 1 equalizes *parameter counts*, but the
compiled per-segment memory also includes activations, instructions, padding
and alignment — only visible after compiling each segment.  The paper uses the
Edge TPU compiler's memory report as feedback and nudges cut positions until
no segment spills to host memory:

* **forward sweep** (first → last segment): if segment ``S_i`` spills, move
  the cut between ``S_i`` and ``S_{i+1}`` one depth *earlier* (shrinking
  ``S_i``); repeat until ``S_i`` fits, then advance to ``S_{i+1}``.
* **backward sweep** (last → first): the forward sweep pushes layers toward
  the last segment; if the *last* segment spills, sweep backwards moving cuts
  one depth *later* (shrinking from the left).

The reporter is pluggable: benchmarks/tests use the analytical
:class:`~repro.core.edge_tpu_model.EdgeTPUModel` reporter (playing the Edge
TPU compiler's role); the pod-scale path uses XLA ``memory_analysis()``
(see launch/xla_reporter.py).  The optimization noted at the end of §6.1.3 —
moving a cut several positions per compilation, sized by the spill amount —
is implemented and on by default (``multi_step=True``).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional, Protocol, Sequence, Tuple

from .segmentation import segment_ranges


class MemoryReporter(Protocol):
    """Compile (or estimate) one segment and report its memory usage."""

    def segment_report(self, depth_lo: int, depth_hi: int) -> Tuple[int, int]:
        """Returns (device_bytes, host_overflow_bytes) for depths [lo, hi]."""
        ...

    def depth_bytes(self, depth: int) -> int:
        """Weight bytes contributed by one depth level (for multi-step moves)."""
        ...


@dataclasses.dataclass
class RefinementResult:
    cuts: List[int]
    compilations: int       # number of reporter calls (§6.1.3 cost metric)
    moves: int
    converged: bool         # True iff no segment spills


def _spill(reporter: MemoryReporter, lo: int, hi: int) -> int:
    return reporter.segment_report(lo, hi)[1]


def _steps_for_spill(reporter: MemoryReporter, spill: int,
                     depths: Sequence[int]) -> int:
    """How many depth levels (from `depths`, in move order) to shed to cover
    `spill` bytes — the §6.1.3 multi-position optimization."""
    shed, steps = 0, 0
    for d in depths:
        if shed >= spill:
            break
        shed += reporter.depth_bytes(d)
        steps += 1
    return max(1, steps)


def refine_cuts(
    cuts: Sequence[int],
    n_levels: int,
    reporter: Optional[MemoryReporter] = None,
    max_rounds: int = 8,
    multi_step: bool = True,
    stage_reporters: Optional[Sequence[MemoryReporter]] = None,
) -> RefinementResult:
    """Run forward/backward refinement sweeps until no segment spills.

    ``reporter`` prices every segment against one device (the paper's
    homogeneous chain).  ``stage_reporters`` instead supplies one reporter
    *per stage* — per-stage device limits for heterogeneous topologies
    (e.g. built by ``TopologyCostModel.stage_reporters``); stage ``i``'s
    spill is judged against its own device's capacity.  Exactly one of the
    two must be given.
    """
    cuts = list(cuts)
    s = len(cuts) + 1
    if (reporter is None) == (stage_reporters is None):
        raise ValueError("pass exactly one of reporter / stage_reporters")
    if stage_reporters is not None and len(stage_reporters) != s:
        raise ValueError(f"need {s} stage reporters, got "
                         f"{len(stage_reporters)}")
    rep_for = ((lambda i: reporter) if stage_reporters is None
               else (lambda i: stage_reporters[i]))
    compilations = 0
    moves = 0

    def ranges() -> List[Tuple[int, int]]:
        return segment_ranges(n_levels, cuts)

    for _ in range(max_rounds):
        dirty = False

        # ---- forward sweep: shrink spilling segments from the right --------
        for i in range(s - 1):                    # segments that own a right cut
            while True:
                lo, hi = ranges()[i]
                compilations += 1
                spill = _spill(rep_for(i), lo, hi)
                if spill <= 0:
                    break
                if hi <= lo:                      # cannot shrink a 1-level segment
                    break
                if multi_step:
                    step = _steps_for_spill(
                        rep_for(i), spill, range(hi, lo, -1))
                    step = min(step, hi - lo)
                else:
                    step = 1
                # move this segment's right cut `step` levels earlier
                new_cut = cuts[i] - step
                floor = cuts[i - 1] + 1 if i > 0 else 0
                cuts[i] = max(new_cut, floor)
                moves += 1
                dirty = True

        # ---- backward sweep: shrink spilling segments from the left ---------
        for i in range(s - 1, 0, -1):             # segments that own a left cut
            while True:
                lo, hi = ranges()[i]
                compilations += 1
                spill = _spill(rep_for(i), lo, hi)
                if spill <= 0:
                    break
                if hi <= lo:
                    break
                if multi_step:
                    step = _steps_for_spill(rep_for(i), spill, range(lo, hi))
                    step = min(step, hi - lo)
                else:
                    step = 1
                # move this segment's left cut `step` levels later
                new_cut = cuts[i - 1] + step
                ceil = cuts[i] - 1 if i < s - 1 else n_levels - 2
                cuts[i - 1] = min(new_cut, ceil)
                moves += 1
                dirty = True

        # check convergence
        ok = True
        for i, (lo, hi) in enumerate(ranges()):
            compilations += 1
            if _spill(rep_for(i), lo, hi) > 0:
                ok = False
                break
        if ok:
            return RefinementResult(cuts=cuts, compilations=compilations,
                                    moves=moves, converged=True)
        if not dirty:
            break   # stuck: no cut can move further

    return RefinementResult(cuts=cuts, compilations=compilations,
                            moves=moves, converged=False)


class GraphReporter:
    """MemoryReporter over an analytical EdgeTPUModel (or any object exposing
    ``segment_memory`` + a LayerGraph) — used by tests and CNN benchmarks.

    Per-depth weight bytes come from the model's segment-cost engine when
    it uses one, so the refiner's multi-step move sizing uses the exact
    bytes accounting of the planner's cost source (one model, no
    duplicated size math); objects without an engine — and the naive
    ``use_engine=False`` baseline models, which must not silently build
    one — fall back to the graph's own per-depth array, the same numbers
    for the analytic source."""

    def __init__(self, tpu_model):
        self._m = tpu_model
        engine = (getattr(tpu_model, "engine", None)
                  if getattr(tpu_model, "use_engine", True) else None)
        self._bytes_per_depth = (engine.depth_weight_bytes()
                                 if engine is not None
                                 else tpu_model.graph.bytes_per_depth())

    def segment_report(self, depth_lo: int, depth_hi: int) -> Tuple[int, int]:
        # fast path: bytes-only query, no per-layer placement dict
        fast = getattr(self._m, "segment_report_bytes", None)
        if fast is not None:
            return fast(depth_lo, depth_hi)
        rep = self._m.segment_memory(depth_lo, depth_hi)
        return rep.device_bytes, rep.host_bytes

    def depth_bytes(self, depth: int) -> int:
        return self._bytes_per_depth[depth]
