"""Analytical Edge TPU performance/memory model.

This container has no Edge TPUs (and no Edge TPU compiler), so the paper's
experiments are reproduced through a calibrated analytical model of the
device, playing the role the real hardware plays in the paper:

* **Memory model** — mirrors the Edge TPU compiler report (paper §4.2):
  8 MiB on-chip; instructions + activations reserve a slice; weights are
  placed *whole-layer-at-a-time* in depth order until on-chip memory is
  exhausted; the rest lives in host memory and is re-streamed over PCIe on
  every inference.  This reproduces the abrupt host-usage steps of Table 2.
* **Time model** — a stage's latency = systolic compute time (at a
  calibrated fraction of the 4 TOPS peak) + PCIe streaming of host-resident
  weights + stage I/O.  Calibration constants are fit to the paper's
  single-TPU measurements (Figs. 2–4, Table 5) and recorded here.
* **Pipeline model** — B inputs through s stages: fill + steady state,
  ``T = sum(t_i) + (B-1) * max(t_i)`` (in-order queues, no bubbles beyond
  the slowest stage — matches the paper's executor, Fig. 5).

The model is intentionally simple and *documented as a model*: benchmark
outputs state that times are analytical.  The paper's qualitative claims
(stepped single-TPU curve, unbalanced SEGM_COMP, SEGM_BALANCED ≥ SEGM_COMP,
super-linear multi-TPU speedups) are validated against it, and the
quantitative constants put the reproduced tables in the paper's ranges.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

from .cost_engine import SegmentCostEngine
from .costs import greedy_layer_placement, weight_capacity_bytes
from .graph import LayerGraph
from .segmentation import segment_ranges

MIB = 1024 * 1024


@dataclasses.dataclass(frozen=True)
class EdgeTPUSpec:
    """Calibrated Edge TPU constants.

    Calibration (documented in EXPERIMENTS.md §Paper-model).  The time model
    has two compute terms — MAC throughput and *weight loading into the
    systolic array* (``t = macs/(eff*peak) + weight_bytes/load_rate``).  The
    weight-load term dominating on real CNNs explains two paper
    observations at once: (a) per-segment time tracks segment *size*, so
    the params-balanced split is also time-balanced (Fig. 10); (b) real
    models sustain ~0.5 int8 TOPS while pure-conv synthetic models do
    better (Fig. 2).  Fit of Table 5 single-TPU times with these defaults:
    ResNet50 33 vs 29.7 ms, ResNet101 54 vs 44.7, ResNet152 72 vs 68.9,
    InceptionV3 33 vs 37.0, DenseNet121 15 vs 14.9 (documented per-model in
    benchmarks/segm_real.py output).
    * ``pcie_gbps`` — effective host->device streaming for host-resident
      weights (per inference; the delegate cannot cache what does not fit).
    * ``spill_event_overhead_s`` — fixed per-inference driver cost once any
      weights are host-resident.  The paper's Fig. 4 drop magnitude is
      larger than bandwidth alone; the residual is a documented limit.
    * capacity: ``onchip - fixed_reserve - act_factor * max_activation`` —
      reconciles Table 2 (whole-model fits at ~6.9 MiB) with Table 4
      (a 6.26 MiB segment of a high-activation synthetic model spills).
    """

    onchip_bytes: int = 8 * MIB          # datasheet: 8 MiB on-chip
    peak_tops: float = 4.0               # datasheet: 4 TOPS int8 (2 ops/MAC)
    mac_efficiency: float = 1.0          # MXU term: fraction of peak
    weight_load_gbps: float = 1.5        # systolic-array weight fill rate
    pcie_gbps: float = 2.0
    fixed_reserve: int = int(0.1 * MIB)
    act_reserve_factor: float = 0.55     # fraction of the largest activation
                                         # charged against weight capacity
    spill_event_overhead_s: float = 8.0e-3
    per_inference_overhead_s: float = 3.0e-4   # invoke/driver overhead
    queue_hop_s: float = 1.2e-4          # host queue hand-off between stages

    @property
    def macs_per_s(self) -> float:
        return self.mac_efficiency * self.peak_tops * 1e12 / 2.0


@dataclasses.dataclass
class MemoryReport:
    """Analog of the Edge TPU compiler's per-segment memory report."""

    device_bytes: int
    host_bytes: int
    layer_placement: Dict[str, str]      # layer name -> "device" | "host"

    @property
    def spills(self) -> bool:
        return self.host_bytes > 0


class EdgeTPUModel:
    """Analytical device model bound to a :class:`LayerGraph`.

    ``use_engine=True`` (default) answers segment queries through the
    precomputed :class:`~repro.core.cost_engine.SegmentCostEngine` —
    bit-identical results, O(1) instead of O(layers) per query.
    ``use_engine=False`` keeps the naive walk-every-layer paths (the
    before/after baseline for benchmarks/planner_bench.py).

    ``cost_source`` selects where per-depth costs come from (a
    :class:`~repro.profiling.sources.CostSource`; ``None`` and the
    analytic source are equivalent and bit-identical).  The naive
    ``use_engine=False`` paths are the closed-form analytic model by
    definition and ignore it.
    """

    def __init__(self, graph: LayerGraph, spec: Optional[EdgeTPUSpec] = None,
                 use_engine: bool = True, cost_source=None):
        self.graph = graph
        self.spec = spec or EdgeTPUSpec()
        self.use_engine = use_engine
        self.cost_source = cost_source
        self._engine: Optional[SegmentCostEngine] = None
        self._depths = graph.depths()
        self._levels = graph.levels()

    @property
    def engine(self) -> SegmentCostEngine:
        """Lazily built segment-cost fast path (always available)."""
        if self._engine is None:
            self._engine = SegmentCostEngine(self.graph, self.spec,
                                             cost_source=self.cost_source)
        return self._engine

    # -- memory -------------------------------------------------------------
    def segment_memory(self, depth_lo: int, depth_hi: int) -> MemoryReport:
        """Whole-layer greedy placement in depth order (paper §4.2: 'the
        neural layer is the minimal storage unit')."""
        if self.use_engine:
            device, host, placement = self.engine.segment_placement(
                depth_lo, depth_hi)
            return MemoryReport(device_bytes=device, host_bytes=host,
                                layer_placement=placement)
        spec = self.spec
        layers = [n for lvl in self._levels[depth_lo:depth_hi + 1] for n in lvl]
        act = max([self.graph.nodes[n].out_bytes for n in layers] + [0])
        capacity = weight_capacity_bytes(spec.onchip_bytes,
                                         spec.fixed_reserve,
                                         spec.act_reserve_factor, act)
        device_used, host_used, placement = greedy_layer_placement(
            layers, [self.graph.nodes[n].bytes for n in layers], capacity)
        return MemoryReport(device_bytes=device_used, host_bytes=host_used,
                            layer_placement=placement)

    def segment_report_bytes(self, depth_lo: int, depth_hi: int
                             ) -> Tuple[int, int]:
        """(device, host) bytes only — the refiner's hot query; skips the
        per-layer placement dict on the engine path."""
        if self.use_engine:
            return self.engine.segment_split(depth_lo, depth_hi)
        rep = self.segment_memory(depth_lo, depth_hi)
        return rep.device_bytes, rep.host_bytes

    def whole_model_memory(self) -> MemoryReport:
        return self.segment_memory(0, self.graph.depth - 1)

    # -- time ----------------------------------------------------------------
    def segment_time(self, depth_lo: int, depth_hi: int,
                     mem: Optional[MemoryReport] = None) -> float:
        """Per-inference latency of one segment on one TPU (seconds)."""
        if self.use_engine and mem is None:
            return self.engine.segment_time(depth_lo, depth_hi)
        spec = self.spec
        mem = mem or self.segment_memory(depth_lo, depth_hi)
        layers = [n for lvl in self._levels[depth_lo:depth_hi + 1] for n in lvl]
        macs = sum(self.graph.nodes[n].macs for n in layers)
        weight_bytes = sum(self.graph.nodes[n].bytes for n in layers)
        t_compute = (macs / spec.macs_per_s
                     + weight_bytes / (spec.weight_load_gbps * 1e9))
        t_stream = mem.host_bytes / (spec.pcie_gbps * 1e9)
        t_spill = spec.spill_event_overhead_s if mem.host_bytes > 0 else 0.0
        # stage input/output transfer through host queues (hoisted: the seed
        # rebuilt this O(depth * layers) array twice per call)
        obd = self.graph.out_bytes_per_depth()
        in_bytes = obd[depth_lo - 1] if depth_lo > 0 else 0
        out_bytes = obd[depth_hi] if depth_hi < self.graph.depth - 1 else 0
        t_io = (in_bytes + out_bytes) / (spec.pcie_gbps * 1e9)
        return (t_compute + t_stream + t_spill + t_io
                + spec.per_inference_overhead_s)

    def single_tpu_time(self) -> float:
        return self.segment_time(0, self.graph.depth - 1)

    def single_tpu_tops(self) -> float:
        """Sustained int8 TOPS for the whole model on one TPU (Fig. 2)."""
        t = self.single_tpu_time()
        return 2.0 * self.graph.total_macs / t / 1e12

    # -- pipeline -------------------------------------------------------------
    def stage_times(self, cuts: Sequence[int]) -> List[float]:
        ranges = segment_ranges(self.graph.depth, cuts)
        return [self.segment_time(lo, hi) for lo, hi in ranges]

    def stage_memories(self, cuts: Sequence[int]) -> List[MemoryReport]:
        ranges = segment_ranges(self.graph.depth, cuts)
        return [self.segment_memory(lo, hi) for lo, hi in ranges]

    def pipeline_batch_time(self, cuts: Sequence[int], batch: int = 15) -> float:
        """Latency of a `batch`-input batch through the stage pipeline.

        Fill (one traversal of all stages) + steady state paced by the
        slowest stage + per-hop queue overhead (paper Fig. 5 executor).
        """
        times = self.stage_times(cuts)
        hop = self.spec.queue_hop_s * len(times)
        return sum(times) + (batch - 1) * max(times) + hop * batch

    def single_tpu_batch_time(self, batch: int = 15) -> float:
        return batch * self.single_tpu_time()

    def speedup(self, cuts: Sequence[int], batch: int = 15) -> float:
        return (self.single_tpu_batch_time(batch)
                / self.pipeline_batch_time(cuts, batch))

    # -- SEGM_PROF cost hook --------------------------------------------------
    def prof_cost(self, batch: int = 15):
        """Cost function for segmentation.prof_split (lower = better)."""
        def cost(cuts: List[int]) -> float:
            return self.pipeline_batch_time(cuts, batch)
        return cost
