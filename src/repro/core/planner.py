"""Raising-stub shim for the removed legacy planning entry points.

The plan types (:class:`~repro.core.placement.StagePlacement`,
:class:`~repro.core.placement.PlacementPlan`) and the stage-count rules
(``min_stages_to_fit`` / ``min_stages_no_spill``) live in
:mod:`repro.core.placement`; import them from there (or from
``repro.core``).  This module deliberately re-exports **nothing** — it
exists only so stale ``repro.core.planner.plan(...)`` call sites fail
fast with the migration pointer instead of an ImportError three frames
deep.

The legacy orchestration entry points ``plan`` / ``plan_placement`` /
``plan_summary_table`` spent their one deprecation release as delegating
shims (PR 4) and were removed in PR 5; the repo's own surface migrated to
the ``repro.api`` front door (DeploymentSpec -> plan -> Deployment), and
CI runs ``-W error::DeprecationWarning`` to keep it that way.
"""
from __future__ import annotations


def _removed(entry: str, replacement: str):
    """The legacy entry points had their one deprecation release (shims
    delegating to the registry, warning once per process); they are now
    stubs that fail fast with the migration pointer."""
    raise RuntimeError(
        f"repro.core.planner.{entry} was removed after its deprecation "
        f"release; use {replacement} (see EXPERIMENTS.md §Deployment API)")


def plan(*_args, **_kwargs):
    """REMOVED — use ``repro.api.plan``::

        from repro.api import DeploymentSpec, plan
        plan(DeploymentSpec(stages=n, strategy="balanced"), graph=graph)
    """
    _removed("plan",
             "repro.api.plan(DeploymentSpec(stages=..., strategy=...))")


def plan_placement(*_args, **_kwargs):
    """REMOVED — use ``repro.api.plan``::

        from repro.api import DeploymentSpec, plan
        plan(DeploymentSpec(topology=topo, strategy="placement"), graph=g)
    """
    _removed(
        "plan_placement",
        "repro.api.plan(DeploymentSpec(topology=..., strategy='placement'))")


def plan_summary_table(*_args, **_kwargs):
    """REMOVED — call ``repro.api.plan(DeploymentSpec(...))`` per strategy."""
    _removed("plan_summary_table",
             "repro.api.plan(DeploymentSpec(...)) per strategy")


def __getattr__(name: str):
    if name in ("PlacementPlan", "SegmentationPlan", "StagePlacement",
                "min_stages_to_fit", "min_stages_no_spill"):
        raise AttributeError(
            f"repro.core.planner.{name} moved to repro.core.placement; "
            f"import it from repro.core or repro.core.placement")
    raise AttributeError(
        f"module {__name__!r} has no attribute {name!r}")
