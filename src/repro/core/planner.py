"""Strategy dispatch: LayerGraph + strategy name -> SegmentationPlan.

The plan is the single hand-off object between the paper's algorithms and the
executors: the host-threaded pipeline (core/pipeline.py), the SPMD pipeline
(launch/pipeline_spmd.py), and the benchmarks all consume a plan.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

from .edge_tpu_model import EdgeTPUModel
from .graph import LayerGraph
from .refine import GraphReporter, MemoryReporter, RefinementResult, refine_cuts
from .segmentation import (balanced_split, comp_split, imbalance,
                           minimax_time_split, prof_split, segment_ranges,
                           segment_sums)

STRATEGIES = ("comp", "prof", "balanced", "balanced_norefine",
              "balanced_cost", "opt")


@dataclasses.dataclass
class SegmentationPlan:
    """Stage assignment for a model pipeline."""

    graph_name: str
    strategy: str
    n_stages: int
    cuts: List[int]                       # s-1 cut depths
    stage_depth_ranges: List[tuple]       # [(lo, hi)] inclusive
    stage_layers: List[List[str]]         # layer names per stage
    stage_params: List[int]
    refinement: Optional[RefinementResult] = None

    @property
    def imbalance(self) -> int:
        """Δs (paper Table 5): largest minus smallest stage, in params."""
        return max(self.stage_params) - min(self.stage_params)

    def describe(self) -> str:
        segs = ", ".join(
            f"S{i}[d{lo}-{hi}]={p/1e6:.2f}M"
            for i, ((lo, hi), p) in enumerate(
                zip(self.stage_depth_ranges, self.stage_params)))
        return (f"{self.graph_name} / {self.strategy} x{self.n_stages}: {segs} "
                f"(Δs={self.imbalance/1e6:.2f}M)")


def plan(
    graph: LayerGraph,
    n_stages: int,
    strategy: str = "balanced",
    reporter: Optional[MemoryReporter] = None,
    tpu_model: Optional[EdgeTPUModel] = None,
    prof_batch: int = 15,
) -> SegmentationPlan:
    """Produce a SegmentationPlan with the requested paper strategy.

    * ``comp``               — SEGM_COMP (layer-count balanced; vendor model)
    * ``prof``               — SEGM_PROF (exhaustive; shallow models only)
    * ``balanced_norefine``  — SEGM_BALANCED step 2 only (Algorithm 1)
    * ``balanced``           — SEGM_BALANCED steps 2+3 (refinement with the
                               supplied memory reporter; defaults to the
                               analytical Edge TPU reporter)
    * ``balanced_cost``      — BEYOND-PAPER: Algorithm 1 run over modeled
                               per-depth *time* (MAC + weight-load terms)
                               instead of raw params, then §6.1.3
                               refinement.  Fixes the residual imbalance on
                               archs whose MAC intensity varies with depth
                               (e.g. high-resolution early CNN stages).
    * ``opt``                — BEYOND-PAPER: time-balanced minimax DP over
                               modeled *stage time* (compute + weight-load +
                               stream + I/O, priced by the
                               SegmentCostEngine).  O(d·s·log d) via a
                               crossing-point search (exact when the cost is
                               monotone; the stage-I/O boundary term can
                               perturb it a few percent off the true optimum
                               — the exact=True oracle in tests/benches
                               measures the gap).  Prof-quality plans for
                               deep graphs where SEGM_PROF's C(d-1, s-1)
                               search is infeasible, and guaranteed never
                               worse than ``balanced`` on max modeled stage
                               time (falls back to the balanced cuts if the
                               DP does not improve).
    """
    if strategy not in STRATEGIES:
        raise ValueError(f"unknown strategy {strategy!r}; pick from {STRATEGIES}")
    P = graph.params_per_depth()
    d = len(P)
    refinement = None

    if strategy == "comp":
        cuts = comp_split(P, n_stages)
    elif strategy == "prof":
        model = tpu_model or EdgeTPUModel(graph)
        cuts = prof_split(P, n_stages, model.prof_cost(batch=prof_batch))
    elif strategy == "balanced_norefine":
        cuts = balanced_split(P, n_stages)
    elif strategy == "balanced_cost":
        model = tpu_model or EdgeTPUModel(graph)
        spec = model.spec
        # integer per-depth cost in nanoseconds: MAC term + weight-load term
        C = [int(1e9 * (m / spec.macs_per_s
                        + b / (spec.weight_load_gbps * 1e9)))
             for m, b in zip(graph.macs_per_depth(),
                             graph.bytes_per_depth())]
        cuts = balanced_split(C, n_stages)
        if reporter is None:
            reporter = GraphReporter(model)
        refinement = refine_cuts(cuts, d, reporter)
        if refinement.converged:
            cuts = refinement.cuts
    elif strategy == "opt":
        model = tpu_model or EdgeTPUModel(graph)
        cuts = minimax_time_split(d, n_stages, model.segment_time)
        # hard guarantee: never worse than the balanced plan on the max
        # modeled stage time (the pipeline's pacing quantity)
        base = plan(graph, n_stages, "balanced", reporter=reporter,
                    tpu_model=model, prof_batch=prof_batch)
        if max(model.stage_times(base.cuts)) < max(model.stage_times(cuts)):
            cuts = base.cuts
            refinement = base.refinement
    else:  # balanced = Algorithm 1 + §6.1.3 refinement
        cuts = balanced_split(P, n_stages)
        if reporter is None:
            reporter = GraphReporter(tpu_model or EdgeTPUModel(graph))
        refinement = refine_cuts(cuts, d, reporter)
        if refinement.converged:
            cuts = refinement.cuts
        # else: spill is unavoidable at this stage count — keep the
        # Algorithm-1 optimum rather than the refiner's wandering point

    ranges = segment_ranges(d, cuts)
    # slice the cached levels (O(L) total) instead of re-scanning the whole
    # graph per stage (O(s * L))
    levels = graph.levels()
    layers = [[n for lvl in levels[lo:hi + 1] for n in lvl]
              for lo, hi in ranges]
    params = segment_sums(P, cuts)
    return SegmentationPlan(
        graph_name=graph.name, strategy=strategy, n_stages=n_stages,
        cuts=list(cuts), stage_depth_ranges=ranges, stage_layers=layers,
        stage_params=params, refinement=refinement)


def min_stages_to_fit(graph: LayerGraph, capacity_bytes: int) -> int:
    """ceil(model_size / capacity): the paper's TPU-count rule (Table 5 note:
    'a model occupying S MiB has been fragmented into ceil(S/8) TPUs')."""
    total = graph.total_bytes
    return max(1, -(-total // capacity_bytes))


def min_stages_no_spill(graph: LayerGraph,
                        tpu_model: Optional[EdgeTPUModel] = None,
                        max_extra: int = 4) -> int:
    """The paper's working rule (§5.2.2): 'the minimum number of TPUs that
    would ideally avoid host memory usage' — smallest n whose refined
    balanced plan leaves every segment on-device."""
    model = tpu_model or EdgeTPUModel(graph)
    start = min_stages_to_fit(graph, model.spec.onchip_bytes)
    for n in range(start, start + max_extra + 1):
        if n >= graph.depth:
            return n
        pl = plan(graph, n, "balanced", tpu_model=model)
        if all(m.host_bytes == 0 for m in model.stage_memories(pl.cuts)):
            return n
    return start + max_extra


def plan_summary_table(graph: LayerGraph, n_stages: int,
                       strategies: Sequence[str] = ("comp", "balanced")) -> Dict[str, SegmentationPlan]:
    return {s: plan(graph, n_stages, s) for s in strategies}
