"""Layer graph: the model representation the segmentation algorithms operate on.

The paper (§6.1.1) treats a model as a feed-forward DAG of layers and assigns
each layer a *depth* — the maximum distance from the input, computed via a
topological order.  Segmentation then only considers *horizontal cuts*: every
open path is cut at the same depth, so a cut after depth ``i`` separates all
layers with depth ``<= i`` from all layers with depth ``> i``.

``LayerGraph`` is framework-agnostic: CNN builders (models/cnn.py) and the LM
builders (models/transformer.py etc.) both lower to it, so the same
SEGM_COMP / SEGM_PROF / SEGM_BALANCED machinery applies to all architectures.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Dict, Iterable, List, Optional, Sequence, Tuple


@dataclasses.dataclass(frozen=True)
class LayerNode:
    """One layer (DAG node) with the costs the segmentation strategies use.

    params:            trainable parameter count (the paper's intrinsic balance
                       metric — 1 byte/param after int8 quantization).
    macs:              multiply-accumulate ops per single-input forward pass.
    out_bytes:         activation bytes produced per input (stage-to-stage
                       transfer cost when a cut lands right after this layer).
    weight_bytes:      storage bytes for the layer's weights.  Defaults to
                       ``params`` (int8) but LM archs use 2*params (bf16).
    """

    name: str
    params: int
    macs: int
    out_bytes: int = 0
    weight_bytes: Optional[int] = None
    kind: str = "generic"

    @property
    def bytes(self) -> int:
        return self.params if self.weight_bytes is None else self.weight_bytes


class LayerGraph:
    """Feed-forward DAG of :class:`LayerNode` with topological-depth utilities.

    Depth/level/per-depth aggregates are memoized after the first query and
    invalidated by :meth:`add` — the planner and the analytical device model
    query them thousands of times per plan search, so recomputing the
    topological order each call dominated plan-search wall time in profiles.
    Mutating ``_edges``/``_redges`` directly bypasses the invalidation and is
    unsupported after the first query.  ``cache=False`` restores the
    recompute-every-call behaviour (used by benchmarks/planner_bench.py to
    measure the uncached baseline).
    """

    def __init__(self, name: str = "model", cache: bool = True):
        self.name = name
        self.nodes: Dict[str, LayerNode] = {}
        self._edges: Dict[str, List[str]] = {}      # src -> [dst]
        self._redges: Dict[str, List[str]] = {}     # dst -> [src]
        self._order: List[str] = []                 # insertion order
        self._cache_enabled = cache
        self._cache: Dict[str, object] = {}

    def set_cache_enabled(self, enabled: bool) -> None:
        self._cache_enabled = enabled
        self._cache.clear()

    def _cached(self, key: str, compute):
        """Memoize `compute()` under `key`; results are shared — treat them
        as immutable."""
        if not self._cache_enabled:
            return compute()
        if key not in self._cache:
            self._cache[key] = compute()
        return self._cache[key]

    # -- construction -------------------------------------------------------
    def add(self, node: LayerNode, inputs: Sequence[str] = ()) -> str:
        if node.name in self.nodes:
            raise ValueError(f"duplicate layer name {node.name!r}")
        for src in inputs:
            if src not in self.nodes:
                raise ValueError(f"unknown input {src!r} for layer {node.name!r}")
        self._cache.clear()
        self.nodes[node.name] = node
        self._order.append(node.name)
        self._edges[node.name] = []
        self._redges[node.name] = list(inputs)
        for src in inputs:
            self._edges[src].append(node.name)
        return node.name

    def add_layer(self, name: str, params: int = 0, macs: int = 0,
                  out_bytes: int = 0, inputs: Sequence[str] = (),
                  weight_bytes: Optional[int] = None, kind: str = "generic") -> str:
        return self.add(
            LayerNode(name=name, params=params, macs=macs, out_bytes=out_bytes,
                      weight_bytes=weight_bytes, kind=kind),
            inputs,
        )

    # -- queries ------------------------------------------------------------
    def __len__(self) -> int:
        return len(self.nodes)

    def successors(self, name: str) -> List[str]:
        return self._edges[name]

    def predecessors(self, name: str) -> List[str]:
        return self._redges[name]

    def topological_order(self) -> List[str]:
        """Kahn's algorithm; raises on cycles (models must be feed-forward)."""
        return self._cached("topo", self._topological_order)

    def _topological_order(self) -> List[str]:
        indeg = {n: len(self._redges[n]) for n in self.nodes}
        # deterministic: seed queue in insertion order
        q = deque(n for n in self._order if indeg[n] == 0)
        out: List[str] = []
        while q:
            n = q.popleft()
            out.append(n)
            for m in self._edges[n]:
                indeg[m] -= 1
                if indeg[m] == 0:
                    q.append(m)
        if len(out) != len(self.nodes):
            raise ValueError("layer graph has a cycle; feed-forward DAG required")
        return out

    def depths(self) -> Dict[str, int]:
        """Depth of each layer = max distance from any input (paper §6.1.1)."""
        return self._cached("depths", self._depths)

    def _depths(self) -> Dict[str, int]:
        depth: Dict[str, int] = {}
        for n in self.topological_order():
            preds = self._redges[n]
            depth[n] = 0 if not preds else 1 + max(depth[p] for p in preds)
        return depth

    @property
    def depth(self) -> int:
        """Total model depth d (number of depth levels)."""
        d = self.depths()
        return 1 + max(d.values()) if d else 0

    # -- per-depth aggregation (the P array of Algorithm 1) ------------------
    def levels(self) -> List[List[str]]:
        """Layer names grouped by depth, ascending."""
        return self._cached("levels", self._levels)

    def _levels(self) -> List[List[str]]:
        d = self.depths()
        levels: List[List[str]] = [[] for _ in range(self.depth)]
        for n in self._order:
            levels[d[n]].append(n)
        return levels

    def params_per_depth(self) -> List[int]:
        """P[i] = number of parameters at depth i (paper §6.1.2)."""
        return self._cached("params_per_depth", lambda: [
            sum(self.nodes[n].params for n in lvl) for lvl in self.levels()])

    def bytes_per_depth(self) -> List[int]:
        return self._cached("bytes_per_depth", lambda: [
            sum(self.nodes[n].bytes for n in lvl) for lvl in self.levels()])

    def macs_per_depth(self) -> List[int]:
        return self._cached("macs_per_depth", lambda: [
            sum(self.nodes[n].macs for n in lvl) for lvl in self.levels()])

    def out_bytes_per_depth(self) -> List[int]:
        """Activation bytes crossing a horizontal cut placed after each depth.

        For a cut after depth i, the transferred tensors are the outputs of
        every layer at depth <= i that feeds a layer at depth > i.
        """
        return self._cached("out_bytes_per_depth", self._out_bytes_per_depth)

    def _out_bytes_per_depth(self) -> List[int]:
        d = self.depths()
        out = [0] * self.depth
        for n in self._order:
            node = self.nodes[n]
            succs = self._edges[n]
            tgt_depths = [d[s] for s in succs]
            if not tgt_depths:
                continue
            hi = max(tgt_depths)
            # this node's output crosses every cut in [d[n], hi-1]
            for cut in range(d[n], hi):
                out[cut] += node.out_bytes
        return out

    # -- totals ---------------------------------------------------------------
    @property
    def total_params(self) -> int:
        return sum(n.params for n in self.nodes.values())

    @property
    def total_macs(self) -> int:
        return sum(n.macs for n in self.nodes.values())

    @property
    def total_bytes(self) -> int:
        return sum(n.bytes for n in self.nodes.values())

    def layers_in_depth_range(self, lo: int, hi: int) -> List[str]:
        """Layers whose depth is in [lo, hi] — i.e. one pipeline segment."""
        d = self.depths()
        return [n for n in self._order if lo <= d[n] <= hi]

    def summary(self) -> str:
        return (f"LayerGraph({self.name}: {len(self)} layers, depth {self.depth}, "
                f"{self.total_params/1e6:.1f}M params, {self.total_macs/1e6:.0f}M MACs)")


def chain_graph(name: str, sizes: Iterable[Tuple[str, int, int, int]]) -> LayerGraph:
    """Build a simple chain model: sizes = [(layer_name, params, macs, out_bytes)]."""
    g = LayerGraph(name)
    prev: Tuple[str, ...] = ()
    for lname, params, macs, out_b in sizes:
        g.add_layer(lname, params=params, macs=macs, out_bytes=out_b, inputs=prev)
        prev = (lname,)
    return g
