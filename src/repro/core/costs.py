"""Per-layer cost helpers shared by the CNN and LM graph builders.

MAC conventions follow the paper (§3): a conv layer's MACs = #params × output
spatial dims (stride-1, zero padding keeps W×H constant); a dense layer's
MACs = #params.  Activation byte counts assume int8 for the quantized CNN
path (1 B/elt) and bf16 (2 B/elt) for LM archs.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Tuple


def conv2d_params(cin: int, cout: int, kh: int, kw: int, bias: bool = True) -> int:
    return cin * cout * kh * kw + (cout if bias else 0)


def conv2d_macs(cin: int, cout: int, kh: int, kw: int,
                out_h: int, out_w: int) -> int:
    return cin * cout * kh * kw * out_h * out_w


def dw_conv2d_params(c: int, kh: int, kw: int, bias: bool = True) -> int:
    return c * kh * kw + (c if bias else 0)


def dw_conv2d_macs(c: int, kh: int, kw: int, out_h: int, out_w: int) -> int:
    return c * kh * kw * out_h * out_w


def dense_params(fin: int, fout: int, bias: bool = True) -> int:
    return fin * fout + (fout if bias else 0)


def dense_macs(fin: int, fout: int) -> int:
    return fin * fout


def conv_out_hw(h: int, w: int, kh: int, kw: int, stride: int,
                padding: str = "same") -> Tuple[int, int]:
    if padding == "same":
        return math.ceil(h / stride), math.ceil(w / stride)
    if padding == "valid":
        return (h - kh) // stride + 1, (w - kw) // stride + 1
    raise ValueError(padding)


@dataclasses.dataclass(frozen=True)
class TransformerBlockCost:
    """Parameter/MAC breakdown of one decoder block (per token)."""

    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    head_dim: int
    qkv_bias: bool = False
    n_experts: int = 0       # 0 = dense FFN
    top_k: int = 0
    ffn_gated: bool = True   # SwiGLU: 3 matrices; plain MLP: 2

    @property
    def attn_params(self) -> int:
        hd = self.head_dim
        q = self.d_model * self.n_heads * hd
        kv = 2 * self.d_model * self.n_kv_heads * hd
        o = self.n_heads * hd * self.d_model
        b = (self.n_heads * hd + 2 * self.n_kv_heads * hd) if self.qkv_bias else 0
        return q + kv + o + b

    @property
    def ffn_params_per_expert(self) -> int:
        m = 3 if self.ffn_gated else 2
        return m * self.d_model * self.d_ff

    @property
    def ffn_params(self) -> int:
        n = max(1, self.n_experts)
        router = self.d_model * self.n_experts if self.n_experts else 0
        return n * self.ffn_params_per_expert + router

    @property
    def block_params(self) -> int:
        norms = 2 * self.d_model
        return self.attn_params + self.ffn_params + norms

    def block_macs(self, seq_len: int, kv_len: int) -> int:
        """MACs per sequence (projections + attention scores + FFN)."""
        proj = seq_len * self.attn_params
        scores = 2 * seq_len * kv_len * self.n_heads * self.head_dim
        active = max(1, self.top_k if self.n_experts else 1)
        ffn = seq_len * active * self.ffn_params_per_expert
        router = seq_len * self.d_model * self.n_experts if self.n_experts else 0
        return proj + scores + ffn + router
