"""Per-layer cost helpers shared by the CNN and LM graph builders, plus the
single bytes-accounting model every memory consumer prices with.

MAC conventions follow the paper (§3): a conv layer's MACs = #params × output
spatial dims (stride-1, zero padding keeps W×H constant); a dense layer's
MACs = #params.  Activation byte counts assume int8 for the quantized CNN
path (1 B/elt) and bf16 (2 B/elt) for LM archs.

The memory helpers (:func:`weight_capacity_bytes`,
:func:`greedy_layer_split`) are the paper's §4.2 compiler-report model in
one place: the :class:`~repro.core.cost_engine.SegmentCostEngine`, the
naive :class:`~repro.core.edge_tpu_model.EdgeTPUModel` paths, and the
refinement reporter all call them, so device/host byte accounting cannot
drift between the planner, the refiner, and the CostSource layer.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, Sequence, Tuple


# ---------------------------------------------------------------------------
# bytes accounting (paper §4.2: the Edge TPU compiler's memory report)
# ---------------------------------------------------------------------------
def weight_capacity_bytes(onchip_bytes: int, fixed_reserve: int,
                          act_reserve_factor: float,
                          max_activation: int) -> int:
    """Weight capacity of one device: on-chip memory minus the fixed
    (instructions) reserve minus the activation reserve — the exact
    expression (and float evaluation order) every capacity query uses."""
    return int(onchip_bytes - fixed_reserve
               - act_reserve_factor * max_activation)


def greedy_layer_split(layer_bytes: Sequence[int], capacity: int,
                       device0: int = 0) -> Tuple[int, int]:
    """(device_bytes, host_bytes) of the paper's greedy whole-layer
    placement: layers are placed in order while they fit; a rejected layer
    goes to host, but smaller later layers may still fit (`§4.2: 'the
    neural layer is the minimal storage unit'`).  ``device0`` seeds the
    device counter — the cost engine's binary-searched fast path hands the
    tail scan its already-placed prefix."""
    device = device0
    host = 0
    for b in layer_bytes:
        if device + b <= capacity:
            device += b
        else:
            host += b
    return device, host


def greedy_layer_placement(names: Sequence[str],
                           layer_bytes: Sequence[int], capacity: int
                           ) -> Tuple[int, int, Dict[str, str]]:
    """Full (device, host, {layer: "device"|"host"}) greedy placement —
    the per-layer report variant of :func:`greedy_layer_split`."""
    device = 0
    host = 0
    placement: Dict[str, str] = {}
    for n, b in zip(names, layer_bytes):
        if device + b <= capacity:
            device += b
            placement[n] = "device"
        else:
            host += b
            placement[n] = "host"
    return device, host, placement


def conv2d_params(cin: int, cout: int, kh: int, kw: int, bias: bool = True) -> int:
    return cin * cout * kh * kw + (cout if bias else 0)


def conv2d_macs(cin: int, cout: int, kh: int, kw: int,
                out_h: int, out_w: int) -> int:
    return cin * cout * kh * kw * out_h * out_w


def dw_conv2d_params(c: int, kh: int, kw: int, bias: bool = True) -> int:
    return c * kh * kw + (c if bias else 0)


def dw_conv2d_macs(c: int, kh: int, kw: int, out_h: int, out_w: int) -> int:
    return c * kh * kw * out_h * out_w


def dense_params(fin: int, fout: int, bias: bool = True) -> int:
    return fin * fout + (fout if bias else 0)


def dense_macs(fin: int, fout: int) -> int:
    return fin * fout


def conv_out_hw(h: int, w: int, kh: int, kw: int, stride: int,
                padding: str = "same") -> Tuple[int, int]:
    if padding == "same":
        return math.ceil(h / stride), math.ceil(w / stride)
    if padding == "valid":
        return (h - kh) // stride + 1, (w - kw) // stride + 1
    raise ValueError(padding)


@dataclasses.dataclass(frozen=True)
class TransformerBlockCost:
    """Parameter/MAC breakdown of one decoder block (per token)."""

    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    head_dim: int
    qkv_bias: bool = False
    n_experts: int = 0       # 0 = dense FFN
    top_k: int = 0
    ffn_gated: bool = True   # SwiGLU: 3 matrices; plain MLP: 2

    @property
    def attn_params(self) -> int:
        hd = self.head_dim
        q = self.d_model * self.n_heads * hd
        kv = 2 * self.d_model * self.n_kv_heads * hd
        o = self.n_heads * hd * self.d_model
        b = (self.n_heads * hd + 2 * self.n_kv_heads * hd) if self.qkv_bias else 0
        return q + kv + o + b

    @property
    def ffn_params_per_expert(self) -> int:
        m = 3 if self.ffn_gated else 2
        return m * self.d_model * self.d_ff

    @property
    def ffn_params(self) -> int:
        n = max(1, self.n_experts)
        router = self.d_model * self.n_experts if self.n_experts else 0
        return n * self.ffn_params_per_expert + router

    @property
    def block_params(self) -> int:
        norms = 2 * self.d_model
        return self.attn_params + self.ffn_params + norms

    def block_macs(self, seq_len: int, kv_len: int) -> int:
        """MACs per sequence (projections + attention scores + FFN)."""
        proj = seq_len * self.attn_params
        scores = 2 * seq_len * kv_len * self.n_heads * self.head_dim
        active = max(1, self.top_k if self.n_experts else 1)
        ffn = seq_len * active * self.ffn_params_per_expert
        router = seq_len * self.d_model * self.n_experts if self.n_experts else 0
        return proj + scores + ffn + router
