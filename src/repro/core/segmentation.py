"""Segmentation strategies (paper §5–§6).

All strategies partition the per-depth cost array ``P`` (``P[i]`` = parameters
at depth ``i``) into ``s`` contiguous segments by choosing ``s-1`` horizontal
cut positions.  A "cut position" ``c`` means the cut lies *after* depth ``c``,
so cuts ``[c0 < c1 < ...]`` produce segments ``[0..c0], [c0+1..c1], ...``.

Strategies:

* :func:`balanced_split` — the paper's Algorithm 1 (SEGM_BALANCED step 2):
  minimize the maximum segment sum via binary search over the bound plus a
  greedy feasibility check.  O(d log ΣP).
* :func:`comp_split` — model of the Edge TPU compiler (SEGM_COMP): balances
  layer *count* per segment, ignoring sizes (paper §5.2: "the compiler
  balances the number of layers in the segments, but not the number of model
  parameters").
* :func:`prof_split` — SEGM_PROF: exhaustive search over all C(d-1, s-1) cut
  placements, scoring each candidate with a caller-supplied cost function
  (the paper profiles real executions; we plug in the analytical Edge TPU
  pipeline model).  Only feasible for shallow models.
* :func:`dp_split` — exact minimax partition via dynamic programming,
  O(d^2 s).  Used as a property-test oracle for ``balanced_split``.
* :func:`placement_split` — BEYOND-PAPER joint search over cuts *and*
  per-stage replica counts under a fixed device budget (heterogeneous
  chain topologies; see core/topology.py and the planner's
  ``plan_placement``).
"""
from __future__ import annotations

import bisect
import itertools
from typing import Callable, List, Optional, Sequence, Tuple


def _validate(P: Sequence[int], s: int) -> None:
    if s < 1:
        raise ValueError(f"segments must be >= 1, got {s}")
    if len(P) == 0:
        raise ValueError("empty cost array")
    if s > len(P):
        raise ValueError(f"cannot split {len(P)} depth levels into {s} segments")
    if any(p < 0 for p in P):
        raise ValueError("cost array entries must be non-negative")


def split_check(P: Sequence[int], bound: int, s: int) -> Tuple[bool, List[int]]:
    """Greedy feasibility check (paper Algorithm 1, ``splitCheck``).

    Traverses ``P`` accumulating values into the current segment; opens a new
    segment whenever the running sum would exceed ``bound``.  Returns
    ``(feasible, cut_positions)`` where feasible means at most ``s`` segments
    were needed.
    """
    min_segms = 0
    params_sum = 0
    split_pos: List[int] = []
    for i, p in enumerate(P):
        params_sum += p
        if params_sum > bound:
            split_pos.append(i - 1)      # cut just before this depth
            min_segms += 1
            params_sum = p
    min_segms += 1                       # the last segment
    return min_segms <= s, split_pos


def _prefix_split_check(prefix: Sequence[int], bound: int,
                        s: int) -> Tuple[bool, List[int]]:
    """`split_check` over a precomputed prefix-sum array, one bisect per
    segment instead of a full traversal: O(s log d).

    Exactly equivalent to the greedy when ``bound >= max(P)`` (each greedy
    segment is the maximal prefix summing to <= bound) — which
    ``balanced_split``'s binary search guarantees.
    """
    d = len(prefix) - 1
    segs = 0
    start = 0
    cuts: List[int] = []
    while start < d:
        i = bisect.bisect_right(prefix, prefix[start] + bound,
                                start + 1, d + 1) - 1
        segs += 1
        if i >= d:
            break
        cuts.append(i - 1)
        start = i
    return segs <= s, cuts


def _greedy_cuts_exact(P: Sequence[int], bound: int, s: int) -> List[int]:
    """Greedy cuts for a known-feasible bound, padded to exactly s-1 cuts.

    ``split_check`` may need fewer than ``s`` segments; downstream code wants
    exactly ``s`` stages (one per device), so we split the largest remaining
    segments at valid positions (or emit empty segments only when unavoidable,
    which cannot happen because s <= len(P)).
    """
    ok, cuts = split_check(P, bound, s)
    assert ok
    cuts = list(cuts)
    # pad: split segments with >1 depth level until we have s-1 cuts
    while len(cuts) < s - 1:
        bounds = [-1] + cuts + [len(P) - 1]
        # candidate extra cut inside the widest segment
        best: Optional[Tuple[int, int]] = None  # (width, cut_pos)
        for lo, hi in zip(bounds[:-1], bounds[1:]):
            width = hi - lo
            if width >= 2:
                cand = (width, lo + (width // 2))
                if best is None or cand[0] > best[0]:
                    best = cand
        if best is None:  # all segments are single-level: impossible since s<=len(P)
            raise AssertionError("cannot pad cuts; s > len(P)?")
        cuts.append(best[1])
        cuts.sort()
    return cuts


def balanced_split(P: Sequence[int], s: int,
                   tie_break: str = "late") -> List[int]:
    """Paper Algorithm 1 (``balancedSplit``): minimax partition of ``P``.

    Binary-searches the smallest ``bound`` such that ``P`` splits into at most
    ``s`` segments each summing to ``<= bound``; returns the s-1 cut positions.

    ``tie_break="late"`` (default) selects, among minimax-optimal splits,
    the one produced by a *backward* greedy pass — slack accumulates in the
    early segments and weight in the late ones.  The last pipeline stage has
    no output transfer, so late-heavy optimal splits give slightly better
    stage times (a tie-break the paper's forward greedy leaves on the
    table; both variants achieve the same optimal bound).
    ``tie_break="early"`` reproduces the paper's forward greedy exactly.
    """
    _validate(P, s)
    if s == 1:
        return []
    prefix = list(itertools.accumulate(P, initial=0))
    lo = max(P)                 # an upper bound must exceed every element
    hi = prefix[-1]             # the array sum is an obvious upper bound
    best_bound = hi
    while lo <= hi:
        bound = (lo + hi) // 2
        ok, _ = _prefix_split_check(prefix, bound, s)
        if ok:
            best_bound = bound
            hi = bound - 1      # search for smaller upper bounds
        else:
            lo = bound + 1
    if tie_break == "late":
        d = len(P)
        rprefix = list(itertools.accumulate(reversed(P), initial=0))
        ok, rcuts = _prefix_split_check(rprefix, best_bound, s)
        if ok:
            cuts = sorted(d - 2 - c for c in rcuts)
            if all(0 <= c < d - 1 for c in cuts):
                cuts = _pad_cuts(P, cuts, s, best_bound)
                if cuts is not None:
                    return cuts
    return _greedy_cuts_exact(P, best_bound, s)


def _pad_cuts(P: Sequence[int], cuts: List[int], s: int,
              bound: int) -> Optional[List[int]]:
    """Pad a valid cut list to exactly s-1 cuts without exceeding bound.

    Extra cuts go into the widest segment, placed as LATE as the bound
    allows (late-heavy tie-break: the final pipeline stage has no output
    transfer, so weight should sit late)."""
    cuts = sorted(set(cuts))
    while len(cuts) < s - 1:
        bounds = [-1] + cuts + [len(P) - 1]
        widest = None
        for lo, hi in zip(bounds[:-1], bounds[1:]):
            width = hi - lo
            if width >= 2 and (widest is None or width > widest[0]):
                widest = (width, lo, hi)
        if widest is None:
            return None
        _, lo, hi = widest
        # latest cut c in (lo, hi) with sum(P[lo+1..c]) <= bound
        pos = None
        run = 0
        for c in range(lo + 1, hi):
            run += P[c]
            if run <= bound:
                pos = c
            else:
                break
        if pos is None:
            pos = lo + 1
        cuts.append(pos)
        cuts.sort()
    if max_segment(P, cuts) > bound:
        return None
    return cuts


def comp_split(P: Sequence[int], s: int) -> List[int]:
    """SEGM_COMP model: equal layer-count segments (paper §5.2 observation).

    Matches the observed vendor behaviour: d levels split as evenly as
    possible by *count*; remainders go to the LAST segments (the paper's
    Table 4 shows a 1-1-1-2 split of 5 layers — the extra layer lands at the
    end, overloading the final TPU).
    """
    _validate(P, s)
    d = len(P)
    base, rem = divmod(d, s)
    sizes = [base] * (s - rem) + [base + 1] * rem   # extras at the end
    cuts, pos = [], 0
    for size in sizes[:-1]:
        pos += size
        cuts.append(pos - 1)
    return cuts


def segment_sums(P: Sequence[int], cuts: Sequence[int]) -> List[int]:
    """Per-segment sums given cut positions."""
    bounds = [-1] + list(cuts) + [len(P) - 1]
    return [sum(P[lo + 1:hi + 1]) for lo, hi in zip(bounds[:-1], bounds[1:])]


def segment_ranges(n_levels: int, cuts: Sequence[int]) -> List[Tuple[int, int]]:
    """[(depth_lo, depth_hi)] per segment (inclusive)."""
    bounds = [-1] + list(cuts) + [n_levels - 1]
    return [(lo + 1, hi) for lo, hi in zip(bounds[:-1], bounds[1:])]


def max_segment(P: Sequence[int], cuts: Sequence[int]) -> int:
    return max(segment_sums(P, cuts))


def imbalance(P: Sequence[int], cuts: Sequence[int]) -> int:
    """Δs of the paper's Table 5: largest minus smallest segment size."""
    sums = segment_sums(P, cuts)
    return max(sums) - min(sums)


def prof_split(
    P: Sequence[int],
    s: int,
    cost_fn: Callable[[List[int]], float],
    max_candidates: int = 2_000_000,
) -> List[int]:
    """SEGM_PROF (paper §5.3): exhaustive profiling over all cut placements.

    ``cost_fn(cuts)`` models one profiled pipeline execution (lower = better).
    Raises if the search space exceeds ``max_candidates`` — the paper's point
    is precisely that this explodes for deep models (>3e9 for ResNet101 s=6).
    """
    _validate(P, s)
    d = len(P)
    import math
    n_cand = math.comb(d - 1, s - 1)
    if n_cand > max_candidates:
        raise ValueError(
            f"SEGM_PROF infeasible: C({d-1},{s-1}) = {n_cand} candidate "
            f"partitions exceeds limit {max_candidates} (paper §5.3)")
    best_cuts: Optional[List[int]] = None
    best_cost = float("inf")
    for combo in itertools.combinations(range(d - 1), s - 1):
        cuts = list(combo)
        c = cost_fn(cuts)
        if c < best_cost:
            best_cost, best_cuts = c, cuts
    assert best_cuts is not None
    return best_cuts


def minimax_time_split(
    d: int,
    s: int,
    cost_fn: Callable[[int, int], float],
    exact: bool = False,
) -> List[int]:
    """Minimax partition of depths [0..d-1] under an arbitrary range cost.

    ``cost_fn(lo, hi)`` is the modeled *stage time* of the segment covering
    depths [lo, hi] inclusive (compute + weight-load + stream + I/O via the
    SegmentCostEngine); the DP minimizes the maximum stage cost over all
    contiguous s-way partitions — the quantity that paces a pipeline.

    dp[k][i] = min over j of max(dp[k-1][j], cost(j+1, i)).  The fast path
    exploits that dp[k-1][j] is non-decreasing in j while cost(j+1, i) is
    non-increasing in j (both hold exactly for cumulative costs; the stage
    I/O boundary term can perturb them locally), binary-searching the
    crossing point per cell: O(d·s·log d) cost evaluations, each O(1) on the
    engine.  ``exact=True`` scans every j — O(d²·s) — and is the oracle the
    tests compare against.  Callers wanting a hard never-worse-than-balanced
    guarantee compare the result against Algorithm 1's cuts (planner "opt"
    does exactly that).
    """
    if s < 1:
        raise ValueError(f"segments must be >= 1, got {s}")
    if d < 1:
        raise ValueError("empty depth range")
    if s > d:
        raise ValueError(f"cannot split {d} depth levels into {s} segments")
    if s == 1:
        return []

    memo: dict = {}

    def cost(lo: int, hi: int) -> float:
        key = (lo, hi)
        v = memo.get(key)
        if v is None:
            v = memo[key] = cost_fn(lo, hi)
        return v

    INF = float("inf")
    prev = [cost(0, i) for i in range(d)]        # k = 1
    back: List[List[int]] = [[-1] * d for _ in range(s + 1)]
    for k in range(2, s + 1):
        cur = [INF] * d
        for i in range(k - 1, d):
            jlo, jhi = k - 2, i - 1
            if exact:
                best, best_j = INF, jlo
                for j in range(jlo, jhi + 1):
                    c = max(prev[j], cost(j + 1, i))
                    if c < best:
                        best, best_j = c, j
            else:
                # smallest j where the (non-decreasing) prefix optimum
                # overtakes the (non-increasing) last-segment cost
                lo_j, hi_j = jlo, jhi
                while lo_j < hi_j:
                    mid = (lo_j + hi_j) // 2
                    if prev[mid] >= cost(mid + 1, i):
                        hi_j = mid
                    else:
                        lo_j = mid + 1
                best, best_j = INF, jlo
                for j in (lo_j - 1, lo_j, lo_j + 1):   # hedge local wobbles
                    if jlo <= j <= jhi:
                        c = max(prev[j], cost(j + 1, i))
                        if c < best:
                            best, best_j = c, j
            cur[i] = best
            back[k][i] = best_j
        prev = cur

    cuts: List[int] = []
    i = d - 1
    for k in range(s, 1, -1):
        j = back[k][i]
        cuts.append(j)
        i = j
    cuts.reverse()
    return cuts


def placement_split(
    d: int,
    n_devices: int,
    cost_fn: Callable[[int, int, int, int], float],
    max_replicas: Optional[int] = None,
) -> Tuple[List[int], List[int]]:
    """Joint minimax search over cuts AND per-stage replica counts.

    Generalizes :func:`minimax_time_split` from "s stages, one device each"
    to a fixed *device budget*: stages consume consecutive runs of devices
    from an ordered topology, a stage may take ``k`` devices (``k``
    replicas, round-robin traffic split), and the number of stages is free
    (1..n_devices).  ``cost_fn(lo, hi, dev_lo, k)`` is the *effective*
    pacing time of depths [lo, hi] replicated over devices
    [dev_lo, dev_lo + k) — +inf marks an inadmissible device grouping
    (e.g. non-identical devices in one replica group).

    dp[n][i] = best max effective stage cost covering depths [0..i] with
    exactly the first ``n`` devices; transitions try every (last-stage
    start j+1, replica count k).  The answer takes the best ``n <=
    n_devices`` — a trailing device that does not help stays idle.  Exact
    search, O(d^2 · n^2) cost evaluations (each O(1) on the engine):
    the planner runs it for single-digit device budgets where this is
    milliseconds-to-seconds even for the deepest Table-1 models.

    Returns ``(cuts, replicas)`` — ``len(replicas) == len(cuts) + 1`` and
    ``sum(replicas) <= n_devices``.  With ``max_replicas=1`` this is an
    exact non-replicated minimax over at most ``n_devices`` stages.
    """
    if d < 1:
        raise ValueError("empty depth range")
    if n_devices < 1:
        raise ValueError(f"device budget must be >= 1, got {n_devices}")
    rmax = n_devices if max_replicas is None else max(1, max_replicas)

    memo: dict = {}

    def cost(lo: int, hi: int, dev_lo: int, k: int) -> float:
        key = (lo, hi, dev_lo, k)
        v = memo.get(key)
        if v is None:
            v = memo[key] = cost_fn(lo, hi, dev_lo, k)
        return v

    INF = float("inf")
    # dp[n][i]; back[n][i] = (j, k): last stage covers [j+1..i] on k devices
    dp = [[INF] * d for _ in range(n_devices + 1)]
    back: List[List[Optional[Tuple[int, int]]]] = [
        [None] * d for _ in range(n_devices + 1)]
    for n in range(1, n_devices + 1):
        dpn, backn = dp[n], back[n]
        for i in range(d):
            best, best_jk = INF, None
            for k in range(1, min(n, rmax) + 1):
                rem = n - k                  # devices left of the last stage
                if rem == 0:                 # single stage covers [0..i]
                    c = cost(0, i, 0, k)
                    if c < best:
                        best, best_jk = c, (-1, k)
                    continue
                dprem = dp[rem]
                for j in range(i):
                    if dprem[j] >= INF:
                        continue
                    tail = cost(j + 1, i, rem, k)
                    c = tail if dprem[j] < tail else dprem[j]
                    if c < best:
                        best, best_jk = c, (j, k)
            dpn[i] = best
            backn[i] = best_jk

    best_n = min((n for n in range(1, n_devices + 1)
                  if dp[n][d - 1] < INF),
                 key=lambda n: dp[n][d - 1], default=None)
    if best_n is None:
        raise ValueError("no admissible placement for this topology")

    cuts: List[int] = []
    replicas: List[int] = []
    n, i = best_n, d - 1
    while True:
        j, k = back[n][i]
        replicas.append(k)
        if j < 0:
            break
        cuts.append(j)
        n, i = n - k, j
    cuts.reverse()
    replicas.reverse()
    return cuts, replicas


def dp_split(P: Sequence[int], s: int) -> List[int]:
    """Exact minimax linear partition via DP — oracle for balanced_split.

    dp[k][i] = minimal possible maximum segment sum when splitting P[0..i]
    into k segments.  O(d^2 s); fine for tests, too slow for production use.
    """
    _validate(P, s)
    d = len(P)
    prefix = [0] * (d + 1)
    for i, p in enumerate(P):
        prefix[i + 1] = prefix[i] + p

    INF = float("inf")
    dp = [[INF] * d for _ in range(s + 1)]
    cut_of = [[-1] * d for _ in range(s + 1)]
    for i in range(d):
        dp[1][i] = prefix[i + 1]
    for k in range(2, s + 1):
        for i in range(k - 1, d):
            # last segment is P[j+1..i]
            for j in range(k - 2, i):
                cand = max(dp[k - 1][j], prefix[i + 1] - prefix[j + 1])
                if cand < dp[k][i]:
                    dp[k][i] = cand
                    cut_of[k][i] = j
    # reconstruct cuts
    cuts: List[int] = []
    k, i = s, d - 1
    while k > 1:
        j = cut_of[k][i]
        cuts.append(j)
        i, k = j, k - 1
    cuts.reverse()
    return cuts
