"""Host-threaded *streaming* pipeline executor — faithful to the paper's
implementation, extended with replicated stages and dynamic micro-batching.

Paper §5.1 / Fig. 5: "we deploy a host thread per Edge TPU that is in charge
of handling it, and a queue (implementing thread-safe mechanisms) on the host
to communicate intermediate results among devices."

Here each *stage* owns worker thread(s) and an input queue; stage ``i`` pops
an item, applies its stage function, and pushes the result to stage ``i+1``'s
queue.  Stage functions are arbitrary callables: the CNN benchmarks bind them
to real JAX forwards of the stage's layers; tests bind simulated latencies to
validate the analytical pipeline model.

The executor is *persistent* and *streaming*:

* Worker threads and their bounded queues are created once (on first use or
  an explicit :meth:`PipelineExecutor.start`) and reused, so steady-state
  serving creates **zero** threads per request.
* :meth:`PipelineExecutor.submit` admits one item into the stream and
  returns a :class:`concurrent.futures.Future`; envelopes flow through the
  stage queues continuously with **no inter-batch barrier** — a collector
  thread at the tail completes each item's future as it exits the last
  stage.  Backpressure comes from the bounded inter-stage queues:
  ``submit`` blocks once ``queue_size`` items are waiting at the head.
* :meth:`PipelineExecutor.run_batch` rides the same stream: it admits the
  whole batch through the same admission path and gathers completions in
  submission order (via a shared batch sink — one slot per item — rather
  than a Future each, keeping the per-item overhead tens of microseconds),
  so outputs (and the first-error-in-submission-order contract) are
  identical to the historical batch-synchronous executor — but two callers
  can now interleave batches, and a serving loop can keep every stage busy
  across what used to be drain/refill bubbles at batch boundaries.
* Stage failures are wrapped and forwarded per item (:class:`_Failed`), so
  one bad input neither kills worker threads nor stalls the stream; the
  item's future receives the original exception.
* :meth:`PipelineExecutor.stop` drains the stream and completes any future
  still in flight with :class:`PipelineStopped` rather than leaving callers
  hanging; the executor may be restarted afterwards.

Busy-time accounting is **monotonic**: per-(stage, replica) counters only
ever grow, and :meth:`busy_snapshot` returns the per-stage totals so callers
measure intervals as snapshot deltas (``run_batch(collect_stage_times=True)``
does exactly that — note the delta spans everything the executor ran in the
interval, which equals the batch only when no other traffic interleaves).

**Replicated stages** (``replicas=[...]``, from a
:class:`~repro.core.planner.PlacementPlan`): a stage with ``k > 1``
replicas — a bottleneck a single dominant layer pins, which no cut
placement can fix — runs ``k`` workers sharing the stage function.  A
dispatcher thread round-robins envelopes from the stage's input queue onto
``k`` per-worker queues; workers push results into a shared queue; a merge
thread restores stream order (items carry monotonic sequence numbers
internally) before forwarding downstream, so the pipeline's in-order
contract is bit-for-bit identical to the unreplicated pipeline — only the
pacing changes.  The merge sequence is monotonic for the executor's whole
lifetime: there is no per-batch reset, which is what lets batches overlap
in flight.

**Dynamic micro-batching** (``microbatch=[...]`` or an int): a stage with
bucket size ``k > 1`` aggregates up to ``k`` *consecutive* queued envelopes
whose payloads share an array signature (shape + dtype, the
:class:`ShapeKeyedStageCache` bucketing key) into one stacked call —
``fn(concat(payloads))`` split back into per-item envelopes — so jitted
accelerator stages amortize dispatch and weight-load over the traffic that
is actually concurrent, not just over what one request batch happened to
contain.  Only a same-signature *prefix* of the queue is taken, so FIFO
order (and therefore the stream's in-order contract) is preserved exactly;
``microbatch_wait_s`` optionally holds the first item briefly to let a
fuller bucket form.  Stages whose output does not split back along the
leading axis are detected on the first stacked probe and run per-item
from then on.

This executor is the *paper-faithful* path (host-mediated transfers).  The
pod-scale SPMD path (shard_map + ppermute over ICI) lives in
launch/pipeline_spmd.py and consumes the same PlacementPlan.
"""
from __future__ import annotations

import itertools
import queue
import threading
import time
from concurrent.futures import Future
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple, Union

_SHUTDOWN = object()      # terminates workers; forwarded by every stage


class PipelineStopped(RuntimeError):
    """Completion error for futures still in flight when the executor (or a
    server built on it) shuts down: callers get this instead of hanging."""


class _Failed:
    """A stage exception travelling the pipeline in the failed item's slot.

    Downstream stages forward it untouched, so one bad input neither kills
    the worker threads nor stalls the rest of the stream."""

    __slots__ = ("error",)

    def __init__(self, error: BaseException):
        self.error = error


class _BatchSink:
    """Lightweight completion target for ``run_batch``: one preallocated
    slot per item and a single Event, instead of a condition-variable
    Future per item — the gather path costs one lock op per item, which
    keeps the zero-latency steady-state microbenchmark within a few tens
    of microseconds per item."""

    __slots__ = ("slots", "_remaining", "_lock", "done")

    def __init__(self, n: int):
        self.slots: List[Any] = [None] * n
        self._remaining = n
        self._lock = threading.Lock()
        self.done = threading.Event()

    def deliver(self, idx: int, payload: Any) -> None:
        self.slots[idx] = (payload,)      # tuple-wrap: None is a valid output
        with self._lock:
            self._remaining -= 1
            if self._remaining == 0:
                self.done.set()


class PipelineExecutor:
    """Run inputs through a chain of stage functions with persistent
    worker threads and reusable bounded queues between stages.

    ``replicas[i] > 1`` replicates stage ``i`` across that many workers
    (shared input queue via a round-robin dispatcher, order-restoring
    fan-in).  ``microbatch[i] > 1`` lets stage ``i`` stack consecutive
    same-shape payloads into one call (see module docstring).  Items travel
    internally as ``(seq, payload)`` envelopes; user code never sees them.
    """

    def __init__(self, stage_fns: Sequence[Callable[[Any], Any]],
                 queue_size: int = 64, name: str = "pipeline",
                 replicas: Optional[Sequence[int]] = None,
                 microbatch: Optional[Union[int, Sequence[int]]] = None,
                 microbatch_wait_s: float = 0.0):
        if not stage_fns:
            raise ValueError("need at least one stage")
        self.stage_fns = list(stage_fns)
        self.queue_size = queue_size
        self.name = name
        n = len(self.stage_fns)
        if replicas is None:
            replicas = [1] * n
        self.replicas = [int(r) for r in replicas]
        if len(self.replicas) != n:
            raise ValueError(f"need {n} replica counts, "
                             f"got {len(self.replicas)}")
        if any(r < 1 for r in self.replicas):
            raise ValueError(f"replica counts must be >= 1: {self.replicas}")
        if microbatch is None:
            microbatch = [1] * n
        elif isinstance(microbatch, int):
            microbatch = [microbatch] * n
        self.microbatch = [int(k) for k in microbatch]
        if len(self.microbatch) != n:
            raise ValueError(f"need {n} microbatch sizes, "
                             f"got {len(self.microbatch)}")
        if any(k < 1 for k in self.microbatch):
            raise ValueError(f"microbatch sizes must be >= 1: "
                             f"{self.microbatch}")
        self.microbatch_wait_s = float(microbatch_wait_s)
        self._lock = threading.RLock()      # lifecycle
        self._submit_lock = threading.Lock()  # seq assignment + head put
        self._queues: List[queue.Queue] = []
        self._threads: List[threading.Thread] = []
        # one busy slot per (stage, replica): each written by one thread
        # only, never reset — read intervals via busy_snapshot() deltas
        self._busy = [[0.0] * r for r in self.replicas]
        # micro-batching amortization counters (calls / items): one slot
        # per (stage, replica) like _busy, so concurrent replica workers
        # never lose updates; monotonic
        self._mb_calls = [[0] * r for r in self.replicas]
        self._mb_items = [[0] * r for r in self.replicas]
        # stages proven unstackable (output does not split along axis 0):
        # skip aggregation instead of re-running every bucket twice
        self._mb_unstackable = [False] * n
        # seq -> Future (submit) or (_BatchSink, idx) (run_batch)
        self._pending: Dict[int, Any] = {}
        self._seq = itertools.count()
        self._started = False
        self._draining = False

    @classmethod
    def for_plan(cls, plan, stage_fns: Sequence[Callable[[Any], Any]],
                 queue_size: int = 64,
                 microbatch: Optional[Union[int, Sequence[int]]] = None,
                 microbatch_wait_s: float = 0.0,
                 name_prefix: str = "pipeline") -> "PipelineExecutor":
        """The one place a plan's execution shape (replica fan-out) meets
        a serving policy: both ``PipelinedModelServer`` and the
        ``repro.api.Deployment`` handle build their executors here, so a
        new executor knob lands in every consumer at once."""
        return cls(stage_fns, queue_size=queue_size,
                   name=f"{name_prefix}-{plan.graph_name}",
                   replicas=getattr(plan, "replica_counts", None),
                   microbatch=microbatch,
                   microbatch_wait_s=microbatch_wait_s)

    @property
    def n_stages(self) -> int:
        return len(self.stage_fns)

    @property
    def n_workers(self) -> int:
        return sum(self.replicas)

    @property
    def n_threads(self) -> int:
        """Threads the running executor owns: stage workers, dispatcher +
        merge per replicated stage, and the tail collector."""
        return (sum(1 if k == 1 else k + 2 for k in self.replicas) + 1)

    @property
    def started(self) -> bool:
        return self._started

    @property
    def in_flight(self) -> int:
        """Submitted items whose futures have not completed yet."""
        return len(self._pending)

    # -- lifecycle -----------------------------------------------------------
    def start(self) -> "PipelineExecutor":
        """Create the queues and spawn the persistent worker threads."""
        with self._lock:
            if self._started:
                return self
            n = self.n_stages
            self._queues = [queue.Queue(self.queue_size) for _ in range(n + 1)]
            self._threads = []
            self._pending = {}
            self._seq = itertools.count()
            self._draining = False
            for i in range(n):
                k = self.replicas[i]
                if k == 1:
                    self._threads.append(threading.Thread(
                        target=self._stage_loop,
                        args=(i, self._queues[i], self._queues[i + 1], 0),
                        daemon=True, name=f"{self.name}-stage{i}"))
                    continue
                # replicated stage: dispatcher -> k workers -> merge
                wqs = [queue.Queue(max(2, self.queue_size // k))
                       for _ in range(k)]
                mq: queue.Queue = queue.Queue(self.queue_size)
                self._threads.append(threading.Thread(
                    target=self._dispatcher, args=(self._queues[i], wqs),
                    daemon=True, name=f"{self.name}-stage{i}-dispatch"))
                for j in range(k):
                    self._threads.append(threading.Thread(
                        target=self._stage_loop, args=(i, wqs[j], mq, j),
                        daemon=True, name=f"{self.name}-stage{i}-r{j}"))
                self._threads.append(threading.Thread(
                    target=self._merge, args=(mq, self._queues[i + 1], k),
                    daemon=True, name=f"{self.name}-stage{i}-merge"))
            self._threads.append(threading.Thread(
                target=self._collector, args=(self._queues[n], self._pending),
                daemon=True, name=f"{self.name}-collect"))
            for t in self._threads:
                t.start()
            self._started = True
            return self

    def submit(self, payload: Any) -> "Future":
        """Admit one item into the stream; returns a Future completed (with
        the tail stage's output, or the stage exception) as the item exits
        the pipeline.  Blocks when the head queue is full — the stream's
        backpressure.  Starts the executor if needed."""
        if not self._started:
            self.start()
        fut: Future = Future()
        with self._submit_lock:
            if self._draining or not self._started:
                raise RuntimeError(f"{self.name}: executor is stopping")
            seq = next(self._seq)
            self._pending[seq] = fut
            self._queues[0].put((seq, payload))
        return fut

    def stop(self, timeout: float = 30.0) -> None:
        """Drain and join the worker threads; the executor may be restarted.

        In-flight items ahead of the shutdown marker complete normally
        (their futures resolve during the drain).  Bounded: if a stage
        hangs and the marker never cascades to the tail within ``timeout``,
        the (daemon) workers are abandoned, and any future still pending is
        completed with :class:`PipelineStopped` rather than left hanging."""
        with self._lock:
            if not self._started:
                return
            deadline = time.monotonic() + timeout
            # refuse new submissions, then queue the marker behind every
            # already-accepted envelope
            if self._submit_lock.acquire(
                    timeout=max(0.01, deadline - time.monotonic())):
                try:
                    self._draining = True
                    self._queues[0].put(_SHUTDOWN)
                except BaseException:
                    self._submit_lock.release()
                    raise
                self._submit_lock.release()
            else:   # a submitter is wedged on a full queue: best effort
                self._draining = True
                try:
                    self._queues[0].put_nowait(_SHUTDOWN)
                except queue.Full:
                    pass
            for t in self._threads:
                t.join(timeout=max(0.0, deadline - time.monotonic()))
            pending, self._pending = self._pending, {}
            for seq in sorted(pending):
                # atomic pop: an abandoned collector may race us here, and
                # exactly one side must complete each entry
                entry = pending.pop(seq, None)
                if entry is None:
                    continue
                err = PipelineStopped(
                    f"{self.name}: stopped with item {seq} in flight")
                if isinstance(entry, Future):
                    if not entry.done():
                        try:
                            entry.set_exception(err)
                        except Exception:
                            pass    # completed concurrently by a straggler
                else:
                    sink, idx = entry
                    sink.deliver(idx, _Failed(err))
            self._threads = []
            self._queues = []
            self._started = False
            self._draining = False

    def __enter__(self) -> "PipelineExecutor":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    # -- workers -------------------------------------------------------------
    def _apply(self, i: int, slot: int, envelope: Tuple[int, Any]):
        """Run stage ``i`` on one envelope; failures become _Failed."""
        fn = self.stage_fns[i]
        seq, payload = envelope
        if isinstance(payload, _Failed):
            return envelope
        try:
            t0 = time.perf_counter()
            out = fn(payload)
            self._busy[i][slot] += time.perf_counter() - t0
        except BaseException as e:   # surface worker failures per item
            return (seq, _Failed(e))
        return (seq, out)

    def _apply_batched(self, i: int, slot: int,
                       bucket: List[Tuple[int, Any]]) -> List[Tuple[int, Any]]:
        """One stacked call over a same-signature bucket, split back into
        per-item envelopes.

        A stage *exception* falls back to per-item execution, which
        attributes the failure to the offending envelope(s).  A stage
        whose output does not split item-for-item along the leading axis
        is marked unstackable — this bucket runs per-item and later
        buckets skip aggregation entirely — so the stacked probe's wasted
        call happens at most once per stage.  Busy time is only credited
        for stacked calls whose result is actually used."""
        fn = self.stage_fns[i]
        payloads = [p for _, p in bucket]
        rows = [int(p.shape[0]) for p in payloads]
        parts = None
        try:
            xp = _array_namespace(payloads[0])
            t0 = time.perf_counter()
            stacked_out = fn(xp.concatenate(payloads, axis=0))
            dt = time.perf_counter() - t0
            out_shape = getattr(stacked_out, "shape", None)
            if out_shape is not None and int(out_shape[0]) == sum(rows):
                parts = []
                off = 0
                for r in rows:
                    parts.append(stacked_out[off:off + r])
                    off += r
            else:
                self._mb_unstackable[i] = True
        except BaseException:
            pass        # per-item rerun pins the failure to the right item
        if parts is None:
            return [self._apply(i, slot, env) for env in bucket]
        self._busy[i][slot] += dt
        self._mb_calls[i][slot] += 1
        self._mb_items[i][slot] += len(bucket)
        return [(seq, part) for (seq, _), part in zip(bucket, parts)]

    def _stage_loop(self, i: int, q_in: queue.Queue, q_out: queue.Queue,
                    slot: int) -> None:
        """Worker loop shared by plain stages and replica workers: FIFO in,
        FIFO out, optional same-signature micro-batching."""
        k = self.microbatch[i]
        carry: Any = None
        while True:
            item = carry if carry is not None else q_in.get()
            carry = None
            if item is _SHUTDOWN:
                q_out.put(_SHUTDOWN)
                return
            key = (_microbatch_key(item[1])
                   if k > 1 and not self._mb_unstackable[i] else None)
            if key is None:
                q_out.put(self._apply(i, slot, item))
                continue
            bucket = [item]
            deadline: Optional[float] = None
            while len(bucket) < k:
                try:
                    nxt = q_in.get_nowait()
                except queue.Empty:
                    if self.microbatch_wait_s <= 0.0:
                        break
                    if deadline is None:
                        deadline = time.monotonic() + self.microbatch_wait_s
                    remaining = deadline - time.monotonic()
                    if remaining <= 0.0:
                        break
                    try:
                        nxt = q_in.get(timeout=remaining)
                    except queue.Empty:
                        break
                if nxt is _SHUTDOWN or _microbatch_key(nxt[1]) != key:
                    carry = nxt     # keep FIFO: process after this bucket
                    break
                bucket.append(nxt)
            if len(bucket) == 1:
                q_out.put(self._apply(i, slot, item))
            else:
                for env in self._apply_batched(i, slot, bucket):
                    q_out.put(env)

    def _dispatcher(self, q_in: queue.Queue,
                    wqs: List[queue.Queue]) -> None:
        """Round-robin fan-out of one stage's input onto its replicas."""
        rr = 0
        while True:
            item = q_in.get()
            if item is _SHUTDOWN:
                for q in wqs:
                    q.put(_SHUTDOWN)
                return
            wqs[rr].put(item)
            rr = (rr + 1) % len(wqs)

    def _merge(self, mq: queue.Queue, q_out: queue.Queue, k: int) -> None:
        """Order-restoring fan-in: buffer out-of-order envelopes, emit by
        monotonic stream sequence; collapse k shutdown markers into one.

        ``next_seq`` advances for the executor's whole lifetime — there is
        no batch boundary to reset it at, which is what lets envelopes from
        different callers overlap in flight through a replicated stage."""
        shutdowns = 0
        buf: Dict[int, Any] = {}
        next_seq = 0
        while True:
            item = mq.get()
            if item is _SHUTDOWN:
                shutdowns += 1
                if shutdowns == k:
                    q_out.put(_SHUTDOWN)
                    return
                continue
            seq, payload = item
            buf[seq] = payload
            while next_seq in buf:
                q_out.put((next_seq, buf.pop(next_seq)))
                next_seq += 1

    def _collector(self, q_tail: queue.Queue,
                   pending: Dict[int, Any]) -> None:
        """Tail thread: complete each item's completion target as it exits
        the last stage — a Future (submit) gets the result or the original
        stage exception; a batch sink (run_batch) gets the raw payload."""
        while True:
            item = q_tail.get()
            if item is _SHUTDOWN:
                return
            seq, payload = item
            entry = pending.pop(seq, None)
            if entry is None:
                continue
            if isinstance(entry, Future):
                try:
                    if isinstance(payload, _Failed):
                        entry.set_exception(payload.error)
                    else:
                        entry.set_result(payload)
                except Exception:
                    pass    # already failed by a concurrent stop()
            else:
                sink, idx = entry
                sink.deliver(idx, payload)

    # -- accounting ----------------------------------------------------------
    def busy_snapshot(self) -> List[float]:
        """Monotonic per-stage busy seconds (summed over replicas).
        Measure an interval as the delta of two snapshots."""
        return [sum(slots) for slots in self._busy]

    def microbatch_snapshot(self) -> Dict[str, List[int]]:
        """Monotonic per-stage micro-batching counters (summed over
        replicas): stacked calls and the items they covered (items/calls
        = realized amortization)."""
        return {"calls": [sum(s) for s in self._mb_calls],
                "items": [sum(s) for s in self._mb_items]}

    # -- batches -------------------------------------------------------------
    def run_batch(self, inputs: Sequence[Any],
                  collect_stage_times: bool = False
                  ) -> Tuple[List[Any], Optional[List[float]]]:
        """Admit `inputs` into the stream and gather their futures; returns
        (outputs, stage_busy_s).

        Outputs preserve input order: unreplicated stages are in-order
        queues, replicated stages restore order at their merge, and futures
        are gathered in submission order, so the output list is identical
        to the historical batch-synchronous executor's.  If any stage
        raised, the first exception (in submission order) is re-raised
        after every item of the batch has drained (so the executor stays
        reusable).  ``stage_busy_s[i]`` is the busy_snapshot() delta across
        the batch — equal to the batch's own busy time when no other
        traffic interleaves.  Creates no threads and takes no barrier:
        another caller's items may flow through the same stream
        concurrently.
        """
        if not self._started:
            self.start()
        snap0 = self.busy_snapshot() if collect_stage_times else None
        items = list(inputs)
        n = len(items)
        outputs: List[Any] = []
        errors: List[BaseException] = []
        if n:
            # same admission as submit(), but completions land in one
            # shared batch sink (a slot per item + one Event) instead of a
            # Future each — the steady-state gather costs one lock op per
            # item, not a condition variable round-trip
            sink = _BatchSink(n)
            with self._submit_lock:
                if self._draining or not self._started:
                    raise RuntimeError(f"{self.name}: executor is stopping")
                seqs = [next(self._seq) for _ in range(n)]
                for idx, seq in enumerate(seqs):
                    self._pending[seq] = (sink, idx)
            q_in = self._queues[0]
            stranded = False
            for seq, x in zip(seqs, items):   # blocking puts: backpressure
                while not stranded:
                    try:
                        q_in.put((seq, x), timeout=0.1)
                        break
                    except queue.Full:
                        # a concurrent stop() may have shut the workers
                        # down under us: our registered entries get
                        # PipelineStopped from stop(), so bail out rather
                        # than block on a dead queue
                        stranded = self._draining or not self._started
                if stranded:
                    break
            sink.done.wait()
            for slot in sink.slots:
                payload = slot[0]
                if isinstance(payload, _Failed):
                    errors.append(payload.error)
                else:
                    outputs.append(payload)
        if errors:
            raise errors[0]
        busy = None
        if collect_stage_times and snap0 is not None:
            busy = [b - a for a, b in zip(snap0, self.busy_snapshot())]
        return outputs, busy

    def timed_run(self, inputs: Sequence[Any]) -> Tuple[List[Any], float, List[float]]:
        t0 = time.perf_counter()
        outs, busy = self.run_batch(inputs, collect_stage_times=True)
        return outs, time.perf_counter() - t0, busy or []


def simulated_stage(latency_s: float) -> Callable[[Any], Any]:
    """A stage that just sleeps — used to validate the pipeline time model.

    Zero latency skips the sleep syscall entirely (``time.sleep(0)`` still
    forces a scheduler yield per item, which would swamp executor-overhead
    measurements)."""
    if latency_s <= 0.0:
        return lambda x: x
    def fn(x: Any) -> Any:
        time.sleep(latency_s)
        return x
    return fn


def stage_balance_metrics(stage_times: Sequence[float]) -> dict:
    """Paper Fig. 10 metrics: slowest stage time and deviation from mean.

    An empty sequence (e.g. a snapshot interval in which no stage ran)
    yields the neutral record rather than raising."""
    if not stage_times:
        return {"max_stage_s": 0.0, "mean_stage_s": 0.0,
                "max_minus_mean_s": 0.0, "balance": 1.0}
    mx = max(stage_times)
    mean = sum(stage_times) / len(stage_times)
    return {"max_stage_s": mx, "mean_stage_s": mean,
            "max_minus_mean_s": mx - mean,
            "balance": mean / mx if mx > 0 else 1.0}


def _shape_key(x: Any) -> Any:
    """Hashable signature of a stage input: (shape, dtype) for arrays."""
    shape = getattr(x, "shape", None)
    if shape is not None:
        return (tuple(shape), str(getattr(x, "dtype", "")))
    return type(x).__name__


def _microbatch_key(payload: Any) -> Optional[Any]:
    """Bucketing key for dynamic micro-batching, or None when the payload
    cannot join a stacked call: failed envelopes forward untouched, and
    only array payloads with a leading (batch) axis stack."""
    if isinstance(payload, _Failed):
        return None
    shape = getattr(payload, "shape", None)
    if shape is None or len(shape) == 0 or not hasattr(payload, "dtype"):
        return None
    return (tuple(shape), str(payload.dtype))


def _array_namespace(x: Any):
    """numpy for numpy arrays; jax.numpy (lazily) for device arrays, so
    stacking stays on-device; numpy as the generic fallback."""
    import numpy as np
    if isinstance(x, np.ndarray):
        return np
    try:
        import jax.numpy as jnp
        return jnp
    except Exception:       # pragma: no cover - jax is a core dep here
        return np


class ShapeKeyedStageCache:
    """Memoize built (typically jitted) stage callables per input signature.

    Stage builders close over sliced parameters and ``jax.jit`` wrappers;
    rebuilding them per server restart (or eagerly for shapes never served)
    wastes startup time and tracing.  ``get(name, x, build)`` builds the
    stage callable at most once per (stage name, input shape/dtype) and
    returns the cached callable afterwards, so steady-state batches reuse
    the already-traced function.  Micro-batched stages compose naturally:
    the stacked array is just another signature, so each realized bucket
    size gets its own traced callable.
    """

    def __init__(self) -> None:
        self._fns: Dict[Any, Callable[[Any], Any]] = {}
        self._lock = threading.Lock()

    def __len__(self) -> int:
        return len(self._fns)

    def get(self, name: str, x: Any,
            build: Callable[[], Callable[[Any], Any]]) -> Callable[[Any], Any]:
        key = (name, _shape_key(x))
        fn = self._fns.get(key)
        if fn is None:
            with self._lock:
                fn = self._fns.get(key)
                if fn is None:
                    fn = self._fns[key] = build()
        return fn

    def wrap(self, name: str,
             build: Callable[[], Callable[[Any], Any]]) -> Callable[[Any], Any]:
        """A stage function that lazily builds/caches per input signature."""
        def stage(x: Any) -> Any:
            return self.get(name, x, build)(x)
        return stage
