"""Host-threaded pipeline executor — faithful to the paper's implementation,
extended with replicated stages.

Paper §5.1 / Fig. 5: "we deploy a host thread per Edge TPU that is in charge
of handling it, and a queue (implementing thread-safe mechanisms) on the host
to communicate intermediate results among devices."

Here each *stage* owns worker thread(s) and an input queue; stage ``i`` pops
an item, applies its stage function, and pushes the result to stage ``i+1``'s
queue.  Stage functions are arbitrary callables: the CNN benchmarks bind them
to real JAX forwards of the stage's layers; tests bind simulated latencies to
validate the analytical pipeline model.

The executor is *persistent*: worker threads and their bounded queues are
created once (on first :meth:`PipelineExecutor.run_batch` or an explicit
:meth:`PipelineExecutor.start`) and reused across batches, so steady-state
serving creates **zero** threads per batch.  A batch is delimited by an
end-marker flowing through the queues; stage failures are wrapped and
forwarded so the pipeline stays drained and reusable after an error.
Lifecycle: ``start()`` / ``stop()`` or a ``with`` block.

**Replicated stages** (``replicas=[...]``, from a
:class:`~repro.core.planner.PlacementPlan`): a stage with ``k > 1``
replicas — a bottleneck a single dominant layer pins, which no cut
placement can fix — runs ``k`` workers sharing the stage function.  A
dispatcher thread round-robins envelopes from the stage's input queue onto
``k`` per-worker queues; workers push results into a shared queue; a merge
thread restores submission order (items carry sequence numbers internally)
before forwarding downstream, so the pipeline's in-order contract is
bit-for-bit identical to the unreplicated pipeline — only the pacing
changes.  Batch-end and shutdown markers collapse k-for-1 at the merge.

This executor is the *paper-faithful* path (host-mediated transfers).  The
pod-scale SPMD path (shard_map + ppermute over ICI) lives in
launch/pipeline_spmd.py and consumes the same PlacementPlan.
"""
from __future__ import annotations

import queue
import threading
import time
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

_BATCH_END = object()     # delimits one batch; forwarded by every stage
_SHUTDOWN = object()      # terminates workers; forwarded by every stage


class _Failed:
    """A stage exception travelling the pipeline in the failed item's slot.

    Downstream stages forward it untouched, so one bad input neither kills
    the worker threads nor stalls the rest of the batch."""

    __slots__ = ("error",)

    def __init__(self, error: BaseException):
        self.error = error


class _EndOfBatch:
    """Batch-end marker on a replicated stage's merge queue: carries how
    many data envelopes the dispatcher fanned out this batch, so the merge
    emits it only after restoring all of them."""

    __slots__ = ("count",)

    def __init__(self, count: int):
        self.count = count


class PipelineExecutor:
    """Run inputs through a chain of stage functions with persistent
    worker threads and reusable bounded queues between stages.

    ``replicas[i] > 1`` replicates stage ``i`` across that many workers
    (shared input queue via a round-robin dispatcher, order-restoring
    fan-in).  Items travel internally as ``(seq, payload)`` envelopes;
    user code never sees them.
    """

    def __init__(self, stage_fns: Sequence[Callable[[Any], Any]],
                 queue_size: int = 64, name: str = "pipeline",
                 replicas: Optional[Sequence[int]] = None):
        if not stage_fns:
            raise ValueError("need at least one stage")
        self.stage_fns = list(stage_fns)
        self.queue_size = queue_size
        self.name = name
        if replicas is None:
            replicas = [1] * len(self.stage_fns)
        self.replicas = [int(r) for r in replicas]
        if len(self.replicas) != len(self.stage_fns):
            raise ValueError(f"need {len(self.stage_fns)} replica counts, "
                             f"got {len(self.replicas)}")
        if any(r < 1 for r in self.replicas):
            raise ValueError(f"replica counts must be >= 1: {self.replicas}")
        self._lock = threading.RLock()
        self._queues: List[queue.Queue] = []
        self._threads: List[threading.Thread] = []
        # one busy slot per (stage, replica): each written by one thread only
        self._busy = [[0.0] * r for r in self.replicas]
        self._started = False

    @property
    def n_stages(self) -> int:
        return len(self.stage_fns)

    @property
    def n_workers(self) -> int:
        return sum(self.replicas)

    @property
    def started(self) -> bool:
        return self._started

    # -- lifecycle -----------------------------------------------------------
    def start(self) -> "PipelineExecutor":
        """Create the queues and spawn the persistent worker threads."""
        with self._lock:
            if self._started:
                return self
            n = self.n_stages
            self._queues = [queue.Queue(self.queue_size) for _ in range(n + 1)]
            self._threads = []
            for i in range(n):
                k = self.replicas[i]
                if k == 1:
                    self._threads.append(threading.Thread(
                        target=self._worker,
                        args=(i, self._queues[i], self._queues[i + 1], 0),
                        daemon=True, name=f"{self.name}-stage{i}"))
                    continue
                # replicated stage: dispatcher -> k workers -> merge
                wqs = [queue.Queue(max(2, self.queue_size // k))
                       for _ in range(k)]
                mq: queue.Queue = queue.Queue(self.queue_size)
                self._threads.append(threading.Thread(
                    target=self._dispatcher, args=(self._queues[i], wqs),
                    daemon=True, name=f"{self.name}-stage{i}-dispatch"))
                for j in range(k):
                    self._threads.append(threading.Thread(
                        target=self._replica_worker, args=(i, wqs[j], mq, j),
                        daemon=True, name=f"{self.name}-stage{i}-r{j}"))
                self._threads.append(threading.Thread(
                    target=self._merge, args=(mq, self._queues[i + 1], k),
                    daemon=True, name=f"{self.name}-stage{i}-merge"))
            for t in self._threads:
                t.start()
            self._started = True
            return self

    def stop(self, timeout: float = 30.0) -> None:
        """Drain and join the worker threads; the executor may be restarted.

        Bounded: if a stage hangs and the shutdown marker never cascades to
        the tail within ``timeout``, the (daemon) workers are abandoned
        rather than blocking the caller forever."""
        with self._lock:
            if not self._started:
                return
            self._queues[0].put(_SHUTDOWN)
            # the marker cascades stage-to-stage; swallow it at the tail
            deadline = time.monotonic() + timeout
            try:
                while self._queues[-1].get(
                        timeout=max(0.0, deadline - time.monotonic())
                ) is not _SHUTDOWN:
                    pass
            except queue.Empty:
                pass                      # stuck stage: abandon daemon workers
            for t in self._threads:
                t.join(timeout=max(0.0, deadline - time.monotonic()))
            self._threads = []
            self._queues = []
            self._started = False

    def __enter__(self) -> "PipelineExecutor":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    # -- workers -------------------------------------------------------------
    def _apply(self, i: int, slot: int, envelope: Tuple[int, Any]):
        """Run stage ``i`` on one envelope; failures become _Failed."""
        fn = self.stage_fns[i]
        seq, payload = envelope
        if isinstance(payload, _Failed):
            return envelope
        try:
            t0 = time.perf_counter()
            out = fn(payload)
            self._busy[i][slot] += time.perf_counter() - t0
        except BaseException as e:   # surface worker failures per item
            return (seq, _Failed(e))
        return (seq, out)

    def _worker(self, i: int, q_in: queue.Queue, q_out: queue.Queue,
                slot: int) -> None:
        while True:
            item = q_in.get()
            if item is _SHUTDOWN:
                q_out.put(_SHUTDOWN)
                return
            if item is _BATCH_END:
                q_out.put(item)
                continue
            q_out.put(self._apply(i, slot, item))

    def _dispatcher(self, q_in: queue.Queue,
                    wqs: List[queue.Queue]) -> None:
        """Round-robin fan-out of one stage's input onto its replicas.

        Batch ends travel as an _EndOfBatch carrying the per-batch envelope
        count, routed through a worker queue like any item; the merge holds
        it until every sequence number below the count has been emitted, so
        it cannot overtake in-flight work on other replicas."""
        rr = 0
        count = 0
        while True:
            item = q_in.get()
            if item is _SHUTDOWN:
                for q in wqs:
                    q.put(_SHUTDOWN)
                return
            if item is _BATCH_END:
                wqs[rr].put(_EndOfBatch(count))
                count = 0
                continue
            wqs[rr].put(item)
            rr = (rr + 1) % len(wqs)
            count += 1

    def _replica_worker(self, i: int, wq: queue.Queue, mq: queue.Queue,
                        slot: int) -> None:
        while True:
            item = wq.get()
            if item is _SHUTDOWN:
                mq.put(_SHUTDOWN)
                return
            if isinstance(item, _EndOfBatch):
                mq.put(item)
                continue
            mq.put(self._apply(i, slot, item))

    def _merge(self, mq: queue.Queue, q_out: queue.Queue, k: int) -> None:
        """Order-restoring fan-in: buffer out-of-order envelopes, emit by
        sequence number; collapse k shutdown markers into one."""
        shutdowns = 0
        buf: Dict[int, Any] = {}
        next_seq = 0
        end_at: Optional[int] = None
        while True:
            item = mq.get()
            if item is _SHUTDOWN:
                shutdowns += 1
                if shutdowns == k:
                    q_out.put(_SHUTDOWN)
                    return
                continue
            if isinstance(item, _EndOfBatch):
                end_at = item.count
            else:
                seq, payload = item
                buf[seq] = payload
            while next_seq in buf:
                q_out.put((next_seq, buf.pop(next_seq)))
                next_seq += 1
            if end_at is not None and next_seq == end_at:
                q_out.put(_BATCH_END)
                end_at = None
                next_seq = 0

    # -- batches -------------------------------------------------------------
    def run_batch(self, inputs: Sequence[Any],
                  collect_stage_times: bool = False
                  ) -> Tuple[List[Any], Optional[List[float]]]:
        """Push `inputs` through the pipeline; returns (outputs, stage_busy_s).

        Outputs preserve input order: unreplicated stages are in-order
        queues, replicated stages restore order at their merge, so the
        output stream is identical to the unreplicated pipeline's.
        ``stage_busy_s[i]`` is the total busy time of stage i *for this
        batch*, summed over its replicas — the paper's Fig. 10 metric.  If
        any stage raised, the first exception (in submission order) is
        re-raised after the batch fully drains (so the executor stays
        reusable).  Creates no threads: feeding interleaves with collection
        (non-blocking puts), so batches larger than the queue capacity
        cannot deadlock the single caller thread.
        """
        with self._lock:
            if not self._started:
                self.start()
            n = self.n_stages
            for slots in self._busy:
                for j in range(len(slots)):
                    slots[j] = 0.0
            q_in, q_out = self._queues[0], self._queues[n]
            items = list(inputs)
            fed = 0
            end_sent = False
            outputs: List[Any] = []
            errors: List[BaseException] = []
            while True:
                # feed as much as fits without blocking
                while fed < len(items):
                    try:
                        q_in.put_nowait((fed, items[fed]))
                    except queue.Full:
                        break
                    fed += 1
                if fed == len(items) and not end_sent:
                    try:
                        q_in.put_nowait(_BATCH_END)
                        end_sent = True
                    except queue.Full:
                        pass
                # collect; poll only while we still owe the pipeline input
                try:
                    item = q_out.get() if end_sent else q_out.get(timeout=0.02)
                except queue.Empty:
                    continue
                if item is _BATCH_END:
                    break
                _seq, payload = item
                if isinstance(payload, _Failed):
                    errors.append(payload.error)
                else:
                    outputs.append(payload)
            if errors:
                raise errors[0]
            busy = ([sum(slots) for slots in self._busy]
                    if collect_stage_times else None)
            return outputs, busy

    def timed_run(self, inputs: Sequence[Any]) -> Tuple[List[Any], float, List[float]]:
        t0 = time.perf_counter()
        outs, busy = self.run_batch(inputs, collect_stage_times=True)
        return outs, time.perf_counter() - t0, busy or []


def simulated_stage(latency_s: float) -> Callable[[Any], Any]:
    """A stage that just sleeps — used to validate the pipeline time model.

    Zero latency skips the sleep syscall entirely (``time.sleep(0)`` still
    forces a scheduler yield per item, which would swamp executor-overhead
    measurements)."""
    if latency_s <= 0.0:
        return lambda x: x
    def fn(x: Any) -> Any:
        time.sleep(latency_s)
        return x
    return fn


def stage_balance_metrics(stage_times: Sequence[float]) -> dict:
    """Paper Fig. 10 metrics: slowest stage time and deviation from mean."""
    mx = max(stage_times)
    mean = sum(stage_times) / len(stage_times)
    return {"max_stage_s": mx, "mean_stage_s": mean,
            "max_minus_mean_s": mx - mean,
            "balance": mean / mx if mx > 0 else 1.0}


def _shape_key(x: Any) -> Any:
    """Hashable signature of a stage input: (shape, dtype) for arrays."""
    shape = getattr(x, "shape", None)
    if shape is not None:
        return (tuple(shape), str(getattr(x, "dtype", "")))
    return type(x).__name__


class ShapeKeyedStageCache:
    """Memoize built (typically jitted) stage callables per input signature.

    Stage builders close over sliced parameters and ``jax.jit`` wrappers;
    rebuilding them per server restart (or eagerly for shapes never served)
    wastes startup time and tracing.  ``get(name, x, build)`` builds the
    stage callable at most once per (stage name, input shape/dtype) and
    returns the cached callable afterwards, so steady-state batches reuse
    the already-traced function.
    """

    def __init__(self) -> None:
        self._fns: Dict[Any, Callable[[Any], Any]] = {}
        self._lock = threading.Lock()

    def __len__(self) -> int:
        return len(self._fns)

    def get(self, name: str, x: Any,
            build: Callable[[], Callable[[Any], Any]]) -> Callable[[Any], Any]:
        key = (name, _shape_key(x))
        fn = self._fns.get(key)
        if fn is None:
            with self._lock:
                fn = self._fns.get(key)
                if fn is None:
                    fn = self._fns[key] = build()
        return fn

    def wrap(self, name: str,
             build: Callable[[], Callable[[Any], Any]]) -> Callable[[Any], Any]:
        """A stage function that lazily builds/caches per input signature."""
        def stage(x: Any) -> Any:
            return self.get(name, x, build)(x)
        return stage
