"""Host-threaded pipeline executor — faithful to the paper's implementation.

Paper §5.1 / Fig. 5: "we deploy a host thread per Edge TPU that is in charge
of handling it, and a queue (implementing thread-safe mechanisms) on the host
to communicate intermediate results among devices."

Here each *stage* owns a worker thread and an input queue; stage ``i`` pops an
item, applies its stage function, and pushes the result to stage ``i+1``'s
queue.  Stage functions are arbitrary callables: the CNN benchmarks bind them
to real JAX forwards of the stage's layers; tests bind simulated latencies to
validate the analytical pipeline model.

The executor is *persistent*: worker threads and their bounded queues are
created once (on first :meth:`PipelineExecutor.run_batch` or an explicit
:meth:`PipelineExecutor.start`) and reused across batches, so steady-state
serving creates **zero** threads per batch — the seed spawned and joined one
thread per stage per batch, which dominated small-batch throughput.  A batch
is delimited by an end-marker flowing through the queues; stage failures are
wrapped and forwarded so the pipeline stays drained and reusable after an
error.  Lifecycle: ``start()`` / ``stop()`` or a ``with`` block.

This executor is the *paper-faithful* path (host-mediated transfers).  The
pod-scale SPMD path (shard_map + ppermute over ICI) lives in
launch/pipeline_spmd.py and consumes the same SegmentationPlan.
"""
from __future__ import annotations

import queue
import threading
import time
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

_BATCH_END = object()     # delimits one batch; forwarded by every stage
_SHUTDOWN = object()      # terminates workers; forwarded by every stage


class _Failed:
    """A stage exception travelling the pipeline in the failed item's slot.

    Downstream stages forward it untouched, so one bad input neither kills
    the worker threads nor stalls the rest of the batch."""

    __slots__ = ("error",)

    def __init__(self, error: BaseException):
        self.error = error


class PipelineExecutor:
    """Run inputs through a chain of stage functions with one persistent
    thread per stage and reusable bounded queues between stages."""

    def __init__(self, stage_fns: Sequence[Callable[[Any], Any]],
                 queue_size: int = 64, name: str = "pipeline"):
        if not stage_fns:
            raise ValueError("need at least one stage")
        self.stage_fns = list(stage_fns)
        self.queue_size = queue_size
        self.name = name
        self._lock = threading.RLock()
        self._queues: List[queue.Queue] = []
        self._threads: List[threading.Thread] = []
        self._busy = [0.0] * len(self.stage_fns)
        self._started = False

    @property
    def n_stages(self) -> int:
        return len(self.stage_fns)

    @property
    def started(self) -> bool:
        return self._started

    # -- lifecycle -----------------------------------------------------------
    def start(self) -> "PipelineExecutor":
        """Create the queues and spawn the persistent worker threads."""
        with self._lock:
            if self._started:
                return self
            n = self.n_stages
            self._queues = [queue.Queue(self.queue_size) for _ in range(n + 1)]
            self._threads = [
                threading.Thread(target=self._worker, args=(i,), daemon=True,
                                 name=f"{self.name}-stage{i}")
                for i in range(n)
            ]
            for t in self._threads:
                t.start()
            self._started = True
            return self

    def stop(self, timeout: float = 30.0) -> None:
        """Drain and join the worker threads; the executor may be restarted.

        Bounded: if a stage hangs and the shutdown marker never cascades to
        the tail within ``timeout``, the (daemon) workers are abandoned
        rather than blocking the caller forever."""
        with self._lock:
            if not self._started:
                return
            self._queues[0].put(_SHUTDOWN)
            # the marker cascades stage-to-stage; swallow it at the tail
            deadline = time.monotonic() + timeout
            try:
                while self._queues[-1].get(
                        timeout=max(0.0, deadline - time.monotonic())
                ) is not _SHUTDOWN:
                    pass
            except queue.Empty:
                pass                      # stuck stage: abandon daemon workers
            for t in self._threads:
                t.join(timeout=max(0.0, deadline - time.monotonic()))
            self._threads = []
            self._queues = []
            self._started = False

    def __enter__(self) -> "PipelineExecutor":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    # -- workers -------------------------------------------------------------
    def _worker(self, i: int) -> None:
        fn = self.stage_fns[i]
        q_in = self._queues[i]
        q_out = self._queues[i + 1]
        while True:
            item = q_in.get()
            if item is _SHUTDOWN:
                q_out.put(_SHUTDOWN)
                return
            if item is _BATCH_END or isinstance(item, _Failed):
                q_out.put(item)
                continue
            try:
                t0 = time.perf_counter()
                out = fn(item)
                self._busy[i] += time.perf_counter() - t0
            except BaseException as e:   # surface worker failures per item
                q_out.put(_Failed(e))
                continue
            q_out.put(out)

    # -- batches -------------------------------------------------------------
    def run_batch(self, inputs: Sequence[Any],
                  collect_stage_times: bool = False
                  ) -> Tuple[List[Any], Optional[List[float]]]:
        """Push `inputs` through the pipeline; returns (outputs, stage_busy_s).

        Outputs preserve input order (in-order queues).  ``stage_busy_s[i]``
        is the total busy time of stage i *for this batch* — the paper's
        Fig. 10 metric.  If any stage raised, the first exception is
        re-raised after the batch fully drains (so the executor stays
        reusable).  Creates no threads: feeding interleaves with collection
        (non-blocking puts), so batches larger than the queue capacity
        cannot deadlock the single caller thread.
        """
        with self._lock:
            if not self._started:
                self.start()
            n = self.n_stages
            for j in range(n):
                self._busy[j] = 0.0
            q_in, q_out = self._queues[0], self._queues[n]
            items = list(inputs)
            fed = 0
            end_sent = False
            outputs: List[Any] = []
            errors: List[BaseException] = []
            while True:
                # feed as much as fits without blocking
                while fed < len(items):
                    try:
                        q_in.put_nowait(items[fed])
                    except queue.Full:
                        break
                    fed += 1
                if fed == len(items) and not end_sent:
                    try:
                        q_in.put_nowait(_BATCH_END)
                        end_sent = True
                    except queue.Full:
                        pass
                # collect; poll only while we still owe the pipeline input
                try:
                    item = q_out.get() if end_sent else q_out.get(timeout=0.02)
                except queue.Empty:
                    continue
                if item is _BATCH_END:
                    break
                if isinstance(item, _Failed):
                    errors.append(item.error)
                else:
                    outputs.append(item)
            if errors:
                raise errors[0]
            busy = list(self._busy) if collect_stage_times else None
            return outputs, busy

    def timed_run(self, inputs: Sequence[Any]) -> Tuple[List[Any], float, List[float]]:
        t0 = time.perf_counter()
        outs, busy = self.run_batch(inputs, collect_stage_times=True)
        return outs, time.perf_counter() - t0, busy or []


def simulated_stage(latency_s: float) -> Callable[[Any], Any]:
    """A stage that just sleeps — used to validate the pipeline time model.

    Zero latency skips the sleep syscall entirely (``time.sleep(0)`` still
    forces a scheduler yield per item, which would swamp executor-overhead
    measurements)."""
    if latency_s <= 0.0:
        return lambda x: x
    def fn(x: Any) -> Any:
        time.sleep(latency_s)
        return x
    return fn


def stage_balance_metrics(stage_times: Sequence[float]) -> dict:
    """Paper Fig. 10 metrics: slowest stage time and deviation from mean."""
    mx = max(stage_times)
    mean = sum(stage_times) / len(stage_times)
    return {"max_stage_s": mx, "mean_stage_s": mean,
            "max_minus_mean_s": mx - mean,
            "balance": mean / mx if mx > 0 else 1.0}


def _shape_key(x: Any) -> Any:
    """Hashable signature of a stage input: (shape, dtype) for arrays."""
    shape = getattr(x, "shape", None)
    if shape is not None:
        return (tuple(shape), str(getattr(x, "dtype", "")))
    return type(x).__name__


class ShapeKeyedStageCache:
    """Memoize built (typically jitted) stage callables per input signature.

    Stage builders close over sliced parameters and ``jax.jit`` wrappers;
    rebuilding them per server restart (or eagerly for shapes never served)
    wastes startup time and tracing.  ``get(name, x, build)`` builds the
    stage callable at most once per (stage name, input shape/dtype) and
    returns the cached callable afterwards, so steady-state batches reuse
    the already-traced function.
    """

    def __init__(self) -> None:
        self._fns: Dict[Any, Callable[[Any], Any]] = {}
        self._lock = threading.Lock()

    def __len__(self) -> int:
        return len(self._fns)

    def get(self, name: str, x: Any,
            build: Callable[[], Callable[[Any], Any]]) -> Callable[[Any], Any]:
        key = (name, _shape_key(x))
        fn = self._fns.get(key)
        if fn is None:
            with self._lock:
                fn = self._fns.get(key)
                if fn is None:
                    fn = self._fns[key] = build()
        return fn

    def wrap(self, name: str,
             build: Callable[[], Callable[[Any], Any]]) -> Callable[[Any], Any]:
        """A stage function that lazily builds/caches per input signature."""
        def stage(x: Any) -> Any:
            return self.get(name, x, build)(x)
        return stage
