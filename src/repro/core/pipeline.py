"""Host-threaded *streaming* pipeline executor — faithful to the paper's
implementation, extended with replicated stages and dynamic micro-batching.

Paper §5.1 / Fig. 5: "we deploy a host thread per Edge TPU that is in charge
of handling it, and a queue (implementing thread-safe mechanisms) on the host
to communicate intermediate results among devices."

Here each *stage* owns worker thread(s) and an input queue; stage ``i`` pops
an item, applies its stage function, and pushes the result to stage ``i+1``'s
queue.  Stage functions are arbitrary callables: the CNN benchmarks bind them
to real JAX forwards of the stage's layers; tests bind simulated latencies to
validate the analytical pipeline model.

The executor is *persistent* and *streaming*:

* Worker threads and their bounded queues are created once (on first use or
  an explicit :meth:`PipelineExecutor.start`) and reused, so steady-state
  serving creates **zero** threads per request.
* :meth:`PipelineExecutor.submit` admits one item into the stream and
  returns a :class:`concurrent.futures.Future`; envelopes flow through the
  stage queues continuously with **no inter-batch barrier** — a collector
  thread at the tail completes each item's future as it exits the last
  stage.  Backpressure comes from the bounded inter-stage queues:
  ``submit`` blocks once ``queue_size`` items are waiting at the head.
* :meth:`PipelineExecutor.run_batch` rides the same stream: it admits the
  whole batch through the same admission path and gathers completions in
  submission order (via a shared batch sink — one slot per item — rather
  than a Future each, keeping the per-item overhead tens of microseconds),
  so outputs (and the first-error-in-submission-order contract) are
  identical to the historical batch-synchronous executor — but two callers
  can now interleave batches, and a serving loop can keep every stage busy
  across what used to be drain/refill bubbles at batch boundaries.
* Stage failures are wrapped and forwarded per item (:class:`_Failed`), so
  one bad input neither kills worker threads nor stalls the stream; the
  item's future receives the original exception.
* :meth:`PipelineExecutor.stop` drains the stream and completes any future
  still in flight with :class:`PipelineStopped` rather than leaving callers
  hanging; the executor may be restarted afterwards.

Busy-time accounting is **monotonic**: per-(stage, replica) counters only
ever grow, and :meth:`busy_snapshot` returns the per-stage totals so callers
measure intervals as snapshot deltas (``run_batch(collect_stage_times=True)``
does exactly that — note the delta spans everything the executor ran in the
interval, which equals the batch only when no other traffic interleaves).

**Replicated stages** (``replicas=[...]``, from a
:class:`~repro.core.placement.PlacementPlan`): a stage with ``k > 1``
replicas — a bottleneck a single dominant layer pins, which no cut
placement can fix — runs ``k`` workers sharing the stage function.  A
dispatcher thread round-robins envelopes from the stage's input queue onto
``k`` per-worker queues; workers push results into a shared queue; a merge
thread restores stream order (items carry monotonic sequence numbers
internally) before forwarding downstream, so the pipeline's in-order
contract is bit-for-bit identical to the unreplicated pipeline — only the
pacing changes.  The merge sequence is monotonic for the executor's whole
lifetime: there is no per-batch reset, which is what lets batches overlap
in flight.

**Dynamic micro-batching** (``microbatch=[...]`` or an int): a stage with
bucket size ``k > 1`` aggregates up to ``k`` *consecutive* queued envelopes
whose payloads share an array signature (shape + dtype, the
:class:`ShapeKeyedStageCache` bucketing key) into one stacked call —
``fn(concat(payloads))`` split back into per-item envelopes — so jitted
accelerator stages amortize dispatch and weight-load over the traffic that
is actually concurrent, not just over what one request batch happened to
contain.  Only a same-signature *prefix* of the queue is taken, so FIFO
order (and therefore the stream's in-order contract) is preserved exactly;
``microbatch_wait_s`` optionally holds the first item briefly to let a
fuller bucket form.  Stages whose output does not split back along the
leading axis are detected on the first stacked probe and run per-item
from then on.

**Failure domains** (fleet-scale serving, ROADMAP item 5): the executor
distinguishes *item* failures from *replica* failures.  An ordinary stage
exception travels the stream as :class:`_Failed` and resolves that item's
future (unchanged).  A :class:`ReplicaFailure` — raised by a stage function
when its device dies, or injected via :meth:`PipelineExecutor.kill_replica`
by a health monitor / chaos harness — retires the *worker*: every envelope
the replica had accepted but not emitted (tracked in a per-stage in-flight
registry) is re-dispatched to a surviving replica and slots back into the
order-restoring merge by stream sequence, so no request is lost or
misordered.  When a stage loses its **last** replica the stage fails fast —
envelopes cross it as ``_Failed(StageLost)`` so the stream keeps flowing and
futures resolve promptly — and the ``on_stage_lost`` callback fires exactly
once (the hook degraded-mode replanning hangs off; see
``runtime.ft.HealthMonitor``).  Because re-dispatch is at-least-once, the
merge deduplicates by sequence: the first result for a sequence wins,
duplicates are dropped.

**Hedged dispatch** (``hedge_after=t``): on a replicated stage, an envelope
still in flight ``t`` seconds after dispatch is speculatively re-issued to a
*different* live replica; first result wins via the merge's
dedup-by-sequence, so outputs are bit-identical to unhedged execution —
only tail latency changes.  Off by default; enabled per deployment through
``DeploymentSpec.hedge_after``.

Liveness/health is observable via :meth:`PipelineExecutor.health_snapshot`:
per-replica alive flags, heartbeat ages, consecutive item-failure counts,
and per-stage hedge/re-dispatch counters.

This executor is the *paper-faithful* path (host-mediated transfers).  The
pod-scale SPMD path (shard_map + ppermute over ICI) lives in
launch/pipeline_spmd.py and consumes the same PlacementPlan.
"""
from __future__ import annotations

import itertools
import queue
import threading
import time
from concurrent.futures import Future
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple, Union

_SHUTDOWN = object()      # terminates workers; forwarded by every stage
_DEAD_TOKEN = object()    # a replica's one-time termination token on death
_DISPATCHER_DONE = object()   # dispatcher -> merge: drain marker delivered
_RETIRE = object()        # killer -> worker: your queue was reclaimed, exit


class PipelineStopped(RuntimeError):
    """Completion error for futures still in flight when the executor (or a
    server built on it) shuts down: callers get this instead of hanging."""


class ReplicaFailure(RuntimeError):
    """The *replica* (device/worker) died, not the item.

    Raised by a stage function when its backing device is gone (JAX device
    loss, a withdrawn Edge TPU) or injected by the chaos harness.  The
    worker retires and its in-flight envelopes are re-dispatched to a
    surviving replica; the item that triggered it is *not* failed."""


class StageLost(RuntimeError):
    """Completion error for envelopes crossing a stage with no live
    replicas left.  Carries ``stage`` so retry policies and the degraded-
    mode replanner know which failure domain collapsed."""

    def __init__(self, stage: int, name: str = "pipeline"):
        super().__init__(f"{name}: stage {stage} has no live replicas")
        self.stage = stage


class _Failed:
    """A stage exception travelling the pipeline in the failed item's slot.

    Downstream stages forward it untouched, so one bad input neither kills
    the worker threads nor stalls the rest of the stream."""

    __slots__ = ("error",)

    def __init__(self, error: BaseException):
        self.error = error


class _BatchSink:
    """Lightweight completion target for ``run_batch``: one preallocated
    slot per item and a single Event, instead of a condition-variable
    Future per item — the gather path costs one lock op per item, which
    keeps the zero-latency steady-state microbenchmark within a few tens
    of microseconds per item."""

    __slots__ = ("slots", "_remaining", "_lock", "done")

    def __init__(self, n: int):
        self.slots: List[Any] = [None] * n
        self._remaining = n
        self._lock = threading.Lock()
        self.done = threading.Event()

    def deliver(self, idx: int, payload: Any) -> None:
        self.slots[idx] = (payload,)      # tuple-wrap: None is a valid output
        with self._lock:
            self._remaining -= 1
            if self._remaining == 0:
                self.done.set()


class _InFlight:
    """Registry record for an envelope a replicated stage has accepted but
    not yet emitted: the payload (for re-dispatch), the replica currently
    working on it, the dispatch time (for hedging), and whether a hedged
    duplicate was already issued."""

    __slots__ = ("payload", "slot", "t_dispatch", "hedged")

    def __init__(self, payload: Any, slot: int = -1):
        self.payload = payload
        self.slot = slot
        self.t_dispatch = time.monotonic()
        self.hedged = False


class _StageState:
    """Shared failure-domain state of one replicated stage: worker queues,
    the merge input queue, per-replica liveness, and the in-flight
    registry (seq -> :class:`_InFlight`).  ``token_emitted`` guarantees
    each of the ``k`` workers contributes exactly one termination token
    (_DEAD_TOKEN on death, _SHUTDOWN on drain) to the merge, whichever
    path retires it first."""

    __slots__ = ("idx", "k", "wqs", "mq", "lock", "alive", "token_emitted",
                 "inflight", "hedges", "redispatches", "rr")

    def __init__(self, idx: int, k: int, wqs: List[queue.Queue],
                 mq: queue.Queue):
        self.idx = idx
        self.k = k
        self.wqs = wqs
        self.mq = mq
        self.lock = threading.Lock()
        self.alive = [True] * k
        self.token_emitted = [False] * k
        self.inflight: Dict[int, _InFlight] = {}
        self.hedges = 0
        self.redispatches = 0
        self.rr = 0


class PipelineExecutor:
    """Run inputs through a chain of stage functions with persistent
    worker threads and reusable bounded queues between stages.

    ``replicas[i] > 1`` replicates stage ``i`` across that many workers
    (shared input queue via a round-robin dispatcher, order-restoring
    fan-in).  ``microbatch[i] > 1`` lets stage ``i`` stack consecutive
    same-shape payloads into one call (see module docstring).  Items travel
    internally as ``(seq, payload)`` envelopes; user code never sees them.
    """

    def __init__(self, stage_fns: Sequence[Callable[[Any], Any]],
                 queue_size: int = 64, name: str = "pipeline",
                 replicas: Optional[Sequence[int]] = None,
                 microbatch: Optional[Union[int, Sequence[int]]] = None,
                 microbatch_wait_s: float = 0.0,
                 hedge_after: Optional[float] = None):
        if not stage_fns:
            raise ValueError("need at least one stage")
        if hedge_after is not None and hedge_after <= 0:
            raise ValueError(f"hedge_after must be > 0, got {hedge_after}")
        self.stage_fns = list(stage_fns)
        self.queue_size = queue_size
        self.name = name
        n = len(self.stage_fns)
        if replicas is None:
            replicas = [1] * n
        self.replicas = [int(r) for r in replicas]
        if len(self.replicas) != n:
            raise ValueError(f"need {n} replica counts, "
                             f"got {len(self.replicas)}")
        if any(r < 1 for r in self.replicas):
            raise ValueError(f"replica counts must be >= 1: {self.replicas}")
        if microbatch is None:
            microbatch = [1] * n
        elif isinstance(microbatch, int):
            microbatch = [microbatch] * n
        self.microbatch = [int(k) for k in microbatch]
        if len(self.microbatch) != n:
            raise ValueError(f"need {n} microbatch sizes, "
                             f"got {len(self.microbatch)}")
        if any(k < 1 for k in self.microbatch):
            raise ValueError(f"microbatch sizes must be >= 1: "
                             f"{self.microbatch}")
        self.microbatch_wait_s = float(microbatch_wait_s)
        self.hedge_after = hedge_after
        # fired exactly once when stage i loses its last replica; called
        # from an executor thread, so implementors must not block (the
        # HealthMonitor hook just enqueues an event)
        self.on_stage_lost: Optional[Callable[[int], None]] = None
        self._lock = threading.RLock()      # lifecycle
        self._submit_lock = threading.Lock()  # seq assignment + head put
        self._health_lock = threading.Lock()  # stage-lost once-only guard
        self._queues: List[queue.Queue] = []
        self._threads: List[threading.Thread] = []
        # one busy slot per (stage, replica): each written by one thread
        # only, never reset — read intervals via busy_snapshot() deltas
        self._busy = [[0.0] * r for r in self.replicas]
        # items successfully applied per (stage, replica), same single-
        # writer discipline: busy/items deltas = observed per-item stage
        # time, the live-telemetry signal the self-healing loop refits from
        self._items = [[0] * r for r in self.replicas]
        # micro-batching amortization counters (calls / items): one slot
        # per (stage, replica) like _busy, so concurrent replica workers
        # never lose updates; monotonic
        self._mb_calls = [[0] * r for r in self.replicas]
        self._mb_items = [[0] * r for r in self.replicas]
        # stages proven unstackable (output does not split along axis 0):
        # skip aggregation instead of re-running every bucket twice
        self._mb_unstackable = [False] * n
        # failure-domain state: per-replica liveness/heartbeats/consecutive
        # item failures (single-writer slots like _busy), per-replicated-
        # stage shared state, and the once-only stage-lost latches
        self._dead = [[False] * r for r in self.replicas]
        self._beats = [[time.monotonic()] * r for r in self.replicas]
        self._consec_fails = [[0] * r for r in self.replicas]
        self._stage_states: List[Optional[_StageState]] = [None] * n
        self._stage_lost_fired = [False] * n
        self._hedge_stop = threading.Event()
        # seq -> Future (submit) or (_BatchSink, idx) (run_batch)
        self._pending: Dict[int, Any] = {}
        self._seq = itertools.count()
        self._started = False
        self._draining = False

    @classmethod
    def for_plan(cls, plan, stage_fns: Sequence[Callable[[Any], Any]],
                 queue_size: int = 64,
                 microbatch: Optional[Union[int, Sequence[int]]] = None,
                 microbatch_wait_s: float = 0.0,
                 hedge_after: Optional[float] = None,
                 name_prefix: str = "pipeline") -> "PipelineExecutor":
        """The one place a plan's execution shape (replica fan-out) meets
        a serving policy: both ``PipelinedModelServer`` and the
        ``repro.api.Deployment`` handle build their executors here, so a
        new executor knob lands in every consumer at once."""
        return cls(stage_fns, queue_size=queue_size,
                   name=f"{name_prefix}-{plan.graph_name}",
                   replicas=getattr(plan, "replica_counts", None),
                   microbatch=microbatch,
                   microbatch_wait_s=microbatch_wait_s,
                   hedge_after=hedge_after)

    @property
    def n_stages(self) -> int:
        return len(self.stage_fns)

    @property
    def n_workers(self) -> int:
        return sum(self.replicas)

    @property
    def n_threads(self) -> int:
        """Threads the running executor owns: stage workers, dispatcher +
        merge per replicated stage, the tail collector, and the hedge
        monitor when hedging is enabled on a replicated pipeline."""
        hedger = 1 if (self.hedge_after is not None
                       and any(k > 1 for k in self.replicas)) else 0
        return (sum(1 if k == 1 else k + 2 for k in self.replicas)
                + 1 + hedger)

    @property
    def started(self) -> bool:
        return self._started

    @property
    def in_flight(self) -> int:
        """Submitted items whose futures have not completed yet."""
        return len(self._pending)

    # -- lifecycle -----------------------------------------------------------
    def start(self) -> "PipelineExecutor":
        """Create the queues and spawn the persistent worker threads."""
        with self._lock:
            if self._started:
                return self
            n = self.n_stages
            self._queues = [queue.Queue(self.queue_size) for _ in range(n + 1)]
            self._threads = []
            self._pending = {}
            self._seq = itertools.count()
            self._draining = False
            # fresh failure-domain state: a restart resurrects every replica
            self._dead = [[False] * r for r in self.replicas]
            self._beats = [[time.monotonic()] * r for r in self.replicas]
            self._consec_fails = [[0] * r for r in self.replicas]
            self._stage_states = [None] * n
            self._stage_lost_fired = [False] * n
            self._hedge_stop = threading.Event()
            for i in range(n):
                k = self.replicas[i]
                if k == 1:
                    self._threads.append(threading.Thread(
                        target=self._stage_loop,
                        args=(i, self._queues[i], self._queues[i + 1], 0),
                        daemon=True, name=f"{self.name}-stage{i}"))
                    continue
                # replicated stage: dispatcher -> k workers -> merge
                wqs = [queue.Queue(max(2, self.queue_size // k))
                       for _ in range(k)]
                mq: queue.Queue = queue.Queue(self.queue_size)
                st = _StageState(i, k, wqs, mq)
                self._stage_states[i] = st
                self._threads.append(threading.Thread(
                    target=self._dispatcher, args=(i, self._queues[i], st),
                    daemon=True, name=f"{self.name}-stage{i}-dispatch"))
                for j in range(k):
                    self._threads.append(threading.Thread(
                        target=self._stage_loop,
                        args=(i, wqs[j], mq, j, st),
                        daemon=True, name=f"{self.name}-stage{i}-r{j}"))
                self._threads.append(threading.Thread(
                    target=self._merge, args=(st, self._queues[i + 1]),
                    daemon=True, name=f"{self.name}-stage{i}-merge"))
            self._threads.append(threading.Thread(
                target=self._collector, args=(self._queues[n], self._pending),
                daemon=True, name=f"{self.name}-collect"))
            if (self.hedge_after is not None
                    and any(k > 1 for k in self.replicas)):
                self._threads.append(threading.Thread(
                    target=self._hedger, daemon=True,
                    name=f"{self.name}-hedge"))
            for t in self._threads:
                t.start()
            self._started = True
            return self

    def submit(self, payload: Any) -> "Future":
        """Admit one item into the stream; returns a Future completed (with
        the tail stage's output, or the stage exception) as the item exits
        the pipeline.  Blocks when the head queue is full — the stream's
        backpressure.  Starts the executor if needed."""
        if not self._started:
            self.start()
        fut: Future = Future()
        with self._submit_lock:
            if self._draining or not self._started:
                raise RuntimeError(f"{self.name}: executor is stopping")
            seq = next(self._seq)
            self._pending[seq] = fut
            self._queues[0].put((seq, payload))
        return fut

    def stop(self, timeout: float = 30.0) -> None:
        """Drain and join the worker threads; the executor may be restarted.

        In-flight items ahead of the shutdown marker complete normally
        (their futures resolve during the drain).  Bounded: if a stage
        hangs and the marker never cascades to the tail within ``timeout``,
        the (daemon) workers are abandoned, and any future still pending is
        completed with :class:`PipelineStopped` rather than left hanging."""
        with self._lock:
            if not self._started:
                return
            deadline = time.monotonic() + timeout
            # refuse new submissions, then queue the marker behind every
            # already-accepted envelope
            if self._submit_lock.acquire(
                    timeout=max(0.01, deadline - time.monotonic())):
                try:
                    self._draining = True
                    self._queues[0].put(_SHUTDOWN)
                except BaseException:
                    self._submit_lock.release()
                    raise
                self._submit_lock.release()
            else:   # a submitter is wedged on a full queue: best effort
                self._draining = True
                try:
                    self._queues[0].put_nowait(_SHUTDOWN)
                except queue.Full:
                    pass
            self._hedge_stop.set()
            for t in self._threads:
                t.join(timeout=max(0.0, deadline - time.monotonic()))
            pending, self._pending = self._pending, {}
            for seq in sorted(pending):
                # atomic pop: an abandoned collector may race us here, and
                # exactly one side must complete each entry
                entry = pending.pop(seq, None)
                if entry is None:
                    continue
                err = PipelineStopped(
                    f"{self.name}: stopped with item {seq} in flight")
                if isinstance(entry, Future):
                    if not entry.done():
                        try:
                            entry.set_exception(err)
                        except Exception:
                            pass    # completed concurrently by a straggler
                else:
                    sink, idx = entry
                    sink.deliver(idx, _Failed(err))
            self._threads = []
            self._queues = []
            self._started = False
            self._draining = False

    def __enter__(self) -> "PipelineExecutor":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    # -- workers -------------------------------------------------------------
    def _apply(self, i: int, slot: int, envelope: Tuple[int, Any]):
        """Run stage ``i`` on one envelope; failures become _Failed.

        :class:`ReplicaFailure` propagates — it retires the worker, not
        the item.  Ordinary exceptions bump the replica's consecutive-
        failure count (a health-monitor death signal); successes reset it.
        """
        fn = self.stage_fns[i]
        seq, payload = envelope
        if isinstance(payload, _Failed):
            return envelope
        try:
            t0 = time.perf_counter()
            out = fn(payload)
            self._busy[i][slot] += time.perf_counter() - t0
            self._items[i][slot] += 1
            self._consec_fails[i][slot] = 0
        except ReplicaFailure:
            raise
        except BaseException as e:   # surface worker failures per item
            self._consec_fails[i][slot] += 1
            return (seq, _Failed(e))
        return (seq, out)

    def _apply_batched(self, i: int, slot: int,
                       bucket: List[Tuple[int, Any]]) -> List[Tuple[int, Any]]:
        """One stacked call over a same-signature bucket, split back into
        per-item envelopes.

        A stage *exception* falls back to per-item execution, which
        attributes the failure to the offending envelope(s).  A stage
        whose output does not split item-for-item along the leading axis
        is marked unstackable — this bucket runs per-item and later
        buckets skip aggregation entirely — so the stacked probe's wasted
        call happens at most once per stage.  Busy time is only credited
        for stacked calls whose result is actually used."""
        fn = self.stage_fns[i]
        payloads = [p for _, p in bucket]
        rows = [int(p.shape[0]) for p in payloads]
        parts = None
        try:
            xp = _array_namespace(payloads[0])
            t0 = time.perf_counter()
            stacked_out = fn(xp.concatenate(payloads, axis=0))
            dt = time.perf_counter() - t0
            out_shape = getattr(stacked_out, "shape", None)
            if out_shape is not None and int(out_shape[0]) == sum(rows):
                parts = []
                off = 0
                for r in rows:
                    parts.append(stacked_out[off:off + r])
                    off += r
            else:
                self._mb_unstackable[i] = True
        except ReplicaFailure:
            raise       # the replica died, not the bucket
        except BaseException:
            pass        # per-item rerun pins the failure to the right item
        if parts is None:
            return [self._apply(i, slot, env) for env in bucket]
        self._busy[i][slot] += dt
        self._items[i][slot] += len(bucket)
        self._mb_calls[i][slot] += 1
        self._mb_items[i][slot] += len(bucket)
        return [(seq, part) for (seq, _), part in zip(bucket, parts)]

    def _stage_loop(self, i: int, q_in: queue.Queue, q_out: queue.Queue,
                    slot: int, st: Optional[_StageState] = None) -> None:
        """Worker loop shared by plain stages and replica workers: FIFO in,
        FIFO out, optional same-signature micro-batching.

        Death semantics: a :class:`ReplicaFailure` out of the stage
        function retires this worker.  A replica of a replicated stage
        (``st`` given) re-dispatches its in-flight envelopes to a survivor
        and exits; the sole worker of an unreplicated stage switches to a
        *bypass* loop — it keeps draining its queue, forwarding every
        envelope as ``_Failed(StageLost)`` so the stream never stalls and
        shutdown still cascades."""
        k = self.microbatch[i]
        carry: Any = None
        while True:
            item = carry
            while item is None:
                try:
                    item = q_in.get(timeout=0.1)
                except queue.Empty:
                    # refresh the heartbeat while idle: a stale beat must
                    # mean "stuck inside the stage fn (or dead)", never
                    # "healthy but nothing to do"
                    self._beats[i][slot] = time.monotonic()
            carry = None
            if item is _SHUTDOWN:
                if st is None:
                    q_out.put(_SHUTDOWN)
                else:
                    self._emit_token(st, slot, _SHUTDOWN)
                return
            if item is _RETIRE:
                return          # killer reclaimed our queue + in-flight
            if self._dead[i][slot]:
                if st is not None:
                    return      # token + re-dispatch handled at kill time
                q_out.put((item[0], _Failed(StageLost(i, self.name))))
                continue
            self._beats[i][slot] = time.monotonic()
            bucket = [item]
            try:
                key = (_microbatch_key(item[1])
                       if k > 1 and not self._mb_unstackable[i] else None)
                if key is None:
                    q_out.put(self._apply(i, slot, item))
                    continue
                deadline: Optional[float] = None
                while len(bucket) < k:
                    try:
                        nxt = q_in.get_nowait()
                    except queue.Empty:
                        if self.microbatch_wait_s <= 0.0:
                            break
                        if deadline is None:
                            deadline = (time.monotonic()
                                        + self.microbatch_wait_s)
                        remaining = deadline - time.monotonic()
                        if remaining <= 0.0:
                            break
                        try:
                            nxt = q_in.get(timeout=remaining)
                        except queue.Empty:
                            break
                    if (nxt is _SHUTDOWN or nxt is _RETIRE
                            or _microbatch_key(nxt[1]) != key):
                        carry = nxt     # keep FIFO: process after bucket
                        break
                    bucket.append(nxt)
                if len(bucket) == 1:
                    q_out.put(self._apply(i, slot, item))
                else:
                    for env in self._apply_batched(i, slot, bucket):
                        q_out.put(env)
            except ReplicaFailure:
                self._dead[i][slot] = True
                if st is not None:
                    # in-hand envelopes (bucket + carry) are all in the
                    # in-flight registry with our slot: retire re-places
                    self._retire_replica(i, slot, st)
                    return
                # sole worker: fail what we hold, then bypass onward
                for env in bucket:
                    q_out.put((env[0], _Failed(StageLost(i, self.name))))
                self._fire_stage_lost(i)
                # carry (if any) is handled by the loop top: a _SHUTDOWN
                # forwards, an envelope fails fast through the dead check

    # -- failure domains ------------------------------------------------------
    def _emit_token(self, st: _StageState, slot: int, token: Any) -> None:
        """Each replica contributes exactly one termination token to its
        merge, whichever retires it first (drain or death)."""
        with st.lock:
            if st.token_emitted[slot]:
                return
            st.token_emitted[slot] = True
        st.mq.put(token)

    def _fire_stage_lost(self, i: int) -> None:
        with self._health_lock:
            if self._stage_lost_fired[i]:
                return
            self._stage_lost_fired[i] = True
        cb = self.on_stage_lost
        if cb is not None:
            try:
                cb(i)
            except Exception:       # observer bugs must not kill workers
                pass

    def _place(self, i: int, st: _StageState, seq: int,
               exclude: Optional[int] = None) -> None:
        """(Re-)dispatch an in-flight envelope onto a live replica of
        stage ``i``; with none left, fail it into the merge as
        ``StageLost`` so the stream keeps flowing.  Safe to call from the
        dispatcher, a dying worker, the hedge monitor, or an external
        killer — the registry record is the single source of truth and a
        seq whose record is gone (already emitted) is a no-op."""
        while True:
            with st.lock:
                rec = st.inflight.get(seq)
                if rec is None:
                    return          # already completed downstream
                live = [j for j in range(st.k)
                        if st.alive[j] and j != exclude]
                if not live:
                    st.inflight.pop(seq, None)
                    payload = rec.payload
                    j = None
                else:
                    j = live[st.rr % len(live)]
                    st.rr += 1
                    rec.slot = j
                    rec.t_dispatch = time.monotonic()
            if j is None:
                st.mq.put((seq, _Failed(StageLost(i, self.name))))
                self._fire_stage_lost(i)
                return
            try:
                st.wqs[j].put((seq, rec.payload), timeout=0.05)
            except queue.Full:
                continue            # re-check liveness, maybe new target
            # j may have died between the choice and the put: anything
            # stranded in its (now consumerless) queue gets re-placed
            with st.lock:
                died = not st.alive[j]
            if not died:
                return
            for stray in self._drain_queue(st.wqs[j]):
                if stray is _SHUTDOWN or stray is _RETIRE:
                    continue
                self._place(i, st, stray[0], exclude=j)
            return

    def _retire_replica(self, i: int, slot: int,
                        st: _StageState) -> None:
        """Retire one replica of a replicated stage: mark it dead, emit
        its termination token, reclaim its queue, and re-dispatch every
        envelope it had accepted but not emitted to a surviving replica
        (or fail them as StageLost when it was the last one)."""
        with st.lock:
            self._dead[i][slot] = True
            st.alive[slot] = False
            assigned = [seq for seq, rec in st.inflight.items()
                        if rec.slot == slot]
            none_alive = not any(st.alive)
        self._emit_token(st, slot, _DEAD_TOKEN)
        # reclaim the dead replica's queue (no consumer anymore) and nudge
        # a worker thread blocked on it out of its get()
        strays = [x[0] for x in self._drain_queue(st.wqs[slot])
                  if x is not _SHUTDOWN and x is not _RETIRE]
        try:
            st.wqs[slot].put_nowait(_RETIRE)
        except queue.Full:
            pass
        for seq in dict.fromkeys(assigned + strays):
            with st.lock:
                known = seq in st.inflight
                if known:
                    st.redispatches += 1
            if known:
                self._place(i, st, seq, exclude=slot)
        if none_alive:
            self._fire_stage_lost(i)

    @staticmethod
    def _drain_queue(q: queue.Queue) -> List[Any]:
        out = []
        while True:
            try:
                out.append(q.get_nowait())
            except queue.Empty:
                return out

    def kill_replica(self, stage: int, slot: int = 0) -> None:
        """Withdraw one replica (health monitor / chaos entry point): its
        in-flight envelopes are re-dispatched to surviving replicas; on an
        unreplicated stage this is a stage loss — subsequent envelopes
        fail fast as :class:`StageLost` (the item the worker is currently
        applying, if any, still completes normally)."""
        if not self._started:
            raise RuntimeError(f"{self.name}: not started")
        if not (0 <= stage < self.n_stages):
            raise ValueError(f"no stage {stage}")
        if not (0 <= slot < self.replicas[stage]):
            raise ValueError(f"stage {stage} has no replica {slot}")
        st = self._stage_states[stage]
        if st is None:
            self._dead[stage][slot] = True
            self._fire_stage_lost(stage)
            return
        self._retire_replica(stage, slot, st)

    def kill_stage(self, stage: int) -> None:
        """Withdraw every replica of a stage (the degraded-mode trigger)."""
        for slot in range(self.replicas[stage]):
            self.kill_replica(stage, slot)

    def _hedger(self) -> None:
        """Hedge monitor: an envelope still in flight ``hedge_after``
        seconds after dispatch is speculatively re-issued to a different
        live replica; the merge's dedup-by-sequence keeps the first
        result, so hedging never changes outputs — only tail latency."""
        interval = max(0.001, self.hedge_after / 4.0)
        while not self._hedge_stop.wait(interval):
            now = time.monotonic()
            for i, st in enumerate(self._stage_states):
                if st is None:
                    continue
                with st.lock:
                    stale = [seq for seq, rec in st.inflight.items()
                             if (not rec.hedged and rec.slot >= 0
                                 and now - rec.t_dispatch
                                 >= self.hedge_after)]
                for seq in stale:
                    self._hedge_one(i, st, seq)

    def _hedge_one(self, i: int, st: _StageState, seq: int) -> None:
        with st.lock:
            rec = st.inflight.get(seq)
            if rec is None or rec.hedged:
                return
            live = [j for j in range(st.k)
                    if st.alive[j] and j != rec.slot]
            if not live:
                return
            j = live[st.rr % len(live)]
            st.rr += 1
            payload = rec.payload
        try:
            st.wqs[j].put_nowait((seq, payload))
        except queue.Full:
            return                  # backpressured: retry next scan
        with st.lock:
            rec = st.inflight.get(seq)
            if rec is not None:
                rec.hedged = True
            st.hedges += 1

    def health_snapshot(self) -> Dict[str, Any]:
        """Failure-domain observability: per-replica liveness, heartbeat
        ages (seconds since the replica last started work), consecutive
        item-failure counts, and per-stage hedge / re-dispatch counters.
        All monotonic or idempotent — safe to poll from a monitor."""
        now = time.monotonic()
        return {
            "alive": [[not d for d in row] for row in self._dead],
            "live_replicas": [sum(1 for d in row if not d)
                              for row in self._dead],
            "heartbeat_age_s": [[now - b for b in row]
                                for row in self._beats],
            "consecutive_failures": [list(row)
                                     for row in self._consec_fails],
            "hedges": [st.hedges if st else 0
                       for st in self._stage_states],
            "redispatches": [st.redispatches if st else 0
                             for st in self._stage_states],
        }

    def _dispatcher(self, i: int, q_in: queue.Queue,
                    st: _StageState) -> None:
        """Fan one stage's input onto its replicas, registering every
        envelope in the stage's in-flight registry before it is placed —
        the registry is what failover re-dispatches from."""
        while True:
            item = q_in.get()
            if item is _SHUTDOWN:
                with st.lock:
                    targets = [j for j in range(st.k) if st.alive[j]]
                for j in targets:
                    while True:
                        with st.lock:
                            if not st.alive[j]:
                                break   # died while draining: _DEAD covers it
                        try:
                            st.wqs[j].put(_SHUTDOWN, timeout=0.05)
                            break
                        except queue.Full:
                            continue
                st.mq.put(_DISPATCHER_DONE)
                return
            with st.lock:
                st.inflight[item[0]] = _InFlight(item[1])
            self._place(i, st, item[0])

    def _merge(self, st: _StageState, q_out: queue.Queue) -> None:
        """Order-restoring, deduplicating fan-in: buffer out-of-order
        envelopes, emit by monotonic stream sequence, and drop duplicate
        results (hedged or re-issued envelopes may complete twice — the
        first one wins, which is what makes hedging/failover invisible
        downstream).

        ``next_seq`` advances for the executor's whole lifetime — there is
        no batch boundary to reset it at, which is what lets batches
        overlap in flight.  Termination: each of the ``k`` replicas emits
        exactly one token (_SHUTDOWN on drain, _DEAD_TOKEN on death); the
        merge forwards one _SHUTDOWN downstream once the dispatcher has
        drained *and* all ``k`` tokens arrived."""
        tokens = 0
        dispatcher_done = False
        buf: Dict[int, Any] = {}
        next_seq = 0
        while True:
            item = st.mq.get()
            if item is _DISPATCHER_DONE:
                dispatcher_done = True
            elif item is _SHUTDOWN or item is _DEAD_TOKEN:
                tokens += 1
            else:
                seq, payload = item
                with st.lock:
                    st.inflight.pop(seq, None)
                if seq < next_seq or seq in buf:
                    continue        # duplicate (hedge / failover re-issue)
                buf[seq] = payload
                while next_seq in buf:
                    q_out.put((next_seq, buf.pop(next_seq)))
                    next_seq += 1
            if dispatcher_done and tokens >= st.k:
                q_out.put(_SHUTDOWN)
                return

    def _collector(self, q_tail: queue.Queue,
                   pending: Dict[int, Any]) -> None:
        """Tail thread: complete each item's completion target as it exits
        the last stage — a Future (submit) gets the result or the original
        stage exception; a batch sink (run_batch) gets the raw payload."""
        while True:
            item = q_tail.get()
            if item is _SHUTDOWN:
                return
            seq, payload = item
            entry = pending.pop(seq, None)
            if entry is None:
                continue
            if isinstance(entry, Future):
                try:
                    if isinstance(payload, _Failed):
                        entry.set_exception(payload.error)
                    else:
                        entry.set_result(payload)
                except Exception:
                    pass    # already failed by a concurrent stop()
            else:
                sink, idx = entry
                sink.deliver(idx, payload)

    # -- accounting ----------------------------------------------------------
    def busy_snapshot(self) -> List[float]:
        """Monotonic per-stage busy seconds (summed over replicas).
        Measure an interval as the delta of two snapshots."""
        return [sum(slots) for slots in self._busy]

    def items_snapshot(self) -> List[int]:
        """Monotonic per-stage successfully-applied item counts (summed
        over replicas).  ``busy_snapshot`` delta / ``items_snapshot``
        delta = the interval's observed per-item stage time — the live
        telemetry the self-healing control loop feeds back into the
        planner's cost model (``runtime.selfheal``)."""
        return [sum(slots) for slots in self._items]

    def microbatch_snapshot(self) -> Dict[str, List[int]]:
        """Monotonic per-stage micro-batching counters (summed over
        replicas): stacked calls and the items they covered (items/calls
        = realized amortization)."""
        return {"calls": [sum(s) for s in self._mb_calls],
                "items": [sum(s) for s in self._mb_items]}

    # -- batches -------------------------------------------------------------
    def run_batch(self, inputs: Sequence[Any],
                  collect_stage_times: bool = False
                  ) -> Tuple[List[Any], Optional[List[float]]]:
        """Admit `inputs` into the stream and gather their futures; returns
        (outputs, stage_busy_s).

        Outputs preserve input order: unreplicated stages are in-order
        queues, replicated stages restore order at their merge, and futures
        are gathered in submission order, so the output list is identical
        to the historical batch-synchronous executor's.  If any stage
        raised, the first exception (in submission order) is re-raised
        after every item of the batch has drained (so the executor stays
        reusable).  ``stage_busy_s[i]`` is the busy_snapshot() delta across
        the batch — equal to the batch's own busy time when no other
        traffic interleaves.  Creates no threads and takes no barrier:
        another caller's items may flow through the same stream
        concurrently.
        """
        if not self._started:
            self.start()
        snap0 = self.busy_snapshot() if collect_stage_times else None
        items = list(inputs)
        n = len(items)
        outputs: List[Any] = []
        errors: List[BaseException] = []
        if n:
            # same admission as submit(), but completions land in one
            # shared batch sink (a slot per item + one Event) instead of a
            # Future each — the steady-state gather costs one lock op per
            # item, not a condition variable round-trip
            sink = _BatchSink(n)
            with self._submit_lock:
                if self._draining or not self._started:
                    raise RuntimeError(f"{self.name}: executor is stopping")
                seqs = [next(self._seq) for _ in range(n)]
                for idx, seq in enumerate(seqs):
                    self._pending[seq] = (sink, idx)
            q_in = self._queues[0]
            stranded = False
            for seq, x in zip(seqs, items):   # blocking puts: backpressure
                while not stranded:
                    try:
                        q_in.put((seq, x), timeout=0.1)
                        break
                    except queue.Full:
                        # a concurrent stop() may have shut the workers
                        # down under us: our registered entries get
                        # PipelineStopped from stop(), so bail out rather
                        # than block on a dead queue
                        stranded = self._draining or not self._started
                if stranded:
                    break
            sink.done.wait()
            for slot in sink.slots:
                payload = slot[0]
                if isinstance(payload, _Failed):
                    errors.append(payload.error)
                else:
                    outputs.append(payload)
        if errors:
            raise errors[0]
        busy = None
        if collect_stage_times and snap0 is not None:
            busy = [b - a for a, b in zip(snap0, self.busy_snapshot())]
        return outputs, busy

    def timed_run(self, inputs: Sequence[Any]) -> Tuple[List[Any], float, List[float]]:
        t0 = time.perf_counter()
        outs, busy = self.run_batch(inputs, collect_stage_times=True)
        return outs, time.perf_counter() - t0, busy or []


def simulated_stage(latency_s: float) -> Callable[[Any], Any]:
    """A stage that just sleeps — used to validate the pipeline time model.

    Zero latency skips the sleep syscall entirely (``time.sleep(0)`` still
    forces a scheduler yield per item, which would swamp executor-overhead
    measurements)."""
    if latency_s <= 0.0:
        return lambda x: x
    def fn(x: Any) -> Any:
        time.sleep(latency_s)
        return x
    return fn


def stage_balance_metrics(stage_times: Sequence[float]) -> dict:
    """Paper Fig. 10 metrics: slowest stage time and deviation from mean.

    An empty sequence (e.g. a snapshot interval in which no stage ran)
    yields the neutral record rather than raising."""
    if not stage_times:
        return {"max_stage_s": 0.0, "mean_stage_s": 0.0,
                "max_minus_mean_s": 0.0, "balance": 1.0}
    mx = max(stage_times)
    mean = sum(stage_times) / len(stage_times)
    return {"max_stage_s": mx, "mean_stage_s": mean,
            "max_minus_mean_s": mx - mean,
            "balance": mean / mx if mx > 0 else 1.0}


def _shape_key(x: Any) -> Any:
    """Hashable signature of a stage input: (shape, dtype) for arrays."""
    shape = getattr(x, "shape", None)
    if shape is not None:
        return (tuple(shape), str(getattr(x, "dtype", "")))
    return type(x).__name__


def _microbatch_key(payload: Any) -> Optional[Any]:
    """Bucketing key for dynamic micro-batching, or None when the payload
    cannot join a stacked call: failed envelopes forward untouched, and
    only array payloads with a leading (batch) axis stack."""
    if isinstance(payload, _Failed):
        return None
    shape = getattr(payload, "shape", None)
    if shape is None or len(shape) == 0 or not hasattr(payload, "dtype"):
        return None
    return (tuple(shape), str(payload.dtype))


def _array_namespace(x: Any):
    """numpy for numpy arrays; jax.numpy (lazily) for device arrays, so
    stacking stays on-device; numpy as the generic fallback."""
    import numpy as np
    if isinstance(x, np.ndarray):
        return np
    try:
        import jax.numpy as jnp
        return jnp
    except Exception:       # pragma: no cover - jax is a core dep here
        return np


class ShapeKeyedStageCache:
    """Memoize built (typically jitted) stage callables per input signature.

    Stage builders close over sliced parameters and ``jax.jit`` wrappers;
    rebuilding them per server restart (or eagerly for shapes never served)
    wastes startup time and tracing.  ``get(name, x, build)`` builds the
    stage callable at most once per (stage name, input shape/dtype) and
    returns the cached callable afterwards, so steady-state batches reuse
    the already-traced function.  Micro-batched stages compose naturally:
    the stacked array is just another signature, so each realized bucket
    size gets its own traced callable.
    """

    def __init__(self) -> None:
        self._fns: Dict[Any, Callable[[Any], Any]] = {}
        self._lock = threading.Lock()

    def __len__(self) -> int:
        return len(self._fns)

    def get(self, name: str, x: Any,
            build: Callable[[], Callable[[Any], Any]]) -> Callable[[Any], Any]:
        key = (name, _shape_key(x))
        fn = self._fns.get(key)
        if fn is None:
            with self._lock:
                fn = self._fns.get(key)
                if fn is None:
                    fn = self._fns[key] = build()
        return fn

    def wrap(self, name: str,
             build: Callable[[], Callable[[Any], Any]]) -> Callable[[Any], Any]:
        """A stage function that lazily builds/caches per input signature."""
        def stage(x: Any) -> Any:
            return self.get(name, x, build)(x)
        return stage
