"""Host-threaded pipeline executor — faithful to the paper's implementation.

Paper §5.1 / Fig. 5: "we deploy a host thread per Edge TPU that is in charge
of handling it, and a queue (implementing thread-safe mechanisms) on the host
to communicate intermediate results among devices."

Here each *stage* owns a worker thread and an input queue; stage ``i`` pops an
item, applies its stage function, and pushes the result to stage ``i+1``'s
queue.  Stage functions are arbitrary callables: the CNN benchmarks bind them
to real JAX forwards of the stage's layers; tests bind simulated latencies to
validate the analytical pipeline model.

This executor is the *paper-faithful* path (host-mediated transfers).  The
pod-scale SPMD path (shard_map + ppermute over ICI) lives in
launch/pipeline_spmd.py and consumes the same SegmentationPlan.
"""
from __future__ import annotations

import queue
import threading
import time
from typing import Any, Callable, List, Optional, Sequence, Tuple

_SENTINEL = object()


class PipelineExecutor:
    """Run inputs through a chain of stage functions with one thread/stage."""

    def __init__(self, stage_fns: Sequence[Callable[[Any], Any]],
                 queue_size: int = 64):
        if not stage_fns:
            raise ValueError("need at least one stage")
        self.stage_fns = list(stage_fns)
        self.queue_size = queue_size

    @property
    def n_stages(self) -> int:
        return len(self.stage_fns)

    def run_batch(self, inputs: Sequence[Any],
                  collect_stage_times: bool = False
                  ) -> Tuple[List[Any], Optional[List[float]]]:
        """Push `inputs` through the pipeline; returns (outputs, stage_busy_s).

        Outputs preserve input order (in-order queues).  ``stage_busy_s[i]``
        is the total busy time of stage i — the paper's Fig. 10 metric.
        """
        n = self.n_stages
        qs: List[queue.Queue] = [queue.Queue(self.queue_size) for _ in range(n + 1)]
        busy = [0.0] * n
        errors: List[BaseException] = []

        def worker(i: int) -> None:
            fn = self.stage_fns[i]
            while True:
                item = qs[i].get()
                if item is _SENTINEL:
                    qs[i + 1].put(_SENTINEL)
                    return
                try:
                    t0 = time.perf_counter()
                    out = fn(item)
                    busy[i] += time.perf_counter() - t0
                except BaseException as e:   # surface worker failures
                    errors.append(e)
                    qs[i + 1].put(_SENTINEL)
                    return
                qs[i + 1].put(out)

        threads = [threading.Thread(target=worker, args=(i,), daemon=True)
                   for i in range(n)]
        for t in threads:
            t.start()
        for x in inputs:
            qs[0].put(x)
        qs[0].put(_SENTINEL)

        outputs: List[Any] = []
        while True:
            item = qs[n].get()
            if item is _SENTINEL:
                break
            outputs.append(item)
        for t in threads:
            t.join(timeout=30)
        if errors:
            raise errors[0]
        return outputs, (busy if collect_stage_times else None)

    def timed_run(self, inputs: Sequence[Any]) -> Tuple[List[Any], float, List[float]]:
        t0 = time.perf_counter()
        outs, busy = self.run_batch(inputs, collect_stage_times=True)
        return outs, time.perf_counter() - t0, busy or []


def simulated_stage(latency_s: float) -> Callable[[Any], Any]:
    """A stage that just sleeps — used to validate the pipeline time model."""
    def fn(x: Any) -> Any:
        time.sleep(latency_s)
        return x
    return fn


def stage_balance_metrics(stage_times: Sequence[float]) -> dict:
    """Paper Fig. 10 metrics: slowest stage time and deviation from mean."""
    mx = max(stage_times)
    mean = sum(stage_times) / len(stage_times)
    return {"max_stage_s": mx, "mean_stage_s": mean,
            "max_minus_mean_s": mx - mean,
            "balance": mean / mx if mx > 0 else 1.0}
