"""Device topology abstraction: heterogeneous device specs for placement.

The paper assumes a linear chain of *identical* Edge TPUs, so its plan is a
bare cut list.  DistrEdge-style distributed-edge setups (PAPERS.md, arXiv
2202.01699) break both assumptions: devices differ (on-chip memory, compute
rate, link bandwidth) and a bottleneck stage may be *replicated* across
several devices.  This module provides the vocabulary the
:class:`~repro.core.placement.PlacementPlan` hand-off needs:

* :class:`DeviceSpec` — one device, expressed as deltas against the
  calibrated :class:`~repro.core.edge_tpu_model.EdgeTPUSpec` (memory
  capacity override + compute / stream-bandwidth scale factors).  The
  default spec is the paper's device bit-for-bit: ``specialize`` returns
  the base spec object unchanged, so homogeneous plans price segments with
  the exact same floats as before.
* :class:`Topology` — an ordered chain of devices (the pipeline order).
  Stages consume consecutive runs of devices; a replicated stage consumes
  ``k`` *identical* consecutive devices (round-robin fan-out needs equal
  service rates for an even split).
* :class:`TopologyCostModel` — per-device segment pricing.  One
  :class:`~repro.core.cost_engine.SegmentCostEngine` per distinct device
  spec, all sharing the graph-side precomputes (prefix sums, sparse table,
  flat layer order) via :meth:`SegmentCostEngine.with_spec`, so adding a
  device class costs O(1) — not another O(L) rebuild.

Replication time model (the planner's rule): a stage replicated over ``k``
devices serves ``1/k`` of the traffic per device, so its *pacing* time
divides by ``k`` — except the systolic-array weight-load term, which every
replica pays per inference it serves and which therefore does not amortize:

    eff(seg, k) = t_weight_load(seg) + (t_stage(seg) - t_weight_load(seg)) / k

``k = 1`` returns ``t_stage`` exactly (no float re-association), keeping
no-replica plans bit-identical to the plain planner.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

from .cost_engine import SegmentCostEngine
from .edge_tpu_model import EdgeTPUModel, EdgeTPUSpec
from .graph import LayerGraph

MIB = 1024 * 1024


@dataclasses.dataclass(frozen=True)
class DeviceSpec:
    """One accelerator, as deltas against the calibrated Edge TPU spec.

    * ``onchip_bytes`` — on-chip memory capacity; ``None`` keeps the base
      spec's (8 MiB for the paper's device).
    * ``compute_scale`` — multiplies MAC throughput *and* the systolic
      weight-load rate (a wider array fills faster too).
    * ``bandwidth_scale`` — multiplies the host link (PCIe) rate used for
      streamed weights and stage I/O.
    """

    name: str = "edgetpu-v1"
    onchip_bytes: Optional[int] = None
    compute_scale: float = 1.0
    bandwidth_scale: float = 1.0

    @property
    def is_reference(self) -> bool:
        """True when this device is the base spec unchanged."""
        return (self.onchip_bytes is None and self.compute_scale == 1.0
                and self.bandwidth_scale == 1.0)

    def specialize(self, base: EdgeTPUSpec) -> EdgeTPUSpec:
        """Concrete per-device spec.  Reference devices return ``base``
        itself so homogeneous pricing stays bit-identical."""
        if self.is_reference:
            return base
        return dataclasses.replace(
            base,
            onchip_bytes=(base.onchip_bytes if self.onchip_bytes is None
                          else self.onchip_bytes),
            mac_efficiency=base.mac_efficiency * self.compute_scale,
            weight_load_gbps=base.weight_load_gbps * self.compute_scale,
            pcie_gbps=base.pcie_gbps * self.bandwidth_scale,
        )

    def to_dict(self) -> Dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: Dict) -> "DeviceSpec":
        return cls(**d)


@dataclasses.dataclass(frozen=True)
class Topology:
    """An ordered chain of devices — the pipeline runs through them in
    order; stage ``i`` occupies a consecutive run of ``replicas[i]``
    devices."""

    devices: Tuple[DeviceSpec, ...]
    name: str = "chain"

    def __post_init__(self):
        if not self.devices:
            raise ValueError("topology needs at least one device")
        object.__setattr__(self, "devices", tuple(self.devices))

    @classmethod
    def homogeneous(cls, n: int, device: Optional[DeviceSpec] = None,
                    name: str = "chain") -> "Topology":
        dev = device if device is not None else DeviceSpec()
        return cls(devices=(dev,) * n, name=name)

    @property
    def n_devices(self) -> int:
        return len(self.devices)

    @property
    def is_homogeneous(self) -> bool:
        return all(d == self.devices[0] for d in self.devices)

    def can_group(self, dev_lo: int, k: int) -> bool:
        """Replica groups must be identical consecutive devices (round-robin
        fan-out splits traffic evenly, which needs equal service rates)."""
        group = self.devices[dev_lo:dev_lo + k]
        return len(group) == k and all(d == group[0] for d in group)

    def describe(self) -> str:
        runs: List[Tuple[DeviceSpec, int]] = []
        for d in self.devices:
            if runs and runs[-1][0] == d:
                runs[-1] = (d, runs[-1][1] + 1)
            else:
                runs.append((d, 1))
        return " + ".join(f"{k}x{d.name}" if k > 1 else d.name
                          for d, k in runs)


class TopologyCostModel:
    """Per-device segment pricing over one graph.

    Builds one :class:`SegmentCostEngine` per *distinct* device spec; all
    engines share the graph precomputes of the base engine (per-stage
    device limits without per-stage O(L) rebuilds).  This is the
    "per-stage device limits instead of one global ``tpu_mem_bytes``"
    object: each stage's memory capacity and time constants come from the
    device the placement assigns it.  ``cost_source`` (a
    :class:`~repro.profiling.sources.CostSource`) threads through to
    every per-device engine — a trace-backed source re-materializes its
    per-depth times per device class, scaled by the device's compute
    rate.
    """

    def __init__(self, graph: LayerGraph, topology: Topology,
                 base_spec: Optional[EdgeTPUSpec] = None, cost_source=None):
        self.graph = graph
        self.topology = topology
        self.base_model = EdgeTPUModel(graph, base_spec,
                                       cost_source=cost_source)
        self._engines: Dict[DeviceSpec, SegmentCostEngine] = {}

    def engine_for(self, device: DeviceSpec) -> SegmentCostEngine:
        eng = self._engines.get(device)
        if eng is None:
            spec = device.specialize(self.base_model.spec)
            base_engine = self.base_model.engine
            eng = (base_engine if spec is self.base_model.spec
                   else base_engine.with_spec(spec))
            self._engines[device] = eng
        return eng

    # -- per-device segment terms -------------------------------------------
    def stage_time(self, device: DeviceSpec, lo: int, hi: int) -> float:
        return self.engine_for(device).segment_time(lo, hi)

    def weight_load_time(self, device: DeviceSpec, lo: int, hi: int) -> float:
        return self.engine_for(device).segment_weight_load_time(lo, hi)

    def stage_host_bytes(self, device: DeviceSpec, lo: int, hi: int) -> int:
        return self.engine_for(device).segment_host_bytes(lo, hi)

    def effective_time(self, device: DeviceSpec, lo: int, hi: int,
                       replicas: int) -> float:
        """Pacing time of the segment on ``replicas`` copies of ``device``
        (weight-load does not amortize; see module docstring)."""
        t = self.stage_time(device, lo, hi)
        if replicas <= 1:
            return t
        t_w = self.weight_load_time(device, lo, hi)
        return t_w + (t - t_w) / replicas

    # -- planner hooks -------------------------------------------------------
    def placement_cost_fn(self):
        """``cost(lo, hi, dev_lo, k)`` for the joint cuts+replicas DP:
        +inf when the device run cannot form a replica group."""
        devices = self.topology.devices
        can_group = self.topology.can_group
        INF = float("inf")

        def cost(lo: int, hi: int, dev_lo: int, k: int) -> float:
            if k > 1 and not can_group(dev_lo, k):
                return INF
            return self.effective_time(devices[dev_lo], lo, hi, k)

        return cost

    def stage_reporters(self, devices: Sequence[DeviceSpec]):
        """One refine :class:`MemoryReporter` per stage, each bound to that
        stage's device limits."""
        from .refine import GraphReporter
        reporters = []
        for dev in devices:
            eng = self.engine_for(dev)
            reporters.append(GraphReporter(_EngineReporterAdapter(
                eng, self.graph)))
        return reporters


class _EngineReporterAdapter:
    """Duck-typed EdgeTPUModel stand-in for GraphReporter: exposes
    ``segment_report_bytes`` + ``graph`` + ``engine`` over a single
    engine (``engine`` lets the reporter share the cost source's
    per-depth bytes accounting)."""

    def __init__(self, engine: SegmentCostEngine, graph: LayerGraph):
        self._engine = engine
        self.engine = engine
        self.graph = graph

    def segment_report_bytes(self, lo: int, hi: int) -> Tuple[int, int]:
        return self._engine.segment_split(lo, hi)
