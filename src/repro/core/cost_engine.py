"""O(1) segment-cost engine: the fast path under the planner and refiner.

The paper's plan search evaluates thousands of candidate segments.  The seed
implementation re-walked every layer of the graph per candidate (and rebuilt
the cut-crossing activation array twice per ``segment_time`` call), making
``plan()`` quadratic-ish in model depth.  :class:`SegmentCostEngine`
precomputes, once per (graph, spec, cost source):

* per-depth prefix sums of params / MACs / weight bytes, so any contiguous
  segment's totals are two array reads;
* the flat layer order (depth-major, insertion order within a depth — the
  exact order the greedy whole-layer placement of paper §4.2 visits) plus a
  prefix-sum over per-layer weight bytes, so the greedy *spill point* of a
  segment is a binary search instead of a scan;
* a sparse table over the per-depth maximum single-layer activation, so the
  activation-reserve term of the capacity formula is an O(1) range-max;
* the cut-crossing activation bytes array (stage I/O term), computed once.

With these, ``segment_time`` is O(1) and the memory split is O(log L) plus a
short tail scan only when the segment actually spills (greedy placement may
still fit later-but-smaller layers after the first rejection, so the tail is
walked layer-by-layer to stay bit-identical with the naive placement).

Cost sources
------------
Where the per-depth numbers come from is pluggable: the engine materializes
a :class:`~repro.profiling.sources.CostSource` (duck-typed — this module
stays import-light) into its prefix arrays once.  Without a source — or
with the :class:`~repro.profiling.sources.AnalyticCostSource` — the arrays
are the graph's own cached lists and ``segment_time`` evaluates the
closed-form expression over segment sums, in the same float order as the
naive ``EdgeTPUModel`` paths: results are **bit-identical**, which
tests/test_cost_engine.py asserts over random segments of real Table-1
models.  A measured source (trace / calibrated) instead supplies per-depth
*times*; the engine prefix-sums them, so a segment's compute time is still
two array reads, and adds the memory-model transfer terms (host-resident
weight streaming, spill overhead, stage I/O) from the device spec exactly
as before — measured compute composed with modeled transfers, the paper's
profile-then-model pipeline.
"""
from __future__ import annotations

import bisect
import itertools
from typing import Dict, List, Sequence, Tuple

from .costs import greedy_layer_placement, greedy_layer_split, \
    weight_capacity_bytes
from .graph import LayerGraph


def _prefix(vals: Sequence[int]) -> List[int]:
    return list(itertools.accumulate(vals, initial=0))


def _fprefix(vals: Sequence[float]) -> List[float]:
    return list(itertools.accumulate(vals, initial=0.0))


class SegmentCostEngine:
    """Precomputed range queries over one :class:`LayerGraph` + device spec.

    ``spec`` is duck-typed (an :class:`~repro.core.edge_tpu_model.EdgeTPUSpec`
    in practice) to keep this module free of circular imports; so is
    ``cost_source`` (anything with ``materialize(graph, spec) ->
    DepthCosts``; ``None`` means the built-in analytic arithmetic).
    """

    def __init__(self, graph: LayerGraph, spec, cost_source=None):
        self.graph = graph
        self.spec = spec
        self.cost_source = cost_source
        levels = graph.levels()
        self.depth = len(levels)
        nodes = graph.nodes

        # flat layer order = greedy placement order (depth-major)
        self._flat: List[str] = [n for lvl in levels for n in lvl]
        self._level_start: List[int] = [0] * (self.depth + 1)
        pos = 0
        for d, lvl in enumerate(levels):
            self._level_start[d] = pos
            pos += len(lvl)
        self._level_start[self.depth] = pos
        self._layer_bytes: List[int] = [nodes[n].bytes for n in self._flat]
        self._layer_prefix: List[int] = _prefix(self._layer_bytes)

        # sparse table over per-depth max single-layer activation
        amax = [max((nodes[n].out_bytes for n in lvl), default=0)
                for lvl in levels]
        self._build_sparse(amax)

        self._materialize(spec)
        self._split_cache: Dict[Tuple[int, int], Tuple[int, int]] = {}

    def _materialize(self, spec) -> None:
        """Fill the per-depth prefix arrays from the cost source (or the
        graph directly when no source is set — same numbers, same list
        objects, zero overhead)."""
        src = self.cost_source
        if src is None:
            graph = self.graph
            params = graph.params_per_depth()
            macs = graph.macs_per_depth()
            weight_bytes = graph.bytes_per_depth()
            cut_bytes = graph.out_bytes_per_depth()
            time_s = wload_s = state_bytes = None
        else:
            dc = src.materialize(self.graph, spec)
            params, macs = dc.params, dc.macs
            weight_bytes, cut_bytes = dc.weight_bytes, dc.cut_bytes
            time_s, wload_s = dc.time_s, dc.weight_load_s
            state_bytes = getattr(dc, "state_bytes", None)
        self._params_prefix = _prefix(params)
        self._macs_prefix = _prefix(macs)
        self._bytes_prefix = _prefix(weight_bytes)
        self._cut_bytes = list(cut_bytes)
        # measured mode: per-depth times prefix-summed for O(1) segments
        self._time_prefix = None if time_s is None else _fprefix(time_s)
        self._wload_prefix = (None if wload_s is None
                              else _fprefix(wload_s))
        # decode mode: per-depth per-sequence state (KV / recurrent) bytes
        self._state_prefix = (None if state_bytes is None
                              else _prefix(state_bytes))

    @property
    def is_measured(self) -> bool:
        """True when segment compute times come from a trace-backed source
        instead of the closed-form analytic expression."""
        return self._time_prefix is not None

    @property
    def has_state_costs(self) -> bool:
        """True when the cost source supplies per-depth decode state bytes
        (KV cache / recurrent state) — the decode-placement regime."""
        return self._state_prefix is not None

    def with_spec(self, spec) -> "SegmentCostEngine":
        """An engine for the same graph under a different device spec.

        The graph-side precomputes (sparse table, flat layer order, layer
        prefix) are spec-independent, so the clone shares them by
        reference — per-stage device limits (heterogeneous topologies)
        cost O(1) per device class instead of another O(L) build.  Only
        the capacity/time queries see the new spec; a measured cost
        source re-materializes its per-depth times for the new device
        (O(d), still amortized once per device class).
        """
        clone = object.__new__(SegmentCostEngine)
        clone.__dict__.update(self.__dict__)
        clone.spec = spec
        clone._split_cache = {}          # capacity differs under the new spec
        if clone.cost_source is not None:
            clone._materialize(spec)     # device-dependent per-depth arrays
        return clone

    # -- sparse-table range max ---------------------------------------------
    def _build_sparse(self, vals: Sequence[int]) -> None:
        n = len(vals)
        log = [0] * (n + 1)
        for i in range(2, n + 1):
            log[i] = log[i // 2] + 1
        table = [list(vals)]
        k = 1
        while (1 << k) <= n:
            prev = table[-1]
            half = 1 << (k - 1)
            table.append([max(prev[i], prev[i + half])
                          for i in range(n - (1 << k) + 1)])
            k += 1
        self._log2 = log
        self._sparse = table

    def segment_max_activation(self, depth_lo: int, depth_hi: int) -> int:
        """Largest single-layer activation in the depth range — O(1)."""
        if depth_hi < depth_lo:
            return 0
        k = self._log2[depth_hi - depth_lo + 1]
        row = self._sparse[k]
        return max(row[depth_lo], row[depth_hi - (1 << k) + 1])

    # -- O(1) range sums -----------------------------------------------------
    def segment_params(self, depth_lo: int, depth_hi: int) -> int:
        return self._params_prefix[depth_hi + 1] - self._params_prefix[depth_lo]

    def segment_macs(self, depth_lo: int, depth_hi: int) -> int:
        return self._macs_prefix[depth_hi + 1] - self._macs_prefix[depth_lo]

    def segment_weight_bytes(self, depth_lo: int, depth_hi: int) -> int:
        return self._bytes_prefix[depth_hi + 1] - self._bytes_prefix[depth_lo]

    def segment_state_bytes(self, depth_lo: int, depth_hi: int) -> int:
        """Per-sequence decode state (KV cache / recurrent) bytes the
        segment pins on-device — 0 unless the cost source supplies a
        decode regime (:attr:`has_state_costs`)."""
        if self._state_prefix is None:
            return 0
        return (self._state_prefix[depth_hi + 1]
                - self._state_prefix[depth_lo])

    def depth_weight_bytes(self) -> List[int]:
        """Per-depth weight bytes as the cost source accounts them — the
        refinement reporter's multi-step move sizing reads these, so the
        refiner and the planner share one bytes model."""
        p = self._bytes_prefix
        return [p[d + 1] - p[d] for d in range(self.depth)]

    def cut_io_bytes(self, depth_lo: int, depth_hi: int) -> Tuple[int, int]:
        """(input, output) activation bytes crossing the segment boundaries."""
        in_b = self._cut_bytes[depth_lo - 1] if depth_lo > 0 else 0
        out_b = (self._cut_bytes[depth_hi]
                 if depth_hi < self.depth - 1 else 0)
        return in_b, out_b

    # -- memory (paper §4.2 greedy placement) --------------------------------
    def segment_capacity(self, depth_lo: int, depth_hi: int) -> int:
        """Weight capacity after the fixed + activation reserves."""
        spec = self.spec
        act = self.segment_max_activation(depth_lo, depth_hi)
        return weight_capacity_bytes(spec.onchip_bytes, spec.fixed_reserve,
                                     spec.act_reserve_factor, act)

    def segment_split(self, depth_lo: int, depth_hi: int) -> Tuple[int, int]:
        """(device_bytes, host_bytes) of the greedy whole-layer placement.

        Binary search over the weight-bytes prefix array finds the greedy
        spill point (the first rejected layer); only the tail after it is
        scanned, because already-rejected capacity never recovers but smaller
        later layers may still fit.
        """
        key = (depth_lo, depth_hi)
        hit = self._split_cache.get(key)
        if hit is not None:
            return hit
        a = self._level_start[depth_lo]
        b = self._level_start[depth_hi + 1]
        cap = self.segment_capacity(depth_lo, depth_hi)
        prefix = self._layer_prefix
        base = prefix[a]
        # largest m with sum(bytes of first m layers) <= cap
        idx = bisect.bisect_right(prefix, base + cap, a, b + 1) - 1
        if idx >= b:                      # everything fits on-device
            result = (prefix[b] - base, 0)
            self._split_cache[key] = result
            return result
        idx = max(idx, a)
        # tail: greedy continues per-layer from the already-placed prefix
        result = greedy_layer_split(self._layer_bytes[idx:b], cap,
                                    device0=prefix[idx] - base)
        self._split_cache[key] = result
        return result

    def segment_host_bytes(self, depth_lo: int, depth_hi: int) -> int:
        return self.segment_split(depth_lo, depth_hi)[1]

    def segment_placement(self, depth_lo: int, depth_hi: int
                          ) -> Tuple[int, int, Dict[str, str]]:
        """Full (device, host, {layer: placement}) report — O(segment)."""
        a = self._level_start[depth_lo]
        b = self._level_start[depth_hi + 1]
        cap = self.segment_capacity(depth_lo, depth_hi)
        return greedy_layer_placement(self._flat[a:b],
                                      self._layer_bytes[a:b], cap)

    # -- time ----------------------------------------------------------------
    def segment_weight_load_time(self, depth_lo: int, depth_hi: int) -> float:
        """Systolic-array weight-fill time of the segment — the stage-time
        term that does NOT amortize when a stage is replicated (every
        replica re-fills its array per inference it serves)."""
        if self._wload_prefix is not None:
            return (self._wload_prefix[depth_hi + 1]
                    - self._wload_prefix[depth_lo])
        weight_bytes = self.segment_weight_bytes(depth_lo, depth_hi)
        return weight_bytes / (self.spec.weight_load_gbps * 1e9)

    def segment_compute_time(self, depth_lo: int, depth_hi: int) -> float:
        """Compute + weight-load time only (no transfer terms): the term a
        measured cost source replaces."""
        if self._time_prefix is not None:
            return self._time_prefix[depth_hi + 1] - self._time_prefix[depth_lo]
        spec = self.spec
        macs = self.segment_macs(depth_lo, depth_hi)
        weight_bytes = self.segment_weight_bytes(depth_lo, depth_hi)
        return (macs / spec.macs_per_s
                + weight_bytes / (spec.weight_load_gbps * 1e9))

    def segment_time(self, depth_lo: int, depth_hi: int) -> float:
        """Per-inference latency of one segment on one TPU — O(1).

        Analytic mode: same expression (and float evaluation order) as the
        naive ``EdgeTPUModel.segment_time`` — systolic compute + weight
        load + host-resident weight streaming + spill overhead + stage I/O
        + per-inference overhead.  Measured mode: the compute+weight-load
        term is the prefix-summed per-depth source time; the transfer
        terms still come from the memory model.
        """
        spec = self.spec
        t_compute = self.segment_compute_time(depth_lo, depth_hi)
        host_bytes = self.segment_host_bytes(depth_lo, depth_hi)
        t_stream = host_bytes / (spec.pcie_gbps * 1e9)
        t_spill = spec.spill_event_overhead_s if host_bytes > 0 else 0.0
        in_bytes, out_bytes = self.cut_io_bytes(depth_lo, depth_hi)
        t_io = (in_bytes + out_bytes) / (spec.pcie_gbps * 1e9)
        return (t_compute + t_stream + t_spill + t_io
                + spec.per_inference_overhead_s)

    def depth_cost_ns(self) -> List[int]:
        """Integer per-depth compute cost in nanoseconds — the balance
        weights of the ``balanced_cost`` strategy.  Analytic mode keeps
        that strategy's historical expression exactly; measured mode uses
        the source's per-depth times."""
        if self._time_prefix is not None:
            tp = self._time_prefix
            return [int(1e9 * (tp[d + 1] - tp[d])) for d in range(self.depth)]
        spec = self.spec
        mp, bp = self._macs_prefix, self._bytes_prefix
        return [int(1e9 * ((mp[d + 1] - mp[d]) / spec.macs_per_s
                           + (bp[d + 1] - bp[d])
                           / (spec.weight_load_gbps * 1e9)))
                for d in range(self.depth)]

    def stage_times(self, cuts: Sequence[int]) -> List[float]:
        from .segmentation import segment_ranges
        return [self.segment_time(lo, hi)
                for lo, hi in segment_ranges(self.depth, cuts)]

    def max_stage_time(self, cuts: Sequence[int]) -> float:
        return max(self.stage_times(cuts))
