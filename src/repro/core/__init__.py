"""Core of the paper's contribution: balanced DAG segmentation for
multi-accelerator pipelined inference (SEGM_COMP / SEGM_PROF / SEGM_BALANCED),
extended with topology-aware placement (heterogeneous devices, replicated
bottleneck stages)."""
from .graph import LayerGraph, LayerNode, chain_graph
from .segmentation import (balanced_split, comp_split, dp_split, imbalance,
                           max_segment, minimax_time_split, placement_split,
                           prof_split, segment_ranges, segment_sums,
                           split_check)
from .cost_engine import SegmentCostEngine
from .refine import GraphReporter, RefinementResult, refine_cuts
from .topology import DeviceSpec, Topology, TopologyCostModel
from .placement import (PlacementPlan, SegmentationPlan, StagePlacement,
                        min_stages_no_spill, min_stages_to_fit)
from .edge_tpu_model import EdgeTPUModel, EdgeTPUSpec, MemoryReport
from .pipeline import (PipelineExecutor, PipelineStopped, ReplicaFailure,
                       ShapeKeyedStageCache, StageLost, simulated_stage,
                       stage_balance_metrics)

__all__ = [
    "LayerGraph", "LayerNode", "chain_graph",
    "balanced_split", "comp_split", "dp_split", "minimax_time_split",
    "placement_split", "prof_split", "split_check",
    "segment_sums", "segment_ranges", "max_segment", "imbalance",
    "SegmentCostEngine",
    "GraphReporter", "RefinementResult", "refine_cuts",
    "DeviceSpec", "Topology", "TopologyCostModel",
    "PlacementPlan", "SegmentationPlan", "StagePlacement",
    "min_stages_to_fit", "min_stages_no_spill",
    "EdgeTPUModel", "EdgeTPUSpec", "MemoryReport",
    "PipelineExecutor", "PipelineStopped", "ReplicaFailure", "StageLost",
    "ShapeKeyedStageCache", "simulated_stage", "stage_balance_metrics",
]
