"""The plan hand-off types: ``StagePlacement`` / ``PlacementPlan``.

The plan is the single hand-off object between the paper's algorithms and
the executors: the host-threaded pipeline (core/pipeline.py), the SPMD
pipeline (launch/pipeline_spmd.py), and the benchmarks all consume a plan.

PR-1's ``SegmentationPlan`` was a bare cut list — implicitly one identical
device per stage.  The hand-off is a :class:`PlacementPlan`: an ordered
list of :class:`StagePlacement` records, each carrying its depth range, its
assigned :class:`~repro.core.topology.DeviceSpec`, and a **replica count**
(a bottleneck stage may be replicated across k identical devices with
round-robin fan-out/fan-in in the executor).  ``PlacementPlan.from_cuts``
is the thin compatibility constructor: homogeneous no-replica plans carry
the exact cuts and modeled stage times the cut-list plans did.
``SegmentationPlan`` remains as a deprecated alias.

This module is the canonical import location for the plan types (it also
keeps the stage-count rules ``min_stages_to_fit`` / ``min_stages_no_spill``).
``repro.core.planner`` — their pre-PR-7 home — is a raising-stub shim for
the removed legacy orchestration entry points and re-exports nothing.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Any, Dict, List, Optional, Sequence, Tuple

from .edge_tpu_model import EdgeTPUModel
from .graph import LayerGraph
from .refine import RefinementResult
from .segmentation import segment_ranges, segment_sums
from .topology import DeviceSpec


@dataclasses.dataclass
class StagePlacement:
    """One pipeline stage: a depth range placed on a device, possibly
    replicated.

    ``time_s`` is the modeled per-inference latency of the segment on ONE
    copy of ``device`` (the analytical Edge TPU model); the *pacing* time
    under replication is :attr:`effective_time_s` — the weight-load term
    does not amortize across replicas (every replica re-fills its systolic
    array per inference it serves), the rest divides by ``replicas``.
    """

    depth_lo: int
    depth_hi: int
    layers: List[str]
    params: int
    device: DeviceSpec = dataclasses.field(default_factory=DeviceSpec)
    replicas: int = 1
    time_s: Optional[float] = None
    weight_load_s: Optional[float] = None

    @property
    def depth_range(self) -> Tuple[int, int]:
        return (self.depth_lo, self.depth_hi)

    @property
    def effective_time_s(self) -> Optional[float]:
        if self.time_s is None:
            return None
        if self.replicas <= 1:
            return self.time_s
        if self.weight_load_s is None:
            return None    # cannot amortize without the non-amortizing term
        t_w = self.weight_load_s
        return t_w + (self.time_s - t_w) / self.replicas

    def to_dict(self) -> Dict:
        return {
            "depth_lo": self.depth_lo, "depth_hi": self.depth_hi,
            "layers": list(self.layers), "params": self.params,
            "device": self.device.to_dict(), "replicas": self.replicas,
            "time_s": self.time_s, "weight_load_s": self.weight_load_s,
        }

    @classmethod
    def from_dict(cls, d: Dict) -> "StagePlacement":
        d = dict(d)
        d["device"] = DeviceSpec.from_dict(d["device"])
        return cls(**d)


@dataclasses.dataclass
class PlacementPlan:
    """Ordered stage placements for a model pipeline.

    The compatibility surface of the old cut-list plan is preserved as
    properties (``cuts``, ``stage_depth_ranges``, ``stage_layers``,
    ``stage_params``, ``n_stages``), so code that only cares about where
    the cuts fall keeps working; replication-aware consumers read
    ``stages`` / ``replica_counts`` / ``n_devices``.
    """

    graph_name: str
    strategy: str
    stages: List[StagePlacement]
    refinement: Optional[RefinementResult] = None
    # modeled quality/memory record (repro.api.PlanReport); attached by the
    # repro.api front door, carried through JSON round-trips
    report: Optional[Any] = None

    # -- compatibility surface (cut-list view) ------------------------------
    @property
    def n_stages(self) -> int:
        return len(self.stages)

    @property
    def n_devices(self) -> int:
        return sum(s.replicas for s in self.stages)

    @property
    def cuts(self) -> List[int]:
        return [s.depth_hi for s in self.stages[:-1]]

    @property
    def stage_depth_ranges(self) -> List[tuple]:
        return [(s.depth_lo, s.depth_hi) for s in self.stages]

    @property
    def stage_layers(self) -> List[List[str]]:
        return [s.layers for s in self.stages]

    @property
    def stage_params(self) -> List[int]:
        return [s.params for s in self.stages]

    @property
    def replica_counts(self) -> List[int]:
        return [s.replicas for s in self.stages]

    @property
    def stage_times_s(self) -> List[Optional[float]]:
        """Modeled per-inference stage times on one device each."""
        return [s.time_s for s in self.stages]

    @property
    def effective_stage_times_s(self) -> List[Optional[float]]:
        """Pacing times with replication amortization applied."""
        return [s.effective_time_s for s in self.stages]

    @property
    def max_stage_time_s(self) -> Optional[float]:
        eff = [t for t in self.effective_stage_times_s if t is not None]
        return max(eff) if eff else None

    @property
    def imbalance(self) -> int:
        """Δs (paper Table 5): largest minus smallest stage, in params."""
        return max(self.stage_params) - min(self.stage_params)

    def describe(self) -> str:
        """One-line plan summary.

        Homogeneous, no-replica plan (the paper's shape)::

            resnet50 / opt x4: S0[d0-17]=6.31M, ... (Δs=1.05M)

        Replicated / heterogeneous placements annotate stages with the
        device and replica count::

            resnet50 / opt_placement x3 (5 devs): S0[d0-17]=6.31M,
            S1[d18-29]=8.1M@edgetpu-v1x3, S2[d30-52]=7.9M (Δs=1.79M)
        """
        segs = []
        for i, st in enumerate(self.stages):
            tag = ""
            if not st.device.is_reference:
                tag += f"@{st.device.name}"
            if st.replicas > 1:
                tag = (tag or f"@{st.device.name}") + f"x{st.replicas}"
            segs.append(f"S{i}[d{st.depth_lo}-{st.depth_hi}]"
                        f"={st.params/1e6:.2f}M{tag}")
        head = f"{self.graph_name} / {self.strategy} x{self.n_stages}"
        if self.n_devices != self.n_stages:
            head += f" ({self.n_devices} devs)"
        return f"{head}: {', '.join(segs)} (Δs={self.imbalance/1e6:.2f}M)"

    # -- construction --------------------------------------------------------
    @classmethod
    def from_cuts(
        cls,
        graph: LayerGraph,
        cuts: Sequence[int],
        strategy: str = "manual",
        device: Optional[DeviceSpec] = None,
        replicas: Optional[Sequence[int]] = None,
        devices: Optional[Sequence[DeviceSpec]] = None,
        tpu_model: Optional[EdgeTPUModel] = None,
        refinement: Optional[RefinementResult] = None,
    ) -> "PlacementPlan":
        """Thin compatibility constructor: a cut list over ``graph``
        becomes a placement on homogeneous reference devices (one per
        stage, no replication) unless per-stage ``devices`` / ``replicas``
        say otherwise.  Modeled stage times come from ``tpu_model`` (or a
        default :class:`EdgeTPUModel`) — on the default device they are
        bit-identical to the cut-list planner's, since the same engine
        prices the same segments."""
        d = graph.depth
        ranges = segment_ranges(d, cuts)
        s = len(ranges)
        dev_list = (list(devices) if devices is not None
                    else [device if device is not None else DeviceSpec()] * s)
        rep_list = list(replicas) if replicas is not None else [1] * s
        if len(dev_list) != s or len(rep_list) != s:
            raise ValueError(f"need {s} per-stage devices/replicas, got "
                             f"{len(dev_list)}/{len(rep_list)}")
        model = tpu_model or EdgeTPUModel(graph)
        # slice the cached levels (O(L) total) instead of re-scanning the
        # whole graph per stage (O(s * L))
        levels = graph.levels()
        P = graph.params_per_depth()
        params = segment_sums(P, cuts)
        stages = []
        for i, (lo, hi) in enumerate(ranges):
            dev = dev_list[i]
            eng = (model.engine if dev.is_reference
                   else model.engine.with_spec(dev.specialize(model.spec)))
            stages.append(StagePlacement(
                depth_lo=lo, depth_hi=hi,
                layers=[n for lvl in levels[lo:hi + 1] for n in lvl],
                params=params[i], device=dev, replicas=rep_list[i],
                time_s=eng.segment_time(lo, hi),
                weight_load_s=eng.segment_weight_load_time(lo, hi)))
        return cls(graph_name=graph.name, strategy=strategy, stages=stages,
                   refinement=refinement)

    # -- (de)serialization ---------------------------------------------------
    def to_json(self, indent: Optional[int] = None) -> str:
        """Persistable plan: benchmarks and serving ship plans instead of
        re-planning at startup."""
        doc = {
            "format": "repro.placement_plan/v1",
            "graph_name": self.graph_name,
            "strategy": self.strategy,
            "stages": [s.to_dict() for s in self.stages],
            "refinement": (None if self.refinement is None else {
                "cuts": list(self.refinement.cuts),
                "compilations": self.refinement.compilations,
                "moves": self.refinement.moves,
                "converged": self.refinement.converged,
            }),
            "report": (None if self.report is None
                       else self.report.to_dict()),
        }
        return json.dumps(doc, indent=indent)

    @classmethod
    def from_json(cls, text: str) -> "PlacementPlan":
        doc = json.loads(text)
        fmt = doc.get("format")
        if fmt != "repro.placement_plan/v1":
            raise ValueError(f"not a placement plan document: {fmt!r}")
        ref = doc.get("refinement")
        rep = doc.get("report")
        if rep is not None:
            from ..api.report import PlanReport
            rep = PlanReport.from_dict(rep)
        return cls(
            graph_name=doc["graph_name"], strategy=doc["strategy"],
            stages=[StagePlacement.from_dict(s) for s in doc["stages"]],
            refinement=None if ref is None else RefinementResult(**ref),
            report=rep)


# deprecated alias: PR-1 consumers imported the cut-list plan by this name
SegmentationPlan = PlacementPlan


def min_stages_to_fit(graph: LayerGraph, capacity_bytes: int) -> int:
    """ceil(model_size / capacity): the paper's TPU-count rule (Table 5 note:
    'a model occupying S MiB has been fragmented into ceil(S/8) TPUs')."""
    total = graph.total_bytes
    return max(1, -(-total // capacity_bytes))


def min_stages_no_spill(graph: LayerGraph,
                        tpu_model: Optional[EdgeTPUModel] = None,
                        max_extra: int = 4) -> int:
    """The paper's working rule (§5.2.2): 'the minimum number of TPUs that
    would ideally avoid host memory usage' — smallest n whose refined
    balanced plan leaves every segment on-device."""
    from ..api import DeploymentSpec
    from ..api import plan as api_plan
    model = tpu_model or EdgeTPUModel(graph)
    start = min_stages_to_fit(graph, model.spec.onchip_bytes)
    for n in range(start, start + max_extra + 1):
        if n >= graph.depth:
            return n
        pl = api_plan(DeploymentSpec(stages=n, strategy="balanced"),
                      graph=graph, tpu_model=model, attach_report=False)
        if all(m.host_bytes == 0 for m in model.stage_memories(pl.cuts)):
            return n
    return start + max_extra
