"""Declarative fleet description: N member deployments, one device pool.

A :class:`FleetSpec` is to the fleet what
:class:`~repro.api.spec.DeploymentSpec` is to one model: frozen (hashable,
safe as a cache key), JSON-round-trippable (``from_json(to_json(f)) == f``
exactly), and free of live Python objects — graphs and stage-function
builders are runtime overrides passed to ``repro.fleet.deploy_fleet``.

Each :class:`FleetMemberSpec` names one model deployment (a full nested
``DeploymentSpec`` — model ref, strategy, serving/fault policy, and the
SLO fields ``slo_p95_ms`` / ``slo_throughput_rps``) plus the fleet-level
knobs that have no meaning standalone: the weighted-fair-queueing
``share`` and the member's device-count bounds for the autoscaler.

The member spec must leave its device shape open (``stages`` /
``topology`` / ``device_budget`` unset): the pool-split solver decides
how many of the *fleet's* devices each member gets — a member that pins
its own shape has opted out of the one decision the fleet exists to make.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Dict, Optional, Tuple

from ..api.spec import DeploymentSpec
from ..core.topology import DeviceSpec, Topology

FLEET_SPEC_FORMAT = "repro.fleet_spec/v1"


@dataclasses.dataclass(frozen=True)
class FleetMemberSpec:
    """One fleet member: a deployment spec plus its fleet-level policy.

    * ``name`` — the routing key (``Fleet.submit(name, payload)``); unique
      within the fleet.
    * ``spec`` — the member's :class:`DeploymentSpec`.  Its SLO fields
      drive the pool split and the autoscaler; its serving policy
      (deadline, shedding, micro-batching) applies unchanged to the
      member's own server.
    * ``share`` — weighted-fair-queueing weight (deficit round-robin
      quantum is proportional to it) and the demand prior the pool-split
      solver falls back to when a member declares no SLO.
    * ``min_devices`` / ``max_devices`` — autoscaler bounds; the fleet
      never resizes a member below ``min_devices`` (floor 1) or above
      ``max_devices`` (``None`` = unbounded).
    """

    name: str
    spec: DeploymentSpec
    share: float = 1.0
    min_devices: int = 1
    max_devices: Optional[int] = None

    def __post_init__(self):
        if not self.name:
            raise ValueError("fleet member needs a name (the routing key)")
        if self.spec.model is None:
            raise ValueError(f"member {self.name!r}: spec needs a model "
                             f"ref (the fleet resolves graphs from it)")
        if self.share <= 0:
            raise ValueError(f"member {self.name!r}: share must be > 0, "
                             f"got {self.share}")
        if self.min_devices < 1:
            raise ValueError(f"member {self.name!r}: min_devices must be "
                             f">= 1, got {self.min_devices}")
        if (self.max_devices is not None
                and self.max_devices < self.min_devices):
            raise ValueError(f"member {self.name!r}: max_devices "
                             f"({self.max_devices}) < min_devices "
                             f"({self.min_devices})")
        if (self.spec.stages is not None
                or self.spec.topology is not None
                or self.spec.device_budget is not None):
            raise ValueError(
                f"member {self.name!r}: spec must leave stages/topology/"
                f"device_budget unset — the fleet's pool-split solver "
                f"assigns the device shape")

    def to_dict(self) -> Dict:
        return {
            "name": self.name,
            "spec": self.spec.to_dict(),
            "share": self.share,
            "min_devices": self.min_devices,
            "max_devices": self.max_devices,
        }

    @classmethod
    def from_dict(cls, d: Dict) -> "FleetMemberSpec":
        d = dict(d)
        d["spec"] = DeploymentSpec.from_dict(d["spec"])
        return cls(**d)


@dataclasses.dataclass(frozen=True)
class FleetSpec:
    """N member deployments over one shared device pool.

    Pool
    ----
    * ``topology`` / ``device_budget`` — the shared device chain, or the
      homogeneous shorthand ``Topology.homogeneous(device_budget)``;
      mutually exclusive, exactly one required.

    Autoscaler policy (consumed by :class:`~repro.fleet.autoscale
    .FleetAutoscaler`; every knob also overridable via an explicit
    ``AutoscalePolicy``)
    ---------------------------------------------------------------
    * ``rebalance_cooldown_windows`` — observation windows suppressed
      after any device move (the moved pair needs fresh telemetry, and
      the guard verdict is read at the end of the cooldown).
    * ``rebalance_headroom`` — a donor must keep at least this much
      modeled SLO headroom (attainment ratio) after giving up a device;
      > 1 biases toward stability over perfect packing.
    """

    members: Tuple[FleetMemberSpec, ...] = ()
    topology: Optional[Topology] = None
    device_budget: Optional[int] = None
    rebalance_cooldown_windows: int = 2
    rebalance_headroom: float = 1.2

    def __post_init__(self):
        object.__setattr__(self, "members", tuple(self.members))
        if not self.members:
            raise ValueError("fleet needs at least one member")
        names = [m.name for m in self.members]
        if len(set(names)) != len(names):
            dupes = sorted({n for n in names if names.count(n) > 1})
            raise ValueError(f"duplicate member names: {dupes}")
        if (self.topology is None) == (self.device_budget is None):
            raise ValueError("fleet needs exactly one of topology or "
                             "device_budget (the shared pool)")
        if self.device_budget is not None and self.device_budget < 1:
            raise ValueError(f"device_budget must be >= 1, "
                             f"got {self.device_budget}")
        if self.rebalance_cooldown_windows < 0:
            raise ValueError("rebalance_cooldown_windows must be >= 0")
        if self.rebalance_headroom <= 0:
            raise ValueError("rebalance_headroom must be > 0")
        floor = sum(m.min_devices for m in self.members)
        pool = self.pool().n_devices
        # a pool smaller than the member count is legal (time-sliced
        # co-residency) but the declared per-member floors must fit the
        # partitioned mode they apply to
        if pool >= len(self.members) and floor > pool:
            raise ValueError(
                f"sum of member min_devices ({floor}) exceeds the pool "
                f"({pool} devices)")

    # -- derived views -------------------------------------------------------
    def pool(self) -> Topology:
        """The shared device chain (homogeneous shorthand expanded)."""
        if self.topology is not None:
            return self.topology
        return Topology.homogeneous(self.device_budget, name="pool")

    def member(self, name: str) -> FleetMemberSpec:
        for m in self.members:
            if m.name == name:
                return m
        raise KeyError(f"no fleet member {name!r}; members: "
                       f"{[m.name for m in self.members]}")

    @property
    def member_names(self) -> Tuple[str, ...]:
        return tuple(m.name for m in self.members)

    @property
    def total_share(self) -> float:
        return sum(m.share for m in self.members)

    # -- (de)serialization ---------------------------------------------------
    def to_dict(self) -> Dict:
        doc = {
            "format": FLEET_SPEC_FORMAT,
            "members": [m.to_dict() for m in self.members],
            "topology": None,
            "device_budget": self.device_budget,
            "rebalance_cooldown_windows": self.rebalance_cooldown_windows,
            "rebalance_headroom": self.rebalance_headroom,
        }
        if self.topology is not None:
            doc["topology"] = {
                "name": self.topology.name,
                "devices": [d.to_dict() for d in self.topology.devices],
            }
        return doc

    @classmethod
    def from_dict(cls, doc: Dict) -> "FleetSpec":
        doc = dict(doc)
        fmt = doc.pop("format", FLEET_SPEC_FORMAT)
        if fmt != FLEET_SPEC_FORMAT:
            raise ValueError(f"not a fleet spec document: {fmt!r}")
        topo = doc.get("topology")
        if topo is not None:
            doc["topology"] = Topology(
                devices=tuple(DeviceSpec.from_dict(d)
                              for d in topo["devices"]),
                name=topo.get("name", "pool"))
        doc["members"] = tuple(FleetMemberSpec.from_dict(m)
                               for m in doc.get("members", ()))
        return cls(**doc)

    def to_json(self, indent: Optional[int] = None) -> str:
        return json.dumps(self.to_dict(), indent=indent)

    @classmethod
    def from_json(cls, text: str) -> "FleetSpec":
        return cls.from_dict(json.loads(text))
