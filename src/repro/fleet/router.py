"""Admission front door: one ``submit()`` for N member servers.

The router is the only thing a fleet client sees — ``submit(model,
payload) -> Request`` — and it owns the *cross-model* fairness decision
the per-model servers cannot make: which member's queue gets the next
dispatch slot.  It runs **deficit round-robin** (DRR) weighted fair
queueing on member ``share``: each sweep credits every backlogged member
``share_i / min_share`` dispatch credits and drains whole requests while
credit lasts, so over any backlogged interval member throughput
converges to the share ratio without starving anyone (a member's unused
credit dies with its empty queue, per classic DRR).

Everything *below* the dispatch decision reuses the PR-8 overload
machinery unchanged: a routed request carries an absolute deadline fixed
at submit time; the remaining budget is recomputed at dispatch and
handed to the member server's own ``submit(deadline_s=)``, so the
member-side shed/deadline logic (pace-EWMA queue-delay estimate,
``Overloaded`` with jittered ``retry_after_s``, merge-exit
``DeadlineExceeded``) applies per model with its own policy.  A request
that dies *in the router queue* completes with ``DeadlineExceeded`` at
``"router"`` — the queue wait is charged against the same budget, never
hidden.  Completion chains back through ``Request.on_done`` (no polling
thread per request).
"""
from __future__ import annotations

import threading
import time
from collections import deque
from typing import Any, Callable, Dict, List, Optional

from ..core.pipeline import PipelineStopped
from ..serving.server import (DeadlineExceeded, PipelinedModelServer,
                              Request, _RID)

ServerSupplier = Callable[[], Optional[PipelinedModelServer]]


class FleetRouter:
    """Weighted-fair admission over per-member servers.

    ``servers`` maps member name -> supplier returning that member's
    *current* live server (suppliers, not servers: the autoscaler
    hot-swaps plans inside a server, and the fleet may cycle servers —
    the router always dispatches to whatever is live now).
    ``shares`` maps member name -> DRR weight; ``deadlines_s`` member
    name -> default relative deadline budget (``None`` = none).
    """

    def __init__(self, servers: Dict[str, ServerSupplier],
                 shares: Dict[str, float],
                 deadlines_s: Optional[Dict[str, Optional[float]]] = None):
        if set(servers) != set(shares):
            raise ValueError("servers and shares must cover the same "
                             "member names")
        if not servers:
            raise ValueError("router needs at least one member")
        for name, s in shares.items():
            if s <= 0:
                raise ValueError(f"member {name!r}: share must be > 0")
        self._servers = dict(servers)
        self._shares = dict(shares)
        self._deadlines = dict(deadlines_s or {})
        self._names = sorted(servers)       # fixed sweep order
        min_share = min(self._shares.values())
        self._quantum = {n: self._shares[n] / min_share
                         for n in self._names}
        self._deficit = {n: 0.0 for n in self._names}
        self._queues: Dict[str, deque] = {n: deque() for n in self._names}
        self._cv = threading.Condition()
        self._stop_evt = threading.Event()
        self._thread: Optional[threading.Thread] = None
        self._stats_lock = threading.Lock()
        self.stats: Dict[str, Dict[str, int]] = {
            n: {"submitted": 0, "dispatched": 0, "completed": 0,
                "failed": 0, "expired_in_router": 0}
            for n in self._names}

    # -- client API ----------------------------------------------------------
    def submit(self, model: str, payload: Any,
               deadline_s: Optional[float] = None,
               on_done: Optional[Callable[[Request], None]] = None
               ) -> Request:
        """Enqueue a request for ``model``.  The deadline budget (explicit
        or the member default) becomes absolute *now* — router queueing
        spends it just like server queueing does.  ``on_done`` is
        installed before the request can complete (attaching it to the
        returned object instead would race the dispatch thread)."""
        if model not in self._queues:
            raise KeyError(f"no fleet member {model!r}; members: "
                           f"{self._names}")
        req = Request(rid=next(_RID), payload=payload, on_done=on_done)
        budget = (deadline_s if deadline_s is not None
                  else self._deadlines.get(model))
        if budget is not None:
            req.deadline_s = req.t_submit + budget
        with self._stats_lock:
            self.stats[model]["submitted"] += 1
        with self._cv:
            if self._stop_evt.is_set():
                self._complete(model, req, None,
                               PipelineStopped("router stopped"))
                return req
            self._queues[model].append(req)
            self._cv.notify()
        return req

    # -- dispatch loop -------------------------------------------------------
    def start(self) -> None:
        if self._thread is not None:
            return
        self._stop_evt.clear()
        self._thread = threading.Thread(target=self._loop, daemon=True,
                                        name="fleet-router")
        self._thread.start()

    def _loop(self) -> None:
        while not self._stop_evt.is_set():
            with self._cv:
                while (not self._stop_evt.is_set()
                       and not any(self._queues[n] for n in self._names)):
                    self._cv.wait(timeout=0.1)
                if self._stop_evt.is_set():
                    return
                batch = self._drr_sweep()
            # dispatch outside the lock: server.submit only enqueues into
            # the member batcher, but it must not serialize new arrivals
            for name, req in batch:
                self._dispatch(name, req)

    def _drr_sweep(self) -> List:
        """One DRR round over backlogged members (caller holds the cv
        lock).  Returns [(member, request), ...] in dispatch order."""
        out = []
        for name in self._names:
            q = self._queues[name]
            if not q:
                self._deficit[name] = 0.0   # classic DRR: no banking
                continue
            self._deficit[name] += self._quantum[name]
            while q and self._deficit[name] >= 1.0:
                self._deficit[name] -= 1.0
                out.append((name, q.popleft()))
        return out

    def _dispatch(self, name: str, req: Request) -> None:
        now = time.perf_counter()
        if req.deadline_s is not None and now >= req.deadline_s:
            self._complete(name, req, None, DeadlineExceeded(
                req.rid, now - req.deadline_s, "router"))
            return
        srv = self._servers[name]()
        if srv is None or srv.stopped:
            self._complete(name, req, None, PipelineStopped(
                f"member {name!r} has no live server"))
            return
        remaining = (None if req.deadline_s is None
                     else req.deadline_s - now)
        try:
            inner = srv.submit(req.payload, deadline_s=remaining)
        except Exception as e:
            self._complete(name, req, None, e)
            return
        with self._stats_lock:
            self.stats[name]["dispatched"] += 1
        inner.on_done = (lambda ireq, n=name, r=req:
                         self._complete(n, r, ireq.result, ireq.error))
        # the inner request may have fully completed between submit()
        # returning and the hook landing — the member's collector would
        # then never see on_done, so finish the chain here (idempotent:
        # _complete no-ops on an already-completed router request)
        if inner.event.is_set():
            self._complete(name, req, inner.result, inner.error)

    def _complete(self, name: str, req: Request, result: Any,
                  error: Optional[BaseException]) -> None:
        with self._stats_lock:
            if req.t_done is not None:      # already completed (hook +
                return                      # completed-early fallback)
            req.result = result
            req.error = error
            req.t_done = time.perf_counter()
            if error is None:
                self.stats[name]["completed"] += 1
            else:
                self.stats[name]["failed"] += 1
                if (isinstance(error, DeadlineExceeded)
                        and error.where == "router"):
                    self.stats[name]["expired_in_router"] += 1
        req.event.set()
        if req.on_done is not None:
            try:
                req.on_done(req)
            except Exception:
                pass

    # -- accounting / lifecycle ----------------------------------------------
    def snapshot(self) -> Dict[str, Any]:
        """Router-side counters and queue depths per member (cumulative —
        the delta view lives in the member servers' own snapshots)."""
        with self._stats_lock:
            counters = {n: dict(c) for n, c in self.stats.items()}
        with self._cv:
            depths = {n: len(self._queues[n]) for n in self._names}
        return {"members": counters, "queue_depth": depths,
                "shares": dict(self._shares)}

    def stop(self) -> None:
        """Stop dispatching; requests still queued in the router complete
        with :class:`PipelineStopped` (never silently dropped)."""
        with self._cv:
            self._stop_evt.set()
            self._cv.notify_all()
        if self._thread is not None:
            self._thread.join(timeout=5)
            self._thread = None
        leftovers = []
        with self._cv:
            for name in self._names:
                while self._queues[name]:
                    leftovers.append((name, self._queues[name].popleft()))
        for name, req in leftovers:
            self._complete(name, req, None,
                           PipelineStopped("router stopped before "
                                           "dispatch"))

    def __enter__(self) -> "FleetRouter":
        self.start()
        return self

    def __exit__(self, *exc) -> None:
        self.stop()
