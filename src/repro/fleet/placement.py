"""Global pool-split solver: partition one device pool across N models.

The paper's planner answers "given *k* devices, where do I cut *this*
CNN?" (the joint cuts+replicas DP behind strategy ``placement``).  The
fleet question is one level up: "given *D* devices and *N* models with
SLOs, how many devices does each model get?"  This module answers it
with a resource-allocation DP whose inner cost oracle is the existing
single-model planner — the same layering DistrEdge uses (per-model
placement inside, device partitioning outside).

Normalized cost.  Each candidate allocation (member *m* on *k* devices)
is priced by planning *m* on those *k* devices and folding the plan's
modeled bottleneck pacing ``b`` (max effective stage time) into an
SLO-normalized scalar::

    norm(m, b) = max( b / (slo_p95_ms / 1e3),      # latency attainment
                      b * slo_throughput_rps )      # pacing x required rate

(1.0 = exactly at SLO, < 1 = headroom; a member with no SLO falls back
to ``b * share`` — its share is read as relative demand).  The outer DP
then minimizes the *worst* member's norm — minimax over the fleet, the
fleet-level analogue of the paper's minimax over stages::

    f[i][d] = min over k of max(f[i-1][d-k], norm(i, d-k, k))

Allocations are contiguous prefixes of the pool chain (member order =
chain order), so a heterogeneous pool prices each member against the
actual devices it would own.  On a homogeneous pool the cost oracle is
keyed by (member, k) only.

Time-sliced co-residency.  When the pool is smaller than the fleet
(D < N) no partition exists; members are co-scheduled onto single
devices instead.  Under share-proportional time slicing a member's
effective bottleneck inflates to ``b_m / (s_m / S_G)`` where ``S_G`` is
the total share resident on its device; the greedy packer places
members (worst normalized demand first) onto the currently
least-loaded device, deterministically.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

from ..api.deploy import plan as plan_one
from ..api.spec import DeploymentSpec, resolve_model_graph
from ..api.strategies import get_strategy
from ..core.graph import LayerGraph
from ..core.placement import PlacementPlan
from ..core.topology import Topology
from .spec import FleetMemberSpec, FleetSpec

_INF = float("inf")


def slo_norm(member: FleetMemberSpec, bottleneck_s: float) -> float:
    """SLO-normalized cost of running ``member`` at modeled bottleneck
    pacing ``bottleneck_s`` (1.0 = exactly at SLO; lower is headroom)."""
    spec = member.spec
    terms = []
    if spec.slo_p95_ms is not None:
        terms.append(bottleneck_s / (spec.slo_p95_ms / 1e3))
    if spec.slo_throughput_rps is not None:
        terms.append(bottleneck_s * spec.slo_throughput_rps)
    if not terms:
        terms.append(bottleneck_s * member.share)
    return max(terms)


def member_plan_spec(member: FleetMemberSpec,
                     devices: Topology) -> DeploymentSpec:
    """The member's spec pinned to a concrete device sub-chain.  Members
    whose strategy cannot plan over a topology are upgraded to the joint
    cuts+replicas DP (strategy ``placement``) — the fleet packs devices,
    so every inner plan must be topology-aware."""
    spec = member.spec
    if not get_strategy(spec.strategy).needs_topology:
        spec = dataclasses.replace(spec, strategy="placement",
                                   objective=None, refine=None)
    return dataclasses.replace(spec, topology=devices)


@dataclasses.dataclass(frozen=True)
class MemberAllocation:
    """One member's slice of the pool.

    ``device_indices`` are positions in the fleet pool chain.  In
    ``partitioned`` mode the member owns them exclusively and
    ``time_share`` is 1.0; in ``time_sliced`` mode the (single) device is
    shared and ``time_share`` is the member's share-proportional slice.
    ``bottleneck_s`` is the *effective* modeled pacing (time slicing
    already applied); ``norm_cost`` is :func:`slo_norm` of it.
    """

    name: str
    device_indices: Tuple[int, ...]
    plan: PlacementPlan
    bottleneck_s: float
    norm_cost: float
    mode: str = "partitioned"
    time_share: float = 1.0

    @property
    def n_devices(self) -> int:
        return len(self.device_indices)

    def summary(self) -> Dict:
        return {
            "name": self.name,
            "devices": list(self.device_indices),
            "n_stages": self.plan.n_stages,
            "replica_counts": list(self.plan.replica_counts),
            "bottleneck_s": self.bottleneck_s,
            "norm_cost": self.norm_cost,
            "mode": self.mode,
            "time_share": self.time_share,
        }


@dataclasses.dataclass(frozen=True)
class FleetPlacement:
    """The solved pool split: one :class:`MemberAllocation` per member."""

    fleet: FleetSpec
    allocations: Tuple[MemberAllocation, ...]
    mode: str                      # "partitioned" | "time_sliced"

    @property
    def worst_norm(self) -> float:
        return max(a.norm_cost for a in self.allocations)

    @property
    def worst_member(self) -> str:
        return max(self.allocations, key=lambda a: a.norm_cost).name

    def allocation(self, name: str) -> MemberAllocation:
        for a in self.allocations:
            if a.name == name:
                return a
        raise KeyError(f"no allocation for member {name!r}")

    def device_counts(self) -> Dict[str, int]:
        return {a.name: a.n_devices for a in self.allocations}

    def summary(self) -> Dict:
        return {
            "mode": self.mode,
            "pool_devices": self.fleet.pool().n_devices,
            "worst_norm": self.worst_norm,
            "worst_member": self.worst_member,
            "members": [a.summary() for a in self.allocations],
        }


class _CostOracle:
    """plan(member, contiguous device window) -> (norm, plan), cached.

    On a homogeneous pool the window's position is irrelevant and the
    cache key collapses to (member, width) — the DP then costs
    O(N * D) plans instead of O(N * D^2).
    """

    def __init__(self, fleet: FleetSpec, pool: Topology,
                 graphs: Dict[str, LayerGraph], tpu_model, base_spec):
        self.fleet = fleet
        self.pool = pool
        self.graphs = graphs
        self.tpu_model = tpu_model
        self.base_spec = base_spec
        self._cache: Dict[Tuple[int, int, int],
                          Tuple[float, Optional[PlacementPlan]]] = {}

    def cost(self, mi: int, start: int, k: int
             ) -> Tuple[float, Optional[PlacementPlan]]:
        key = (mi, 0, k) if self.pool.is_homogeneous else (mi, start, k)
        hit = self._cache.get(key)
        if hit is not None:
            return hit
        member = self.fleet.members[mi]
        sub = Topology(devices=self.pool.devices[start:start + k],
                       name=f"{member.name}[{k}]")
        try:
            pl = plan_one(member_plan_spec(member, sub),
                          graph=self.graphs[member.name],
                          tpu_model=self.tpu_model,
                          base_spec=self.base_spec, attach_report=False)
            b = pl.max_stage_time_s
            out = ((_INF, None) if b is None
                   else (slo_norm(member, b), pl))
        except ValueError:
            # infeasible window (e.g. replication disabled and more
            # devices than layers) — priced out, not fatal
            out = (_INF, None)
        self._cache[key] = out
        return out


def _resolve_graphs(fleet: FleetSpec,
                    graphs: Optional[Dict[str, LayerGraph]]
                    ) -> Dict[str, LayerGraph]:
    out = dict(graphs) if graphs else {}
    for m in fleet.members:
        if m.name not in out:
            out[m.name] = resolve_model_graph(m.spec.model)
    return out


def plan_fleet(fleet: FleetSpec, *,
               graphs: Optional[Dict[str, LayerGraph]] = None,
               tpu_model=None, base_spec=None,
               fixed_counts: Optional[Dict[str, int]] = None
               ) -> FleetPlacement:
    """Solve the global pool split for ``fleet``.

    ``graphs`` maps member name -> live :class:`LayerGraph`, overriding
    ``spec.model`` resolution (same contract as ``plan(spec, graph=)``).
    ``fixed_counts`` pins the split (member name -> device count, must
    sum to the pool) instead of solving it — the static-baseline mode
    benchmarks compare the solver against.  Returns a
    :class:`FleetPlacement`; raises ``ValueError`` when no feasible
    split exists.
    """
    pool = fleet.pool()
    members = fleet.members
    gmap = _resolve_graphs(fleet, graphs)
    if fixed_counts is not None:
        return _plan_fixed(fleet, pool, gmap, tpu_model, base_spec,
                           fixed_counts)
    if pool.n_devices < len(members):
        return _plan_time_sliced(fleet, pool, gmap, tpu_model, base_spec)

    oracle = _CostOracle(fleet, pool, gmap, tpu_model, base_spec)
    n, d_total = len(members), pool.n_devices
    lo = [m.min_devices for m in members]
    hi = [m.max_devices if m.max_devices is not None else d_total
          for m in members]
    # suffix_lo[i] = devices the members after i still need at minimum
    suffix_lo = [0] * (n + 1)
    for i in range(n - 1, -1, -1):
        suffix_lo[i] = suffix_lo[i + 1] + lo[i]

    # f[i][d]: best worst-norm covering members[:i] with the first d
    # pool devices; choice[i][d] the k that achieves it
    f = [[_INF] * (d_total + 1) for _ in range(n + 1)]
    choice = [[0] * (d_total + 1) for _ in range(n + 1)]
    f[0][0] = 0.0
    for i in range(1, n + 1):
        mi = i - 1
        for d in range(d_total + 1):
            best, best_k = _INF, 0
            # states whose remainder cannot hold the later members'
            # min_devices are dead ends — skip, don't price them
            if d + suffix_lo[i] <= d_total:
                for k in range(lo[mi], min(hi[mi], d) + 1):
                    if f[i - 1][d - k] == _INF:
                        continue
                    c, _ = oracle.cost(mi, d - k, k)
                    cand = max(f[i - 1][d - k], c)
                    if cand < best:
                        best, best_k = cand, k
            f[i][d] = best
            choice[i][d] = best_k
    # the last member absorbs the full remainder: every pool device is
    # owned by someone (idle devices are the autoscaler's slack, not
    # the solver's)
    if f[n][d_total] == _INF:
        raise ValueError(
            f"no feasible pool split: {d_total} devices across "
            f"{[m.name for m in members]} with min_devices={lo}, "
            f"max_devices={hi}")

    allocs: List[MemberAllocation] = []
    d = d_total
    for i in range(n, 0, -1):
        k = choice[i][d]
        start = d - k
        norm, pl = oracle.cost(i - 1, start, k)
        allocs.append(MemberAllocation(
            name=members[i - 1].name,
            device_indices=tuple(range(start, start + k)),
            plan=pl, bottleneck_s=pl.max_stage_time_s,
            norm_cost=norm, mode="partitioned"))
        d = start
    allocs.reverse()
    return FleetPlacement(fleet=fleet, allocations=tuple(allocs),
                          mode="partitioned")


def _plan_fixed(fleet: FleetSpec, pool: Topology,
                gmap: Dict[str, LayerGraph], tpu_model, base_spec,
                counts: Dict[str, int]) -> FleetPlacement:
    """Pinned split: price the given member -> device-count map as-is."""
    if set(counts) != set(fleet.member_names):
        raise ValueError("fixed_counts must cover exactly the fleet's "
                         "members")
    if sum(counts.values()) != pool.n_devices:
        raise ValueError(f"fixed_counts sum to {sum(counts.values())}, "
                         f"pool has {pool.n_devices} devices")
    if any(k < 1 for k in counts.values()):
        raise ValueError("fixed_counts must give every member >= 1 "
                         "device")
    oracle = _CostOracle(fleet, pool, gmap, tpu_model, base_spec)
    allocs: List[MemberAllocation] = []
    start = 0
    for mi, m in enumerate(fleet.members):
        k = counts[m.name]
        norm, pl = oracle.cost(mi, start, k)
        if pl is None:
            raise ValueError(f"member {m.name!r} cannot be planned on "
                             f"{k} devices")
        allocs.append(MemberAllocation(
            name=m.name, device_indices=tuple(range(start, start + k)),
            plan=pl, bottleneck_s=pl.max_stage_time_s,
            norm_cost=norm, mode="partitioned"))
        start += k
    return FleetPlacement(fleet=fleet, allocations=tuple(allocs),
                          mode="partitioned")


def _plan_time_sliced(fleet: FleetSpec, pool: Topology,
                      gmap: Dict[str, LayerGraph],
                      tpu_model, base_spec) -> FleetPlacement:
    """D < N fallback: co-schedule members onto single shared devices."""
    members = fleet.members
    base: List[Tuple[FleetMemberSpec, PlacementPlan, float]] = []
    for mi, m in enumerate(members):
        sub = Topology(devices=pool.devices[:1], name=f"{m.name}[1]")
        pl = plan_one(member_plan_spec(m, sub), graph=gmap[m.name],
                      tpu_model=tpu_model, base_spec=base_spec,
                      attach_report=False)
        b = pl.max_stage_time_s
        if b is None:
            raise ValueError(f"member {m.name!r}: cost model returned no "
                             f"stage times; time slicing needs them")
        base.append((m, pl, b))

    # worst normalized demand first onto the least-loaded device;
    # ties broken by member order (deterministic)
    order = sorted(range(len(members)),
                   key=lambda i: (-slo_norm(base[i][0], base[i][2]), i))
    loads = [0.0] * pool.n_devices
    groups: List[List[int]] = [[] for _ in range(pool.n_devices)]
    for i in order:
        di = min(range(pool.n_devices), key=lambda j: (loads[j], j))
        groups[di].append(i)
        loads[di] += base[i][0].share * base[i][2]

    allocs: List[Optional[MemberAllocation]] = [None] * len(members)
    for di, grp in enumerate(groups):
        total_share = sum(base[i][0].share for i in grp)
        for i in grp:
            m, pl, b = base[i]
            ts = m.share / total_share
            eff = b / ts
            allocs[i] = MemberAllocation(
                name=m.name, device_indices=(di,), plan=pl,
                bottleneck_s=eff, norm_cost=slo_norm(m, eff),
                mode="time_sliced", time_share=ts)
    return FleetPlacement(fleet=fleet, allocations=tuple(allocs),
                          mode="time_sliced")
