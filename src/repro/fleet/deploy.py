"""The fleet runtime handle: ``deploy_fleet(spec) -> Fleet``.

Mirrors ``repro.api.deploy`` one level up: :func:`deploy_fleet` solves
the pool split (:func:`~repro.fleet.placement.plan_fleet`), builds one
:class:`~repro.api.deploy.Deployment` per member on its slice of the
pool, serves them all, fronts them with the
:class:`~repro.fleet.router.FleetRouter`, and wires the
:class:`~repro.fleet.autoscale.FleetAutoscaler` over the lot.  The
:class:`Fleet` object owns every lifecycle underneath it — ``close()``
(or the context manager) tears down router, autoscaler, servers, and
deployments in order, so a fleet can never leak a member thread.

Stage functions come per member via ``stage_fn_builders`` (name ->
builder), same contract as ``deploy(stage_fn_builder=)`` — builders, not
fixed lists, because both the autoscaler and degraded-mode replans
change member stage shapes at runtime.
"""
from __future__ import annotations

import dataclasses
import logging
from typing import Any, Callable, Dict, List, Optional

from ..api.deploy import Deployment, StageFnBuilder
from ..api.spec import DeploymentSpec, resolve_model_graph
from ..core.graph import LayerGraph
from ..core.topology import Topology
from ..serving.server import Request
from .autoscale import AutoscalePolicy, FleetAutoscaler
from .placement import FleetPlacement, member_plan_spec, plan_fleet
from .router import FleetRouter
from .spec import FleetSpec

logger = logging.getLogger(__name__)


class Fleet:
    """N live member deployments, one front door.

    Use :func:`deploy_fleet` to build one.  The interesting surface:

    * :meth:`submit` — route a request to a member (weighted-fair
      admission, per-member deadline/shed policy downstream).
    * :attr:`router` / :attr:`autoscaler` / :attr:`deployments` — the
      owned subsystems, exposed for observation and tests.
    * :meth:`snapshot` — router counters + per-member server snapshots
      + the current device split, one coherent view.
    * ``with fleet: ...`` / :meth:`close` — full teardown.
    """

    def __init__(self, spec: FleetSpec, placement: FleetPlacement,
                 deployments: Dict[str, Deployment],
                 router: FleetRouter,
                 autoscaler: Optional[FleetAutoscaler]):
        self.spec = spec
        self.placement = placement
        self.deployments = deployments
        self.router = router
        self.autoscaler = autoscaler
        self._closed = False

    # -- client API ----------------------------------------------------------
    def submit(self, model: str, payload: Any,
               deadline_s: Optional[float] = None,
               on_done: Optional[Callable[[Request], None]] = None
               ) -> Request:
        """Submit a request for ``model`` through the admission router.
        Returns a :class:`~repro.serving.server.Request` future; wait on
        ``req.event`` and read ``req.result`` / ``req.error`` (or pass
        ``on_done``, installed race-free before dispatch)."""
        if self._closed:
            raise RuntimeError("fleet is closed")
        return self.router.submit(model, payload, deadline_s=deadline_s,
                                  on_done=on_done)

    @property
    def member_names(self):
        return self.spec.member_names

    def device_counts(self) -> Dict[str, int]:
        """The live device split (the autoscaler mutates it; before any
        move it equals the solved placement's)."""
        if self.autoscaler is not None:
            return dict(self.autoscaler.device_counts)
        return self.placement.device_counts()

    def snapshot(self) -> Dict[str, Any]:
        """One coherent observability view: router counters, per-member
        server snapshot deltas (including their cumulative ``totals``),
        the live device split, and autoscaler events so far."""
        members = {}
        for name, dep in self.deployments.items():
            srv = dep.server
            members[name] = None if srv is None else srv.snapshot()
        return {
            "router": self.router.snapshot(),
            "members": members,
            "device_counts": self.device_counts(),
            "autoscaler_events": (list(self.autoscaler.events)
                                  if self.autoscaler is not None else []),
        }

    # -- lifecycle -----------------------------------------------------------
    def close(self) -> None:
        """Tear down: router first (no new dispatches), then autoscaler,
        then every member deployment.  Idempotent."""
        if self._closed:
            return
        self._closed = True
        self.router.stop()
        if self.autoscaler is not None:
            self.autoscaler.stop()
        for dep in self.deployments.values():
            dep.close()

    def __enter__(self) -> "Fleet":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


def _member_runtime_spec(fleet: FleetSpec, name: str, *,
                         device_budget: Optional[int] = None,
                         topology: Optional[Topology] = None
                         ) -> DeploymentSpec:
    """The member's spec pinned to its slice of the pool.  Homogeneous
    slices pin ``device_budget`` (``with_stages`` then resizes it — the
    shape the autoscaler needs); heterogeneous slices pin the actual
    sub-chain."""
    base = member_plan_spec(fleet.member(name),
                            topology if topology is not None
                            else Topology.homogeneous(device_budget))
    if topology is not None:
        return base
    return dataclasses.replace(base, topology=None,
                               device_budget=device_budget)


def deploy_fleet(spec: FleetSpec, *,
                 graphs: Optional[Dict[str, LayerGraph]] = None,
                 stage_fn_builders: Dict[str, StageFnBuilder],
                 tpu_model=None, base_spec=None,
                 fixed_counts: Optional[Dict[str, int]] = None,
                 autoscale: bool = True,
                 autoscale_policy: Optional[AutoscalePolicy] = None,
                 start: bool = True) -> Fleet:
    """Solve the pool split and bring the whole fleet up.

    ``graphs`` overrides ``spec.model`` resolution per member (same
    contract as ``plan(spec, graph=)``); ``stage_fn_builders`` maps
    member name -> stage-function builder (required — every member
    serves).  ``fixed_counts`` pins the pool split instead of solving it
    (the static-baseline mode).  ``autoscale=False`` skips the
    autoscaler; it is also
    skipped (with a log line) when the fleet shape cannot resize:
    time-sliced mode, a heterogeneous pool, or a single member.
    ``start=True`` starts every member's executor + admission loop and
    the router's dispatch thread (the autoscaler's thread is never
    auto-started — call ``fleet.autoscaler.start(interval_s)`` or drive
    ``tick()`` directly).
    """
    missing = [m.name for m in spec.members
               if m.name not in stage_fn_builders]
    if missing:
        raise ValueError(f"stage_fn_builders missing members: {missing}")
    gmap = dict(graphs) if graphs else {}
    for m in spec.members:
        if m.name not in gmap:
            gmap[m.name] = resolve_model_graph(m.spec.model)

    placement = plan_fleet(spec, graphs=gmap, tpu_model=tpu_model,
                           base_spec=base_spec, fixed_counts=fixed_counts)
    pool = spec.pool()
    homogeneous = pool.is_homogeneous

    deployments: Dict[str, Deployment] = {}
    try:
        for alloc in placement.allocations:
            name = alloc.name
            if placement.mode == "time_sliced" or homogeneous:
                dspec = _member_runtime_spec(
                    spec, name, device_budget=max(1, alloc.n_devices))
            else:
                sub = Topology(
                    devices=tuple(pool.devices[i]
                                  for i in alloc.device_indices),
                    name=f"{name}[{alloc.n_devices}]")
                dspec = _member_runtime_spec(spec, name, topology=sub)
            deployments[name] = Deployment(
                dspec, alloc.plan, graph=gmap[name],
                stage_fn_builder=stage_fn_builders[name],
                tpu_model=tpu_model, base_spec=base_spec)
            deployments[name].serve(start=start)

        router = FleetRouter(
            servers={n: (lambda d=dep: d.server)
                     for n, dep in deployments.items()},
            shares={m.name: m.share for m in spec.members},
            deadlines_s={m.name: (None if m.spec.deadline_ms is None
                                  else m.spec.deadline_ms / 1e3)
                         for m in spec.members})
        if start:
            router.start()

        autoscaler = None
        if autoscale:
            if placement.mode != "partitioned":
                logger.info("fleet autoscaler skipped: time-sliced mode "
                            "has no devices to move")
            elif not homogeneous:
                logger.info("fleet autoscaler skipped: heterogeneous "
                            "pool slices cannot resize by count")
            elif len(spec.members) < 2:
                logger.info("fleet autoscaler skipped: nothing to "
                            "rebalance with one member")
            else:
                autoscaler = FleetAutoscaler(
                    spec, deployments, placement.device_counts(),
                    policy=autoscale_policy)
        return Fleet(spec, placement, deployments, router, autoscaler)
    except Exception:
        for dep in deployments.values():
            dep.close()
        raise
