"""Multi-tenant serving fleet: N models on one shared device pool.

The paper balances *one* CNN's segments across a fixed set of Edge TPUs;
this package is the many-workloads extension (ROADMAP item 2, DistrEdge's
framing in PAPERS.md): pack several :class:`~repro.core.placement
.PlacementPlan`s onto one :class:`~repro.core.topology.Topology` so every
model meets its SLO.

* :mod:`repro.fleet.spec` — :class:`FleetSpec` / :class:`FleetMemberSpec`:
  the frozen, JSON-round-trippable description of N member deployments
  with per-model SLOs over one shared pool.
* :mod:`repro.fleet.placement` — the global pool-split solver:
  a resource-allocation DP over the member-count x device-count grid
  whose inner cost is the existing joint cuts+replicas planner, plus the
  time-sliced co-residency fallback for pools smaller than the fleet.
* :mod:`repro.fleet.router` — the admission front door: one
  ``submit(model, payload)`` entry, deficit-round-robin weighted fair
  queueing on member ``share``, per-model deadline/shed reusing the
  PR-8 ``DeadlineExceeded`` / ``Overloaded`` machinery.
* :mod:`repro.fleet.autoscale` — the SLO-headroom autoscaler: folds each
  member's ``snapshot()`` telemetry into headroom and moves devices from
  over-provisioned members to violating ones through the existing
  ``ElasticPlanner.resize_server`` -> ``reconfigure()`` hot-swap path,
  guarded (commit-or-rollback + cooldown, never below one device).
* :mod:`repro.fleet.deploy` — the :class:`Fleet` runtime handle
  (``deploy_fleet(spec) -> Fleet``), mirroring ``repro.api.Deployment``.
* :mod:`repro.fleet.scenario` — a synthetic traffic driver shared by
  ``benchmarks/fleet_bench.py`` and ``launch/serve.py --fleet``.
"""
from .autoscale import AutoscalePolicy, FleetAutoscaler
from .deploy import Fleet, deploy_fleet
from .placement import FleetPlacement, MemberAllocation, plan_fleet
from .router import FleetRouter
from .spec import FLEET_SPEC_FORMAT, FleetMemberSpec, FleetSpec

__all__ = [
    "AutoscalePolicy", "Fleet", "FleetAutoscaler", "FleetMemberSpec",
    "FleetPlacement", "FleetRouter", "FleetSpec", "FLEET_SPEC_FORMAT",
    "MemberAllocation", "deploy_fleet", "plan_fleet",
]
