"""SLO-headroom autoscaler: move devices between fleet members, guarded.

The pool split (:mod:`repro.fleet.placement`) is solved against the
*modeled* cost of each member; live traffic drifts away from any model —
a member's arrival mix shifts and its p95 blows through the target while
a neighbor idles on devices it no longer needs.  The autoscaler closes
that loop the same way the self-healing controller closes the
single-model one (:mod:`repro.runtime.selfheal`): a synchronous,
deterministic :meth:`FleetAutoscaler.tick` that folds each member's
``snapshot()`` deltas into an observed SLO attainment ratio, plus a
thread wrapper for production use.

One tick = at most one device move:

1. **Observe** — per member, fold the snapshot window into an EWMA of
   the observed norm (p95 / target, and required-rate / observed-rate
   when the member shows pressure: sheds, deadline misses, or standing
   queue).  Thin windows (too few completions) leave the EWMA untouched.
2. **Select** — the worst member with norm past the violation threshold
   is the receiver; the donor is the member whose *modeled* norm after
   giving up a device stays under ``1 / donor_headroom`` (modeled via
   the same per-member replan the pool split used; observed norm breaks
   ties).  Donors never drop below ``max(1, min_devices)``; receivers
   never exceed ``max_devices``.
3. **Move** — donor resizes to k-1, receiver to k+1, both through the
   existing ``Deployment.reconfigure`` -> server hot-swap drain path
   (in-flight requests drain, queued requests land on the new plan —
   nothing is lost or reordered).
4. **Guard** — for ``guard_ticks`` windows the move is provisional; then
   the receiver must have improved (or reached attainment) and the donor
   must not have become the new worst violator, else the move is rolled
   back (the reverse resize).  Commit or rollback, a cooldown of
   ``cooldown_ticks`` quiet windows follows.  Every decision lands in
   :attr:`FleetAutoscaler.events`.

Device moves need a resizable member shape, so the autoscaler requires
the partitioned mode on a homogeneous pool (``device_budget`` resizes;
a pinned heterogeneous sub-chain does not) — ``deploy_fleet`` simply
skips the autoscaler otherwise.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Any, Dict, List, Optional

from ..api.deploy import Deployment, plan as plan_one
from .placement import slo_norm
from .spec import FleetSpec

_EPS = 1e-9


@dataclasses.dataclass(frozen=True)
class AutoscalePolicy:
    """When the autoscaler acts and how suspicious it stays.

    * ``violation_threshold`` — observed norm past this marks a member
      as violating (1.0 = exactly at SLO).
    * ``donor_headroom`` — a donor's *modeled* norm after giving up a
      device must stay under ``1 / donor_headroom``; > 1 keeps donors
      comfortably inside SLO rather than trading one violation for
      another.
    * ``guard_ticks`` — windows a move stays provisional before the
      commit-or-rollback verdict.
    * ``cooldown_ticks`` — quiet windows after a verdict (telemetry from
      mid-swap windows would feed the next decision noise).
    * ``min_window_requests`` — windows with fewer completions leave the
      observed-norm EWMA untouched (no signal, no update).
    * ``ewma_alpha`` — weight of the newest window in the observed norm.
    * ``min_improvement`` — relative receiver improvement the guard
      accepts as progress when the receiver is still past threshold.
    """

    violation_threshold: float = 1.0
    donor_headroom: float = 1.2
    guard_ticks: int = 2
    cooldown_ticks: int = 1
    min_window_requests: int = 5
    ewma_alpha: float = 0.5
    min_improvement: float = 0.05

    def __post_init__(self):
        if self.violation_threshold <= 0:
            raise ValueError("violation_threshold must be > 0")
        if self.donor_headroom <= 0:
            raise ValueError("donor_headroom must be > 0")
        if self.guard_ticks < 1:
            raise ValueError("guard_ticks must be >= 1")
        if self.cooldown_ticks < 0:
            raise ValueError("cooldown_ticks must be >= 0")
        if not 0 < self.ewma_alpha <= 1:
            raise ValueError("ewma_alpha must be in (0, 1]")


class FleetAutoscaler:
    """Drive device moves between the fleet's member deployments.

    ``deployments`` maps member name -> live :class:`Deployment` (the
    reconfigure target *and* the ``snapshot()`` source via its server);
    ``device_counts`` the solved pool split the fleet launched with.
    :meth:`tick` is synchronous and deterministic — benchmarks and tests
    drive it directly; :meth:`start` wraps it in a paced thread.
    """

    def __init__(self, fleet: FleetSpec,
                 deployments: Dict[str, Deployment],
                 device_counts: Dict[str, int],
                 policy: Optional[AutoscalePolicy] = None):
        if set(deployments) != set(fleet.member_names):
            raise ValueError("deployments must cover exactly the fleet's "
                             "members")
        if set(device_counts) != set(fleet.member_names):
            raise ValueError("device_counts must cover exactly the "
                             "fleet's members")
        self.fleet = fleet
        self.policy = policy if policy is not None else AutoscalePolicy(
            cooldown_ticks=fleet.rebalance_cooldown_windows,
            donor_headroom=fleet.rebalance_headroom)
        self._deps = dict(deployments)
        self.device_counts = dict(device_counts)
        self._norm_ewma: Dict[str, Optional[float]] = {
            n: None for n in self._deps}
        self._modeled_cache: Dict[tuple, float] = {}
        self._pending: Optional[Dict[str, Any]] = None
        self._cooldown = 0
        self._tick_no = 0
        self._lock = threading.Lock()
        self.events: List[Dict[str, Any]] = []
        self._stop_evt = threading.Event()
        self._thread: Optional[threading.Thread] = None

    # -- observation ---------------------------------------------------------
    def _observed_norm(self, name: str, snap: Dict[str, Any]
                       ) -> Optional[float]:
        """Fold one snapshot window into the member's observed SLO norm,
        or None when the window carries no usable signal."""
        spec = self.fleet.member(name).spec
        terms: List[float] = []
        lat = snap.get("latency", {})
        if (spec.slo_p95_ms is not None
                and lat.get("n", 0) >= self.policy.min_window_requests):
            terms.append(lat["p95_s"] / (spec.slo_p95_ms / 1e3))
        pressure = (snap.get("shed", 0) + snap.get("deadline_exceeded", 0)
                    + snap.get("queue_depth", 0)) > 0
        if spec.slo_throughput_rps is not None and pressure:
            rate = max(snap.get("throughput_rps", 0.0), _EPS)
            terms.append(spec.slo_throughput_rps / rate)
        return max(terms) if terms else None

    def _fold(self, name: str, snap: Dict[str, Any]) -> None:
        obs = self._observed_norm(name, snap)
        if obs is None:
            return
        prev = self._norm_ewma[name]
        a = self.policy.ewma_alpha
        self._norm_ewma[name] = obs if prev is None \
            else a * obs + (1 - a) * prev

    def _modeled_norm(self, name: str, k: int) -> float:
        """The pool-split cost oracle at a hypothetical device count:
        replan the member at k devices, normalize the modeled bottleneck
        against its SLO."""
        key = (name, k)
        if key not in self._modeled_cache:
            dep = self._deps[name]
            pl = plan_one(dep.spec.with_stages(k), graph=dep.graph,
                          attach_report=False)
            b = pl.max_stage_time_s
            self._modeled_cache[key] = (float("inf") if b is None
                                        else slo_norm(self.fleet.member(name),
                                                      b))
        return self._modeled_cache[key]

    # -- the control step ----------------------------------------------------
    def tick(self) -> Dict[str, Any]:
        """One deterministic control step: observe every member, then
        either advance a pending guard, sit out a cooldown, or attempt
        one device move.  Returns a record of what happened."""
        with self._lock:
            return self._tick_locked()

    def _tick_locked(self) -> Dict[str, Any]:
        self._tick_no += 1
        for name, dep in self._deps.items():
            srv = dep.server
            if srv is not None:
                self._fold(name, srv.snapshot())
        norms = {n: v for n, v in self._norm_ewma.items() if v is not None}

        if self._pending is not None:
            return self._advance_guard(norms)
        if self._cooldown > 0:
            self._cooldown -= 1
            return self._note("cooldown", norms=dict(norms),
                              remaining=self._cooldown)

        move = self._pick_move(norms)
        if move is None:
            return self._note("steady", norms=dict(norms))
        return self._execute(move, norms)

    def _pick_move(self, norms: Dict[str, float]
                   ) -> Optional[Dict[str, Any]]:
        pol = self.policy
        violating = sorted(
            (n for n, v in norms.items() if v > pol.violation_threshold),
            key=lambda n: -norms[n])
        for recv in violating:
            m_recv = self.fleet.member(recv)
            k_recv = self.device_counts[recv]
            if (m_recv.max_devices is not None
                    and k_recv + 1 > m_recv.max_devices):
                continue
            if self._modeled_norm(recv, k_recv + 1) \
                    >= self._modeled_norm(recv, k_recv) - _EPS:
                continue            # another device would not help
            donor = self._pick_donor(recv, norms)
            if donor is not None:
                return {"from": donor, "to": recv}
        return None

    def _pick_donor(self, recv: str,
                    norms: Dict[str, float]) -> Optional[str]:
        pol = self.policy
        best, best_key = None, None
        for name in self.fleet.member_names:
            if name == recv:
                continue
            k = self.device_counts[name]
            floor = max(1, self.fleet.member(name).min_devices)
            if k - 1 < floor:
                continue
            modeled_after = self._modeled_norm(name, k - 1)
            if modeled_after > 1.0 / pol.donor_headroom:
                continue
            obs = norms.get(name, 0.0)
            if obs > pol.violation_threshold:
                continue            # already struggling; not a donor
            key = (modeled_after, obs, name)   # name: deterministic tie
            if best_key is None or key < best_key:
                best, best_key = name, key
        return best

    def _execute(self, move: Dict[str, str],
                 norms: Dict[str, float]) -> Dict[str, Any]:
        donor, recv = move["from"], move["to"]
        try:
            self._resize(donor, self.device_counts[donor] - 1)
            self._resize(recv, self.device_counts[recv] + 1)
        except Exception as e:
            # a failed resize leaves counts consistent (_resize updates
            # the count only after the reconfigure lands)
            self._cooldown = self.policy.cooldown_ticks
            return self._note("move_failed", move=dict(move),
                              error=repr(e))
        self._pending = {
            "move": dict(move),
            "ticks_left": self.policy.guard_ticks,
            "pre_recv": norms.get(recv),
            "pre_donor": norms.get(donor),
        }
        # the swap window's telemetry is noise; restart the EWMA for the
        # moved pair so the guard judges post-move windows only
        self._norm_ewma[donor] = None
        self._norm_ewma[recv] = None
        return self._note("move", move=dict(move),
                          counts=dict(self.device_counts),
                          guard_ticks=self.policy.guard_ticks)

    def _advance_guard(self, norms: Dict[str, float]) -> Dict[str, Any]:
        pol = self.policy
        pend = self._pending
        pend["ticks_left"] -= 1
        if pend["ticks_left"] > 0:
            return self._note("guard", move=dict(pend["move"]),
                              ticks_left=pend["ticks_left"])
        self._pending = None
        self._cooldown = pol.cooldown_ticks
        donor, recv = pend["move"]["from"], pend["move"]["to"]
        post_recv = norms.get(recv)
        post_donor = norms.get(donor)
        pre_recv = pend["pre_recv"]
        recv_ok = (
            post_recv is None           # no pressure left at all
            or post_recv <= pol.violation_threshold
            or (pre_recv is not None
                and post_recv <= pre_recv * (1 - pol.min_improvement)))
        donor_ok = (post_donor is None
                    or post_donor <= pol.violation_threshold
                    or (post_recv is not None
                        and post_donor <= post_recv))
        if recv_ok and donor_ok:
            return self._note("commit", move=dict(pend["move"]),
                              counts=dict(self.device_counts),
                              post_recv=post_recv, post_donor=post_donor)
        try:
            self._resize(recv, self.device_counts[recv] - 1)
            self._resize(donor, self.device_counts[donor] + 1)
        except Exception as e:
            return self._note("rollback_failed", move=dict(pend["move"]),
                              error=repr(e))
        self._norm_ewma[donor] = None
        self._norm_ewma[recv] = None
        return self._note("rollback", move=dict(pend["move"]),
                          counts=dict(self.device_counts),
                          post_recv=post_recv, post_donor=post_donor)

    def _resize(self, name: str, k: int) -> None:
        self._deps[name].reconfigure(stages=k)
        self.device_counts[name] = k

    def _note(self, kind: str, **fields) -> Dict[str, Any]:
        ev = {"tick": self._tick_no, "event": kind, **fields}
        self.events.append(ev)
        return ev

    @property
    def committed_moves(self) -> int:
        return sum(1 for e in self.events if e["event"] == "commit")

    # -- thread wrapper ------------------------------------------------------
    def start(self, interval_s: float = 1.0) -> "FleetAutoscaler":
        if self._thread is not None:
            return self
        self._stop_evt.clear()

        def loop():
            while not self._stop_evt.wait(interval_s):
                try:
                    self.tick()
                except Exception:
                    pass        # a bad tick must not kill the loop

        self._thread = threading.Thread(target=loop, daemon=True,
                                        name="fleet-autoscale")
        self._thread.start()
        return self

    def stop(self) -> None:
        self._stop_evt.set()
        if self._thread is not None:
            self._thread.join(timeout=5)
            self._thread = None

    def __enter__(self) -> "FleetAutoscaler":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()
