"""Synthetic multi-tenant traffic driver, shared by the fleet benchmark
(``benchmarks/fleet_bench.py``) and ``launch/serve.py --fleet``.

A :class:`FleetScenario` turns a :class:`~repro.fleet.spec.FleetSpec`
into something that actually serves: sleep-based stage functions whose
per-depth service times are a *static synthetic truth* (each member's
whole-model time is pinned by ``service_sum_s``, distributed across
depths by the analytic MAC + weight-byte shape), so every latency the
run observes is a property of the committed plans and the traffic — not
of host noise.  Every request's completion is tapped (via the router's
race-free ``on_done`` hook) in merge-exit order, giving the 0-lost /
0-misordered audit across every autoscaler hot-swap: the executor's
merge restores stream order after replicated stages, so per member the
successful completions must come back in submission order exactly.

Traffic is window-driven: a :class:`TrafficPhase` says how many
requests each member submits per window; phase boundaries are the
mid-run shifts the autoscaler must chase.  :meth:`FleetScenario.drive`
runs phases against a live :class:`~repro.fleet.deploy.Fleet`, ticking
its autoscaler once per window, and folds everything into per-member
metrics with an SLO-attainment summary (fraction of submitted requests
completed within the member's p95 target — a shed, late, or lost
request counts against attainment, so surviving-request percentiles
cannot flatter an overloaded member).
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Any, Dict, List, Optional

from ..api.spec import resolve_model_graph
from ..core.graph import LayerGraph
from .deploy import Fleet, deploy_fleet
from .spec import FleetSpec

# synthetic device constants (same flavor as the self-healing bench):
# dense MACs + weight-byte streaming set the per-depth shape
_MAC_RATE = 4.0e12
_WEIGHT_RATE = 30e9


def true_depth_times(g: LayerGraph, service_sum_s: float) -> List[float]:
    """Per-depth service times whose sum is exactly ``service_sum_s``,
    shaped by the analytic MAC + weight-load profile."""
    macs = g.macs_per_depth()
    wb = g.bytes_per_depth()
    raw = [m / _MAC_RATE + b / _WEIGHT_RATE for m, b in zip(macs, wb)]
    total = sum(raw)
    if total <= 0:
        raw = [1.0] * g.depth
        total = float(g.depth)
    return [t * service_sum_s / total for t in raw]


@dataclasses.dataclass(frozen=True)
class TrafficPhase:
    """``windows`` windows of ``rates[member]`` requests per window."""

    windows: int
    rates: Dict[str, int]


class FleetScenario:
    """One runnable multi-tenant serving scenario.

    ``service_sum_s`` maps member name -> that model's whole-model true
    service time (the sleep budget one request costs end to end on one
    device).  Build the runtime with :meth:`deploy` (or hand
    :meth:`builders` / :attr:`graphs` to ``deploy_fleet`` yourself),
    then :meth:`drive` phases through it.
    """

    def __init__(self, spec: FleetSpec,
                 service_sum_s: Dict[str, float]):
        missing = [n for n in spec.member_names if n not in service_sum_s]
        if missing:
            raise ValueError(f"service_sum_s missing members: {missing}")
        self.spec = spec
        self.graphs: Dict[str, LayerGraph] = {
            m.name: resolve_model_graph(m.spec.model)
            for m in spec.members}
        self.true_s: Dict[str, List[float]] = {
            n: true_depth_times(self.graphs[n], service_sum_s[n])
            for n in spec.member_names}
        self._tap_lock = threading.Lock()
        self.exit_order: Dict[str, List[int]] = {
            n: [] for n in spec.member_names}
        self._next_id: Dict[str, int] = {n: 0 for n in spec.member_names}
        self.lost: Dict[str, int] = {n: 0 for n in spec.member_names}

    # -- stage functions -----------------------------------------------------
    def builder_for(self, name: str):
        """Stage-fn builder for one member: each stage sleeps its depth
        range's true time.  (Exit order is tapped at request completion,
        not inside a stage fn — replicated-stage workers run concurrently
        and only the executor's merge restores stream order.)"""
        true_s = self.true_s[name]

        def builder(pl):
            fns = []
            for (lo, hi) in pl.stage_depth_ranges:
                dt = sum(true_s[d] for d in range(lo, hi + 1))

                def fn(x, dt=dt):
                    time.sleep(dt)
                    return x
                fns.append(fn)
            return fns
        return builder

    def builders(self) -> Dict[str, Any]:
        return {n: self.builder_for(n) for n in self.spec.member_names}

    def deploy(self, **kwargs) -> Fleet:
        """``deploy_fleet`` with this scenario's graphs and builders."""
        return deploy_fleet(self.spec, graphs=self.graphs,
                            stage_fn_builders=self.builders(), **kwargs)

    def _tap(self, name: str):
        """Completion tap: successful exits append in merge-exit order
        (errored requests never crossed the pipeline tail)."""
        order = self.exit_order[name]

        def on_done(req):
            if req.error is None:
                with self._tap_lock:
                    order.append(int(req.result))
        return on_done

    # -- traffic -------------------------------------------------------------
    def drive(self, fleet: Fleet, phases: List[TrafficPhase], *,
              tick_autoscaler: bool = True,
              wait_timeout_s: float = 60.0) -> Dict[str, Any]:
        """Run the phases: each window submits every member's quota
        through the fleet front door, waits for the window to resolve,
        then ticks the autoscaler once (when present and enabled).
        Returns per-member metrics; cumulative across calls on the same
        scenario (ids keep counting, exit order keeps appending)."""
        metrics = {n: {"submitted": 0, "completed": 0, "failed": 0,
                       "shed": 0, "deadline_exceeded": 0,
                       "within_slo": 0, "latencies_s": []}
                   for n in self.spec.member_names}
        for phase in phases:
            unknown = set(phase.rates) - set(self.spec.member_names)
            if unknown:
                raise ValueError(f"phase rates name non-members: "
                                 f"{sorted(unknown)}")
            for _ in range(phase.windows):
                window = []
                for name, rate in phase.rates.items():
                    for _ in range(rate):
                        rid = self._next_id[name]
                        self._next_id[name] += 1
                        window.append(
                            (name, fleet.submit(name, rid,
                                                on_done=self._tap(name))))
                for name, req in window:
                    m = metrics[name]
                    m["submitted"] += 1
                    if not req.event.wait(wait_timeout_s):
                        self.lost[name] += 1
                        continue
                    if req.error is None:
                        lat = req.t_done - req.t_submit
                        m["completed"] += 1
                        m["latencies_s"].append(lat)
                        slo = self.spec.member(name).spec.slo_p95_ms
                        if slo is None or lat <= slo / 1e3:
                            m["within_slo"] += 1
                    else:
                        m["failed"] += 1
                        kind = type(req.error).__name__
                        if kind == "Overloaded":
                            m["shed"] += 1
                        elif kind == "DeadlineExceeded":
                            m["deadline_exceeded"] += 1
                if (tick_autoscaler and fleet.autoscaler is not None):
                    fleet.autoscaler.tick()
        return metrics

    # -- audit / summary -----------------------------------------------------
    def misordered(self, name: str) -> int:
        order = self.exit_order[name]
        return sum(1 for a, b in zip(order, order[1:]) if b < a)

    def audit(self) -> Dict[str, Any]:
        """Zero-loss / zero-misorder accounting per member.  ``exited``
        counts successful merge-exit completions (shed / expired
        requests resolve with an error and never cross the pipeline
        tail); the invariant checked here is *no hang and no reorder*
        across every hot-swap — the drain contract."""
        return {n: {"submitted": self._next_id[n],
                    "exited": len(self.exit_order[n]),
                    "lost": self.lost[n],
                    "misordered": self.misordered(n)}
                for n in self.spec.member_names}

    def attainment(self, metrics: Dict[str, Any]) -> Dict[str, float]:
        """Per-member SLO attainment in [0, 1]: the fraction of
        submitted requests that completed within the p95 target
        (completed at all, for members without one)."""
        out = {}
        for name, m in metrics.items():
            if m["submitted"] == 0:
                out[name] = 1.0
                continue
            out[name] = m["within_slo"] / m["submitted"]
        return out

    @staticmethod
    def worst(attainment: Dict[str, float]) -> float:
        return min(attainment.values())


def summarize_member(metrics: Dict[str, Any]) -> Dict[str, Any]:
    """Fold one member's metrics into a JSON-friendly record."""
    from ..serving.server import latency_percentiles
    lat = latency_percentiles(metrics["latencies_s"])
    return {
        "submitted": metrics["submitted"],
        "completed": metrics["completed"],
        "failed": metrics["failed"],
        "shed": metrics["shed"],
        "deadline_exceeded": metrics["deadline_exceeded"],
        "within_slo": metrics["within_slo"],
        "p50_ms": round(lat["p50_s"] * 1e3, 3),
        "p95_ms": round(lat["p95_s"] * 1e3, 3),
        "p99_ms": round(lat["p99_s"] * 1e3, 3),
    }
