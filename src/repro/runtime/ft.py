"""Fault-tolerant training runtime.

* :class:`TrainSupervisor` — checkpoint/restart driver: periodic async
  checkpoints, automatic restore-and-replay on step failure (device loss is
  surfaced by JAX as an exception on the host), bounded restart budget.
  Because the data pipeline is step-addressable, replay is exact.
* :class:`FailureInjector` — deterministic fault injection for tests/examples
  (fail at step k / with probability p).
* :class:`ElasticPlanner` — elastic scaling hook: when the healthy device
  count changes, re-derive the segmentation plan with the paper's
  O(d log sum P) balanced split.  The paper's §2.2 argument — *fast*
  partitioning enables dynamic edge deployments — is exactly what makes
  replan-on-resize viable here (ms-scale, vs profiling-based partitioners).
  :meth:`ElasticPlanner.resize_server` drives a live streaming
  ``PipelinedModelServer`` through a resize: replan, rebuild the stage
  functions, and hot-swap the server's executor (in-flight requests drain
  first; requests still queued are served by the new plan).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional

from ..api import DeploymentSpec
from ..api import plan as plan_spec
from ..checkpoint import CheckpointStore
from ..core.graph import LayerGraph
from ..core.planner import PlacementPlan


class FailureInjector:
    """Raises RuntimeError at configured steps (deterministic chaos)."""

    def __init__(self, fail_at_steps=(), fail_rate: float = 0.0, seed: int = 0):
        self.fail_at = set(fail_at_steps)
        self.fail_rate = fail_rate
        self._seed = seed
        self._fired = set()

    def check(self, step: int) -> None:
        if step in self.fail_at and step not in self._fired:
            self._fired.add(step)
            raise RuntimeError(f"injected failure at step {step}")
        if self.fail_rate > 0.0:
            import random
            rnd = random.Random((self._seed, step))
            if rnd.random() < self.fail_rate and step not in self._fired:
                self._fired.add(step)
                raise RuntimeError(f"injected random failure at step {step}")


@dataclasses.dataclass
class SupervisorReport:
    final_step: int
    restarts: int
    checkpoints: int
    history: list


class TrainSupervisor:
    """Run `n_steps` of `step_fn` with checkpoint/restart fault tolerance.

    step_fn(state, step) -> (state, metrics).  `state` must be a pytree
    (params + opt state + anything needed to resume).
    """

    def __init__(self, store: CheckpointStore, step_fn: Callable,
                 ckpt_every: int = 50, max_restarts: int = 8,
                 injector: Optional[FailureInjector] = None,
                 async_ckpt: bool = True):
        self.store = store
        self.step_fn = step_fn
        self.ckpt_every = ckpt_every
        self.max_restarts = max_restarts
        self.injector = injector
        self.async_ckpt = async_ckpt

    def run(self, state: Any, n_steps: int, start_step: int = 0
            ) -> tuple:
        restarts = 0
        checkpoints = 0
        history = []
        # resume from latest checkpoint if one exists
        latest = self.store.latest_step()
        if latest is not None and latest > start_step:
            latest, state = self.store.restore(state)
            start_step = latest
        step = start_step
        while step < n_steps:
            try:
                if self.injector is not None:
                    self.injector.check(step)
                state, metrics = self.step_fn(state, step)
                history.append((step, metrics))
                step += 1
                if step % self.ckpt_every == 0 or step == n_steps:
                    self.store.save(step, state,
                                    blocking=not self.async_ckpt)
                    checkpoints += 1
            except RuntimeError as e:
                restarts += 1
                if restarts > self.max_restarts:
                    raise RuntimeError(
                        f"exceeded restart budget ({self.max_restarts}): {e}")
                restored, state = self.store.restore(state)
                step = restored if restored is not None else start_step
        self.store.wait()
        return state, SupervisorReport(final_step=step, restarts=restarts,
                                       checkpoints=checkpoints,
                                       history=history)


class ElasticPlanner:
    """Re-plan the pipeline segmentation when the device pool resizes.

    Planning goes through the ``repro.api`` front door: the planner holds
    one base :class:`~repro.api.DeploymentSpec` (built from the legacy
    ``strategy`` name, or passed in whole via ``spec=``) and re-derives it
    at each device count with ``spec.with_stages(n)``."""

    def __init__(self, graph: LayerGraph, strategy: str = "balanced",
                 spec: Optional[DeploymentSpec] = None):
        self.graph = graph
        self.spec = spec if spec is not None \
            else DeploymentSpec(strategy=strategy)
        self.strategy = self.spec.strategy
        self._cache: Dict[int, PlacementPlan] = {}
        self.replan_times: Dict[int, float] = {}

    def plan_for(self, n_devices: int) -> PlacementPlan:
        if n_devices not in self._cache:
            t0 = time.perf_counter()
            # attach_report=False: replan_times is a reported latency
            # metric and must keep measuring the plan search alone
            self._cache[n_devices] = plan_spec(
                self.spec.with_stages(n_devices), graph=self.graph,
                attach_report=False)
            self.replan_times[n_devices] = time.perf_counter() - t0
        return self._cache[n_devices]

    def on_resize(self, healthy_devices: int) -> PlacementPlan:
        """Called by the serving loop when devices join/leave."""
        return self.plan_for(max(1, healthy_devices))

    def resize_server(self, server: Any,
                      stage_fn_builder: Callable[[PlacementPlan],
                                                 List[Callable]],
                      healthy_devices: int,
                      drain_timeout: float = 30.0) -> PlacementPlan:
        """Elastic hook for a live streaming server: replan for the
        surviving devices, build the new per-stage functions, and hot-swap
        the server's executor via ``server.reconfigure`` (admission pauses,
        in-flight requests drain, queued requests are served by the new
        plan).  Returns the new plan."""
        pl = self.on_resize(healthy_devices)
        server.reconfigure(pl, stage_fn_builder(pl),
                           drain_timeout=drain_timeout)
        return pl
