"""Fault-tolerant runtime: checkpoint/restart training, fault injection,
elastic replanning, and health-monitored degraded-mode serving.

* :class:`TrainSupervisor` — checkpoint/restart driver: periodic async
  checkpoints, automatic restore-and-replay on step failure (device loss is
  surfaced by JAX as an exception on the host — of *any* type, so every
  ``Exception`` triggers a restart), bounded restart budget.  With an empty
  store the restart is a clean replay from ``start_step`` with the initial
  state.  Because the data pipeline is step-addressable, replay is exact.
* :class:`FailureInjector` — deterministic + seeded-random fault injection
  for tests/examples/chaos: fail at step k, fail with independent per-step
  probability p, restricted to a named target, raising a configurable
  exception type (``ReplicaFailure`` for the executor chaos hooks), and a
  callable-target mode (:meth:`FailureInjector.wrap`) that turns any stage
  function into a chaos-injected one.
* :class:`ElasticPlanner` — elastic scaling hook: when the healthy device
  count changes, re-derive the segmentation plan with the paper's
  O(d log sum P) balanced split.  The paper's §2.2 argument — *fast*
  partitioning enables dynamic edge deployments — is exactly what makes
  replan-on-resize viable here (ms-scale, vs profiling-based partitioners).
  :meth:`ElasticPlanner.resize_server` drives a live streaming
  ``PipelinedModelServer`` through a resize: replan, rebuild the stage
  functions, and hot-swap the server's executor (in-flight requests drain
  first; requests still queued are served by the new plan).
* :class:`HealthMonitor` + :class:`FaultPolicy` — the closed loop between
  the executor's failure domains and the planner: it watches
  ``PipelineExecutor.health_snapshot()`` (heartbeats, consecutive
  item-failure counts per stage/replica), withdraws replicas that exceed
  the policy (``kill_replica`` — the executor re-dispatches their
  in-flight work), and on losing the *last* replica of a stage replans
  against the shrunken device pool and hot-swaps through the existing
  ``reconfigure()`` drain path, optionally warm-restoring stage state
  from a ``checkpoint.CheckpointStore`` first.
"""
from __future__ import annotations

import dataclasses
import queue
import random
import threading
import time
from typing import Any, Callable, Dict, List, Optional

from ..api import DeploymentSpec
from ..api import plan as plan_spec
from ..checkpoint import CheckpointStore
from ..core.graph import LayerGraph
from ..core.placement import PlacementPlan


class FailureInjector:
    """Deterministic + seeded-random fault injection.

    * ``fail_at_steps`` — raise at exactly these steps, once per
      (target, step).
    * ``fail_rate`` — seeded per-step coin.  The decision is *independent*
      per (target, step) and independent of the deterministic schedule
      (separate fired sets), so a deterministic failure at a step never
      suppresses — or forces — a random one at the same step.
    * ``fail_target`` — restrict firing to one named target (a stage name,
      a replica id), so one injector can be shared across many call sites.
    * ``exc_type`` — the exception class raised; pass
      :class:`repro.core.pipeline.ReplicaFailure` to make the executor
      treat the fault as a replica death (failover) rather than an item
      failure.
    * :meth:`wrap` — callable-target mode: wrap a stage function with a
      per-target call counter driving :meth:`check`, the hook the chaos
      harness uses to kill workers from inside the pipeline.
    """

    def __init__(self, fail_at_steps=(), fail_rate: float = 0.0,
                 seed: int = 0, exc_type: type = RuntimeError,
                 fail_target: Optional[str] = None):
        self.fail_at = set(fail_at_steps)
        self.fail_rate = fail_rate
        self.exc_type = exc_type
        self.fail_target = fail_target
        self._seed = seed
        self._fired_at = set()      # (target, step) deterministic firings
        self._decided_rate = set()  # (target, step) coins already flipped
        self._counts: Dict[Optional[str], int] = {}
        self._lock = threading.Lock()

    def check(self, step: int, target: Optional[str] = None) -> None:
        if self.fail_target is not None and target != self.fail_target:
            return
        key = (target, step)
        if step in self.fail_at and key not in self._fired_at:
            self._fired_at.add(key)
            where = f" on {target}" if target else ""
            raise self.exc_type(f"injected failure at step {step}{where}")
        if self.fail_rate > 0.0 and key not in self._decided_rate:
            # flip the coin exactly once per (target, step); independent
            # of whether the deterministic schedule fired there
            self._decided_rate.add(key)
            rnd = random.Random(f"{self._seed}:{target}:{step}")
            if rnd.random() < self.fail_rate:
                where = f" on {target}" if target else ""
                raise self.exc_type(
                    f"injected random failure at step {step}{where}")

    def wrap(self, fn: Callable[[Any], Any],
             target: str) -> Callable[[Any], Any]:
        """Callable-target mode: a stage function whose calls are counted
        per ``target`` and checked against this injector — usable directly
        as a ``PipelineExecutor`` stage fn (the executor chaos hook)."""
        def wrapped(x):
            with self._lock:
                step = self._counts.get(target, 0)
                self._counts[target] = step + 1
            self.check(step, target=target)
            return fn(x)
        wrapped.__name__ = f"chaos[{target}]"
        return wrapped


@dataclasses.dataclass
class SupervisorReport:
    final_step: int
    restarts: int
    checkpoints: int
    history: list


class TrainSupervisor:
    """Run `n_steps` of `step_fn` with checkpoint/restart fault tolerance.

    step_fn(state, step) -> (state, metrics).  `state` must be a pytree
    (params + opt state + anything needed to resume).
    """

    def __init__(self, store: CheckpointStore, step_fn: Callable,
                 ckpt_every: int = 50, max_restarts: int = 8,
                 injector: Optional[FailureInjector] = None,
                 async_ckpt: bool = True):
        self.store = store
        self.step_fn = step_fn
        self.ckpt_every = ckpt_every
        self.max_restarts = max_restarts
        self.injector = injector
        self.async_ckpt = async_ckpt

    def run(self, state: Any, n_steps: int, start_step: int = 0
            ) -> tuple:
        restarts = 0
        checkpoints = 0
        history = []
        initial_state = state     # clean-restart fallback (store empty)
        # resume from latest checkpoint if one exists
        latest = self.store.latest_step()
        if latest is not None and latest > start_step:
            latest, state = self.store.restore(state)
            start_step = latest
        step = start_step
        while step < n_steps:
            try:
                if self.injector is not None:
                    self.injector.check(step)
                state, metrics = self.step_fn(state, step)
                history.append((step, metrics))
                step += 1
                if step % self.ckpt_every == 0 or step == n_steps:
                    self.store.save(step, state,
                                    blocking=not self.async_ckpt)
                    checkpoints += 1
            # device loss surfaces as whatever the backend raises (JAX is
            # not guaranteed to use RuntimeError) — any Exception restarts
            except Exception as e:
                restarts += 1
                if restarts > self.max_restarts:
                    raise RuntimeError(
                        f"exceeded restart budget ({self.max_restarts}): {e}")
                restored = None
                if self.store.has_checkpoint():
                    restored, restored_state = self.store.restore(state)
                if restored is None:
                    # empty (or fully corrupt) store: restart cleanly from
                    # the initial state, not from the failed mid-run state
                    state = initial_state
                    step = start_step
                else:
                    state = restored_state
                    step = restored
        self.store.wait()
        return state, SupervisorReport(final_step=step, restarts=restarts,
                                       checkpoints=checkpoints,
                                       history=history)


class ElasticPlanner:
    """Re-plan the pipeline segmentation when the device pool resizes.

    Planning goes through the ``repro.api`` front door: the planner holds
    one base :class:`~repro.api.DeploymentSpec` (built from the legacy
    ``strategy`` name, or passed in whole via ``spec=``) and re-derives it
    at each device count with ``spec.with_stages(n)``."""

    def __init__(self, graph: LayerGraph, strategy: str = "balanced",
                 spec: Optional[DeploymentSpec] = None):
        self.graph = graph
        self.spec = spec if spec is not None \
            else DeploymentSpec(strategy=strategy)
        self.strategy = self.spec.strategy
        self._cache: Dict[int, PlacementPlan] = {}
        self.replan_times: Dict[int, float] = {}

    def plan_for(self, n_devices: int) -> PlacementPlan:
        if n_devices not in self._cache:
            t0 = time.perf_counter()
            # attach_report=False: replan_times is a reported latency
            # metric and must keep measuring the plan search alone
            self._cache[n_devices] = plan_spec(
                self.spec.with_stages(n_devices), graph=self.graph,
                attach_report=False)
            self.replan_times[n_devices] = time.perf_counter() - t0
        return self._cache[n_devices]

    def on_resize(self, healthy_devices: int) -> PlacementPlan:
        """Called by the serving loop when devices join/leave."""
        return self.plan_for(max(1, healthy_devices))

    def resize_server(self, server: Any,
                      stage_fn_builder: Callable[[PlacementPlan],
                                                 List[Callable]],
                      healthy_devices: int,
                      drain_timeout: float = 30.0) -> PlacementPlan:
        """Elastic hook for a live streaming server: replan for the
        surviving devices, build the new per-stage functions, and hot-swap
        the server's executor via ``server.reconfigure`` (admission pauses,
        in-flight requests drain, queued requests are served by the new
        plan).  Returns the new plan."""
        pl = self.on_resize(healthy_devices)
        server.reconfigure(pl, stage_fn_builder(pl),
                           drain_timeout=drain_timeout)
        return pl


@dataclasses.dataclass
class FaultPolicy:
    """When the health monitor declares a replica dead and how fast it
    reacts.

    * ``heartbeat_timeout_s`` — a replica whose heartbeat is older than
      this *while the executor has work in flight* is withdrawn (a hung
      device: its in-flight envelopes are re-dispatched, and any result
      the zombie later produces is deduplicated by the merge).  ``None``
      disables heartbeat-based kills.
    * ``max_consecutive_failures`` — a replica whose stage function failed
      this many items in a row is withdrawn (a sick device: persistent
      item errors are a death signal, per-item errors stay per-item below
      the threshold).  ``None`` disables.
    * ``poll_interval_s`` — monitor loop cadence; also bounds how quickly
      a stage-lost event turns into a degraded-mode replan.
    * ``min_devices`` — never replan below this many devices.
    """

    heartbeat_timeout_s: Optional[float] = None
    max_consecutive_failures: Optional[int] = None
    poll_interval_s: float = 0.02
    min_devices: int = 1


class HealthMonitor:
    """Close the loop: executor failure domains -> degraded-mode replan.

    Wires itself to the server's stage-lost notifications (re-wired
    automatically across ``reconfigure`` swaps) and polls
    ``health_snapshot()`` under a :class:`FaultPolicy`.  On losing the
    last replica of a stage it counts the surviving replicas across the
    old executor, optionally warm-restores state via ``warm_restore()``
    (e.g. re-read stage params from a ``CheckpointStore`` so replacement
    devices start from the latest snapshot), and drives
    ``ElasticPlanner.resize_server`` — replan for the shrunken pool, hot
    swap through the drain path.  Requests that failed fast as
    ``StageLost`` meanwhile are re-admitted by the server's
    ``stage_loss_retries`` policy and served by the new plan: zero lost
    requests end to end.

    The replan runs on the monitor's own thread — never on an executor
    worker — because ``reconfigure`` joins the executor's threads.
    """

    def __init__(self, server: Any, planner: ElasticPlanner,
                 stage_fn_builder: Callable[[PlacementPlan],
                                            List[Callable]],
                 policy: Optional[FaultPolicy] = None,
                 warm_restore: Optional[Callable[[], None]] = None):
        self.server = server
        self.planner = planner
        self.stage_fn_builder = stage_fn_builder
        self.policy = policy if policy is not None else FaultPolicy()
        self.warm_restore = warm_restore
        self.replans: List[Dict[str, Any]] = []
        self.kills: List[tuple] = []
        self._events: "queue.Queue[int]" = queue.Queue()
        self._stop_evt = threading.Event()
        self._thread: Optional[threading.Thread] = None
        server.add_stage_lost_listener(self.notify_stage_lost)

    # executor threads call this: enqueue only, never block
    def notify_stage_lost(self, stage: int) -> None:
        self._events.put(stage)

    def start(self) -> "HealthMonitor":
        if self._thread is not None:
            return self
        self._stop_evt.clear()
        self._thread = threading.Thread(
            target=self._loop, daemon=True,
            name=f"health-{getattr(self.server.plan, 'graph_name', '?')}")
        self._thread.start()
        return self

    def stop(self) -> None:
        self._stop_evt.set()
        if self._thread is not None:
            self._thread.join(timeout=5)
            self._thread = None

    def __enter__(self) -> "HealthMonitor":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    # -- monitor loop --------------------------------------------------------
    def _loop(self) -> None:
        while not self._stop_evt.is_set():
            try:
                stage = self._events.get(timeout=self.policy.poll_interval_s)
            except queue.Empty:
                self._probe()
                continue
            self._replan(stage)

    def _probe(self) -> None:
        """Policy-driven replica withdrawal: stale heartbeats while work
        is in flight, or too many consecutive item failures."""
        pol = self.policy
        if pol.heartbeat_timeout_s is None \
                and pol.max_consecutive_failures is None:
            return
        ex = self.server.executor
        if not ex.started:
            return
        h = ex.health_snapshot()
        busy = ex.in_flight > 0
        for i, alive_row in enumerate(h["alive"]):
            for j, alive in enumerate(alive_row):
                if not alive:
                    continue
                stale = (pol.heartbeat_timeout_s is not None and busy
                         and h["heartbeat_age_s"][i][j]
                         > pol.heartbeat_timeout_s)
                sick = (pol.max_consecutive_failures is not None
                        and h["consecutive_failures"][i][j]
                        >= pol.max_consecutive_failures)
                if not (stale or sick):
                    continue
                try:
                    ex.kill_replica(i, j)
                    self.kills.append((i, j, "stale" if stale else "sick"))
                except (RuntimeError, ValueError):
                    pass        # executor swapped/stopped under the probe

    def _replan(self, stage: int) -> None:
        """Degraded mode: replan against the surviving devices and hot
        swap.  Coalesces queued stage-lost events — one replan covers
        every stage lost in the same epoch."""
        lost = {stage}
        while True:
            try:
                lost.add(self._events.get_nowait())
            except queue.Empty:
                break
        ex = self.server.executor
        h = ex.health_snapshot()
        healthy = max(self.policy.min_devices,
                      sum(h["live_replicas"]))
        if self.warm_restore is not None:
            try:
                self.warm_restore()
            except Exception:
                pass            # cold rebuild beats no rebuild
        t0 = time.perf_counter()
        pl = self.planner.resize_server(self.server, self.stage_fn_builder,
                                        healthy)
        self.replans.append({
            "lost_stages": sorted(lost),
            "healthy_devices": healthy,
            "n_stages": pl.n_stages,
            "replan_s": time.perf_counter() - t0,
        })
