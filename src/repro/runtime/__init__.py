from .ft import (ElasticPlanner, FailureInjector, FaultPolicy,
                 HealthMonitor, TrainSupervisor)
from .straggler import SpeculativeExecutor
from .chaos import (ChaosEvent, ChaosMonkey, ChaosReport,
                    replica_kill_schedule, run_chaos_executor)
from .selfheal import DriftDetector, DriftPolicy, SelfHealingController

__all__ = ["TrainSupervisor", "FailureInjector", "ElasticPlanner",
           "FaultPolicy", "HealthMonitor", "SpeculativeExecutor",
           "ChaosEvent", "ChaosMonkey", "ChaosReport",
           "replica_kill_schedule", "run_chaos_executor",
           "DriftDetector", "DriftPolicy", "SelfHealingController"]
