from .ft import ElasticPlanner, FailureInjector, TrainSupervisor
from .straggler import SpeculativeExecutor

__all__ = ["TrainSupervisor", "FailureInjector", "ElasticPlanner",
           "SpeculativeExecutor"]
