from .ft import (ElasticPlanner, FailureInjector, FaultPolicy,
                 HealthMonitor, TrainSupervisor)
from .straggler import SpeculativeExecutor
from .chaos import (ChaosEvent, ChaosMonkey, ChaosReport,
                    replica_kill_schedule, run_chaos_executor)

__all__ = ["TrainSupervisor", "FailureInjector", "ElasticPlanner",
           "FaultPolicy", "HealthMonitor", "SpeculativeExecutor",
           "ChaosEvent", "ChaosMonkey", "ChaosReport",
           "replica_kill_schedule", "run_chaos_executor"]
