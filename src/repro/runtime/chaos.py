"""Chaos harness for the streaming pipeline's fault-tolerance tier.

Kills workers/replicas/stages under open-loop load with *deterministic
seeds* and checks the executor's exactly-once contract:

* zero lost requests — every submitted request's future resolves (a value
  or a ``StageLost`` error; nothing hangs, nothing vanishes);
* zero misordered outputs — a tap stage appended after the user stages
  records the order results exit the pipeline, which must equal
  submission order (the order-restoring merge's dedup-by-sequence makes
  failover re-dispatch and hedged duplicates invisible downstream);
* bounded p99 inflation — latency percentiles per scenario, compared to
  a no-fault baseline by ``benchmarks/chaos_bench.py``.

Pieces: :func:`replica_kill_schedule` (seeded kill plans that can spare
the last replica of every stage, or not — stage loss is a scenario too),
:class:`ChaosMonkey` (a thread that executes a schedule against a live —
possibly hot-swapped — executor), and :func:`run_chaos_executor` (one
open-loop run → :class:`ChaosReport`).
"""
from __future__ import annotations

import dataclasses
import random
import threading
import time
from concurrent.futures import TimeoutError as _FutureTimeout
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

from ..core.pipeline import PipelineExecutor


@dataclasses.dataclass(frozen=True)
class ChaosEvent:
    """One scheduled fault: at ``at_s`` seconds into the run, kill
    ``stage``'s replica ``slot`` (``kind="kill_replica"``), the whole
    stage (``kind="kill_stage"``, slot ignored), or apply a *sustained
    slowdown* — multiply the stage's service time by ``factor`` from this
    point on (``kind="slowdown"``; the drift scenario the self-healing
    loop reacts to, delivered through the monkey's ``slowdown_target``
    hook since stage-fn timing lives in the harness, not the executor)."""

    at_s: float
    kind: str
    stage: int
    slot: int = 0
    factor: float = 1.0

    def __post_init__(self):
        if self.kind not in ("kill_replica", "kill_stage", "slowdown"):
            raise ValueError(f"unknown chaos kind: {self.kind!r}")
        if self.at_s < 0:
            raise ValueError(f"at_s must be >= 0, got {self.at_s}")
        if self.kind == "slowdown" and self.factor <= 0:
            raise ValueError(f"slowdown factor must be > 0, "
                             f"got {self.factor}")


def replica_kill_schedule(replicas: Sequence[int], n_kills: int,
                          duration_s: float, seed: int = 0,
                          spare_last: bool = True,
                          max_per_stage: Optional[int] = None
                          ) -> List[ChaosEvent]:
    """Seeded schedule of ``n_kills`` replica kills spread across the
    middle 80% of ``duration_s``.  Each (stage, slot) dies at most once.
    ``spare_last=True`` (the failover scenario) never kills slot 0, so
    every stage keeps at least one survivor; ``spare_last=False`` allows
    full stage loss (the degraded-replan scenario).  ``max_per_stage``
    caps kills per stage — a failover *latency* experiment should leave
    each stage enough survivors to carry the offered load, otherwise it
    measures overload, not failover.  Same seed, same arguments →
    identical schedule."""
    rnd = random.Random(seed)
    candidates = [(i, j) for i, k in enumerate(replicas)
                  for j in range(1 if spare_last else 0, k)]
    rnd.shuffle(candidates)
    picked = []
    per_stage: Dict[int, int] = {}
    for (i, j) in candidates:
        if len(picked) >= max(0, n_kills):
            break
        if max_per_stage is not None \
                and per_stage.get(i, 0) >= max_per_stage:
            continue
        per_stage[i] = per_stage.get(i, 0) + 1
        picked.append((i, j))
    lo, hi = 0.1 * duration_s, 0.9 * duration_s
    times = sorted(rnd.uniform(lo, hi) for _ in picked)
    return [ChaosEvent(at_s=t, kind="kill_replica", stage=i, slot=j)
            for t, (i, j) in zip(times, picked)]


class ChaosMonkey:
    """Execute a chaos schedule against a live executor.

    Takes a *getter* rather than the executor itself so the schedule
    keeps applying across ``reconfigure()`` hot-swaps (the server's
    ``.executor`` property changes identity).  Kills that no longer apply
    — executor stopped, stage index out of range after a replan — are
    recorded as skipped, not raised."""

    def __init__(self, executor_getter: Callable[[], PipelineExecutor],
                 events: Sequence[ChaosEvent],
                 slowdown_target: Optional[Callable[[int, float],
                                                    None]] = None):
        self.get = executor_getter
        self.events = sorted(events, key=lambda e: e.at_s)
        # ``slowdown`` events land here (stage, factor) — the harness owns
        # stage-fn timing, so it decides what "this stage got slower" means
        self.slowdown_target = slowdown_target
        self.applied: List[Tuple[ChaosEvent, bool]] = []
        self._thread: Optional[threading.Thread] = None
        self._stop = threading.Event()

    def start(self) -> "ChaosMonkey":
        self._thread = threading.Thread(target=self._run, daemon=True,
                                        name="chaos-monkey")
        self._thread.start()
        return self

    def join(self, timeout: Optional[float] = None) -> None:
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=timeout)

    def _run(self) -> None:
        t0 = time.monotonic()
        for ev in self.events:
            delay = ev.at_s - (time.monotonic() - t0)
            if delay > 0 and self._stop.wait(delay):
                # harness asked us to stop before this event fired; record
                # the remainder as skipped so reports are complete
                self.applied.append((ev, False))
                continue
            ok = True
            try:
                if ev.kind == "slowdown":
                    if self.slowdown_target is None:
                        ok = False
                    else:
                        self.slowdown_target(ev.stage, ev.factor)
                else:
                    ex = self.get()
                    if ev.kind == "kill_stage":
                        ex.kill_stage(ev.stage)
                    else:
                        ex.kill_replica(ev.stage, ev.slot)
            except (RuntimeError, ValueError, IndexError):
                ok = False
            self.applied.append((ev, ok))


@dataclasses.dataclass
class ChaosReport:
    submitted: int
    completed: int
    failed: int
    lost: int
    misordered: int
    duration_s: float
    latency: Dict[str, float]
    health: Dict[str, Any]
    kills_applied: int

    def to_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)


def _percentile(sorted_xs: List[float], q: float) -> float:
    if not sorted_xs:
        return 0.0
    idx = min(len(sorted_xs) - 1, int(round(q * (len(sorted_xs) - 1))))
    return sorted_xs[idx]


def run_chaos_executor(stage_fns: Sequence[Callable[[Any], Any]],
                       replicas: Sequence[int],
                       n_requests: int,
                       interval_s: float = 0.0,
                       events: Sequence[ChaosEvent] = (),
                       hedge_after: Optional[float] = None,
                       queue_size: int = 64,
                       timeout_s: float = 120.0) -> ChaosReport:
    """One open-loop chaos run against a raw :class:`PipelineExecutor`.

    Submits the integers ``0..n_requests-1`` at a fixed ``interval_s``
    while a :class:`ChaosMonkey` executes ``events``, then audits the
    exactly-once contract.  ``stage_fns`` must propagate their input's
    identity (return the input, possibly after work) so the appended tap
    stage can record exit order; items that fail (``StageLost`` when a
    whole stage dies) count as ``failed``, never ``lost``."""
    exit_order: List[int] = []
    tap_lock = threading.Lock()

    def tap(x):
        with tap_lock:
            exit_order.append(int(x))
        return x

    ex = PipelineExecutor(list(stage_fns) + [tap],
                          replicas=list(replicas) + [1],
                          queue_size=queue_size, hedge_after=hedge_after,
                          name="chaos")
    monkey = ChaosMonkey(lambda: ex, events)
    t_submit: List[float] = [0.0] * n_requests
    t_done: List[Optional[float]] = [None] * n_requests
    futures = []
    t0 = time.monotonic()

    def stamp(i):
        # done-callbacks fire on the collector thread the moment the
        # future resolves — latency must not include the time this
        # harness spends still submitting the rest of the open loop
        def cb(_f):
            t_done[i] = time.monotonic()
        return cb

    with ex:
        monkey.start()
        for i in range(n_requests):
            t_submit[i] = time.monotonic()
            fut = ex.submit(i)
            fut.add_done_callback(stamp(i))
            futures.append(fut)
            if interval_s > 0:
                time.sleep(interval_s)
        lat: List[float] = []
        completed = failed = lost = 0
        deadline = time.monotonic() + timeout_s
        for i, fut in enumerate(futures):
            try:
                val = fut.result(timeout=max(0.01,
                                             deadline - time.monotonic()))
                if val != i:
                    raise AssertionError(
                        f"identity broken: submitted {i}, got {val!r}")
                completed += 1
                lat.append((t_done[i] or time.monotonic()) - t_submit[i])
            except (_FutureTimeout, TimeoutError):
                lost += 1
            except Exception:
                failed += 1
        health = ex.health_snapshot()
        monkey.join(timeout=5)
    duration = time.monotonic() - t0
    lat.sort()
    # hedged duplicates are deduped by the merge, so each request exits
    # at most once; any adjacent inversion is a real ordering violation
    misordered = sum(1 for a, b in zip(exit_order, exit_order[1:])
                     if b < a)
    return ChaosReport(
        submitted=n_requests, completed=completed, failed=failed,
        lost=lost, misordered=misordered, duration_s=duration,
        latency={
            "p50_ms": 1e3 * _percentile(lat, 0.50),
            "p90_ms": 1e3 * _percentile(lat, 0.90),
            "p99_ms": 1e3 * _percentile(lat, 0.99),
            "mean_ms": 1e3 * (sum(lat) / len(lat)) if lat else 0.0,
            "max_ms": 1e3 * (lat[-1] if lat else 0.0),
        },
        health={"hedges": health["hedges"],
                "redispatches": health["redispatches"],
                "live_replicas": health["live_replicas"]},
        kills_applied=sum(1 for _, ok in monkey.applied if ok),
    )
