"""Straggler mitigation: speculative duplicate dispatch.

For inference pipelines the slowest stage paces the whole pipeline (paper
Fig. 10).  Transient stragglers (thermal throttling on the Edge TPU — §4 —
or preempted hosts at pod scale) are mitigated by hedged execution: if a
work item has not completed within ``hedge_after`` seconds, the same item is
dispatched to a backup executor and the first result wins.  Duplicates are
safe because stages are pure functions.
"""
from __future__ import annotations

import threading
import time
from concurrent.futures import ThreadPoolExecutor, wait, FIRST_COMPLETED
from typing import Any, Callable, Sequence


class SpeculativeExecutor:
    def __init__(self, fn: Callable[[Any], Any], hedge_after: float = 0.05,
                 max_workers: int = 4):
        self.fn = fn
        self.hedge_after = hedge_after
        self.pool = ThreadPoolExecutor(max_workers=max_workers)
        self.hedged = 0          # number of duplicate dispatches issued
        self.completed = 0

    def submit(self, item: Any) -> Any:
        primary = self.pool.submit(self.fn, item)
        done, _ = wait([primary], timeout=self.hedge_after,
                       return_when=FIRST_COMPLETED)
        if done:
            self.completed += 1
            return primary.result()
        # primary is straggling: hedge
        self.hedged += 1
        backup = self.pool.submit(self.fn, item)
        done, _ = wait([primary, backup], return_when=FIRST_COMPLETED)
        self.completed += 1
        winner = next(iter(done))
        # leave the loser running (pure fn, result discarded)
        return winner.result()

    def map(self, items: Sequence[Any]):
        return [self.submit(x) for x in items]

    def shutdown(self):
        self.pool.shutdown(wait=False)
