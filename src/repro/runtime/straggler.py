"""Straggler mitigation: speculative duplicate dispatch.

For inference pipelines the slowest stage paces the whole pipeline (paper
Fig. 10).  Transient stragglers (thermal throttling on the Edge TPU — §4 —
or preempted hosts at pod scale) are mitigated by hedged execution: if a
work item has not completed within ``hedge_after`` seconds, the same item is
dispatched to a backup executor and the first result wins.  Duplicates are
safe because stages are pure functions.

The *streaming* pipeline has this built in (``PipelineExecutor``'s
``hedge_after`` — duplicates are deduplicated by the order-restoring merge);
:class:`SpeculativeExecutor` is the standalone per-call form for code that
is not running inside the executor.

"First result wins" means first *successful* result: a fast failure hedges
immediately, and the winner is the first future that completed without an
exception — a transient fault on the primary must not mask a good backup
result (and vice versa).  Only if every attempt fails does the primary's
exception propagate.
"""
from __future__ import annotations

from concurrent.futures import ThreadPoolExecutor, wait, FIRST_COMPLETED
from typing import Any, Callable, Sequence


class SpeculativeExecutor:
    def __init__(self, fn: Callable[[Any], Any], hedge_after: float = 0.05,
                 max_workers: int = 4):
        self.fn = fn
        self.hedge_after = hedge_after
        self.pool = ThreadPoolExecutor(max_workers=max_workers)
        self.hedged = 0          # number of duplicate dispatches issued
        self.completed = 0

    def submit(self, item: Any) -> Any:
        primary = self.pool.submit(self.fn, item)
        done, _ = wait([primary], timeout=self.hedge_after,
                       return_when=FIRST_COMPLETED)
        if done and primary.exception() is None:
            self.completed += 1
            return primary.result()
        # primary is straggling (or failed fast): hedge
        self.hedged += 1
        backup = self.pool.submit(self.fn, item)
        pending = {primary, backup}
        while pending:
            done, pending = wait(pending, return_when=FIRST_COMPLETED)
            for fut in done:
                if fut.exception() is None:
                    self.completed += 1
                    # leave any loser running (pure fn, result discarded)
                    return fut.result()
        # both attempts failed: surface the primary's exception
        self.completed += 1
        return primary.result()

    def map(self, items: Sequence[Any]):
        return [self.submit(x) for x in items]

    def shutdown(self, wait: bool = True):
        self.pool.shutdown(wait=wait)
