"""Self-healing control plane: live drift detection, guarded replanning.

The paper's whole premise is that *profiled* costs beat analytic ones
(BENCH_profile.json: 60-80% analytic stage-time error on unprofiled
hardware — the off-chip cliff mispredictions of Seshadri et al.).  PR 5
made that an offline workflow (profile -> calibrate -> ``trace:<path>`` ->
plan); this module closes the loop at runtime:

    telemetry ──> rolling trace ──> refit ──> drift? ──> replan
        ^  (snapshot deltas)  (LiveTraceBuilder)   |  (front-door registry,
        |                                          v   live cost_source)
    commit <── canary validate <── candidate executor
        |      (held-aside requests, observed bottleneck
        v       vs incumbent; fail/worse => ROLLBACK)
    serving on the trace-backed plan

Pieces:

* :class:`DriftPolicy` — every knob of the loop, a frozen dataclass.
* :class:`DriftDetector` — modeled-vs-observed per-stage drift.  The
  metric is **shape-based** (both vectors normalized by their means
  before comparing): a uniformly miscalibrated device — every stage 3x
  the model — still yields the *same* balanced cuts, so uniform scale
  error must not thrash replans; what triggers is relative imbalance the
  model did not predict, which is exactly when different cuts win.
  Observed times are EWMA-smoothed; the trigger needs ``hysteresis``
  *consecutive* over-threshold windows (a transient straggler is not
  drift) and is suppressed for ``cooldown_windows`` after every
  reconfigure (measured in windows, not seconds: deterministic under
  test clocks).
* :class:`SelfHealingController` — the loop itself.  Runs on its own
  thread (a replan must never run on an executor worker: ``reconfigure``
  joins those threads); every window it folds ``server.snapshot()``
  deltas into a :class:`~repro.profiling.live.LiveTraceBuilder`, feeds
  the detector and, on a trigger, replans through ``repro.api.plan`` with
  the live calibrated source and applies the result through a **guarded
  reconfigure**: build the candidate executor, validate it on held-aside
  canary payloads, commit only if its observed bottleneck stage time
  beats the incumbent's (x ``canary_margin``) — otherwise roll back
  (the incumbent never stopped serving; the prior plan + stage fns are
  kept warm in :attr:`SelfHealingController.prior` after a commit too).
  Canary failures retry under seeded-jitter exponential backoff
  (in windows); past ``max_canary_retries`` the loop **degrades** to the
  incumbent — it keeps observing, and re-arms once drift subsides.

Tests drive the loop deterministically through :meth:`tick` (one window,
synchronous); the thread is a convenience wrapper that calls it.
"""
from __future__ import annotations

import dataclasses
import random
import threading
import time
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

from ..core.pipeline import PipelineExecutor
from ..core.placement import PlacementPlan
from ..profiling.live import LiveTraceBuilder

_EPS = 1e-12


@dataclasses.dataclass(frozen=True)
class DriftPolicy:
    """Every knob of the self-healing loop.

    ``drift_threshold`` — relative per-stage shape deviation past which a
    window counts toward a trigger.  ``hysteresis`` — consecutive
    over-threshold windows required to trigger.  ``cooldown_windows`` —
    windows after any reconfigure/decision during which triggers are
    suppressed (the new plan needs fresh telemetry).  ``canary_margin`` —
    a candidate commits only if its canary bottleneck is <= incumbent's
    observed bottleneck times this factor (>1 tolerates canary noise).
    ``backoff_*_windows`` — seeded-jitter exponential backoff between
    canary retries; past ``max_canary_retries`` the loop degrades until
    drift subsides."""

    drift_threshold: float = 0.5
    hysteresis: int = 3
    cooldown_windows: int = 3
    min_window_requests: int = 1
    ewma_alpha: float = 0.5
    live_alpha: float = 0.25
    # which live source replans price against: "auto" uses the raw trace
    # when every depth has live coverage (a localized slowdown is exactly
    # measurable, and a global coefficient fit cannot express it) and the
    # calibrated fit when coverage is partial (it extrapolates
    # structurally to unvisited depths); "trace"/"calibrated" force one
    live_source: str = "auto"
    # strategy used for live replans on plain (non-placement) specs.  The
    # paper's SEGM_BALANCED cuts on raw per-depth *params* — live costs
    # would never move its cuts — so replans default to the time-balanced
    # minimax DP ("opt", never worse than balanced on modeled time).
    # "" keeps the spec's own strategy verbatim.
    replan_strategy: str = "opt"
    canary_requests: int = 4
    canary_margin: float = 1.10
    max_canary_retries: int = 3
    backoff_base_windows: int = 2
    backoff_max_windows: int = 16
    backoff_seed: int = 0

    def __post_init__(self):
        if self.drift_threshold < 0:
            raise ValueError("drift_threshold must be >= 0")
        if self.hysteresis < 1:
            raise ValueError("hysteresis must be >= 1")
        if self.cooldown_windows < 0:
            raise ValueError("cooldown_windows must be >= 0")
        if not 0.0 < self.ewma_alpha <= 1.0:
            raise ValueError("ewma_alpha must be in (0, 1]")
        if self.canary_requests < 1:
            raise ValueError("canary_requests must be >= 1")
        if self.canary_margin <= 0:
            raise ValueError("canary_margin must be > 0")
        if self.max_canary_retries < 0:
            raise ValueError("max_canary_retries must be >= 0")
        if (self.backoff_base_windows < 1
                or self.backoff_max_windows < self.backoff_base_windows):
            raise ValueError("need 1 <= backoff_base_windows "
                             "<= backoff_max_windows")
        if self.live_source not in ("auto", "trace", "calibrated"):
            raise ValueError(f"live_source must be 'auto', 'trace' or "
                             f"'calibrated', got {self.live_source!r}")


class DriftDetector:
    """Modeled-vs-observed per-stage drift with EWMA + hysteresis.

    Deterministic: the same sequence of ``observe`` calls always yields
    the same drift values and trigger decisions (no clocks, no rng).
    """

    def __init__(self, policy: DriftPolicy):
        self.policy = policy
        self._ewma: Optional[List[float]] = None
        self._consec = 0
        self.last_drift = 0.0

    def rebase(self) -> None:
        """Forget the observed EWMA + trigger streak — call after every
        plan change (stage shapes moved; old telemetry is meaningless)."""
        self._ewma = None
        self._consec = 0
        self.last_drift = 0.0

    @staticmethod
    def _normalize(xs: Sequence[float]) -> Optional[List[float]]:
        mean = sum(xs) / len(xs)
        if mean <= _EPS:
            return None
        return [x / mean for x in xs]

    def observe(self, modeled: Sequence[float],
                observed: Sequence[float]) -> float:
        """Fold one window in; returns the (smoothed) drift metric.
        ``modeled`` is the live plan's per-stage modeled time,
        ``observed`` the window's per-item observed stage time
        (``snapshot()['stage_time_per_req_s']``)."""
        if len(modeled) != len(observed) or not modeled:
            self.rebase()
            return 0.0
        if self._ewma is None or len(self._ewma) != len(observed):
            self._ewma = list(observed)
        else:
            a = self.policy.ewma_alpha
            self._ewma = [a * o + (1 - a) * e
                          for o, e in zip(observed, self._ewma)]
        mod_n = self._normalize(modeled)
        obs_n = self._normalize(self._ewma)
        if mod_n is None or obs_n is None:
            return self.last_drift
        drift = max(abs(o - m) / max(m, _EPS)
                    for o, m in zip(obs_n, mod_n))
        self.last_drift = drift
        if drift > self.policy.drift_threshold:
            self._consec += 1
        else:
            self._consec = 0
        return drift

    @property
    def triggered(self) -> bool:
        return self._consec >= self.policy.hysteresis


class SelfHealingController:
    """The closed loop over a live :class:`PipelinedModelServer`.

    ``spec`` shapes every replan (the incumbent's stage/budget shape is
    kept — self-healing re-*cuts*, it does not re-*size*; that is
    ``runtime.ft.ElasticPlanner``'s job).  ``stage_fn_builder`` rebuilds
    stage callables for a candidate plan.  ``canary_payloads`` are the
    held-aside validation requests — they ride the *candidate* executor
    only, never the serving stream.

    States: ``steady`` (observing) -> ``cooldown`` (just decided;
    suppressing) -> ``backoff`` (canary failed; waiting) -> ``degraded``
    (retries exhausted; serving the incumbent, re-arms when drift
    subsides).  Inspect :attr:`events` / :attr:`state` for the history.
    """

    def __init__(self, server, spec, graph,
                 stage_fn_builder: Callable[[PlacementPlan],
                                            List[Callable[[Any], Any]]],
                 policy: Optional[DriftPolicy] = None,
                 canary_payloads: Sequence[Any] = (),
                 poll_interval_s: float = 0.25,
                 tpu_model=None, base_spec=None,
                 trace_builder: Optional[LiveTraceBuilder] = None):
        if graph is None:
            raise ValueError("SelfHealingController needs the live "
                             "LayerGraph (replans re-price it)")
        self.server = server
        self.spec = spec
        self.graph = graph
        self.builder = stage_fn_builder
        self.policy = policy or DriftPolicy()
        self.canary_payloads = list(canary_payloads)
        self.poll_interval_s = poll_interval_s
        self._tpu_model = tpu_model
        self._base_spec = base_spec
        self.trace = (trace_builder if trace_builder is not None
                      else LiveTraceBuilder(graph,
                                            alpha=self.policy.live_alpha))
        self.detector = DriftDetector(self.policy)
        self.state = "steady"
        self.prior: Optional[Tuple[PlacementPlan, List[Callable]]] = None
        self.events: List[Dict[str, Any]] = []
        self.windows = 0
        self.replans = 0
        self.commits = 0
        self.rollbacks = 0
        self._cooldown = 0
        self._backoff = 0
        self._retries = 0
        self._rng = random.Random(self.policy.backoff_seed)
        self._thread: Optional[threading.Thread] = None
        self._stop = threading.Event()

    # -- lifecycle -----------------------------------------------------------
    def start(self) -> "SelfHealingController":
        if self._thread is not None:
            return self
        self._stop.clear()

        def loop():
            while not self._stop.wait(self.poll_interval_s):
                try:
                    self.tick()
                except Exception as e:      # the loop must outlive a bad
                    self._event("error", error=repr(e))   # window
        self._thread = threading.Thread(target=loop, daemon=True,
                                        name="selfheal")
        self._thread.start()
        return self

    def stop(self) -> None:
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=10)
            self._thread = None

    def __enter__(self) -> "SelfHealingController":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    # -- the loop ------------------------------------------------------------
    def _event(self, kind: str, **fields) -> None:
        self.events.append({"window": self.windows, "kind": kind,
                            "state": self.state, **fields})

    def _modeled_stage_times(self, plan: PlacementPlan
                             ) -> Optional[List[float]]:
        ts = plan.stage_times_s
        if any(t is None for t in ts):
            return None
        return [float(t) for t in ts]

    def tick(self) -> Optional[float]:
        """One control window: snapshot -> refit -> detect -> (maybe)
        guarded replan.  Returns the window's drift metric, or None when
        the window carried no telemetry signal.  Synchronous and
        deterministic given the snapshot stream — tests drive this
        directly."""
        snap = self.server.snapshot()
        plan = self.server.plan
        ranges = [tuple(r) for r in plan.stage_depth_ranges]
        per_item = snap.get("stage_time_per_req_s")
        items = snap.get("stage_items")
        if per_item is None or items is None:
            return None
        self.windows += 1
        if sum(items) < self.policy.min_window_requests * len(items):
            return None
        self.trace.observe(ranges, per_item, items)
        modeled = self._modeled_stage_times(plan)
        if modeled is None:
            return None
        drift = self.detector.observe(modeled, per_item)
        if self.state == "degraded":
            # serving the incumbent; re-arm only once drift subsides (a
            # calm window means the world stopped shifting under us)
            if drift <= self.policy.drift_threshold:
                self._retries = 0
                self.state = "steady"
                self._event("rearmed", drift=drift)
            return drift
        if self._backoff > 0:
            self._backoff -= 1
            return drift
        if self._cooldown > 0:
            self._cooldown -= 1
            return drift
        if self.state == "cooldown":
            self.state = "steady"
        if self.detector.triggered:
            self._attempt_replan(drift)
        return drift

    # -- guarded reconfigure -------------------------------------------------
    def _attempt_replan(self, drift: float) -> None:
        from ..api.deploy import plan as plan_fn
        self.replans += 1
        kind = self.policy.live_source
        if kind == "auto":
            kind = ("trace" if self.trace.coverage() >= 0.999
                    else "calibrated")
        live_src = self.trace.cost_source(kind)
        incumbent = self.server.plan
        shaped = self.spec.with_stages(incumbent.n_devices)
        strat = self.policy.replan_strategy
        if (strat and shaped.resolved_topology() is None
                and shaped.strategy != strat):
            # params-balancing strategies are blind to live costs; replan
            # through a time-balancing one (objective cleared: it was
            # declared against the original strategy)
            shaped = dataclasses.replace(shaped, strategy=strat,
                                         objective=None)
        try:
            candidate = plan_fn(shaped, graph=self.graph,
                                tpu_model=self._tpu_model,
                                base_spec=self._base_spec,
                                cost_source=live_src,
                                attach_report=False)
        except Exception as e:
            self._event("replan_failed", drift=drift, error=repr(e))
            self._canary_failed(drift)
            return
        if (candidate.cuts == incumbent.cuts
                and candidate.replica_counts == incumbent.replica_counts):
            # the live-trace-priced planner endorses the incumbent: the
            # drift is real but no better cuts exist — stand down
            self._event("noop", drift=drift,
                        coverage=self.trace.coverage())
            self.detector.rebase()
            self.state = "cooldown"
            self._cooldown = self.policy.cooldown_windows
            return
        ok, observed_bottleneck, canary_bottleneck, err = (
            self._canary_validate(candidate))
        if ok:
            self._commit(candidate, drift, observed_bottleneck,
                         canary_bottleneck)
        else:
            self._event("rollback", drift=drift, error=err,
                        incumbent_bottleneck_s=observed_bottleneck,
                        canary_bottleneck_s=canary_bottleneck,
                        retries=self._retries + 1)
            self.rollbacks += 1
            self._canary_failed(drift)

    def _canary_validate(self, candidate: PlacementPlan
                         ) -> Tuple[bool, float, Optional[float],
                                    Optional[str]]:
        """Run the held-aside canaries through a freshly-built candidate
        executor (the incumbent keeps serving — it is the warm rollback
        target by construction).  Pass iff the candidate's observed
        bottleneck per-item stage time beats the incumbent's observed
        bottleneck x ``canary_margin``."""
        observed = self.detector._ewma or []
        incumbent_bottleneck = max(observed) if observed else float("inf")
        payloads = (self.canary_payloads
                    [:max(1, self.policy.canary_requests)])
        if not payloads:
            return False, incumbent_bottleneck, None, "no canary payloads"
        try:
            fns = self.builder(candidate)
            ex = PipelineExecutor.for_plan(candidate, fns,
                                           name_prefix="canary")
            with ex:
                _, busy = ex.run_batch(payloads,
                                       collect_stage_times=True)
        except Exception as e:
            return False, incumbent_bottleneck, None, repr(e)
        per_item = [b / len(payloads) for b in busy]
        canary_bottleneck = max(per_item) if per_item else float("inf")
        ok = (canary_bottleneck
              <= incumbent_bottleneck * self.policy.canary_margin)
        return ok, incumbent_bottleneck, canary_bottleneck, None

    def _commit(self, candidate: PlacementPlan, drift: float,
                incumbent_bottleneck: float,
                canary_bottleneck: Optional[float]) -> None:
        # the prior plan + stage fns stay warm: a caller (or a future
        # regression guard) can swap back without replanning
        self.prior = (self.server.plan, list(self.server.stage_fns))
        fns = self.builder(candidate)
        self.server.reconfigure(candidate, fns)
        self.commits += 1
        self._retries = 0
        self.detector.rebase()
        self.state = "cooldown"
        self._cooldown = self.policy.cooldown_windows
        self._event("commit", drift=drift,
                    cuts=list(candidate.cuts),
                    replicas=list(candidate.replica_counts),
                    incumbent_bottleneck_s=incumbent_bottleneck,
                    canary_bottleneck_s=canary_bottleneck,
                    coverage=self.trace.coverage())

    def _canary_failed(self, drift: float) -> None:
        self._retries += 1
        if self._retries > self.policy.max_canary_retries:
            self.state = "degraded"
            self._event("degraded", drift=drift, retries=self._retries)
            return
        base = min(self.policy.backoff_max_windows,
                   self.policy.backoff_base_windows
                   * (2 ** (self._retries - 1)))
        # seeded jitter (0..1 extra windows): deterministic, but spreads
        # concurrent controllers that share a seed-free default
        self._backoff = base + self._rng.randrange(0, 2)
        self.state = "backoff"
        self.detector.rebase()

    def rollback_last(self) -> bool:
        """Swap back to the pre-commit plan + stage fns kept warm by the
        last commit (manual escape hatch).  Returns False when there is
        nothing to roll back to."""
        if self.prior is None:
            return False
        plan, fns = self.prior
        self.server.reconfigure(plan, fns)
        self.prior = None
        self.rollbacks += 1
        self.detector.rebase()
        self.state = "cooldown"
        self._cooldown = self.policy.cooldown_windows
        self._event("manual_rollback", cuts=list(plan.cuts))
        return True
