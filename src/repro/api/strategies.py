"""Pluggable plan-strategy registry: one name, one ``PlanStrategy``.

The Edge TPU evaluation the paper builds on (PAPERS.md, arXiv 2102.10423)
makes the case directly: the best segmentation policy is model- and
topology-dependent, so the policy must be *pluggable* — a registry entry,
not a hand-picked function import.  Every split/plan path the repo grew in
PRs 1-3 is registered here behind one call
(:func:`repro.api.plan`):

==================== ====================================================
name                 policy
==================== ====================================================
``comp``             SEGM_COMP — layer-count balanced (vendor model)
``prof``             SEGM_PROF — exhaustive search over the modeled
                     pipeline batch time (shallow models only)
``balanced``         SEGM_BALANCED — Algorithm 1 params split + §6.1.3
                     refinement (the paper's headline)
``balanced_norefine`` Algorithm 1 split only
``balanced_cost``    Algorithm 1 over modeled per-depth *time*, refined
``opt``              time-balanced minimax DP over modeled stage time,
                     never worse than ``balanced`` on max stage time
``placement``        joint cuts + replica-count DP over a device
                     topology (alias ``opt_placement``)
``balanced_placement`` params split + per-stage-device-limit refinement
                     over a topology, no replication search
==================== ====================================================

§6.1.3 refinement is a *composable post-pass*: each strategy declares a
default (``balanced`` refines, ``comp`` does not), and
``DeploymentSpec.refine`` overrides it either way.  With the default
tri-state (``None``) every strategy reproduces its legacy entry point
bit-for-bit — asserted over all 21 Table-1 models in
tests/test_deploy_api.py.

Registering a new policy::

    @register_strategy("my_policy")
    class MyStrategy(PlanStrategy):
        objective = "min_max_stage_time"
        def plan(self, ctx):
            cuts = my_split(ctx.graph, ctx.n_stages())
            return self.finish(ctx, cuts, model=ctx.model())
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Tuple, Type

from ..core.edge_tpu_model import EdgeTPUModel, EdgeTPUSpec
from ..core.graph import LayerGraph
from ..core.placement import PlacementPlan
from ..core.refine import (GraphReporter, MemoryReporter, RefinementResult,
                           refine_cuts)
from ..core.segmentation import (balanced_split, comp_split,
                                 minimax_time_split, placement_split,
                                 prof_split)
from ..core.topology import Topology, TopologyCostModel
from .spec import DeploymentSpec


@dataclasses.dataclass
class PlanContext:
    """Everything a strategy may need at plan time: the declarative spec
    plus the runtime objects that cannot live in a JSON document (a
    prebuilt graph, a calibrated device model, a compiler-backed memory
    reporter)."""

    spec: DeploymentSpec
    graph: LayerGraph
    tpu_model: Optional[EdgeTPUModel] = None
    reporter: Optional[MemoryReporter] = None
    base_spec: Optional[EdgeTPUSpec] = None
    _model: Optional[EdgeTPUModel] = dataclasses.field(
        default=None, repr=False)
    _cost_source: Optional[object] = dataclasses.field(
        default=None, repr=False)
    _cost_source_resolved: bool = dataclasses.field(
        default=False, repr=False)

    def cost_source(self):
        """The spec's resolved :class:`~repro.profiling.sources.CostSource`
        (trace artifacts loaded once per context), or None for the
        built-in analytic path — passing None instead of an
        AnalyticCostSource keeps the engine's default construction
        byte-for-byte what it always was."""
        if not self._cost_source_resolved:
            from ..profiling.sources import resolve_cost_source
            if self.spec.cost_source != "analytic":
                self._cost_source = resolve_cost_source(
                    self.spec.cost_source,
                    reference_spec=self.base_spec)
            self._cost_source_resolved = True
        return self._cost_source

    def trace(self):
        """The ProfileTrace behind a trace-backed cost source (for the
        plan report's modeled-vs-measured columns), or None."""
        src = self.cost_source()
        return getattr(src, "trace", None)

    def device_base_spec(self) -> Optional[EdgeTPUSpec]:
        """Per-device constants with the spec's memory headroom applied.
        ``None`` (the default) keeps pricing bit-identical to the legacy
        paths — no spec object is even constructed."""
        base = self.base_spec
        headroom = self.spec.memory_headroom_bytes
        if headroom:
            base = base or EdgeTPUSpec()
            remaining = base.onchip_bytes - headroom
            if remaining <= 0:
                raise ValueError(
                    f"memory_headroom_bytes={headroom} consumes the whole "
                    f"on-chip capacity ({base.onchip_bytes} bytes) — every "
                    f"plan would spill; lower the headroom")
            base = dataclasses.replace(base, onchip_bytes=remaining)
        return base

    def model(self) -> EdgeTPUModel:
        """The device model strategies price against (explicit override
        wins — it may carry its own cost source; otherwise built once per
        context around the spec's cost source)."""
        if self.tpu_model is not None:
            return self.tpu_model
        if self._model is None:
            self._model = EdgeTPUModel(self.graph, self.device_base_spec(),
                                       cost_source=self.cost_source())
        return self._model

    def n_stages(self) -> int:
        """Spec stage count, or the paper's §5.2.2 auto rule (smallest
        count whose refined balanced plan avoids host memory)."""
        if self.spec.stages is not None:
            return self.spec.stages
        from ..core.placement import min_stages_no_spill
        return min_stages_no_spill(self.graph, self.model())

    def topology(self) -> Topology:
        topo = self.spec.resolved_topology()
        if topo is None:
            raise ValueError(
                f"strategy {self.spec.strategy!r} plans over a device "
                f"topology; set DeploymentSpec.topology or device_budget")
        return topo

    def child(self, strategy: str, n_stages: int,
              tpu_model: Optional[EdgeTPUModel] = None) -> "PlanContext":
        """Context for an internal sub-plan (e.g. ``opt``'s balanced
        baseline, or a placement strategy's homogeneous delegation)."""
        spec = dataclasses.replace(self.spec, strategy=strategy,
                                   stages=n_stages, topology=None,
                                   device_budget=None)
        return PlanContext(spec=spec, graph=self.graph,
                           tpu_model=tpu_model or self.tpu_model,
                           reporter=self.reporter,
                           base_spec=self.base_spec,
                           # share the resolved source: the child must not
                           # re-read the trace artifact from disk
                           _cost_source=self._cost_source,
                           _cost_source_resolved=self._cost_source_resolved)


class PlanStrategy:
    """One planning policy.  Subclass, set the class attributes, implement
    :meth:`plan`, and register with :func:`register_strategy`."""

    name: str = ""                      # filled in by register_strategy
    objective: str = "min_max_stage_time"
    default_refine: bool = False
    needs_topology: bool = False

    def plan(self, ctx: PlanContext) -> PlacementPlan:
        raise NotImplementedError

    # -- shared machinery ---------------------------------------------------
    def want_refine(self, ctx: PlanContext) -> bool:
        refine = ctx.spec.refine
        return self.default_refine if refine is None else refine

    def refine_pass(self, ctx: PlanContext, cuts: List[int],
                    model: Optional[EdgeTPUModel]
                    ) -> Tuple[List[int], Optional[EdgeTPUModel],
                               RefinementResult]:
        """§6.1.3 refinement as a post-pass: nudge cuts until no segment
        spills; keep the unrefined optimum if the refiner cannot converge
        (spill is unavoidable at this stage count)."""
        reporter = ctx.reporter
        if reporter is None:
            model = model or ctx.model()
            reporter = GraphReporter(model)
        refinement = refine_cuts(cuts, ctx.graph.depth, reporter)
        if refinement.converged:
            cuts = refinement.cuts
        return cuts, model, refinement

    def finish(self, ctx: PlanContext, cuts: List[int],
               model: Optional[EdgeTPUModel] = None,
               refinement: Optional[RefinementResult] = None,
               name: Optional[str] = None) -> PlacementPlan:
        if refinement is None and self.want_refine(ctx):
            cuts, model, refinement = self.refine_pass(ctx, cuts, model)
        return PlacementPlan.from_cuts(
            ctx.graph, cuts, strategy=name or self.name,
            tpu_model=model or ctx.tpu_model, refinement=refinement)


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------
_REGISTRY: Dict[str, PlanStrategy] = {}
_ALIASES: Dict[str, str] = {}


def register_strategy(name: str, *, aliases: Tuple[str, ...] = ()
                      ) -> Callable[[Type[PlanStrategy]],
                                    Type[PlanStrategy]]:
    """Class decorator: instantiate and register a strategy under ``name``
    (plus ``aliases``).  Re-registering a name replaces it — downstream
    code may override a built-in policy."""

    def deco(cls: Type[PlanStrategy]) -> Type[PlanStrategy]:
        inst = cls()
        inst.name = name
        _REGISTRY[name] = inst
        for alias in aliases:
            _ALIASES[alias] = name
        return cls

    return deco


def get_strategy(name: str) -> PlanStrategy:
    key = _ALIASES.get(name, name)
    try:
        return _REGISTRY[key]
    except KeyError:
        raise ValueError(f"unknown strategy {name!r}; pick from "
                         f"{available_strategies()}") from None


def available_strategies() -> Tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


# ---------------------------------------------------------------------------
# the paper's strategies (+ the beyond-paper ones from PRs 1-2)
# ---------------------------------------------------------------------------
@register_strategy("comp")
class CompStrategy(PlanStrategy):
    """SEGM_COMP: balance the layer-*count* proxy (vendor model)."""

    objective = "balance_params"

    def plan(self, ctx: PlanContext) -> PlacementPlan:
        cuts = comp_split(ctx.graph.params_per_depth(), ctx.n_stages())
        return self.finish(ctx, cuts)


@register_strategy("prof")
class ProfStrategy(PlanStrategy):
    """SEGM_PROF: exhaustive search over modeled pipeline batch time —
    C(d-1, s-1) candidates, shallow models only (the paper's point)."""

    objective = "min_pipeline_batch_time"

    def plan(self, ctx: PlanContext) -> PlacementPlan:
        model = ctx.model()
        cuts = prof_split(ctx.graph.params_per_depth(), ctx.n_stages(),
                          model.prof_cost(batch=ctx.spec.prof_batch))
        return self.finish(ctx, cuts, model=model)


@register_strategy("balanced")
class BalancedStrategy(PlanStrategy):
    """SEGM_BALANCED: Algorithm 1 params split + §6.1.3 refinement."""

    objective = "balance_params"
    default_refine = True

    def plan(self, ctx: PlanContext) -> PlacementPlan:
        cuts = balanced_split(ctx.graph.params_per_depth(), ctx.n_stages())
        return self.finish(ctx, cuts)


@register_strategy("balanced_norefine")
class BalancedNoRefineStrategy(BalancedStrategy):
    """SEGM_BALANCED step 2 only (Algorithm 1, no refinement)."""

    default_refine = False


@register_strategy("balanced_cost")
class BalancedCostStrategy(PlanStrategy):
    """Algorithm 1 over modeled per-depth *time* (MAC + weight-load
    terms — or the cost source's measured per-depth times) instead of raw
    params, then §6.1.3 refinement — fixes residual imbalance on archs
    whose MAC intensity varies with depth."""

    objective = "balance_modeled_time"
    default_refine = True

    def plan(self, ctx: PlanContext) -> PlacementPlan:
        model = ctx.model()
        # integer per-depth cost in nanoseconds (the engine keeps this
        # strategy's historical analytic expression bit-for-bit; a
        # trace-backed source substitutes its measured times)
        C = model.engine.depth_cost_ns()
        cuts = balanced_split(C, ctx.n_stages())
        return self.finish(ctx, cuts, model=model)


@register_strategy("opt")
class OptStrategy(PlanStrategy):
    """Time-balanced minimax DP over modeled stage time, with a hard
    guarantee: never worse than ``balanced`` on the max modeled stage time
    (falls back to the balanced cuts if the DP does not improve)."""

    objective = "min_max_stage_time"

    def plan(self, ctx: PlanContext) -> PlacementPlan:
        model = ctx.model()
        s = ctx.n_stages()
        cuts = minimax_time_split(ctx.graph.depth, s, model.segment_time)
        refinement = None
        base = get_strategy("balanced").plan(
            ctx.child("balanced", s, tpu_model=model))
        if max(model.stage_times(base.cuts)) < max(model.stage_times(cuts)):
            cuts = base.cuts
            refinement = base.refinement
        elif self.want_refine(ctx):      # explicit refine=True on DP cuts
            cuts, model, refinement = self.refine_pass(ctx, cuts, model)
        return self.finish(ctx, cuts, model=model, refinement=refinement)


@register_strategy("placement", aliases=("opt_placement",))
class PlacementStrategy(PlanStrategy):
    """Joint cuts + device-assignment + replica-count exact DP over a
    topology: a bottleneck stage pinned by a single dominant layer gets
    k-fold relief on its non-weight-load terms
    (``t_weight_load + (t - t_weight_load)/k`` pacing)."""

    objective = "min_max_stage_time"
    needs_topology = True

    def plan(self, ctx: PlanContext) -> PlacementPlan:
        topo = ctx.topology()
        n = topo.n_devices
        tcm = TopologyCostModel(ctx.graph, topo, ctx.device_base_spec(),
                                cost_source=ctx.cost_source())
        if topo.is_homogeneous and topo.devices[0].is_reference \
                and not ctx.spec.replicate:
            return get_strategy("opt").plan(
                ctx.child("opt", n, tpu_model=tcm.base_model))
        if ctx.spec.refine:
            # the joint cuts+replicas DP already fixes the replica
            # structure; a §6.1.3 cut-nudging pass cannot compose with it
            raise ValueError(
                "strategy 'placement' does not compose the refine "
                "post-pass; use strategy='balanced_placement' (per-stage "
                "device-limit refinement) or leave refine unset")
        rmax = n if ctx.spec.replicate else 1
        if ctx.spec.max_replicas is not None:
            rmax = min(rmax, max(1, ctx.spec.max_replicas))
        cuts, replicas = placement_split(ctx.graph.depth, n,
                                         tcm.placement_cost_fn(),
                                         max_replicas=rmax)
        offsets = [0]
        for r in replicas[:-1]:
            offsets.append(offsets[-1] + r)
        devices = [topo.devices[o] for o in offsets]
        return PlacementPlan.from_cuts(
            ctx.graph, cuts, strategy="opt_placement", devices=devices,
            replicas=replicas, tpu_model=tcm.base_model)


@register_strategy("balanced_placement")
class BalancedPlacementStrategy(PlanStrategy):
    """Algorithm 1 params split over a topology, refined with *per-stage*
    memory limits (each stage judged against its own device's capacity) —
    no replication search."""

    objective = "balance_params"
    default_refine = True
    needs_topology = True

    def plan(self, ctx: PlanContext) -> PlacementPlan:
        topo = ctx.topology()
        n = topo.n_devices
        tcm = TopologyCostModel(ctx.graph, topo, ctx.device_base_spec(),
                                cost_source=ctx.cost_source())
        if topo.is_homogeneous and topo.devices[0].is_reference \
                and not ctx.spec.replicate:
            return get_strategy("balanced").plan(
                ctx.child("balanced", n, tpu_model=tcm.base_model))
        cuts = balanced_split(ctx.graph.params_per_depth(), n)
        refinement = None
        if self.want_refine(ctx):
            reporters = tcm.stage_reporters(topo.devices[:n])
            refinement = refine_cuts(cuts, ctx.graph.depth,
                                     stage_reporters=reporters)
            if refinement.converged:
                cuts = refinement.cuts
        return PlacementPlan.from_cuts(
            ctx.graph, cuts, strategy="balanced_placement",
            devices=list(topo.devices[:len(cuts) + 1]),
            tpu_model=tcm.base_model, refinement=refinement)
