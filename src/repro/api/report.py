"""PlanReport: what a plan will do, before anything runs.

Every plan the front door produces carries one — modeled stage times
(raw and replication-amortized), the pacing bottleneck, params/time
imbalance, per-stage device memory (on-device bytes, host spill,
capacity), and *which cost source priced it*.  When the plan came from a
trace-backed source the report also records the measured per-stage
compute times and the modeled-vs-trace stage-time error — the number the
calibration loop (EXPERIMENTS.md §Profiling & calibration) watches.  It
is the decision record a deployment pipeline logs next to the plan it
shipped, and it is JSON-round-trippable like the spec.

Degenerate plans yield *neutral* records instead of raising: a 1-stage
plan reports zero imbalance, an empty plan reports all-zero fields
(regression-tested in tests/test_deploy_api.py).
"""
from __future__ import annotations

import dataclasses
import json
from typing import Dict, Optional, Tuple

from ..core.edge_tpu_model import EdgeTPUModel, EdgeTPUSpec
from ..core.graph import LayerGraph
from ..core.placement import PlacementPlan

REPORT_FORMAT = "repro.plan_report/v1"


@dataclasses.dataclass(frozen=True)
class PlanReport:
    """Modeled properties of one :class:`PlacementPlan`."""

    graph_name: str
    strategy: str
    n_stages: int
    n_devices: int
    # time (seconds; 0.0 where the plan carries no modeled time)
    stage_times_s: Tuple[float, ...] = ()
    effective_stage_times_s: Tuple[float, ...] = ()
    max_stage_time_s: float = 0.0
    bottleneck_stage: int = -1          # -1: no timed stages
    imbalance_time_pct: float = 0.0     # (max - min) / max over pacing times
    # size
    stage_params: Tuple[int, ...] = ()
    imbalance_params: int = 0           # paper Table 5's Δs
    # memory (bytes; empty when no graph was available to price against)
    stage_device_bytes: Tuple[int, ...] = ()
    stage_host_bytes: Tuple[int, ...] = ()
    stage_capacity_bytes: Tuple[int, ...] = ()
    spill_bytes: int = 0                # total host overflow across stages
    # placement
    devices: Tuple[str, ...] = ()
    replicas: Tuple[int, ...] = ()
    # provenance: which cost source priced the plan, and how the modeled
    # stage times compare against the trace when one is available
    cost_source: str = "analytic"
    trace_stage_times_s: Tuple[float, ...] = ()
    stage_time_error_pct: float = -1.0    # -1: no trace to compare against
    # decode operating point (workload="decode" plans only; see
    # repro.decode.placement.decode_info)
    decode_tokens_per_s: float = 0.0
    decode_concurrency: int = 0           # 0: not a decode plan
    decode_max_context: int = 0
    stage_kv_bytes: Tuple[int, ...] = ()
    stage_kv_cap_bytes: Tuple[int, ...] = ()
    kv_headroom_pct: float = -1.0         # min over stages; -1: no KV view

    @property
    def spills(self) -> bool:
        return self.spill_bytes > 0

    @property
    def has_trace(self) -> bool:
        return self.stage_time_error_pct >= 0.0

    @property
    def is_decode(self) -> bool:
        return self.decode_concurrency > 0

    @classmethod
    def from_plan(cls, plan: PlacementPlan,
                  graph: Optional[LayerGraph] = None,
                  base_spec: Optional[EdgeTPUSpec] = None,
                  base_model: Optional[EdgeTPUModel] = None,
                  cost_source: str = "analytic",
                  trace=None, decode: Optional[Dict] = None) -> "PlanReport":
        """Price a plan.  ``base_model`` (preferred — the device model the
        planner itself priced with, so the report cannot contradict the
        plan) or ``graph`` [+ ``base_spec``] enables the per-stage memory
        columns; without either the report still carries the time/size
        view the plan itself knows.  ``trace`` (a
        :class:`~repro.profiling.trace.ProfileTrace` covering the plan's
        depths) enables the measured-stage-time column and the
        modeled-vs-trace error.  ``decode`` (the plan's ``decode_info``
        dict, from the decode_placement strategy) fills the decode
        operating-point columns."""
        stages = plan.stages
        times = tuple(0.0 if s.time_s is None else s.time_s for s in stages)
        eff = tuple(0.0 if t is None else t
                    for t in plan.effective_stage_times_s)
        timed = [(i, t) for i, t in enumerate(eff) if t > 0.0]
        if timed:
            bottleneck, max_t = max(timed, key=lambda it: it[1])
            min_t = min(t for _, t in timed)
            imb_pct = ((max_t - min_t) / max_t * 100.0
                       if len(timed) > 1 and max_t > 0 else 0.0)
        else:
            bottleneck, max_t, imb_pct = -1, 0.0, 0.0
        params = tuple(s.params for s in stages)
        imb_params = (max(params) - min(params)) if len(params) > 1 else 0

        dev_bytes: Tuple[int, ...] = ()
        host_bytes: Tuple[int, ...] = ()
        cap_bytes: Tuple[int, ...] = ()
        if base_model is None and graph is not None:
            base_model = EdgeTPUModel(graph, base_spec)
        if base_model is not None and stages:
            dev_list, host_list, cap_list = [], [], []
            for st in stages:
                spec = st.device.specialize(base_model.spec)
                eng = (base_model.engine if spec is base_model.spec
                       else base_model.engine.with_spec(spec))
                d, h = eng.segment_split(st.depth_lo, st.depth_hi)
                dev_list.append(d)
                host_list.append(h)
                cap_list.append(spec.onchip_bytes)
            dev_bytes = tuple(dev_list)
            host_bytes = tuple(host_list)
            cap_bytes = tuple(cap_list)

        trace_times: Tuple[float, ...] = ()
        err_pct = -1.0
        if trace is not None and stages:
            measured = trace.stage_times([(s.depth_lo, s.depth_hi)
                                          for s in stages])
            if measured is not None:
                trace_times = tuple(measured)
                rel = [abs(m - t) / t
                       for m, t in zip(times, trace_times) if t > 0.0]
                err_pct = (sum(rel) / len(rel) * 100.0) if rel else -1.0

        return cls(
            graph_name=plan.graph_name, strategy=plan.strategy,
            n_stages=plan.n_stages, n_devices=plan.n_devices,
            stage_times_s=times, effective_stage_times_s=eff,
            max_stage_time_s=max_t, bottleneck_stage=bottleneck,
            imbalance_time_pct=imb_pct,
            stage_params=params, imbalance_params=imb_params,
            stage_device_bytes=dev_bytes, stage_host_bytes=host_bytes,
            stage_capacity_bytes=cap_bytes, spill_bytes=sum(host_bytes),
            devices=tuple(s.device.name for s in stages),
            replicas=tuple(s.replicas for s in stages),
            cost_source=cost_source, trace_stage_times_s=trace_times,
            stage_time_error_pct=err_pct,
            **({k: (tuple(v) if isinstance(v, (list, tuple)) else v)
                for k, v in decode.items()} if decode else {}))

    def describe(self) -> str:
        """One-line report summary for logs."""
        head = (f"{self.graph_name} / {self.strategy} x{self.n_stages}"
                + (f" ({self.n_devices} devs)"
                   if self.n_devices != self.n_stages else ""))
        if self.bottleneck_stage < 0:
            return f"{head}: no modeled times"
        mib = self.spill_bytes / (1024 * 1024)
        line = (f"{head}: pacing S{self.bottleneck_stage}"
                f"={self.max_stage_time_s*1e3:.3f} ms, time imbalance "
                f"{self.imbalance_time_pct:.1f}%, "
                f"Δs={self.imbalance_params/1e6:.2f}M, "
                f"spill {mib:.2f} MiB")
        if self.cost_source != "analytic":
            line += f" [{self.cost_source}]"
        if self.has_trace:
            line += f" (vs trace: {self.stage_time_error_pct:.1f}% err)"
        if self.is_decode:
            line += (f" | decode {self.decode_tokens_per_s:.1f} tok/s "
                     f"@ c={self.decode_concurrency}"
                     f"/ctx={self.decode_max_context}, KV headroom "
                     f"{self.kv_headroom_pct:.0f}%")
        return line

    # -- (de)serialization ---------------------------------------------------
    def to_dict(self) -> Dict:
        doc = dataclasses.asdict(self)
        doc["format"] = REPORT_FORMAT
        for key, val in list(doc.items()):
            if isinstance(val, tuple):
                doc[key] = list(val)
        return doc

    @classmethod
    def from_dict(cls, doc: Dict) -> "PlanReport":
        doc = dict(doc)
        fmt = doc.pop("format", REPORT_FORMAT)
        if fmt != REPORT_FORMAT:
            raise ValueError(f"not a plan report document: {fmt!r}")
        for f in dataclasses.fields(cls):
            if f.name in doc and isinstance(doc[f.name], list):
                doc[f.name] = tuple(doc[f.name])
        return cls(**doc)

    def to_json(self, indent: Optional[int] = None) -> str:
        return json.dumps(self.to_dict(), indent=indent)

    @classmethod
    def from_json(cls, text: str) -> "PlanReport":
        return cls.from_dict(json.loads(text))
