"""Declarative deployment description: the input to the one front door.

A :class:`DeploymentSpec` says *what* to deploy — which model graph, over
which devices, optimized how, under which constraints and serving policy —
without naming any of the machinery that does it.  ``repro.api.plan`` turns
a spec into a :class:`~repro.core.placement.PlacementPlan`;
``repro.api.deploy`` turns it into a live :class:`~repro.api.deploy.Deployment`.
DistrEdge (PAPERS.md, arXiv 2202.01699) frames multi-device CNN serving as
exactly this: one placement decision over a declarative description of
devices + model, not a hand-wired call sequence.

Specs are frozen (hashable, safe as cache keys — ``ElasticPlanner`` keys
its replan cache on them) and JSON-round-trippable (ship a deployment to a
fleet as a document; ``from_json(to_json(spec)) == spec`` exactly, floats
included).  Live Python objects (a prebuilt ``LayerGraph``, an
``EdgeTPUModel``) are *not* part of the spec: they are runtime overrides
passed alongside it to ``plan``/``deploy``.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Dict, Optional, Tuple

from ..core.graph import LayerGraph
from ..core.topology import DeviceSpec, Topology

SPEC_FORMAT = "repro.deployment_spec/v1"


@dataclasses.dataclass(frozen=True)
class DeploymentSpec:
    """What to deploy, declaratively.

    Model / devices
    ---------------
    * ``model`` — graph reference resolvable without live objects:
      ``"cnn:<Name>"`` (a Table-1 model from ``repro.models.cnn.REAL_CNNS``),
      ``"synthetic-cnn:<f>"`` (``synthetic_cnn(f)``), or
      ``"lm:<arch>[:seq=<n>]"`` (an LM smoke config's layer graph).  May be
      ``None`` when a live graph is passed to ``plan``/``deploy`` directly.
    * ``stages`` — pipeline stage count for homogeneous planning.  ``None``
      with no topology means *auto*: the paper's §5.2.2 rule (smallest
      count whose refined balanced plan avoids host memory).
    * ``topology`` / ``device_budget`` — heterogeneous device chain, or the
      homogeneous shorthand ``Topology.homogeneous(device_budget)``.  Used
      by the placement strategies; mutually exclusive.

    Objective / constraints
    -----------------------
    * ``strategy`` — a name in the strategy registry
      (:func:`repro.api.available_strategies`).
    * ``objective`` — optional declared objective; validated against the
      chosen strategy's objective at plan time (catches "I asked for
      time balance but picked a params-balancing strategy" early).
    * ``refine`` — tri-state §6.1.3 refinement post-pass: ``None`` keeps
      the strategy's default, ``True``/``False`` forces it on/off (a
      strategy that cannot compose it — the joint ``placement`` DP —
      rejects ``True`` with a ValueError rather than ignoring it).
    * ``replicate`` / ``max_replicas`` — whether placement strategies may
      replicate a bottleneck stage across identical devices, and a cap.
    * ``memory_headroom_bytes`` — plan as if each device had this much
      less on-chip memory (deployment safety margin for runtime buffers).
    * ``prof_batch`` — batch size priced by the SEGM_PROF objective.
    * ``cost_source`` — where per-depth costs come from (the paper's
      plans are *profile-based*; see repro.profiling): ``"analytic"``
      (default: the closed-form device model, bit-identical to previous
      releases), ``"trace:<path>"`` (plan from a persisted
      :class:`~repro.profiling.trace.ProfileTrace`), or
      ``"calibrated:<path>"`` (the analytic model least-squares-fit to
      that trace).  Validated at construction; the trace file itself is
      read at plan time.

    Serving policy (consumed by :class:`~repro.api.deploy.Deployment`)
    ------------------------------------------------------------------
    ``max_batch`` / ``max_wait_s`` (admission micro-batching),
    ``queue_size`` (inter-stage backpressure), ``microbatch`` /
    ``microbatch_wait_s`` (stage-level shape-bucketed dynamic
    micro-batching).

    ``backend`` — which execution tier ``Deployment.executor()`` builds:
    ``"host"`` (default; the threaded
    :class:`~repro.core.pipeline.PipelineExecutor`, one worker per stage
    with queues between) or ``"spmd"`` (the
    :class:`~repro.launch.pipeline_spmd.SpmdPipelineExecutor`:
    shard_map/ppermute pipeline over a device mesh with overlapped weight
    streaming; needs one device per stage and an unreplicated plan —
    replicated plans fall back to the host executor with a logged
    notice).

    Fault policy (also serving-side): ``hedge_after`` — seconds before a
    straggling item on a replicated stage is speculatively re-dispatched
    to another replica (first result wins via the merge's dedup; ``None``
    — the default — disables hedging); ``stage_loss_retries`` — how many
    times a request that failed with ``StageLost`` (a whole stage died)
    is re-admitted, so it survives a degraded-mode replan (0 disables).

    Overload / self-healing policy (see EXPERIMENTS.md §Self-healing
    serving): ``deadline_ms`` — default per-request latency budget; a
    request past it completes with
    :class:`~repro.serving.server.DeadlineExceeded` at admission or merge
    exit instead of waiting unbounded (``None`` disables).  ``shed_policy``
    — ``"deadline"`` enables admission control: requests whose estimated
    queue delay outlives the deadline budget are shed with
    :class:`~repro.serving.server.Overloaded` + a jittered-backoff
    ``retry_after_s`` hint (``"none"`` disables).  ``drift_threshold`` —
    relative modeled-vs-observed per-stage time drift past which the
    self-healing controller (:class:`~repro.runtime.selfheal
    .SelfHealingController`) replans from live telemetry (0 disables the
    loop).  ``canary_requests`` — held-aside requests used to validate a
    candidate executor before a guarded reconfigure commits.

    Service-level objective (consumed by the fleet tier — see
    repro.fleet): ``slo_p95_ms`` — target p95 request latency; the fleet
    pool-split solver sizes this deployment's device allocation against
    it and the autoscaler treats an observed p95 past it as a violation.
    ``slo_throughput_rps`` — minimum sustained throughput the deployment
    must support (its modeled bottleneck pacing must stay under
    ``1/slo_throughput_rps``).  Both optional; a standalone deployment
    ignores them.

    Decode serving tier (see repro.decode / EXPERIMENTS.md §Decode
    serving): ``workload`` — ``"batch"`` (default; everything above) or
    ``"decode"``: steady-state autoregressive token generation.  Decode
    requires an ``lm:`` model ref, is planned at the
    ``(decode_concurrency, max_context)`` operating point (defaults in
    ``repro.decode.placement``), and ``Deployment.serve()`` returns a
    continuous-batching :class:`~repro.decode.engine.DecodeServer`
    streaming tokens instead of a request/response pipeline server.
    """

    model: Optional[str] = None
    stages: Optional[int] = None
    strategy: str = "balanced"
    objective: Optional[str] = None
    topology: Optional[Topology] = None
    device_budget: Optional[int] = None
    replicate: bool = True
    max_replicas: Optional[int] = None
    refine: Optional[bool] = None
    memory_headroom_bytes: int = 0
    prof_batch: int = 15
    cost_source: str = "analytic"
    # serving policy
    max_batch: int = 15
    max_wait_s: float = 0.02
    queue_size: int = 64
    microbatch: Optional[int] = None
    microbatch_wait_s: float = 0.0
    backend: str = "host"
    # fault policy
    hedge_after: Optional[float] = None
    stage_loss_retries: int = 0
    # overload / self-healing policy
    deadline_ms: Optional[float] = None
    shed_policy: str = "none"
    drift_threshold: float = 0.0
    canary_requests: int = 4
    # service-level objective (consumed by the fleet tier)
    slo_p95_ms: Optional[float] = None
    slo_throughput_rps: Optional[float] = None
    # decode serving tier (see repro.decode): workload="decode" plans with
    # the per-token cost regime at the (decode_concurrency, max_context)
    # operating point and serves via continuous batching
    workload: str = "batch"
    max_context: Optional[int] = None
    decode_concurrency: Optional[int] = None

    def __post_init__(self):
        if not self.strategy:
            raise ValueError("spec needs a strategy name")
        if self.stages is not None and self.stages < 1:
            raise ValueError(f"stages must be >= 1, got {self.stages}")
        if self.topology is not None and self.device_budget is not None:
            raise ValueError("topology and device_budget are mutually "
                             "exclusive (device_budget is the homogeneous "
                             "shorthand)")
        if self.device_budget is not None and self.device_budget < 1:
            raise ValueError(f"device_budget must be >= 1, "
                             f"got {self.device_budget}")
        if self.memory_headroom_bytes < 0:
            raise ValueError("memory_headroom_bytes must be >= 0")
        if self.hedge_after is not None and self.hedge_after <= 0:
            raise ValueError(f"hedge_after must be > 0, "
                             f"got {self.hedge_after}")
        if self.stage_loss_retries < 0:
            raise ValueError(f"stage_loss_retries must be >= 0, "
                             f"got {self.stage_loss_retries}")
        if self.backend not in ("host", "spmd"):
            raise ValueError(f"backend must be 'host' or 'spmd', "
                             f"got {self.backend!r}")
        if self.deadline_ms is not None and self.deadline_ms <= 0:
            raise ValueError(f"deadline_ms must be > 0 (or None), "
                             f"got {self.deadline_ms}")
        if self.shed_policy not in ("none", "deadline"):
            raise ValueError(f"shed_policy must be 'none' or 'deadline', "
                             f"got {self.shed_policy!r}")
        if self.shed_policy == "deadline" and self.deadline_ms is None:
            raise ValueError("shed_policy='deadline' needs deadline_ms "
                             "(the budget the queue-delay estimate is "
                             "compared against)")
        if self.drift_threshold < 0:
            raise ValueError(f"drift_threshold must be >= 0, "
                             f"got {self.drift_threshold}")
        if self.canary_requests < 1:
            raise ValueError(f"canary_requests must be >= 1, "
                             f"got {self.canary_requests}")
        if self.slo_p95_ms is not None and self.slo_p95_ms <= 0:
            raise ValueError(f"slo_p95_ms must be > 0 (or None), "
                             f"got {self.slo_p95_ms}")
        if (self.slo_throughput_rps is not None
                and self.slo_throughput_rps <= 0):
            raise ValueError(f"slo_throughput_rps must be > 0 (or None), "
                             f"got {self.slo_throughput_rps}")
        if self.workload not in ("batch", "decode"):
            raise ValueError(f"workload must be 'batch' or 'decode', "
                             f"got {self.workload!r}")
        if self.workload == "decode" and (
                self.model is None or not self.model.startswith("lm:")):
            raise ValueError(
                f"workload='decode' requires an 'lm:<arch>' model ref "
                f"(the decode regime is derived from the LM config); "
                f"got model={self.model!r}")
        if self.max_context is not None and self.max_context < 2:
            raise ValueError(f"max_context must be >= 2 (room for a prompt "
                             f"token and a generated token), "
                             f"got {self.max_context}")
        if self.decode_concurrency is not None and self.decode_concurrency < 1:
            raise ValueError(f"decode_concurrency must be >= 1, "
                             f"got {self.decode_concurrency}")
        from ..profiling.sources import parse_cost_source
        parse_cost_source(self.cost_source)   # raises on malformed refs

    # -- derived views -------------------------------------------------------
    def resolved_topology(self) -> Optional[Topology]:
        """The device chain the placement strategies plan over (homogeneous
        shorthand expanded), or None for plain stage-count planning."""
        if self.topology is not None:
            return self.topology
        if self.device_budget is not None:
            return Topology.homogeneous(self.device_budget)
        return None

    def with_stages(self, n: int) -> "DeploymentSpec":
        """Elastic-resize helper: the same deployment at a new device
        count (stage count for plain specs, budget for placement specs)."""
        if self.topology is not None:
            # devices leave from the tail of the chain (the pipeline order
            # is part of the topology's meaning)
            devs = self.topology.devices[:max(1, n)]
            return dataclasses.replace(
                self, topology=dataclasses.replace(self.topology,
                                                   devices=devs))
        if self.device_budget is not None:
            return dataclasses.replace(self, device_budget=max(1, n))
        return dataclasses.replace(self, stages=max(1, n))

    # -- (de)serialization ---------------------------------------------------
    def to_dict(self) -> Dict:
        doc = dataclasses.asdict(self)
        doc["format"] = SPEC_FORMAT
        if self.topology is not None:
            doc["topology"] = {
                "name": self.topology.name,
                "devices": [d.to_dict() for d in self.topology.devices],
            }
        return doc

    @classmethod
    def from_dict(cls, doc: Dict) -> "DeploymentSpec":
        doc = dict(doc)
        fmt = doc.pop("format", SPEC_FORMAT)
        if fmt != SPEC_FORMAT:
            raise ValueError(f"not a deployment spec document: {fmt!r}")
        topo = doc.get("topology")
        if topo is not None:
            doc["topology"] = Topology(
                devices=tuple(DeviceSpec.from_dict(d)
                              for d in topo["devices"]),
                name=topo.get("name", "chain"))
        return cls(**doc)

    def to_json(self, indent: Optional[int] = None) -> str:
        return json.dumps(self.to_dict(), indent=indent)

    @classmethod
    def from_json(cls, text: str) -> "DeploymentSpec":
        return cls.from_dict(json.loads(text))


# ---------------------------------------------------------------------------
# model-reference resolution
# ---------------------------------------------------------------------------
def resolve_model_graph(model: str) -> LayerGraph:
    """Materialize the graph a spec's ``model`` string names.

    ``cnn:`` and ``synthetic-cnn:`` stay import-light; ``lm:`` pulls in the
    JAX-backed config stack lazily (only deployments that ask for it pay
    for it)."""
    kind, _, rest = model.partition(":")
    if not rest:
        raise ValueError(f"malformed model ref {model!r}; expected "
                         f"'cnn:<Name>', 'synthetic-cnn:<f>' or "
                         f"'lm:<arch>[:seq=<n>]'")
    if kind == "cnn":
        from ..models.cnn import REAL_CNNS
        if rest not in REAL_CNNS:
            raise ValueError(f"unknown CNN {rest!r}; pick from "
                             f"{sorted(REAL_CNNS)}")
        return REAL_CNNS[rest]().to_layer_graph()
    if kind == "synthetic-cnn":
        from ..models.cnn import synthetic_cnn
        return synthetic_cnn(int(rest)).to_layer_graph()
    if kind == "lm":
        arch, _, opt = rest.partition(":")
        seq = 64
        if opt:
            key, _, val = opt.partition("=")
            if key != "seq":
                raise ValueError(f"unknown lm option {opt!r} in {model!r}")
            seq = int(val)
        from .. import configs
        from ..models import lm_graph
        cfg = configs.get(arch).smoke_config()
        return lm_graph.lm_layer_graph(cfg, seq_len=seq)
    raise ValueError(f"unknown model ref kind {kind!r} in {model!r}")
