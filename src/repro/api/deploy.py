"""The one front door: ``plan(spec) -> PlacementPlan`` and
``deploy(spec) -> Deployment``.

The paper's pipeline is profile → segment → refine → place → execute; after
PRs 1-3 that pipeline was exposed as ~10 loose functions whose orchestration
every consumer hand-copied.  This module is the single typed entry point:

* :func:`plan` — declarative :class:`~repro.api.spec.DeploymentSpec` in,
  :class:`~repro.core.placement.PlacementPlan` (with an attached
  :class:`~repro.api.report.PlanReport`) out, dispatched through the
  strategy registry.
* :func:`deploy` / :class:`Deployment` — the runtime handle.  It owns
  executor/server construction so callers never wire
  ``PipelineExecutor``/``PipelinedModelServer`` by hand, and its
  :meth:`Deployment.reconfigure` drives the existing hot-swap path
  (drain in-flight, replan, swap) for elastic resizes.

::

    spec = DeploymentSpec(model="cnn:ResNet50", stages=4, strategy="opt")
    pl = plan(spec)                       # planning only
    print(pl.report.describe())

    dep = deploy(spec2, graph=g, stage_fn_builder=fns_for)
    with dep.serve() as server:           # admission loop + stage workers
        ...
        dep.reconfigure(spec2.with_stages(3))   # a device left
"""
from __future__ import annotations

import dataclasses
import logging
from typing import Any, Callable, List, Optional, Sequence

from ..core.edge_tpu_model import EdgeTPUModel, EdgeTPUSpec
from ..core.graph import LayerGraph
from ..core.pipeline import PipelineExecutor
from ..core.placement import PlacementPlan
from ..core.refine import MemoryReporter
from .report import PlanReport
from .spec import DeploymentSpec, resolve_model_graph
from .strategies import PlanContext, get_strategy

StageFnBuilder = Callable[[PlacementPlan], List[Callable[[Any], Any]]]

logger = logging.getLogger(__name__)


def plan(spec: DeploymentSpec, *,
         graph: Optional[LayerGraph] = None,
         tpu_model: Optional[EdgeTPUModel] = None,
         reporter: Optional[MemoryReporter] = None,
         base_spec: Optional[EdgeTPUSpec] = None,
         cost_source: Optional[Any] = None,
         attach_report: bool = True) -> PlacementPlan:
    """Turn a declarative spec into a placement plan.

    ``graph`` overrides ``spec.model`` resolution (pass a live LayerGraph
    you already built); ``tpu_model``/``reporter``/``base_spec`` override
    the default analytical device model, the refinement memory reporter,
    and the per-device constants — runtime objects that cannot live in the
    JSON spec.  ``cost_source`` overrides ``spec.cost_source`` resolution
    with a live :class:`~repro.profiling.sources.CostSource` instance —
    the self-healing loop replans against its in-memory live trace this
    way (there is no file to point a ``trace:<path>`` ref at).  Every
    registered strategy is reachable; plans are bit-identical to the
    legacy ``repro.core.planner`` entry points for the same inputs
    (asserted over all 21 Table-1 models in tests/test_deploy_api.py)."""
    if graph is None:
        if spec.model is None:
            raise ValueError("spec has no model ref; pass plan(spec, "
                             "graph=...) or set DeploymentSpec.model")
        graph = resolve_model_graph(spec.model)
    strategy = get_strategy(spec.strategy)
    if spec.objective is not None and spec.objective != strategy.objective:
        raise ValueError(
            f"spec declares objective {spec.objective!r} but strategy "
            f"{spec.strategy!r} optimizes {strategy.objective!r}")
    if strategy.needs_topology and spec.resolved_topology() is None:
        raise ValueError(f"strategy {spec.strategy!r} plans over a device "
                         f"topology; set DeploymentSpec.topology or "
                         f"device_budget")
    ctx = PlanContext(spec=spec, graph=graph, tpu_model=tpu_model,
                      reporter=reporter, base_spec=base_spec,
                      _cost_source=cost_source,
                      _cost_source_resolved=cost_source is not None)
    pl = strategy.plan(ctx)
    if attach_report:
        # price the report with the model the planner itself used (the
        # tpu_model override included) so the report cannot contradict
        # the plan; ctx.model() reuses the context's cached instance.
        # Trace-backed cost sources also contribute the measured stage
        # times and the modeled-vs-trace error column.
        src_tag = (spec.cost_source if cost_source is None
                   else f"live:{getattr(cost_source, 'name', 'object')}")
        pl.report = PlanReport.from_plan(pl, base_model=ctx.model(),
                                         cost_source=src_tag,
                                         trace=ctx.trace(),
                                         decode=getattr(pl, "decode_info",
                                                        None))
    return pl


class Deployment:
    """A planned deployment and the runtime it owns.

    Construction is planning only — no threads, no jit.  Ask for the
    runtime explicitly:

    * :meth:`executor` — a :class:`PipelineExecutor` wired from the plan
      (replica fan-out) and the spec's serving policy (queue size,
      stage-level micro-batching).
    * :meth:`serve` — a :class:`PipelinedModelServer` over that executor
      (admission micro-batching, per-request futures, snapshot deltas).
    * :meth:`reconfigure` — replan for a new spec and hot-swap the live
      server (in-flight requests drain; queued requests are served by the
      new plan).

    Stage functions come from ``stage_fns`` (a fixed list) or
    ``stage_fn_builder`` (rebuilt per plan — required for
    :meth:`reconfigure`, which changes the stage count).
    """

    def __init__(self, spec: DeploymentSpec, plan: PlacementPlan, *,
                 graph: Optional[LayerGraph] = None,
                 stage_fn_builder: Optional[StageFnBuilder] = None,
                 stage_fns: Optional[Sequence[Callable]] = None,
                 tpu_model: Optional[EdgeTPUModel] = None,
                 reporter=None,
                 base_spec: Optional[EdgeTPUSpec] = None):
        self.spec = spec
        self.plan = plan
        self.graph = graph
        self._builder = stage_fn_builder
        self._fns = list(stage_fns) if stage_fns is not None else None
        self._server = None
        self._closed = False
        # runtime pricing overrides deploy() planned with — re-passed on
        # every reconfigure() replan so resizes price against the same
        # device model as the original plan
        self._tpu_model = tpu_model
        self._reporter = reporter
        self._base_spec = base_spec
        # resize baseline: ``reconfigure(stages=n)`` always derives from
        # this spec, not from the previous resize's output — a scale-down
        # that truncated the topology must not cap a later scale-up
        self._spec_template = spec

    @classmethod
    def from_plan(cls, plan: PlacementPlan,
                  spec: Optional[DeploymentSpec] = None, *,
                  graph: Optional[LayerGraph] = None,
                  stage_fn_builder: Optional[StageFnBuilder] = None,
                  stage_fns: Optional[Sequence[Callable]] = None,
                  tpu_model: Optional[EdgeTPUModel] = None,
                  reporter: Optional[MemoryReporter] = None,
                  base_spec: Optional[EdgeTPUSpec] = None
                  ) -> "Deployment":
        """Wrap an existing plan (shipped as JSON, hand-built, …) in a
        deployment handle.  The derived spec must keep :meth:`reconfigure`
        usable: the plan's strategy tag is adopted when it names a
        registered strategy (placement tags become a ``device_budget``
        spec sized to the plan's devices); hand-built tags (``manual``,
        ``replicated``, …) fall back to ``balanced`` resizes.  Pass
        ``spec=`` to control this explicitly, and
        ``tpu_model``/``reporter``/``base_spec`` if the plan was priced
        against non-default device constants so resizes are too."""
        if spec is None:
            try:
                strat = get_strategy(plan.strategy)
            except ValueError:
                strat = None
            if strat is None:
                spec = DeploymentSpec(stages=plan.n_stages,
                                      strategy="balanced")
            elif strat.needs_topology:
                spec = DeploymentSpec(strategy=strat.name,
                                      device_budget=plan.n_devices)
            else:
                spec = DeploymentSpec(stages=plan.n_stages,
                                      strategy=strat.name)
        return cls(spec, plan, graph=graph,
                   stage_fn_builder=stage_fn_builder, stage_fns=stage_fns,
                   tpu_model=tpu_model, reporter=reporter,
                   base_spec=base_spec)

    @property
    def server(self):
        """The live server, or None before :meth:`serve` / after it
        stopped (stopping through the server's own ``stop()``/``with``
        counts — the handle checks, it does not need to be told)."""
        return self._live_server()

    @property
    def closed(self) -> bool:
        """True once :meth:`close` ran.  ``close()`` is terminal: a
        closed deployment refuses to build runtime (:meth:`serve`,
        :meth:`executor`, :meth:`reconfigure`) — lifecycle owners that
        cycle servers (the fleet does, repeatedly) stop the *server*
        and call :meth:`serve` again instead."""
        return self._closed

    def _check_open(self, what: str) -> None:
        if self._closed:
            raise RuntimeError(
                f"deployment is closed; {what} needs a live deployment "
                f"(close() is terminal — build a new handle via "
                f"deploy() / Deployment.from_plan)")

    def _live_server(self):
        if self._server is not None and self._server.stopped:
            self._server = None            # stopped behind our back
        return self._server

    def stage_functions(self, plan: Optional[PlacementPlan] = None
                        ) -> List[Callable]:
        pl = plan if plan is not None else self.plan
        if self._builder is not None:
            return list(self._builder(pl))
        if self._fns is not None:
            if len(self._fns) != pl.n_stages:
                raise ValueError(
                    f"deployment carries {len(self._fns)} fixed stage fns "
                    f"but the plan has {pl.n_stages} stages; use "
                    f"stage_fn_builder for resizable deployments")
            return list(self._fns)
        raise ValueError("deployment has no stage functions; pass "
                         "stage_fns or stage_fn_builder to deploy()")

    def executor(self, start: bool = False, *,
                 backend: Optional[str] = None,
                 model: Any = None, params: Any = None,
                 mesh: Any = None, n_microbatches: int = 4,
                 overlap_streaming: bool = True,
                 batch_size: Optional[int] = None,
                 seq_len: Optional[int] = None):
        """An executor wired from the plan + spec (caller owns its
        lifecycle; use as a context manager or call stop()).

        ``backend`` (default: the spec's) picks the execution tier:

        * ``"host"`` — the threaded :class:`PipelineExecutor` over this
          deployment's stage functions.
        * ``"spmd"`` — the
          :class:`~repro.launch.pipeline_spmd.SpmdPipelineExecutor`:
          the plan lowered onto a device mesh (shard_map + ppermute, one
          stage per mesh slice, overlapped weight streaming).  Needs the
          live model (a ``GraphModel`` or LM config) and its ``params`` —
          runtime objects that cannot live in the spec.  A plan with
          replicated stages cannot map one-stage-one-slice: it falls back
          to the host executor with a logged one-line notice (the
          low-level SPMD entry points keep the hard error).
        """
        self._check_open("executor()")
        backend = backend if backend is not None else self.spec.backend
        if backend not in ("host", "spmd"):
            raise ValueError(f"unknown backend {backend!r}; pick 'host' "
                             f"or 'spmd'")
        if backend == "spmd":
            from ..launch.pipeline_spmd import (SpmdPipelineExecutor,
                                                plan_supports_spmd)
            if not plan_supports_spmd(self.plan):
                logger.warning(
                    "spmd backend: plan has replicated stages "
                    "(replica_counts=%s); falling back to the host "
                    "PipelineExecutor", self.plan.replica_counts)
            else:
                if model is None or params is None:
                    raise ValueError(
                        "backend='spmd' needs the live model and params: "
                        "executor(backend='spmd', model=..., params=...)")
                return SpmdPipelineExecutor.for_model(
                    model, params, self.plan, mesh=mesh,
                    n_microbatches=n_microbatches,
                    overlap_streaming=overlap_streaming,
                    batch_size=batch_size,
                    **({"seq_len": seq_len} if seq_len is not None
                       else {}))
        ex = PipelineExecutor.for_plan(
            self.plan, self.stage_functions(),
            queue_size=self.spec.queue_size,
            microbatch=self.spec.microbatch,
            microbatch_wait_s=self.spec.microbatch_wait_s,
            hedge_after=self.spec.hedge_after,
            name_prefix="deploy")
        if start:
            ex.start()
        return ex

    def serve(self, start: bool = False, *, params: Any = None):
        """The streaming server over this deployment's plan.  At most one
        live server per deployment (reconfigure targets it); a server the
        caller already stopped no longer counts.

        ``workload="decode"`` specs get a continuous-batching
        :class:`~repro.decode.engine.DecodeServer` (token streams, not
        request/response futures); ``params`` optionally supplies the LM
        weights (fresh smoke weights otherwise)."""
        self._check_open("serve()")
        if self.spec.workload == "decode":
            from ..decode.engine import build_decode_server
            srv = build_decode_server(
                self.spec, plan=self.plan, params=params,
                queue_size=self.spec.queue_size)
            if start:
                srv.start()
            return srv
        if self._live_server() is not None:
            raise RuntimeError("deployment already has a live server; "
                               "stop it before serving again")
        from ..serving.server import PipelinedModelServer
        srv = PipelinedModelServer(
            self.plan, self.stage_functions(),
            max_batch=self.spec.max_batch, max_wait_s=self.spec.max_wait_s,
            queue_size=self.spec.queue_size,
            microbatch=self.spec.microbatch,
            microbatch_wait_s=self.spec.microbatch_wait_s,
            hedge_after=self.spec.hedge_after,
            stage_loss_retries=self.spec.stage_loss_retries,
            deadline_s=(None if self.spec.deadline_ms is None
                        else self.spec.deadline_ms / 1e3),
            shed_policy=self.spec.shed_policy)
        self._server = srv
        if start:
            srv.executor.start()
            srv.start()
        return srv

    def self_heal(self, canary_payloads: Sequence[Any], *,
                  policy=None, poll_interval_s: float = 0.25):
        """A :class:`~repro.runtime.selfheal.SelfHealingController` wired
        to this deployment's live server: live telemetry -> rolling trace
        -> drift detection -> guarded (canary + rollback) replans through
        the front-door registry.  Needs a live :meth:`serve` server and a
        ``stage_fn_builder`` (replans change the stage shapes).  The
        spec's ``drift_threshold``/``canary_requests`` seed the policy
        unless an explicit ``policy`` is given.  Caller owns the
        controller's lifecycle (use as a context manager)."""
        self._check_open("self_heal()")
        srv = self._live_server()
        if srv is None:
            raise RuntimeError("self_heal needs a live server; call "
                               "serve() first")
        if self._builder is None:
            raise ValueError("self_heal needs stage_fn_builder (guarded "
                             "replans rebuild the stage functions)")
        from ..runtime.selfheal import DriftPolicy, SelfHealingController
        if policy is None:
            policy = DriftPolicy(
                drift_threshold=self.spec.drift_threshold or 0.5,
                canary_requests=self.spec.canary_requests)
        return SelfHealingController(
            srv, self.spec, self.graph, self._builder,
            policy=policy, canary_payloads=canary_payloads,
            poll_interval_s=poll_interval_s,
            tpu_model=self._tpu_model, base_spec=self._base_spec)

    def reconfigure(self, spec: Optional[DeploymentSpec] = None, *,
                    stages: Optional[int] = None,
                    drain_timeout: float = 30.0) -> PlacementPlan:
        """Replan under a new spec (or the same deployment at a new device
        count via ``stages=``) and hot-swap the live server through the
        existing drain-and-swap path.  Without a live server this just
        re-plans and updates the handle."""
        self._check_open("reconfigure()")
        if (spec is None) == (stages is None):
            raise ValueError("pass exactly one of spec or stages")
        if spec is not None:
            new_spec = self._spec_template = spec
        else:
            new_spec = self._spec_template.with_stages(stages)
        new_plan = plan(new_spec, graph=self.graph,
                        tpu_model=self._tpu_model, reporter=self._reporter,
                        base_spec=self._base_spec)
        fns = self.stage_functions(new_plan)
        if self._live_server() is not None:
            self._server.reconfigure(new_plan, fns,
                                     drain_timeout=drain_timeout)
        self.spec = new_spec
        self.plan = new_plan
        return new_plan

    def close(self) -> None:
        """Stop any live server and retire the handle.  Terminal and
        idempotent: a second ``close()`` is a no-op, but ``serve()`` /
        ``executor()`` / ``reconfigure()`` after it raise — a consumer
        holding a closed handle is a lifecycle bug, not a state to limp
        through."""
        self._closed = True
        if self._server is not None:
            self._server.stop()
            self._server = None

    def __enter__(self) -> "Deployment":
        self._check_open("entering the context")
        return self

    def __exit__(self, *exc) -> None:
        self.close()


def deploy(spec: DeploymentSpec, *,
           graph: Optional[LayerGraph] = None,
           stage_fn_builder: Optional[StageFnBuilder] = None,
           stage_fns: Optional[Sequence[Callable]] = None,
           tpu_model: Optional[EdgeTPUModel] = None,
           reporter: Optional[MemoryReporter] = None,
           base_spec: Optional[EdgeTPUSpec] = None) -> Deployment:
    """Plan a spec and wrap it in a :class:`Deployment` handle."""
    if graph is None and spec.model is not None:
        graph = resolve_model_graph(spec.model)
    pl = plan(spec, graph=graph, tpu_model=tpu_model, reporter=reporter,
              base_spec=base_spec)
    return Deployment(spec, pl, graph=graph,
                      stage_fn_builder=stage_fn_builder,
                      stage_fns=stage_fns, tpu_model=tpu_model,
                      reporter=reporter, base_spec=base_spec)
