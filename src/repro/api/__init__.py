"""One front door: declarative DeploymentSpec -> Plan -> Deployment.

::

    from repro.api import DeploymentSpec, plan, deploy

    pl = plan(DeploymentSpec(model="cnn:ResNet50", stages=4,
                             strategy="opt"))
    dep = deploy(spec, graph=g, stage_fn_builder=fns_for)

Per-depth costs are pluggable (``DeploymentSpec.cost_source``:
``"analytic"`` / ``"trace:<path>"`` / ``"calibrated:<path>"``, backed by
:mod:`repro.profiling`).  See EXPERIMENTS.md §Deployment API for the
migration table from the removed ``repro.core.planner`` entry points and
§Profiling & calibration for the trace workflow.
"""
from .spec import DeploymentSpec, resolve_model_graph
from .report import PlanReport
from .strategies import (PlanContext, PlanStrategy, available_strategies,
                         get_strategy, register_strategy)
from .deploy import Deployment, deploy, plan

# the decode tier's strategy lives in repro.decode.placement, which
# imports this package's modules — registration is deferred into a
# callable invoked once the registry exists
from ..decode.placement import _register as _register_decode
_register_decode()
del _register_decode

# fleet-tier names re-exported lazily (PEP 562): repro.fleet imports
# from this package's submodules, so an eager import here would cycle
_FLEET_EXPORTS = ("Fleet", "FleetSpec", "FleetMemberSpec", "deploy_fleet",
                  "plan_fleet")

__all__ = [
    "DeploymentSpec", "resolve_model_graph",
    "PlanReport",
    "PlanContext", "PlanStrategy", "register_strategy", "get_strategy",
    "available_strategies",
    "plan", "deploy", "Deployment",
    *_FLEET_EXPORTS,
]


def __getattr__(name):
    if name in _FLEET_EXPORTS:
        from .. import fleet
        return getattr(fleet, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
