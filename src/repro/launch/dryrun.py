"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

MUST set the fake-device flag before ANY other import (jax locks the device
count on first init)::

    python -m repro.launch.dryrun --arch qwen3-1.7b --shape train_4k
    python -m repro.launch.dryrun --all --mesh both --out benchmarks/artifacts
"""
import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))

import argparse          # noqa: E402
import json              # noqa: E402
import time              # noqa: E402
import traceback         # noqa: E402
from typing import Any, Dict, Optional  # noqa: E402

import jax               # noqa: E402
import numpy as np       # noqa: E402

from repro import configs                          # noqa: E402
from repro.configs.common import SHAPES, input_specs  # noqa: E402
from repro.launch import sharding as shd           # noqa: E402
from repro.launch import steps as steps_lib        # noqa: E402
from repro.launch.hlo_analysis import analyze      # noqa: E402
from repro.launch.mesh import (HBM_BW, ICI_BW, PEAK_FLOPS_BF16,  # noqa: E402
                               make_production_mesh, mesh_context)
from repro.models import api                       # noqa: E402
from repro.optim import AdamWConfig                # noqa: E402

HBM_PER_CHIP = 16 * 1024**3          # v5e


def _normalize_cost_analysis(raw: Any) -> Dict[str, float]:
    """``Compiled.cost_analysis()`` returns a dict on older jaxlib but a
    *list of per-computation dicts* on newer releases; fold either shape
    into one flat {metric: summed value} dict."""
    if raw is None:
        return {}
    if isinstance(raw, dict):
        return raw
    merged: Dict[str, float] = {}
    for entry in raw:
        for k, v in (entry or {}).items():
            if isinstance(v, (int, float)):
                merged[k] = merged.get(k, 0.0) + v
    return merged


def _sharded_leaf_bytes(leaf, sh, mesh) -> float:
    """Per-device bytes of one array under its NamedSharding."""
    n = float(np.prod(leaf.shape)) * np.dtype(leaf.dtype).itemsize
    spec = getattr(sh, "spec", None)
    if spec is None:
        return n
    denom = 1
    for entry in spec:
        if entry is None:
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        for a in axes:
            denom *= mesh.shape[a]
    return n / denom


def analytic_state_bytes(trees_and_shardings, mesh) -> float:
    total = 0.0
    for tree, sh_tree in trees_and_shardings:
        leaves = jax.tree.leaves(tree)
        shs = jax.tree.leaves(sh_tree,
                              is_leaf=lambda x: hasattr(x, "spec"))
        for leaf, sh in zip(leaves, shs):
            total += _sharded_leaf_bytes(leaf, sh, mesh)
    return total


def analytic_activation_bytes(cfg, spec, mesh) -> float:
    """Per-device activation working set (documented model, see
    EXPERIMENTS.md §Dry-run): remat residual stack + transients + logits
    shard + attention score chunk."""
    from repro.launch.mesh import data_parallel_size, model_axis_size
    dp = data_parallel_size(mesh)
    tp = model_axis_size(mesh)
    b = spec.global_batch
    b_loc = b / dp if b % dp == 0 else b
    s = spec.seq_len if spec.kind != "decode" else 1
    d = cfg.d_model
    v_loc = cfg.vocab / tp if cfg.vocab % tp == 0 else cfg.vocab
    h_loc = max(1, cfg.n_heads / tp)
    act = 0.0
    f_loc = cfg.d_ff / tp if cfg.d_ff % tp == 0 else cfg.d_ff
    if cfg.family == "moe":
        e_loc = max(1, cfg.n_experts / tp)
        f_loc = f_loc * e_loc * 3          # dispatch keeps E_loc expert bufs
    if spec.kind == "train":
        # remat carry stack is sequence-sharded over `model` when divisible
        s_stack = s / tp if (cfg.seq_shard_acts and s % tp == 0) else s
        act += cfg.n_layers * b_loc * s_stack * d * 2  # remat carry stack
        # in-block transients: 2 bf16 full-seq residual copies + gated MLP
        # hidden shards + 2 fp32 seq-sharded norm buffers
        act += 2 * b_loc * s * d * 2
        act += 2 * b_loc * s * f_loc * 2
        act += 2 * b_loc * s_stack * d * 4
        act += 2 * b_loc * 512 * v_loc * 4             # chunked-loss logits
        act += 2 * b_loc * h_loc * min(s, cfg.q_chunk) * s * 4   # scores
    elif spec.kind == "prefill":
        act += 3 * b_loc * s * d * 2 + b_loc * s * f_loc * 2
        act += b_loc * h_loc * min(s, cfg.q_chunk) * s * 4
        act += b_loc * v_loc * 4                       # last-token logits
    else:
        act += 4 * b_loc * d * 4 + b_loc * v_loc * 4
    return act


def _mem_dict(mem) -> Dict[str, int]:
    out = {}
    for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                 "temp_size_in_bytes", "alias_size_in_bytes",
                 "generated_code_size_in_bytes"):
        try:
            out[attr] = int(getattr(mem, attr))
        except (AttributeError, TypeError):
            pass
    return out


def model_flops(arch: str, shape_name: str) -> float:
    """'Useful' FLOPs: 6*N_active*tokens (train) / 2*N_active*tokens (fwd)."""
    spec = SHAPES[shape_name]
    cfg = configs.get(arch).config()
    n = api.active_param_count(cfg)
    if spec.kind == "train":
        tokens = spec.global_batch * spec.seq_len
        return 6.0 * n * tokens
    if spec.kind == "prefill":
        tokens = spec.global_batch * spec.seq_len
        return 2.0 * n * tokens
    tokens = spec.global_batch * 1          # decode: one new token
    return 2.0 * n * tokens


def dryrun_cell(arch: str, shape_name: str, multi_pod: bool,
                verbose: bool = True) -> Dict[str, Any]:
    """Lower+compile one cell; returns the roofline record."""
    mod = configs.get(arch)
    skip = mod.SKIP_SHAPES.get(shape_name)
    rec: Dict[str, Any] = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "n_devices": 512 if multi_pod else 256,
    }
    if skip:
        rec["status"] = "skipped"
        rec["skip_reason"] = skip
        return rec

    cfg = mod.config()
    spec = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()

    with mesh_context(mesh):
        if spec.kind == "train":
            from repro.launch import variants
            params_s, opt_s = steps_lib.train_state_shapes(cfg)
            batch_s = input_specs(cfg, spec)
            fsdp = ("blocks" if not (variants.on("no_fsdp")
                                     or variants.on("full_fsdp"))
                    else (True if variants.on("full_fsdp") else False))
            in_sh = (shd.param_shardings(mesh, params_s, fsdp=fsdp),
                     shd.opt_state_shardings(mesh, opt_s),
                     shd.batch_shardings(mesh, batch_s))
            fn = steps_lib.make_train_step(
                cfg, AdamWConfig(),
                loss_chunk=2048 if variants.on("loss_chunk_2k") else 512)
            out_sh = (in_sh[0], in_sh[1], shd.replicated(mesh, {
                "lr": 0, "grad_norm": 0, "loss": 0}))
            jitted = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh,
                             donate_argnums=(0, 1))
            lowered = jitted.lower(params_s, opt_s, batch_s)
        elif spec.kind == "prefill":
            params_s = jax.eval_shape(lambda k: api.init(cfg, k),
                                      jax.ShapeDtypeStruct((2,), "uint32"))
            batch_s = input_specs(cfg, spec)
            in_sh = (shd.param_shardings(mesh, params_s),
                     shd.batch_shardings(mesh, batch_s))
            fn = steps_lib.make_prefill_step(cfg)
            jitted = jax.jit(fn, in_shardings=in_sh)
            lowered = jitted.lower(params_s, batch_s)
        else:  # decode
            from repro.launch import variants
            params_s = jax.eval_shape(lambda k: api.init(cfg, k),
                                      jax.ShapeDtypeStruct((2,), "uint32"))
            cache_s = steps_lib.cache_shapes(cfg, spec.global_batch,
                                             spec.seq_len)
            tok_s = input_specs(cfg, spec)["tokens"]
            # flash-decoding seq-sharded cache is the default for the
            # attention families (2.9x decode win); `cache_hd` reverts
            cache_mode = ("seq" if (cfg.family in ("dense", "moe", "vlm")
                                    and not variants.on("cache_hd"))
                          else "hd")
            in_sh = (shd.param_shardings(mesh, params_s),
                     shd.cache_shardings(mesh, cache_s, mode=cache_mode),
                     shd.batch_shardings(mesh, {"tokens": tok_s})["tokens"])
            fn = steps_lib.make_decode_step(cfg)
            out_sh = (None, in_sh[1])
            jitted = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh,
                             donate_argnums=(1,))
            lowered = jitted.lower(params_s, cache_s, tok_s)

        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem = _mem_dict(compiled.memory_analysis())
    raw_cost = _normalize_cost_analysis(compiled.cost_analysis())
    totals = analyze(compiled.as_text())
    n_dev = rec["n_devices"]

    flops_dev = totals.flops
    bytes_dev = totals.hbm_bytes
    coll_dev = totals.coll_bytes
    mf = model_flops(arch, shape_name)

    compute_s = flops_dev / PEAK_FLOPS_BF16
    memory_s = bytes_dev / HBM_BW
    collective_s = coll_dev / ICI_BW
    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": collective_s}
    dominant = max(terms, key=terms.get)

    # per-device residency: sharded state (exact) + activation model
    state_pairs = []
    if spec.kind == "train":
        state_pairs = [(params_s, in_sh[0]), (opt_s, in_sh[1])]
    elif spec.kind == "prefill":
        state_pairs = [(params_s, in_sh[0])]
    else:
        state_pairs = [(params_s, in_sh[0]), (cache_s, in_sh[1])]
    state_bytes = analytic_state_bytes(state_pairs, mesh)
    act_bytes = analytic_activation_bytes(cfg, spec, mesh)
    dev_bytes = state_bytes + act_bytes

    rec.update({
        "status": "ok",
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "memory_analysis_raw": mem,     # CPU backend: includes bf16->f32
                                        # legalization temps (see DESIGN.md)
        "state_bytes_per_device": state_bytes,
        "activation_bytes_per_device": act_bytes,
        "device_bytes": dev_bytes,
        "fits_hbm": bool(dev_bytes <= HBM_PER_CHIP),
        "hlo_flops_per_device": flops_dev,
        "hlo_flops_raw_cost_analysis": float(raw_cost.get("flops", 0.0)),
        "hlo_bytes_per_device": bytes_dev,
        "collective_bytes_per_device": coll_dev,
        "collective_breakdown": totals.coll_by_kind,
        "collective_counts": totals.coll_counts,
        "roofline": dict(terms, dominant=dominant),
        "model_flops_global": mf,
        "useful_flops_ratio": (mf / (flops_dev * n_dev)
                               if flops_dev else None),
    })
    if verbose:
        print(f"[{rec['mesh']}] {arch} x {shape_name}: "
              f"compile {t_compile:.1f}s, "
              f"{dev_bytes/2**30:.2f} GiB/dev (fits={rec['fits_hbm']}), "
              f"terms(ms): C={compute_s*1e3:.2f} M={memory_s*1e3:.2f} "
              f"X={collective_s*1e3:.2f} -> {dominant}, "
              f"useful={rec['useful_flops_ratio'] and round(rec['useful_flops_ratio'],3)}")
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="benchmarks/artifacts/dryrun")
    args = ap.parse_args()

    cells = []
    if args.all:
        for aid, sname, _skip in configs.cells(include_skipped=True):
            cells.append((aid, sname))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells.append((args.arch, args.shape))
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    os.makedirs(args.out, exist_ok=True)
    failures = []
    for arch, shape in cells:
        for mp in meshes:
            tag = f"{arch}_{shape}_{'2x16x16' if mp else '16x16'}"
            path = os.path.join(args.out, tag + ".json")
            try:
                rec = dryrun_cell(arch, shape, mp)
            except Exception as e:   # noqa: BLE001 — record and continue
                rec = {"arch": arch, "shape": shape,
                       "mesh": "2x16x16" if mp else "16x16",
                       "status": "error", "error": repr(e),
                       "traceback": traceback.format_exc()}
                failures.append(tag)
                print(f"FAILED {tag}: {e}")
            with open(path, "w") as f:
                json.dump(rec, f, indent=1)
    if failures:
        print(f"\n{len(failures)} FAILURES: {failures}")
        raise SystemExit(1)
    print("\nall dry-run cells green")


if __name__ == "__main__":
    main()
