"""Training driver: fault-tolerant loop over the step builders.

Runnable at smoke scale on CPU (default) and at pod scale with the same
code path (the mesh/shardings come from launch.sharding)::

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-1.7b \
        --steps 60 --ckpt-dir /tmp/ckpt --fail-at 25
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.checkpoint import CheckpointStore
from repro.configs.common import concrete_batch
from repro.data import DataConfig, SyntheticLMDataset
from repro.launch import steps as steps_lib
from repro.optim import AdamWConfig
from repro.runtime import FailureInjector, TrainSupervisor


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--fail-at", type=int, nargs="*", default=[],
                    help="inject failures at these steps (FT demo)")
    ap.add_argument("--full", action="store_true",
                    help="use the full config (pod-scale; not for CPU)")
    args = ap.parse_args()

    mod = configs.get(args.arch)
    cfg = mod.config() if args.full else mod.smoke_config()
    print(f"training {cfg.name} ({cfg.family}) for {args.steps} steps")

    opt_cfg = AdamWConfig(lr=args.lr, warmup_steps=10,
                          total_steps=args.steps)
    data = SyntheticLMDataset(DataConfig(
        global_batch=args.batch, seq_len=args.seq, vocab=cfg.vocab))
    params, opt_state = steps_lib.init_train_state(cfg,
                                                   jax.random.PRNGKey(0))
    raw_step = jax.jit(steps_lib.make_train_step(
        cfg, opt_cfg, loss_chunk=min(512, args.seq)))

    def step_fn(state, step):
        params, opt_state = state
        np_batch = data.batch_at(step)
        batch = {k: jnp.asarray(v) for k, v in np_batch.items()}
        if cfg.family == "vlm":
            full = concrete_batch(cfg, args.seq + cfg.n_patches, args.batch,
                                  key=jax.random.PRNGKey(step))
            batch = full
        elif cfg.family == "encdec":
            frames = concrete_batch(cfg, args.seq, args.batch,
                                    key=jax.random.PRNGKey(step))["frames"]
            batch["frames"] = frames
        params, opt_state, metrics = raw_step(params, opt_state, batch)
        return (params, opt_state), {k: float(v) for k, v in metrics.items()}

    store = CheckpointStore(args.ckpt_dir, keep=2)
    injector = FailureInjector(fail_at_steps=args.fail_at)
    sup = TrainSupervisor(store, step_fn, ckpt_every=args.ckpt_every,
                          injector=injector)

    t0 = time.time()
    (params, opt_state), report = sup.run((params, opt_state), args.steps)
    dt = time.time() - t0
    losses = [m["loss"] for _, m in report.history]
    print(f"done in {dt:.1f}s; restarts={report.restarts} "
          f"checkpoints={report.checkpoints}")
    print(f"loss: first={losses[0]:.4f} last={losses[-1]:.4f} "
          f"min={min(losses):.4f}")
    assert np.isfinite(losses).all(), "NaN loss"
    if len(losses) > 10:
        assert losses[-1] < losses[0], "loss did not decrease"
        print("loss decreased — training sanity OK")


if __name__ == "__main__":
    main()
