"""Step builders: train_step / prefill_step / decode_step per architecture.

These are the functions the dry-run lowers and the drivers execute.  They
are pure pytree->pytree functions; distribution comes entirely from the
in/out shardings attached at jit time (launch/sharding.py).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from ..models import api
from ..models.lm import LMConfig, lm_loss
from ..optim import AdamWConfig, adamw_init, adamw_update

Params = Any


def chunked_lm_loss(cfg: LMConfig, params: Params, hidden: jax.Array,
                    labels: jax.Array, chunk: int = 512) -> jax.Array:
    """Cross-entropy without materializing (B, S, V) logits: scan over
    sequence chunks, unembedding one chunk at a time; jax.checkpoint makes
    the backward recompute chunk logits instead of saving them."""
    b, s, d = hidden.shape
    chunk = min(chunk, s)
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk
    hc = hidden.reshape(b, nc, chunk, d).swapaxes(0, 1)
    lc = labels.reshape(b, nc, chunk).swapaxes(0, 1)

    @jax.checkpoint
    def chunk_nll(h, lab):
        logits = api.unembed(cfg, params, h)            # (B, chunk, V) fp32
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, lab[..., None], axis=-1)[..., 0]
        return jnp.sum(logz - gold)

    def body(acc, xs):
        h, lab = xs
        return acc + chunk_nll(h, lab), None

    total, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32), (hc, lc))
    return total / (b * s)


def make_train_step(cfg: LMConfig, opt_cfg: AdamWConfig,
                    loss_chunk: int = 512):
    def train_step(params: Params, opt_state: Params,
                   batch: Dict[str, jax.Array]):
        def loss_fn(p):
            hidden = api.forward_hidden(cfg, p, batch)
            return chunked_lm_loss(cfg, p, hidden, batch["labels"],
                                   chunk=loss_chunk)

        loss, grads = jax.value_and_grad(loss_fn)(params)
        params, opt_state, metrics = adamw_update(opt_cfg, params, grads,
                                                  opt_state)
        metrics = dict(metrics, loss=loss)
        return params, opt_state, metrics

    return train_step


def make_prefill_step(cfg: LMConfig):
    def prefill_step(params: Params, batch: Dict[str, jax.Array]):
        # unembed only the last position: avoids the (B, S, V) logits buffer
        logits = api.forward(cfg, params, batch, last_token_only=True)
        return logits[:, -1, :]            # next-token logits (B, V)

    return prefill_step


def make_decode_step(cfg: LMConfig):
    def decode_step(params: Params, cache: Params, tokens: jax.Array):
        logits, cache = api.decode(cfg, params, tokens, cache)
        return logits[:, -1, :], cache

    return decode_step


def init_train_state(cfg: LMConfig, key: jax.Array) -> Tuple[Params, Params]:
    params = api.init(cfg, key)
    return params, adamw_init(params)


def train_state_shapes(cfg: LMConfig) -> Tuple[Params, Params]:
    """eval_shape versions (no allocation) for the dry-run."""
    params = jax.eval_shape(lambda k: api.init(cfg, k),
                            jax.ShapeDtypeStruct((2,), "uint32"))
    opt = jax.eval_shape(adamw_init, params)
    return params, opt


def cache_shapes(cfg: LMConfig, batch: int, max_len: int) -> Params:
    return jax.eval_shape(
        functools.partial(api.init_cache, cfg, batch, max_len))
