"""Parse collective-communication bytes out of lowered/compiled HLO text.

``compiled.cost_analysis()`` has no collective term, so the roofline's
third term comes from summing the operand sizes of every all-gather /
all-reduce / reduce-scatter / all-to-all / collective-permute instruction
in the (post-SPMD-partitioning) HLO.  Operand types appear inline in HLO
call sites (``all-reduce(f32[8,128]{1,0} %add.5)``), so one regex pass
over the text suffices.
"""
from __future__ import annotations

import re
from typing import Dict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

# e.g. "bf16[8,128,1024]" (dims optional: "f32[]" is a scalar)
_SHAPE_RE = re.compile(r"\b(" + "|".join(_DTYPE_BYTES) + r")\[([0-9,]*)\]")
# collective instruction line: "...= TYPE[..] all-reduce(ARGS)..." — also
# match fused/start variants (all-reduce-start, all-gather-start, ...)
_COLL_RE = re.compile(
    r"=\s+[^=]*?\b(" + "|".join(COLLECTIVES) + r")(?:-start)?\((.*)$")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Per-collective operand bytes (one program execution, per device),
    plus 'total'."""
    out = {k: 0 for k in COLLECTIVES}
    counts = {k: 0 for k in COLLECTIVES}
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        kind, args = m.group(1), m.group(2)
        # cut at the closing paren of the call (args never nest parens
        # except in replica_groups={{...}} which comes after ')')
        depth, end = 1, len(args)
        for i, ch in enumerate(args):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    end = i
                    break
        ops = args[:end]
        b = sum(_shape_bytes(d, s) for d, s in _SHAPE_RE.findall(ops))
        out[kind] += b
        counts[kind] += 1
    out["total"] = sum(out[k] for k in COLLECTIVES)
    out["counts"] = counts  # type: ignore[assignment]
    return out
