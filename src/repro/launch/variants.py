"""Perf-variant knobs (§Perf hillclimbing), read from REPRO_VARIANT.

Knobs compose as a comma-separated list; ``baseline`` = the paper-faithful
+ first-green configuration recorded in the dry-run sweep.

Knobs:
* ``cache_seq``     — decode KV cache sharded over the *sequence* axis
                      (flash-decoding style) instead of head_dim.
* ``attn_shard``    — explicit q/k/v sharding constraints inside attention
                      (head-sharded q where divisible, replicated kv) to
                      stop GSPMD resharding churn.
* ``no_fsdp``       — disable FSDP weight sharding for train (TP-only
                      params; isolates FSDP gather cost).
* ``no_seqshard``   — disable Megatron-SP activation sharding at block
                      boundaries.
* ``scores_bf16``   — attention scores in bf16 (halves score traffic;
                      softmax stats still fp32).
* ``rwkv_chunked``  — chunked-parallel WKV formulation (state leaves the
                      inner loop; jnp mirror of the Pallas kernel blocking).
* ``loss_chunk_2k`` — chunked-loss block 2048 instead of 512.
"""
from __future__ import annotations

import os
from typing import Set


def active() -> Set[str]:
    return {v.strip() for v in os.environ.get("REPRO_VARIANT", "").split(",")
            if v.strip() and v.strip() != "baseline"}


def on(knob: str) -> bool:
    return knob in active()
