"""Launchers: production mesh, sharding rules, train/serve steps, dry-run."""
