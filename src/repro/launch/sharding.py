"""Sharding rules: param/cache/batch pytrees -> NamedSharding.

Megatron-style tensor parallelism over the ``model`` axis with name-keyed
rules and divisibility fallbacks:

* column-parallel (output-feature sharded): wq/wk/wv/wu/wg (+ their biases)
* row-parallel (input-feature sharded):     wo/wd
* expert-parallel: MoE expert tensors shard the leading expert axis
* vocab-parallel: embed/head shard the vocab axis when divisible
  (granite's 49155 and whisper's 51865 are not -> fall back to d_model
  sharding or replication, chosen by divisibility)
* stacked layer axes (blocks/super/tail/enc/dec) are never sharded
* KV caches shard batch over (pod, data) and head_dim over model
  (all assigned head_dims are divisible by 16); recurrent states shard
  their channel/head dims over model.

Everything falls back to replication when nothing divides — the rules can
never produce an invalid sharding, only a slower one (visible in the
roofline, which is where the perf loop iterates).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from .mesh import data_axes, model_axis_size

# param-name -> role
_COL = {"wq", "wk", "wv", "wu", "wg", "wr", "wx", "wgate", "maa_w1",
        "w_lora1"}
_ROW = {"wo", "wd", "w_lora2"}
_COL_BIAS = {"bq", "bk", "bv", "bu"}
_STACK_KEYS = {"blocks", "super", "tail", "enc", "dec"}


def _path_names(path) -> Tuple[str, ...]:
    out = []
    for p in path:
        if hasattr(p, "key"):
            out.append(str(p.key))
        elif hasattr(p, "name"):
            out.append(str(p.name))
    return tuple(out)


def _spec_for_param(names: Tuple[str, ...], shape: Tuple[int, ...],
                    msize: int, mesh_has_model: bool) -> P:
    stacked = any(n in _STACK_KEYS for n in names)
    off = 1 if stacked else 0
    nd = len(shape)
    name = names[-1] if names else ""
    parent = names[-2] if len(names) >= 2 else ""

    def spec(axis: Optional[int]) -> P:
        dims: list = [None] * nd
        if axis is not None:
            dims[axis] = "model"
        return P(*dims)

    if not mesh_has_model or msize <= 1:
        return P()

    def ok(axis: int) -> bool:
        return 0 <= axis < nd and shape[axis] % msize == 0

    # MoE expert tensors: (L, E, D, F) or (E, D, F) -> shard E
    if parent == "mlp" and name in ("wg", "wu", "wd") and nd - off == 3:
        return spec(off) if ok(off) else P()
    if name == "router":
        return P()
    if name == "embed":
        if ok(nd - 2):                      # vocab axis
            return spec(nd - 2)
        if ok(nd - 1):                      # d_model axis
            return spec(nd - 1)
        return P()
    if name == "head":
        if ok(nd - 1):                      # vocab axis
            return spec(nd - 1)
        if ok(nd - 2):
            return spec(nd - 2)
        return P()
    if name in _COL and nd - off >= 2:
        return spec(nd - 1) if ok(nd - 1) else P()
    if name in _ROW and nd - off >= 2:
        return spec(nd - 2) if ok(nd - 2) else P()
    if name in _COL_BIAS:
        return spec(nd - 1) if ok(nd - 1) else P()
    if name in ("conv_w", "conv_b", "a_gate_w", "a_gate_b", "i_gate_w",
                "i_gate_b", "lam"):         # rglru channel vectors
        return spec(nd - 1) if ok(nd - 1) else P()
    return P()                              # norms, scalars, small adapters


def param_shardings(mesh: Mesh, params: Any, fsdp: str | bool = False) -> Any:
    """TP rules; ``fsdp`` additionally shards weights over the data axes on
    the first free divisible dim (gathered per layer inside the scan —
    ZeRO-3/FSDP, used by the train path when TP-only params overflow).

    fsdp="blocks" (recommended): only the stacked per-layer tensors.
    Data-sharding the embed/head vocab tensors measurably explodes the
    collective volume (the embedding backward's scatter and the chunked
    unembed re-gather them constantly — see EXPERIMENTS.md §Perf,
    qwen2.5-14b train: 13x collective-term regression), while the block
    tensors gather once per layer per pass, which is the FSDP contract.
    fsdp=True ("full") shards everything; False disables.
    """
    msize = model_axis_size(mesh)
    has_model = "model" in mesh.axis_names
    daxes = data_axes(mesh)
    dsize = int(np.prod([mesh.shape[a] for a in daxes])) if daxes else 1
    dspec = daxes if len(daxes) > 1 else (daxes[0] if daxes else None)

    def one(path, leaf):
        names = _path_names(path)
        sp = _spec_for_param(names, leaf.shape, msize, has_model)
        stacked = any(n in _STACK_KEYS for n in names)
        apply_fsdp = (fsdp is True or (fsdp == "blocks" and stacked))
        if apply_fsdp and dsize > 1:
            dims = list(sp) + [None] * (len(leaf.shape) - len(sp))
            start = 1 if stacked else 0
            for ax in range(start, len(leaf.shape)):
                if dims[ax] is None and leaf.shape[ax] % dsize == 0:
                    dims[ax] = dspec
                    break
            sp = P(*dims)
        return NamedSharding(mesh, sp)

    return jax.tree_util.tree_map_with_path(one, params)


def opt_state_shardings(mesh: Mesh, opt_state: Any) -> Any:
    """ZeRO-1: optimizer moments follow the param rules PLUS an extra
    data-axis shard on the first free divisible dimension.  The AdamW update
    is pointwise, so XLA turns the gradient all-reduce into reduce-scatter +
    (next-step) all-gather — per-device optimizer memory drops by the DP
    degree at no extra communication volume."""
    msize = model_axis_size(mesh)
    has_model = "model" in mesh.axis_names
    daxes = data_axes(mesh)
    dsize = int(np.prod([mesh.shape[a] for a in daxes])) if daxes else 1
    dspec = daxes if len(daxes) > 1 else (daxes[0] if daxes else None)

    def one(path, leaf):
        names = _path_names(path)
        if names and names[-1] == "step":
            return NamedSharding(mesh, P())
        base = _spec_for_param(names, leaf.shape, msize, has_model)
        dims = list(base) + [None] * (len(leaf.shape) - len(base))
        if dsize > 1:
            stacked = any(n in _STACK_KEYS for n in names)
            start = 1 if stacked else 0
            for ax in range(start, len(leaf.shape)):
                if dims[ax] is None and leaf.shape[ax] % dsize == 0:
                    dims[ax] = dspec
                    break
        return NamedSharding(mesh, P(*dims))

    return jax.tree_util.tree_map_with_path(one, opt_state)


# ---------------------------------------------------------------------------
# batches and caches
# ---------------------------------------------------------------------------
def batch_shardings(mesh: Mesh, batch: Any) -> Any:
    """Shard the leading batch dim over (pod, data); positions (3,B,S) on
    axis 1.  Falls back to replication when batch doesn't divide."""
    daxes = data_axes(mesh)
    dsize = int(np.prod([mesh.shape[a] for a in daxes])) if daxes else 1

    def one(path, leaf):
        names = _path_names(path)
        baxis = 1 if (names and names[-1] == "positions") else 0
        if dsize > 1 and leaf.shape[baxis] % dsize == 0:
            dims: list = [None] * len(leaf.shape)
            dims[baxis] = daxes if len(daxes) > 1 else daxes[0]
            return NamedSharding(mesh, P(*dims))
        return NamedSharding(mesh, P())

    return jax.tree_util.tree_map_with_path(one, batch)


def cache_shardings(mesh: Mesh, cache: Any, batch_axis: int = 1,
                    mode: str = "hd") -> Any:
    """KV caches (L,B,T,H,D): batch over data axes, plus per ``mode``:

    * ``hd``  — head_dim (last axis) over model: simple, but every decode
      attention psums fp32 scores over the hd shards (collective-heavy).
    * ``seq`` — flash-decoding style: the cache *sequence* axis over model;
      softmax reductions over the sharded T psum only per-token scalars and
      the probs@V partial sums (tiny) — see EXPERIMENTS.md §Perf.

    Recurrent states keep batch + channel/head-dim sharding in both modes.
    """
    daxes = data_axes(mesh)
    dsize = int(np.prod([mesh.shape[a] for a in daxes])) if daxes else 1
    msize = model_axis_size(mesh)
    dspec = daxes if len(daxes) > 1 else (daxes[0] if daxes else None)

    def one(path, leaf):
        names = _path_names(path)
        nd = len(leaf.shape)
        if names and names[-1] == "len":
            return NamedSharding(mesh, P())
        dims: list = [None] * nd
        # batch axis: caches are stacked per layer -> axis 1 (or 0 for
        # unstacked); find the first axis that divides dsize
        if dsize > 1:
            for ax in (batch_axis, 0):
                if ax < nd and leaf.shape[ax] % dsize == 0:
                    dims[ax] = dspec
                    break
        is_kv = names and names[-1] in ("k", "v", "mem_k", "mem_v")
        if (mode == "seq" and is_kv and nd == 5 and msize > 1
                and leaf.shape[2] % msize == 0):
            dims[2] = "model"              # sequence axis
        elif msize > 1 and nd >= 2 and leaf.shape[-1] % msize == 0:
            dims[-1] = "model"             # head_dim / channels
        return NamedSharding(mesh, P(*dims))

    return jax.tree_util.tree_map_with_path(one, cache)


def replicated(mesh: Mesh, tree: Any) -> Any:
    return jax.tree.map(lambda _: NamedSharding(mesh, P()), tree)
