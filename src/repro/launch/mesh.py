"""Production mesh builders.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so that
importing this module never touches JAX device state — the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before its first
jax import, and everything else must see the real (1-device) topology.
"""
from __future__ import annotations

import contextlib
from typing import Dict, Optional, Tuple

import jax

PODS = 2
POD_SIDE = 16          # 16 x 16 = 256 chips per v5e pod

# TPU v5e hardware constants (roofline denominators; see EXPERIMENTS.md)
PEAK_FLOPS_BF16 = 197e12        # per chip
HBM_BW = 819e9                  # bytes/s per chip
ICI_BW = 50e9                   # bytes/s per link


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (PODS, POD_SIDE, POD_SIDE) if multi_pod else (POD_SIDE, POD_SIDE)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape: Tuple[int, ...], axes: Tuple[str, ...]) -> jax.sharding.Mesh:
    """Generic mesh helper (tests / small-scale runs)."""
    return jax.make_mesh(shape, axes)


def data_axes(mesh: jax.sharding.Mesh) -> Tuple[str, ...]:
    """Axes the batch dimension shards over (pod+data when present)."""
    names = mesh.axis_names
    return tuple(a for a in ("pod", "data") if a in names)


def model_axis_size(mesh: jax.sharding.Mesh) -> int:
    return mesh.shape.get("model", 1)


def data_parallel_size(mesh: jax.sharding.Mesh) -> int:
    out = 1
    for a in data_axes(mesh):
        out *= mesh.shape[a]
    return out


# ---------------------------------------------------------------------------
# Trace-time mesh registry.  jax.sharding.get_abstract_mesh() is EMPTY when
# tracing under a plain ``with mesh:`` context and get_mesh() is forbidden
# inside jit, so the in-model sharding constraints (seq_shard / attn_shard /
# weight-gather) read the mesh from here; launchers must use mesh_context().
# ---------------------------------------------------------------------------
_CURRENT: Optional[jax.sharding.Mesh] = None


@contextlib.contextmanager
def mesh_context(mesh: jax.sharding.Mesh):
    global _CURRENT
    prev = _CURRENT
    _CURRENT = mesh
    try:
        with mesh:
            yield mesh
    finally:
        _CURRENT = prev


def current_mesh_info() -> Optional[Tuple[Tuple[str, ...], Dict[str, int]]]:
    if _CURRENT is None:
        return None
    return tuple(_CURRENT.axis_names), dict(_CURRENT.shape)
