"""SPMD pipeline parallelism over a mesh axis — the paper's technique at
pod scale.

The host-threaded executor (core/pipeline.py) is paper-faithful for a PCIe
card of Edge TPUs; on a pod the stage-to-stage hop is a
``jax.lax.ppermute`` over ICI inside ``shard_map``.  The stage->layer
assignment comes from the same :class:`PlacementPlan` (SEGM_BALANCED /
SEGM_COMP over the arch's LayerGraph): per-stage *block counts may differ*
(balanced split shifts blocks away from the embed/head stages), realized by
padding every stage to ``max_count`` blocks with identity-masked slots.

GPipe circular schedule, M microbatches over S stages::

    t = 0 .. M+S-2:
      stage 0 injects microbatch t (while t < M)
      every stage applies its blocks to its current input
      outputs rotate to the next stage via ppermute
      stage S-1 emits microbatch t-S+1

Embedding and unembedding run data-parallel outside the pipeline (their
*cost* still participates in the plan: stages holding them receive fewer
blocks).  Supported for the scan-block families (dense / moe / vlm).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

if hasattr(jax, "shard_map"):                      # jax >= 0.6
    _shard_map = jax.shard_map
    _SHMAP_NOCHECK = {"check_vma": False}
else:                                              # jax 0.4.x
    from jax.experimental.shard_map import shard_map as _shard_map
    _SHMAP_NOCHECK = {"check_rep": False}

from ..core.planner import PlacementPlan
from ..models import lm
from ..models.lm import LMConfig

Params = Any


def stage_block_counts(plan: PlacementPlan, n_blocks: int) -> List[int]:
    """Blocks per stage from a plan over the full LayerGraph (embed +
    block_i + final_norm/head nodes): count only block_* layers."""
    counts = []
    for layers in plan.stage_layers:
        counts.append(sum(1 for l in layers if l.startswith("block_")))
    assert sum(counts) == n_blocks, (counts, n_blocks)
    return counts


def _require_unreplicated(plan: PlacementPlan) -> None:
    """The SPMD pipeline maps one stage to one mesh slice; replicated
    stages belong to the host-threaded executor (core/pipeline.py)."""
    reps = getattr(plan, "replica_counts", None)
    if reps and any(r != 1 for r in reps):
        raise NotImplementedError(
            f"SPMD pipeline does not support replicated stages "
            f"(replica_counts={reps}); use the host PipelineExecutor or "
            f"re-plan with replicate=False")


def build_stage_blocks(blocks: Params, counts: Sequence[int]
                       ) -> Tuple[Params, jax.Array]:
    """Repack the (L, ...) stacked blocks into (S, max_c, ...) + mask.

    Padding slots replicate block 0 (they are identity-masked at apply
    time, so the values never matter)."""
    s = len(counts)
    max_c = max(counts)
    offsets = np.concatenate([[0], np.cumsum(counts)])
    mask = np.zeros((s, max_c), np.bool_)
    for i, c in enumerate(counts):
        mask[i, :c] = True

    def repack(leaf):
        parts = []
        for i, c in enumerate(counts):
            seg = leaf[offsets[i]:offsets[i + 1]]
            if c < max_c:
                pad = jnp.broadcast_to(leaf[:1],
                                       (max_c - c,) + leaf.shape[1:])
                seg = jnp.concatenate([seg, pad], axis=0)
            parts.append(seg)
        return jnp.stack(parts, axis=0)

    return jax.tree.map(repack, blocks), jnp.asarray(mask)


def _stage_apply(cfg: LMConfig, blocks_local: Params, mask_local: jax.Array,
                 x: jax.Array, positions: jax.Array) -> jax.Array:
    fn = lm._block_fn(cfg)

    def body(x, xs):
        bp, m = xs
        y = fn(x, bp, positions)
        return jnp.where(m, y, x), None

    x, _ = jax.lax.scan(body, x, (blocks_local, mask_local))
    return x


def make_pipeline_hidden(cfg: LMConfig, mesh: Mesh, plan: PlacementPlan,
                         n_microbatches: int, stage_axis: str = "model"):
    """Returns hidden_fn(params, batch) -> (B, S, D) hidden states, with the
    blocks executed as a `stage_axis`-wide pipeline per the plan."""
    _require_unreplicated(plan)
    n_stages = mesh.shape[stage_axis]
    assert plan.n_stages == n_stages, (plan.n_stages, n_stages)
    counts = stage_block_counts(plan, cfg.n_layers)
    m = n_microbatches

    def hidden_fn(params: Params, batch: Dict[str, jax.Array]) -> jax.Array:
        x = lm.embed_tokens(cfg, params, batch["tokens"])
        if cfg.family == "vlm" and "embeds" in batch:
            x = jnp.concatenate([batch["embeds"].astype(x.dtype), x], axis=1)
        b, s, d = x.shape
        assert b % m == 0, (b, m)
        mb = b // m
        positions = jnp.arange(s)[None, :]
        if cfg.family == "vlm":
            positions = jnp.broadcast_to(positions[None], (3, 1, s))
        stage_blocks, mask = build_stage_blocks(params["blocks"], counts)
        x_mb = x.reshape(m, mb, s, d)

        @functools.partial(
            _shard_map, mesh=mesh,
            in_specs=(P(stage_axis), P(stage_axis), P()),
            out_specs=P(),
            **_SHMAP_NOCHECK)
        def pipe(blocks_sh, mask_sh, x_all):
            blocks_l = jax.tree.map(lambda a: a[0], blocks_sh)
            mask_l = mask_sh[0]
            sid = jax.lax.axis_index(stage_axis)
            state = jnp.zeros((mb, s, d), x_all.dtype)
            outputs = jnp.zeros((m, mb, s, d), x_all.dtype)
            perm = [(i, (i + 1) % n_stages) for i in range(n_stages)]

            def step(t, carry):
                state, outputs = carry
                inj = x_all[jnp.clip(t, 0, m - 1)]
                inp = jnp.where(jnp.logical_and(sid == 0, t < m), inj, state)
                out = _stage_apply(cfg, blocks_l, mask_l, inp, positions)
                widx = t - (n_stages - 1)
                write = jnp.logical_and(sid == n_stages - 1,
                                        jnp.logical_and(widx >= 0, widx < m))
                upd = jax.lax.dynamic_update_slice(
                    outputs, out[None], (jnp.clip(widx, 0, m - 1), 0, 0, 0))
                outputs = jnp.where(write, upd, outputs)
                state = jax.lax.ppermute(out, stage_axis, perm)
                return state, outputs

            _, outputs = jax.lax.fori_loop(0, m + n_stages - 1, step,
                                           (state, outputs))
            # outputs are valid only on the last stage; sum-over-stages
            # broadcasts them (all other stages contribute zeros)
            outputs = jnp.where(sid == n_stages - 1, outputs, 0.0)
            return jax.lax.psum(outputs, stage_axis)

        out = pipe(stage_blocks, mask, x_mb)
        return out.reshape(b, s, d)

    return hidden_fn


def pipeline_logits(cfg: LMConfig, mesh: Mesh, plan: PlacementPlan,
                    params: Params, batch: Dict[str, jax.Array],
                    n_microbatches: int = 4) -> jax.Array:
    hidden_fn = make_pipeline_hidden(cfg, mesh, plan, n_microbatches)
    h = hidden_fn(params, batch)
    return lm.unembed(cfg, params, h)
