"""SPMD pipeline execution: lower a PlacementPlan onto a device mesh.

The host-threaded executor (core/pipeline.py) is paper-faithful for a PCIe
card of Edge TPUs; on a pod the stage-to-stage hop is a
``jax.lax.ppermute`` over ICI inside ``shard_map``.  This module lowers
*any* unreplicated :class:`~repro.core.placement.PlacementPlan` onto a mesh
axis:

* **CNN GraphModels** — each stage's layer range is fused into one traced
  per-stage callable built on ``GraphModel.apply_subset``; the tensors
  crossing each cut (skip connections included — a tensor produced in
  stage 0 and consumed in stage 3 rides through the intermediate stages)
  are flat-packed into one fixed-size ``(microbatch, FLAT)`` f32 buffer so
  every stage has a uniform signature, selected per device with
  ``jax.lax.switch`` on the stage index.
* **LM scan-block families** — contiguous block ranges per stage.  Uneven
  per-stage block counts are executed *without* the identity-masked
  padding tax: stages are grouped by distinct count and each group scans a
  statically-sliced ``blocks[:c]`` inside a ``lax.switch`` branch (a plan
  with equal counts compiles to a plain scan, no switch at all).

GPipe circular schedule, M microbatches over S stages::

    t = 0 .. M+S-2:
      stage 0 injects microbatch t (while t < M)
      every stage applies its fused range to its current input
      outputs rotate to the next stage via ppermute
      stage S-1 emits microbatch t-S+1

Output collection is a **last-stage-only gather** (``out_specs``
sharded over the stage axis; the host reads the final shard) — not the
previous O(S) ``psum`` broadcast that materialized the full output buffer
on every device.

**Weight streaming** (:func:`stream_stage_weights`): per-stage weight
shards are placed on their pipeline devices with asynchronous transfers
issued in stage order — stage *k+1*'s copy is in flight while stage *k*'s
lands — and the pipeline's AOT compilation runs while they land, so the
non-amortizing ``t_weight_load`` fill the placement DP models is
overlapped with bring-up instead of serialized in front of it.  The
:class:`StreamReport` separates the wall fill from ``blocked_s`` — the
time the host spent *waiting* on transfers.  Overlapped streaming drives
``blocked_s`` to ~0 (the transfers land behind the compile) on any
backend; the *wall* fill only shrinks where transfers have their own DMA
engine (real TPUs) — on the CPU-emulated mesh host-to-device copies run
on the same worker pool and memory bus as every other XLA operation, so
wall time is conserved no matter the issue order, and ``blocked_s`` is
the number the benchmark asserts on.

:class:`SpmdPipelineExecutor` wraps the lowering behind the
``Deployment.executor(backend="spmd")`` front door, with buffer donation
(``donate_argnums``) on the inter-stage microbatch buffer, batch padding
for microbatch counts that do not divide the batch, and per-stage
predicted-vs-achieved probes for the modeled-vs-real loop.

Replicated-stage plans belong to the host executor:
:func:`_require_unreplicated` fails fast for direct low-level calls, and
the front door (``Deployment.executor``) downgrades that to a logged
fallback onto :class:`~repro.core.pipeline.PipelineExecutor`.
"""
from __future__ import annotations

import functools
import time
import warnings
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

if hasattr(jax, "shard_map"):                      # jax >= 0.6
    _shard_map = jax.shard_map
    _SHMAP_NOCHECK = {"check_vma": False}
else:                                              # jax 0.4.x
    from jax.experimental.shard_map import shard_map as _shard_map
    _SHMAP_NOCHECK = {"check_rep": False}

from ..core.placement import PlacementPlan
from ..models.layers import GraphModel

Params = Any

# the CPU backend cannot always honor donation; the result is correct,
# the warning is noise on the emulated mesh
_DONATION_NOISE = "Some donated buffers were not usable"


# ---------------------------------------------------------------------------
# plan-side helpers
# ---------------------------------------------------------------------------
def stage_block_counts(plan: PlacementPlan, n_blocks: int) -> List[int]:
    """Blocks per stage from a plan over the full LayerGraph (embed +
    block_i + final_norm/head nodes): count only block_* layers."""
    counts = []
    for layers in plan.stage_layers:
        counts.append(sum(1 for l in layers if l.startswith("block_")))
    assert sum(counts) == n_blocks, (counts, n_blocks)
    return counts


def plan_supports_spmd(plan: PlacementPlan) -> bool:
    """One stage == one mesh slice: replicated stages need the host
    executor's round-robin fan-out."""
    reps = getattr(plan, "replica_counts", None)
    return not (reps and any(r != 1 for r in reps))


def _require_unreplicated(plan: PlacementPlan) -> None:
    """Hard error for direct low-level calls; the ``Deployment.executor``
    front door checks :func:`plan_supports_spmd` first and falls back to
    the host executor with a logged notice instead of reaching this."""
    if not plan_supports_spmd(plan):
        raise NotImplementedError(
            f"SPMD pipeline does not support replicated stages "
            f"(replica_counts={plan.replica_counts}); use the host "
            f"PipelineExecutor or re-plan with replicate=False")


def _stage_devices(mesh: Mesh, stage_axis: str) -> List[Any]:
    """One representative device per pipeline stage (the first of each
    mesh slice along ``stage_axis``)."""
    ax = list(mesh.axis_names).index(stage_axis)
    grid = np.moveaxis(np.asarray(mesh.devices), ax, 0)
    return [grid[s].flat[0] for s in range(grid.shape[0])]


def default_stage_mesh(n_stages: int, stage_axis: str = "model") -> Mesh:
    """A (1, S) mesh over the first S local devices (tests / benches force
    the device count via XLA_FLAGS=--xla_force_host_platform_device_count)."""
    devs = jax.devices()
    if len(devs) < n_stages:
        raise ValueError(
            f"SPMD pipeline needs >= {n_stages} devices for {n_stages} "
            f"stages; this process sees {len(devs)} (force a host mesh "
            f"with XLA_FLAGS=--xla_force_host_platform_device_count="
            f"{n_stages} before the first jax import)")
    return Mesh(np.asarray(devs[:n_stages]).reshape(1, n_stages),
                ("data", stage_axis))


# ---------------------------------------------------------------------------
# the circular GPipe schedule (shared by the CNN and LM lowerings)
# ---------------------------------------------------------------------------
def _gpipe_outputs(stage_apply: Callable[[jax.Array], jax.Array],
                   sid: jax.Array, x_all: jax.Array, n_stages: int,
                   stage_axis: str) -> jax.Array:
    """Run the schedule inside shard_map; returns the (m, mb, ...) outputs
    buffer, valid on the last stage only (callers gather that shard)."""
    m = x_all.shape[0]
    state = jnp.zeros_like(x_all[0])
    outputs = jnp.zeros_like(x_all)
    perm = [(i, (i + 1) % n_stages) for i in range(n_stages)]

    def step(t, carry):
        state, outputs = carry
        inj = x_all[jnp.clip(t, 0, m - 1)]
        inp = jnp.where(jnp.logical_and(sid == 0, t < m), inj, state)
        out = stage_apply(inp)
        widx = t - (n_stages - 1)
        write = jnp.logical_and(sid == n_stages - 1,
                                jnp.logical_and(widx >= 0, widx < m))
        upd = jax.lax.dynamic_update_slice(
            outputs, out[None], (jnp.clip(widx, 0, m - 1),) + (0,) * out.ndim)
        outputs = jnp.where(write, upd, outputs)
        state = jax.lax.ppermute(out, stage_axis, perm)
        return state, outputs

    _, outputs = jax.lax.fori_loop(0, m + n_stages - 1, step,
                                   (state, outputs))
    return outputs


# ---------------------------------------------------------------------------
# LM lowering: contiguous block ranges, unpadded uneven stages
# ---------------------------------------------------------------------------
def build_stage_blocks(blocks: Params, counts: Sequence[int]
                       ) -> Tuple[Params, jax.Array]:
    """Repack the (L, ...) stacked blocks into (S, max_c, ...) + count mask.

    Padding slots replicate block 0; the unpadded switch path never reads
    them (each stage scans a static ``[:count]`` slice), the mask is kept
    for callers that still want the identity-masked view."""
    s = len(counts)
    max_c = max(counts)
    offsets = np.concatenate([[0], np.cumsum(counts)])
    mask = np.zeros((s, max_c), np.bool_)
    for i, c in enumerate(counts):
        mask[i, :c] = True

    def repack(leaf):
        parts = []
        for i, c in enumerate(counts):
            seg = leaf[offsets[i]:offsets[i + 1]]
            if c < max_c:
                pad = jnp.broadcast_to(leaf[:1],
                                       (max_c - c,) + leaf.shape[1:])
                seg = jnp.concatenate([seg, pad], axis=0)
            parts.append(seg)
        return jnp.stack(parts, axis=0)

    return jax.tree.map(repack, blocks), jnp.asarray(mask)


def _lm_stage_apply_builder(cfg, counts: Sequence[int]):
    """Per-device stage body: scan exactly this stage's blocks.

    Equal counts compile to one plain scan; uneven counts become a
    ``lax.switch`` over the *distinct* counts, each branch scanning a
    statically-sliced ``blocks[:c]`` — no identity-masked padding compute."""
    from ..models import lm
    distinct = sorted(set(counts))
    count_idx = np.asarray([distinct.index(c) for c in counts], np.int32)

    def make(blocks_l, positions, sid):
        fn = lm._block_fn(cfg)

        def scan_c(c):
            def apply_c(x):
                if c == 0:
                    return x

                def body(x, bp):
                    return fn(x, bp, positions), None

                sliced = jax.tree.map(lambda a: a[:c], blocks_l)
                x, _ = jax.lax.scan(body, x, sliced)
                return x

            return apply_c

        if len(distinct) == 1:
            return scan_c(distinct[0])
        branches = [scan_c(c) for c in distinct]
        my_idx = jnp.asarray(count_idx)[sid]
        return lambda x: jax.lax.switch(my_idx, branches, x)

    return make


def make_pipeline_hidden(cfg, mesh: Mesh, plan: PlacementPlan,
                         n_microbatches: int, stage_axis: str = "model",
                         donate: bool = True):
    """Returns hidden_fn(params, batch) -> (B, S, D) hidden states, with the
    blocks executed as a `stage_axis`-wide pipeline per the plan."""
    from ..models import lm
    _require_unreplicated(plan)
    n_stages = mesh.shape[stage_axis]
    assert plan.n_stages == n_stages, (plan.n_stages, n_stages)
    counts = stage_block_counts(plan, cfg.n_layers)
    m = n_microbatches
    apply_builder = _lm_stage_apply_builder(cfg, counts)

    @functools.partial(_shard_map, mesh=mesh,
                       in_specs=(P(stage_axis), P(), P()),
                       out_specs=P(stage_axis), **_SHMAP_NOCHECK)
    def pipe(blocks_sh, x_all, positions):
        blocks_l = jax.tree.map(lambda a: a[0], blocks_sh)
        sid = jax.lax.axis_index(stage_axis)
        stage_apply = apply_builder(blocks_l, positions, sid)
        outputs = _gpipe_outputs(stage_apply, sid, x_all, n_stages,
                                 stage_axis)
        # last-stage-only gather: each device contributes its (m, mb, s, d)
        # block; the host reads shard S-1 instead of a psum broadcast
        return outputs[None]

    pipe_jit = jax.jit(pipe, donate_argnums=(1,) if donate else ())

    def hidden_fn(params: Params, batch: Dict[str, jax.Array]) -> jax.Array:
        x = lm.embed_tokens(cfg, params, batch["tokens"])
        if cfg.family == "vlm" and "embeds" in batch:
            x = jnp.concatenate([batch["embeds"].astype(x.dtype), x], axis=1)
        b, s, d = x.shape
        assert b % m == 0, (b, m)
        mb = b // m
        positions = jnp.arange(s)[None, :]
        if cfg.family == "vlm":
            positions = jnp.broadcast_to(positions[None], (3, 1, s))
        stage_blocks, _ = build_stage_blocks(params["blocks"], counts)
        x_mb = x.reshape(m, mb, s, d)
        with warnings.catch_warnings():
            warnings.filterwarnings("ignore", message=_DONATION_NOISE)
            out = pipe_jit(stage_blocks, x_mb, positions)
        return jax.device_get(out[-1]).reshape(b, s, d)

    return hidden_fn


def pipeline_logits(cfg, mesh: Mesh, plan: PlacementPlan,
                    params: Params, batch: Dict[str, jax.Array],
                    n_microbatches: int = 4) -> jax.Array:
    from ..models import lm
    hidden_fn = make_pipeline_hidden(cfg, mesh, plan, n_microbatches)
    h = hidden_fn(params, batch)
    return lm.unembed(cfg, params, h)


# ---------------------------------------------------------------------------
# CNN lowering: fused apply_subset ranges behind flat boundary buffers
# ---------------------------------------------------------------------------
def _cnn_stage_of(model: GraphModel, plan: PlacementPlan) -> Dict[str, int]:
    stage_of: Dict[str, int] = {}
    for s, layers in enumerate(plan.stage_layers):
        for name in layers:
            stage_of[name] = s
    missing = [n for n in model._order if n not in stage_of]
    if missing:
        raise ValueError(f"plan does not cover model layers {missing[:5]}; "
                         f"was it planned over {model.name}'s LayerGraph?")
    return stage_of


def cnn_boundary_specs(model: GraphModel, plan: PlacementPlan
                       ) -> Tuple[List[List[Tuple[str, Tuple[int, ...]]]],
                                  List[Tuple[str, Tuple[int, ...]]]]:
    """Per-stage input boundaries as ordered ``(name, shape)`` lists.

    ``B[s]`` is everything stage ``s`` reads that it does not compute:
    the model input for stage 0, and for later stages every tensor
    produced at a stage ``< s`` with a consumer at a stage ``>= s``
    (skip connections make these multi-tensor and make tensors ride
    through intermediate stages unchanged).  Also returns the packed
    output spec of the last stage."""
    S = plan.n_stages
    stage_of = _cnn_stage_of(model, plan)
    consumers: Dict[str, List[str]] = {}
    for name in model._order:
        for i in model.nodes[name].inputs:
            consumers.setdefault(i, []).append(name)
    B: List[List[Tuple[str, Tuple[int, ...]]]] = [
        [(GraphModel.INPUT, tuple(model.input_shape))]]
    for s in range(1, S):
        names: List[Tuple[str, Tuple[int, ...]]] = []
        if any(stage_of[c] >= s
               for c in consumers.get(GraphModel.INPUT, ())):
            names.append((GraphModel.INPUT, tuple(model.input_shape)))
        for name in model._order:
            if stage_of[name] >= s:
                continue
            if any(stage_of[c] >= s for c in consumers.get(name, ())):
                names.append((name, tuple(model.nodes[name].out_shape)))
        B.append(names)
    assert model.output is not None
    out_spec = [(model.output, tuple(model.nodes[model.output].out_shape))]
    return B, out_spec


def _specs_elems(specs: Sequence[Tuple[str, Tuple[int, ...]]]) -> int:
    return int(sum(int(np.prod(shape)) for _, shape in specs))


def _pack(acts: Dict[str, jax.Array],
          specs: Sequence[Tuple[str, Tuple[int, ...]]],
          flat: int) -> jax.Array:
    mb = next(iter(acts.values())).shape[0]
    parts = [acts[name].reshape(mb, -1).astype(jnp.float32)
             for name, _ in specs]
    buf = jnp.concatenate(parts, axis=1)
    if buf.shape[1] < flat:
        buf = jnp.pad(buf, ((0, 0), (0, flat - buf.shape[1])))
    return buf


def _unpack(buf: jax.Array,
            specs: Sequence[Tuple[str, Tuple[int, ...]]]
            ) -> Dict[str, jax.Array]:
    out: Dict[str, jax.Array] = {}
    off = 0
    for name, shape in specs:
        n = int(np.prod(shape))
        out[name] = buf[:, off:off + n].reshape((buf.shape[0],)
                                                + tuple(shape))
        off += n
    return out


def _flatten_stage_params(params: Params, layer_names: Sequence[str]):
    """One f32 vector per stage + the layout to rebuild the subtree inside
    a traced branch (uniform with the LM stacked blocks for streaming)."""
    sub = {n: params[n] for n in layer_names if n in params and params[n]}
    leaves, treedef = jax.tree.flatten(sub)
    layout = [(tuple(np.shape(l)), jnp.asarray(l).dtype) for l in leaves]
    if leaves:
        flat = np.concatenate([np.asarray(l, np.float32).ravel()
                               for l in leaves])
    else:
        flat = np.zeros((0,), np.float32)
    return flat, treedef, layout


def _unflatten_stage_params(w: jax.Array, treedef, layout) -> Params:
    leaves, off = [], 0
    for shape, dtype in layout:
        n = int(np.prod(shape)) if shape else 1
        leaves.append(w[off:off + n].reshape(shape).astype(dtype))
        off += n
    return jax.tree.unflatten(treedef, leaves)


def make_cnn_pipeline(model: GraphModel, plan: PlacementPlan, mesh: Mesh,
                      n_microbatches: int, stage_axis: str = "model",
                      donate: bool = True):
    """Boundary/packing metadata for lowering a CNN GraphModel + plan.

    Returns ``(B, out_spec, flat, make_branch)``: the per-stage input
    boundary specs, the packed output spec, the flat buffer width, and a
    factory ``make_branch(s, treedef, layout)`` producing stage ``s``'s
    fused callable ``branch(w_row, buf) -> buf`` (unpack boundary →
    ``apply_subset`` over the stage's layer range → pack the next
    boundary).  :class:`SpmdPipelineExecutor.for_cnn` assembles these into
    the jitted shard_map program; the branches are also used stand-alone
    by the achieved-time probes."""
    _require_unreplicated(plan)
    n_stages = mesh.shape[stage_axis]
    assert plan.n_stages == n_stages, (plan.n_stages, n_stages)
    B, out_spec = cnn_boundary_specs(model, plan)
    flat = max(max(_specs_elems(b) for b in B), _specs_elems(out_spec))
    stage_layers = plan.stage_layers

    def make_branch(s: int, treedef, layout):
        in_specs = B[s]
        nxt = B[s + 1] if s + 1 < n_stages else out_spec

        def branch(w_row: jax.Array, buf: jax.Array) -> jax.Array:
            stage_params = _unflatten_stage_params(w_row, treedef, layout)
            boundary = _unpack(buf, in_specs)
            acts = model.apply_subset(stage_params, boundary,
                                      stage_layers[s])
            avail = {**boundary, **acts}
            return _pack(avail, nxt, flat)

        return branch

    return B, out_spec, flat, make_branch


class _CnnLowering:
    """Everything the executor needs for one CNN plan on one mesh."""

    def __init__(self, model: GraphModel, params: Params,
                 plan: PlacementPlan, mesh: Mesh, n_microbatches: int,
                 stage_axis: str, donate: bool):
        self.model, self.plan, self.mesh = model, plan, mesh
        self.stage_axis, self.m = stage_axis, n_microbatches
        n_stages = plan.n_stages
        B, out_spec, flat, make_branch = make_cnn_pipeline(
            model, plan, mesh, n_microbatches, stage_axis, donate)
        self.B, self.out_spec, self.flat = B, out_spec, flat

        flats, self.branches = [], []
        for s in range(n_stages):
            w, treedef, layout = _flatten_stage_params(
                params, plan.stage_layers[s])
            flats.append(w)
            self.branches.append(make_branch(s, treedef, layout))
        wmax = max(1, max(f.size for f in flats))
        self.stacked_host = np.stack(
            [np.pad(f, (0, wmax - f.size)) for f in flats])   # (S, Wmax)

        @functools.partial(_shard_map, mesh=mesh,
                           in_specs=(P(stage_axis), P()),
                           out_specs=P(stage_axis), **_SHMAP_NOCHECK)
        def pipe(weights_sh, x_all):
            w_row = weights_sh[0]
            sid = jax.lax.axis_index(stage_axis)
            branches = self.branches

            def stage_apply(buf):
                return jax.lax.switch(sid, branches, w_row, buf)

            outputs = _gpipe_outputs(stage_apply, sid, x_all, n_stages,
                                     stage_axis)
            return outputs[None]        # last-stage-only gather

        self.pipe_jit = jax.jit(pipe,
                                donate_argnums=(1,) if donate else ())

    def pack_input(self, x: jax.Array) -> jax.Array:
        b = x.shape[0]
        mb = b // self.m
        buf = _pack({GraphModel.INPUT: x}, self.B[0], self.flat)
        return buf.reshape(self.m, mb, self.flat)

    def unpack_output(self, out_last: jax.Array, b: int) -> jax.Array:
        m, mb, _ = out_last.shape
        name, shape = self.out_spec[0]
        flat_out = out_last.reshape(m * mb, self.flat)
        n = int(np.prod(shape))
        return flat_out[:b, :n].reshape((b,) + tuple(shape))


# ---------------------------------------------------------------------------
# overlapped weight streaming
# ---------------------------------------------------------------------------
class StreamReport:
    """Timing record of one :func:`stream_stage_weights` call.

    * ``fill_s`` — wall-clock bring-up fill: transfers + compile.
    * ``blocked_s`` — the part of ``fill_s`` the host spent *waiting* on
      transfers (``block_until_ready``).  This is what overlapped issue
      eliminates: the transfers land behind the compile and the final
      drain finds them done.  The wall fill only shrinks too where
      transfers have a DMA engine of their own (real accelerators); on a
      CPU-emulated mesh host-to-device copies share the worker pool and
      memory bus with all other XLA work, so wall time is conserved and
      ``blocked_s`` is the honest overlap metric.
    """

    __slots__ = ("fill_s", "blocked_s")

    def __init__(self, fill_s: float, blocked_s: float):
        self.fill_s = fill_s
        self.blocked_s = blocked_s

    def __repr__(self):
        return (f"StreamReport(fill_s={self.fill_s:.4f}, "
                f"blocked_s={self.blocked_s:.4f})")


def stream_stage_weights(mesh: Mesh, stacked: Params,
                         stage_axis: str = "model", *,
                         overlap: bool = True,
                         compile_fn: Optional[Callable[[], Any]] = None
                         ) -> Tuple[Params, Any, StreamReport]:
    """Place per-stage weight shards on their pipeline devices.

    ``stacked`` is a pytree of host arrays with leading dimension S (the
    stage axis); each stage's slice lands on that stage's mesh devices,
    sharded ``P(stage_axis)``.

    * ``overlap=True`` — double-buffered streaming: per-stage transfers
      are *issued* asynchronously in stage order (stage k+1's copy is in
      flight while stage k's lands) and ``compile_fn`` — typically the
      pipeline's AOT compile, the bring-up work that needs only shapes —
      runs while they land.
    * ``overlap=False`` — the non-overlapped reference: each stage's
      transfer completes before the next stage's is issued, and
      ``compile_fn`` runs only after the last one landed.

    Returns ``(global_tree, compile_result, report)`` where ``report``
    is a :class:`StreamReport` (wall fill + host-blocked seconds)."""
    leaves, treedef = jax.tree.flatten(stacked)
    leaves = [np.asarray(l) for l in leaves]
    shardings = [NamedSharding(mesh, P(*([stage_axis]
                                         + [None] * (l.ndim - 1))))
                 for l in leaves]
    ax = list(mesh.axis_names).index(stage_axis)
    grid = np.moveaxis(np.asarray(mesh.devices), ax, 0)
    stage_of_dev = {d.id: s for s in range(grid.shape[0])
                    for d in grid[s].flat}
    puts = []                       # (stage, device, leaf_idx, nd_index)
    for li, (leaf, sh) in enumerate(zip(leaves, shardings)):
        for dev, index in sh.addressable_devices_indices_map(
                leaf.shape).items():
            puts.append((stage_of_dev[dev.id], dev, li, index))
    puts.sort(key=lambda r: r[0])

    shards: Dict[int, List[Any]] = {li: [] for li in range(len(leaves))}
    compiled = None
    blocked_s = 0.0
    t0 = time.perf_counter()
    if overlap:
        for _, dev, li, index in puts:
            shards[li].append(jax.device_put(leaves[li][index], dev))
        if compile_fn is not None:
            compiled = compile_fn()
        tw = time.perf_counter()
        for arrs in shards.values():
            for a in arrs:
                a.block_until_ready()
        blocked_s = time.perf_counter() - tw
    else:
        def drain(pending):
            nonlocal blocked_s
            tw = time.perf_counter()
            for a in pending:
                a.block_until_ready()
            blocked_s += time.perf_counter() - tw

        cur, pending = None, []
        for s, dev, li, index in puts:
            if cur is not None and s != cur:
                drain(pending)
                pending = []
            cur = s
            a = jax.device_put(leaves[li][index], dev)
            pending.append(a)
            shards[li].append(a)
        drain(pending)
        if compile_fn is not None:
            compiled = compile_fn()
    fill_s = time.perf_counter() - t0

    glb = [jax.make_array_from_single_device_arrays(
               leaves[li].shape, shardings[li], shards[li])
           for li in range(len(leaves))]
    return (jax.tree.unflatten(treedef, glb), compiled,
            StreamReport(fill_s, blocked_s))


# ---------------------------------------------------------------------------
# the executor
# ---------------------------------------------------------------------------
class SpmdPipelineExecutor:
    """Run an unreplicated PlacementPlan as a shard_map pipeline.

    Mirrors the host :class:`~repro.core.pipeline.PipelineExecutor`'s
    batch surface (``run_batch`` / ``close`` / context manager;
    ``start``/``stop`` are no-ops — there are no worker threads) and adds
    the modeled-vs-real probes the SPMD tier exists for:

    * :attr:`fill_s` / :attr:`fill_blocked_s` — bring-up fill cost
      (weight streaming + compile) and the host-blocked part of it,
      overlapped or serial per ``overlap_streaming`` (see
      :class:`StreamReport`).
    * :meth:`predicted_stage_times` — the plan's modeled per-stage times.
    * :meth:`achieved_stage_times` — each stage's fused callable timed in
      isolation on its own mesh device.
    """

    def __init__(self, *, kind: str, plan: PlacementPlan, mesh: Mesh,
                 stage_axis: str, n_microbatches: int, fill_s: float,
                 overlap_streaming: bool, run_fn: Callable,
                 probe_fns: List[Callable[[], Callable[[], Any]]],
                 fill_blocked_s: float = 0.0):
        self.kind = kind
        self.plan = plan
        self.mesh = mesh
        self.stage_axis = stage_axis
        self.n_microbatches = n_microbatches
        self.fill_s = fill_s
        self.fill_blocked_s = fill_blocked_s
        self.overlap_streaming = overlap_streaming
        self._run = run_fn
        self._probe_fns = probe_fns
        self._closed = False

    # -- construction -------------------------------------------------------
    @classmethod
    def for_model(cls, model, params, plan: PlacementPlan, **kw
                  ) -> "SpmdPipelineExecutor":
        """Dispatch on the model object: a GraphModel lowers via
        apply_subset ranges, an LM config via scan-block ranges."""
        if isinstance(model, GraphModel):
            return cls.for_cnn(model, params, plan, **kw)
        if hasattr(model, "n_layers") and hasattr(model, "family"):
            return cls.for_lm(model, params, plan, **kw)
        raise TypeError(f"cannot lower {type(model).__name__} onto the "
                        f"SPMD pipeline; pass a GraphModel or an LMConfig")

    @classmethod
    def for_cnn(cls, model: GraphModel, params: Params,
                plan: PlacementPlan, *, mesh: Optional[Mesh] = None,
                n_microbatches: int = 4, stage_axis: str = "model",
                overlap_streaming: bool = True, donate: bool = True,
                batch_size: Optional[int] = None) -> "SpmdPipelineExecutor":
        _require_unreplicated(plan)
        if mesh is None:
            mesh = default_stage_mesh(plan.n_stages, stage_axis)
        low = _CnnLowering(model, params, plan, mesh, n_microbatches,
                           stage_axis, donate)
        m = n_microbatches

        compile_fn, aot_shape = None, None
        if batch_size is not None:
            bp0 = -(-batch_size // m) * m
            aot_shape = (m, bp0 // m, low.flat)
            x_struct = jax.ShapeDtypeStruct(
                aot_shape, jnp.float32,
                sharding=NamedSharding(mesh, P()))
            w_struct = jax.ShapeDtypeStruct(
                low.stacked_host.shape, jnp.float32,
                sharding=NamedSharding(mesh, P(stage_axis)))
            compile_fn = lambda: low.pipe_jit.lower(
                w_struct, x_struct).compile()
        weights, compiled, stream = stream_stage_weights(
            mesh, low.stacked_host, stage_axis,
            overlap=overlap_streaming, compile_fn=compile_fn)
        repl = NamedSharding(mesh, P())

        def run(x: jax.Array) -> jax.Array:
            b = x.shape[0]
            bp = -(-b // m) * m
            if bp != b:
                pad = jnp.broadcast_to(x[:1], (bp - b,) + x.shape[1:])
                x = jnp.concatenate([x, pad], axis=0)
            x_all = jax.device_put(
                low.pack_input(jnp.asarray(x, jnp.float32)), repl)
            with warnings.catch_warnings():
                warnings.filterwarnings("ignore", message=_DONATION_NOISE)
                if compiled is not None and x_all.shape == aot_shape:
                    out = compiled(weights, x_all)
                else:
                    out = low.pipe_jit(weights, x_all)
            return low.unpack_output(jax.device_get(out[-1]), b)

        devs = _stage_devices(mesh, stage_axis)
        mb_probe = max(1, (batch_size or m) // m)

        def make_probe(s):
            def build():
                w_row = jax.device_put(low.stacked_host[s], devs[s])
                buf = jax.device_put(
                    np.zeros((mb_probe, low.flat), np.float32), devs[s])
                fn = jax.jit(low.branches[s])

                def probe():
                    return fn(w_row, buf).block_until_ready()

                return probe

            return build

        return cls(kind="cnn", plan=plan, mesh=mesh, stage_axis=stage_axis,
                   n_microbatches=m, fill_s=stream.fill_s,
                   fill_blocked_s=stream.blocked_s,
                   overlap_streaming=overlap_streaming, run_fn=run,
                   probe_fns=[make_probe(s) for s in range(plan.n_stages)])

    @classmethod
    def for_lm(cls, cfg, params: Params, plan: PlacementPlan, *,
               mesh: Optional[Mesh] = None, n_microbatches: int = 4,
               stage_axis: str = "model", overlap_streaming: bool = True,
               donate: bool = True, batch_size: Optional[int] = None,
               seq_len: Optional[int] = None) -> "SpmdPipelineExecutor":
        from ..models import lm
        _require_unreplicated(plan)
        if cfg.family not in ("dense", "moe"):
            raise ValueError(f"SPMD LM executor supports the dense/moe "
                             f"scan-block families, not {cfg.family!r}")
        if mesh is None:
            mesh = default_stage_mesh(plan.n_stages, stage_axis)
        n_stages = plan.n_stages
        counts = stage_block_counts(plan, cfg.n_layers)
        m = n_microbatches
        apply_builder = _lm_stage_apply_builder(cfg, counts)

        stacked_dev, _ = build_stage_blocks(params["blocks"], counts)
        stacked_host = jax.tree.map(np.asarray, stacked_dev)
        rest = {k: v for k, v in params.items() if k != "blocks"}

        @functools.partial(_shard_map, mesh=mesh,
                           in_specs=(P(stage_axis), P(), P()),
                           out_specs=P(stage_axis), **_SHMAP_NOCHECK)
        def pipe(blocks_sh, x_all, positions):
            blocks_l = jax.tree.map(lambda a: a[0], blocks_sh)
            sid = jax.lax.axis_index(stage_axis)
            stage_apply = apply_builder(blocks_l, positions, sid)
            outputs = _gpipe_outputs(stage_apply, sid, x_all, n_stages,
                                     stage_axis)
            return outputs[None]

        pipe_jit = jax.jit(pipe, donate_argnums=(1,) if donate else ())
        embed_jit = jax.jit(
            lambda p, tok: lm.embed_tokens(cfg, p, tok))
        unembed_jit = jax.jit(
            lambda p, h: lm.unembed(cfg, p, h))

        compile_fn, aot_shape = None, None
        if batch_size is not None and seq_len is not None:
            bp0 = -(-batch_size // m) * m
            aot_shape = (m, bp0 // m, seq_len, cfg.d_model)
            x_struct = jax.ShapeDtypeStruct(
                aot_shape, jnp.float32,
                sharding=NamedSharding(mesh, P()))
            pos_struct = jax.ShapeDtypeStruct((1, seq_len), jnp.int32,
                                              sharding=NamedSharding(
                                                  mesh, P()))
            b_structs = jax.tree.map(
                lambda a: jax.ShapeDtypeStruct(
                    a.shape, a.dtype,
                    sharding=NamedSharding(
                        mesh, P(*([stage_axis]
                                  + [None] * (a.ndim - 1))))),
                stacked_host)
            compile_fn = lambda: pipe_jit.lower(
                b_structs, x_struct, pos_struct).compile()
        blocks_glb, compiled, stream = stream_stage_weights(
            mesh, stacked_host, stage_axis,
            overlap=overlap_streaming, compile_fn=compile_fn)
        repl = NamedSharding(mesh, P())

        def run(tokens: jax.Array) -> jax.Array:
            b = tokens.shape[0]
            bp = -(-b // m) * m
            if bp != b:
                pad = jnp.broadcast_to(tokens[:1],
                                       (bp - b,) + tokens.shape[1:])
                tokens = jnp.concatenate([tokens, pad], axis=0)
            x = embed_jit(rest, tokens)
            _, s, d = x.shape
            positions = jax.device_put(jnp.arange(s)[None, :], repl)
            x_mb = jax.device_put(
                jnp.asarray(x, jnp.float32).reshape(m, bp // m, s, d),
                repl)
            with warnings.catch_warnings():
                warnings.filterwarnings("ignore", message=_DONATION_NOISE)
                if compiled is not None and x_mb.shape == aot_shape:
                    out = compiled(blocks_glb, x_mb, positions)
                else:
                    out = pipe_jit(blocks_glb, x_mb, positions)
            h = jax.device_get(out[-1]).reshape(bp, s, d)
            return unembed_jit(rest, jnp.asarray(h))[:b]

        devs = _stage_devices(mesh, stage_axis)
        mb_probe = max(1, (batch_size or m) // m)
        probe_seq = seq_len or 16

        def make_probe(s):
            def build():
                c = counts[s]
                blocks_s = jax.tree.map(
                    lambda a: jax.device_put(a[s, :max(c, 1)], devs[s]),
                    stacked_host)
                x0 = jax.device_put(
                    np.zeros((mb_probe, probe_seq, cfg.d_model),
                             np.float32), devs[s])
                positions = jax.device_put(
                    np.arange(probe_seq, dtype=np.int32)[None, :], devs[s])
                fn = lm._block_fn(cfg)

                @jax.jit
                def stage(blocks_s, x, positions):
                    if c == 0:
                        return x

                    def body(x, bp):
                        return fn(x, bp, positions), None

                    x, _ = jax.lax.scan(body, x, blocks_s)
                    return x

                def probe():
                    return stage(blocks_s, x0,
                                 positions).block_until_ready()

                return probe

            return build

        return cls(kind="lm", plan=plan, mesh=mesh, stage_axis=stage_axis,
                   n_microbatches=m, fill_s=stream.fill_s,
                   fill_blocked_s=stream.blocked_s,
                   overlap_streaming=overlap_streaming, run_fn=run,
                   probe_fns=[make_probe(s) for s in range(n_stages)])

    # -- execution ----------------------------------------------------------
    def __call__(self, batch: jax.Array) -> jax.Array:
        if self._closed:
            raise RuntimeError("executor is closed")
        return self._run(batch)

    def run_batch(self, items: Sequence[Any]) -> Tuple[List[Any], Dict]:
        """Host-executor-shaped batch entry: a list of unbatched items in,
        a list of outputs + a stats record out."""
        x = jnp.stack([jnp.asarray(i) for i in items])
        t0 = time.perf_counter()
        out = self(x)
        dt = time.perf_counter() - t0
        stats = {"batch_s": dt, "items_per_s": len(items) / dt,
                 "fill_s": self.fill_s,
                 "fill_blocked_s": self.fill_blocked_s,
                 "n_microbatches": self.n_microbatches}
        return [out[i] for i in range(len(items))], stats

    # -- modeled-vs-real probes ---------------------------------------------
    def predicted_stage_times(self) -> List[Optional[float]]:
        """The plan's modeled per-stage seconds (the placement DP's view)."""
        return list(self.plan.stage_times_s)

    def achieved_stage_times(self, reps: int = 5, warmup: int = 2
                             ) -> List[float]:
        """Each stage's fused callable timed in isolation on its own mesh
        device (median of ``reps``): the 'achieved' column of the
        modeled-vs-real loop."""
        times = []
        for build in self._probe_fns:
            probe = build()
            for _ in range(warmup):
                probe()
            samples = []
            for _ in range(reps):
                t0 = time.perf_counter()
                probe()
                samples.append(time.perf_counter() - t0)
            times.append(float(np.median(samples)))
        return times

    # -- lifecycle (host-executor parity) ------------------------------------
    def start(self) -> "SpmdPipelineExecutor":
        return self          # no worker threads to start

    def stop(self) -> None:
        self.close()

    def close(self) -> None:
        self._closed = True

    def __enter__(self) -> "SpmdPipelineExecutor":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
