"""XLA-backed MemoryReporter for the §6.1.3 refinement loop.

On the Edge TPU the paper re-compiles each candidate segment and reads the
compiler's memory report.  The pod-scale analogue: compile the segment's
stage function with ``.lower().compile()`` and read
``memory_analysis()`` — overflow = bytes beyond the per-device budget.
Used by tests and the serve planner when ``--refine xla`` is selected;
the analytical GraphReporter remains the fast default.
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..core.graph import LayerGraph
from ..models import api, lm
from ..models.lm import LMConfig


class XlaSegmentReporter:
    """MemoryReporter protocol over real XLA compiles of block ranges."""

    def __init__(self, cfg: LMConfig, graph: LayerGraph, budget_bytes: int,
                 batch: int = 1, seq: int = 128):
        self.cfg = cfg
        self.graph = graph
        self.budget = budget_bytes
        self.batch = batch
        self.seq = seq
        self._levels = graph.levels()
        self._bytes_per_depth = graph.bytes_per_depth()
        self._cache: Dict[Tuple[int, int], Tuple[int, int]] = {}
        self.compilations = 0

    def _block_range(self, depth_lo: int, depth_hi: int) -> Tuple[int, int]:
        """Map a depth range to a [lo, hi) block index range."""
        names = [n for lvl in self._levels[depth_lo:depth_hi + 1]
                 for n in lvl if n.startswith("block_")]
        if not names:
            return (0, 0)
        idxs = sorted(int(n.split("_")[1]) for n in names)
        return idxs[0], idxs[-1] + 1

    def segment_report(self, depth_lo: int, depth_hi: int) -> Tuple[int, int]:
        key = (depth_lo, depth_hi)
        if key in self._cache:
            return self._cache[key]
        cfg = self.cfg
        lo, hi = self._block_range(depth_lo, depth_hi)
        n_blocks = max(1, hi - lo)
        block_shapes = jax.eval_shape(
            lambda k: lm._stack_init(
                k, n_blocks, lambda kk: lm.init_block_params(cfg, kk,
                                                             cfg.dtype)),
            jax.ShapeDtypeStruct((2,), "uint32"))
        x_spec = jax.ShapeDtypeStruct((self.batch, self.seq, cfg.d_model),
                                      cfg.dtype)
        pos = jnp.arange(self.seq)[None, :]

        def stage(blocks, x):
            fn = lm._block_fn(cfg)

            def body(x, bp):
                return fn(x, bp, pos), None

            x, _ = jax.lax.scan(body, x, blocks)
            return x

        compiled = jax.jit(stage).lower(block_shapes, x_spec).compile()
        self.compilations += 1
        mem = compiled.memory_analysis()
        used = int(mem.argument_size_in_bytes + mem.output_size_in_bytes
                   + mem.temp_size_in_bytes)
        over = max(0, used - self.budget)
        self._cache[key] = (min(used, self.budget), over)
        return self._cache[key]

    def depth_bytes(self, depth: int) -> int:
        return self._bytes_per_depth[depth]
