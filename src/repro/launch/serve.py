"""Serving driver: batched requests through the balanced-segmented pipeline.

Demonstrates the paper's full deployment story at LM scale, on CPU:

1. build the arch's LayerGraph and run SEGM_BALANCED (vs SEGM_COMP) for
   ``--stages`` devices;
2. split the stacked block params by the plan; one host thread per stage
   with queues between (paper Fig. 5 executor) — or the SPMD
   shard_map/ppermute pipeline with ``--backend spmd`` (needs >=stages
   devices, e.g. ``XLA_FLAGS=--xla_force_host_platform_device_count=4``);
3. serve a *stream* of requests: each request is admitted into the
   pipeline as it arrives (no inter-batch barrier) and completes its own
   future; report throughput, per-request latency percentiles, and
   per-stage busy times (paper Fig. 10 metric) from the server's
   monotonic-counter snapshot() deltas.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-1.7b \
        --stages 4 --requests 15
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.api import DeploymentSpec, deploy
from repro.configs.common import concrete_batch
from repro.core.pipeline import (PipelineExecutor, ShapeKeyedStageCache,
                                 stage_balance_metrics)
from repro.models import api, lm, lm_graph


def make_stage_fns(cfg, params, counts, stage_cache=None):
    """Per-stage callables applying a contiguous block range (+ embed on
    stage 0, unembed on the last stage).

    Stage bodies are built lazily through a :class:`ShapeKeyedStageCache`:
    the jitted closure for a stage is constructed once per input
    shape/dtype and reused for every subsequent batch (pass a shared
    ``stage_cache`` to also reuse across executor/server restarts)."""
    offsets = np.concatenate([[0], np.cumsum(counts)]).astype(int)
    cache = stage_cache if stage_cache is not None else ShapeKeyedStageCache()

    def block_range_fn(lo, hi, first, last):
        def build():
            blocks = jax.tree.map(lambda a: a[lo:hi], params["blocks"])

            @jax.jit
            def run(x_or_tokens):
                if first:
                    x = lm.embed_tokens(cfg, params, x_or_tokens)
                else:
                    x = x_or_tokens
                s = x.shape[1]
                positions = jnp.arange(s)[None, :]
                fn = lm._block_fn(cfg)

                def body(x, bp):
                    return fn(x, bp, positions), None

                if hi > lo:
                    x, _ = jax.lax.scan(body, x, blocks)
                if last:
                    return lm.unembed(cfg, params, x[:, -1:])
                return x

            return run

        # first/last must be part of the key: two empty block ranges (e.g.
        # a final_norm-only stage vs the head stage) share lo == hi
        return cache.wrap(f"blocks[{lo}:{hi}]:f{int(first)}l{int(last)}",
                          build)

    fns = []
    for i, c in enumerate(counts):
        fns.append(block_range_fn(offsets[i], offsets[i + 1],
                                  i == 0, i == len(counts) - 1))
    return fns


def spec_from_args(args) -> DeploymentSpec:
    """CLI flags -> declarative DeploymentSpec (the repro.api front door).

    ``--device-budget`` switches to the joint cuts+replicas placement
    strategy over that many devices; otherwise ``--stages`` identical
    devices, one per stage, with the requested split strategy."""
    common = dict(
        model=f"lm:{args.arch}:seq={args.seq}",
        backend=getattr(args, "backend", "host"),
        microbatch=args.microbatch,
        microbatch_wait_s=args.microbatch_wait_ms / 1e3,
        max_batch=args.requests, max_wait_s=0.005,
        cost_source=args.cost_source,
        hedge_after=(getattr(args, "hedge_after_ms", 0.0) / 1e3
                     or None),
        stage_loss_retries=getattr(args, "stage_loss_retries", 0),
        deadline_ms=(getattr(args, "deadline_ms", 0.0) or None),
        shed_policy=getattr(args, "shed_policy", "none"),
        drift_threshold=getattr(args, "drift_threshold", 0.0),
        canary_requests=getattr(args, "canary_requests", 4))
    if getattr(args, "workload", "batch") == "decode":
        # decode plans at the (concurrency, max_context) operating point
        # with the per-token cost regime; see repro.decode
        return DeploymentSpec(
            strategy="decode_placement", stages=args.stages,
            workload="decode",
            max_context=getattr(args, "max_context", None) or None,
            decode_concurrency=(getattr(args, "decode_concurrency", None)
                                or None),
            **common)
    if args.device_budget:
        # joint cuts+replicas search: a bottleneck stage may get k devices
        # (round-robin fan-out in the executor, order-restoring fan-in)
        return DeploymentSpec(strategy="placement",
                              device_budget=args.device_budget, **common)
    return DeploymentSpec(strategy=args.strategy, stages=args.stages,
                          **common)


def run_decode(args) -> None:
    """``--workload decode``: KV-aware placement + continuous batching.

    Plans with the ``decode_placement`` strategy (per-token costs, KV cap
    at the operating point — works for *every* family, recurrent ones as
    O(1)-state blocks), then serves token streams through the
    :class:`~repro.decode.engine.DecodeServer` for the scan-block
    families."""
    from repro.decode import DECODE_FAMILIES

    cfg = configs.get(args.arch).smoke_config()
    g = lm_graph.lm_layer_graph(cfg, seq_len=args.seq)
    spec = spec_from_args(args)
    dep = deploy(spec, graph=g)
    pl = dep.plan
    print("plan:", pl.describe())
    print("report:", pl.report.describe())
    if cfg.family not in DECODE_FAMILIES:
        print(f"note: family {cfg.family!r} ({args.arch}) plans decode "
              f"placement (above) but the continuous-batching runtime "
              f"binds the scan-block families {DECODE_FAMILIES}; pick one "
              f"of those archs to stream tokens")
        return

    params = api.init(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab, size=8).astype(np.int32)
               for _ in range(args.requests)]
    with dep.serve(start=True, params=params) as srv:
        srv.submit(prompts[0], max_new_tokens=2).result(600)   # jit warmup
        srv.snapshot()                          # reset the delta window
        t0 = time.perf_counter()
        reqs = [srv.submit(p, max_new_tokens=args.max_new_tokens)
                for p in prompts]
        outs = [r.result(600) for r in reqs]
        dt = time.perf_counter() - t0
        snap = srv.snapshot()
    assert all(len(o) == args.max_new_tokens for o in outs), \
        [len(o) for o in outs]
    print(f"{len(outs)} streams x {args.max_new_tokens} tokens in "
          f"{dt*1e3:.1f} ms ({snap['tokens']/dt:.1f} tok/s, "
          f"{snap['steps']} batched steps)")
    print(f"inter-token p50/p95 (ms): "
          f"{snap['inter_token_p50_s']*1e3:.2f} / "
          f"{snap['inter_token_p95_s']*1e3:.2f}")
    print(f"modeled decode: {pl.report.decode_tokens_per_s:.1f} tok/s, "
          f"KV headroom {pl.report.kv_headroom_pct:.0f}%")


def run_fleet(args) -> None:
    """``--fleet fleet.json``: bring up a multi-tenant fleet from a spec
    document and drive the synthetic traffic scenario against it —
    weighted-fair routing, per-member SLOs, and a mid-run traffic shift
    the autoscaler chases (see EXPERIMENTS.md §Multi-tenant fleet)."""
    from repro.fleet import FleetSpec
    from repro.fleet.scenario import (FleetScenario, TrafficPhase,
                                      summarize_member)

    with open(args.fleet) as f:
        fspec = FleetSpec.from_json(f.read())
    names = list(fspec.member_names)
    print(f"fleet: {len(names)} members over "
          f"{fspec.pool().n_devices} devices: {names}")

    svc = args.fleet_service_ms / 1e3
    sc = FleetScenario(fspec, {n: svc for n in names})
    fleet = sc.deploy()
    counts0 = fleet.device_counts()
    print(f"pool split: {counts0} (mode={fleet.placement.mode}, "
          f"worst modeled norm "
          f"{fleet.placement.worst_norm:.2f})")

    # phase 1: share-proportional traffic; phase 2: the first member's
    # load triples (the shift the autoscaler must chase)
    base = {m.name: max(1, round(2 * m.share)) for m in fspec.members}
    shifted = dict(base)
    shifted[names[0]] = 3 * base[names[0]]
    with fleet:
        metrics = sc.drive(fleet, [
            TrafficPhase(windows=args.fleet_windows, rates=base),
            TrafficPhase(windows=args.fleet_windows, rates=shifted),
        ])
        counts1 = fleet.device_counts()
        events = ([] if fleet.autoscaler is None
                  else list(fleet.autoscaler.events))
    att = sc.attainment(metrics)
    for n in names:
        print(f"  {n}: {summarize_member(metrics[n])} "
              f"attainment={att[n]:.2f}")
    audit = sc.audit()
    moves = [e for e in events if e["event"] in ("commit", "rollback")]
    print(f"audit: {audit}")
    print(f"device split {counts0} -> {counts1}; "
          f"{sum(1 for e in moves if e['event'] == 'commit')} committed "
          f"moves, {sum(1 for e in moves if e['event'] == 'rollback')} "
          f"rollbacks")
    assert all(a["lost"] == 0 and a["misordered"] == 0
               for a in audit.values()), audit


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--stages", type=int, default=4)
    ap.add_argument("--requests", type=int, default=15)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--strategy", default="balanced",
                    choices=["balanced", "balanced_norefine", "comp"])
    ap.add_argument("--backend", default="host",
                    choices=["host", "spmd"],
                    help="execution tier: 'host' (threaded stage workers, "
                         "streaming admission) or 'spmd' (the plan lowered "
                         "onto a device mesh: shard_map + ppermute with "
                         "overlapped weight streaming; needs >= --stages "
                         "devices — set XLA_FLAGS="
                         "--xla_force_host_platform_device_count=N)")
    ap.add_argument("--microbatch", type=int, default=1,
                    help="stage-level dynamic micro-batching bucket size "
                         "(stack up to k same-shape in-flight requests "
                         "into one jitted call; 1 = off)")
    ap.add_argument("--microbatch-wait-ms", type=float, default=2.0,
                    help="max hold time for a micro-batch bucket to fill")
    ap.add_argument("--device-budget", type=int, default=0,
                    help="plan over this many devices with replicated "
                         "bottleneck stages (the 'placement' strategy; "
                         "0 = off, use --stages identical devices, one "
                         "per stage)")
    ap.add_argument("--hedge-after-ms", type=float, default=0.0,
                    help="speculatively re-dispatch an item stuck on a "
                         "replicated stage for this long to another "
                         "replica (first result wins; 0 = off)")
    ap.add_argument("--stage-loss-retries", type=int, default=0,
                    help="re-admit a request that crossed a dead stage "
                         "this many times (survives degraded-mode "
                         "replans; 0 = fail fast)")
    ap.add_argument("--deadline-ms", type=float, default=0.0,
                    help="per-request latency budget: a request past it "
                         "completes with DeadlineExceeded at admission or "
                         "merge exit instead of waiting unbounded (0 = "
                         "off)")
    ap.add_argument("--shed-policy", default="none",
                    choices=["none", "deadline"],
                    help="'deadline': shed requests at admission when the "
                         "queue-delay estimate (in_flight x service pace) "
                         "would outlive their deadline budget; callers "
                         "get Overloaded + a jittered retry_after_s hint")
    ap.add_argument("--drift-threshold", type=float, default=0.0,
                    help="relative modeled-vs-observed per-stage drift "
                         "past which the self-healing controller replans "
                         "from live telemetry (0 = loop off; see "
                         "EXPERIMENTS.md §Self-healing serving)")
    ap.add_argument("--canary-requests", type=int, default=4,
                    help="held-aside requests validating a candidate "
                         "executor before a guarded reconfigure commits")
    ap.add_argument("--workload", default="batch",
                    choices=["batch", "decode"],
                    help="'batch': prefill request/response serving "
                         "(default).  'decode': KV-cache-aware placement "
                         "(decode_placement strategy) + continuous-"
                         "batching token streaming; see EXPERIMENTS.md "
                         "§Decode serving")
    ap.add_argument("--max-context", type=int, default=128,
                    help="decode operating point: per-sequence KV budget "
                         "(prompt + generated tokens)")
    ap.add_argument("--decode-concurrency", type=int, default=4,
                    help="decode operating point: concurrent sequences in "
                         "the running batch")
    ap.add_argument("--max-new-tokens", type=int, default=16,
                    help="tokens generated per decode request")
    ap.add_argument("--cost-source", default="analytic",
                    help="where the planner's per-depth costs come from: "
                         "'analytic' (closed-form device model), "
                         "'trace:<path>' (a repro.profiling ProfileTrace "
                         "artifact), or 'calibrated:<path>' (analytic "
                         "model least-squares-fit to that trace); see "
                         "EXPERIMENTS.md §Profiling & calibration")
    ap.add_argument("--fleet", default="",
                    help="path to a FleetSpec JSON document: serve N "
                         "models on one shared device pool (SLO-driven "
                         "pool split, weighted-fair admission, "
                         "autoscaling) and drive the synthetic traffic "
                         "scenario against it; ignores the single-model "
                         "flags above")
    ap.add_argument("--fleet-windows", type=int, default=10,
                    help="traffic windows per fleet scenario phase")
    ap.add_argument("--fleet-service-ms", type=float, default=6.0,
                    help="synthetic whole-model service time per fleet "
                         "member (sleep-based stage fns)")
    args = ap.parse_args()

    if args.fleet:
        run_fleet(args)
        return
    if args.workload == "decode":
        run_decode(args)
        return

    mod = configs.get(args.arch)
    cfg = mod.smoke_config()
    if cfg.family not in ("dense", "moe", "vlm"):
        # every family plans via lm_graph; only the batch-serving runtime
        # binds scan-block stage functions.  Plan, report, and say so.
        g = lm_graph.lm_layer_graph(cfg, seq_len=args.seq)
        pl = deploy(spec_from_args(args), graph=g).plan
        print("plan:", pl.describe())
        print("report:", pl.report.describe())
        print(f"note: family {cfg.family!r} ({args.arch}) plans via "
              f"lm_graph (above) but the pipeline serving runtime binds "
              f"the scan-block families ('dense', 'moe', 'vlm'); pick one "
              f"of those archs to serve, or use --workload decode for "
              f"KV-aware decode planning")
        return
    params = api.init(cfg, jax.random.PRNGKey(0))

    g = lm_graph.lm_layer_graph(cfg, seq_len=args.seq)
    spec = spec_from_args(args)

    from repro.launch.pipeline_spmd import stage_block_counts

    def fns_for(p):
        counts = stage_block_counts(p, cfg.n_layers)
        return make_stage_fns(cfg, params, counts)

    dep = deploy(spec, graph=g, stage_fn_builder=fns_for)
    pl = dep.plan
    print("plan:", pl.describe())
    print("report:", pl.report.describe())
    print("blocks per stage:", stage_block_counts(pl, cfg.n_layers))

    reqs = [concrete_batch(cfg, args.seq, 1,
                           key=jax.random.PRNGKey(i),
                           kind="prefill")["tokens"]
            for i in range(args.requests)]

    if args.backend == "spmd":
        # batch path: the whole request set rides one mesh dispatch (the
        # SPMD tier has no streaming admission loop — that is the host
        # executor's job; see EXPERIMENTS.md §SPMD execution)
        ex = dep.executor(backend="spmd", model=cfg, params=params,
                          n_microbatches=max(1, args.microbatch),
                          batch_size=args.requests, seq_len=args.seq)
        if isinstance(ex, PipelineExecutor):     # replicated-plan fallback
            raise SystemExit("plan has replicated stages; rerun without "
                             "--device-budget or use --backend host")
        rows = [r[0] for r in reqs]              # (seq,) token rows
        with ex:
            ex.run_batch(rows[:1])               # warmup (compile)
            t0 = time.perf_counter()
            outs, stats = ex.run_batch(rows)
            dt = time.perf_counter() - t0
            print(f"{len(outs)} requests in {dt*1e3:.1f} ms "
                  f"({stats['items_per_s']:.1f} req/s, "
                  f"m={stats['n_microbatches']}, "
                  f"weight-stream fill {stats['fill_s']*1e3:.0f} ms)")
            print("predicted stage times (s):",
                  [round(t, 4) for t in ex.predicted_stage_times()])
            print("achieved stage times (s): ",
                  [round(t, 4) for t in ex.achieved_stage_times()])
        ref = api.forward(cfg, params, {"tokens": reqs[0]},
                          last_token_only=True)
        err = float(jnp.max(jnp.abs(outs[0][-1:] - ref[0])))
        print(f"pipeline vs direct max err: {err:.2e}")
        assert err < 2e-2
        return

    # persistent streaming executor: stage workers live for the whole
    # serving session; requests are admitted continuously (no barrier).
    # The Deployment handle owns the server wiring (spec's serving policy).
    with dep.serve() as server:
        server.serve_batch(reqs[:1])           # warmup (jit)
        server.start()                          # admission loop
        healer = None
        if args.drift_threshold > 0:
            # closed-loop calibration: live telemetry -> rolling trace ->
            # guarded (canary + rollback) replans; see runtime.selfheal
            healer = dep.self_heal(reqs[:args.canary_requests]).start()
        server.snapshot()                       # reset the delta window
        t0 = time.perf_counter()
        pending = [server.submit(r) for r in reqs]
        for req in pending:
            assert req.event.wait(300), f"request {req.rid} timed out"
        dt = time.perf_counter() - t0
        snap = server.snapshot()
        assert all(r.error is None for r in pending)
        outs = [r.result for r in pending]
        busy = snap["stage_busy_s"]
        metrics = stage_balance_metrics(busy)
        lat = snap["latency"]
        print(f"{len(outs)} requests in {dt*1e3:.1f} ms "
              f"({snap['throughput_rps']:.1f} req/s)")
        print(f"latency p50/p95/p99 (ms): {lat['p50_s']*1e3:.1f} / "
              f"{lat['p95_s']*1e3:.1f} / {lat['p99_s']*1e3:.1f}")
        print(f"stage busy (s): {[round(b,4) for b in busy]}")
        print(f"balance (mean/max): {metrics['balance']:.3f}")
        if healer is not None:
            healer.stop()
            print(f"self-heal: {healer.windows} windows, "
                  f"{healer.commits} commits, "
                  f"{healer.rollbacks} rollbacks "
                  f"(state={healer.state})")

        # reference check
        ref = api.forward(cfg, params, {"tokens": reqs[0]},
                          last_token_only=True)
        err = float(jnp.max(jnp.abs(outs[0] - ref)))
        print(f"pipeline vs direct max err: {err:.2e}")
        assert err < 2e-2


if __name__ == "__main__":
    main()
