"""HLO-text cost analysis with while-loop trip-count scaling.

``compiled.cost_analysis()`` counts a while-loop body ONCE, so a scanned
48-layer transformer reports ~1/48th of its real FLOPs; collectives inside
the scan are similarly undercounted.  This module parses the post-SPMD
optimized HLO (``compiled.as_text()``) and evaluates costs bottom-up,
multiplying while bodies by their trip counts:

* **flops**: every ``dot`` (2 * prod(result dims) * contracting size) and
  ``convolution`` — resolved through an instruction-shape map;
* **collective bytes**: operand bytes of all-gather / all-reduce /
  reduce-scatter / all-to-all / collective-permute (``-start`` variants
  counted once, ``-done`` skipped);
* **hbm bytes** (fusion-optimistic TPU model): the CPU backend materializes
  elementwise chains that a TPU build would fuse into neighbouring matmuls,
  so raw operand+result counting over-reports traffic by ~100x on
  softmax-heavy decode graphs.  We count traffic only at ops that *must*
  touch HBM at TPU fusion granularity — dot/convolution, reduce(-window),
  gather/scatter, sort, concatenate, copy, dynamic-(update-)slice (slice
  bytes only), and fusions whose root is one of these; pure elementwise
  producers are treated as fused into their consumers (their buffers are
  still counted once wherever a counted op reads them);
* **trip counts**: parsed from each while condition's comparison constant.

Known caveats (documented in EXPERIMENTS.md): CPU-backend HLO contains
bf16->f32 legalization converts that a TPU build would not have — flops of
converts are not counted; elementwise-dominated layers (rwkv ddlerp) may
undercount HBM traffic by up to ~2x; conditionals take the max over
branches.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s4": 1, "u4": 1, "s16": 2, "u16": 2,
    "f16": 2, "bf16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "token": 0, "f8e4m3fn": 1, "f8e5m2": 1,
}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_SHAPE_TOKEN = re.compile(
    r"\b(" + "|".join(_DTYPE_BYTES) + r")\[([0-9,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.*)$")
_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")
_ATTR_CALLS = re.compile(r"calls=%?([\w.\-]+)")
_ATTR_BODY = re.compile(r"body=%?([\w.\-]+)")
_ATTR_COND = re.compile(r"condition=%?([\w.\-]+)")
_ATTR_TO_APPLY = re.compile(r"to_apply=%?([\w.\-]+)")
_ATTR_BRANCHES = re.compile(r"branch_computations={([^}]*)}")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims={([0-9,]*)}")
_CONST_RE = re.compile(r"constant\((-?\d+)\)")

_SKIP_BYTES_OPS = ("parameter(", "constant(", "get-tuple-element(",
                   "bitcast(", "tuple(", "after-all(", "partition-id(",
                   "replica-id(")

# ops that materialize HBM traffic at TPU fusion granularity
_MEM_OPS = (" dot(", " convolution(", " reduce(", " reduce-window(",
            " gather(", " scatter(", " sort(", " concatenate(", " copy(",
            " dynamic-slice(", " cholesky(", " triangular-solve(",
            " rng(", " rng-bit-generator(", " fft(")


def _shape_list(type_str: str) -> List[Tuple[str, List[int]]]:
    """All dtype[dims] tokens in a type string (tuples give several)."""
    out = []
    for dt, dims in _SHAPE_TOKEN.findall(type_str):
        out.append((dt, [int(d) for d in dims.split(",") if d]))
    return out


def _bytes_of(type_str: str) -> int:
    total = 0
    for dt, dims in _shape_list(type_str):
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class Instr:
    name: str
    type_str: str
    body: str           # everything right of '='
    is_root: bool = False


@dataclasses.dataclass
class Computation:
    name: str
    instrs: List[Instr]


@dataclasses.dataclass
class CostTotals:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    coll_bytes: float = 0.0
    coll_by_kind: Optional[Dict[str, float]] = None
    coll_counts: Optional[Dict[str, float]] = None

    def __add__(self, o: "CostTotals") -> "CostTotals":
        kinds = {k: (self.coll_by_kind or {}).get(k, 0.0)
                 + (o.coll_by_kind or {}).get(k, 0.0)
                 for k in COLLECTIVES}
        counts = {k: (self.coll_counts or {}).get(k, 0.0)
                  + (o.coll_counts or {}).get(k, 0.0)
                  for k in COLLECTIVES}
        return CostTotals(self.flops + o.flops,
                          self.hbm_bytes + o.hbm_bytes,
                          self.coll_bytes + o.coll_bytes, kinds, counts)

    def scaled(self, f: float) -> "CostTotals":
        return CostTotals(
            self.flops * f, self.hbm_bytes * f, self.coll_bytes * f,
            {k: v * f for k, v in (self.coll_by_kind or {}).items()},
            {k: v * f for k, v in (self.coll_counts or {}).items()})


class HloCostModel:
    def __init__(self, hlo_text: str):
        self.computations: Dict[str, Computation] = {}
        self.shape_of: Dict[str, str] = {}
        self.entry: Optional[str] = None
        self._parse(hlo_text)
        self._memo: Dict[str, CostTotals] = {}

    # ------------------------------------------------------------------ parse
    def _parse(self, text: str) -> None:
        cur: Optional[Computation] = None
        for raw in text.splitlines():
            line = raw.strip()
            if not line:
                continue
            if line.endswith("{") and "->" in line:
                m = _COMP_HDR.match(line)
                if m:
                    cur = Computation(m.group(1), [])
                    self.computations[cur.name] = cur
                    if line.startswith("ENTRY"):
                        self.entry = cur.name
                    continue
            if line == "}":
                cur = None
                continue
            m = _DEF_RE.match(line)
            if m and cur is not None:
                name, rhs = m.group(1), m.group(2)
                instr = Instr(name, rhs, rhs,
                              is_root=line.lstrip().startswith("ROOT"))
                cur.instrs.append(instr)
                # record result type (first shape tokens before the op call)
                self.shape_of[name] = rhs

    # -------------------------------------------------------------- helpers
    def _result_bytes(self, instr: Instr) -> int:
        # result type is the prefix of rhs before the op name; taking the
        # first shape token (or tuple) is sufficient
        paren = instr.body.find("(")
        head = instr.body[:paren] if paren > 0 else instr.body
        return _bytes_of(head)

    def _operand_names(self, instr: Instr) -> List[str]:
        paren = instr.body.find("(")
        if paren < 0:
            return []
        depth, end = 0, len(instr.body)
        for i in range(paren, len(instr.body)):
            ch = instr.body[i]
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    end = i
                    break
        return _OPERAND_RE.findall(instr.body[paren:end])

    def _operand_bytes(self, instr: Instr) -> int:
        total = 0
        for name in self._operand_names(instr):
            rhs = self.shape_of.get(name)
            if rhs is None:
                continue
            paren = rhs.find("(")
            head = rhs[:paren] if paren > 0 else rhs
            total += _bytes_of(head)
        return total

    def _dot_flops(self, instr: Instr) -> float:
        out_shapes = _shape_list(instr.body[:instr.body.find(" dot(") + 1]
                                 or instr.body)
        if not out_shapes:
            return 0.0
        out_elems = 1
        for d in out_shapes[0][1]:
            out_elems *= d
        # contracting size from lhs operand shape + lhs_contracting_dims
        ops = self._operand_names(instr)
        m = _CONTRACT_RE.search(instr.body)
        contract = 1
        if ops and m:
            lhs_rhs = self.shape_of.get(ops[0], "")
            lhs_shapes = _shape_list(lhs_rhs[:lhs_rhs.find("(")]
                                     if "(" in lhs_rhs else lhs_rhs)
            if lhs_shapes:
                dims = lhs_shapes[0][1]
                for ax in m.group(1).split(","):
                    if ax:
                        ax_i = int(ax)
                        if ax_i < len(dims):
                            contract *= dims[ax_i]
        return 2.0 * out_elems * contract

    def _conv_flops(self, instr: Instr) -> float:
        # flops ~= 2 * out_elems * (kh*kw*cin/groups); parse kernel shape
        ops = self._operand_names(instr)
        out_shapes = _shape_list(instr.body[:instr.body.find("(")])
        if len(ops) < 2 or not out_shapes:
            return 0.0
        out_elems = 1
        for d in out_shapes[0][1]:
            out_elems *= d
        k_rhs = self.shape_of.get(ops[1], "")
        k_shapes = _shape_list(k_rhs[:k_rhs.find("(")]
                               if "(" in k_rhs else k_rhs)
        if not k_shapes:
            return 0.0
        kdims = k_shapes[0][1]
        # HWIO kernel: prod(all) / out_features ~= kh*kw*cin
        if not kdims:
            return 0.0
        per_out = 1
        for d in kdims:
            per_out *= d
        # divide by output-feature dim (last by HWIO / f in dims);
        # use max dim as feature heuristic-free: take dims[-1]
        per_out //= max(1, kdims[-1])
        return 2.0 * out_elems * per_out

    def _fusion_bytes(self, ins: Instr, called: str) -> int:
        """Fusion traffic at TPU granularity, decided by the fused root:
        dus-root -> slice bytes only; mem-op root -> operands + result;
        elementwise root -> fused away (0)."""
        res = self._result_bytes(ins)
        comp = self.computations.get(called)
        if comp:
            root = next((i for i in comp.instrs if i.is_root),
                        comp.instrs[-1] if comp.instrs else None)
            dus_bytes = 0
            for inner in comp.instrs:
                if " dynamic-update-slice(" in inner.body:
                    ops = self._operand_names(inner)
                    if len(ops) >= 2:
                        upd = self.shape_of.get(ops[1], "")
                        head = upd[:upd.find("(")] if "(" in upd else upd
                        dus_bytes += 2 * _bytes_of(head)
            if dus_bytes:
                # in-place scatter-write fusion (incl. tuple roots): only
                # the updated slices move
                return dus_bytes
            if any(" dynamic-slice(" in inner.body for inner in comp.instrs):
                # gather-from-big-buffer fusion: the source buffer is not
                # traffic, only the extracted slice (~ the fusion result)
                return 2 * res
            if root is not None and not any(op in root.body
                                            for op in _MEM_OPS):
                return 0                    # elementwise root: fused away
        return res + self._operand_bytes(ins)

    def _trip_count(self, cond_name: str) -> int:
        comp = self.computations.get(cond_name)
        if not comp:
            return 1
        consts = []
        for ins in comp.instrs:
            consts += [int(c) for c in _CONST_RE.findall(ins.body)]
        pos = [c for c in consts if c > 0]
        return max(pos) if pos else 1

    # ---------------------------------------------------------------- evaluate
    def computation_cost(self, name: str, top_level: bool = True
                         ) -> CostTotals:
        key = f"{name}@{top_level}"
        if key in self._memo:
            return self._memo[key]
        comp = self.computations.get(name)
        total = CostTotals(coll_by_kind={k: 0.0 for k in COLLECTIVES},
                           coll_counts={k: 0.0 for k in COLLECTIVES})
        if comp is None:
            return total
        self._memo[key] = total     # break cycles defensively
        for ins in comp.instrs:
            body = ins.body
            # --- nested computations -------------------------------------
            mb = _ATTR_BODY.search(body)
            if " while(" in body and mb:
                mc = _ATTR_COND.search(body)
                trips = self._trip_count(mc.group(1)) if mc else 1
                inner = self.computation_cost(mb.group(1), top_level=True)
                total = total + inner.scaled(trips)
                continue
            mcalls = _ATTR_CALLS.search(body)
            if " fusion(" in body and mcalls:
                # fusion: flops from inside; bytes as a single unit
                inner = self.computation_cost(mcalls.group(1),
                                              top_level=False)
                total = total + inner
                if top_level:
                    total.hbm_bytes += self._fusion_bytes(ins,
                                                          mcalls.group(1))
                continue
            mapply = _ATTR_TO_APPLY.search(body)
            if (" call(" in body or " custom-call(" in body) and mapply:
                total = total + self.computation_cost(mapply.group(1),
                                                      top_level)
                continue
            mbr = _ATTR_BRANCHES.search(body)
            if " conditional(" in body and mbr:
                branches = _OPERAND_RE.findall(mbr.group(1)) or [
                    b.strip().lstrip("%") for b in mbr.group(1).split(",")]
                costs = [self.computation_cost(b, top_level)
                         for b in branches if b]
                if costs:
                    total = total + max(costs, key=lambda c: c.flops)
                # fall through to count the conditional's own bytes
            # --- flops ---------------------------------------------------------
            if " dot(" in body:
                total.flops += self._dot_flops(ins)
            elif " convolution(" in body:
                total.flops += self._conv_flops(ins)
            # --- collectives ------------------------------------------------
            for kind in COLLECTIVES:
                if (f" {kind}(" in body or f" {kind}-start(" in body):
                    b = self._operand_bytes(ins)
                    total.coll_bytes += b
                    total.coll_by_kind[kind] += b
                    total.coll_counts[kind] += 1
                    break
            # --- hbm bytes ------------------------------------------------------
            if top_level and not any(s in body for s in _SKIP_BYTES_OPS):
                if " while(" in body or " tuple(" in body:
                    continue        # loop state is not traffic; body counted
                if " dynamic-update-slice(" in body:
                    # in-place update: traffic = the updated slice only
                    ops = self._operand_names(ins)
                    if len(ops) >= 2:
                        upd = self.shape_of.get(ops[1], "")
                        head = upd[:upd.find("(")] if "(" in upd else upd
                        total.hbm_bytes += 2 * _bytes_of(head)
                    continue
                if " dynamic-slice(" in body:
                    # slice read + write; the source buffer is not traffic
                    total.hbm_bytes += 2 * self._result_bytes(ins)
                    continue
                if any(op in body for op in _MEM_OPS):
                    total.hbm_bytes += (self._result_bytes(ins)
                                        + self._operand_bytes(ins))
                # pure elementwise at top level: assume fused (TPU model)
        self._memo[key] = total
        return total

    def entry_cost(self) -> CostTotals:
        assert self.entry, "no ENTRY computation found"
        return self.computation_cost(self.entry)


def analyze(hlo_text: str) -> CostTotals:
    return HloCostModel(hlo_text).entry_cost()
