"""Checkpoint store: atomic, integrity-checked pytree snapshots.

Fault-tolerance contract (runtime/ft.py builds on this):
* **atomic**: write to ``step_N.tmp/`` then rename — a crash mid-save never
  corrupts the latest checkpoint;
* **integrity**: every array file carries a CRC32 in metadata.json; restore
  verifies and falls back to the previous step on mismatch;
* **async**: ``save(..., blocking=False)`` snapshots to host memory
  synchronously (cheap) and writes to disk on a background thread, so the
  train loop is never blocked by I/O;
* **retention**: keeps the newest ``keep`` checkpoints.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import zlib
from typing import Any, List, Optional, Tuple

import jax
import numpy as np

Params = Any


class CheckpointStore:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._lock = threading.Lock()
        self._pending: Optional[threading.Thread] = None

    # -- paths ----------------------------------------------------------------
    def _step_dir(self, step: int) -> str:
        return os.path.join(self.dir, f"step_{step:010d}")

    def steps(self) -> List[int]:
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and not name.endswith(".tmp"):
                try:
                    out.append(int(name.split("_")[1]))
                except ValueError:
                    pass
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        s = self.steps()
        return s[-1] if s else None

    def has_checkpoint(self) -> bool:
        """True if at least one checkpoint exists — lets restart logic
        (``TrainSupervisor``, warm stage restore) distinguish "restore the
        latest snapshot" from "start clean" without trying a restore."""
        return bool(self.steps())

    # -- save -------------------------------------------------------------------
    def save(self, step: int, tree: Params, blocking: bool = True) -> None:
        # snapshot to host memory NOW (donated/updated arrays stay valid)
        leaves, treedef = jax.tree.flatten(tree)
        host = [np.asarray(l) for l in leaves]

        def write():
            tmp = self._step_dir(step) + ".tmp"
            final = self._step_dir(step)
            if os.path.exists(tmp):
                shutil.rmtree(tmp)
            os.makedirs(tmp)
            meta = {"step": step, "n_leaves": len(host), "crc": [],
                    "treedef": str(treedef)}
            for i, arr in enumerate(host):
                path = os.path.join(tmp, f"leaf_{i:05d}.npy")
                np.save(path, arr)
                with open(path, "rb") as f:
                    meta["crc"].append(zlib.crc32(f.read()))
            with open(os.path.join(tmp, "metadata.json"), "w") as f:
                json.dump(meta, f)
            with self._lock:
                if os.path.exists(final):
                    shutil.rmtree(final)
                os.rename(tmp, final)
                self._gc()

        if blocking:
            write()
        else:
            self.wait()
            self._pending = threading.Thread(target=write, daemon=True)
            self._pending.start()

    def wait(self) -> None:
        if self._pending is not None:
            self._pending.join()
            self._pending = None

    def _gc(self) -> None:
        steps = self.steps()
        for s in steps[:-self.keep]:
            shutil.rmtree(self._step_dir(s), ignore_errors=True)

    # -- restore ------------------------------------------------------------------
    def _verify(self, step: int) -> bool:
        d = self._step_dir(step)
        try:
            with open(os.path.join(d, "metadata.json")) as f:
                meta = json.load(f)
            for i, crc in enumerate(meta["crc"]):
                path = os.path.join(d, f"leaf_{i:05d}.npy")
                with open(path, "rb") as f:
                    if zlib.crc32(f.read()) != crc:
                        return False
            return True
        except (OSError, json.JSONDecodeError, KeyError):
            return False

    def restore(self, template: Params, step: Optional[int] = None
                ) -> Tuple[Optional[int], Params]:
        """Restore into the structure of `template`; returns (step, tree).
        Tries the latest verified checkpoint, falling back on corruption."""
        self.wait()
        candidates = ([step] if step is not None else
                      list(reversed(self.steps())))
        leaves_t, treedef = jax.tree.flatten(template)
        for s in candidates:
            if not self._verify(s):
                continue
            d = self._step_dir(s)
            leaves = [np.load(os.path.join(d, f"leaf_{i:05d}.npy"))
                      for i in range(len(leaves_t))]

            def cast(a, t):
                want = np.dtype(t.dtype)
                if a.dtype.kind == "V":          # ml_dtypes (bf16) roundtrip
                    a = a.view(want)
                return np.asarray(a, dtype=want).reshape(t.shape)

            out = jax.tree.unflatten(
                treedef, [cast(a, t) for a, t in zip(leaves, leaves_t)])
            return s, out
        return None, template
