from .pipeline import (DataConfig, SyntheticImageDataset, SyntheticLMDataset,
                       prefetch)

__all__ = ["DataConfig", "SyntheticLMDataset", "SyntheticImageDataset",
           "prefetch"]
